//! Held-out prediction / denoising with a fitted IBP feature model: fit
//! on train rows, then reconstruct noisy held-out images from their
//! inferred feature assignments — the downstream task that motivates
//! latent feature discovery in the paper's introduction.
//!
//! ```bash
//! cargo run --release --example heldout -- [n] [iters]
//! ```

use pibp::config::{RunConfig, SamplerKind};
use pibp::data::cambridge::{generate, CambridgeConfig};
use pibp::model::state::FeatureState;
use pibp::rng::Pcg64;
use pibp::runner;
use pibp::samplers::uncollapsed::{residuals, sweep_rows};
use pibp::viz;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n: usize = args.first().map_or(500, |s| s.parse().expect("n"));
    let iters: usize = args.get(1).map_or(80, |s| s.parse().expect("iters"));

    // fit on train rows
    let cfg = RunConfig {
        n,
        iters,
        sampler: SamplerKind::Hybrid,
        processors: 3,
        eval_every: 10,
        seed: 3,
        ..Default::default()
    };
    println!("fitting hybrid P=3 on cambridge N={n} ({iters} iterations)…");
    let out = runner::run(&cfg, |_| {})?;
    let params = out.final_params;
    println!("fitted: K⁺={} σ_X={:.3}\n", params.k(), params.lg.sigma_x);

    // fresh noisy test images from the same generative process
    let (test, z_true) = generate(&CambridgeConfig {
        n: 6,
        seed: 999,
        ..Default::default()
    });

    // infer Z for the test rows under the fitted model, then reconstruct
    let k = params.k();
    let mut z = FeatureState::empty(test.x.rows());
    z.add_features(k);
    let prior_logit: Vec<f64> = params
        .pi
        .iter()
        .map(|&p| (p.clamp(1e-9, 1.0 - 1e-9) / (1.0 - p.clamp(1e-9, 1.0 - 1e-9))).ln())
        .collect();
    let inv2s2 = 1.0 / (2.0 * params.lg.sigma_x * params.lg.sigma_x);
    let mut rng = Pcg64::new(11);
    let mut resid = residuals(&test.x, &z, &params.a, 0..test.x.rows());
    for _ in 0..20 {
        sweep_rows(
            &test.x, &mut z, &mut resid, &params.a, &prior_logit, inv2s2,
            0..test.x.rows(), k, &mut rng,
        );
    }
    let recon = z.to_mat().matmul(&params.a);

    let noise_mse = test.x.sub(&z_true.matmul(
        &pibp::data::cambridge::true_features(4))).frob2()
        / (test.x.rows() * test.x.cols()) as f64;
    let recon_mse = test.x.sub(&recon).frob2() / (test.x.rows() * test.x.cols()) as f64;
    println!("per-pixel MSE of noisy input vs clean truth: {noise_mse:.4} (= σ_X²)");
    println!("per-pixel MSE of reconstruction vs noisy input: {recon_mse:.4}");
    println!("(a good model reconstructs the *structure* and leaves ≈σ_X² of noise)\n");

    for i in 0..3 {
        let noisy = pibp::linalg::Mat::from_fn(1, 36, |_, j| test.x[(i, j)]);
        let rec = pibp::linalg::Mat::from_fn(1, 36, |_, j| recon[(i, j)]);
        println!("test image {i}: noisy input        reconstruction");
        let a = viz::render_features_ascii(&noisy);
        let b = viz::render_features_ascii(&rec);
        for (la, lb) in a.lines().zip(b.lines()) {
            println!("  {la}    {lb}");
        }
        println!();
    }
    Ok(())
}
