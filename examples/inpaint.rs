//! Image inpainting with a fitted IBP feature model (missing-data
//! extension): fit the hybrid sampler on complete training images, then
//! reconstruct held-out images in which a large fraction of the pixels is
//! hidden — inferring each image's feature assignments from the observed
//! pixels alone.
//!
//! ```bash
//! cargo run --release --example inpaint -- [missing_frac] [n] [iters]
//! ```

use pibp::config::{RunConfig, SamplerKind};
use pibp::data::cambridge::{generate, true_features, CambridgeConfig};
use pibp::linalg::Mat;
use pibp::model::missing::{masked_sweep, missing_mse, reconstruct, Mask};
use pibp::model::state::FeatureState;
use pibp::rng::Pcg64;
use pibp::runner;
use pibp::viz;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let missing: f64 = args.first().map_or(0.5, |s| s.parse().expect("frac"));
    let n: usize = args.get(1).map_or(400, |s| s.parse().expect("n"));
    let iters: usize = args.get(2).map_or(80, |s| s.parse().expect("iters"));

    // --- fit on complete data ---
    let cfg = RunConfig {
        n,
        iters,
        sampler: SamplerKind::Hybrid,
        processors: 3,
        eval_every: 10,
        seed: 5,
        ..Default::default()
    };
    println!("fitting hybrid P=3 on {n} complete images ({iters} iterations)…");
    let out = runner::run(&cfg, |_| {})?;
    let params = out.final_params;
    println!("fitted: K⁺={}, σ_X={:.3}\n", params.k(), params.lg.sigma_x);

    // --- fresh test images, hide `missing` of the pixels ---
    let (test, z_true) = generate(&CambridgeConfig { n: 40, seed: 777, ..Default::default() });
    let clean = z_true.matmul(&true_features(4));
    let mut rng = Pcg64::new(9);
    let mask = Mask::random(test.x.rows(), 36, missing, &mut rng);
    println!(
        "hiding {:.0}% of pixels on 40 fresh images ({} of {} entries observed)",
        missing * 100.0,
        mask.observed_count(),
        40 * 36
    );

    // --- infer Z from observed pixels only ---
    let k = params.k();
    let mut z = FeatureState::empty(test.x.rows());
    z.add_features(k);
    let prior_logit: Vec<f64> = params
        .pi
        .iter()
        .map(|&p| {
            let p = p.clamp(1e-9, 1.0 - 1e-9);
            (p / (1.0 - p)).ln()
        })
        .collect();
    let inv2s2 = 1.0 / (2.0 * params.lg.sigma_x * params.lg.sigma_x);
    for _ in 0..25 {
        masked_sweep(&test.x, &mask, &mut z, &params.a, &prior_logit, inv2s2, &mut rng);
    }
    let recon = reconstruct(&test.x, &mask, &z, &params.a);

    // --- score against the clean ground truth on the MISSING pixels ---
    let model_mse = missing_mse(&clean, &recon, &mask);
    // baselines
    let mut mean_fill = test.x.clone();
    for j in 0..36 {
        let (mut s, mut c) = (0.0f64, 0.0f64);
        for i in 0..test.x.rows() {
            if mask.observed(i, j) {
                s += test.x[(i, j)];
                c += 1.0;
            }
        }
        let mu = s / c.max(1.0);
        for i in 0..test.x.rows() {
            if !mask.observed(i, j) {
                mean_fill[(i, j)] = mu;
            }
        }
    }
    let mean_mse = missing_mse(&clean, &mean_fill, &mask);
    let zero_fill = Mat::from_fn(test.x.rows(), 36, |i, j| {
        if mask.observed(i, j) { test.x[(i, j)] } else { 0.0 }
    });
    let zero_mse = missing_mse(&clean, &zero_fill, &mask);

    println!("\nMSE on missing pixels vs clean truth:");
    println!("  zero fill          {zero_mse:.4}");
    println!("  column-mean fill   {mean_mse:.4}");
    println!("  IBP reconstruction {model_mse:.4}   ({:.1}× better than mean fill)",
             mean_mse / model_mse.max(1e-12));

    // show one example: clean | observed (masked=faded) | reconstruction
    println!("\nimage 0: clean                 reconstruction");
    let c0 = Mat::from_fn(1, 36, |_, j| clean[(0, j)]);
    let r0 = Mat::from_fn(1, 36, |_, j| recon[(0, j)]);
    let ca = viz::render_features_ascii(&c0);
    let ra = viz::render_features_ascii(&r0);
    for (l1, l2) in ca.lines().zip(ra.lines()) {
        println!("  {l1}    {l2}");
    }
    Ok(())
}
