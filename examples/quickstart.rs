//! Quickstart: infer latent features in a small synthetic image set with
//! the paper's parallel hybrid sampler, in ~a minute.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use pibp::config::{RunConfig, SamplerKind};
use pibp::data::cambridge;
use pibp::runner;
use pibp::viz;

fn main() -> anyhow::Result<()> {
    // 300 noisy 6×6 images, each a superposition of 4 latent glyphs
    let cfg = RunConfig {
        dataset: "cambridge".into(),
        n: 300,
        sampler: SamplerKind::Hybrid,
        processors: 3,
        sub_iters: 5,
        iters: 60,
        eval_every: 5,
        seed: 7,
        ..Default::default()
    };
    println!("pibp quickstart — hybrid parallel MCMC, P=3, N={}", cfg.n);
    println!("(paper: Zhang, Dubey & Williamson 2017)\n");

    let out = runner::run(&cfg, |i| {
        if i % 10 == 0 {
            println!("  iteration {i}…");
        }
    })?;

    let last = out.trace.last().unwrap();
    println!("\nconverged: K⁺={} features, σ_X={:.3}, α={:.2}", last.k, last.sigma_x, last.alpha);
    println!("held-out joint log P(X,Z) plateau: {:.1}\n", out.trace.plateau(0.25));

    println!("true glyphs:");
    println!("{}", viz::render_features_ascii(&cambridge::true_features(4)));
    println!("posterior loadings:");
    println!("{}", viz::render_features_ascii(&out.features));
    println!("(the 4 true glyphs should be recognisable among the posterior features,");
    println!(" up to permutation and the odd low-weight noise feature — compare Fig. 2)");
    Ok(())
}
