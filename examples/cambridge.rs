//! The paper's evaluation (Figures 1 and 2) end-to-end on the canonical
//! 1000×36 Cambridge data set: collapsed baseline vs hybrid P ∈ {1,3,5},
//! held-out joint log P(X,Z) over (virtual) time, and the posterior
//! feature images.
//!
//! ```bash
//! cargo run --release --example cambridge -- [iters] [n] [backend]
//! # defaults: 200 iterations, N=1000, native
//! ```
//!
//! This is the END-TO-END VALIDATION driver recorded in EXPERIMENTS.md:
//! it exercises all three layers (rust coordinator → PJRT-loaded HLO when
//! backend=pjrt → Pallas-kernel semantics) on the paper's real workload.

use std::path::Path;

use pibp::config::{RunConfig, SamplerKind};
use pibp::data::cambridge;
use pibp::metrics::Trace;
use pibp::runner;
use pibp::viz;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let iters: usize = args.first().map_or(200, |s| s.parse().expect("iters"));
    let n: usize = args.get(1).map_or(1000, |s| s.parse().expect("n"));
    let backend = args.get(2).map_or("native", |s| s.as_str());

    let mut base = RunConfig { n, iters, eval_every: 5, seed: 0, ..Default::default() };
    base.apply("backend", backend)?;
    println!("=== Cambridge reproduction: N={n}, D=36, {iters} iterations, L=5, backend={backend} ===\n");

    // ---------- Figure 1 ----------
    let mut traces: Vec<Trace> = Vec::new();
    let mut cfg = base.clone();
    cfg.sampler = SamplerKind::Collapsed;
    println!("[fig1] collapsed baseline…");
    traces.push(runner::run(&cfg, |_| {})?.trace);
    let mut hybrid_features = None;
    for p in [1usize, 3, 5] {
        let mut cfg = base.clone();
        cfg.sampler = SamplerKind::Hybrid;
        cfg.processors = p;
        println!("[fig1] hybrid P={p}…");
        let out = runner::run(&cfg, |_| {})?;
        if p == 5 {
            hybrid_features = Some((out.final_k, out.features.clone()));
        }
        traces.push(out.trace);
    }

    println!("\n--- Figure 1 series (held-out log P(X,Z) vs virtual seconds) ---");
    println!("{:<16} {:>12} {:>14} {:>10}", "sampler", "plateau", "t to plateau-5", "final K");
    let mut collapsed_plateau = f64::NEG_INFINITY;
    for t in &traces {
        if t.label.starts_with("collapsed") {
            collapsed_plateau = t.plateau(0.25);
        }
    }
    for t in &traces {
        let plat = t.plateau(0.25);
        let t_to = t
            .time_to(collapsed_plateau - 5.0)
            .map_or("n/a".into(), |s| format!("{s:.2}s"));
        println!(
            "{:<16} {:>12.1} {:>14} {:>10}",
            t.label, plat, t_to, t.last().unwrap().k
        );
        t.save_csv(Path::new("results/cambridge").join(format!("{}.csv", t.label)).as_path())?;
    }
    println!("(paper shape: all plateaus agree; more processors reach it sooner in");
    println!(" virtual time; hybrid P=1 beats pure collapsed on time-to-quality)");

    // ---------- Figure 2 ----------
    println!("\n--- Figure 2: features ---");
    let truth = cambridge::true_features(base.k_true);
    println!("true glyphs:\n{}", viz::render_features_ascii(&truth));
    if let Some((k, feats)) = hybrid_features {
        println!("hybrid P=5 posterior (K={k}):\n{}", viz::render_features_ascii(&feats));
        viz::save_feature_grid(Path::new("results/cambridge/hybrid_p5.pgm"), &feats, 8)?;
    }
    viz::save_feature_grid(Path::new("results/cambridge/true.pgm"), &truth, 8)?;
    println!("CSV traces + PGM images → results/cambridge/");
    Ok(())
}
