//! Multi-chain convergence diagnostics: run C replica hybrid chains
//! through `runner::run_multi` — the engine behind `pibp run --chains C`
//! — which streams per-chain ESS and cross-chain split-R̂ (Gelman–Rubin)
//! over the kept trace scalars while the chains run, then re-score the
//! post-warmup halves offline with the batch estimators (what
//! `pibp diagnose` does to exported traces).
//!
//! ```bash
//! cargo run --release --example diagnostics -- [chains] [iters] [n]
//! ```
//!
//! The CLI equivalent, including `--until` early stopping:
//!
//! ```bash
//! pibp run --chains 4 --until 'rhat<1.05,ess>100' --trace-out t.json
//! pibp diagnose --trace t.c0.json --trace t.c1.json --trace t.c2.json --trace t.c3.json
//! ```

use pibp::config::{RunConfig, SamplerKind};
use pibp::metrics::{ess, split_rhat};
use pibp::runner;
use pibp::viz::plot_traces;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let chains: usize = args.first().map_or(4, |s| s.parse().expect("chains"));
    let iters: usize = args.get(1).map_or(120, |s| s.parse().expect("iters"));
    let n: usize = args.get(2).map_or(300, |s| s.parse().expect("n"));

    println!("running {chains} replica hybrid chains (P=3, N={n}, {iters} iters)…");
    let cfg = RunConfig {
        n,
        iters,
        sampler: SamplerKind::Hybrid,
        processors: 3,
        eval_every: 2,
        chains,
        ..Default::default()
    };
    let out = runner::run_multi(&cfg, |_| {})?;
    for (c, chain) in out.chains.iter().enumerate() {
        println!(
            "  chain {c} (seed {}): plateau {:.1}, final K {}",
            runner::chain_seed(cfg.seed, c),
            chain.trace.plateau(0.3),
            chain.final_k
        );
    }

    // the streaming estimators' view of the whole run (no warm-up cut)
    print!("\n{}", out.diag.render());

    // offline re-score: discard the first half as warm-up, diagnose the
    // second half with the batch estimators — the pibp diagnose view
    let traces: Vec<_> = out.chains.into_iter().map(|c| c.trace).collect();
    let series = |f: &dyn Fn(&pibp::metrics::TracePoint) -> f64| -> Vec<Vec<f64>> {
        traces
            .iter()
            .map(|t| {
                let pts = &t.points[t.points.len() / 2..];
                pts.iter().map(|p| f(p)).collect()
            })
            .collect()
    };
    let heldout = series(&|p| p.heldout);
    let sigma = series(&|p| p.sigma_x);
    let kfeat = series(&|p| p.k as f64);

    println!("\npost-warmup (second half), batch estimators:");
    println!("| quantity  |   split-R̂ | min ESS (per chain) |");
    println!("|-----------|-----------|---------------------|");
    for (name, chains_data) in
        [("heldout", &heldout), ("sigma_x", &sigma), ("K", &kfeat)]
    {
        let r = split_rhat(chains_data);
        let min_ess = chains_data
            .iter()
            .map(|c| ess(c))
            .fold(f64::INFINITY, f64::min);
        println!("| {name:<9} | {r:>9.3} | {min_ess:>19.1} |");
    }
    println!("\n(rule of thumb: split-R̂ < 1.1 ⇒ chains agree)");

    let refs: Vec<&pibp::metrics::Trace> = traces.iter().collect();
    println!("\nheld-out joint vs log10 virtual time, all chains:\n");
    println!("{}", plot_traces(&refs, 72, 16, true));
    Ok(())
}
