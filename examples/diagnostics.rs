//! Multi-chain convergence diagnostics: run C independent hybrid chains,
//! report split-R̂ (Gelman–Rubin) on the held-out joint, σ_X and K, plus
//! per-chain ESS — the workflow a practitioner uses to decide whether the
//! sampler has converged before trusting Figure-1 style comparisons.
//!
//! ```bash
//! cargo run --release --example diagnostics -- [chains] [iters] [n]
//! ```

use pibp::config::{RunConfig, SamplerKind};
use pibp::metrics::{ess, split_rhat};
use pibp::runner;
use pibp::viz::plot_traces;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let chains: usize = args.first().map_or(4, |s| s.parse().expect("chains"));
    let iters: usize = args.get(1).map_or(120, |s| s.parse().expect("iters"));
    let n: usize = args.get(2).map_or(300, |s| s.parse().expect("n"));

    println!("running {chains} independent hybrid chains (P=3, N={n}, {iters} iters)…");
    let mut traces = Vec::new();
    for c in 0..chains {
        let cfg = RunConfig {
            n,
            iters,
            sampler: SamplerKind::Hybrid,
            processors: 3,
            eval_every: 2,
            seed: 1000 + c as u64,
            ..Default::default()
        };
        let out = runner::run(&cfg, |_| {})?;
        println!(
            "  chain {c}: plateau {:.1}, final K {}",
            out.trace.plateau(0.3),
            out.final_k
        );
        traces.push(out.trace);
    }

    // discard the first half as warm-up, diagnose the second half
    let series = |f: &dyn Fn(&pibp::metrics::TracePoint) -> f64| -> Vec<Vec<f64>> {
        traces
            .iter()
            .map(|t| {
                let pts = &t.points[t.points.len() / 2..];
                pts.iter().map(|p| f(p)).collect()
            })
            .collect()
    };
    let heldout = series(&|p| p.heldout);
    let sigma = series(&|p| p.sigma_x);
    let kfeat = series(&|p| p.k as f64);

    println!("\n| quantity  |   split-R̂ | min ESS (per chain) |");
    println!("|-----------|-----------|---------------------|");
    for (name, chains_data) in
        [("heldout", &heldout), ("sigma_x", &sigma), ("K", &kfeat)]
    {
        let r = split_rhat(chains_data);
        let min_ess = chains_data
            .iter()
            .map(|c| ess(c))
            .fold(f64::INFINITY, f64::min);
        println!("| {name:<9} | {r:>9.3} | {min_ess:>19.1} |");
    }
    println!("\n(rule of thumb: split-R̂ < 1.1 ⇒ chains agree)");

    let refs: Vec<&pibp::metrics::Trace> = traces.iter().collect();
    println!("\nheld-out joint vs log10 virtual time, all chains:\n");
    println!("{}", plot_traces(&refs, 72, 16, true));
    Ok(())
}
