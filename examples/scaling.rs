//! Strong-scaling study (our T-S1): virtual-time speedup of the hybrid
//! sampler as processors increase on a 4× Cambridge workload, with the
//! per-iteration breakdown (compute vs master vs comm) the paper's §5
//! discussion is about.
//!
//! ```bash
//! cargo run --release --example scaling -- [n] [iters]
//! ```

use pibp::config::{Backend, CommModel};
use pibp::coordinator::{Coordinator, CoordinatorConfig};
use pibp::data::cambridge::{generate, CambridgeConfig};
use pibp::model::LinGauss;
use pibp::samplers::SamplerOptions;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n: usize = args.first().map_or(4000, |s| s.parse().expect("n"));
    let iters: usize = args.get(1).map_or(30, |s| s.parse().expect("iters"));
    let (ds, _) = generate(&CambridgeConfig { n, seed: 1, ..Default::default() });

    println!("=== strong scaling: hybrid on cambridge N={n}, {iters} iterations ===\n");
    println!(
        "{:>3} {:>12} {:>12} {:>12} {:>12} {:>10} {:>9}",
        "P", "vtime/iter", "worker max", "master", "comm bytes", "speedup", "efficy"
    );
    let mut t1 = 0.0f64;
    for p in [1usize, 2, 3, 5, 8] {
        let cfg = CoordinatorConfig {
            processors: p,
            sub_iters: 5,
            threads_per_worker: 1,
            seed: 42,
            lg: LinGauss::new(0.5, 1.0),
            alpha: 1.0,
            opts: SamplerOptions::default(),
            backend: Backend::Native,
            artifacts_dir: "artifacts".into(),
            comm: CommModel::default(),
            ..Default::default()
        };
        let mut coord = Coordinator::new(&ds.x, cfg)?;
        let (mut vt, mut wb, mut mb, mut cb) = (0.0, 0.0, 0.0, 0usize);
        for _ in 0..iters {
            let r = coord.step()?;
            vt += r.vtime_iter_s;
            wb += r.max_worker_busy_s;
            mb += r.master_busy_s;
            cb += r.comm_bytes;
        }
        let per = vt / iters as f64;
        if p == 1 {
            t1 = per;
        }
        let speedup = t1 / per;
        println!(
            "{p:>3} {:>11.4}s {:>11.4}s {:>11.4}s {:>12} {:>9.2}x {:>8.0}%",
            per,
            wb / iters as f64,
            mb / iters as f64,
            cb / iters,
            speedup,
            100.0 * speedup / p as f64
        );
    }
    println!("\n(speedup is sub-linear because the master's global step and the");
    println!(" gather/broadcast are serial — the bottleneck the paper's §5 names)");
    Ok(())
}
