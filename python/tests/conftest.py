"""Shared fixtures + deterministic hypothesis profile for kernel tests."""

import os
import sys

import numpy as np
import pytest
from hypothesis import HealthCheck, settings

# Tests run from python/ via `python -m pytest tests/`; make `compile`
# importable when invoked from the repo root too.
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

settings.register_profile(
    "ci",
    deadline=None,
    max_examples=12,
    suppress_health_check=[HealthCheck.too_slow, HealthCheck.data_too_large],
)
settings.load_profile("ci")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(20170711)


def make_problem(rng, b, k, d, masked_rows=0, masked_feats=0, sigma_x=0.5):
    """Random (x, z, a, prior_logit, u, inv2s2, row_mask, k_mask) instance."""
    x = rng.normal(size=(b, d)).astype(np.float32)
    z = (rng.random((b, k)) < 0.3).astype(np.float32)
    a = rng.normal(size=(k, d)).astype(np.float32)
    pi = np.clip(rng.random(k), 0.05, 0.95).astype(np.float32)
    prior_logit = np.log(pi / (1 - pi)).astype(np.float32)
    if masked_feats:
        prior_logit[k - masked_feats:] = -1e30
        z[:, k - masked_feats:] = 0.0
    u = rng.random((b, k)).astype(np.float32)
    row_mask = np.ones(b, np.float32)
    if masked_rows:
        row_mask[b - masked_rows:] = 0.0
        z[b - masked_rows:] = 0.0
    k_mask = np.ones(k, np.float32)
    if masked_feats:
        k_mask[k - masked_feats:] = 0.0
    inv2s2 = np.float32(1.0 / (2.0 * sigma_x * sigma_x))
    return x, z, a, prior_logit, u, inv2s2, row_mask, k_mask
