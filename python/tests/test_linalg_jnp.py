"""Plain-HLO Cholesky/triangular solves vs numpy LAPACK reference."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given
from hypothesis import strategies as st

from compile.kernels import linalg_jnp


def random_spd(rng, k):
    b = rng.normal(size=(k + 3, k))
    return (b.T @ b + 0.5 * np.eye(k)).astype(np.float32)


@given(k=st.integers(1, 24), seed=st.integers(0, 2**31 - 1))
def test_cholesky_matches_lapack(k, seed):
    rng = np.random.default_rng(seed)
    a = random_spd(rng, k)
    got = np.asarray(linalg_jnp.cholesky(jnp.asarray(a)))
    want = np.linalg.cholesky(a.astype(np.float64))
    np.testing.assert_allclose(got, want, atol=5e-3, rtol=5e-3)
    # strictly lower-triangular output
    assert np.abs(np.triu(got, 1)).max() == 0.0


@given(k=st.integers(1, 16), d=st.integers(1, 8), seed=st.integers(0, 2**31 - 1))
def test_solves_match(k, d, seed):
    rng = np.random.default_rng(seed)
    a = random_spd(rng, k)
    b = rng.normal(size=(k, d)).astype(np.float32)
    l = np.linalg.cholesky(a.astype(np.float64)).astype(np.float32)
    y_got = np.asarray(linalg_jnp.solve_lower(jnp.asarray(l), jnp.asarray(b)))
    y_want = np.linalg.solve(l.astype(np.float64), b)
    np.testing.assert_allclose(y_got, y_want, atol=5e-3, rtol=5e-3)
    x_got = np.asarray(linalg_jnp.solve_upper_t(jnp.asarray(l), jnp.asarray(b)))
    x_want = np.linalg.solve(l.T.astype(np.float64), b)
    np.testing.assert_allclose(x_got, x_want, atol=5e-3, rtol=5e-3)


@given(k=st.integers(1, 16), seed=st.integers(0, 2**31 - 1))
def test_psd_solve_and_logdet(k, seed):
    rng = np.random.default_rng(seed)
    a = random_spd(rng, k)
    b = rng.normal(size=(k, 3)).astype(np.float32)
    x, logdet = linalg_jnp.psd_solve(jnp.asarray(a), jnp.asarray(b))
    x_want = np.linalg.solve(a.astype(np.float64), b)
    np.testing.assert_allclose(np.asarray(x), x_want, atol=1e-2, rtol=1e-2)
    _, ld_want = np.linalg.slogdet(a.astype(np.float64))
    np.testing.assert_allclose(float(logdet), ld_want, rtol=1e-3, atol=1e-3)


def test_masked_identity_rows():
    """The apost path feeds masked features as identity rows: chol of
    blockdiag(M, I) must leave the masked block as I."""
    a = np.eye(6, dtype=np.float32)
    a[:3, :3] = random_spd(np.random.default_rng(0), 3)
    l = np.asarray(linalg_jnp.cholesky(jnp.asarray(a)))
    np.testing.assert_allclose(l[3:, 3:], np.eye(3), atol=1e-6)
    np.testing.assert_allclose(l[3:, :3], 0.0, atol=1e-6)
