"""Pallas rowloglik kernel vs oracle + Gaussian sanity checks."""

import numpy as np
from hypothesis import given
from hypothesis import strategies as st

from compile.kernels import ref
from compile.kernels.loglik import rowloglik

from .conftest import make_problem


def _logdet_term(d, sigma_x):
    return np.float32(-0.5 * d * np.log(2.0 * np.pi * sigma_x * sigma_x))


@given(
    b=st.sampled_from([16, 64, 256]),
    k=st.sampled_from([4, 16]),
    d=st.sampled_from([4, 36]),
    masked_rows=st.integers(0, 10),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_ref(b, k, d, masked_rows, seed):
    rng = np.random.default_rng(seed)
    x, z, a, _, _, inv, rm, _ = make_problem(rng, b, k, d,
                                             masked_rows=masked_rows)
    ld = _logdet_term(d, 0.5)
    pr_r, tot_r = ref.rowloglik_ref(x, z, a, inv, ld, rm)
    pr_k, tot_k = rowloglik(x, z, a, inv, ld, rm)
    np.testing.assert_allclose(np.asarray(pr_r), np.asarray(pr_k),
                               atol=1e-3, rtol=1e-4)
    np.testing.assert_allclose(float(tot_r), float(tot_k), rtol=1e-4)


def test_exact_gaussian_value(rng):
    """Exact hand-computed density for a 1-row problem."""
    d, sx = 3, 0.7
    x = np.array([[1.0, -2.0, 0.5]], np.float32)
    z = np.ones((1, 1), np.float32)
    a = np.array([[1.0, -1.5, 0.0]], np.float32)
    r = x - a
    expect = (-0.5 * d * np.log(2 * np.pi * sx**2)
              - float((r * r).sum()) / (2 * sx**2))
    _, tot = rowloglik(x, z, a, np.float32(1 / (2 * sx**2)),
                       _logdet_term(d, sx), np.ones(1, np.float32))
    np.testing.assert_allclose(float(tot), expect, rtol=1e-5)


def test_perfect_fit_maximises(rng):
    """x == zA gives the maximum attainable per-row loglik."""
    b, k, d = 32, 4, 8
    z = (rng.random((b, k)) < 0.5).astype(np.float32)
    a = rng.normal(size=(k, d)).astype(np.float32)
    x = (z @ a).astype(np.float32)
    ld = _logdet_term(d, 0.5)
    inv = np.float32(1 / (2 * 0.25))
    pr, _ = rowloglik(x, z, a, inv, ld, np.ones(b, np.float32))
    np.testing.assert_allclose(np.asarray(pr), ld, atol=1e-4)
    x2 = x + 1.0
    pr2, _ = rowloglik(x2, z, a, inv, ld, np.ones(b, np.float32))
    assert (np.asarray(pr2) < np.asarray(pr)).all()
