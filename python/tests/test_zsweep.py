"""Pallas zsweep kernel vs the pure-jnp oracle (ref.zsweep_ref).

The sweep is the hybrid sampler's hot path; the rust coordinator executes
its AOT-lowered form on every worker every sub-iteration, so bit-exact
agreement with the reference semantics is the core correctness signal.
"""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref
from compile.kernels.zsweep import zsweep, zsweep_block_height, vmem_bytes

from .conftest import make_problem


def run_both(x, z, a, prior_logit, u, inv2s2, row_mask, **kw):
    zr, rr, mr = ref.zsweep_ref(x, z, a, prior_logit, u, inv2s2, row_mask)
    zk, rk, mk = zsweep(x, z, a, prior_logit, u, inv2s2, row_mask, **kw)
    return (np.asarray(zr), np.asarray(rr), np.asarray(mr),
            np.asarray(zk), np.asarray(rk), np.asarray(mk))


@given(
    b=st.sampled_from([16, 32, 64, 128]),
    k=st.sampled_from([4, 8, 16, 32]),
    d=st.sampled_from([4, 12, 36]),
    seed=st.integers(0, 2**31 - 1),
)
@settings(max_examples=20)
def test_matches_ref_hypothesis(b, k, d, seed):
    rng = np.random.default_rng(seed)
    x, z, a, pl_, u, inv, rm, _ = make_problem(rng, b, k, d)
    zr, rr, mr, zk, rk, mk = run_both(x, z, a, pl_, u, inv, rm)
    np.testing.assert_array_equal(zr, zk)
    np.testing.assert_allclose(rr, rk, atol=1e-4, rtol=1e-4)
    np.testing.assert_array_equal(mr, mk)


@given(
    masked_rows=st.integers(0, 15),
    masked_feats=st.integers(0, 7),
    seed=st.integers(0, 2**31 - 1),
)
def test_masking(masked_rows, masked_feats, seed):
    rng = np.random.default_rng(seed)
    b, k, d = 64, 8, 12
    x, z, a, pl_, u, inv, rm, _ = make_problem(
        rng, b, k, d, masked_rows=masked_rows, masked_feats=masked_feats
    )
    _, _, _, zk, _, mk = run_both(x, z, a, pl_, u, inv, rm)
    if masked_rows:
        assert zk[b - masked_rows:].sum() == 0, "padded rows must stay zero"
    if masked_feats:
        assert zk[:, k - masked_feats:].sum() == 0, "masked feats stay off"
        assert (mk[k - masked_feats:] == 0).all()
    # column counts consistent with returned Z
    np.testing.assert_array_equal(mk, (zk * rm[:, None]).sum(0))


def test_residual_is_consistent(rng):
    """r_new returned by the kernel must equal x - z_new @ a."""
    x, z, a, pl_, u, inv, rm, _ = make_problem(rng, 64, 16, 36)
    _, _, _, zk, rk, _ = run_both(x, z, a, pl_, u, inv, rm)
    np.testing.assert_allclose(rk, x - zk @ a, atol=1e-3, rtol=1e-3)


def test_block_height_invariance(rng):
    """Different VMEM tilings must produce identical samples."""
    x, z, a, pl_, u, inv, rm, _ = make_problem(rng, 128, 8, 12)
    z1, r1, m1 = zsweep(x, z, a, pl_, u, inv, rm, block_height=16)
    z2, r2, m2 = zsweep(x, z, a, pl_, u, inv, rm, block_height=128)
    np.testing.assert_array_equal(np.asarray(z1), np.asarray(z2))
    np.testing.assert_allclose(np.asarray(r1), np.asarray(r2), atol=1e-4)
    np.testing.assert_array_equal(np.asarray(m1), np.asarray(m2))


def test_deterministic_given_uniforms(rng):
    x, z, a, pl_, u, inv, rm, _ = make_problem(rng, 64, 8, 12)
    z1, _, _ = zsweep(x, z, a, pl_, u, inv, rm)
    z2, _, _ = zsweep(x, z, a, pl_, u, inv, rm)
    np.testing.assert_array_equal(np.asarray(z1), np.asarray(z2))


def test_extreme_prior_forces_bits(rng):
    """prior_logit = +-huge pins bits on/off regardless of likelihood."""
    x, z, a, _, u, inv, rm, _ = make_problem(rng, 32, 4, 8)
    on = np.full(4, 60.0, np.float32)
    zk, _, _ = zsweep(x, z, a, on, u, inv, rm)
    assert np.asarray(zk).min() == 1.0
    off = np.full(4, -60.0, np.float32)
    zk, _, _ = zsweep(x, z, a, off, u, inv, rm)
    assert np.asarray(zk).max() == 0.0


def test_gibbs_moves_towards_truth(rng):
    """Starting from all-zero Z with the true A and a strong signal, one
    sweep should recover most of the true assignment pattern."""
    b, k, d = 128, 4, 36
    z_true = (rng.random((b, k)) < 0.5).astype(np.float32)
    a = (3.0 * rng.normal(size=(k, d))).astype(np.float32)
    x = (z_true @ a + 0.1 * rng.normal(size=(b, d))).astype(np.float32)
    pl_ = np.zeros(k, np.float32)  # pi = 0.5
    u = rng.random((b, k)).astype(np.float32)
    inv = np.float32(1.0 / (2.0 * 0.1**2))
    zk, _, _ = zsweep(x, np.zeros((b, k), np.float32), a, pl_, u, inv,
                      np.ones(b, np.float32))
    agree = (np.asarray(zk) == z_true).mean()
    assert agree > 0.9, f"sweep should track truth, agreement={agree}"


def test_vmem_budget():
    """Chosen block heights must respect the VMEM budget model."""
    for b, k, d in [(1024, 32, 36), (256, 8, 36), (1024, 64, 36)]:
        bt = zsweep_block_height(b, k, d)
        assert b % bt == 0 or bt <= b
        assert vmem_bytes(bt, k, d) <= 8 * 1024 * 1024


def test_bad_block_height_raises(rng):
    x, z, a, pl_, u, inv, rm, _ = make_problem(rng, 64, 8, 12)
    with pytest.raises(ValueError):
        zsweep(x, z, a, pl_, u, inv, rm, block_height=48)
