"""AOT driver tests: lowering produces parseable HLO text with the right
parameter signature, and the manifest is consistent."""

import json
import os
import re

import numpy as np
import pytest

from compile import aot


@pytest.fixture(scope="module")
def small_manifest(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    man = aot.build(out, rows=(16,), feats=(4,), dims=(6,), verbose=False)
    return out, man


def test_manifest_counts(small_manifest):
    _, man = small_manifest
    # 4 bucketed kernels x 1 row-bucket + 1 apost per (k, d)
    assert len(man["entries"]) == 5
    names = sorted(e["name"] for e in man["entries"])
    assert names == sorted(
        ["zsweep", "suffstats", "heldout", "collapsed_loglik", "apost"])


def test_files_exist_and_are_hlo(small_manifest):
    out, man = small_manifest
    for e in man["entries"]:
        path = os.path.join(out, e["file"])
        assert os.path.exists(path)
        text = open(path).read()
        assert "ENTRY" in text and "HloModule" in text
        # 64-bit-id-proto pitfall: interchange must be text, never binary.
        assert text.isprintable() or "\n" in text


def test_parameter_count_matches_inputs(small_manifest):
    out, man = small_manifest
    for e in man["entries"]:
        text = open(os.path.join(out, e["file"])).read()
        entry_block = text[text.index("ENTRY"):]
        params = re.findall(r"parameter\(\d+\)", entry_block)
        assert len(params) == len(e["inputs"]), e["name"]


def test_shapes_recorded_correctly(small_manifest):
    _, man = small_manifest
    for e in man["entries"]:
        if e["name"] == "zsweep":
            shapes = dict((n, tuple(s)) for n, s in e["inputs"])
            assert shapes["x"] == (16, 6)
            assert shapes["z"] == (16, 4)
            assert shapes["inv2s2"] == (1, 1)
            outs = dict((n, tuple(s)) for n, s in e["outputs"])
            assert outs["z_new"] == (16, 4)
            assert outs["m"] == (1, 4)


def test_sha_integrity(small_manifest):
    import hashlib
    out, man = small_manifest
    for e in man["entries"]:
        text = open(os.path.join(out, e["file"])).read()
        assert hashlib.sha256(text.encode()).hexdigest() == e["sha256"]


def test_manifest_json_roundtrip(small_manifest):
    out, man = small_manifest
    loaded = json.load(open(os.path.join(out, "manifest.json")))
    assert loaded == json.loads(json.dumps(man))


def test_all_rank2(small_manifest):
    """Interchange contract with rust: every tensor is rank-2 f32."""
    _, man = small_manifest
    for e in man["entries"]:
        for _, s in e["inputs"] + e["outputs"]:
            assert len(s) == 2
