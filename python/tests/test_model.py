"""L2 model-graph tests: A-posterior sampling, held-out metric, collapsed
marginal — validated against dense numpy linear algebra."""

import jax
import numpy as np
import jax.numpy as jnp
from hypothesis import given
from hypothesis import strategies as st

from compile import model
from compile.kernels import ref

from .conftest import make_problem

# model.apost_sample / collapsed_loglik contain fori_loop linear algebra
# that is only fast under jit (they are always jitted in the AOT path);
# eager dispatch would make the sampling-heavy tests below crawl.
_apost_jit = jax.jit(model.apost_sample)
_collapsed_jit = jax.jit(model.collapsed_loglik)


def naive_collapsed(x, z, sx, sa):
    n, k = z.shape
    d = x.shape[1]
    m = z.T @ z + (sx / sa) ** 2 * np.eye(k)
    _, ld = np.linalg.slogdet(m)
    minv = np.linalg.inv(m)
    return (
        -(n * d / 2) * np.log(2 * np.pi)
        - (n - k) * d * np.log(sx)
        - k * d * np.log(sa)
        - d / 2 * ld
        - (np.trace(x.T @ x) - np.trace(x.T @ z @ minv @ z.T @ x))
        / (2 * sx**2)
    )


@given(
    n=st.integers(10, 60),
    k=st.integers(1, 8),
    d=st.integers(2, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_collapsed_matches_naive(n, k, d, seed):
    rng = np.random.default_rng(seed)
    z = (rng.random((n, k)) < 0.4).astype(np.float64)
    a = rng.normal(size=(k, d))
    x = z @ a + 0.3 * rng.normal(size=(n, d))
    got = _collapsed_jit(
        jnp.asarray(x, jnp.float32), jnp.asarray(z, jnp.float32),
        0.5, 1.2, jnp.ones(k, jnp.float32), jnp.ones(n, jnp.float32)
    )
    want = naive_collapsed(x, z, 0.5, 1.2)
    assert abs(float(got) - want) < max(1e-3 * abs(want), 0.5)


def test_collapsed_padding_invariant(rng):
    """Padding rows/features must not change the collapsed marginal."""
    n, k, d = 30, 5, 7
    z = (rng.random((n, k)) < 0.4).astype(np.float32)
    a = rng.normal(size=(k, d)).astype(np.float32)
    x = (z @ a + 0.3 * rng.normal(size=(n, d))).astype(np.float32)
    base = float(_collapsed_jit(
        jnp.asarray(x), jnp.asarray(z), 0.5, 1.0,
        jnp.ones(k, jnp.float32), jnp.ones(n, jnp.float32)))
    np_, kp = 48, 8
    zp = np.zeros((np_, kp), np.float32); zp[:n, :k] = z
    xp = np.zeros((np_, d), np.float32); xp[:n] = x
    km = np.zeros(kp, np.float32); km[:k] = 1
    rm = np.zeros(np_, np.float32); rm[:n] = 1
    padded = float(_collapsed_jit(
        jnp.asarray(xp), jnp.asarray(zp), 0.5, 1.0,
        jnp.asarray(km), jnp.asarray(rm)))
    assert abs(base - padded) < 0.1


def test_apost_mean_and_masking(rng):
    n, k, d = 40, 5, 7
    z = (rng.random((n, k)) < 0.4).astype(np.float64)
    a = rng.normal(size=(k, d))
    x = z @ a + 0.1 * rng.normal(size=(n, d))
    sx, sa = 0.3, 1.0
    ztz = (z.T @ z).astype(np.float32)
    ztx = (z.T @ x).astype(np.float32)
    got = _apost_jit(
        jnp.asarray(ztz), jnp.asarray(ztx), jnp.zeros((k, d), jnp.float32),
        sx, sa, jnp.ones(k, jnp.float32))
    want = np.linalg.solve(z.T @ z + (sx / sa) ** 2 * np.eye(k), z.T @ x)
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-3)
    # masked rows exactly zero, even with noise
    kp = 8
    ztz_p = np.zeros((kp, kp), np.float32); ztz_p[:k, :k] = ztz
    ztx_p = np.zeros((kp, d), np.float32); ztx_p[:k] = ztx
    km = np.zeros(kp, np.float32); km[:k] = 1
    eps = rng.normal(size=(kp, d)).astype(np.float32)
    got_p = np.asarray(_apost_jit(
        jnp.asarray(ztz_p), jnp.asarray(ztx_p), jnp.asarray(eps),
        sx, sa, jnp.asarray(km)))
    assert np.abs(got_p[k:]).max() == 0.0
    np.testing.assert_allclose(got_p[:k] - np.asarray(got), 0, atol=2.0)


def test_apost_sample_covariance(rng):
    """Empirical covariance of A-draws matches sigma_x^2 M^{-1}."""
    k, d = 3, 2
    ztz = np.array([[9.0, 2.0, 1.0], [2.0, 7.0, 0.5], [1.0, 0.5, 5.0]],
                   np.float32)
    ztx = np.zeros((k, d), np.float32)
    sx, sa = 0.8, 1.0
    m = ztz + (sx / sa) ** 2 * np.eye(k)
    cov_want = sx**2 * np.linalg.inv(m)
    draws = []
    for i in range(1500):
        eps = rng.normal(size=(k, d)).astype(np.float32)
        draws.append(np.asarray(_apost_jit(
            jnp.asarray(ztz), jnp.asarray(ztx), jnp.asarray(eps),
            sx, sa, jnp.ones(k, jnp.float32)))[:, 0])
    cov_got = np.cov(np.array(draws).T)
    np.testing.assert_allclose(cov_got, cov_want, atol=0.04)


def test_heldout_joint_decomposes(rng):
    """joint = gaussian loglik + bernoulli prior, checked by hand."""
    b, k, d = 16, 3, 5
    x, z, a, _, _, inv, rm, km = make_problem(rng, b, k, d)
    pi = np.array([0.3, 0.6, 0.9], np.float32)
    sx = 0.5
    ld = np.float32(-0.5 * d * np.log(2 * np.pi * sx**2))
    got = float(model.heldout_joint_loglik(
        jnp.asarray(x), jnp.asarray(z), jnp.asarray(a),
        jnp.log(pi), jnp.log1p(-pi), inv, ld, jnp.asarray(rm),
        jnp.asarray(km)))
    r = x - z @ a
    want_x = (ld - (r * r).sum(1) * inv).sum()
    want_z = (z * np.log(pi) + (1 - z) * np.log1p(-pi)).sum()
    np.testing.assert_allclose(got, want_x + want_z, rtol=1e-4)


def test_heldout_masked_rows_ignored(rng):
    b, k, d = 32, 4, 6
    x, z, a, _, _, inv, _, km = make_problem(rng, b, k, d)
    pi = np.full(k, 0.4, np.float32)
    ld = np.float32(-0.5 * d * np.log(2 * np.pi * 0.25))
    rm_full = np.ones(b, np.float32)
    rm_half = rm_full.copy(); rm_half[16:] = 0
    z_half = z.copy(); z_half[16:] = 0
    got_half = float(model.heldout_joint_loglik(
        jnp.asarray(x), jnp.asarray(z_half), jnp.asarray(a),
        jnp.log(pi), jnp.log1p(-pi), inv, ld, jnp.asarray(rm_half),
        jnp.asarray(km)))
    got_sub = float(model.heldout_joint_loglik(
        jnp.asarray(x[:16]), jnp.asarray(z[:16]), jnp.asarray(a),
        jnp.log(pi), jnp.log1p(-pi), inv, ld,
        jnp.ones(16, np.float32), jnp.asarray(km)))
    np.testing.assert_allclose(got_half, got_sub, rtol=1e-4)
