"""Pallas suffstats kernel vs oracle + algebraic invariants."""

import numpy as np
from hypothesis import given
from hypothesis import strategies as st

from compile.kernels import ref
from compile.kernels.suffstats import suffstats

from .conftest import make_problem


@given(
    b=st.sampled_from([16, 64, 256]),
    k=st.sampled_from([4, 8, 32]),
    d=st.sampled_from([4, 36]),
    masked_rows=st.integers(0, 10),
    seed=st.integers(0, 2**31 - 1),
)
def test_matches_ref(b, k, d, masked_rows, seed):
    rng = np.random.default_rng(seed)
    x, z, _, _, _, _, rm, _ = make_problem(rng, b, k, d,
                                           masked_rows=masked_rows)
    ztz_r, ztx_r = ref.suffstats_ref(z, x, rm)
    ztz_k, ztx_k = suffstats(z, x, rm)
    np.testing.assert_allclose(np.asarray(ztz_r), np.asarray(ztz_k),
                               atol=1e-3, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(ztx_r), np.asarray(ztx_k),
                               atol=1e-3, rtol=1e-4)


def test_ztz_symmetric_and_counts(rng):
    x, z, _, _, _, _, rm, _ = make_problem(rng, 128, 16, 12)
    ztz, _ = suffstats(z, x, rm)
    ztz = np.asarray(ztz)
    np.testing.assert_allclose(ztz, ztz.T, atol=1e-4)
    # diagonal = column counts m_k
    np.testing.assert_allclose(np.diag(ztz), z.sum(0), atol=1e-4)


def test_block_sharding_additivity(rng):
    """suffstats over a whole shard == sum of suffstats over row chunks —
    the exact property the master's merge relies on."""
    x, z, _, _, _, _, rm, _ = make_problem(rng, 128, 8, 12)
    full = suffstats(z, x, rm)
    half = 64
    part1 = suffstats(z[:half], x[:half], rm[:half])
    part2 = suffstats(z[half:], x[half:], rm[half:])
    np.testing.assert_allclose(
        np.asarray(full[0]), np.asarray(part1[0]) + np.asarray(part2[0]),
        atol=1e-3)
    np.testing.assert_allclose(
        np.asarray(full[1]), np.asarray(part1[1]) + np.asarray(part2[1]),
        atol=1e-3)


def test_masked_rows_excluded(rng):
    x, z, _, _, _, _, _, _ = make_problem(rng, 64, 8, 12)
    rm = np.zeros(64, np.float32)
    rm[:32] = 1.0
    z[32:] = 1.0  # garbage in padded region must not leak
    ztz, ztx = suffstats(z, x, rm)
    ztz_expect = z[:32].T @ z[:32]
    np.testing.assert_allclose(np.asarray(ztz), ztz_expect, atol=1e-3)
