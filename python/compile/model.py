"""L2: the JAX compute graph of the linear-Gaussian IBP model.

Every public function here is a jittable graph that calls the L1 Pallas
kernels and is AOT-lowered to HLO text by `aot.py`; the rust coordinator
executes the lowered artifacts via PJRT and NEVER imports this module at
runtime.

Conventions shared with the rust side (rust/src/runtime/artifact.rs):
  * all tensors are float32; scalars travel as (1,1) f32 where a kernel
    needs them, plain rank-0 here at the jit boundary;
  * K (feature columns) and B (rows) are padded to the bucket sizes listed
    in artifacts/manifest.json; `k_mask` / `row_mask` carry liveness;
  * uniforms / standard normals are drawn by the rust RNG and passed in, so
    the artifacts are pure functions and chains are reproducible from the
    rust seed alone.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import linalg_jnp, ref
from .kernels.loglik import rowloglik
from .kernels.suffstats import suffstats
from .kernels.zsweep import zsweep

__all__ = [
    "zsweep_step",
    "local_suffstats",
    "apost_sample",
    "heldout_joint_loglik",
    "collapsed_loglik",
]


def zsweep_step(x, z, a, prior_logit, u, inv2s2, row_mask):
    """One uncollapsed Gibbs sweep over a worker shard (hot path).

    Returns (z_new (B,K), r_new (B,D), m (K,)).
    """
    return zsweep(x, z, a, prior_logit, u, inv2s2, row_mask)


def local_suffstats(z, x, row_mask):
    """Worker-local (ZtZ, ZtX) shipped to the master each global step."""
    return suffstats(z, x, row_mask)


def apost_sample(ztz, ztx, eps, sigma_x, sigma_a, k_mask):
    """Master step: draw A | X, Z from its matrix-normal posterior.

      M = ZtZ + (sx^2/sa^2) I,  A = M^-1 ZtX + sx * solve(L^T, eps),
      L L^T = M,  eps ~ N(0,1)^{K x D}  (drawn by the rust RNG).

    Masked feature rows come back exactly zero. Uses the plain-HLO
    Cholesky (kernels.linalg_jnp) — LAPACK custom-calls cannot run under
    the rust PJRT client (see linalg_jnp docstring); semantics are pinned
    against ref.apost_mean_chol_ref by pytest.
    """
    ratio = (sigma_x / sigma_a) ** 2
    mask2 = k_mask[:, None] * k_mask[None, :]
    diag = ratio * k_mask + (1.0 - k_mask)
    m_mat = ztz * mask2 + jnp.diag(diag)
    chol = linalg_jnp.cholesky(m_mat)
    mean = linalg_jnp.solve_upper_t(
        chol, linalg_jnp.solve_lower(chol, ztx * k_mask[:, None])
    )
    noise = linalg_jnp.solve_upper_t(chol, eps * k_mask[:, None])
    a = mean + sigma_x * noise
    return a * k_mask[:, None]


def heldout_joint_loglik(x, z, a, log_pi, log_1mpi, inv2s2, logdet_term,
                         row_mask, k_mask):
    """The paper's Figure-1 metric: joint log P(X_test, Z_test | A, pi).

      log P(X|Z,A,sx) + log P(Z|pi)
        = sum_n [ logdet_term - ||x_n - z_n A||^2 inv2s2 ]
        + sum_{n,k} [ z_nk log pi_k + (1 - z_nk) log(1 - pi_k) ]

    Masked rows/features contribute zero.
    """
    _, ll_x = rowloglik(x, z, a, inv2s2, logdet_term, row_mask)
    zm = z * row_mask[:, None]
    n_live = jnp.sum(row_mask)
    prior = (
        jnp.sum(zm * (log_pi * k_mask)[None, :])
        + jnp.sum((n_live * k_mask) * log_1mpi)
        - jnp.sum(zm * (log_1mpi * k_mask)[None, :])
    )
    return ll_x + prior


def collapsed_loglik(x, z, sigma_x, sigma_a, k_mask, row_mask):
    """Collapsed marginal log P(X|Z) (A integrated out) — used by the
    collapsed baseline's diagnostics and validated against the rust-native
    implementation in integration tests. Same maths as
    ref.collapsed_loglik_ref but with the plain-HLO Cholesky so the
    artifact runs under the rust PJRT client."""
    zm = z * row_mask[:, None] * k_mask[None, :]
    xm = x * row_mask[:, None]
    n = jnp.sum(row_mask)
    k_live = jnp.sum(k_mask)
    d = x.shape[1]
    ratio = (sigma_x / sigma_a) ** 2
    ztz = zm.T @ zm
    diag = ratio * k_mask + (1.0 - k_mask)
    m_mat = ztz + jnp.diag(diag)
    ztx = zm.T @ xm
    w, logdet_m = linalg_jnp.psd_solve(m_mat, ztx)
    tr_xx = jnp.sum(xm * xm)
    tr_quad = jnp.sum(ztx * w)
    return (
        -(n * d / 2.0) * jnp.log(2.0 * jnp.pi)
        - (n - k_live) * d * jnp.log(sigma_x)
        - k_live * d * jnp.log(sigma_a)
        - (d / 2.0) * logdet_m
        - (tr_xx - tr_quad) / (2.0 * sigma_x**2)
    )
