"""L1 Pallas kernel: row-parallel uncollapsed Gibbs sweep over Z.

This is the hot path of the hybrid sampler (paper §3): every worker, every
sub-iteration, resamples its shard's Z restricted to the K+ instantiated
features, conditionally on (A, pi). Rows are independent given (A, pi) —
that is the conditional independence the paper parallelises over — so the
kernel tiles rows into VMEM blocks (grid over row-blocks) and scans features
sequentially inside the block, carrying the running residual R = X - Z A in
registers/VMEM.

TPU thinking (DESIGN.md §Hardware-Adaptation): the initial residual is an
MXU matmul (Z @ A); the per-feature flip update is a rank-1 outer product
(VPU); A (K x D, <= 64 x 36 f32 = 9 KiB) stays resident in VMEM across the
scan; block height Bt is chosen so (X, Z, U, R) blocks fit VMEM comfortably
(see vmem_bytes()).

interpret=True everywhere on this image — CPU PJRT cannot execute Mosaic
custom-calls; the lowering is still a single fused HLO while-loop.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["zsweep", "zsweep_block_height", "vmem_bytes"]


def _zsweep_kernel(x_ref, z_ref, a_ref, pl_ref, u_ref, s_ref, rm_ref,
                   zo_ref, ro_ref):
    """One row-block. Shapes: x (Bt,D) z/u (Bt,K) a (K,D) pl (1,K) s (1,1)
    rm (Bt,1); outputs zo (Bt,K) ro (Bt,D)."""
    x = x_ref[...]
    z = z_ref[...]
    a = a_ref[...]
    u = u_ref[...]
    prior_logit = pl_ref[...]          # (1, K)
    inv2s2 = s_ref[0, 0]
    rm = rm_ref[...]                   # (Bt, 1)

    k_feats = z.shape[1]
    r = x - jnp.dot(z, a, preferred_element_type=jnp.float32)

    def body(k, carry):
        z_c, r_c = carry
        a_k = jax.lax.dynamic_slice_in_dim(a, k, 1, axis=0)        # (1, D)
        z_k = jax.lax.dynamic_slice_in_dim(z_c, k, 1, axis=1)      # (Bt, 1)
        r0 = r_c + z_k * a_k
        dll = (2.0 * jnp.dot(r0, a_k.T, preferred_element_type=jnp.float32)
               - jnp.sum(a_k * a_k)) * inv2s2                      # (Bt, 1)
        logit = jax.lax.dynamic_slice_in_dim(prior_logit, k, 1, axis=1) + dll
        p1 = jax.nn.sigmoid(logit)
        u_k = jax.lax.dynamic_slice_in_dim(u, k, 1, axis=1)
        z_new = (u_k < p1).astype(jnp.float32) * rm
        r_c = r0 - z_new * a_k
        z_c = jax.lax.dynamic_update_slice(z_c, z_new, (0, k))
        return z_c, r_c

    z_out, r_out = jax.lax.fori_loop(0, k_feats, body, (z, r))
    zo_ref[...] = z_out
    ro_ref[...] = r_out


def zsweep_block_height(b, k, d, vmem_budget=8 * 1024 * 1024):
    """Largest power-of-two row-block height whose VMEM working set fits.

    Working set per block: x (Bt,D) + r (Bt,D) + r0 (Bt,D) + z,u,zo (Bt,K)
    + a (K,D), all f32.
    """
    bt = 1024
    while bt > 8:
        if bt <= b and vmem_bytes(bt, k, d) <= vmem_budget:
            break
        bt //= 2
    return max(8, min(bt, b))


def vmem_bytes(bt, k, d):
    """Estimated VMEM working set of one grid step (bytes, f32)."""
    return 4 * (3 * bt * d + 3 * bt * k + k * d + k + bt)


@functools.partial(jax.jit, static_argnames=("block_height",))
def zsweep(x, z, a, prior_logit, u, inv2s2, row_mask, *, block_height=None):
    """Pallas uncollapsed Gibbs sweep. Semantics == ref.zsweep_ref.

    Args match ref.zsweep_ref except inv2s2 is passed as shape (1,1) and
    prior_logit as (K,) (reshaped internally). Returns (z_new, r_new, m).
    """
    b, d = x.shape
    k = z.shape[1]
    bt = block_height or zsweep_block_height(b, k, d)
    if b % bt:
        raise ValueError(f"rows {b} not divisible by block height {bt}")
    grid = (b // bt,)

    z_new, r_new = pl.pallas_call(
        _zsweep_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),   # x
            pl.BlockSpec((bt, k), lambda i: (i, 0)),   # z
            pl.BlockSpec((k, d), lambda i: (0, 0)),    # a (resident)
            pl.BlockSpec((1, k), lambda i: (0, 0)),    # prior_logit
            pl.BlockSpec((bt, k), lambda i: (i, 0)),   # u
            pl.BlockSpec((1, 1), lambda i: (0, 0)),    # inv2s2
            pl.BlockSpec((bt, 1), lambda i: (i, 0)),   # row_mask
        ],
        out_specs=[
            pl.BlockSpec((bt, k), lambda i: (i, 0)),
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, k), jnp.float32),
            jax.ShapeDtypeStruct((b, d), jnp.float32),
        ],
        interpret=True,
    )(
        x.astype(jnp.float32),
        z.astype(jnp.float32),
        a.astype(jnp.float32),
        jnp.reshape(prior_logit, (1, k)).astype(jnp.float32),
        u.astype(jnp.float32),
        jnp.reshape(inv2s2, (1, 1)).astype(jnp.float32),
        jnp.reshape(row_mask, (b, 1)).astype(jnp.float32),
    )
    m = jnp.sum(z_new * jnp.reshape(row_mask, (b, 1)), axis=0)
    return z_new, r_new, m
