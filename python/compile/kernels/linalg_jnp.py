"""Pure-jnp dense linear algebra for AOT-lowered graphs.

`jnp.linalg.cholesky` / `cho_solve` lower to LAPACK custom-calls with
API_VERSION_TYPED_FFI on CPU, which the image's xla_extension 0.5.1 (behind
the rust `xla` crate) cannot execute. These column-loop implementations
lower to plain HLO (while + dynamic-slice), so the compiled artifacts are
runnable anywhere. K ≤ 64 in every bucket, so the O(K) sequential loop is
irrelevant next to the O(K²D) solves it unlocks.

pytest pins each of these against the numpy/lapack reference.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["cholesky", "solve_lower", "solve_upper_t", "psd_solve"]


def cholesky(a):
    """Lower-triangular L with L Lᵀ = a (a must be SPD; masked features
    get an identity diagonal upstream). Plain-HLO lowering."""
    k = a.shape[0]
    idx = jnp.arange(k)

    def body(j, l):
        row_j = jax.lax.dynamic_slice_in_dim(l, j, 1, axis=0)[0]  # (k,)
        a_col = jax.lax.dynamic_slice_in_dim(a, j, 1, axis=1)[:, 0]  # (k,)
        s = a_col - l @ row_j
        dj = jnp.sqrt(jnp.take(s, j))
        col = jnp.where(idx > j, s / dj, 0.0)
        col = jnp.where(idx == j, dj, col)
        return jax.lax.dynamic_update_slice(l, col[:, None], (0, j))

    return jax.lax.fori_loop(0, k, body, jnp.zeros_like(a))


def solve_lower(l, b):
    """Solve L y = b for lower-triangular L; b is (K, D)."""
    k = l.shape[0]

    def body(i, y):
        l_row = jax.lax.dynamic_slice_in_dim(l, i, 1, axis=0)[0]  # (k,)
        b_row = jax.lax.dynamic_slice_in_dim(b, i, 1, axis=0)[0]  # (d,)
        lii = jnp.take(l_row, i)
        acc = l_row @ y  # rows ≥ i of y are still 0 ⇒ only j<i contribute
        yi = (b_row - acc) / lii
        return jax.lax.dynamic_update_slice(y, yi[None, :], (i, 0))

    return jax.lax.fori_loop(0, k, body, jnp.zeros_like(b))


def solve_upper_t(l, b):
    """Solve Lᵀ x = b for lower-triangular L (i.e. upper-tri solve)."""
    k = l.shape[0]

    def body(t, x):
        i = k - 1 - t
        l_col = jax.lax.dynamic_slice_in_dim(l, i, 1, axis=1)[:, 0]  # (k,)
        b_row = jax.lax.dynamic_slice_in_dim(b, i, 1, axis=0)[0]
        lii = jnp.take(l_col, i)
        acc = l_col @ x  # rows ≤ i of x are still 0 ⇒ only j>i contribute
        xi = (b_row - acc) / lii
        return jax.lax.dynamic_update_slice(x, xi[None, :], (i, 0))

    return jax.lax.fori_loop(0, k, body, jnp.zeros_like(b))


def psd_solve(a, b):
    """Solve a x = b for SPD a via the plain-HLO Cholesky.

    Returns (x, logdet(a))."""
    l = cholesky(a)
    y = solve_lower(l, b)
    x = solve_upper_t(l, y)
    logdet = 2.0 * jnp.sum(jnp.log(jnp.diagonal(l)))
    return x, logdet
