"""Pure-jnp reference oracle for every Pallas kernel in this package.

These functions define the semantics; the Pallas kernels (`zsweep.py`,
`suffstats.py`, `loglik.py`) must match them to float32 tolerance, and the
rust native fallbacks (rust/src/samplers/uncollapsed.rs) implement the same
maths in f64. pytest (python/tests/) sweeps shapes with hypothesis and
asserts allclose against these.

Model (paper Eq. 1): X = Z A + eps, eps ~ N(0, sigma_x^2 I).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "zsweep_ref",
    "suffstats_ref",
    "rowloglik_ref",
    "collapsed_loglik_ref",
    "apost_mean_chol_ref",
]


def zsweep_ref(x, z, a, prior_logit, u, inv2s2, row_mask):
    """One uncollapsed Gibbs sweep of Z for a block of rows.

    For each row n (independently, given A and pi) and each feature k in
    order, resample

        P(Z_nk = 1 | -) ∝ pi_k * N(x_n ; z_n A, sigma_x^2 I)

    using the pre-drawn uniform u[n, k]. `prior_logit[k] = logit(pi_k)`;
    padded (masked) features carry prior_logit = -inf so they are never
    switched on. `row_mask[n] = 0` forces padded rows to all-zero.

    Args:
      x:            (B, D) observations.
      z:            (B, K) current binary states (float 0/1).
      a:            (K, D) feature loadings.
      prior_logit:  (K,)   log(pi/(1-pi)), -1e30 for masked features.
      u:            (B, K) uniforms in (0,1).
      inv2s2:       ()     1 / (2 sigma_x^2).
      row_mask:     (B,)   1.0 for live rows, 0.0 for padding.

    Returns:
      (z_new (B,K), r_new (B,D), m (K,)) where r_new = x - z_new @ a is the
      final residual and m are the masked column sums of z_new.
    """
    x = jnp.asarray(x)
    z = jnp.asarray(z)
    a = jnp.asarray(a)
    prior_logit = jnp.asarray(prior_logit)
    u = jnp.asarray(u)
    row_mask = jnp.asarray(row_mask)
    k_feats = z.shape[1]
    r = x - z @ a
    rm = row_mask[:, None]

    def body(k, carry):
        z_c, r_c = carry
        a_k = jax.lax.dynamic_slice_in_dim(a, k, 1, axis=0)  # (1, D)
        z_k = jax.lax.dynamic_slice_in_dim(z_c, k, 1, axis=1)  # (B, 1)
        # Residual with bit k forced to 0.
        r0 = r_c + z_k * a_k
        # loglik(bit=1) - loglik(bit=0) = (2 r0·a_k - a_k·a_k) * inv2s2
        dll = (2.0 * (r0 @ a_k.T) - jnp.sum(a_k * a_k)) * inv2s2  # (B, 1)
        logit = prior_logit[k] + dll
        p1 = jax.nn.sigmoid(logit)
        u_k = jax.lax.dynamic_slice_in_dim(u, k, 1, axis=1)  # (B, 1)
        z_new = (u_k < p1).astype(x.dtype) * rm
        r_c = r0 - z_new * a_k
        z_c = jax.lax.dynamic_update_slice(z_c, z_new, (0, k))
        return z_c, r_c

    z_out, r_out = jax.lax.fori_loop(0, k_feats, body, (z, r))
    m = jnp.sum(z_out * rm, axis=0)
    return z_out, r_out, m


def suffstats_ref(z, x, row_mask):
    """Local sufficient statistics for the master's global step.

    Returns (ZtZ (K,K), ZtX (K,D)) with padded rows excluded.
    """
    zm = z * row_mask[:, None]
    return zm.T @ z, zm.T @ x


def rowloglik_ref(x, z, a, inv2s2, logdet_term, row_mask):
    """Per-row uncollapsed Gaussian log-likelihood.

    log N(x_n; z_n A, sigma_x^2 I) = logdet_term - ||x_n - z_n A||^2 * inv2s2
    where logdet_term = -(D/2) log(2 pi sigma_x^2). Padded rows get 0.

    Returns (per_row (B,), total ()).
    """
    r = x - z @ a
    ll = (logdet_term - jnp.sum(r * r, axis=1) * inv2s2) * row_mask
    return ll, jnp.sum(ll)


def collapsed_loglik_ref(x, z, sigma_x, sigma_a, k_mask, row_mask):
    """Collapsed marginal log P(X | Z) with A integrated out (G&G 2005).

    With M = Z^T Z + (sigma_x^2/sigma_a^2) I_K (over live features only):

      log P(X|Z) = -(N D / 2) log(2 pi) - (N - K) D log sigma_x
                   - K D log sigma_a - (D/2) log |M|
                   - (tr(X^T X) - tr(X^T Z M^-1 Z^T X)) / (2 sigma_x^2)

    Masked features are frozen to identity rows of M (contributing
    log|M| += 0 after the ratio correction below) and zero columns of Z, so
    padded and unpadded evaluations agree. N and K count live rows/features.
    """
    zm = z * row_mask[:, None] * k_mask[None, :]
    xm = x * row_mask[:, None]
    n = jnp.sum(row_mask)
    k_live = jnp.sum(k_mask)
    d = x.shape[1]
    ratio = (sigma_x / sigma_a) ** 2
    ztz = zm.T @ zm
    # Masked features get a 1.0 diagonal so chol is well-posed; their
    # log-det contribution log(1.0) = 0 and their M^-1 block is inert
    # because the corresponding rows of ZtX are zero.
    diag = ratio * k_mask + (1.0 - k_mask)
    m_mat = ztz + jnp.diag(diag)
    chol = jnp.linalg.cholesky(m_mat)
    logdet_m = 2.0 * jnp.sum(jnp.log(jnp.diagonal(chol)))
    # Correct for masked diagonal entries contributing log(ratio) vs log(1):
    # nothing to correct — masked diag is exactly 1 by construction.
    ztx = zm.T @ xm
    w = jax.scipy.linalg.cho_solve((chol, True), ztx)
    tr_xx = jnp.sum(xm * xm)
    tr_quad = jnp.sum(ztx * w)
    return (
        -(n * d / 2.0) * jnp.log(2.0 * jnp.pi)
        - (n - k_live) * d * jnp.log(sigma_x)
        - k_live * d * jnp.log(sigma_a)
        - (d / 2.0) * logdet_m
        - (tr_xx - tr_quad) / (2.0 * sigma_x**2)
    )


def apost_mean_chol_ref(ztz, ztx, sigma_x, sigma_a, k_mask):
    """Posterior of the loadings A | X, Z  (matrix normal).

      M = ZtZ + (sigma_x^2/sigma_a^2) I,   mean = M^-1 ZtX,
      A = mean + sigma_x * L^-T  E,  E_kd ~ N(0,1),  L L^T = M.

    Masked features get an identity row in M and a zero row in ZtX, so their
    posterior mean is 0 and their noise is sigma_x * (unit scale) — callers
    must zero masked rows of the returned sample (the model wrapper does).

    Returns (mean (K,D), chol (K,K) lower).
    """
    ratio = (sigma_x / sigma_a) ** 2
    k_feats = ztz.shape[0]
    mask2 = k_mask[:, None] * k_mask[None, :]
    diag = ratio * k_mask + (1.0 - k_mask)
    m_mat = ztz * mask2 + jnp.diag(diag)
    chol = jnp.linalg.cholesky(m_mat)
    mean = jax.scipy.linalg.cho_solve((chol, True), ztx * k_mask[:, None])
    return mean, chol
