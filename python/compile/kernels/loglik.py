"""L1 Pallas kernel: per-row uncollapsed Gaussian log-likelihood.

Used for (a) the held-out joint log P(X, Z) curve that reproduces the
paper's Figure 1 metric, and (b) Metropolis-Hastings likelihood ratios.
Row blocks are streamed through VMEM; the residual is one MXU matmul per
block followed by a VPU row-reduction.

Semantics == ref.rowloglik_ref.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["rowloglik"]


def _rowloglik_kernel(x_ref, z_ref, a_ref, s_ref, ld_ref, rm_ref, out_ref):
    x = x_ref[...]
    z = z_ref[...]
    a = a_ref[...]
    inv2s2 = s_ref[0, 0]
    logdet_term = ld_ref[0, 0]
    rm = rm_ref[...]                  # (Bt, 1)
    r = x - jnp.dot(z, a, preferred_element_type=jnp.float32)
    ll = (logdet_term - jnp.sum(r * r, axis=1, keepdims=True) * inv2s2) * rm
    out_ref[...] = ll


@functools.partial(jax.jit, static_argnames=("block_height",))
def rowloglik(x, z, a, inv2s2, logdet_term, row_mask, *, block_height=None):
    """Per-row log N(x_n; z_n A, sigma^2 I) (masked) and its total."""
    b, d = x.shape
    k = z.shape[1]
    bt = block_height or min(b, 256)
    if b % bt:
        raise ValueError(f"rows {b} not divisible by block height {bt}")
    grid = (b // bt,)

    ll = pl.pallas_call(
        _rowloglik_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((bt, k), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((bt, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bt, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.float32),
        interpret=True,
    )(
        x.astype(jnp.float32),
        z.astype(jnp.float32),
        a.astype(jnp.float32),
        jnp.reshape(inv2s2, (1, 1)).astype(jnp.float32),
        jnp.reshape(logdet_term, (1, 1)).astype(jnp.float32),
        jnp.reshape(row_mask, (b, 1)).astype(jnp.float32),
    )
    per_row = ll[:, 0]
    return per_row, jnp.sum(per_row)
