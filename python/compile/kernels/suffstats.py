"""L1 Pallas kernel: local sufficient statistics (ZtZ, ZtX).

Each worker ships (m_k, ZtZ_p, ZtX_p) to the master at the end of every
global iteration (paper §3, "Receive summary statistics from all other
processors"). These are plain MXU matmuls — the kernel tiles rows into VMEM
blocks and accumulates K x K / K x D partials across the grid, the classic
reduction-over-rows schedule.

Semantics == ref.suffstats_ref.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["suffstats"]


def _suffstats_kernel(z_ref, x_ref, rm_ref, ztz_ref, ztx_ref):
    """Accumulating kernel: the output blocks map to the same (0,0) tile for
    every grid step, so step i adds its row-block's contribution."""
    i = pl.program_id(0)
    z = z_ref[...]
    x = x_ref[...]
    rm = rm_ref[...]                  # (Bt, 1)
    zm = z * rm

    @pl.when(i == 0)
    def _init():
        ztz_ref[...] = jnp.zeros_like(ztz_ref)
        ztx_ref[...] = jnp.zeros_like(ztx_ref)

    ztz_ref[...] += jnp.dot(zm.T, z, preferred_element_type=jnp.float32)
    ztx_ref[...] += jnp.dot(zm.T, x, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_height",))
def suffstats(z, x, row_mask, *, block_height=None):
    """Masked (ZtZ, ZtX) via a row-blocked Pallas reduction."""
    b, d = x.shape
    k = z.shape[1]
    bt = block_height or min(b, 256)
    if b % bt:
        raise ValueError(f"rows {b} not divisible by block height {bt}")
    grid = (b // bt,)

    ztz, ztx = pl.pallas_call(
        _suffstats_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bt, k), lambda i: (i, 0)),
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
            pl.BlockSpec((bt, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((k, k), lambda i: (0, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((k, k), jnp.float32),
            jax.ShapeDtypeStruct((k, d), jnp.float32),
        ],
        interpret=True,
    )(
        z.astype(jnp.float32),
        x.astype(jnp.float32),
        jnp.reshape(row_mask, (b, 1)).astype(jnp.float32),
    )
    return ztz, ztx
