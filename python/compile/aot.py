"""AOT driver: lower the L2 graphs to HLO *text* artifacts + manifest.

Run once by `make artifacts`:

    cd python && python -m compile.aot --out ../artifacts

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids that the image's xla_extension
0.5.1 (behind the rust `xla` crate) rejects; the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Shape policy (mirrored by rust/src/runtime/artifact.rs):
  * every input/output is a rank-2 f32 array — scalars travel as (1,1),
    vectors as (1,K) or (B,1) — so the rust literal layer stays uniform;
  * each graph is compiled for a grid of (B rows, K features, D dims)
    buckets; the rust runtime pads to the smallest fitting bucket;
  * lowering uses return_tuple=True; the rust side unwraps the tuple.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

DEFAULT_ROWS = (256, 1024)
DEFAULT_FEATS = (8, 16, 32)
DEFAULT_DIMS = (36,)


# --------------------------------------------------------------------------
# Uniform rank-2 adapters around the L2 graphs.
# --------------------------------------------------------------------------

def _zsweep2(x, z, a, prior_logit, u, inv2s2, row_mask):
    z_new, r_new, m = model.zsweep_step(
        x, z, a, prior_logit[0], u, inv2s2[0, 0], row_mask[:, 0]
    )
    return z_new, r_new, m[None, :]


def _suffstats2(z, x, row_mask):
    return model.local_suffstats(z, x, row_mask[:, 0])


def _apost2(ztz, ztx, eps, sigma_x, sigma_a, k_mask):
    return (
        model.apost_sample(
            ztz, ztx, eps, sigma_x[0, 0], sigma_a[0, 0], k_mask[0]
        ),
    )


def _heldout2(x, z, a, log_pi, log_1mpi, inv2s2, logdet_term, row_mask,
              k_mask):
    out = model.heldout_joint_loglik(
        x, z, a, log_pi[0], log_1mpi[0], inv2s2[0, 0], logdet_term[0, 0],
        row_mask[:, 0], k_mask[0]
    )
    return (out[None, None],)


def _collapsed2(x, z, sigma_x, sigma_a, k_mask, row_mask):
    out = model.collapsed_loglik(
        x, z, sigma_x[0, 0], sigma_a[0, 0], k_mask[0], row_mask[:, 0]
    )
    return (out[None, None],)


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def entry_signatures(b, k, d):
    """(name, fn, [(arg_name, shape)], [(out_name, shape)]) per bucket."""
    return [
        (
            "zsweep", _zsweep2,
            [("x", (b, d)), ("z", (b, k)), ("a", (k, d)),
             ("prior_logit", (1, k)), ("u", (b, k)), ("inv2s2", (1, 1)),
             ("row_mask", (b, 1))],
            [("z_new", (b, k)), ("r_new", (b, d)), ("m", (1, k))],
        ),
        (
            "suffstats", _suffstats2,
            [("z", (b, k)), ("x", (b, d)), ("row_mask", (b, 1))],
            [("ztz", (k, k)), ("ztx", (k, d))],
        ),
        (
            "heldout", _heldout2,
            [("x", (b, d)), ("z", (b, k)), ("a", (k, d)),
             ("log_pi", (1, k)), ("log_1mpi", (1, k)), ("inv2s2", (1, 1)),
             ("logdet_term", (1, 1)), ("row_mask", (b, 1)),
             ("k_mask", (1, k))],
            [("loglik", (1, 1))],
        ),
        (
            "collapsed_loglik", _collapsed2,
            [("x", (b, d)), ("z", (b, k)), ("sigma_x", (1, 1)),
             ("sigma_a", (1, 1)), ("k_mask", (1, k)), ("row_mask", (b, 1))],
            [("loglik", (1, 1))],
        ),
    ]


def apost_signature(k, d):
    return (
        "apost", _apost2,
        [("ztz", (k, k)), ("ztx", (k, d)), ("eps", (k, d)),
         ("sigma_x", (1, 1)), ("sigma_a", (1, 1)), ("k_mask", (1, k))],
        [("a", (k, d))],
    )


# --------------------------------------------------------------------------
# Lowering.
# --------------------------------------------------------------------------

def to_hlo_text(fn, arg_shapes):
    """jit -> stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    specs = [_spec(*s) for s in arg_shapes]
    lowered = jax.jit(fn).lower(*specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build(out_dir, rows=DEFAULT_ROWS, feats=DEFAULT_FEATS, dims=DEFAULT_DIMS,
          verbose=True):
    """Lower all bucket variants into out_dir; return the manifest dict."""
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    sigs = []
    for d in dims:
        for k in feats:
            sigs.append((None, k, d, apost_signature(k, d)))
            for b in rows:
                for sig in entry_signatures(b, k, d):
                    sigs.append((b, k, d, sig))

    for b, k, d, (name, fn, inputs, outputs) in sigs:
        tag = f"{name}_" + (f"b{b}_" if b else "") + f"k{k}_d{d}"
        path = f"{tag}.hlo.txt"
        text = to_hlo_text(fn, [s for _, s in inputs])
        with open(os.path.join(out_dir, path), "w") as f:
            f.write(text)
        entries.append({
            "name": name,
            "b": b,
            "k": k,
            "d": d,
            "file": path,
            "inputs": [[n, list(s)] for n, s in inputs],
            "outputs": [[n, list(s)] for n, s in outputs],
            "sha256": hashlib.sha256(text.encode()).hexdigest(),
        })
        if verbose:
            print(f"  lowered {tag}  ({len(text)} chars)")

    manifest = {
        "version": 1,
        "dtype": "f32",
        "rows": list(rows),
        "feats": list(feats),
        "dims": list(dims),
        "entries": entries,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if verbose:
        print(f"wrote {len(entries)} artifacts + manifest to {out_dir}")
    return manifest


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts")
    p.add_argument("--rows", default=",".join(map(str, DEFAULT_ROWS)))
    p.add_argument("--feats", default=",".join(map(str, DEFAULT_FEATS)))
    p.add_argument("--dims", default=",".join(map(str, DEFAULT_DIMS)))
    a = p.parse_args()
    build(
        a.out,
        rows=tuple(int(x) for x in a.rows.split(",")),
        feats=tuple(int(x) for x in a.feats.split(",")),
        dims=tuple(int(x) for x in a.dims.split(",")),
    )


if __name__ == "__main__":
    main()
