//! Machine-readable report, in the same hand-rolled zero-dependency JSON
//! idiom as the main crate's `config/json.rs`: a tiny value enum with a
//! `Display`-based serialiser and full string escaping. Key order is
//! insertion order, so reports are byte-deterministic.

use std::fmt;

use crate::rules::{Finding, Waiver};

/// Minimal JSON value.
pub enum Json {
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    f.write_str("\"")?;
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\r' => f.write_str("\\r")?,
            '\t' => f.write_str("\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    f.write_str("\"")
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(xs) => {
                f.write_str("[")?;
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{x}")?;
                }
                f.write_str("]")
            }
            Json::Obj(kvs) => {
                f.write_str("{")?;
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write_escaped(f, k)?;
                    f.write_str(":")?;
                    write!(f, "{v}")?;
                }
                f.write_str("}")
            }
        }
    }
}

fn finding_json(x: &Finding) -> Json {
    let mut kvs = vec![
        ("rule".to_string(), Json::str(x.rule)),
        ("file".to_string(), Json::str(&x.file)),
        ("line".to_string(), Json::num(x.line)),
        ("msg".to_string(), Json::str(&x.msg)),
        ("waived".to_string(), Json::Bool(x.waived)),
    ];
    if let Some(r) = &x.waiver_reason {
        kvs.push(("reason".to_string(), Json::str(r)));
    }
    Json::Obj(kvs)
}

fn waiver_json(w: &Waiver) -> Json {
    Json::Obj(vec![
        ("rule".to_string(), Json::str(&w.rule)),
        ("file".to_string(), Json::str(&w.file)),
        ("line".to_string(), Json::num(w.line)),
        ("reason".to_string(), Json::str(&w.reason)),
        ("used".to_string(), Json::Bool(w.used)),
    ])
}

/// Build the full report document.
pub fn build(
    roots: &[String],
    files_checked: usize,
    findings: &[Finding],
    waivers: &[Waiver],
) -> Json {
    let unwaived = findings.iter().filter(|f| !f.waived).count();
    let waived = findings.iter().filter(|f| f.waived).count();
    let unused = waivers.iter().filter(|w| !w.used).count();
    Json::Obj(vec![
        ("tool".to_string(), Json::str("detlint")),
        ("version".to_string(), Json::str(env!("CARGO_PKG_VERSION"))),
        (
            "roots".to_string(),
            Json::Arr(roots.iter().map(Json::str).collect()),
        ),
        (
            "rules".to_string(),
            Json::Arr(crate::rules::RULE_IDS.iter().map(|r| Json::str(*r)).collect()),
        ),
        ("files_checked".to_string(), Json::num(files_checked as u32)),
        (
            "summary".to_string(),
            Json::Obj(vec![
                ("unwaived".to_string(), Json::num(unwaived as u32)),
                ("waived".to_string(), Json::num(waived as u32)),
                ("unused_waivers".to_string(), Json::num(unused as u32)),
            ]),
        ),
        (
            "findings".to_string(),
            Json::Arr(findings.iter().map(finding_json).collect()),
        ),
        (
            "waivers".to_string(),
            Json::Arr(waivers.iter().map(waiver_json).collect()),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn escaping_and_shape() {
        let j = Json::Obj(vec![
            ("k\"ey".to_string(), Json::str("va\\l\nue")),
            ("n".to_string(), Json::num(3u32)),
            ("b".to_string(), Json::Bool(true)),
            ("a".to_string(), Json::Arr(vec![Json::num(1u32), Json::num(2u32)])),
        ]);
        assert_eq!(
            j.to_string(),
            r#"{"k\"ey":"va\\l\nue","n":3,"b":true,"a":[1,2]}"#
        );
    }

    #[test]
    fn report_schema_has_all_top_level_keys() {
        let f = Finding {
            rule: crate::rules::RULE_RNG_TAG,
            file: "a.rs".into(),
            line: 3,
            msg: "m".into(),
            waived: true,
            waiver_reason: Some("because".into()),
        };
        let w = Waiver {
            rule: "rng-tag-literal".into(),
            file: "a.rs".into(),
            line: 2,
            target_line: 3,
            reason: "because".into(),
            used: true,
        };
        let doc = build(&["rust/src".into()], 1, &[f], &[w]).to_string();
        for key in [
            "\"tool\"", "\"version\"", "\"roots\"", "\"rules\"", "\"files_checked\"",
            "\"summary\"", "\"unwaived\"", "\"waived\"", "\"unused_waivers\"",
            "\"findings\"", "\"waivers\"", "\"reason\"",
        ] {
            assert!(doc.contains(key), "missing {key} in {doc}");
        }
        assert!(doc.contains("\"unwaived\":0"));
        assert!(doc.contains("\"waived\":1"));
    }
}
