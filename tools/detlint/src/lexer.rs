//! A minimal hand-rolled Rust lexer — just enough token structure for the
//! determinism rules, with the parts that matter for *not lying* done
//! carefully: comments (line, doc, nested block), string / raw-string /
//! byte-string / char literals, and the `'x'`-char vs `'a`-lifetime
//! ambiguity. Everything the rules match (`.split(`, `Instant::now`,
//! `unsafe`, …) is matched against real code tokens, never against text
//! inside comments or string literals.
//!
//! No `syn`, no dependencies: the repo's vendoring policy is offline, and
//! the subset of Rust lexical structure needed here is small and stable.

/// Token kind. Literal *content* is irrelevant to every rule except
/// comments (waivers, `// SAFETY:`), so only comments carry their text.
#[derive(Clone, Debug, PartialEq)]
pub enum Tok {
    /// Identifier or keyword (`split`, `unsafe`, `pub`, `r#async`, …).
    Ident(String),
    /// `'a` — lifetime or loop label.
    Lifetime,
    /// Numeric literal (`1000`, `0x5D17`, `2.0`, `1_000`).
    Num,
    /// String literal of any flavour: `"…"`, `r"…"`, `r#"…"#`, `b"…"`.
    Str,
    /// Char or byte literal: `'x'`, `'\n'`, `b'x'`.
    Char,
    /// Any single punctuation / operator character.
    Punct(char),
    /// Comment of any flavour (`//`, `///`, `//!`, `/* … */`, nested),
    /// carrying its raw text including delimiters.
    Comment(String),
}

/// One token with its 1-based source line span (block comments can span
/// many lines; everything else starts and ends on `line`).
#[derive(Clone, Debug)]
pub struct Token {
    pub tok: Tok,
    pub line: u32,
    pub end_line: u32,
}

impl Token {
    pub fn ident(&self) -> Option<&str> {
        match &self.tok {
            Tok::Ident(s) => Some(s),
            _ => None,
        }
    }

    pub fn is_punct(&self, c: char) -> bool {
        self.tok == Tok::Punct(c)
    }

    pub fn is_comment(&self) -> bool {
        matches!(self.tok, Tok::Comment(_))
    }
}

/// Lex `src` into tokens. Unterminated constructs (string/comment at EOF)
/// terminate at end of input rather than erroring: the linter must never
/// crash on the tree it guards, and rustc will reject such files anyway.
pub fn lex(src: &str) -> Vec<Token> {
    let cs: Vec<char> = src.chars().collect();
    let n = cs.len();
    let mut out = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;

    while i < n {
        let c = cs[i];

        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }

        // ---- comments ------------------------------------------------
        if c == '/' && i + 1 < n && cs[i + 1] == '/' {
            let start = i;
            while i < n && cs[i] != '\n' {
                i += 1;
            }
            out.push(Token {
                tok: Tok::Comment(cs[start..i].iter().collect()),
                line,
                end_line: line,
            });
            continue;
        }
        if c == '/' && i + 1 < n && cs[i + 1] == '*' {
            let start = i;
            let start_line = line;
            let mut depth = 1u32;
            i += 2;
            while i < n && depth > 0 {
                if cs[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if cs[i] == '/' && i + 1 < n && cs[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if cs[i] == '*' && i + 1 < n && cs[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            out.push(Token {
                tok: Tok::Comment(cs[start..i].iter().collect()),
                line: start_line,
                end_line: line,
            });
            continue;
        }

        // ---- raw / byte string prefixes ------------------------------
        // r"…", r#"…"#, br"…", b"…", b'…' — checked before plain ident
        // lexing so the prefix letters don't come out as an Ident.
        if c == 'r' || c == 'b' {
            if let Some((next_i, next_line, tok)) = lex_prefixed_literal(&cs, i, line) {
                out.push(Token { tok, line, end_line: next_line });
                line = next_line;
                i = next_i;
                continue;
            }
        }

        // ---- plain string --------------------------------------------
        if c == '"' {
            let start_line = line;
            i += 1;
            while i < n && cs[i] != '"' {
                if cs[i] == '\\' {
                    i += 2;
                } else {
                    if cs[i] == '\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            i += 1; // closing quote (or EOF)
            out.push(Token { tok: Tok::Str, line: start_line, end_line: line });
            continue;
        }

        // ---- char literal vs lifetime --------------------------------
        if c == '\'' {
            // 'x' / '\n' are chars; 'a / 'static are lifetimes. After the
            // quote: a backslash means char; <single char>' means char;
            // anything else is a lifetime (including '' which rustc
            // rejects — treated as a zero-length lifetime here).
            if i + 1 < n && cs[i + 1] == '\\' {
                i += 2; // quote + backslash
                while i < n && cs[i] != '\'' {
                    if cs[i] == '\\' {
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
                i += 1;
                out.push(Token { tok: Tok::Char, line, end_line: line });
                continue;
            }
            if i + 2 < n && cs[i + 2] == '\'' && cs[i + 1] != '\'' {
                i += 3;
                out.push(Token { tok: Tok::Char, line, end_line: line });
                continue;
            }
            i += 1;
            while i < n && (cs[i] == '_' || cs[i].is_alphanumeric()) {
                i += 1;
            }
            out.push(Token { tok: Tok::Lifetime, line, end_line: line });
            continue;
        }

        // ---- identifiers / keywords ----------------------------------
        if c == '_' || c.is_alphabetic() {
            let start = i;
            while i < n && (cs[i] == '_' || cs[i].is_alphanumeric()) {
                i += 1;
            }
            out.push(Token {
                tok: Tok::Ident(cs[start..i].iter().collect()),
                line,
                end_line: line,
            });
            continue;
        }

        // ---- numbers -------------------------------------------------
        if c.is_ascii_digit() {
            i += 1;
            while i < n && (cs[i] == '_' || cs[i].is_alphanumeric()) {
                i += 1;
            }
            // fractional part: `.` followed by a digit (leaves `1..k`
            // ranges and method calls like `1.max(x)` alone)
            if i + 1 < n && cs[i] == '.' && cs[i + 1].is_ascii_digit() {
                i += 1;
                while i < n && (cs[i] == '_' || cs[i].is_alphanumeric()) {
                    i += 1;
                }
            }
            out.push(Token { tok: Tok::Num, line, end_line: line });
            continue;
        }

        out.push(Token { tok: Tok::Punct(c), line, end_line: line });
        i += 1;
    }
    out
}

/// Try to lex `r"…"`, `r#"…"#`, `b"…"`, `br#"…"#`, `b'…'` at `i` (which
/// points at `r` or `b`). Returns `(index after, line after, token)`, or
/// `None` if this is an ordinary identifier starting with r/b.
fn lex_prefixed_literal(cs: &[char], i: usize, line: u32) -> Option<(usize, u32, Tok)> {
    let n = cs.len();
    let mut j = i;
    let mut raw = false;
    if cs[j] == 'b' {
        j += 1;
        if j < n && cs[j] == '\'' {
            // byte char literal b'x' / b'\n'
            j += 1;
            if j < n && cs[j] == '\\' {
                j += 1;
                while j < n && cs[j] != '\'' {
                    if cs[j] == '\\' {
                        j += 2;
                    } else {
                        j += 1;
                    }
                }
                j += 1;
            } else {
                // b'x'
                j += 1;
                if j < n && cs[j] == '\'' {
                    j += 1;
                } else {
                    return None; // b'a — not a literal rustc accepts
                }
            }
            return Some((j, line, Tok::Char));
        }
        if j < n && cs[j] == 'r' {
            raw = true;
            j += 1;
        }
    } else {
        // cs[j] == 'r'
        raw = true;
        j += 1;
    }

    if raw {
        let mut hashes = 0usize;
        while j < n && cs[j] == '#' {
            hashes += 1;
            j += 1;
        }
        if j >= n || cs[j] != '"' {
            return None; // r#foo raw identifier, or plain ident r…/br…
        }
        j += 1;
        let mut ln = line;
        // scan for `"` followed by `hashes` hash chars
        'outer: while j < n {
            if cs[j] == '\n' {
                ln += 1;
                j += 1;
                continue;
            }
            if cs[j] == '"' {
                let mut k = 0usize;
                while k < hashes {
                    if j + 1 + k >= n || cs[j + 1 + k] != '#' {
                        j += 1;
                        continue 'outer;
                    }
                    k += 1;
                }
                j += 1 + hashes;
                return Some((j, ln, Tok::Str));
            }
            j += 1;
        }
        return Some((j, ln, Tok::Str)); // unterminated: swallow to EOF
    }

    // b"…" plain byte string
    if j < n && cs[j] == '"' {
        j += 1;
        let mut ln = line;
        while j < n && cs[j] != '"' {
            if cs[j] == '\\' {
                j += 2;
            } else {
                if cs[j] == '\n' {
                    ln += 1;
                }
                j += 1;
            }
        }
        j += 1;
        return Some((j, ln, Tok::Str));
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<Tok> {
        lex(src).into_iter().map(|t| t.tok).collect()
    }

    #[test]
    fn idents_puncts_numbers() {
        let toks = kinds("let x = rng.split(1000 + p);");
        assert!(toks.contains(&Tok::Ident("split".into())));
        assert!(toks.contains(&Tok::Num));
        assert!(toks.contains(&Tok::Punct('.')));
        assert_eq!(toks.iter().filter(|t| matches!(t, Tok::Num)).count(), 1);
    }

    #[test]
    fn hex_and_underscored_numbers_are_single_tokens() {
        assert_eq!(kinds("0x5D17"), vec![Tok::Num]);
        assert_eq!(kinds("1_000_000u64"), vec![Tok::Num]);
        assert_eq!(kinds("2.5e3"), vec![Tok::Num]);
    }

    #[test]
    fn line_and_doc_comments() {
        let toks = lex("// plain\n/// doc\n//! inner\ncode");
        assert_eq!(toks.len(), 4);
        assert!(toks[0].is_comment() && toks[1].is_comment() && toks[2].is_comment());
        assert_eq!(toks[3].ident(), Some("code"));
        assert_eq!(toks[3].line, 4);
    }

    #[test]
    fn nested_block_comments() {
        let toks = lex("/* outer /* inner */ still comment */ after");
        assert_eq!(toks.len(), 2);
        assert!(toks[0].is_comment());
        assert_eq!(toks[1].ident(), Some("after"));
    }

    #[test]
    fn block_comment_line_spans() {
        let toks = lex("/* a\nb\nc */ x");
        assert_eq!(toks[0].line, 1);
        assert_eq!(toks[0].end_line, 3);
        assert_eq!(toks[1].line, 3);
    }

    #[test]
    fn code_inside_strings_is_not_tokenised() {
        let toks = kinds(r#"let s = ".split(1000) Instant::now() unsafe";"#);
        assert!(!toks.contains(&Tok::Ident("Instant".into())));
        assert!(!toks.contains(&Tok::Ident("unsafe".into())));
        assert_eq!(toks.iter().filter(|t| matches!(t, Tok::Str)).count(), 1);
    }

    #[test]
    fn raw_strings_with_hashes_and_embedded_quotes() {
        let toks = kinds(r##"let s = r#"quote " and .split(7777)"# ; x"##);
        assert_eq!(toks.iter().filter(|t| matches!(t, Tok::Str)).count(), 1);
        assert!(!toks.contains(&Tok::Ident("split".into())));
        assert!(toks.contains(&Tok::Ident("x".into())));
    }

    #[test]
    fn multiline_raw_string_tracks_lines() {
        let toks = lex("r\"a\nb\" x");
        assert_eq!(toks[0].line, 1);
        assert_eq!(toks[0].end_line, 2);
        assert_eq!(toks[1].line, 2);
    }

    #[test]
    fn byte_strings_and_byte_chars() {
        assert_eq!(kinds(r#"b"bytes""#), vec![Tok::Str]);
        assert_eq!(kinds("b'x'"), vec![Tok::Char]);
        assert_eq!(kinds(r"b'\n'"), vec![Tok::Char]);
        assert_eq!(kinds(r#"br"raw bytes""#), vec![Tok::Str]);
    }

    #[test]
    fn char_vs_lifetime() {
        assert_eq!(kinds("'x'"), vec![Tok::Char]);
        assert_eq!(kinds(r"'\n'"), vec![Tok::Char]);
        assert_eq!(kinds(r"'\''"), vec![Tok::Char]);
        // lifetime then ident
        let toks = kinds("&'static str");
        assert_eq!(
            toks,
            vec![Tok::Punct('&'), Tok::Lifetime, Tok::Ident("str".into())]
        );
        // lifetime in generics: the `'a` must not eat the `>`
        let toks = kinds("Foo<'a>");
        assert!(toks.contains(&Tok::Lifetime));
        assert!(toks.contains(&Tok::Punct('>')));
        // char containing a quote-adjacent letter: 'r' is a char, not a
        // raw-string prefix
        assert_eq!(kinds("'r'"), vec![Tok::Char]);
    }

    #[test]
    fn idents_starting_with_r_or_b_are_plain_idents() {
        assert_eq!(kinds("rng"), vec![Tok::Ident("rng".into())]);
        assert_eq!(kinds("b_rows"), vec![Tok::Ident("b_rows".into())]);
        assert_eq!(kinds("break"), vec![Tok::Ident("break".into())]);
        assert_eq!(kinds("raw"), vec![Tok::Ident("raw".into())]);
    }

    #[test]
    fn split_in_comment_is_a_comment() {
        let toks = lex("// rng.split(1000 + p) explanation\ncode");
        assert!(toks[0].is_comment());
        assert_eq!(toks[1].ident(), Some("code"));
    }
}
