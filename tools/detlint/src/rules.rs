//! The determinism rules, the `#[cfg(test)]` skip, and waiver handling.
//!
//! Rules are named and individually waivable with an inline pragma on the
//! line above (or the same line as) the finding:
//!
//! ```text
//! // detlint:allow(rule-id): one-line justification
//! offending_code();
//! ```
//!
//! A waiver with no justification is itself a finding (`waiver-syntax`):
//! the whole point is that every exception carries its proof in-tree.
//! Unused waivers are reported as warnings (not failures) so stale
//! pragmas get cleaned up.
//!
//! | id                    | scope                                        | invariant |
//! |-----------------------|----------------------------------------------|-----------|
//! | `rng-tag-literal`     | everywhere                                   | `.split(tag)` must use the `rng/tags.rs` registry, not a numeric literal |
//! | `wall-clock-in-chain` | all but `obs/`, `bench/`, `main.rs`, `runner.rs` | no `Instant::now` / `SystemTime` where the chain could see it |
//! | `hash-order`          | `coordinator/ samplers/ model/ parallel/ serve/` | no `HashMap`/`HashSet` (iteration order is hasher-seeded) |
//! | `no-panic-coordinator`| `coordinator/`, `parallel/pool.rs`, `serve/` | no `unwrap()` / `expect(` / `panic!` / `unreachable!` |
//! | `undocumented-unsafe` | everywhere                                   | every `unsafe` block carries a `// SAFETY:` comment |
//! | `stray-thread`        | all but `parallel/`                          | no `thread::spawn` / `thread::scope` / `thread::Builder` |
//! | `net-outside-transport` | all but `coordinator/transport/`, `main.rs` | no `std::net`/UDS socket types: every byte crosses the `Transport` trait |
//!
//! Code under `#[cfg(test)]` (and `#[test]` functions) is exempt from all
//! rules: tests may panic, time themselves, and spawn threads freely.

use std::collections::BTreeSet;

use crate::lexer::{lex, Tok, Token};

pub const RULE_RNG_TAG: &str = "rng-tag-literal";
pub const RULE_WALL_CLOCK: &str = "wall-clock-in-chain";
pub const RULE_HASH_ORDER: &str = "hash-order";
pub const RULE_NO_PANIC: &str = "no-panic-coordinator";
pub const RULE_UNSAFE: &str = "undocumented-unsafe";
pub const RULE_STRAY_THREAD: &str = "stray-thread";
pub const RULE_NET: &str = "net-outside-transport";
pub const RULE_WAIVER_SYNTAX: &str = "waiver-syntax";

/// All enforceable rule ids (what `detlint:allow(...)` may name).
pub const RULE_IDS: &[&str] = &[
    RULE_RNG_TAG,
    RULE_WALL_CLOCK,
    RULE_HASH_ORDER,
    RULE_NO_PANIC,
    RULE_UNSAFE,
    RULE_STRAY_THREAD,
    RULE_NET,
];

/// One rule violation (possibly waived).
#[derive(Clone, Debug)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: u32,
    pub msg: String,
    pub waived: bool,
    pub waiver_reason: Option<String>,
}

/// One parsed `detlint:allow` pragma.
#[derive(Clone, Debug)]
pub struct Waiver {
    pub rule: String,
    pub file: String,
    /// Line of the pragma comment itself.
    pub line: u32,
    /// Line of the first code token after the pragma — what it covers.
    pub target_line: u32,
    pub reason: String,
    /// Set when a finding matched this waiver.
    pub used: bool,
}

/// Everything the linter learned about one file.
#[derive(Clone, Debug, Default)]
pub struct FileReport {
    pub findings: Vec<Finding>,
    pub waivers: Vec<Waiver>,
}

/// The `.split(…)` allowlist parsed from `rng/tags.rs`: names of
/// `pub const NAME: u64` items and `pub fn name(…)` helpers.
#[derive(Clone, Debug, Default)]
pub struct TagRegistry {
    pub names: BTreeSet<String>,
}

impl TagRegistry {
    /// Parse the registry source. Only u64 consts count (the `FAMILIES`
    /// table itself must not legitimise a raw tag expression).
    pub fn parse(src: &str) -> Self {
        let toks = lex(src);
        let code: Vec<&Token> = toks.iter().filter(|t| !t.is_comment()).collect();
        let mut names = BTreeSet::new();
        let mut i = 0;
        while i < code.len() {
            if code[i].ident() == Some("pub") {
                match code.get(i + 1).and_then(|t| t.ident()) {
                    Some("const") => {
                        // pub const NAME : u64 =
                        if let (Some(name), true, Some("u64")) = (
                            code.get(i + 2).and_then(|t| t.ident()),
                            code.get(i + 3).is_some_and(|t| t.is_punct(':')),
                            code.get(i + 4).and_then(|t| t.ident()),
                        ) {
                            names.insert(name.to_string());
                        }
                    }
                    Some("fn") => {
                        if let Some(name) = code.get(i + 2).and_then(|t| t.ident()) {
                            names.insert(name.to_string());
                        }
                    }
                    _ => {}
                }
            }
            i += 1;
        }
        Self { names }
    }
}

/// Path scoping, on `/`-normalised relative paths.
struct Scope<'a> {
    path: &'a str,
    file_name: &'a str,
}

impl<'a> Scope<'a> {
    fn new(path: &'a str) -> Self {
        let file_name = path.rsplit('/').next().unwrap_or(path);
        Self { path, file_name }
    }

    fn in_dir(&self, dir: &str) -> bool {
        // matches "…/<dir>/…" and a leading "<dir>/…"
        self.path.contains(&format!("/{dir}/")) || self.path.starts_with(&format!("{dir}/"))
    }

    fn wall_clock_allowed(&self) -> bool {
        self.in_dir("obs")
            || self.in_dir("bench")
            || self.file_name == "main.rs"
            || self.file_name == "runner.rs"
    }

    fn hash_order_scoped(&self) -> bool {
        ["coordinator", "samplers", "model", "parallel", "serve"]
            .iter()
            .any(|d| self.in_dir(d))
    }

    fn no_panic_scoped(&self) -> bool {
        self.in_dir("coordinator")
            || self.in_dir("serve")
            || (self.in_dir("parallel") && self.file_name == "pool.rs")
    }

    fn thread_allowed(&self) -> bool {
        self.in_dir("parallel")
    }

    fn net_allowed(&self) -> bool {
        // the transport module owns every socket; main.rs only *names*
        // the worker CLI entry point (run_remote_worker lives in
        // transport/ too, so main.rs rarely needs this allowance)
        self.path.contains("coordinator/transport/") || self.file_name == "main.rs"
    }
}

/// R7 target set: the socket/datagram types of `std::net` and
/// `std::os::unix::net`. Naming one outside the transport module means
/// bytes are moving around the `Transport` trait — and around the frame
/// bounds, handshake, and abort-sentinel discipline that keep socket
/// runs bit-identical and hang-free.
const NET_TYPES: &[&str] = &[
    "TcpListener",
    "TcpStream",
    "UdpSocket",
    "UnixListener",
    "UnixStream",
    "UnixDatagram",
];

/// Lint one file. `path` is the repo-relative path (used for scoping and
/// reporting); `src` its contents; `tags` the `.split` allowlist.
pub fn check_file(path: &str, src: &str, tags: &TagRegistry) -> FileReport {
    let path = path.replace('\\', "/");
    let scope = Scope::new(&path);
    let toks = lex(src);
    let skip = test_regions(&toks);
    let mut report = FileReport::default();

    parse_waivers(&toks, &path, &mut report);

    // Code-token view (comments out), remembering raw indices so the
    // test-region skip mask (built over raw tokens) still applies.
    let code: Vec<(usize, &Token)> = toks
        .iter()
        .enumerate()
        .filter(|(_, t)| !t.is_comment())
        .collect();

    let mut push = |rule: &'static str, line: u32, msg: String| {
        report.findings.push(Finding {
            rule,
            file: path.clone(),
            line,
            msg,
            waived: false,
            waiver_reason: None,
        });
    };

    for (ci, &(ri, tok)) in code.iter().enumerate() {
        if skip[ri] {
            continue;
        }
        let at = |off: isize| -> Option<&Token> {
            let idx = ci as isize + off;
            if idx < 0 {
                None
            } else {
                code.get(idx as usize).map(|&(_, t)| t)
            }
        };

        match &tok.tok {
            // ---- R1: .split(<expr>) must reference the tag registry --
            Tok::Ident(id) if id == "split" => {
                if at(-1).is_some_and(|t| t.is_punct('.'))
                    && at(1).is_some_and(|t| t.is_punct('('))
                {
                    check_split_args(&code, ci + 2, tags, tok.line, &mut push);
                }
            }

            // ---- R2: wall clock -------------------------------------
            Tok::Ident(id) if id == "Instant" && !scope.wall_clock_allowed() => {
                if at(1).is_some_and(|t| t.is_punct(':'))
                    && at(2).is_some_and(|t| t.is_punct(':'))
                    && at(3).and_then(|t| t.ident()) == Some("now")
                {
                    push(
                        RULE_WALL_CLOCK,
                        tok.line,
                        "Instant::now() outside the obs/bench/main/runner timing allowlist"
                            .into(),
                    );
                }
            }
            Tok::Ident(id) if id == "SystemTime" && !scope.wall_clock_allowed() => {
                push(
                    RULE_WALL_CLOCK,
                    tok.line,
                    "SystemTime outside the obs/bench/main/runner timing allowlist".into(),
                );
            }

            // ---- R3: hash-ordered collections -----------------------
            Tok::Ident(id)
                if (id == "HashMap" || id == "HashSet") && scope.hash_order_scoped() =>
            {
                push(
                    RULE_HASH_ORDER,
                    tok.line,
                    format!(
                        "{id} in a chain-affecting module: iteration order is \
                         hasher-seeded; use BTreeMap/BTreeSet or a Vec"
                    ),
                );
            }

            // ---- R4: panic paths ------------------------------------
            Tok::Ident(id)
                if scope.no_panic_scoped()
                    && (id == "unwrap" || id == "expect")
                    && at(-1).is_some_and(|t| t.is_punct('.'))
                    && at(1).is_some_and(|t| t.is_punct('(')) =>
            {
                push(
                    RULE_NO_PANIC,
                    tok.line,
                    format!(".{id}() in a no-panic zone: convert to a contextual Err"),
                );
            }
            Tok::Ident(id)
                if scope.no_panic_scoped()
                    && (id == "panic" || id == "unreachable" || id == "todo"
                        || id == "unimplemented")
                    && at(1).is_some_and(|t| t.is_punct('!')) =>
            {
                push(
                    RULE_NO_PANIC,
                    tok.line,
                    format!("{id}! in a no-panic zone: convert to a contextual Err"),
                );
            }

            // ---- R5: undocumented unsafe ----------------------------
            Tok::Ident(id) if id == "unsafe" => {
                if !preceded_by_safety_comment(&toks, ri) {
                    push(
                        RULE_UNSAFE,
                        tok.line,
                        "unsafe without a `// SAFETY:` comment immediately above".into(),
                    );
                }
            }

            // ---- R6: stray threads ----------------------------------
            Tok::Ident(id) if id == "thread" && !scope.thread_allowed() => {
                if at(1).is_some_and(|t| t.is_punct(':'))
                    && at(2).is_some_and(|t| t.is_punct(':'))
                {
                    if let Some(what) = at(3).and_then(|t| t.ident()) {
                        if what == "spawn" || what == "scope" || what == "Builder" {
                            push(
                                RULE_STRAY_THREAD,
                                tok.line,
                                format!(
                                    "thread::{what} outside parallel/: all threads \
                                     belong to the pool or the coordinator"
                                ),
                            );
                        }
                    }
                }
            }
            // ---- R7: sockets outside the transport module -----------
            Tok::Ident(id)
                if NET_TYPES.contains(&id.as_str()) && !scope.net_allowed() =>
            {
                push(
                    RULE_NET,
                    tok.line,
                    format!(
                        "{id} outside coordinator/transport/: all master↔worker \
                         bytes must cross the Transport trait"
                    ),
                );
            }
            _ => {}
        }
    }

    apply_waivers(&mut report);
    report
}

/// R1 argument check, starting at the code index just past `.split(`.
/// A first-argument string/char literal means `str::split` — skipped.
/// Otherwise the argument tokens must reference at least one registry
/// name; a purely literal/operator expression (e.g. `1000 + p` has `p`…
/// so: any *numeric literal* present without a registry identifier) is a
/// finding.
fn check_split_args<F: FnMut(&'static str, u32, String)>(
    code: &[(usize, &Token)],
    start: usize,
    tags: &TagRegistry,
    line: u32,
    push: &mut F,
) {
    // collect argument tokens to the matching close paren
    let mut depth = 1i32;
    let mut i = start;
    let mut arg: Vec<&Token> = Vec::new();
    while i < code.len() && depth > 0 {
        let t = code[i].1;
        if t.is_punct('(') {
            depth += 1;
        } else if t.is_punct(')') {
            depth -= 1;
            if depth == 0 {
                break;
            }
        }
        arg.push(t);
        i += 1;
    }
    match arg.first().map(|t| &t.tok) {
        // str::split / split(',') / split("sep") — not an RNG split
        Some(Tok::Str) | Some(Tok::Char) => return,
        None => return, // `.split()` — not ours either
        _ => {}
    }
    let has_registry_name = arg
        .iter()
        .any(|t| t.ident().is_some_and(|id| id == "tags" || tags.names.contains(id)));
    if has_registry_name {
        return;
    }
    let has_num = arg.iter().any(|t| matches!(t.tok, Tok::Num));
    if has_num {
        push(
            RULE_RNG_TAG,
            line,
            "raw numeric RNG stream tag: use a named constant from rng/tags.rs".into(),
        );
    } else {
        push(
            RULE_RNG_TAG,
            line,
            "RNG stream tag not derived from the rng/tags.rs registry".into(),
        );
    }
}

/// True if the contiguous comment block directly above raw token `ri`
/// (only comments between it and the `unsafe` token) contains `SAFETY:`.
fn preceded_by_safety_comment(toks: &[Token], ri: usize) -> bool {
    let mut j = ri;
    while j > 0 {
        j -= 1;
        match &toks[j].tok {
            Tok::Comment(text) => {
                if text.contains("SAFETY:") {
                    return true;
                }
            }
            // allow the pattern `let x = unsafe { … }`: look past the
            // few tokens of the binding on the same line
            _ => {
                if toks[j].end_line + 1 >= toks[ri].line {
                    continue;
                }
                return false;
            }
        }
    }
    false
}

/// Mark every raw-token index inside a `#[cfg(test)]` / `#[test]` item.
///
/// Matches exactly `# [ cfg ( test ) ]` and `# [ test ]` — *not*
/// `#[cfg(feature = "…")]` or `#[cfg_attr(…)]` — then consumes any
/// further attributes and the following item to its matching `}` (or a
/// terminating `;` for itemless forms like `#[cfg(test)] use …;`).
fn test_regions(toks: &[Token]) -> Vec<bool> {
    let mut skip = vec![false; toks.len()];
    // code-token indices
    let code: Vec<usize> = (0..toks.len()).filter(|&i| !toks[i].is_comment()).collect();
    let tok_at = |k: usize| -> Option<&Token> { code.get(k).map(|&i| &toks[i]) };

    let mut k = 0usize;
    while k < code.len() {
        let is_test_attr = tok_at(k).is_some_and(|t| t.is_punct('#'))
            && tok_at(k + 1).is_some_and(|t| t.is_punct('['))
            && (
                // #[test]
                (tok_at(k + 2).and_then(|t| t.ident()) == Some("test")
                    && tok_at(k + 3).is_some_and(|t| t.is_punct(']')))
                // #[cfg(test)]
                || (tok_at(k + 2).and_then(|t| t.ident()) == Some("cfg")
                    && tok_at(k + 3).is_some_and(|t| t.is_punct('('))
                    && tok_at(k + 4).and_then(|t| t.ident()) == Some("test")
                    && tok_at(k + 5).is_some_and(|t| t.is_punct(')'))
                    && tok_at(k + 6).is_some_and(|t| t.is_punct(']')))
            );
        if !is_test_attr {
            k += 1;
            continue;
        }
        let start = k;
        // past this attribute
        k = skip_attr(&code, toks, k);
        // past any further attributes (#[allow(…)], #[ignore], …)
        while tok_at(k).is_some_and(|t| t.is_punct('#'))
            && tok_at(k + 1).is_some_and(|t| t.is_punct('['))
        {
            k = skip_attr(&code, toks, k);
        }
        // consume the item: to the close of the first brace group, or a
        // `;` seen before any `{` (e.g. `#[cfg(test)] use foo;`)
        let mut depth = 0i32;
        let mut entered = false;
        while k < code.len() {
            let t = &toks[code[k]];
            if t.is_punct('{') {
                depth += 1;
                entered = true;
            } else if t.is_punct('}') {
                depth -= 1;
                if entered && depth == 0 {
                    k += 1;
                    break;
                }
            } else if t.is_punct(';') && !entered {
                k += 1;
                break;
            }
            k += 1;
        }
        // mark the raw-token span (comments inside included)
        let lo = code[start];
        let hi = if k < code.len() { code[k] } else { toks.len() };
        for s in skip.iter_mut().take(hi).skip(lo) {
            *s = true;
        }
    }
    skip
}

/// Advance past one `# [ … ]` attribute starting at code index `k`.
fn skip_attr(code: &[usize], toks: &[Token], mut k: usize) -> usize {
    // at '#'; move to '['
    k += 1;
    let mut depth = 0i32;
    while k < code.len() {
        let t = &toks[code[k]];
        if t.is_punct('[') {
            depth += 1;
        } else if t.is_punct(']') {
            depth -= 1;
            if depth == 0 {
                return k + 1;
            }
        }
        k += 1;
    }
    k
}

/// Extract `detlint:allow(rule): reason` pragmas from comment tokens.
/// The waiver covers findings on its own line and on the line of the
/// next code token after it (comments in between are skipped).
fn parse_waivers(toks: &[Token], path: &str, report: &mut FileReport) {
    for (i, t) in toks.iter().enumerate() {
        let text = match &t.tok {
            Tok::Comment(c) => c,
            _ => continue,
        };
        let Some(pos) = text.find("detlint:allow") else { continue };
        let rest = &text[pos + "detlint:allow".len()..];
        // expect (rule-id): reason
        let parsed = (|| {
            let rest = rest.trim_start();
            let rest = rest.strip_prefix('(')?;
            let close = rest.find(')')?;
            let rule = rest[..close].trim().to_string();
            let after = rest[close + 1..].trim_start();
            let reason = after.strip_prefix(':')?.trim().to_string();
            Some((rule, reason))
        })();
        let Some((rule, reason)) = parsed else {
            report.findings.push(Finding {
                rule: RULE_WAIVER_SYNTAX,
                file: path.to_string(),
                line: t.line,
                msg: "malformed waiver: expected `detlint:allow(<rule>): <reason>`".into(),
                waived: false,
                waiver_reason: None,
            });
            continue;
        };
        if !RULE_IDS.contains(&rule.as_str()) {
            report.findings.push(Finding {
                rule: RULE_WAIVER_SYNTAX,
                file: path.to_string(),
                line: t.line,
                msg: format!("waiver names unknown rule `{rule}`"),
                waived: false,
                waiver_reason: None,
            });
            continue;
        }
        if reason.is_empty() {
            report.findings.push(Finding {
                rule: RULE_WAIVER_SYNTAX,
                file: path.to_string(),
                line: t.line,
                msg: format!("waiver for `{rule}` has no justification"),
                waived: false,
                waiver_reason: None,
            });
            continue;
        }
        let target_line = toks[i + 1..]
            .iter()
            .find(|n| !n.is_comment())
            .map(|n| n.line)
            .unwrap_or(u32::MAX);
        report.waivers.push(Waiver {
            rule,
            file: path.to_string(),
            line: t.line,
            target_line,
            reason,
            used: false,
        });
    }
}

/// Match findings against waivers (same rule, finding on the waiver's
/// own line or its target line).
fn apply_waivers(report: &mut FileReport) {
    for f in report.findings.iter_mut() {
        if f.rule == RULE_WAIVER_SYNTAX {
            continue; // the waiver mechanism cannot waive itself
        }
        for w in report.waivers.iter_mut() {
            if w.rule == f.rule && (f.line == w.line || f.line == w.target_line) {
                f.waived = true;
                f.waiver_reason = Some(w.reason.clone());
                w.used = true;
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn registry() -> TagRegistry {
        TagRegistry::parse(
            "pub const MASTER: u64 = 1;\n\
             pub const WORKER_BASE: u64 = 1000;\n\
             pub fn worker(p: usize) -> u64 { WORKER_BASE + p as u64 }\n\
             pub const FAMILIES: &[Family] = &[];\n",
        )
    }

    #[test]
    fn registry_parses_u64_consts_and_fns_only() {
        let r = registry();
        assert!(r.names.contains("MASTER"));
        assert!(r.names.contains("WORKER_BASE"));
        assert!(r.names.contains("worker"));
        assert!(!r.names.contains("FAMILIES"), "non-u64 consts must not count");
    }

    #[test]
    fn r1_flags_literal_tags_and_accepts_registry_names() {
        let r = registry();
        let bad = check_file("x/a.rs", "fn f(rng: R) { rng.split(1000 + p); }", &r);
        assert_eq!(bad.findings.len(), 1);
        assert_eq!(bad.findings[0].rule, RULE_RNG_TAG);

        let good = check_file(
            "x/a.rs",
            "fn f(rng: R) { rng.split(tags::worker(p)); rng.split(MASTER); }",
            &r,
        );
        assert!(good.findings.is_empty(), "{:?}", good.findings);
    }

    #[test]
    fn r1_ignores_str_split() {
        let r = registry();
        let rep = check_file(
            "x/a.rs",
            "fn f(s: &str) { s.split(','); s.split(\"sep\"); line.split('\\t'); }",
            &r,
        );
        assert!(rep.findings.is_empty(), "{:?}", rep.findings);
    }

    #[test]
    fn r2_is_path_scoped() {
        let r = registry();
        let bad = check_file("rust/src/model/a.rs", "fn f() { let t = Instant::now(); }", &r);
        assert_eq!(bad.findings.len(), 1);
        assert_eq!(bad.findings[0].rule, RULE_WALL_CLOCK);
        for ok_path in ["rust/src/obs/mod.rs", "rust/src/bench/x.rs", "rust/src/main.rs", "rust/src/runner.rs"] {
            let ok = check_file(ok_path, "fn f() { let t = Instant::now(); }", &r);
            assert!(ok.findings.is_empty(), "{ok_path}: {:?}", ok.findings);
        }
    }

    #[test]
    fn r3_flags_hash_collections_in_chain_modules_only() {
        let r = registry();
        let bad = check_file(
            "rust/src/model/state.rs",
            "use std::collections::HashMap;\nfn f() { let m: HashMap<u8, u8> = HashMap::new(); }",
            &r,
        );
        assert_eq!(bad.findings.len(), 3); // use + type + ctor mentions
        assert!(bad.findings.iter().all(|f| f.rule == RULE_HASH_ORDER));
        let ok = check_file("rust/src/runtime/pjrt.rs", "use std::collections::HashMap;", &r);
        assert!(ok.findings.is_empty());
    }

    #[test]
    fn r4_flags_panic_paths_in_scope() {
        let r = registry();
        let src = "fn f() { x.unwrap(); y.expect(\"m\"); panic!(\"b\"); unreachable!(); }";
        let bad = check_file("rust/src/coordinator/master.rs", src, &r);
        assert_eq!(bad.findings.len(), 4);
        assert!(bad.findings.iter().all(|f| f.rule == RULE_NO_PANIC));
        // pool.rs is in scope; blocks.rs is not
        assert!(!check_file("rust/src/parallel/pool.rs", src, &r).findings.is_empty());
        assert!(check_file("rust/src/parallel/blocks.rs", src, &r).findings.is_empty());
        assert!(check_file("rust/src/samplers/gibbs.rs", src, &r).findings.is_empty());
    }

    #[test]
    fn r5_requires_safety_comment() {
        let r = registry();
        let bad = check_file("x/a.rs", "fn f() { unsafe { g(); } }", &r);
        assert_eq!(bad.findings.len(), 1);
        assert_eq!(bad.findings[0].rule, RULE_UNSAFE);
        let ok = check_file(
            "x/a.rs",
            "fn f() {\n    // SAFETY: g is sound here because reasons\n    unsafe { g(); }\n}",
            &r,
        );
        assert!(ok.findings.is_empty(), "{:?}", ok.findings);
        // binding form: let x = unsafe { … } with the comment above the let
        let ok2 = check_file(
            "x/a.rs",
            "fn f() {\n    // SAFETY: sound\n    let x = unsafe { g() };\n}",
            &r,
        );
        assert!(ok2.findings.is_empty(), "{:?}", ok2.findings);
    }

    #[test]
    fn r6_flags_threads_outside_parallel() {
        let r = registry();
        let src = "fn f() { std::thread::spawn(|| {}); }";
        let bad = check_file("rust/src/serve/mod.rs", src, &r);
        assert_eq!(bad.findings.len(), 1);
        assert_eq!(bad.findings[0].rule, RULE_STRAY_THREAD);
        assert!(check_file("rust/src/parallel/pool.rs", src, &r).findings.is_empty());
    }

    #[test]
    fn r7_flags_socket_types_outside_the_transport_module() {
        let r = registry();
        let src = "use std::net::TcpStream;\nfn f(s: UnixListener) {}\n";
        let bad = check_file("rust/src/coordinator/master.rs", src, &r);
        assert_eq!(bad.findings.len(), 2, "{:?}", bad.findings);
        assert!(bad.findings.iter().all(|f| f.rule == RULE_NET));
        for ok_path in [
            "rust/src/coordinator/transport/socket.rs",
            "rust/src/coordinator/transport/mod.rs",
            "rust/src/main.rs",
        ] {
            let ok = check_file(ok_path, src, &r);
            assert!(ok.findings.is_empty(), "{ok_path}: {:?}", ok.findings);
        }
    }

    #[test]
    fn cfg_test_regions_are_exempt() {
        let r = registry();
        let src = "\
fn prod() {}\n\
#[cfg(test)]\n\
mod tests {\n\
    use std::collections::HashMap;\n\
    #[test]\n\
    fn t() { x.unwrap(); let i = Instant::now(); rng.split(1003); }\n\
}\n";
        let rep = check_file("rust/src/coordinator/messages.rs", src, &r);
        assert!(rep.findings.is_empty(), "{:?}", rep.findings);
    }

    #[test]
    fn cfg_feature_attrs_are_not_test_regions() {
        let r = registry();
        let src = "\
#[cfg(feature = \"pjrt\")]\n\
fn prod() { x.unwrap(); }\n\
#[cfg(not(feature = \"pjrt\"))]\n\
fn prod2() { y.unwrap(); }\n";
        let rep = check_file("rust/src/coordinator/master.rs", src, &r);
        assert_eq!(rep.findings.len(), 2, "feature-gated code is still production");
    }

    #[test]
    fn test_attr_with_following_attrs_and_use_items() {
        let r = registry();
        let src = "\
#[cfg(test)]\n\
use std::collections::HashMap;\n\
#[cfg(test)]\n\
#[allow(dead_code)]\n\
fn helper() { x.unwrap() }\n\
fn prod() { y.unwrap(); }\n";
        let rep = check_file("rust/src/coordinator/master.rs", src, &r);
        assert_eq!(rep.findings.len(), 1, "{:?}", rep.findings);
        assert_eq!(rep.findings[0].line, 6);
    }

    #[test]
    fn waiver_covers_next_code_line_and_is_counted() {
        let r = registry();
        let src = "\
fn f() {\n\
    // detlint:allow(no-panic-coordinator): provably infallible because reasons\n\
    x.unwrap();\n\
    y.unwrap();\n\
}\n";
        let rep = check_file("rust/src/coordinator/master.rs", src, &r);
        let unwaived: Vec<_> = rep.findings.iter().filter(|f| !f.waived).collect();
        assert_eq!(unwaived.len(), 1, "only the second unwrap stays flagged");
        assert_eq!(unwaived[0].line, 4);
        assert_eq!(rep.waivers.len(), 1);
        assert!(rep.waivers[0].used);
        assert_eq!(rep.waivers[0].rule, RULE_NO_PANIC);
    }

    #[test]
    fn waiver_must_name_the_right_rule() {
        let r = registry();
        let src = "\
fn f() {\n\
    // detlint:allow(wall-clock-in-chain): wrong rule for this finding\n\
    x.unwrap();\n\
}\n";
        let rep = check_file("rust/src/coordinator/master.rs", src, &r);
        assert_eq!(rep.findings.iter().filter(|f| !f.waived).count(), 1);
        assert!(!rep.waivers[0].used, "mismatched waiver stays unused");
    }

    #[test]
    fn malformed_or_reasonless_waivers_are_findings() {
        let r = registry();
        let src = "\
// detlint:allow(no-panic-coordinator):\n\
// detlint:allow no parens\n\
// detlint:allow(not-a-rule): reason\n\
fn f() {}\n";
        let rep = check_file("rust/src/coordinator/master.rs", src, &r);
        assert_eq!(rep.findings.len(), 3);
        assert!(rep.findings.iter().all(|f| f.rule == RULE_WAIVER_SYNTAX));
        assert!(rep.waivers.is_empty());
    }

    #[test]
    fn unused_waiver_is_reported_not_fatal() {
        let r = registry();
        let src = "// detlint:allow(hash-order): stale pragma\nfn f() {}\n";
        let rep = check_file("rust/src/model/a.rs", src, &r);
        assert!(rep.findings.is_empty());
        assert_eq!(rep.waivers.len(), 1);
        assert!(!rep.waivers[0].used);
    }
}
