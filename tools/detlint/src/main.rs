//! CLI driver. Usage:
//!
//! ```text
//! detlint [--json <path>] [--quiet] <root>...
//! ```
//!
//! Exit codes: 0 = clean (unwaived findings: none), 1 = at least one
//! unwaived finding, 2 = usage / IO error. Waived findings and unused
//! waivers are reported but never fail the run; the JSON report (written
//! before exiting, so CI can upload it on failure) carries everything.

use std::path::PathBuf;
use std::process::ExitCode;

use detlint::rules::{Finding, Waiver};
use detlint::{report, run_roots};

fn main() -> ExitCode {
    match run(std::env::args().skip(1).collect()) {
        Ok(code) => code,
        Err(e) => {
            eprintln!("detlint: {e}");
            eprintln!("usage: detlint [--json <path>] [--quiet] <root>...");
            ExitCode::from(2)
        }
    }
}

fn run(args: Vec<String>) -> Result<ExitCode, String> {
    let mut roots: Vec<PathBuf> = Vec::new();
    let mut json_out: Option<PathBuf> = None;
    let mut quiet = false;
    let mut it = args.into_iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--json" => {
                let p = it.next().ok_or("--json needs a path")?;
                json_out = Some(PathBuf::from(p));
            }
            "--quiet" => quiet = true,
            "--help" | "-h" => {
                println!("usage: detlint [--json <path>] [--quiet] <root>...");
                return Ok(ExitCode::SUCCESS);
            }
            _ if a.starts_with('-') => return Err(format!("unknown flag {a}")),
            _ => roots.push(PathBuf::from(a)),
        }
    }
    if roots.is_empty() {
        return Err("no roots given".into());
    }

    let (reports, files) = run_roots(&roots)?;
    let findings: Vec<Finding> = reports.iter().flat_map(|r| r.findings.clone()).collect();
    let waivers: Vec<Waiver> = reports.iter().flat_map(|r| r.waivers.clone()).collect();

    let unwaived: Vec<&Finding> = findings.iter().filter(|f| !f.waived).collect();
    let waived = findings.len() - unwaived.len();
    let unused: Vec<&Waiver> = waivers.iter().filter(|w| !w.used).collect();

    if let Some(path) = &json_out {
        let root_strs: Vec<String> =
            roots.iter().map(|r| r.to_string_lossy().into_owned()).collect();
        let doc = report::build(&root_strs, files, &findings, &waivers);
        std::fs::write(path, format!("{doc}\n"))
            .map_err(|e| format!("writing {}: {e}", path.display()))?;
    }

    if !quiet {
        for f in &unwaived {
            println!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.msg);
        }
        for w in &unused {
            println!(
                "{}:{}: warning: unused waiver for `{}` ({})",
                w.file, w.line, w.rule, w.reason
            );
        }
        println!(
            "detlint: {} files, {} unwaived finding(s), {} waived, {} waiver(s) ({} unused)",
            files,
            unwaived.len(),
            waived,
            waivers.len(),
            unused.len()
        );
    }

    if unwaived.is_empty() {
        Ok(ExitCode::SUCCESS)
    } else {
        Ok(ExitCode::from(1))
    }
}
