//! # detlint — the pibp determinism linter
//!
//! Statically enforces the source-level invariants that the differential
//! test grids (`parallel_equivalence`, `thread_equivalence`,
//! `packed_equivalence`, `obs_equivalence`, `diag_equivalence`) can only
//! probe at runtime: a centrally partitioned RNG stream-tag space, no
//! wall clock or hash-iteration order in chain-affecting code, no panic
//! paths in the coordinator, documented `unsafe`, and no threads outside
//! the sanctioned spawn sites.
//!
//! Zero dependencies by design (see `Cargo.toml`); the Rust lexer is
//! hand-rolled in [`lexer`] and handles exactly the constructs that could
//! make a text-level linter lie: comments (including nested block
//! comments), string / raw-string / byte / char literals, and the
//! char-vs-lifetime ambiguity. Rules and the waiver pragma live in
//! [`rules`]; the machine-readable JSON report in [`report`].
//!
//! Run as `cargo run -p detlint -- rust/src` (exit 1 on any unwaived
//! finding; `--json <path>` writes the report).

pub mod lexer;
pub mod report;
pub mod rules;

use std::fs;
use std::path::{Path, PathBuf};

use rules::{FileReport, TagRegistry};

/// Recursively collect `.rs` files under `root` in sorted (deterministic)
/// order. A `root` that is itself a file is returned as-is.
pub fn collect_rs_files(root: &Path) -> Result<Vec<PathBuf>, String> {
    let mut out = Vec::new();
    if root.is_file() {
        out.push(root.to_path_buf());
        return Ok(out);
    }
    fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> Result<(), String> {
        let rd = fs::read_dir(dir).map_err(|e| format!("reading {}: {e}", dir.display()))?;
        let mut entries: Vec<PathBuf> = rd
            .filter_map(|e| e.ok().map(|e| e.path()))
            .collect();
        entries.sort();
        for p in entries {
            if p.is_dir() {
                walk(&p, out)?;
            } else if p.extension().is_some_and(|e| e == "rs") {
                out.push(p);
            }
        }
        Ok(())
    }
    walk(root, &mut out)?;
    Ok(out)
}

/// Load the `.split` tag allowlist: `<root>/rng/tags.rs` under each root
/// (merged if several roots carry one). Missing registries are fine —
/// rule R1 then flags every non-literal `.split` call, which is exactly
/// right for a tree that hasn't adopted the registry.
pub fn load_registry(roots: &[PathBuf]) -> TagRegistry {
    let mut merged = TagRegistry::default();
    for root in roots {
        let candidate = root.join("rng").join("tags.rs");
        if let Ok(src) = fs::read_to_string(&candidate) {
            let r = TagRegistry::parse(&src);
            merged.names.extend(r.names);
        }
    }
    merged
}

/// Lint every `.rs` file under `roots`. Returns the per-file reports
/// (keyed by the path as constructed from the root argument) and the
/// number of files checked.
pub fn run_roots(roots: &[PathBuf]) -> Result<(Vec<FileReport>, usize), String> {
    let registry = load_registry(roots);
    let mut reports = Vec::new();
    let mut files = 0usize;
    for root in roots {
        for path in collect_rs_files(root)? {
            let src = fs::read_to_string(&path)
                .map_err(|e| format!("reading {}: {e}", path.display()))?;
            let rel = path.to_string_lossy().replace('\\', "/");
            reports.push(rules::check_file(&rel, &src, &registry));
            files += 1;
        }
    }
    Ok((reports, files))
}
