//! Per-rule fixture tests: every file under `fixtures/` is lexed and
//! linted through the real pipeline at a pseudo-path chosen to put it in
//! (or out of) each rule's scope. Fixture files are never compiled — they
//! only need to lex.

use std::fs;
use std::path::{Path, PathBuf};

use detlint::rules::{self, check_file, FileReport, TagRegistry};

fn fixture(name: &str) -> String {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures").join(name);
    fs::read_to_string(&p).unwrap_or_else(|e| panic!("reading {}: {e}", p.display()))
}

/// The live registry from `rust/src/rng/tags.rs`, exactly as the binary
/// loads it — so these tests also pin the registry parser against the
/// real file.
fn live_registry() -> TagRegistry {
    let root: PathBuf = Path::new(env!("CARGO_MANIFEST_DIR")).join("../../rust/src");
    let reg = detlint::load_registry(&[root]);
    for expected in ["MASTER", "WORKER_BASE", "worker", "block", "chain", "serve_sample"] {
        assert!(
            reg.names.contains(expected),
            "live rng/tags.rs registry is missing `{expected}`; parsed: {:?}",
            reg.names
        );
    }
    assert!(
        !reg.names.contains("FAMILIES"),
        "the FAMILIES table (non-u64 const) must not legitimise raw tags"
    );
    reg
}

fn lint(name: &str, pseudo_path: &str) -> FileReport {
    check_file(pseudo_path, &fixture(name), &live_registry())
}

fn rules_of(rep: &FileReport) -> Vec<&'static str> {
    rep.findings.iter().map(|f| f.rule).collect()
}

#[test]
fn r1_bad_fixture_flags_every_raw_tag() {
    let rep = lint("r1_rng_tag_bad.rs", "rust/src/samplers/hybrid.rs");
    assert_eq!(rep.findings.len(), 5, "{:?}", rep.findings);
    assert!(rep.findings.iter().all(|f| f.rule == rules::RULE_RNG_TAG));
    assert!(rep.findings.iter().all(|f| !f.waived));
}

#[test]
fn r1_ok_fixture_is_clean() {
    let rep = lint("r1_rng_tag_ok.rs", "rust/src/samplers/hybrid.rs");
    assert!(rep.findings.is_empty(), "{:?}", rep.findings);
}

#[test]
fn r2_bad_fixture_flags_both_clocks_outside_allowlist() {
    let rep = lint("r2_wall_clock_bad.rs", "rust/src/samplers/uncollapsed.rs");
    assert_eq!(rules_of(&rep), vec![rules::RULE_WALL_CLOCK, rules::RULE_WALL_CLOCK]);
}

#[test]
fn r2_bad_fixture_is_fine_inside_obs() {
    let rep = lint("r2_wall_clock_bad.rs", "rust/src/obs/mod.rs");
    assert!(rep.findings.is_empty(), "{:?}", rep.findings);
}

#[test]
fn r3_bad_fixture_flags_hashmap_in_chain_scope() {
    let rep = lint("r3_hash_order_bad.rs", "rust/src/model/state.rs");
    // `use`, the type annotation, and `HashMap::new()` each mention it
    assert_eq!(rep.findings.len(), 3, "{:?}", rep.findings);
    assert!(rep.findings.iter().all(|f| f.rule == rules::RULE_HASH_ORDER));
}

#[test]
fn r3_bad_fixture_is_fine_outside_chain_scope() {
    let rep = lint("r3_hash_order_bad.rs", "rust/src/runtime/pjrt.rs");
    assert!(rep.findings.is_empty(), "{:?}", rep.findings);
}

#[test]
fn r4_bad_fixture_flags_all_four_panic_paths() {
    for scoped in [
        "rust/src/coordinator/master.rs",
        "rust/src/parallel/pool.rs",
        "rust/src/serve/mod.rs",
    ] {
        let rep = lint("r4_no_panic_bad.rs", scoped);
        assert_eq!(rep.findings.len(), 4, "{scoped}: {:?}", rep.findings);
        assert!(rep.findings.iter().all(|f| f.rule == rules::RULE_NO_PANIC));
    }
}

#[test]
fn r4_bad_fixture_is_fine_outside_no_panic_zone() {
    for unscoped in ["rust/src/parallel/blocks.rs", "rust/src/samplers/gibbs.rs"] {
        let rep = lint("r4_no_panic_bad.rs", unscoped);
        assert!(rep.findings.is_empty(), "{unscoped}: {:?}", rep.findings);
    }
}

#[test]
fn r5_fixtures_require_safety_comment() {
    let bad = lint("r5_unsafe_bad.rs", "rust/src/parallel/pool.rs");
    assert_eq!(rules_of(&bad), vec![rules::RULE_UNSAFE]);

    let ok = lint("r5_unsafe_ok.rs", "rust/src/parallel/pool.rs");
    assert!(ok.findings.is_empty(), "{:?}", ok.findings);
}

#[test]
fn r6_bad_fixture_flags_all_three_spawn_forms() {
    let rep = lint("r6_stray_thread_bad.rs", "rust/src/coordinator/master.rs");
    assert_eq!(rep.findings.len(), 3, "{:?}", rep.findings);
    assert!(rep.findings.iter().all(|f| f.rule == rules::RULE_STRAY_THREAD));
}

#[test]
fn r6_bad_fixture_is_fine_inside_parallel() {
    let rep = lint("r6_stray_thread_bad.rs", "rust/src/parallel/pool.rs");
    assert!(rep.findings.is_empty(), "{:?}", rep.findings);
}

#[test]
fn r7_bad_fixture_flags_every_socket_type_mention() {
    let rep = lint("r7_net_bad.rs", "rust/src/coordinator/master.rs");
    // 2 use-mentions + TcpListener/TcpStream/UnixStream/UdpSocket uses
    assert_eq!(rep.findings.len(), 7, "{:?}", rep.findings);
    assert!(rep.findings.iter().all(|f| f.rule == rules::RULE_NET));
}

#[test]
fn r7_bad_fixture_is_fine_inside_transport_and_main() {
    for allowed in ["rust/src/coordinator/transport/socket.rs", "rust/src/main.rs"] {
        let rep = lint("r7_net_bad.rs", allowed);
        assert!(rep.findings.is_empty(), "{allowed}: {:?}", rep.findings);
    }
}

#[test]
fn waiver_fixture_exercises_every_waiver_path() {
    let rep = lint("waivers.rs", "rust/src/coordinator/w.rs");

    // Three findings: the waived unwrap, the unwrap whose waiver names
    // the wrong rule, and the reasonless pragma (waiver-syntax).
    assert_eq!(rep.findings.len(), 3, "{:?}", rep.findings);
    let waived: Vec<_> = rep.findings.iter().filter(|f| f.waived).collect();
    assert_eq!(waived.len(), 1);
    assert_eq!(waived[0].rule, rules::RULE_NO_PANIC);
    assert!(waived[0].waiver_reason.as_deref().unwrap().contains("checked non-None"));

    let unwaived: Vec<_> = rep.findings.iter().filter(|f| !f.waived).collect();
    assert_eq!(unwaived.len(), 2);
    assert!(unwaived.iter().any(|f| f.rule == rules::RULE_NO_PANIC));
    assert!(unwaived.iter().any(|f| f.rule == rules::RULE_WAIVER_SYNTAX));

    // Three well-formed waivers parsed; only the first was consumed.
    assert_eq!(rep.waivers.len(), 3, "{:?}", rep.waivers);
    assert_eq!(rep.waivers.iter().filter(|w| w.used).count(), 1);
    assert_eq!(rep.waivers.iter().filter(|w| !w.used).count(), 2);
}

#[test]
fn lexer_torture_fixture_yields_zero_findings_everywhere() {
    // Placed at the strictest possible path: every rule in scope. All the
    // violation-shaped text lives in comments / strings / char literals,
    // so a lexer that mis-tracks any delimiter will produce findings.
    let rep = lint("lexer_torture.rs", "rust/src/coordinator/torture.rs");
    assert!(rep.findings.is_empty(), "{:?}", rep.findings);
    assert!(rep.waivers.is_empty());
}

#[test]
fn every_fixture_is_covered_by_a_test() {
    // Guards against someone adding a fixture without wiring it up.
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures");
    let mut names: Vec<String> = fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.file_name().to_string_lossy().into_owned())
        .collect();
    names.sort();
    let expected = [
        "lexer_torture.rs",
        "r1_rng_tag_bad.rs",
        "r1_rng_tag_ok.rs",
        "r2_wall_clock_bad.rs",
        "r3_hash_order_bad.rs",
        "r4_no_panic_bad.rs",
        "r5_unsafe_bad.rs",
        "r5_unsafe_ok.rs",
        "r6_stray_thread_bad.rs",
        "r7_net_bad.rs",
        "waivers.rs",
    ];
    assert_eq!(names, expected, "fixture set drifted: update tests/fixtures.rs");
}
