//! The meta-test: the live `rust/src` tree must lint clean, with exactly
//! the waivers the repo has argued for (each carrying a justification),
//! and injecting a violation into a real file must produce an unwaived
//! finding. This is what makes detlint load-bearing: the tree cannot
//! drift without either fixing the drift or writing down a proof.

use std::fs;
use std::path::{Path, PathBuf};

use detlint::rules::{self, check_file, Finding, Waiver};

fn live_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../../rust/src")
}

fn lint_live_tree() -> (Vec<Finding>, Vec<Waiver>, usize) {
    let (reports, files) = detlint::run_roots(&[live_root()]).expect("linting rust/src");
    let mut findings = Vec::new();
    let mut waivers = Vec::new();
    for r in reports {
        findings.extend(r.findings);
        waivers.extend(r.waivers);
    }
    (findings, waivers, files)
}

#[test]
fn live_tree_lints_clean() {
    let (findings, _, files) = lint_live_tree();
    assert!(files > 30, "expected the full tree, only saw {files} files");
    let unwaived: Vec<_> = findings.iter().filter(|f| !f.waived).collect();
    assert!(
        unwaived.is_empty(),
        "unwaived determinism findings in the live tree:\n{}",
        unwaived
            .iter()
            .map(|f| format!("  {}:{}: [{}] {}", f.file, f.line, f.rule, f.msg))
            .collect::<Vec<_>>()
            .join("\n")
    );
}

#[test]
fn live_tree_has_exactly_the_argued_waivers() {
    let (_, waivers, _) = lint_live_tree();

    // Every waiver must be consumed (no stale pragmas) and justified.
    for w in &waivers {
        assert!(w.used, "stale waiver {}:{} ({})", w.file, w.line, w.rule);
        assert!(
            w.reason.split_whitespace().count() >= 4,
            "waiver {}:{} needs a real justification, got {:?}",
            w.file,
            w.line,
            w.reason
        );
    }

    // The pinned census. If you add or remove a waiver on purpose,
    // update these counts — that is the point of this test.
    let count = |rule: &str| waivers.iter().filter(|w| w.rule == rule).count();
    assert_eq!(count(rules::RULE_WALL_CLOCK), 4, "{waivers:?}");
    assert_eq!(count(rules::RULE_NO_PANIC), 5, "{waivers:?}");
    // two sanctioned spawn sites: the coordinator's worker threads
    // (master.rs) and the socket transport's per-worker reader threads
    // (transport/socket.rs)
    assert_eq!(count(rules::RULE_STRAY_THREAD), 2, "{waivers:?}");
    assert_eq!(waivers.len(), 11, "{waivers:?}");
}

#[test]
fn live_registry_migration_left_no_raw_tags() {
    let (findings, _, _) = lint_live_tree();
    assert!(
        !findings.iter().any(|f| f.rule == rules::RULE_RNG_TAG),
        "rng-tag-literal must be clean with zero waivers after the \
         tags.rs migration"
    );
}

#[test]
fn injected_violations_fail_the_live_tree() {
    let root = live_root();
    let registry = detlint::load_registry(&[root.clone()]);
    let master = root.join("coordinator/master.rs");
    let src = fs::read_to_string(&master).expect("reading master.rs");

    // The pristine file is covered entirely by its waivers…
    let before = check_file("rust/src/coordinator/master.rs", &src, &registry);
    assert_eq!(before.findings.iter().filter(|f| !f.waived).count(), 0);

    // …but appending panic- and raw-tag-shaped code (outside any
    // #[cfg(test)] region) must each produce an unwaived finding.
    let cases: &[(&str, &str)] = &[
        (
            "\nfn detlint_injected(x: Option<u32>) -> u32 { x.unwrap() }\n",
            rules::RULE_NO_PANIC,
        ),
        (
            "\nfn detlint_injected2(rng: &Pcg64) -> Pcg64 { rng.split(31337) }\n",
            rules::RULE_RNG_TAG,
        ),
        (
            "\nfn detlint_injected3() { let _h = std::thread::spawn(|| ()); }\n",
            rules::RULE_STRAY_THREAD,
        ),
        (
            "\nfn detlint_injected4() { let _s = std::net::TcpStream::connect(\"x\"); }\n",
            rules::RULE_NET,
        ),
    ];
    for (snippet, rule) in cases {
        let mutated = format!("{src}{snippet}");
        let rep = check_file("rust/src/coordinator/master.rs", &mutated, &registry);
        let new_unwaived: Vec<_> = rep.findings.iter().filter(|f| !f.waived).collect();
        assert_eq!(new_unwaived.len(), 1, "injection for {rule}: {new_unwaived:?}");
        assert_eq!(new_unwaived[0].rule, *rule);
    }
}

#[test]
fn report_over_live_tree_is_well_formed() {
    let (findings, waivers, files) = lint_live_tree();
    let doc = detlint::report::build(&["rust/src".into()], files, &findings, &waivers)
        .to_string();
    assert!(doc.contains("\"unwaived\":0"), "{doc}");
    assert!(doc.contains(&format!("\"files_checked\":{files}")));
    // Byte-determinism: building the same report twice is identical.
    let doc2 = detlint::report::build(&["rust/src".into()], files, &findings, &waivers)
        .to_string();
    assert_eq!(doc, doc2);
}
