// Fixture: R2 wall-clock-in-chain must fire on both sites below when the
// file is placed outside the obs/bench/main/runner allowlist.

fn bad() {
    let _t0 = Instant::now();
    let _wall = std::time::SystemTime::now();
}
