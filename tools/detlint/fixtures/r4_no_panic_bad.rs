// Fixture: R4 no-panic-coordinator must fire on all four panic paths
// when the file is placed in coordinator/, parallel/pool.rs, or serve/.

fn bad(x: Option<u32>, y: Result<u32, ()>) -> u32 {
    let a = x.unwrap();
    let b = y.expect("coordinator must not panic");
    if a > b {
        panic!("boom");
    }
    match a {
        0 => unreachable!(),
        n => n,
    }
}
