// Fixture: R5 undocumented-unsafe must fire — no SAFETY comment on the
// block below.

fn bad(job: Task<'_>) -> Job {
    unsafe { std::mem::transmute::<Task<'_>, Task<'static>>(job) }
}
