// Fixture: R7 net-outside-transport must fire on every std::net /
// unix-socket type named outside coordinator/transport/ (and main.rs).

use std::net::{TcpListener, TcpStream};
use std::os::unix::net::UnixStream;

fn bad() {
    let l = TcpListener::bind("127.0.0.1:0");
    let _s: Option<TcpStream> = None;
    let _u: Option<UnixStream> = None;
    let _d = std::net::UdpSocket::bind("127.0.0.1:0");
    drop(l);
}
