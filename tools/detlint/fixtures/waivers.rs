// Fixture: waiver handling. One properly waived finding, one finding
// whose waiver names the wrong rule (stays unwaived), one reasonless
// waiver (a waiver-syntax finding), and one unused waiver.

fn waived(x: Option<u32>) -> u32 {
    // detlint:allow(no-panic-coordinator): x was checked non-None by the caller two lines up
    x.unwrap()
}

fn wrong_rule(y: Option<u32>) -> u32 {
    // detlint:allow(hash-order): this names the wrong rule entirely
    y.unwrap()
}

// detlint:allow(no-panic-coordinator):
fn reasonless() {}

// detlint:allow(stray-thread): nothing below ever spawns — stale pragma
fn unused_waiver() {}
