// Fixture: R1-clean split call sites — registry names, helper fns, and
// str::split with literal separators.

fn good(rng: &Pcg64, s: &str, p: usize) {
    let _a = rng.split(tags::MASTER);
    let _b = rng.split(tags::worker(p));
    let _c = Pcg64::new(7).split(MASTER);
    let _d = rng.split(worker(p));
    let _e: Vec<&str> = s.split(',').collect();
    let _f: Vec<&str> = s.split("PIBP_PROP_SEED=").collect();
}
