// Fixture: R6 stray-thread must fire on all three spawn forms when the
// file is placed outside parallel/.

fn bad() {
    let h = std::thread::spawn(|| 1 + 1);
    std::thread::scope(|s| {
        s.spawn(|| ());
    });
    let _b = std::thread::Builder::new().name("rogue".into());
    h.join().ok();
}
