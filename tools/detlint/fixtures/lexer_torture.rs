// Fixture: everything here is comment / string / char-literal content —
// a correct lexer reports ZERO findings for this file on any path.
// rng.split(1000 + p) in a line comment must not fire.

/* block comment with Instant::now() and rng.split(7777)
   /* nested block comment: unsafe { HashMap::new() } */
   still inside the outer comment: x.unwrap() panic!("no")
*/

/// Doc comment: the master uses `root.split(1)` and workers
/// `root.split(1000 + p)`; never write `thread::spawn` by hand.
fn strings() {
    let _plain = "rng.split(2000) Instant::now() unsafe thread::spawn";
    let _raw = r#"x.unwrap() with "quotes" and rng.split(8000 + c)"#;
    let _rawhash = r##"one "#" deep: SystemTime::now() HashMap"##;
    let _bytes = b".split(9000) panic!";
    let _rawbytes = br#"thread::scope(|s| s.spawn)"#;
    let _multi = "line one
        line two with rng.split(4242) still a string";
    let _ch = '"'; // a quote char, then a comment: rng.split(1)
    let _esc = '\''; // escaped quote char
    let _nl = '\n';
    let _lifetime: &'static str = "lifetime, not a char literal";
    let _amb = 'r'; // char 'r', not a raw-string prefix
}

struct G<'a> {
    // generic lifetimes must not eat the closing angle bracket
    x: &'a str,
}
