// Fixture: R1 rng-tag-literal must fire on every raw-tag split below.
// (Fixtures are lexed, never compiled — paths are supplied by the test.)

fn bad(rng: &Pcg64, p: usize, c: usize) {
    let _a = rng.split(1); // literal scalar tag
    let _b = rng.split(1000 + p as u64); // literal family base
    let _c = rng.split(0x5D17); // hex literal tag
    let _d = Pcg64::new(7).split(8000 + c as u64).next_u64();
    let _e = rng.split(QUERY_TAG_BASE + 3); // constant, but not from the registry
}
