// Fixture: R3 hash-order must fire in chain-affecting modules — HashMap
// iteration order is seeded per process, so any fold over it can change
// float accumulation order run-to-run.

use std::collections::HashMap;

fn bad(keys: &[Vec<u8>]) -> f64 {
    let mut counts: HashMap<Vec<u8>, usize> = HashMap::new();
    for k in keys {
        *counts.entry(k.clone()).or_insert(0) += 1;
    }
    counts.values().map(|&c| c as f64).sum()
}
