// Fixture: R5-clean — the SAFETY comment directly above the block
// documents the soundness argument.

fn good(job: Task<'_>) -> Job {
    // SAFETY: the latch below blocks until the job has run to
    // completion, so no borrow escapes this stack frame.
    let widened = unsafe { std::mem::transmute::<Task<'_>, Task<'static>>(job) };
    widened
}
