//! T-S2b — AOT artifact execution latency: per-call time of every kernel
//! family across its (B, K) buckets on the PJRT CPU client, plus compile
//! (cold-start) cost. This is the L1/L2 profile feeding EXPERIMENTS.md
//! §Perf.

use std::path::Path;
use std::time::{Duration, Instant};

use pibp::bench::{bench, header};
use pibp::linalg::Mat;
use pibp::model::state::FeatureState;
use pibp::rng::Pcg64;
use pibp::runtime::{Engine, Ops};

fn main() {
    let Ok(engine) = Engine::load(Path::new("artifacts")) else {
        println!("## T-S2b — skipped (run `make artifacts` first)");
        return;
    };
    println!("## T-S2b — AOT artifact execution latency (PJRT CPU)\n");

    // cold compile cost per entry kind
    let t0 = Instant::now();
    let ops = Ops::new(&engine);
    let mut rng = Pcg64::new(1);
    let d = 36;
    {
        let (x, z, a, logit) = mk(256, 8, d);
        let mut z = z;
        ops.zsweep(&x, &mut z, &a, &logit, 2.0, &mut rng).unwrap();
    }
    println!("cold first zsweep (compile+run): {:.1} ms\n", t0.elapsed().as_secs_f64() * 1e3);

    println!("{}", header());
    let budget = Duration::from_millis(600);
    for &(b, k) in &[(256usize, 8usize), (1024, 16), (1024, 32)] {
        let (x, z0, a, logit) = mk(b, k, d);
        let mut z = z0.clone();
        let r = bench(&format!("zsweep          b={b} k={k}"), 1, budget, 5, || {
            ops.zsweep(&x, &mut z, &a, &logit, 2.0, &mut rng).unwrap();
        });
        println!("{}", r.row());
        let r = bench(&format!("suffstats       b={b} k={k}"), 1, budget, 5, || {
            ops.suffstats(&z0, &x).unwrap();
        });
        println!("{}", r.row());
        let pi = vec![0.5; k];
        let r = bench(&format!("heldout         b={b} k={k}"), 1, budget, 5, || {
            ops.heldout(&x, &z0, &a, &pi, 0.5).unwrap();
        });
        println!("{}", r.row());
    }
    for &k in &[8usize, 16, 32] {
        let (x, z0, _, _) = mk(256, k, d);
        let zm = z0.to_mat();
        let ztz = zm.gram();
        let ztx = zm.t_matmul(&x);
        let r = bench(&format!("apost                 k={k}"), 1, budget, 5, || {
            ops.apost(&ztz, &ztx, 0.5, 1.0, &mut rng).unwrap();
        });
        println!("{}", r.row());
    }
    println!("\ncompiled executables: {}", engine.compiled_count());
    println!("total executions: {}", engine.exec_count.borrow());
}

fn mk(b: usize, k: usize, d: usize) -> (Mat, FeatureState, Mat, Vec<f64>) {
    let mut rng = Pcg64::new(7);
    let mut z = FeatureState::empty(b);
    z.add_features(k);
    for i in 0..b {
        for j in 0..k {
            if rng.bernoulli(0.3) {
                z.set(i, j, 1);
            }
        }
    }
    let a = Mat::from_fn(k, d, |_, _| rng.normal());
    let mut x = z.to_mat().matmul(&a);
    for v in x.as_mut_slice().iter_mut() {
        *v += 0.5 * rng.normal();
    }
    (x, z, a, vec![0.0; k])
}
