//! T-S5 — packed (u64-word, popcount) vs scalar (byte-per-bit) Z
//! kernels: the gram/suffstat rebuild (`FeatureState::gram` +
//! `t_matmul`) and the full uncollapsed sweep (`par_sweep_rows`), over
//! K ∈ {16, 64, 256} and T ∈ {1, 4}. Both kernels produce bit-identical
//! chains (`rust/tests/packed_equivalence.rs`); this bench records what
//! the packed layout buys in wall-clock, machine-readably in
//! `BENCH_pack.json`.

use std::hint::black_box;
use std::time::Duration;

use pibp::bench::{bench, header};
use pibp::linalg::Mat;
use pibp::model::state::{FeatureState, Kernel};
use pibp::parallel::{par_sweep_rows, ExecConfig, ParallelCtx};
use pibp::rng::Pcg64;
use pibp::samplers::uncollapsed::residuals;
use pibp::testutil::planted_with;

fn states(n: usize, k: usize, d: usize) -> (Mat, FeatureState, FeatureState, Mat) {
    let (x, scalar, a) = planted_with(n, k, d, 1, 0.3, 1.0, 0.5);
    let mut packed = scalar.clone();
    packed.set_kernel(Kernel::Packed);
    (x, scalar, packed, a)
}

fn main() {
    let full = std::env::var("PIBP_BENCH_FULL").is_ok();
    let (n, d) = if full { (4096usize, 36usize) } else { (1024, 36) };
    let budget = Duration::from_millis(600);
    println!("## T-S5 — packed vs scalar Z kernels (N={n}, D={d})\n");
    println!("{}", header());

    let mut entries: Vec<String> = Vec::new();
    for &k in &[16usize, 64, 256] {
        let (x, scalar, packed, a) = states(n, k, d);

        // ---- gram + ZᵀX rebuild: the CollapsedCache / master-merge path ----
        let r_gs = bench(&format!("gram+ztx scalar k={k}"), 1, budget, 5, || {
            black_box(scalar.gram());
            black_box(scalar.t_matmul(&x));
        });
        println!("{}", r_gs.row());
        let r_gp = bench(&format!("gram+ztx packed k={k}"), 1, budget, 5, || {
            black_box(packed.gram());
            black_box(packed.t_matmul(&x));
        });
        println!("{}", r_gp.row());
        let gram_speedup = r_gs.per_iter.mean / r_gp.per_iter.mean;
        println!("        packed-over-scalar gram: {gram_speedup:.2}×");

        // ---- full uncollapsed sweep: the worker hot path ----
        let logit = vec![0.0f64; k];
        let mut sweeps: Vec<String> = Vec::new();
        for &t in &[1usize, 4] {
            let rate = |z0: &FeatureState, kernel: Kernel| {
                let mut z = z0.clone();
                let mut resid = residuals(&x, &z, &a, 0..n);
                let exec = ExecConfig {
                    ctx: if t <= 1 { ParallelCtx::inline() } else { ParallelCtx::pooled(t) },
                    kernel,
                    ..ExecConfig::default()
                };
                let mut rng = Pcg64::new(2).split(1000);
                let r = bench(
                    &format!("sweep {} k={k} T={t}", kernel.name()),
                    1,
                    budget,
                    5,
                    || {
                        par_sweep_rows(
                            &mut z, &mut resid, &a, &logit, 2.0, 0..n, k, &exec, &mut rng,
                        );
                    },
                );
                println!("{}", r.row());
                n as f64 / r.per_iter.mean
            };
            let rs = rate(&scalar, Kernel::Scalar);
            let rp = rate(&packed, Kernel::Packed);
            println!("        packed-over-scalar sweep T={t}: {:.2}×", rp / rs);
            sweeps.push(format!(
                "        {{\"threads\": {t}, \"scalar_rows_per_s\": {rs:.1}, \
                 \"packed_rows_per_s\": {rp:.1}, \"packed_over_scalar\": {:.4}}}",
                rp / rs
            ));
        }

        entries.push(format!(
            "    {{\"k\": {k}, \"gram_scalar_us\": {:.3}, \"gram_packed_us\": {:.3}, \
             \"gram_packed_over_scalar\": {gram_speedup:.4},\n      \"sweeps\": [\n{}\n      ]}}",
            r_gs.per_iter.mean * 1e6,
            r_gp.per_iter.mean * 1e6,
            sweeps.join(",\n")
        ));
    }

    // machine-readable packed-over-scalar deltas for the perf trajectory
    let json = format!(
        "{{\n  \"bench\": \"packed_gram\",\n  \"n\": {n},\n  \"d\": {d},\n  \
         \"results\": [\n{}\n  ]\n}}\n",
        entries.join(",\n")
    );
    // cargo runs bench binaries with cwd = the package dir (rust/), so
    // anchor the output at the workspace root where CI expects it
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_pack.json");
    match std::fs::write(&out, &json) {
        Ok(()) => println!("\npacked-kernel deltas → {}", out.display()),
        Err(e) => eprintln!("\ncould not write {}: {e}", out.display()),
    }
}
