//! T-S4 — posterior-serving throughput: queries/second of the
//! `serve::PredictEngine` vs the number of posterior samples averaged
//! over, for the three query types (imputation, held-out predictive
//! log-likelihood, reconstruction). One query = one row × one query type.
//!
//! Writes the machine-readable `BENCH_predict.json` trajectory point —
//! the serving counterpart of `BENCH_sweep.json` — so the perf log tracks
//! the query path as the subsystem evolves (batching, caching, per-sample
//! parallel fan-out are the obvious next levers).

use std::time::Duration;

use pibp::bench::{bench, header};
use pibp::linalg::Mat;
use pibp::model::missing::Mask;
use pibp::model::state::FeatureState;
use pibp::parallel::ParallelCtx;
use pibp::rng::Pcg64;
use pibp::serve::{PosteriorSample, PredictEngine};

/// Planted model + S jittered posterior samples around its truth.
fn problem(n: usize, k: usize, d: usize, s_count: usize)
           -> (Mat, Vec<PosteriorSample>) {
    let mut rng = Pcg64::new(1);
    let mut z = FeatureState::empty(n);
    z.add_features(k);
    for i in 0..n {
        for j in 0..k {
            if rng.bernoulli(0.5) {
                z.set(i, j, 1);
            }
        }
    }
    let a = Mat::from_fn(k, d, |_, _| 2.0 * rng.normal());
    let mut x = z.to_mat().matmul(&a);
    for v in x.as_mut_slice().iter_mut() {
        *v += 0.2 * rng.normal();
    }
    let samples = (0..s_count)
        .map(|s| {
            let mut a_s = a.clone();
            for v in a_s.as_mut_slice().iter_mut() {
                *v += 0.05 * rng.normal();
            }
            PosteriorSample {
                iter: s as u64 + 1,
                z: z.clone(),
                a: a_s,
                pi: vec![0.5; k],
                sigma_x: 0.25,
                sigma_a: 1.0,
                alpha: 1.0,
            }
        })
        .collect();
    (x, samples)
}

fn main() {
    let (q, k, d, sweeps) = (128usize, 8usize, 36usize, 3usize);
    println!("## T-S4 — posterior-serving query throughput (Q={q} rows, K={k}, D={d}, {sweeps} sweeps/sample)\n");
    println!("{}", header());
    let budget = Duration::from_millis(600);
    let mut results: Vec<(usize, f64, f64, f64)> = Vec::new();

    for &s_count in &[1usize, 4, 16] {
        let (x, samples) = problem(q, k, d, s_count);
        let mut mrng = Pcg64::new(2);
        let mask = Mask::random(q, d, 0.3, &mut mrng);
        let engine = PredictEngine::new(&samples, sweeps, 1);

        let r = bench(&format!("impute      S={s_count}"), 1, budget, 3, || {
            let _ = engine.impute(&x, &mask, 7);
        });
        let imp = q as f64 / r.per_iter.mean;
        println!("{}  [{imp:.1} rows/s]", r.row());

        let r = bench(&format!("heldout ll  S={s_count}"), 1, budget, 3, || {
            let _ = engine.heldout_loglik(&x, 7);
        });
        let ll = q as f64 / r.per_iter.mean;
        println!("{}  [{ll:.1} rows/s]", r.row());

        let r = bench(&format!("reconstruct S={s_count}"), 1, budget, 3, || {
            let _ = engine.reconstruct(&x, 7);
        });
        let rec = q as f64 / r.per_iter.mean;
        println!("{}  [{rec:.1} rows/s]", r.row());

        results.push((s_count, imp, ll, rec));
    }

    // ---- per-sample fan-out scaling: the same S=8 query batch across
    //      T ∈ {1, 2, 4, 8} lanes, persistent pool vs scoped respawn.
    //      Answers are byte-identical at every point (the fan-out merges
    //      per-sample buffers in sample order); only wall-clock moves. ----
    println!();
    let fan_s = 8usize;
    let (x, samples) = problem(q, k, d, fan_s);
    let mut mrng = Pcg64::new(3);
    let mask = Mask::random(q, d, 0.3, &mut mrng);
    let mut t_results: Vec<(usize, f64, f64)> = Vec::new();
    for &t in &[1usize, 2, 4, 8] {
        let rate_for = |label: &str, ctx: ParallelCtx| {
            let engine = PredictEngine::with_ctx(&samples, sweeps, ctx);
            let r = bench(&format!("{label} batch S={fan_s} T={t}"), 1, budget, 3, || {
                let _ = engine.impute(&x, &mask, 7);
                let _ = engine.heldout_loglik(&x, 7);
                let _ = engine.reconstruct(&x, 7);
            });
            let rate = (3 * q) as f64 / r.per_iter.mean;
            println!("{}  [{rate:.1} rows/s]", r.row());
            rate
        };
        let pooled = rate_for("pooled ", ParallelCtx::pooled(t));
        let scoped = rate_for("scoped ", ParallelCtx::scoped(t));
        println!("        pool/respawn at T={t}: {:.3}×", pooled / scoped);
        t_results.push((t, pooled, scoped));
    }

    // machine-readable trajectory point for the perf log
    let entries: Vec<String> = results
        .iter()
        .map(|(s, imp, ll, rec)| {
            format!(
                "    {{\"samples\": {s}, \"impute_rows_per_s\": {imp:.1}, \
                 \"loglik_rows_per_s\": {ll:.1}, \"reconstruct_rows_per_s\": {rec:.1}}}"
            )
        })
        .collect();
    let t_entries: Vec<String> = t_results
        .iter()
        .map(|(t, pooled, scoped)| {
            format!(
                "    {{\"threads\": {t}, \"pooled_rows_per_s\": {pooled:.1}, \
                 \"scoped_rows_per_s\": {scoped:.1}, \
                 \"pooled_over_scoped\": {:.4}}}",
                pooled / scoped
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"predict_throughput\",\n  \"rows\": {q},\n  \
         \"k\": {k},\n  \"d\": {d},\n  \"sweeps\": {sweeps},\n  \
         \"results\": [\n{}\n  ],\n  \"fanout_samples\": {fan_s},\n  \
         \"thread_results\": [\n{}\n  ]\n}}\n",
        entries.join(",\n"),
        t_entries.join(",\n")
    );
    // cargo runs bench binaries with cwd = the package dir (rust/), so
    // anchor the output at the workspace root where CI expects it
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_predict.json");
    match std::fs::write(&out, &json) {
        Ok(()) => println!("\nserving throughput results → {}", out.display()),
        Err(e) => eprintln!("\ncould not write {}: {e}", out.display()),
    }
    println!("(mean column is seconds per full batched query over the Q rows)");
}
