//! T-S3 — ablations of the design choices DESIGN.md calls out:
//!
//! 1. sub-iterations L ∈ {1, 5, 10} (paper uses 5): more local sweeps per
//!    global step amortise communication but stale (π, A) longer;
//! 2. new-feature proposal truncation kmax_new ∈ {1, 4};
//! 3. communication model sensitivity: virtual-time per iteration under
//!    LAN-ish vs WAN-ish latency/bandwidth (the paper's §5 overhead).

use pibp::config::{Backend, CommModel, RunConfig, SamplerKind};
use pibp::coordinator::{Coordinator, CoordinatorConfig};
use pibp::data::cambridge::{generate, CambridgeConfig};
use pibp::model::state::Kernel;
use pibp::model::LinGauss;
use pibp::runner;
use pibp::samplers::SamplerOptions;

fn main() {
    let full = std::env::var("PIBP_BENCH_FULL").is_ok();
    let (n, iters) = if full { (1000, 300) } else { (300, 80) };

    // ---- 1. sub-iterations ----
    println!("## T-S3a — sub-iterations L (hybrid P=3, cambridge {n}×36, {iters} iters)\n");
    println!("| {:>3} | {:>12} | {:>12} | {:>8} |", "L", "plateau", "vtime total", "final K");
    println!("|{}|{}|{}|{}|", "-".repeat(5), "-".repeat(14), "-".repeat(14), "-".repeat(10));
    for l in [1usize, 5, 10] {
        let cfg = RunConfig {
            n,
            iters,
            sampler: SamplerKind::Hybrid,
            processors: 3,
            sub_iters: l,
            eval_every: 5,
            seed: 2,
            ..Default::default()
        };
        let out = runner::run(&cfg, |_| {}).expect("run");
        println!(
            "| {l:>3} | {:>12.1} | {:>11.3}s | {:>8} |",
            out.trace.plateau(0.25),
            out.elapsed_s,
            out.final_k
        );
    }

    // ---- 2. proposal truncation ----
    println!("\n## T-S3b — new-feature truncation kmax_new\n");
    println!("| {:>5} | {:>12} | {:>8} |", "kmax", "plateau", "final K");
    println!("|{}|{}|{}|", "-".repeat(7), "-".repeat(14), "-".repeat(10));
    for kmax in [1usize, 4] {
        let cfg = RunConfig {
            n,
            iters,
            sampler: SamplerKind::Hybrid,
            processors: 3,
            kmax_new: kmax,
            eval_every: 5,
            seed: 3,
            ..Default::default()
        };
        let out = runner::run(&cfg, |_| {}).expect("run");
        println!(
            "| {kmax:>5} | {:>12.1} | {:>8} |",
            out.trace.plateau(0.25),
            out.final_k
        );
    }

    // ---- 3. comm model sensitivity ----
    println!("\n## T-S3c — communication model sensitivity (P=5, 10 iters)\n");
    println!("| {:<22} | {:>14} | {:>13} |", "link", "vtime/iter", "comm share");
    println!("|{}|{}|{}|", "-".repeat(24), "-".repeat(16), "-".repeat(15));
    let (ds, _) = generate(&CambridgeConfig { n, seed: 4, ..Default::default() });
    for (label, lat_us, gbps) in [
        ("datacentre 10µs/10G", 10.0, 10.0),
        ("LAN 50µs/1G (default)", 50.0, 1.0),
        ("WAN 5ms/100M", 5000.0, 0.1),
    ] {
        let comm = CommModel {
            latency_s: lat_us * 1e-6,
            bandwidth_bps: gbps * 1024.0 * 1024.0 * 1024.0,
        };
        let cfg = CoordinatorConfig {
            processors: 5,
            sub_iters: 5,
            threads_per_worker: 1,
            kernel: Kernel::Scalar,
            seed: 5,
            lg: LinGauss::new(0.5, 1.0),
            alpha: 1.0,
            opts: SamplerOptions::default(),
            backend: Backend::Native,
            artifacts_dir: "artifacts".into(),
            comm,
            ..Default::default()
        };
        let mut coord = Coordinator::new(&ds.x, cfg).expect("coord");
        let (mut vt, mut compute) = (0.0, 0.0);
        for _ in 0..10 {
            let r = coord.step().expect("step");
            vt += r.vtime_iter_s;
            compute += r.max_worker_busy_s + r.master_busy_s;
        }
        println!(
            "| {label:<22} | {:>12.4}s | {:>12.1}% |",
            vt / 10.0,
            100.0 * (vt - compute) / vt
        );
    }
    println!("\n(paper §5: summary-statistic traffic to/from the master is the");
    println!(" scalability bottleneck — visible as the WAN row's comm share)");
}
