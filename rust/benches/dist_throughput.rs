//! T-S10 — transport throughput: the same hybrid workload driven over
//! the three message planes (in-process channels, Unix domain socket,
//! TCP loopback) at P ∈ {2, 4}, reporting iterations/sec and
//! bytes/iteration.
//!
//! Socket rows launch real `pibp worker --connect` child processes, so
//! the measured gap is the honest end-to-end price of process isolation:
//! frame encode → kernel socket → decode, twice per gather. The chain
//! itself is transport-invariant (`process_equivalence.rs` pins
//! bit-identity), which this bench re-checks cheaply via final K⁺ —
//! bytes/iteration is identical across rows *by construction*.
//!
//! Writes `BENCH_dist.json` at the repo root; `PIBP_BENCH_FULL=1` for a
//! paper-scale workload.

use std::path::Path;
use std::process::{Child, Command, Stdio};
use std::time::Instant;

use pibp::config::{Backend, CommModel};
use pibp::coordinator::{Coordinator, CoordinatorConfig, TransportConfig};
use pibp::data::cambridge::{generate, CambridgeConfig};
use pibp::linalg::Mat;
use pibp::model::state::Kernel;
use pibp::model::LinGauss;
use pibp::samplers::SamplerOptions;

fn coord_cfg(p: usize, transport: TransportConfig) -> CoordinatorConfig {
    CoordinatorConfig {
        processors: p,
        sub_iters: 5,
        threads_per_worker: 1,
        kernel: Kernel::Scalar,
        seed: 42,
        lg: LinGauss::new(0.5, 1.0),
        alpha: 1.0,
        opts: SamplerOptions::default(),
        backend: Backend::Native,
        artifacts_dir: Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
        comm: CommModel::default(),
        transport,
    }
}

fn spawn_workers(addr: &str, n: usize) -> Vec<Child> {
    (0..n)
        .map(|_| {
            Command::new(env!("CARGO_BIN_EXE_pibp"))
                .args(["worker", "--connect", addr])
                .stdout(Stdio::null())
                .stderr(Stdio::inherit())
                .spawn()
                .expect("spawning pibp worker")
        })
        .collect()
}

fn reap(children: Vec<Child>) {
    for mut c in children {
        let mut done = false;
        for _ in 0..400 {
            if c.try_wait().expect("try_wait").is_some() {
                done = true;
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(25));
        }
        if !done {
            c.kill().ok();
            eprintln!("warning: worker did not exit after Shutdown; killed");
        }
    }
}

/// A free loopback port: bind :0, read the assignment, release it. The
/// tiny race (someone else grabbing it before the master rebinds) only
/// costs a bench re-run.
fn free_tcp_port() -> u16 {
    std::net::TcpListener::bind("127.0.0.1:0")
        .expect("probe bind")
        .local_addr()
        .expect("probe addr")
        .port()
}

struct Row {
    transport: &'static str,
    p: usize,
    iters_per_s: f64,
    bytes_per_iter: f64,
    k: usize,
}

fn run_one(x: &Mat, transport: &'static str, p: usize, iters: usize) -> Row {
    let (tcfg, children, sock) = match transport {
        "channel" => (TransportConfig::Channel, Vec::new(), String::new()),
        "uds" => {
            let sock = std::env::temp_dir()
                .join(format!("pibp_bench_{}_{p}.sock", std::process::id()))
                .to_string_lossy()
                .into_owned();
            let _ = std::fs::remove_file(&sock);
            let children = spawn_workers(&sock, p);
            (TransportConfig::Uds { listen: sock.clone() }, children, sock)
        }
        "tcp" => {
            let addr = format!("127.0.0.1:{}", free_tcp_port());
            let children = spawn_workers(&addr, p);
            (TransportConfig::Tcp { listen: addr }, children, String::new())
        }
        other => unreachable!("transport {other}"),
    };
    let mut coord = Coordinator::new(x, coord_cfg(p, tcfg)).expect("coordinator");
    // K grows from 0 — warm up so the steady-state frame sizes are measured
    for _ in 0..3 {
        coord.step().expect("warmup");
    }
    let mut bytes = 0usize;
    let t0 = Instant::now();
    for _ in 0..iters {
        bytes += coord.step().expect("step").comm_bytes;
    }
    let dt = t0.elapsed().as_secs_f64();
    let k = coord.k();
    drop(coord);
    reap(children);
    let _ = sock; // unlinked by the transport's shutdown
    Row {
        transport,
        p,
        iters_per_s: iters as f64 / dt.max(1e-9),
        bytes_per_iter: bytes as f64 / iters as f64,
        k,
    }
}

fn main() {
    let full = std::env::var("PIBP_BENCH_FULL").is_ok();
    let (n, iters) = if full { (2000, 40) } else { (400, 10) };
    let (ds, _) = generate(&CambridgeConfig { n, seed: 1, ..Default::default() });

    println!("## T-S10 — transport throughput (hybrid, cambridge {n}×36, {iters} iters, L=5)\n");
    println!(
        "| {:>9} | {:>3} | {:>10} | {:>12} | {:>4} |",
        "transport", "P", "iters/s", "bytes/iter", "K⁺"
    );
    println!("|{}|{}|{}|{}|{}|", "-".repeat(11), "-".repeat(5), "-".repeat(12),
             "-".repeat(14), "-".repeat(6));

    let mut rows: Vec<Row> = Vec::new();
    for p in [2usize, 4] {
        for transport in ["channel", "uds", "tcp"] {
            let row = run_one(&ds.x, transport, p, iters);
            println!(
                "| {:>9} | {:>3} | {:>10.2} | {:>12.0} | {:>4} |",
                row.transport, row.p, row.iters_per_s, row.bytes_per_iter, row.k
            );
            rows.push(row);
        }
        // the cheap cross-check: same seed + same config ⇒ same chain,
        // whatever moved the frames
        let ks: Vec<usize> = rows.iter().filter(|r| r.p == p).map(|r| r.k).collect();
        assert!(
            ks.windows(2).all(|w| w[0] == w[1]),
            "final K⁺ diverged across transports at P={p}: {ks:?}"
        );
    }

    let mut json = String::from("{\n  \"bench\": \"dist_throughput\",\n");
    json.push_str(&format!("  \"n\": {n},\n  \"iters\": {iters},\n  \"rows\": [\n"));
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"transport\": \"{}\", \"p\": {}, \"iters_per_s\": {:.4}, \
             \"bytes_per_iter\": {:.1}, \"k\": {}}}{}\n",
            r.transport,
            r.p,
            r.iters_per_s,
            r.bytes_per_iter,
            r.k,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    let out = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("BENCH_dist.json");
    match std::fs::write(&out, &json) {
        Ok(()) => println!("\ntransport throughput results → {}", out.display()),
        Err(e) => eprintln!("could not write {}: {e}", out.display()),
    }
}
