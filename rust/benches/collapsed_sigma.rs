//! T-S4 — σ-MH proposal cost in the collapsed sampler: the retired
//! full-recompute path (`z.to_mat()` + `collapsed_loglik` over all N
//! rows — what `mh_sigmas` paid per proposal, accepted OR rejected)
//! vs the ratio-reparameterised cache path (`loglik_at_ratio`, which
//! factorises M′ = ZᵀZ + r′I from cached sufficient statistics and
//! never touches X or Z). The new path's cost must be independent of N
//! — that is the machine-checkable claim in `BENCH_collapsed.json`.

use std::hint::black_box;
use std::time::Duration;

use pibp::bench::{bench, header};
use pibp::linalg::Mat;
use pibp::model::state::FeatureState;
use pibp::model::{CollapsedCache, LinGauss};

fn problem(n: usize, k: usize, d: usize) -> (Mat, FeatureState) {
    let (x, z, _) = pibp::testutil::planted_with(n, k, d, 1, 0.3, 1.0, 0.5);
    (x, z)
}

fn main() {
    let d = 24;
    println!("## T-S4 — σ-MH proposal cost, old vs ratio-reparameterised (D={d})\n");
    println!("{}", header());
    let budget = Duration::from_millis(600);
    let lg = LinGauss::new(0.5, 1.0);
    // a realistic σ_X proposal: same Z, different ridge ratio
    let prop = LinGauss::new(0.55, 1.0);

    let mut entries: Vec<String> = Vec::new();
    for &(n, k) in &[(500usize, 10usize), (500, 40), (5000, 10), (5000, 40)] {
        let (x, z) = problem(n, k, d);
        let cache = CollapsedCache::new(&x, &z.to_mat(), lg.ratio());

        // old path: exactly what mh_sigmas did per proposal — materialise
        // Z and recompute the collapsed loglik over the full data
        let r_old = bench(&format!("old     full recompute n={n} k={k}"), 1, budget, 5, || {
            let zm = z.to_mat();
            black_box(prop.collapsed_loglik(&x, &zm));
        });
        println!("{}", r_old.row());

        // new path: factorise from cached ZᵀZ/G — no N factor
        let r_new = bench(&format!("ratio   loglik_at_ratio n={n} k={k}"), 1, budget, 5, || {
            black_box(cache.loglik_at_ratio(&prop).expect("PD").loglik);
        });
        println!("{}", r_new.row());

        let old_us = r_old.per_iter.mean * 1e6;
        let new_us = r_new.per_iter.mean * 1e6;
        entries.push(format!(
            "    {{\"n\": {n}, \"k\": {k}, \"old_us\": {old_us:.3}, \
             \"ratio_us\": {new_us:.3}, \"speedup\": {:.1}}}",
            old_us / new_us
        ));
    }

    // machine-readable datapoint for the perf trajectory: proposal cost
    // at fixed K must be ~flat in N on the ratio path
    let json = format!(
        "{{\n  \"bench\": \"collapsed_sigma\",\n  \"d\": {d},\n  \
         \"results\": [\n{}\n  ]\n}}\n",
        entries.join(",\n")
    );
    match std::fs::write("BENCH_collapsed.json", &json) {
        Ok(()) => println!("\nσ-MH proposal costs → BENCH_collapsed.json"),
        Err(e) => eprintln!("\ncould not write BENCH_collapsed.json: {e}"),
    }
    println!("(ratio rows should be ~identical across n at fixed k)");
}
