//! FIG2 — regenerates the paper's Figure 2: the true Cambridge glyphs
//! (top) vs posterior features from the collapsed sampler (middle) and
//! the hybrid sampler with 5 processors (bottom).
//!
//! Quantitative check (the paper is qualitative): for each true glyph we
//! report the best cosine similarity among the recovered loadings — the
//! reproduction target is all four glyphs matched (> 0.8) by both
//! samplers, with the hybrid allowed extra low-weight noise features.

use pibp::config::{RunConfig, SamplerKind};
use pibp::data::cambridge;
use pibp::linalg::Mat;
use pibp::runner;
use pibp::viz;

fn cosine(a: &[f64], b: &[f64]) -> f64 {
    let dot: f64 = a.iter().zip(b).map(|(x, y)| x * y).sum();
    let na: f64 = a.iter().map(|x| x * x).sum::<f64>().sqrt();
    let nb: f64 = b.iter().map(|x| x * x).sum::<f64>().sqrt();
    dot / (na * nb).max(1e-12)
}

fn match_scores(truth: &Mat, feats: &Mat) -> Vec<f64> {
    (0..truth.rows())
        .map(|t| {
            (0..feats.rows())
                .map(|f| cosine(truth.row(t), feats.row(f)))
                .fold(f64::NEG_INFINITY, f64::max)
        })
        .collect()
}

fn main() {
    let full = std::env::var("PIBP_BENCH_FULL").is_ok();
    let (n, iters) = if full { (1000, 500) } else { (400, 120) };
    let base = RunConfig { n, iters, eval_every: 10, seed: 0, ..Default::default() };
    let truth = cambridge::true_features(base.k_true);

    println!("## FIG2 — true vs posterior features (cambridge {n}×36)\n");
    println!("true glyphs:\n{}", viz::render_features_ascii(&truth));
    viz::save_feature_grid(std::path::Path::new("results/fig2/true.pgm"), &truth, 8).ok();

    for (label, sampler, p) in [
        ("collapsed", SamplerKind::Collapsed, 1usize),
        ("hybrid-p5", SamplerKind::Hybrid, 5),
    ] {
        let mut cfg = base.clone();
        cfg.sampler = sampler;
        cfg.processors = p;
        eprintln!("[fig2] {label}…");
        let out = runner::run(&cfg, |_| {}).expect("run");
        println!("{label} posterior (K={}):\n{}", out.final_k,
                 viz::render_features_ascii(&out.features));
        let scores = match_scores(&truth, &out.features);
        println!(
            "| {label:<10} | K={:<3} | glyph cosine matches: {} | min {:.3} |",
            out.final_k,
            scores.iter().map(|s| format!("{s:.2}")).collect::<Vec<_>>().join(", "),
            scores.iter().fold(f64::INFINITY, |a, &b| a.min(b)),
        );
        viz::save_feature_grid(
            std::path::Path::new("results/fig2").join(format!("{label}.pgm")).as_path(),
            &out.features, 8,
        ).ok();
    }
    println!("\nimages → results/fig2/*.pgm");
    println!("(paper shape: both samplers recover the glyphs; the hybrid row");
    println!(" shows extra noisy low-weight features — same as paper Fig. 2 bottom)");
}
