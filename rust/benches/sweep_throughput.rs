//! T-S2a — uncollapsed-sweep throughput: rows/second of the hybrid
//! worker hot path, native f64 vs the AOT PJRT (Pallas zsweep) backend,
//! across (B rows, K features) buckets. Also the collapsed sweep for
//! contrast — the paper's core cost argument (collapsed is O(K²) per bit,
//! uncollapsed O(D)).

use std::path::Path;
use std::time::Duration;

use pibp::bench::{bench, header, human_time};
use pibp::linalg::Mat;
use pibp::model::state::FeatureState;
use pibp::model::LinGauss;
use pibp::parallel::{par_sweep_rows, ExecConfig, ParallelCtx, DEFAULT_BLOCK_ROWS};
use pibp::rng::Pcg64;
use pibp::runtime::{Engine, Ops};
use pibp::samplers::collapsed::{CollapsedGibbs, Mode};
use pibp::samplers::uncollapsed::{residuals, sweep_rows};
use pibp::samplers::SamplerOptions;

fn problem(b: usize, k: usize, d: usize) -> (Mat, FeatureState, Mat, Vec<f64>) {
    let (x, z, a) = pibp::testutil::planted_with(b, k, d, 1, 0.3, 1.0, 0.5);
    (x, z, a, vec![0.0; k])
}

fn main() {
    let d = 36;
    println!("## T-S2a — Z-sweep throughput (D={d})\n");
    println!("{}", header());
    let budget = Duration::from_millis(800);
    let engine = Engine::load(Path::new("artifacts")).ok();

    for &(b, k) in &[(256usize, 8usize), (256, 16), (1024, 8), (1024, 16), (1024, 32)] {
        // native
        let (x, z0, a, logit) = problem(b, k, d);
        let mut z = z0.clone();
        let mut rng = Pcg64::new(2);
        let mut resid = residuals(&x, &z, &a, 0..b);
        let r = bench(&format!("native  sweep b={b} k={k}"), 1, budget, 5, || {
            sweep_rows(&x, &mut z, &mut resid, &a, &logit, 2.0, 0..b, k, &mut rng);
        });
        println!("{}  [{} rows/s]", r.row(),
                 fmt_rate(b as f64 / r.per_iter.mean));
        // pjrt
        if let Some(eng) = &engine {
            let ops = Ops::new(eng);
            let mut z = z0.clone();
            let mut rng = Pcg64::new(2);
            let r = bench(&format!("pjrt    sweep b={b} k={k}"), 1, budget, 5, || {
                ops.zsweep(&x, &mut z, &a, &logit, 2.0, &mut rng).expect("zsweep");
            });
            println!("{}  [{} rows/s]", r.row(),
                     fmt_rate(b as f64 / r.per_iter.mean));
        }
    }

    // ---- intra-worker thread scaling: the same sweep through the two
    //      deterministic schedulers, T ∈ {1, 2, 4, 8} — persistent pool
    //      (production) vs scoped respawn (PR-2 behaviour). Identical
    //      chains; only wall-clock moves. The pooled/scoped ratio is the
    //      respawn overhead the pool eliminates. ----
    println!();
    let (tb, tk) = (1024usize, 16usize);
    let mut t_results: Vec<(usize, f64, f64)> = Vec::new();
    for &t in &[1usize, 2, 4, 8] {
        let rate_for = |label: &str, ctx: ParallelCtx| {
            let (x, z0, a, logit) = problem(tb, tk, d);
            let mut z = z0.clone();
            let mut rng = Pcg64::new(4).split(1000);
            let mut resid = residuals(&x, &z, &a, 0..tb);
            let exec = ExecConfig::with_ctx(ctx);
            let r = bench(&format!("{label} sweep b={tb} k={tk} T={t}"), 1,
                          budget, 5, || {
                par_sweep_rows(&mut z, &mut resid, &a, &logit, 2.0, 0..tb, tk,
                               &exec, &mut rng);
            });
            let rate = tb as f64 / r.per_iter.mean;
            println!("{}  [{} rows/s]", r.row(), fmt_rate(rate));
            rate
        };
        let pooled = rate_for("pooled ", ParallelCtx::pooled(t));
        let scoped = rate_for("scoped ", ParallelCtx::scoped(t));
        println!("        pool/respawn at T={t}: {:.3}×", pooled / scoped);
        t_results.push((t, pooled, scoped));
    }
    // machine-readable trajectory point (rows/sec per T, both schedulers
    // + the pool-vs-respawn delta) for the perf log
    let entries: Vec<String> = t_results
        .iter()
        .map(|(t, pooled, scoped)| {
            format!(
                "    {{\"threads\": {t}, \"pooled_rows_per_s\": {pooled:.1}, \
                 \"scoped_rows_per_s\": {scoped:.1}, \
                 \"pooled_over_scoped\": {:.4}}}",
                pooled / scoped
            )
        })
        .collect();
    let json = format!(
        "{{\n  \"bench\": \"sweep_throughput\",\n  \"b\": {tb},\n  \
         \"k\": {tk},\n  \"d\": {d},\n  \"block_rows\": {DEFAULT_BLOCK_ROWS},\n  \
         \"results\": [\n{}\n  ]\n}}\n",
        entries.join(",\n")
    );
    // cargo runs bench binaries with cwd = the package dir (rust/), so
    // anchor the output at the workspace root where CI expects it
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_sweep.json");
    match std::fs::write(&out, &json) {
        Ok(()) => println!("\nthread-scaling results → {}", out.display()),
        Err(e) => eprintln!("\ncould not write {}: {e}", out.display()),
    }

    // collapsed sweep for contrast (one full Gibbs iteration over rows)
    println!();
    for &(b, k) in &[(256usize, 8usize), (256, 16)] {
        let (x, _, _, _) = problem(b, k, d);
        let mut rng = Pcg64::new(3);
        let mut s = CollapsedGibbs::new(
            x, LinGauss::new(0.5, 1.0), 1.0, Mode::Exact,
            SamplerOptions { sample_alpha: false, sample_sigmas: false, ..Default::default() },
            &mut rng,
        );
        let r = bench(&format!("collapsed full-iter b={b} (K≈{k})"), 1, budget, 3, || {
            s.step(&mut rng);
        });
        println!("{}  [{} rows/s]", r.row(),
                 fmt_rate(b as f64 / r.per_iter.mean));
    }
    println!("\n(mean column is seconds per full sweep over the B rows)");
}

fn fmt_rate(r: f64) -> String {
    if r > 1e6 {
        format!("{:.2}M", r / 1e6)
    } else if r > 1e3 {
        format!("{:.1}k", r / 1e3)
    } else {
        format!("{r:.0}")
    }
}

#[allow(dead_code)]
fn unused(_: &str) -> String {
    human_time(0.0)
}
