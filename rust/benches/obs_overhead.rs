//! T-S7 — observability overhead on the sweep hot loop: the same
//! `par_sweep_rows` workload at obs ∈ {off, counters, full}, T ∈ {1, 4}.
//!
//! The obs layer's contract is "cannot perturb the chain, and costs
//! (almost) nothing when you turn it on": probes are one relaxed atomic
//! load when off, counters add relaxed atomic adds at aggregation points
//! (never per row), and full mode adds `Instant::now()` span pairs at
//! phase boundaries (per block dispatch, not per row). This bench pins
//! the cost: full-mode overhead must stay under 5% of the off-mode sweep
//! time (compared on medians), and the process exits non-zero if not —
//! CI treats that as a failure.

use std::time::Duration;

use pibp::bench::{bench, header};
use pibp::linalg::Mat;
use pibp::model::state::FeatureState;
use pibp::obs::{self, ObsLevel};
use pibp::parallel::{par_sweep_rows, ExecConfig, ParallelCtx};
use pibp::rng::Pcg64;
use pibp::samplers::uncollapsed::residuals;

const THRESHOLD: f64 = 0.05;

fn problem(b: usize, k: usize, d: usize) -> (Mat, FeatureState, Mat, Vec<f64>) {
    let (x, z, a) = pibp::testutil::planted_with(b, k, d, 1, 0.3, 1.0, 0.5);
    (x, z, a, vec![0.0; k])
}

fn main() {
    let (b, k, d) = (1024usize, 16usize, 36usize);
    println!("## T-S7 — obs overhead on the sweep hot loop (b={b} k={k} d={d})\n");
    println!("{}", header());
    let budget = Duration::from_millis(800);

    let mut entries: Vec<String> = Vec::new();
    let mut max_full_overhead = f64::NEG_INFINITY;
    for &t in &[1usize, 4] {
        let mut medians = Vec::new();
        for level in [ObsLevel::Off, ObsLevel::Counters, ObsLevel::Full] {
            obs::set_level(level);
            obs::reset();
            let (x, z0, a, logit) = problem(b, k, d);
            let mut z = z0.clone();
            let mut rng = Pcg64::new(4).split(1000);
            let mut resid = residuals(&x, &z, &a, 0..b);
            let exec = ExecConfig::with_ctx(ParallelCtx::pooled(t));
            let r = bench(
                &format!("obs={:<8} sweep b={b} k={k} T={t}", level.name()),
                1,
                budget,
                5,
                || {
                    par_sweep_rows(&mut z, &mut resid, &a, &logit, 2.0, 0..b, k,
                                   &exec, &mut rng);
                },
            );
            println!("{}", r.row());
            medians.push(r.per_iter.median);
        }
        obs::set_level(ObsLevel::Off);
        let (off, counters, full) = (medians[0], medians[1], medians[2]);
        let counters_ov = counters / off - 1.0;
        let full_ov = full / off - 1.0;
        max_full_overhead = max_full_overhead.max(full_ov);
        println!(
            "        T={t}: counters {:+.2}%, full {:+.2}% vs off\n",
            100.0 * counters_ov,
            100.0 * full_ov
        );
        entries.push(format!(
            "    {{\"threads\": {t}, \"off_s\": {off:.6e}, \
             \"counters_s\": {counters:.6e}, \"full_s\": {full:.6e}, \
             \"counters_overhead\": {counters_ov:.4}, \
             \"full_overhead\": {full_ov:.4}}}"
        ));
    }

    let ok = max_full_overhead < THRESHOLD;
    let json = format!(
        "{{\n  \"bench\": \"obs_overhead\",\n  \"b\": {b},\n  \"k\": {k},\n  \
         \"d\": {d},\n  \"threshold\": {THRESHOLD},\n  \
         \"max_full_overhead\": {max_full_overhead:.4},\n  \
         \"full_under_threshold\": {ok},\n  \"results\": [\n{}\n  ]\n}}\n",
        entries.join(",\n")
    );
    // cargo runs bench binaries with cwd = the package dir (rust/), so
    // anchor the output at the workspace root where CI expects it
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_obs.json");
    match std::fs::write(&out, &json) {
        Ok(()) => println!("obs overhead results → {}", out.display()),
        Err(e) => eprintln!("could not write {}: {e}", out.display()),
    }
    if ok {
        println!(
            "PASS: full-mode overhead {:.2}% < {:.0}%",
            100.0 * max_full_overhead,
            100.0 * THRESHOLD
        );
    } else {
        eprintln!(
            "FAIL: full-mode obs overhead {:.2}% exceeds the {:.0}% budget",
            100.0 * max_full_overhead,
            100.0 * THRESHOLD
        );
        std::process::exit(1);
    }
}
