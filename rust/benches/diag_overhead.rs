//! T-S8 — convergence-diagnostics overhead on a full hybrid run: the
//! same single-chain workload through `runner::run` (no diagnostics)
//! and `runner::run_multi` with `chains=1` (streaming ESS/R̂ fed from
//! every kept trace point, rolling summary published to the obs
//! registry).
//!
//! The diag layer's contract mirrors obs: it only *reads* the kept
//! trace points (no RNG, no ordering effects — `diag_equivalence.rs`
//! pins bit-identity), and each point costs O(max_lag) floats per
//! watched quantity. This bench pins the price: the diagnosed run's
//! median must stay within 5% of the plain run's, and the process exits
//! non-zero if not — CI treats that as a failure.

use std::time::Duration;

use pibp::bench::{bench, header};
use pibp::config::{RunConfig, SamplerKind};
use pibp::runner;

const THRESHOLD: f64 = 0.05;

fn cfg() -> RunConfig {
    RunConfig {
        n: 120,
        iters: 6,
        eval_every: 1,
        sampler: SamplerKind::Hybrid,
        processors: 2,
        seed: 11,
        out_dir: std::env::temp_dir()
            .join("pibp_diag_overhead")
            .to_string_lossy()
            .into_owned(),
        ..Default::default()
    }
}

fn main() {
    println!("## T-S8 — diag overhead on a hybrid run (n=120, 6 iters, eval every iter)\n");
    println!("{}", header());
    let budget = Duration::from_secs(3);

    let plain = bench("run        (no diagnostics)", 1, budget, 4, || {
        runner::run(&cfg(), |_| {}).unwrap();
    });
    println!("{}", plain.row());
    let diagnosed = bench("run_multi  (chains=1, diag on)", 1, budget, 4, || {
        runner::run_multi(&cfg(), |_| {}).unwrap();
    });
    println!("{}", diagnosed.row());

    let (off, on) = (plain.per_iter.median, diagnosed.per_iter.median);
    let overhead = on / off - 1.0;
    println!("\n        diag overhead {:+.2}% vs plain run", 100.0 * overhead);

    let ok = overhead < THRESHOLD;
    let json = format!(
        "{{\n  \"bench\": \"diag_overhead\",\n  \"n\": 120,\n  \"iters\": 6,\n  \
         \"threshold\": {THRESHOLD},\n  \"plain_s\": {off:.6e},\n  \
         \"diag_s\": {on:.6e},\n  \"overhead\": {overhead:.4},\n  \
         \"under_threshold\": {ok}\n}}\n"
    );
    // cargo runs bench binaries with cwd = the package dir (rust/), so
    // anchor the output at the workspace root where CI expects it
    let out = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("BENCH_diag.json");
    match std::fs::write(&out, &json) {
        Ok(()) => println!("diag overhead results → {}", out.display()),
        Err(e) => eprintln!("could not write {}: {e}", out.display()),
    }
    if ok {
        println!(
            "PASS: diag overhead {:.2}% < {:.0}%",
            100.0 * overhead,
            100.0 * THRESHOLD
        );
    } else {
        eprintln!(
            "FAIL: diag overhead {:.2}% exceeds the {:.0}% budget",
            100.0 * overhead,
            100.0 * THRESHOLD
        );
        std::process::exit(1);
    }
}
