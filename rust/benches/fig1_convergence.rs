//! FIG1 — regenerates the paper's Figure 1: held-out joint log P(X,Z)
//! over log (virtual) time, collapsed baseline vs hybrid P ∈ {1, 3, 5}
//! on the Cambridge 1000×36 data (1000 iterations, L = 5 in the paper).
//!
//! Default run uses a reduced budget so `cargo bench` finishes quickly;
//! set `PIBP_BENCH_FULL=1` for the paper-scale 1000×36 / 1000-iteration
//! configuration (as recorded in EXPERIMENTS.md).
//!
//! Reproduction target (shape, not absolute numbers): all samplers reach
//! the same plateau; more processors reach it sooner in virtual time;
//! hybrid P=1 beats the pure collapsed sampler on time-to-quality.

use pibp::config::{RunConfig, SamplerKind};
use pibp::metrics::Trace;
use pibp::runner;

fn main() {
    let full = std::env::var("PIBP_BENCH_FULL").is_ok();
    let (n, iters) = if full { (1000, 1000) } else { (400, 120) };
    let base = RunConfig { n, iters, eval_every: 5, seed: 0, ..Default::default() };

    println!("## FIG1 — held-out log P(X,Z) vs log virtual time");
    println!("cambridge {n}×36, {iters} iterations, L=5, heldout 10%\n");

    let mut traces: Vec<Trace> = Vec::new();
    let mut cfg = base.clone();
    cfg.sampler = SamplerKind::Collapsed;
    eprintln!("[fig1] collapsed…");
    traces.push(runner::run(&cfg, |_| {}).expect("collapsed run").trace);
    for p in [1usize, 3, 5] {
        let mut cfg = base.clone();
        cfg.sampler = SamplerKind::Hybrid;
        cfg.processors = p;
        eprintln!("[fig1] hybrid P={p}…");
        traces.push(runner::run(&cfg, |_| {}).expect("hybrid run").trace);
    }

    let collapsed_plateau = traces[0].plateau(0.25);
    let target = collapsed_plateau - 5.0; // "within 5 nats of the plateau"
    println!(
        "| {:<14} | {:>12} | {:>10} | {:>16} | {:>7} |",
        "sampler", "plateau", "final K", "t→plateau-5 (vs)", "speedup"
    );
    println!("|{}|{}|{}|{}|{}|", "-".repeat(16), "-".repeat(14),
             "-".repeat(12), "-".repeat(18), "-".repeat(9));
    let t_collapsed = traces[0].time_to(target);
    for t in &traces {
        let tt = t.time_to(target);
        let speedup = match (t_collapsed, tt) {
            (Some(c), Some(x)) if x > 0.0 => format!("{:.2}x", c / x),
            _ => "n/a".into(),
        };
        println!(
            "| {:<14} | {:>12.1} | {:>10} | {:>16} | {:>7} |",
            t.label,
            t.plateau(0.25),
            t.last().map_or(0, |p| p.k),
            tt.map_or("n/a".into(), |s| format!("{s:.3}")),
            speedup
        );
    }

    let refs: Vec<&Trace> = traces.iter().collect();
    println!("\n### held-out log P(X,Z) vs log10 virtual seconds\n");
    println!("{}", pibp::viz::plot_traces(&refs, 76, 18, true));

    println!("\n### series (for plotting: heldout vs log10 vtime)\n");
    for t in &traces {
        println!("# {}", t.label);
        for p in t.points.iter().step_by(if full { 10 } else { 2 }) {
            println!("{:.4e},{:.2}", p.vtime_s.max(1e-6), p.heldout);
        }
        t.save_csv(std::path::Path::new("results/fig1")
            .join(format!("{}.csv", t.label)).as_path()).ok();
    }
    println!("\ncsv → results/fig1/*.csv");
}
