//! T-S1 — strong-scaling table: virtual-time speedup and per-iteration
//! breakdown (worker compute / master / communication) of the hybrid
//! sampler for P ∈ {1, 2, 3, 5, 8} on a 4× Cambridge workload.
//!
//! Reproduction target (paper Fig. 1's mechanism + §5 discussion):
//! monotone speedup in P, sub-linear because the master's global step and
//! the star-topology gather/broadcast are serial.

use pibp::config::{Backend, CommModel};
use pibp::coordinator::{Coordinator, CoordinatorConfig};
use pibp::data::cambridge::{generate, CambridgeConfig};
use pibp::model::state::Kernel;
use pibp::model::LinGauss;
use pibp::samplers::SamplerOptions;

fn main() {
    let full = std::env::var("PIBP_BENCH_FULL").is_ok();
    let (n, iters) = if full { (4000, 60) } else { (1200, 20) };
    let (ds, _) = generate(&CambridgeConfig { n, seed: 1, ..Default::default() });

    println!("## T-S1 — strong scaling (hybrid, cambridge {n}×36, {iters} iters, L=5)\n");
    println!(
        "| {:>3} | {:>12} | {:>12} | {:>12} | {:>11} | {:>8} | {:>6} |",
        "P", "vtime/iter", "worker max", "master", "comm bytes", "speedup", "eff"
    );
    println!("|{}|{}|{}|{}|{}|{}|{}|", "-".repeat(5), "-".repeat(14), "-".repeat(14),
             "-".repeat(14), "-".repeat(13), "-".repeat(10), "-".repeat(8));
    let mut t1 = 0.0f64;
    for p in [1usize, 2, 3, 5, 8] {
        let cfg = CoordinatorConfig {
            processors: p,
            sub_iters: 5,
            threads_per_worker: 1,
            kernel: Kernel::Scalar,
            seed: 42,
            lg: LinGauss::new(0.5, 1.0),
            alpha: 1.0,
            opts: SamplerOptions::default(),
            backend: Backend::Native,
            artifacts_dir: "artifacts".into(),
            comm: CommModel::default(),
            ..Default::default()
        };
        let mut coord = Coordinator::new(&ds.x, cfg).expect("coordinator");
        // skip 3 warm-up iterations (K grows from 0)
        for _ in 0..3 {
            coord.step().expect("warmup");
        }
        let (mut vt, mut wb, mut mb, mut cb) = (0.0, 0.0, 0.0, 0usize);
        for _ in 0..iters {
            let r = coord.step().expect("step");
            vt += r.vtime_iter_s;
            wb += r.max_worker_busy_s;
            mb += r.master_busy_s;
            cb += r.comm_bytes;
        }
        let per = vt / iters as f64;
        if p == 1 {
            t1 = per;
        }
        let speedup = t1 / per;
        println!(
            "| {p:>3} | {:>10.4}s | {:>10.4}s | {:>10.4}s | {:>11} | {:>7.2}x | {:>5.0}% |",
            per,
            wb / iters as f64,
            mb / iters as f64,
            cb / iters,
            speedup,
            100.0 * speedup / p as f64
        );
    }
}
