//! Shared planted-data fixtures for tests and benches.
//!
//! Every in-module and integration suite used to carry its own copy of a
//! `problem(n, k, d, seed)` builder; they differed only in the Bernoulli
//! density of the planted Z, the scale of the loadings A, and the noise
//! level. One parameterised core lives here now, with named wrappers
//! reproducing each historical parameterisation **draw-for-draw** (the
//! RNG consumption order is part of the fixtures' contract: row-major
//! Bernoulli bits for Z, then A entries, then one noise draw per X
//! entry), so every numeric threshold in the migrated tests still sees
//! the exact same data.

use crate::linalg::Mat;
use crate::model::state::FeatureState;
use crate::model::LinGauss;
use crate::rng::Pcg64;

/// Planted linear-Gaussian problem: Z ~ Bernoulli(`density`) (row-major
/// draws), A = `a_scale`·N(0,1) entries, X = Z A + `noise`·N(0,1).
pub fn planted_with(
    n: usize,
    k: usize,
    d: usize,
    seed: u64,
    density: f64,
    a_scale: f64,
    noise: f64,
) -> (Mat, FeatureState, Mat) {
    let mut rng = Pcg64::new(seed);
    let mut z = FeatureState::empty(n);
    z.add_features(k);
    for i in 0..n {
        for j in 0..k {
            if rng.bernoulli(density) {
                z.set(i, j, 1);
            }
        }
    }
    let a = Mat::from_fn(k, d, |_, _| a_scale * rng.normal());
    let mut x = z.to_mat().matmul(&a);
    for v in x.as_mut_slice().iter_mut() {
        *v += noise * rng.normal();
    }
    (x, z, a)
}

/// The strong-signal fixture (`model/missing.rs`, `samplers/uncollapsed.rs`
/// historical `planted`): dense features, large loadings, small noise.
pub fn planted(n: usize, k: usize, d: usize, seed: u64) -> (Mat, FeatureState, Mat) {
    planted_with(n, k, d, seed, 0.5, 2.0, 0.1)
}

/// The weak-signal sweep fixture (`parallel/mod.rs` historical
/// `problem`): small logits keep bits flipping so determinism assertions
/// stay meaningful. Returns per-feature prior logits too.
pub fn sweep_problem(
    n: usize,
    k: usize,
    d: usize,
    seed: u64,
) -> (Mat, FeatureState, Mat, Vec<f64>) {
    let (x, z, a) = planted_with(n, k, d, seed, 0.4, 0.5, 0.4);
    let logit: Vec<f64> = (0..k).map(|j| 0.2 * (j as f64) - 0.4).collect();
    (x, z, a, logit)
}

/// The collapsed-model fixture (`model/lingauss.rs` historical
/// `problem`): returns Z dense (the collapsed API is Mat-based) and the
/// repo-standard LinGauss(0.5, 1.1).
pub fn collapsed_problem(n: usize, k: usize, d: usize, seed: u64) -> (Mat, Mat, LinGauss) {
    let (x, z, _) = planted_with(n, k, d, seed, 0.4, 1.0, 0.3);
    (x, z.to_mat(), LinGauss::new(0.5, 1.1))
}

/// The cache-drift stress fixture (`rust/tests/collapsed_cache_drift.rs`
/// historical `problem`): slightly denser Z than [`collapsed_problem`].
pub fn drift_problem(n: usize, k: usize, d: usize, seed: u64) -> (Mat, Mat, LinGauss) {
    let (x, z, _) = planted_with(n, k, d, seed, 0.45, 1.0, 0.3);
    (x, z.to_mat(), LinGauss::new(0.5, 1.1))
}

/// The runtime-integration fixture (`rust/tests/integration_runtime.rs`
/// historical `problem`): adds per-feature π draws and LinGauss(0.4, 1.1).
/// Note the π draws come *after* the noise draws, matching the original.
pub fn runtime_problem(
    b: usize,
    k: usize,
    d: usize,
    seed: u64,
) -> (Mat, FeatureState, Mat, Vec<f64>, LinGauss) {
    let mut rng = Pcg64::new(seed);
    let mut z = FeatureState::empty(b);
    z.add_features(k);
    for i in 0..b {
        for j in 0..k {
            if rng.bernoulli(0.4) {
                z.set(i, j, 1);
            }
        }
    }
    let a = Mat::from_fn(k, d, |_, _| rng.normal());
    let mut x = z.to_mat().matmul(&a);
    for v in x.as_mut_slice().iter_mut() {
        *v += 0.4 * rng.normal();
    }
    let pi: Vec<f64> = (0..k).map(|_| rng.uniform().clamp(0.05, 0.95)).collect();
    (x, z, a, pi, LinGauss::new(0.4, 1.1))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The wrappers must reproduce the historical builders draw-for-draw;
    /// spot-check the invariants the migrated suites rely on.
    #[test]
    fn fixtures_are_deterministic_and_consistent() {
        let (x, z, a) = planted(12, 3, 5, 7);
        let (x2, z2, a2) = planted(12, 3, 5, 7);
        assert!(x.max_abs_diff(&x2) == 0.0);
        assert_eq!(z, z2);
        assert!(a.max_abs_diff(&a2) == 0.0);
        assert!(z.check_invariants());
        assert_eq!(x.rows(), 12);
        assert_eq!(a.rows(), 3);

        let (_, _, _, logit) = sweep_problem(10, 4, 3, 1);
        assert_eq!(logit.len(), 4);
        assert!((logit[0] + 0.4).abs() < 1e-12);

        let (x, zm, lg) = collapsed_problem(15, 4, 6, 2);
        assert_eq!(zm.rows(), 15);
        assert_eq!(zm.cols(), 4);
        assert_eq!(x.cols(), 6);
        assert_eq!(lg.sigma_x, 0.5);

        let (_, z, _, pi, lg) = runtime_problem(9, 5, 4, 3);
        assert_eq!(z.k(), 5);
        assert_eq!(pi.len(), 5);
        assert!(pi.iter().all(|&p| (0.05..=0.95).contains(&p)));
        assert_eq!(lg.sigma_x, 0.4);
    }
}
