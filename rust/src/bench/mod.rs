//! Hand-rolled benchmark harness (in-tree `criterion` replacement): fixed
//! warm-up, adaptive iteration count targeting a measurement budget,
//! mean/median/σ rows, and a markdown-ish table printer shared by all
//! `rust/benches/*` targets.

use std::time::{Duration, Instant};

use crate::metrics::stats::Summary;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Seconds per iteration.
    pub per_iter: Summary,
    pub iters: usize,
}

impl BenchResult {
    pub fn row(&self) -> String {
        format!(
            "| {:<42} | {:>10} | {:>10} | {:>10} | {:>6} |",
            self.name,
            human_time(self.per_iter.mean),
            human_time(self.per_iter.median),
            human_time(self.per_iter.std),
            self.iters
        )
    }
}

pub fn header() -> String {
    format!(
        "| {:<42} | {:>10} | {:>10} | {:>10} | {:>6} |\n|{}|{}|{}|{}|{}|",
        "benchmark", "mean", "median", "stddev", "iters",
        "-".repeat(44), "-".repeat(12), "-".repeat(12), "-".repeat(12), "-".repeat(8)
    )
}

pub fn human_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Benchmark a closure: `warmup` untimed runs, then enough timed runs to
/// fill `budget` (at least `min_iters`).
pub fn bench<F: FnMut()>(name: &str, warmup: usize, budget: Duration,
                         min_iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    // pilot to size the loop
    let t0 = Instant::now();
    f();
    let pilot = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((budget.as_secs_f64() / pilot) as usize)
        .clamp(min_iters.max(1), 100_000);
    let mut samples = Vec::with_capacity(iters + 1);
    samples.push(pilot);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    BenchResult {
        name: name.to_string(),
        per_iter: Summary::of(&samples),
        iters: samples.len(),
    }
}

/// Convenience wrapper with repo-standard settings.
pub fn quick<F: FnMut()>(name: &str, f: F) -> BenchResult {
    bench(name, 2, Duration::from_millis(1500), 5, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_sleeps() {
        let r = bench("sleep", 0, Duration::from_millis(30), 3, || {
            std::thread::sleep(Duration::from_millis(2));
        });
        assert!(r.per_iter.mean >= 0.0015, "mean {}", r.per_iter.mean);
        assert!(r.iters >= 3);
    }

    #[test]
    fn human_time_units() {
        assert_eq!(human_time(2.5), "2.500 s");
        assert_eq!(human_time(0.0025), "2.500 ms");
        assert_eq!(human_time(2.5e-6), "2.500 µs");
        assert!(human_time(5e-9).ends_with("ns"));
    }

    #[test]
    fn row_and_header_align() {
        let r = quick("noop", || {});
        let h = header();
        assert_eq!(h.lines().next().unwrap().matches('|').count(),
                   r.row().matches('|').count());
    }
}
