//! Dynamic feature-assignment state: the binary matrix Z with a growing /
//! shrinking set of instantiated columns, plus maintained column counts.
//!
//! Every sampler and the coordinator share this representation. Invariant
//! (property-tested): `m[k] == Σ_n z[n][k]` at all times, and no column
//! with `m[k] == 0` survives `compact()`.
//!
//! Z is binary, so two physical layouts are supported behind one API
//! ([`Kernel`]): the original one-byte-per-entry rows (`Repr::Bytes`,
//! stride K) and a bit-packed layout (`Repr::Words`) that packs each
//! row's K⁺ bits into `⌈K/64⌉` `u64` words. Packed rows make ZᵀZ a
//! popcount-over-AND, m_k a column popcount, and cut the sweep kernels'
//! cache traffic ~8×. Both layouts are **bit-equivalent by construction**:
//! every f64 the samplers consume (gram entries, ZᵀX sums, residual
//! updates) is accumulated in the same order from the same values, so a
//! chain run packed is identical to one run scalar — the differential
//! harness in `rust/tests/packed_equivalence.rs` pins this.
//!
//! Packed-layout rules (see docs/ARCHITECTURE.md § Packed Z layout):
//! * row stride is `words_per_row() = ⌈K/64⌉` words, row-major;
//! * bits at positions ≥ K in a row's tail word are **always zero**
//!   (checked by [`FeatureState::check_invariants`]) — growth by
//!   `add_features` inside the same word count is then just a K bump;
//! * `compact()` rebuilds rows by gathering kept columns into freshly
//!   zeroed words, re-establishing the tail invariant.

use crate::linalg::Mat;

/// Which Z kernel family a component should run: the scalar byte-per-bit
/// representation (`Scalar`, the default and the oracle in every
/// differential test) or the bit-packed `u64` representation (`Packed`).
/// A pure performance knob: chains are bit-identical under either, so it
/// is excluded from the checkpoint fingerprint like the thread count.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Kernel {
    #[default]
    Scalar,
    Packed,
}

impl Kernel {
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        match s {
            "scalar" => Ok(Kernel::Scalar),
            "packed" => Ok(Kernel::Packed),
            other => anyhow::bail!("unknown kernel '{other}' (scalar|packed)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Kernel::Scalar => "scalar",
            Kernel::Packed => "packed",
        }
    }
}

/// Physical bit storage. Both variants are row-major; `Bytes` has stride
/// K (one byte per entry), `Words` has stride `⌈K/64⌉` (64 entries per
/// word, bit j of word w covering column `64w + j`).
#[derive(Clone, Debug)]
enum Repr {
    Bytes(Vec<u8>),
    Words(Vec<u64>),
}

#[derive(Clone, Debug)]
pub struct FeatureState {
    n: usize,
    /// Row-major bits in one of the two layouts.
    repr: Repr,
    /// Active column count.
    k: usize,
    /// Column sums m_k.
    m: Vec<usize>,
}

/// Words needed for one packed row of `k` columns.
#[inline]
fn wpr_for(k: usize) -> usize {
    k.div_ceil(64)
}

/// Mask of valid bits in the tail word of a `k`-column packed row
/// (all-ones when K is a multiple of 64).
#[inline]
fn tail_mask(k: usize) -> u64 {
    if k % 64 == 0 {
        !0u64
    } else {
        (1u64 << (k % 64)) - 1
    }
}

impl FeatureState {
    pub fn empty(n: usize) -> Self {
        Self::empty_with(n, Kernel::Scalar)
    }

    /// Empty state in the given layout.
    pub fn empty_with(n: usize, kernel: Kernel) -> Self {
        let repr = match kernel {
            Kernel::Scalar => Repr::Bytes(vec![]),
            Kernel::Packed => Repr::Words(vec![]),
        };
        Self { n, repr, k: 0, m: vec![] }
    }

    /// Build from a dense 0/1 matrix (scalar layout; call
    /// [`Self::set_kernel`] to pack).
    pub fn from_mat(z: &Mat) -> Self {
        let (n, k) = (z.rows(), z.cols());
        let mut bits = vec![0u8; n * k];
        let mut m = vec![0usize; k];
        for i in 0..n {
            for j in 0..k {
                let v = z[(i, j)];
                debug_assert!(v == 0.0 || v == 1.0, "Z must be binary");
                if v == 1.0 {
                    bits[i * k + j] = 1;
                    m[j] += 1;
                }
            }
        }
        Self { n, repr: Repr::Bytes(bits), k, m }
    }

    /// Which layout this state currently uses.
    #[inline]
    pub fn kernel(&self) -> Kernel {
        match self.repr {
            Repr::Bytes(_) => Kernel::Scalar,
            Repr::Words(_) => Kernel::Packed,
        }
    }

    #[inline]
    pub fn is_packed(&self) -> bool {
        matches!(self.repr, Repr::Words(_))
    }

    /// Convert in place to the requested layout (no-op when already
    /// there). Purely a storage change: the logical Z is untouched, so
    /// this is safe at any point of a chain — checkpoints restored under
    /// the other kernel continue bit-identically.
    pub fn set_kernel(&mut self, kernel: Kernel) {
        match (&self.repr, kernel) {
            (Repr::Bytes(_), Kernel::Scalar) | (Repr::Words(_), Kernel::Packed) => {}
            (Repr::Bytes(bytes), Kernel::Packed) => {
                let wpr = wpr_for(self.k);
                let mut words = vec![0u64; self.n * wpr];
                for i in 0..self.n {
                    for j in 0..self.k {
                        if bytes[i * self.k + j] == 1 {
                            words[i * wpr + j / 64] |= 1u64 << (j % 64);
                        }
                    }
                }
                self.repr = Repr::Words(words);
            }
            (Repr::Words(words), Kernel::Scalar) => {
                let wpr = wpr_for(self.k);
                let mut bytes = vec![0u8; self.n * self.k];
                for i in 0..self.n {
                    for j in 0..self.k {
                        if words[i * wpr + j / 64] >> (j % 64) & 1 == 1 {
                            bytes[i * self.k + j] = 1;
                        }
                    }
                }
                self.repr = Repr::Bytes(bytes);
            }
        }
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }

    /// Packed row stride in words (`⌈K/64⌉`; meaningful for either
    /// layout — it is what [`Self::rows_words_mut`] slices by).
    #[inline]
    pub fn words_per_row(&self) -> usize {
        wpr_for(self.k)
    }

    #[inline]
    pub fn get(&self, row: usize, col: usize) -> u8 {
        debug_assert!(row < self.n && col < self.k);
        match &self.repr {
            Repr::Bytes(z) => z[row * self.k + col],
            Repr::Words(w) => {
                (w[row * wpr_for(self.k) + col / 64] >> (col % 64) & 1) as u8
            }
        }
    }

    /// Set a bit, keeping `m` consistent.
    pub fn set(&mut self, row: usize, col: usize, v: u8) {
        debug_assert!(v <= 1);
        let old = self.get(row, col);
        if old == v {
            return;
        }
        match &mut self.repr {
            Repr::Bytes(z) => z[row * self.k + col] = v,
            Repr::Words(w) => {
                w[row * wpr_for(self.k) + col / 64] ^= 1u64 << (col % 64)
            }
        }
        if v == 1 {
            self.m[col] += 1;
        } else {
            self.m[col] -= 1;
        }
    }

    #[inline]
    pub fn m(&self) -> &[usize] {
        &self.m
    }

    /// Row view as f64 (for linalg interop).
    pub fn row_f64(&self, row: usize) -> Vec<f64> {
        (0..self.k).map(|j| self.get(row, j) as f64).collect()
    }

    /// Scalar-layout row view (one byte per entry). Panics on a packed
    /// state — use [`Self::row_words`] or [`Self::get`] there.
    pub fn row_bits(&self, row: usize) -> &[u8] {
        match &self.repr {
            Repr::Bytes(z) => &z[row * self.k..(row + 1) * self.k],
            Repr::Words(_) => panic!("row_bits on a packed state"),
        }
    }

    /// Packed-layout row view (`words_per_row()` words). Panics on a
    /// scalar state.
    pub fn row_words(&self, row: usize) -> &[u64] {
        match &self.repr {
            Repr::Words(w) => {
                let wpr = wpr_for(self.k);
                &w[row * wpr..(row + 1) * wpr]
            }
            Repr::Bytes(_) => panic!("row_words on a scalar state"),
        }
    }

    /// Raw mutable bit access for a contiguous row range (row-major with
    /// stride [`Self::k`]) — the parallel executor's entry point for
    /// carving disjoint per-block views of a **scalar** state (panics on
    /// packed; see [`Self::rows_words_mut`]). The column counts `m` are
    /// **not** maintained through this view: after mutating, the caller
    /// must restore the invariant with [`Self::apply_m_delta`].
    pub fn rows_bits_mut(&mut self, rows: std::ops::Range<usize>) -> &mut [u8] {
        debug_assert!(rows.start <= rows.end && rows.end <= self.n);
        match &mut self.repr {
            Repr::Bytes(z) => &mut z[rows.start * self.k..rows.end * self.k],
            Repr::Words(_) => panic!("rows_bits_mut on a packed state"),
        }
    }

    /// Packed twin of [`Self::rows_bits_mut`]: raw mutable word access
    /// for a contiguous row range (row-major, stride
    /// [`Self::words_per_row`]). Callers must keep the tail-word
    /// invariant (no bits ≥ K) and restore `m` via
    /// [`Self::apply_m_delta`]. Panics on a scalar state.
    pub fn rows_words_mut(&mut self, rows: std::ops::Range<usize>) -> &mut [u64] {
        debug_assert!(rows.start <= rows.end && rows.end <= self.n);
        let wpr = wpr_for(self.k);
        match &mut self.repr {
            Repr::Words(w) => &mut w[rows.start * wpr..rows.end * wpr],
            Repr::Bytes(_) => panic!("rows_words_mut on a scalar state"),
        }
    }

    /// Fold per-column count changes from raw-bit mutation (see
    /// [`Self::rows_bits_mut`]) back into `m`: `m[k] += delta[k]`.
    /// `delta` may be shorter than K (columns past its end are untouched).
    pub fn apply_m_delta(&mut self, delta: &[i64]) {
        debug_assert!(delta.len() <= self.k);
        for (k, &d) in delta.iter().enumerate() {
            let m = self.m[k] as i64 + d;
            debug_assert!(
                (0..=self.n as i64).contains(&m),
                "m[{k}] out of range after delta {d}"
            );
            self.m[k] = m as usize;
        }
    }

    /// Append `count` new all-zero columns; returns the first new index.
    pub fn add_features(&mut self, count: usize) -> usize {
        if count == 0 {
            return self.k;
        }
        let new_k = self.k + count;
        match &mut self.repr {
            Repr::Bytes(z) => {
                let mut nz = vec![0u8; self.n * new_k];
                for i in 0..self.n {
                    nz[i * new_k..i * new_k + self.k]
                        .copy_from_slice(&z[i * self.k..(i + 1) * self.k]);
                }
                *z = nz;
            }
            Repr::Words(w) => {
                let (wpr, new_wpr) = (wpr_for(self.k), wpr_for(new_k));
                if new_wpr != wpr {
                    let mut nw = vec![0u64; self.n * new_wpr];
                    for i in 0..self.n {
                        nw[i * new_wpr..i * new_wpr + wpr]
                            .copy_from_slice(&w[i * wpr..(i + 1) * wpr]);
                    }
                    *w = nw;
                }
                // same word count: the tail invariant means the new
                // columns' bits are already zero — only K moves
            }
        }
        let first = self.k;
        self.k = new_k;
        self.m.resize(new_k, 0);
        first
    }

    /// Drop all empty columns. Returns the retained original indices in
    /// order (so callers can permute A / π the same way).
    pub fn compact(&mut self) -> Vec<usize> {
        let keep: Vec<usize> = (0..self.k).filter(|&j| self.m[j] > 0).collect();
        if keep.len() == self.k {
            return keep;
        }
        let new_k = keep.len();
        match &mut self.repr {
            Repr::Bytes(z) => {
                let mut nz = vec![0u8; self.n * new_k];
                for i in 0..self.n {
                    for (jj, &j) in keep.iter().enumerate() {
                        nz[i * new_k + jj] = z[i * self.k + j];
                    }
                }
                *z = nz;
            }
            Repr::Words(w) => {
                // gather kept columns into freshly zeroed words — the
                // tail invariant holds by construction
                let (wpr, new_wpr) = (wpr_for(self.k), wpr_for(new_k));
                let mut nw = vec![0u64; self.n * new_wpr];
                for i in 0..self.n {
                    let row = &w[i * wpr..(i + 1) * wpr];
                    let nrow = &mut nw[i * new_wpr..(i + 1) * new_wpr];
                    for (jj, &j) in keep.iter().enumerate() {
                        if row[j / 64] >> (j % 64) & 1 == 1 {
                            nrow[jj / 64] |= 1u64 << (jj % 64);
                        }
                    }
                }
                *w = nw;
            }
        }
        self.m = keep.iter().map(|&j| self.m[j]).collect();
        self.k = new_k;
        keep
    }

    /// Dense f64 copy (N × K).
    pub fn to_mat(&self) -> Mat {
        Mat::from_fn(self.n, self.k, |i, j| self.get(i, j) as f64)
    }

    /// Dense f64 copy padded to (rows × cols) with zeros.
    pub fn to_mat_padded(&self, rows: usize, cols: usize) -> Mat {
        assert!(rows >= self.n && cols >= self.k);
        Mat::from_fn(rows, cols, |i, j| {
            if i < self.n && j < self.k {
                self.get(i, j) as f64
            } else {
                0.0
            }
        })
    }

    /// ZᵀZ over all rows. See [`Self::gram_range`] for the kernel split.
    pub fn gram(&self) -> Mat {
        self.gram_range(0..self.n)
    }

    /// ZᵀZ restricted to a row range (K × K). Scalar states materialise
    /// the dense sub-block and use [`Mat::gram`] — exactly the
    /// computation every call site used to spell out. Packed states build
    /// per-column bitsets over the range and take popcounts of ANDed word
    /// pairs. Every entry is an integer co-occurrence count (< 2^53)
    /// accumulated from non-negative integer steps, so the two paths
    /// produce **bit-identical** f64s regardless of summation order.
    pub fn gram_range(&self, rows: std::ops::Range<usize>) -> Mat {
        debug_assert!(rows.start <= rows.end && rows.end <= self.n);
        match &self.repr {
            Repr::Bytes(_) => {
                let start = rows.start;
                Mat::from_fn(rows.len(), self.k, |i, j| {
                    self.get(start + i, j) as f64
                })
                .gram()
            }
            Repr::Words(w) => {
                let k = self.k;
                let nr = rows.len();
                let cw = wpr_for(nr); // words per column bitset
                let wpr = wpr_for(k);
                // transpose the range into column bitsets
                let mut cols = vec![0u64; k * cw];
                for (ri, i) in rows.enumerate() {
                    let cbit = 1u64 << (ri % 64);
                    let cword = ri / 64;
                    for (wi, &word) in w[i * wpr..(i + 1) * wpr].iter().enumerate() {
                        let mut word = word;
                        while word != 0 {
                            let j = wi * 64 + word.trailing_zeros() as usize;
                            cols[j * cw + cword] |= cbit;
                            word &= word - 1;
                        }
                    }
                }
                let mut out = Mat::zeros(k, k);
                for i in 0..k {
                    let ci = &cols[i * cw..(i + 1) * cw];
                    for j in i..k {
                        let cj = &cols[j * cw..(j + 1) * cw];
                        let c: u64 = ci
                            .iter()
                            .zip(cj)
                            .map(|(a, b)| (a & b).count_ones() as u64)
                            .sum();
                        out[(i, j)] = c as f64;
                        out[(j, i)] = c as f64;
                    }
                }
                out
            }
        }
    }

    /// ZᵀX over all rows (K × D); `x` must have N rows.
    pub fn t_matmul(&self, x: &Mat) -> Mat {
        assert_eq!(x.rows(), self.n, "t_matmul outer dim");
        self.t_matmul_range(0..self.n, x)
    }

    /// ZᵀX restricted to a row range; `x` holds exactly the range's rows
    /// (shard-local indexing, as the master's per-shard gram assembly
    /// uses). Scalar states go through the dense sub-block +
    /// [`Mat::t_matmul`]; packed states enumerate set bits per row in
    /// ascending order. [`Mat::t_matmul`] skips zero entries and walks
    /// rows ascending, so per output cell both paths add the same x
    /// values in the same order (and `1.0 * x == x` bitwise) — the
    /// results are bit-identical.
    pub fn t_matmul_range(&self, rows: std::ops::Range<usize>, x: &Mat) -> Mat {
        debug_assert!(rows.start <= rows.end && rows.end <= self.n);
        assert_eq!(x.rows(), rows.len(), "t_matmul_range rows");
        match &self.repr {
            Repr::Bytes(_) => {
                let start = rows.start;
                Mat::from_fn(rows.len(), self.k, |i, j| {
                    self.get(start + i, j) as f64
                })
                .t_matmul(x)
            }
            Repr::Words(w) => {
                let wpr = wpr_for(self.k);
                let mut out = Mat::zeros(self.k, x.cols());
                for (ri, i) in rows.enumerate() {
                    let xrow = x.row(ri);
                    for (wi, &word) in w[i * wpr..(i + 1) * wpr].iter().enumerate() {
                        let mut word = word;
                        while word != 0 {
                            let j = wi * 64 + word.trailing_zeros() as usize;
                            let orow = out.row_mut(j);
                            for (o, &b) in orow.iter_mut().zip(xrow) {
                                *o += b;
                            }
                            word &= word - 1;
                        }
                    }
                }
                out
            }
        }
    }

    /// Recompute `m` from scratch (test/debug helper). The packed path is
    /// the column-popcount the layout was built for.
    pub fn recount(&self) -> Vec<usize> {
        let mut m = vec![0usize; self.k];
        match &self.repr {
            Repr::Bytes(z) => {
                for i in 0..self.n {
                    for j in 0..self.k {
                        m[j] += z[i * self.k + j] as usize;
                    }
                }
            }
            Repr::Words(w) => {
                let wpr = wpr_for(self.k);
                for i in 0..self.n {
                    for (wi, &word) in w[i * wpr..(i + 1) * wpr].iter().enumerate() {
                        let mut word = word;
                        while word != 0 {
                            m[wi * 64 + word.trailing_zeros() as usize] += 1;
                            word &= word - 1;
                        }
                    }
                }
            }
        }
        m
    }

    /// Check the m-consistency invariant (and, packed, the tail-word
    /// masking + storage-size invariants).
    pub fn check_invariants(&self) -> bool {
        let storage_ok = match &self.repr {
            Repr::Bytes(z) => z.len() == self.n * self.k,
            Repr::Words(w) => {
                let wpr = wpr_for(self.k);
                let mask = tail_mask(self.k);
                w.len() == self.n * wpr
                    && (wpr == 0
                        || (0..self.n).all(|i| w[i * wpr + wpr - 1] & !mask == 0))
            }
        };
        storage_ok && self.m == self.recount()
    }

    /// Histogram of identical columns (for the lof-prior K_h! term),
    /// keyed by the column bit-pattern. A `BTreeMap` (not `HashMap`) so
    /// the bucket order — and hence the float accumulation order of the
    /// `Σ ln K_h!` consumer in `ibp::log_prior` — is a pure function of
    /// the bit patterns, not of the process's random hasher seed
    /// (detlint rule R3 hash-order).
    pub fn column_histogram(&self) -> Vec<usize> {
        use std::collections::BTreeMap;
        let mut counts: BTreeMap<Vec<u8>, usize> = BTreeMap::new();
        for j in 0..self.k {
            let col: Vec<u8> = (0..self.n).map(|i| self.get(i, j)).collect();
            *counts.entry(col).or_insert(0) += 1;
        }
        counts.into_values().collect()
    }
}

/// Logical equality: same shape, counts, and bits — regardless of layout
/// (a packed state equals its scalar twin). Same-layout comparisons take
/// the raw-storage fast path, which is valid for `Words` because tail
/// bits are invariantly zero.
impl PartialEq for FeatureState {
    fn eq(&self, other: &Self) -> bool {
        if self.n != other.n || self.k != other.k || self.m != other.m {
            return false;
        }
        match (&self.repr, &other.repr) {
            (Repr::Bytes(a), Repr::Bytes(b)) => a == b,
            (Repr::Words(a), Repr::Words(b)) => a == b,
            _ => (0..self.n)
                .all(|i| (0..self.k).all(|j| self.get(i, j) == other.get(i, j))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_maintains_counts() {
        let mut st = FeatureState::empty(4);
        st.add_features(3);
        st.set(0, 0, 1);
        st.set(1, 0, 1);
        st.set(2, 2, 1);
        assert_eq!(st.m(), &[2, 0, 1]);
        st.set(0, 0, 0);
        assert_eq!(st.m(), &[1, 0, 1]);
        st.set(0, 0, 0); // idempotent
        assert_eq!(st.m(), &[1, 0, 1]);
        assert!(st.check_invariants());
    }

    #[test]
    fn set_maintains_counts_packed() {
        let mut st = FeatureState::empty_with(4, Kernel::Packed);
        assert!(st.is_packed());
        st.add_features(3);
        st.set(0, 0, 1);
        st.set(1, 0, 1);
        st.set(2, 2, 1);
        assert_eq!(st.m(), &[2, 0, 1]);
        st.set(0, 0, 0);
        assert_eq!(st.m(), &[1, 0, 1]);
        st.set(0, 0, 0); // idempotent
        assert_eq!(st.m(), &[1, 0, 1]);
        assert!(st.check_invariants());
    }

    #[test]
    fn compact_drops_empty_and_returns_mapping() {
        for kernel in [Kernel::Scalar, Kernel::Packed] {
            let mut st = FeatureState::empty_with(3, kernel);
            st.add_features(4);
            st.set(0, 1, 1);
            st.set(2, 3, 1);
            let keep = st.compact();
            assert_eq!(keep, vec![1, 3]);
            assert_eq!(st.k(), 2);
            assert_eq!(st.m(), &[1, 1]);
            assert_eq!(st.get(0, 0), 1);
            assert_eq!(st.get(2, 1), 1);
            assert!(st.check_invariants());
        }
    }

    #[test]
    fn from_mat_roundtrip() {
        let m = Mat::from_vec(2, 3, vec![1.0, 0.0, 1.0, 0.0, 1.0, 1.0]);
        let st = FeatureState::from_mat(&m);
        assert_eq!(st.m(), &[1, 1, 2]);
        assert!(st.to_mat().max_abs_diff(&m) == 0.0);
        assert!(st.check_invariants());
    }

    #[test]
    fn add_features_preserves_old_bits() {
        let m = Mat::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
        let mut st = FeatureState::from_mat(&m);
        let first = st.add_features(2);
        assert_eq!(first, 2);
        assert_eq!(st.k(), 4);
        assert_eq!(st.get(0, 0), 1);
        assert_eq!(st.get(1, 1), 1);
        assert_eq!(st.get(0, 2), 0);
        assert!(st.check_invariants());
    }

    #[test]
    fn padded_matrix() {
        let m = Mat::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
        let st = FeatureState::from_mat(&m);
        let p = st.to_mat_padded(4, 5);
        assert_eq!(p.rows(), 4);
        assert_eq!(p.cols(), 5);
        assert_eq!(p[(0, 0)], 1.0);
        assert_eq!(p[(3, 4)], 0.0);
    }

    #[test]
    fn raw_bits_roundtrip_with_m_delta() {
        let mut st = FeatureState::empty(5);
        st.add_features(3);
        st.set(0, 0, 1);
        st.set(4, 2, 1);
        // flip bits through the raw view for rows 1..4 and track deltas
        let mut delta = [0i64; 3];
        {
            let bits = st.rows_bits_mut(1..4);
            assert_eq!(bits.len(), 9);
            bits[0] = 1; // (1, 0)
            delta[0] += 1;
            bits[2 * 3 + 1] = 1; // (3, 1)
            delta[1] += 1;
        }
        st.apply_m_delta(&delta);
        assert_eq!(st.m(), &[2, 1, 1]);
        assert!(st.check_invariants());
        // a negative delta after clearing a bit
        let mut delta = [0i64; 2];
        st.rows_bits_mut(0..1)[0] = 0;
        delta[0] -= 1;
        st.apply_m_delta(&delta);
        assert_eq!(st.m(), &[1, 1, 1]);
        assert!(st.check_invariants());
    }

    #[test]
    fn raw_words_roundtrip_with_m_delta() {
        let mut st = FeatureState::empty_with(5, Kernel::Packed);
        st.add_features(3);
        st.set(0, 0, 1);
        st.set(4, 2, 1);
        let mut delta = [0i64; 3];
        {
            let words = st.rows_words_mut(1..4);
            assert_eq!(words.len(), 3); // 3 rows × 1 word
            words[0] |= 1 << 0; // (1, 0)
            delta[0] += 1;
            words[2] |= 1 << 1; // (3, 1)
            delta[1] += 1;
        }
        st.apply_m_delta(&delta);
        assert_eq!(st.m(), &[2, 1, 1]);
        assert!(st.check_invariants());
        assert_eq!(st.get(1, 0), 1);
        assert_eq!(st.get(3, 1), 1);
    }

    #[test]
    fn column_histogram_groups_identical() {
        let m = Mat::from_vec(3, 3, vec![
            1.0, 1.0, 0.0,
            0.0, 0.0, 1.0,
            1.0, 1.0, 0.0,
        ]);
        let st = FeatureState::from_mat(&m);
        let mut h = st.column_histogram();
        h.sort_unstable();
        assert_eq!(h, vec![1, 2]);
    }

    /// Scalar/packed conversions roundtrip and compare equal across
    /// layouts, including K values straddling word boundaries.
    #[test]
    fn kernel_conversion_roundtrips() {
        use crate::rng::Pcg64;
        for k in [1usize, 7, 63, 64, 65, 130] {
            let mut rng = Pcg64::new(k as u64);
            let mut st = FeatureState::empty(9);
            st.add_features(k);
            for i in 0..9 {
                for j in 0..k {
                    if rng.bernoulli(0.3) {
                        st.set(i, j, 1);
                    }
                }
            }
            let mut packed = st.clone();
            packed.set_kernel(Kernel::Packed);
            assert!(packed.is_packed());
            assert!(packed.check_invariants(), "K={k} tail invariant");
            assert_eq!(packed, st, "K={k} cross-layout equality");
            let mut back = packed.clone();
            back.set_kernel(Kernel::Scalar);
            assert_eq!(back, st, "K={k} roundtrip");
            assert_eq!(back.row_bits(3), st.row_bits(3));
        }
    }

    /// Packed `add_features` within the same word count must not
    /// resurrect stale bits (the tail invariant earns its keep here).
    #[test]
    fn packed_growth_keeps_new_columns_zero() {
        let mut st = FeatureState::empty_with(3, Kernel::Packed);
        st.add_features(5);
        for i in 0..3 {
            st.set(i, 4, 1);
        }
        // drop the only occupied column, then grow back within one word
        for i in 0..3 {
            st.set(i, 4, 0);
        }
        let first = st.add_features(10);
        assert_eq!(first, 5);
        assert_eq!(st.k(), 15);
        assert!(st.m().iter().all(|&m| m == 0));
        assert!(st.check_invariants());
        // growth across a word boundary
        let first = st.add_features(80);
        assert_eq!(first, 15);
        assert_eq!(st.k(), 95);
        assert_eq!(st.words_per_row(), 2);
        assert!(st.check_invariants());
    }

    /// gram / t_matmul agree bit-for-bit between the packed kernels and
    /// the dense scalar computation, on full ranges and sub-ranges.
    #[test]
    fn packed_gram_and_t_matmul_match_dense() {
        use crate::rng::Pcg64;
        for (n, k, d, seed) in [(40usize, 5usize, 7usize, 1u64), (30, 66, 3, 2)] {
            let mut rng = Pcg64::new(seed);
            let mut st = FeatureState::empty(n);
            st.add_features(k);
            for i in 0..n {
                for j in 0..k {
                    if rng.bernoulli(0.35) {
                        st.set(i, j, 1);
                    }
                }
            }
            let x = Mat::from_fn(n, d, |_, _| rng.normal());
            let mut packed = st.clone();
            packed.set_kernel(Kernel::Packed);

            let want_g = st.to_mat().gram();
            assert!(st.gram().max_abs_diff(&want_g) == 0.0);
            assert!(packed.gram().max_abs_diff(&want_g) == 0.0);

            let want_t = st.to_mat().t_matmul(&x);
            assert!(st.t_matmul(&x).max_abs_diff(&want_t) == 0.0);
            assert!(packed.t_matmul(&x).max_abs_diff(&want_t) == 0.0);

            // sub-range with shard-local x, as the master's merge uses
            let range = (n / 4)..(3 * n / 4);
            let xp = Mat::from_fn(range.len(), d, |i, j| x[(range.start + i, j)]);
            let zp = Mat::from_fn(range.len(), k, |i, j| {
                st.get(range.start + i, j) as f64
            });
            let want_gr = zp.gram();
            let want_tr = zp.t_matmul(&xp);
            assert!(st.gram_range(range.clone()).max_abs_diff(&want_gr) == 0.0);
            assert!(packed.gram_range(range.clone()).max_abs_diff(&want_gr) == 0.0);
            assert!(st.t_matmul_range(range.clone(), &xp).max_abs_diff(&want_tr) == 0.0);
            assert!(packed.t_matmul_range(range, &xp).max_abs_diff(&want_tr) == 0.0);
        }
    }

    #[test]
    fn kernel_parse_and_name() {
        assert_eq!(Kernel::parse("scalar").unwrap(), Kernel::Scalar);
        assert_eq!(Kernel::parse("packed").unwrap(), Kernel::Packed);
        assert!(Kernel::parse("simd").is_err());
        assert_eq!(Kernel::Packed.name(), "packed");
        assert_eq!(Kernel::default(), Kernel::Scalar);
    }
}
