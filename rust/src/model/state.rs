//! Dynamic feature-assignment state: the binary matrix Z with a growing /
//! shrinking set of instantiated columns, plus maintained column counts.
//!
//! Every sampler and the coordinator share this representation. Invariant
//! (property-tested): `m[k] == Σ_n z[n][k]` at all times, and no column
//! with `m[k] == 0` survives `compact()`.

use crate::linalg::Mat;

#[derive(Clone, Debug, PartialEq)]
pub struct FeatureState {
    n: usize,
    /// Row-major bits: z[n * k_cap + k] — stored flat.
    z: Vec<u8>,
    /// Active column count.
    k: usize,
    /// Column sums m_k.
    m: Vec<usize>,
}

impl FeatureState {
    pub fn empty(n: usize) -> Self {
        Self { n, z: vec![], k: 0, m: vec![] }
    }

    /// Build from a dense 0/1 matrix.
    pub fn from_mat(z: &Mat) -> Self {
        let (n, k) = (z.rows(), z.cols());
        let mut bits = vec![0u8; n * k];
        let mut m = vec![0usize; k];
        for i in 0..n {
            for j in 0..k {
                let v = z[(i, j)];
                debug_assert!(v == 0.0 || v == 1.0, "Z must be binary");
                if v == 1.0 {
                    bits[i * k + j] = 1;
                    m[j] += 1;
                }
            }
        }
        Self { n, z: bits, k, m }
    }

    #[inline]
    pub fn n(&self) -> usize {
        self.n
    }

    #[inline]
    pub fn k(&self) -> usize {
        self.k
    }

    #[inline]
    pub fn get(&self, row: usize, col: usize) -> u8 {
        debug_assert!(row < self.n && col < self.k);
        self.z[row * self.k + col]
    }

    /// Set a bit, keeping `m` consistent.
    pub fn set(&mut self, row: usize, col: usize, v: u8) {
        debug_assert!(v <= 1);
        let idx = row * self.k + col;
        let old = self.z[idx];
        if old == v {
            return;
        }
        self.z[idx] = v;
        if v == 1 {
            self.m[col] += 1;
        } else {
            self.m[col] -= 1;
        }
    }

    #[inline]
    pub fn m(&self) -> &[usize] {
        &self.m
    }

    /// Row view as f64 (for linalg interop).
    pub fn row_f64(&self, row: usize) -> Vec<f64> {
        (0..self.k).map(|j| self.get(row, j) as f64).collect()
    }

    pub fn row_bits(&self, row: usize) -> &[u8] {
        &self.z[row * self.k..(row + 1) * self.k]
    }

    /// Raw mutable bit access for a contiguous row range (row-major with
    /// stride [`Self::k`]) — the parallel executor's entry point for
    /// carving disjoint per-block views. The column counts `m` are **not**
    /// maintained through this view: after mutating, the caller must
    /// restore the invariant with [`Self::apply_m_delta`].
    pub fn rows_bits_mut(&mut self, rows: std::ops::Range<usize>) -> &mut [u8] {
        debug_assert!(rows.start <= rows.end && rows.end <= self.n);
        &mut self.z[rows.start * self.k..rows.end * self.k]
    }

    /// Fold per-column count changes from raw-bit mutation (see
    /// [`Self::rows_bits_mut`]) back into `m`: `m[k] += delta[k]`.
    /// `delta` may be shorter than K (columns past its end are untouched).
    pub fn apply_m_delta(&mut self, delta: &[i64]) {
        debug_assert!(delta.len() <= self.k);
        for (k, &d) in delta.iter().enumerate() {
            let m = self.m[k] as i64 + d;
            debug_assert!(
                (0..=self.n as i64).contains(&m),
                "m[{k}] out of range after delta {d}"
            );
            self.m[k] = m as usize;
        }
    }

    /// Append `count` new all-zero columns; returns the first new index.
    pub fn add_features(&mut self, count: usize) -> usize {
        if count == 0 {
            return self.k;
        }
        let new_k = self.k + count;
        let mut z = vec![0u8; self.n * new_k];
        for i in 0..self.n {
            z[i * new_k..i * new_k + self.k]
                .copy_from_slice(&self.z[i * self.k..(i + 1) * self.k]);
        }
        self.z = z;
        let first = self.k;
        self.k = new_k;
        self.m.resize(new_k, 0);
        first
    }

    /// Drop all empty columns. Returns the retained original indices in
    /// order (so callers can permute A / π the same way).
    pub fn compact(&mut self) -> Vec<usize> {
        let keep: Vec<usize> = (0..self.k).filter(|&j| self.m[j] > 0).collect();
        if keep.len() == self.k {
            return keep;
        }
        let new_k = keep.len();
        let mut z = vec![0u8; self.n * new_k];
        for i in 0..self.n {
            for (jj, &j) in keep.iter().enumerate() {
                z[i * new_k + jj] = self.z[i * self.k + j];
            }
        }
        self.m = keep.iter().map(|&j| self.m[j]).collect();
        self.z = z;
        self.k = new_k;
        keep
    }

    /// Dense f64 copy (N × K).
    pub fn to_mat(&self) -> Mat {
        Mat::from_fn(self.n, self.k, |i, j| self.get(i, j) as f64)
    }

    /// Dense f64 copy padded to (rows × cols) with zeros.
    pub fn to_mat_padded(&self, rows: usize, cols: usize) -> Mat {
        assert!(rows >= self.n && cols >= self.k);
        Mat::from_fn(rows, cols, |i, j| {
            if i < self.n && j < self.k {
                self.get(i, j) as f64
            } else {
                0.0
            }
        })
    }

    /// Recompute `m` from scratch (test/debug helper).
    pub fn recount(&self) -> Vec<usize> {
        let mut m = vec![0usize; self.k];
        for i in 0..self.n {
            for j in 0..self.k {
                m[j] += self.z[i * self.k + j] as usize;
            }
        }
        m
    }

    /// Check the m-consistency invariant.
    pub fn check_invariants(&self) -> bool {
        self.m == self.recount() && self.z.len() == self.n * self.k
    }

    /// Histogram of identical columns (for the lof-prior K_h! term),
    /// keyed by the column bit-pattern.
    pub fn column_histogram(&self) -> Vec<usize> {
        use std::collections::HashMap;
        let mut counts: HashMap<Vec<u8>, usize> = HashMap::new();
        for j in 0..self.k {
            let col: Vec<u8> = (0..self.n).map(|i| self.get(i, j)).collect();
            *counts.entry(col).or_insert(0) += 1;
        }
        counts.into_values().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_maintains_counts() {
        let mut st = FeatureState::empty(4);
        st.add_features(3);
        st.set(0, 0, 1);
        st.set(1, 0, 1);
        st.set(2, 2, 1);
        assert_eq!(st.m(), &[2, 0, 1]);
        st.set(0, 0, 0);
        assert_eq!(st.m(), &[1, 0, 1]);
        st.set(0, 0, 0); // idempotent
        assert_eq!(st.m(), &[1, 0, 1]);
        assert!(st.check_invariants());
    }

    #[test]
    fn compact_drops_empty_and_returns_mapping() {
        let mut st = FeatureState::empty(3);
        st.add_features(4);
        st.set(0, 1, 1);
        st.set(2, 3, 1);
        let keep = st.compact();
        assert_eq!(keep, vec![1, 3]);
        assert_eq!(st.k(), 2);
        assert_eq!(st.m(), &[1, 1]);
        assert_eq!(st.get(0, 0), 1);
        assert_eq!(st.get(2, 1), 1);
        assert!(st.check_invariants());
    }

    #[test]
    fn from_mat_roundtrip() {
        let m = Mat::from_vec(2, 3, vec![1.0, 0.0, 1.0, 0.0, 1.0, 1.0]);
        let st = FeatureState::from_mat(&m);
        assert_eq!(st.m(), &[1, 1, 2]);
        assert!(st.to_mat().max_abs_diff(&m) == 0.0);
        assert!(st.check_invariants());
    }

    #[test]
    fn add_features_preserves_old_bits() {
        let m = Mat::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
        let mut st = FeatureState::from_mat(&m);
        let first = st.add_features(2);
        assert_eq!(first, 2);
        assert_eq!(st.k(), 4);
        assert_eq!(st.get(0, 0), 1);
        assert_eq!(st.get(1, 1), 1);
        assert_eq!(st.get(0, 2), 0);
        assert!(st.check_invariants());
    }

    #[test]
    fn padded_matrix() {
        let m = Mat::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
        let st = FeatureState::from_mat(&m);
        let p = st.to_mat_padded(4, 5);
        assert_eq!(p.rows(), 4);
        assert_eq!(p.cols(), 5);
        assert_eq!(p[(0, 0)], 1.0);
        assert_eq!(p[(3, 4)], 0.0);
    }

    #[test]
    fn raw_bits_roundtrip_with_m_delta() {
        let mut st = FeatureState::empty(5);
        st.add_features(3);
        st.set(0, 0, 1);
        st.set(4, 2, 1);
        // flip bits through the raw view for rows 1..4 and track deltas
        let mut delta = [0i64; 3];
        {
            let bits = st.rows_bits_mut(1..4);
            assert_eq!(bits.len(), 9);
            bits[0] = 1; // (1, 0)
            delta[0] += 1;
            bits[2 * 3 + 1] = 1; // (3, 1)
            delta[1] += 1;
        }
        st.apply_m_delta(&delta);
        assert_eq!(st.m(), &[2, 1, 1]);
        assert!(st.check_invariants());
        // a negative delta after clearing a bit
        let mut delta = [0i64; 2];
        st.rows_bits_mut(0..1)[0] = 0;
        delta[0] -= 1;
        st.apply_m_delta(&delta);
        assert_eq!(st.m(), &[1, 1, 1]);
        assert!(st.check_invariants());
    }

    #[test]
    fn column_histogram_groups_identical() {
        let m = Mat::from_vec(3, 3, vec![
            1.0, 1.0, 0.0,
            0.0, 0.0, 1.0,
            1.0, 1.0, 0.0,
        ]);
        let st = FeatureState::from_mat(&m);
        let mut h = st.column_histogram();
        h.sort_unstable();
        assert_eq!(h, vec![1, 2]);
    }
}
