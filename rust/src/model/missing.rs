//! Missing-data support: inference when only a subset of each row's
//! dimensions is observed.
//!
//! The linear-Gaussian likelihood factorises over dimensions, so masking
//! is exact: unobserved entries simply drop out of every product. This
//! powers the `inpaint` example (reconstruct masked pixels of held-out
//! images from the features inferred on the observed pixels) — the
//! downstream use the paper's introduction motivates latent feature
//! models with.

use crate::linalg::Mat;
use crate::model::lingauss::LN_2PI;
use crate::model::state::FeatureState;
use crate::rng::Pcg64;

/// Per-entry observation mask (1.0 = observed). Same shape as X.
#[derive(Clone, Debug)]
pub struct Mask {
    pub m: Mat,
}

impl Mask {
    pub fn full(rows: usize, cols: usize) -> Self {
        Self { m: Mat::from_fn(rows, cols, |_, _| 1.0) }
    }

    /// Hide each entry independently with probability `p_missing`.
    pub fn random(rows: usize, cols: usize, p_missing: f64, rng: &mut Pcg64) -> Self {
        Self {
            m: Mat::from_fn(rows, cols, |_, _| {
                if rng.bernoulli(p_missing) { 0.0 } else { 1.0 }
            }),
        }
    }

    #[inline]
    pub fn observed(&self, i: usize, j: usize) -> bool {
        self.m[(i, j)] == 1.0
    }

    pub fn observed_count(&self) -> usize {
        self.m.as_slice().iter().filter(|&&v| v == 1.0).count()
    }
}

/// `log N(x_row[obs] ; (z_row A)[obs], σ² I)` over observed dims only.
pub fn masked_row_loglik(
    x_row: &[f64],
    mask_row: &[f64],
    z_row: &[f64],
    a: &Mat,
    sigma_x: f64,
) -> f64 {
    let d = x_row.len();
    let mut rss = 0.0;
    let mut d_obs = 0.0;
    for j in 0..d {
        if mask_row[j] == 0.0 {
            continue;
        }
        d_obs += 1.0;
        let mut mean = 0.0;
        for (k, &zk) in z_row.iter().enumerate() {
            if zk != 0.0 {
                mean += a[(k, j)];
            }
        }
        let r = x_row[j] - mean;
        rss += r * r;
    }
    -0.5 * d_obs * (LN_2PI + 2.0 * sigma_x.ln())
        - rss / (2.0 * sigma_x * sigma_x)
}

/// One masked uncollapsed Gibbs sweep of `z` given (A, prior logits):
/// identical to `samplers::uncollapsed::sweep_rows` except that residual
/// dot products skip unobserved dimensions. Returns flips.
#[allow(clippy::too_many_arguments)]
pub fn masked_sweep(
    x: &Mat,
    mask: &Mask,
    z: &mut FeatureState,
    a: &Mat,
    prior_logit: &[f64],
    inv2s2: f64,
    rng: &mut Pcg64,
) -> usize {
    let n = x.rows();
    let d = x.cols();
    let k_limit = z.k().min(a.rows());
    let mut flips = 0;
    for row in 0..n {
        // residual over observed dims for this row
        let mut resid: Vec<f64> = (0..d).map(|j| x[(row, j)]).collect();
        for k in 0..k_limit {
            if z.get(row, k) == 1 {
                for j in 0..d {
                    resid[j] -= a[(k, j)];
                }
            }
        }
        let mrow = mask.m.row(row);
        for k in 0..k_limit {
            let z_old = z.get(row, k);
            let mut r0a = 0.0;
            let mut aa = 0.0;
            for j in 0..d {
                if mrow[j] == 0.0 {
                    continue;
                }
                let aj = a[(k, j)];
                let r0 = resid[j] + if z_old == 1 { aj } else { 0.0 };
                r0a += r0 * aj;
                aa += aj * aj;
            }
            let logit = prior_logit[k] + (2.0 * r0a - aa) * inv2s2;
            let u = rng.uniform();
            let z_new = if (u / (1.0 - u)).ln() < logit { 1u8 } else { 0u8 };
            if z_new != z_old {
                flips += 1;
                let sign = z_old as f64 - z_new as f64;
                for j in 0..d {
                    resid[j] += sign * a[(k, j)];
                }
                z.set(row, k, z_new);
            }
        }
    }
    flips
}

/// Posterior-mean reconstruction: observed entries pass through, missing
/// entries are filled with `(Z A)[i,j]`.
pub fn reconstruct(x: &Mat, mask: &Mask, z: &FeatureState, a: &Mat) -> Mat {
    let mut out = Mat::zeros(x.rows(), x.cols());
    reconstruct_into(&mut out, x, mask, z, a);
    out
}

/// In-place variant of [`reconstruct`]: overwrites `out` (same shape as
/// `x`) without allocating, summing active rows of `A` directly instead
/// of materialising a dense Z and a dense Z·A. The prediction hot loop
/// (`serve::PredictEngine::impute`) writes each fanned-out posterior
/// sample's reconstruction into that sample's private buffer through
/// this, so the per-sample cost is one buffer, not a dense Z·A chain.
pub fn reconstruct_into(out: &mut Mat, x: &Mat, mask: &Mask, z: &FeatureState, a: &Mat) {
    assert_eq!(out.rows(), x.rows(), "reconstruct_into: row mismatch");
    assert_eq!(out.cols(), x.cols(), "reconstruct_into: col mismatch");
    let d = x.cols();
    let k_limit = z.k().min(a.rows());
    for i in 0..x.rows() {
        let row = out.row_mut(i);
        row.fill(0.0);
        for k in 0..k_limit {
            if z.get(i, k) == 1 {
                for (t, &v) in row.iter_mut().zip(a.row(k)) {
                    *t += v;
                }
            }
        }
        for j in 0..d {
            if mask.observed(i, j) {
                row[j] = x[(i, j)];
            }
        }
    }
}

/// MSE over the MISSING entries only (against ground truth).
pub fn missing_mse(truth: &Mat, recon: &Mat, mask: &Mask) -> f64 {
    let mut acc = 0.0;
    let mut count = 0usize;
    for i in 0..truth.rows() {
        for j in 0..truth.cols() {
            if !mask.observed(i, j) {
                let r = truth[(i, j)] - recon[(i, j)];
                acc += r * r;
                count += 1;
            }
        }
    }
    if count == 0 { 0.0 } else { acc / count as f64 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::state::Kernel;
    use crate::testutil::planted;

    #[test]
    fn masked_sweep_is_kernel_invariant() {
        // masked_sweep goes through get/set only — the packed state must
        // produce the same bits and consume the same RNG stream
        let (x, _, a) = planted(30, 3, 12, 11);
        let mut rng = Pcg64::new(12);
        let mask = Mask::random(30, 12, 0.4, &mut rng);
        let logit = vec![0.0; 3];
        let mut runs = vec![];
        for kernel in [Kernel::Scalar, Kernel::Packed] {
            let mut z = FeatureState::empty_with(30, kernel);
            z.add_features(3);
            let mut rng = Pcg64::new(13);
            let flips: usize = (0..3)
                .map(|_| masked_sweep(&x, &mask, &mut z, &a, &logit, 1.0 / 0.02, &mut rng))
                .sum();
            runs.push((z, flips, rng.next_u64()));
        }
        assert_eq!(runs[0].0, runs[1].0, "Z diverged across kernels");
        assert_eq!(runs[0].1, runs[1].1, "flips diverged across kernels");
        assert_eq!(runs[0].2, runs[1].2, "RNG diverged across kernels");
        assert!(runs[0].1 > 0);
        assert!(runs[1].0.is_packed() && runs[1].0.check_invariants());
    }

    #[test]
    fn full_mask_matches_unmasked_loglik() {
        let (x, z, a) = planted(10, 3, 8, 1);
        let mask = Mask::full(10, 8);
        let lg = crate::model::LinGauss::new(0.4, 1.0);
        for i in 0..10 {
            let zr = z.row_f64(i);
            let got = masked_row_loglik(x.row(i), mask.m.row(i), &zr, &a, 0.4);
            let want = lg.row_loglik(x.row(i), &zr, &a);
            assert!((got - want).abs() < 1e-10);
        }
    }

    #[test]
    fn masked_loglik_ignores_hidden_dims() {
        let (x, z, a) = planted(5, 2, 6, 2);
        let mut mask = Mask::full(5, 6);
        mask.m[(0, 3)] = 0.0;
        // corrupt the hidden entry wildly: loglik must not change
        let mut x2 = x.clone();
        x2[(0, 3)] = 1e6;
        let zr = z.row_f64(0);
        let a1 = masked_row_loglik(x.row(0), mask.m.row(0), &zr, &a, 0.4);
        let a2 = masked_row_loglik(x2.row(0), mask.m.row(0), &zr, &a, 0.4);
        assert_eq!(a1, a2);
    }

    #[test]
    fn masked_sweep_recovers_bits_from_partial_observations() {
        let (x, z_true, a) = planted(80, 3, 36, 3);
        let mut rng = Pcg64::new(4);
        let mask = Mask::random(80, 36, 0.5, &mut rng);
        let mut z = FeatureState::empty(80);
        z.add_features(3);
        let logit = vec![0.0; 3];
        let inv2s2 = 1.0 / (2.0 * 0.01);
        for _ in 0..3 {
            masked_sweep(&x, &mask, &mut z, &a, &logit, inv2s2, &mut rng);
        }
        let agree: usize = (0..80)
            .map(|i| (0..3).filter(|&k| z.get(i, k) == z_true.get(i, k)).count())
            .sum();
        assert!(
            agree as f64 / 240.0 > 0.9,
            "agreement {} with half the pixels hidden",
            agree as f64 / 240.0
        );
    }

    #[test]
    fn reconstruction_beats_mean_imputation() {
        let (x, z_true, a) = planted(60, 3, 36, 5);
        let mut rng = Pcg64::new(6);
        let mask = Mask::random(60, 36, 0.4, &mut rng);
        // infer z from observed half
        let mut z = FeatureState::empty(60);
        z.add_features(3);
        let logit = vec![0.0; 3];
        for _ in 0..4 {
            masked_sweep(&x, &mask, &mut z, &a, &logit, 1.0 / 0.02, &mut rng);
        }
        let recon = reconstruct(&x, &mask, &z, &a);
        let clean = z_true.to_mat().matmul(&a);
        let model_mse = missing_mse(&clean, &recon, &mask);
        // baseline: per-column observed mean
        let mut mean_fill = x.clone();
        for j in 0..36 {
            let (mut s, mut c) = (0.0f64, 0.0f64);
            for i in 0..60 {
                if mask.observed(i, j) {
                    s += x[(i, j)];
                    c += 1.0;
                }
            }
            let mu = s / c.max(1.0);
            for i in 0..60 {
                if !mask.observed(i, j) {
                    mean_fill[(i, j)] = mu;
                }
            }
        }
        let base_mse = missing_mse(&clean, &mean_fill, &mask);
        assert!(
            model_mse < 0.3 * base_mse,
            "model {model_mse:.4} vs mean-impute {base_mse:.4}"
        );
    }

    #[test]
    fn reconstruct_into_matches_reconstruct_without_allocating_fresh() {
        let (x, z, a) = planted(25, 3, 10, 8);
        let mut rng = Pcg64::new(9);
        let mask = Mask::random(25, 10, 0.3, &mut rng);
        let want = reconstruct(&x, &mask, &z, &a);
        // dirty buffer: reconstruct_into must fully overwrite it
        let mut out = Mat::from_fn(25, 10, |_, _| f64::NAN);
        reconstruct_into(&mut out, &x, &mask, &z, &a);
        assert!(out.max_abs_diff(&want) == 0.0);
        // reuse the same buffer for a second (different) reconstruction
        let mask2 = Mask::full(25, 10);
        reconstruct_into(&mut out, &x, &mask2, &z, &a);
        assert!(out.max_abs_diff(&x) == 0.0);
    }

    #[test]
    fn mask_counting() {
        let mut rng = Pcg64::new(7);
        let mask = Mask::random(100, 10, 0.3, &mut rng);
        let frac = mask.observed_count() as f64 / 1000.0;
        assert!((frac - 0.7).abs() < 0.05);
        assert_eq!(Mask::full(4, 4).observed_count(), 16);
    }
}
