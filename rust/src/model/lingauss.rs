//! Linear-Gaussian IBP likelihood machinery (paper Eq. 1).
//!
//! Two representations:
//! * **uncollapsed** — `P(X | Z, A, σ_X)`, a plain Gaussian; used by the
//!   parallel workers on the instantiated features.
//! * **collapsed** — `P(X | Z, σ_X, σ_A)` with A marginalised (G&G 2005);
//!   used by the collapsed baseline and the p′ tail sampler. The
//!   [`CollapsedCache`] maintains `M⁻¹`, `log|M|`, `E = ZᵀX` and
//!   `G = E Eᵀ` under rank-1 row removal / insertion so each Gibbs bit
//!   flip costs O(K² + KD) instead of a refactorisation.

use crate::linalg::{sm_update, symmetrize, Cholesky, Mat, UCholesky};
use crate::model::state::FeatureState;
use crate::rng::Pcg64;

pub const LN_2PI: f64 = 1.837_877_066_409_345_5;

/// Model hyper-state: the two scale parameters.
#[derive(Clone, Copy, Debug)]
pub struct LinGauss {
    pub sigma_x: f64,
    pub sigma_a: f64,
}

impl LinGauss {
    pub fn new(sigma_x: f64, sigma_a: f64) -> Self {
        assert!(sigma_x > 0.0 && sigma_a > 0.0);
        Self { sigma_x, sigma_a }
    }

    /// (σ_X / σ_A)² — the ridge added to ZᵀZ.
    #[inline]
    pub fn ratio(&self) -> f64 {
        (self.sigma_x / self.sigma_a).powi(2)
    }

    /// log N(x_row ; z_row A, σ_X² I).
    pub fn row_loglik(&self, x_row: &[f64], z_row: &[f64], a: &Mat) -> f64 {
        let d = x_row.len();
        debug_assert_eq!(z_row.len(), a.rows());
        debug_assert_eq!(d, a.cols());
        let mut rss = 0.0;
        for j in 0..d {
            let mut mean = 0.0;
            for (k, &zk) in z_row.iter().enumerate() {
                if zk != 0.0 {
                    mean += a[(k, j)];
                }
            }
            let r = x_row[j] - mean;
            rss += r * r;
        }
        -0.5 * d as f64 * (LN_2PI + 2.0 * self.sigma_x.ln())
            - rss / (2.0 * self.sigma_x * self.sigma_x)
    }

    /// Full uncollapsed log P(X | Z, A).
    pub fn loglik(&self, x: &Mat, z: &Mat, a: &Mat) -> f64 {
        let resid = x.sub(&z.matmul(a));
        let (n, d) = (x.rows() as f64, x.cols() as f64);
        -0.5 * n * d * (LN_2PI + 2.0 * self.sigma_x.ln())
            - resid.frob2() / (2.0 * self.sigma_x * self.sigma_x)
    }

    /// Collapsed log P(X | Z) from scratch (oracle path; O(K³ + K²D + NKD)).
    pub fn collapsed_loglik(&self, x: &Mat, z: &Mat) -> f64 {
        let (n, d, k) = (x.rows(), x.cols(), z.cols());
        let mut m = z.gram();
        m.add_diag(self.ratio());
        let ch = Cholesky::new(&m).expect("M = ZᵀZ + rI is PD");
        let e = z.t_matmul(x);
        let w = ch.solve_mat(&e);
        let tr_quad = e.dot(&w);
        collapsed_loglik_terms(
            n, d, k, self.sigma_x, self.sigma_a, ch.logdet(), x.frob2(), tr_quad,
        )
    }

    /// Posterior mean of A | X, Z: M⁻¹ ZᵀX.
    pub fn apost_mean(&self, ztz: &Mat, ztx: &Mat) -> Mat {
        let mut m = ztz.clone();
        m.add_diag(self.ratio());
        Cholesky::new(&m).expect("PD").solve_mat(ztx)
    }

    /// Draw A | X, Z ~ MN(M⁻¹ZᵀX, σ_X² M⁻¹, I_D).
    pub fn apost_sample(&self, ztz: &Mat, ztx: &Mat, rng: &mut Pcg64) -> Mat {
        let k = ztz.rows();
        let d = ztx.cols();
        let mut m = ztz.clone();
        m.add_diag(self.ratio());
        let ch = Cholesky::new(&m).expect("PD");
        let mean = ch.solve_mat(ztx);
        let eps = Mat::from_fn(k, d, |_, _| rng.normal());
        let mut noise = ch.lt_solve_mat(&eps);
        noise.scale(self.sigma_x);
        let mut a = mean;
        a.add_assign(&noise);
        a
    }

    /// Residual sum of squares ‖X − Z A‖².
    pub fn rss(&self, x: &Mat, z: &Mat, a: &Mat) -> f64 {
        x.sub(&z.matmul(a)).frob2()
    }
}

/// Assemble the collapsed log-likelihood from its sufficient scalars.
#[allow(clippy::too_many_arguments)]
pub fn collapsed_loglik_terms(
    n: usize,
    d: usize,
    k: usize,
    sigma_x: f64,
    sigma_a: f64,
    logdet_m: f64,
    tr_xx: f64,
    tr_quad: f64,
) -> f64 {
    let (nf, df, kf) = (n as f64, d as f64, k as f64);
    -0.5 * nf * df * LN_2PI
        - (nf - kf) * df * sigma_x.ln()
        - kf * df * sigma_a.ln()
        - 0.5 * df * logdet_m
        - (tr_xx - tr_quad) / (2.0 * sigma_x * sigma_x)
}

/// Incremental collapsed-likelihood cache over (Z, X).
///
/// Maintains, for the *current* Z:
///   `ztz = ZᵀZ`, `minv = (ZᵀZ + ratio·I)⁻¹`, `chol` = lower factor of M,
///   `logdet = log|M|` (from the factor — exact, no summed-delta drift),
///   `e = ZᵀX`, `g = E Eᵀ`, `tr_xx = ‖X‖²`, `tr_quad = tr(M⁻¹ G)`.
///
/// The Gibbs sweep uses `remove_row` / `candidate_loglik` / `insert_row`;
/// drift from long SM chains is bounded by periodic `refresh`. Once the
/// cache is warm, **no Z-side operation touches X or Z again**:
/// structural growth ([`Self::append_empty_features`]), compaction
/// ([`Self::retain_features`]) and σ ridge changes
/// ([`Self::loglik_at_ratio`] / [`Self::adopt`]) all work off the cached
/// sufficient statistics — at most O(K³ + K²D), never O(N·…). The two
/// deliberate N paths are `refresh` (drift fallback) and
/// [`Self::reset_data`] (the data matrix itself changed — E must be
/// recomputed at O(NKD), the inherent cost of new data).
#[derive(Clone, Debug)]
pub struct CollapsedCache {
    pub ztz: Mat,
    pub minv: Mat,
    pub logdet: f64,
    pub e: Mat,
    pub g: Mat,
    pub tr_xx: f64,
    chol: UCholesky,
    n: usize,
    d: usize,
    ratio: f64,
    updates: usize,
}

/// A collapsed likelihood evaluated at a *different* ridge ratio than the
/// cache's, together with the freshly factorised M′ so a σ-MH acceptance
/// can [`CollapsedCache::adopt`] it without any O(N·…) rebuild. Holds
/// only the factor — the Sherman–Morrison inverse is built lazily in
/// `adopt`, so a *rejected* proposal never pays the explicit inverse.
#[derive(Clone, Debug)]
pub struct RatioEval {
    /// Collapsed log P(X | Z) under the proposal's (σ_X, σ_A).
    pub loglik: f64,
    ratio: f64,
    chol: Cholesky,
    logdet: f64,
}

impl CollapsedCache {
    pub fn new(x: &Mat, z: &Mat, ratio: f64) -> Self {
        Self::from_stats(z.gram(), z.t_matmul(x), x, ratio)
    }

    /// Build directly from a [`FeatureState`] — under the packed kernel
    /// the gram is popcount-over-AND and E = ZᵀX a sparse accumulation,
    /// both bit-identical to the dense path (integer counts < 2⁵³ and
    /// identical summation order), so caches built either way agree to
    /// the last bit. Never densifies Z.
    pub fn from_state(x: &Mat, z: &FeatureState, ratio: f64) -> Self {
        Self::from_stats(z.gram(), z.t_matmul(x), x, ratio)
    }

    /// Shared constructor core: `ztz = ZᵀZ`, `e = ZᵀX` already computed
    /// by either the dense or the packed kernel.
    fn from_stats(ztz: Mat, e: Mat, x: &Mat, ratio: f64) -> Self {
        let mut m = ztz.clone();
        m.add_diag(ratio);
        let ch = Cholesky::new(&m).expect("M PD");
        let g = e.matmul(&e.transpose());
        let minv = ch.inverse();
        let logdet = ch.logdet();
        Self {
            ztz,
            minv,
            logdet,
            e,
            g,
            tr_xx: x.frob2(),
            chol: UCholesky::from_cholesky(ch),
            n: x.rows(),
            d: x.cols(),
            ratio,
            updates: 0,
        }
    }

    #[inline]
    pub fn k(&self) -> usize {
        self.minv.rows()
    }

    /// Current collapsed log P(X | Z).
    pub fn loglik(&self, lg: &LinGauss) -> f64 {
        let tr_quad = self.minv.dot(&self.g);
        collapsed_loglik_terms(
            self.n, self.d, self.k(), lg.sigma_x, lg.sigma_a,
            self.logdet, self.tr_xx, tr_quad,
        )
    }

    /// Remove observation row (z_row, x_row) from all statistics.
    /// Returns false if the downdate is singular — the cache may then be
    /// partially mutated and the caller MUST `refresh` (every caller
    /// already does).
    pub fn remove_row(&mut self, z_row: &[f64], x_row: &[f64]) -> bool {
        if !self.chol.downdate(z_row) {
            return false;
        }
        if sm_update(&mut self.minv, z_row, -1.0).is_none() {
            return false;
        }
        self.logdet = self.chol.logdet();
        self.rank1_gram(z_row, -1.0);
        self.rank1_e(z_row, x_row, -1.0);
        self.maybe_symmetrize();
        true
    }

    /// Insert observation row (z_row, x_row) into all statistics.
    /// Returns false if accumulated drift has made the rank-1 update
    /// numerically singular — the cache may then be partially mutated and
    /// the caller MUST `refresh`, exactly as for [`Self::remove_row`].
    #[must_use]
    pub fn insert_row(&mut self, z_row: &[f64], x_row: &[f64]) -> bool {
        if sm_update(&mut self.minv, z_row, 1.0).is_none() {
            return false;
        }
        if !self.chol.update(z_row) {
            return false;
        }
        self.logdet = self.chol.logdet();
        self.rank1_gram(z_row, 1.0);
        self.rank1_e(z_row, x_row, 1.0);
        self.maybe_symmetrize();
        true
    }

    /// Collapsed log P(X | Z′) where Z′ = current Z (with some row already
    /// removed) plus candidate row `z_row` holding observation `x_row`.
    /// O(K² + KD); does not mutate the cache.
    ///
    /// Returns `NaN` if drift has pushed the Sherman–Morrison denominator
    /// `1 + z′ᵀM⁻¹z′` non-positive or non-finite — callers check
    /// finiteness and refresh-and-retry rather than feeding a silent NaN
    /// into the categorical draw.
    pub fn candidate_loglik(&self, z_row: &[f64], x_row: &[f64], lg: &LinGauss) -> f64 {
        let k = self.k();
        // w = M⁻¹ z′
        let w = self.minv.matvec(z_row);
        let ztw: f64 = z_row.iter().zip(&w).map(|(a, b)| a * b).sum();
        let denom = 1.0 + ztw;
        if !(denom > 0.0) || !denom.is_finite() {
            return f64::NAN;
        }
        let logdet_new = self.logdet + denom.ln();
        // c = E x′ᵀ  (K), s = x′·x′
        let mut c = vec![0.0; k];
        for i in 0..k {
            let erow = self.e.row(i);
            c[i] = erow.iter().zip(x_row).map(|(a, b)| a * b).sum();
        }
        let s: f64 = x_row.iter().map(|v| v * v).sum();
        // tr(M′⁻¹ G′) where M′ = M + z′z′ᵀ, G′ = G + z′cᵀ + cz′ᵀ + s z′z′ᵀ
        let tr_mg = self.minv.dot(&self.g);
        let wc: f64 = w.iter().zip(&c).map(|(a, b)| a * b).sum();
        let wz: f64 = ztw;
        let tr_mgp = tr_mg + 2.0 * wc + s * wz;
        // wᵀG′w
        let gw = self.g.matvec(&w);
        let wgw: f64 = w.iter().zip(&gw).map(|(a, b)| a * b).sum();
        let wgpw = wgw + 2.0 * wz * wc + s * wz * wz;
        let tr_quad = tr_mgp - wgpw / denom;
        collapsed_loglik_terms(
            self.n, self.d, k, lg.sigma_x, lg.sigma_a,
            logdet_new, self.tr_xx, tr_quad,
        )
    }

    /// Collapsed log P(X | Z″) where Z″ = (current Z with some row removed)
    /// + candidate row `z_row` + `j_new` brand-new singleton columns active
    /// only in that row. This is the weight of proposing `j_new` features
    /// for one observation (G&G new-dish step / the paper's Poisson(α/N)
    /// proposal). O((K+j)³ + (K+j)²·D) — no N factor thanks to the cache.
    pub fn candidate_loglik_aug(
        &self,
        z_row: &[f64],
        x_row: &[f64],
        j_new: usize,
        lg: &LinGauss,
    ) -> f64 {
        if j_new == 0 {
            return self.candidate_loglik(z_row, x_row, lg);
        }
        let k = self.k();
        if k == 0 {
            // Closed form (perf fast path — §Perf L3-1). With no existing
            // features, M″ = 1_j 1_jᵀ + r·I_j has eigenvalues (j + r) and
            // r (multiplicity j−1), and E″ rows all equal x′, so
            //   log|M″|  = ln(j + r) + (j−1)·ln r
            //   tr(M″⁻¹G″) = (x′·x′)·Σ_ij M″⁻¹_ij = (x′·x′)·j/(j + r).
            // This is the overwhelmingly common case on p′ (K* = 0) and
            // turns the per-row K_new weights into O(D).
            let j = j_new as f64;
            let r = self.ratio;
            let xx: f64 = x_row.iter().map(|v| v * v).sum();
            let logdet = (j + r).ln() + (j - 1.0) * r.ln();
            let tr_quad = xx * j / (j + r);
            return collapsed_loglik_terms(
                self.n, self.d, j_new, lg.sigma_x, lg.sigma_a,
                logdet, self.tr_xx, tr_quad,
            );
        }
        let kj = k + j_new;
        // M″ = [[ZᵀZ + z′z′ᵀ + rI ,  z′ᵀ 1ᵀ ],
        //       [ 1 z′            ,  1_{j×j} + r I_j ]]
        let mut m = Mat::zeros(kj, kj);
        for i in 0..k {
            for j in 0..k {
                m[(i, j)] = self.ztz[(i, j)] + z_row[i] * z_row[j];
            }
        }
        for i in 0..k {
            for j in k..kj {
                m[(i, j)] = z_row[i];
                m[(j, i)] = z_row[i];
            }
        }
        for i in k..kj {
            for j in k..kj {
                m[(i, j)] = 1.0;
            }
        }
        m.add_diag(self.ratio);
        let Some(ch) = Cholesky::new(&m) else {
            return f64::NAN; // ztz drifted non-PD — caller refreshes
        };
        // E″ = [E + z′ᵀ x′ ; rows of x′]
        let mut e = Mat::zeros(kj, self.d);
        for i in 0..k {
            let src = self.e.row(i);
            let dst = e.row_mut(i);
            for (t, (&ev, &xv)) in dst.iter_mut().zip(src.iter().zip(x_row)) {
                *t = ev + z_row[i] * xv;
            }
        }
        for i in k..kj {
            e.row_mut(i).copy_from_slice(x_row);
        }
        let w = ch.solve_mat(&e);
        let tr_quad = e.dot(&w);
        collapsed_loglik_terms(
            self.n, self.d, kj, lg.sigma_x, lg.sigma_a,
            ch.logdet(), self.tr_xx, tr_quad,
        )
    }

    /// All augmented candidates j = 0..=jmax in ONE pass (perf fast path,
    /// §Perf L3-3). Equivalent to calling [`Self::candidate_loglik_aug`]
    /// for each j (pinned by tests) but via the Schur complement of the
    /// arrow-structured M″, sharing the O(K² + KD) work across j:
    ///
    /// with w = M⁻¹z′, δ = 1 + z′ᵀw, u = w/δ, E₁ = E + z′ᵀx′, v = E₁ᵀu:
    ///   log|M″|   = log|M| + ln δ + (j−1)·ln r + ln(r + j/δ)
    ///   tr(M″⁻¹G″) = T₁ + c_j·‖v − x′‖²,   c_j = j/(r + j/δ)
    /// where T₁ is the j = 0 quadratic (the candidate_loglik value).
    pub fn candidate_loglik_aug_batch(
        &self,
        z_row: &[f64],
        x_row: &[f64],
        jmax: usize,
        lg: &LinGauss,
    ) -> Vec<f64> {
        let k = self.k();
        let r = self.ratio;
        // --- shared O(K² + KD) prefix (j = 0 candidate quantities) ---
        let w = self.minv.matvec(z_row);
        let ztw: f64 = z_row.iter().zip(&w).map(|(a, b)| a * b).sum();
        let denom = 1.0 + ztw;
        if !(denom > 0.0) || !denom.is_finite() {
            // poisoned SM denominator: return NaN weights so the sweep
            // can refresh-and-retry instead of drawing from garbage
            return vec![f64::NAN; jmax + 1];
        }
        let logdet1 = self.logdet + denom.ln();
        // c = E x′ᵀ, s = x′·x′  (as in candidate_loglik)
        let mut c = vec![0.0; k];
        for i in 0..k {
            let erow = self.e.row(i);
            c[i] = erow.iter().zip(x_row).map(|(a, b)| a * b).sum();
        }
        let xx: f64 = x_row.iter().map(|v| v * v).sum();
        let tr_mg = self.minv.dot(&self.g);
        let wc: f64 = w.iter().zip(&c).map(|(a, b)| a * b).sum();
        let tr_mgp = tr_mg + 2.0 * wc + xx * ztw;
        let gw = self.g.matvec(&w);
        let wgw: f64 = w.iter().zip(&gw).map(|(a, b)| a * b).sum();
        let wgpw = wgw + 2.0 * ztw * wc + xx * ztw * ztw;
        let t1 = tr_mgp - wgpw / denom;
        // v = E₁ᵀ u = (Eᵀw + (z′ᵀw)·x′)/δ; we only need ‖v − x′‖².
        let mut v_minus_x2 = 0.0;
        for (jdim, &xj) in x_row.iter().enumerate() {
            let mut etw = 0.0;
            for (i, &wi) in w.iter().enumerate() {
                if wi != 0.0 {
                    etw += self.e[(i, jdim)] * wi;
                }
            }
            let vj = (etw + ztw * xj) / denom;
            v_minus_x2 += (vj - xj) * (vj - xj);
        }
        // --- per-j O(1) tail ---
        (0..=jmax)
            .map(|j_new| {
                let j = j_new as f64;
                let (logdet, tr_quad) = if j_new == 0 {
                    (logdet1, t1)
                } else {
                    let cj = j / (r + j / denom);
                    (
                        logdet1 + (j - 1.0) * r.ln() + (r + j / denom).ln(),
                        t1 + cj * v_minus_x2,
                    )
                };
                collapsed_loglik_terms(
                    self.n, self.d, k + j_new, lg.sigma_x, lg.sigma_a,
                    logdet, self.tr_xx, tr_quad,
                )
            })
            .collect()
    }

    /// Predictive log P(x_row | z_row, X₋, Z₋) with A marginalised against
    /// the *current* cache state (which must already exclude the row):
    /// x ~ N(z w E, σ_X²(1 + zᵀM⁻¹z) I_D). This is the Doshi-Velez
    /// "accelerated" form of the same conditional — O(K² + KD), no G.
    pub fn predictive_loglik(&self, z_row: &[f64], x_row: &[f64], lg: &LinGauss) -> f64 {
        let w = self.minv.matvec(z_row);
        let ztw: f64 = z_row.iter().zip(&w).map(|(a, b)| a * b).sum();
        if !(1.0 + ztw > 0.0) || !ztw.is_finite() {
            return f64::NAN; // drift poisoned 1 + zᵀM⁻¹z — caller refreshes
        }
        let var = lg.sigma_x * lg.sigma_x * (1.0 + ztw);
        let d = self.d;
        let mut rss = 0.0;
        for j in 0..d {
            let mut mean = 0.0;
            for (i, &wi) in w.iter().enumerate() {
                if wi != 0.0 {
                    mean += wi * self.e[(i, j)];
                }
            }
            let r = x_row[j] - mean;
            rss += r * r;
        }
        -0.5 * d as f64 * (LN_2PI + var.ln()) - rss / (2.0 * var)
    }

    /// Full rebuild (drift control / fallback after a singular rank-1
    /// update). Callers MUST pass the current `lg.ratio()` — the cache's
    /// M = ZᵀZ + ratio·I is only consistent with likelihood evaluations
    /// whose `LinGauss` has the same ratio. Together with
    /// [`Self::reset_data`] (new data ⇒ inherent O(NKD)) this is the
    /// only O(N·…) path; the Z-side warm-cache operations below never
    /// need it.
    pub fn refresh(&mut self, x: &Mat, z: &Mat, ratio: f64) {
        *self = Self::new(x, z, ratio);
    }

    /// [`Self::refresh`] from a [`FeatureState`] — bit-identical to the
    /// dense rebuild for either kernel, without densifying Z.
    pub fn refresh_from_state(&mut self, x: &Mat, z: &FeatureState, ratio: f64) {
        *self = Self::from_state(x, z, ratio);
    }

    /// Collapsed log P(X | Z) under a *proposal* `lg` whose ridge ratio
    /// r′ differs from the cache's: factorise M′ = ZᵀZ + r′·I from the
    /// **cached** ZᵀZ and take tr(M′⁻¹G) = ‖L′⁻¹E‖²_F from the cached E
    /// — O(K³ + K²D), no N factor, no `z.to_mat()`. Returns the
    /// evaluation plus the fresh M′ factor; a σ-MH acceptance hands it
    /// to [`Self::adopt`] so even acceptance costs nothing N-dependent.
    /// Rejection discards it — rejection is free.
    ///
    /// `None` if M′ fails to factorise (cannot happen for finite ZᵀZ and
    /// r′ > 0; the caller treats it as a rejected proposal).
    pub fn loglik_at_ratio(&self, lg: &LinGauss) -> Option<RatioEval> {
        let ratio = lg.ratio();
        let mut m = self.ztz.clone();
        m.add_diag(ratio);
        let ch = Cholesky::new(&m)?;
        let logdet = ch.logdet();
        // tr(M′⁻¹G) = tr(M′⁻¹EEᵀ) = ‖L′⁻¹E‖²_F — forward substitutions
        // only (O(K²D)); the explicit O(K³) inverse is deferred to
        // `adopt`, so rejected proposals never pay it.
        let k = self.k();
        let mut col = vec![0.0; k];
        let mut tr_quad = 0.0;
        for j in 0..self.d {
            for (i, c) in col.iter_mut().enumerate() {
                *c = self.e[(i, j)];
            }
            let y = ch.forward(&col);
            tr_quad += y.iter().map(|v| v * v).sum::<f64>();
        }
        let loglik = collapsed_loglik_terms(
            self.n, self.d, k, lg.sigma_x, lg.sigma_a,
            logdet, self.tr_xx, tr_quad,
        );
        Some(RatioEval { loglik, ratio, chol: ch, logdet })
    }

    /// Adopt the M′ machinery of an accepted [`Self::loglik_at_ratio`]
    /// evaluation: the cache now lives at the proposal's ridge. The
    /// O(K³) inverse is built here — acceptance-only — from the factor
    /// the proposal already paid for. Also a drift reset for the M side,
    /// since M′ came from the exact ZᵀZ.
    pub fn adopt(&mut self, eval: RatioEval) {
        debug_assert_eq!(eval.chol.factor().rows(), self.k(), "adopt across resize");
        self.minv = eval.chol.inverse();
        self.chol = UCholesky::from_cholesky(eval.chol);
        self.logdet = eval.logdet;
        self.ratio = eval.ratio;
    }

    /// Append `j` brand-new feature columns that are empty in the cached
    /// Z (the row that will hold them is inserted afterwards via
    /// [`Self::insert_row`]). All statistics extend exactly:
    /// ZᵀZ and G grow block-diagonally by zeros, E by zero rows,
    /// M by r·I_j — so M⁻¹ gains a (1/r)·I_j block and the factor a
    /// √r·I_j block. O((K+j)² + jD) copying; no X or Z access.
    pub fn append_empty_features(&mut self, j: usize) {
        if j == 0 {
            return;
        }
        let k = self.k();
        let kj = k + j;
        let mut ztz = Mat::zeros(kj, kj);
        ztz.paste(&self.ztz);
        self.ztz = ztz;
        let mut minv = Mat::zeros(kj, kj);
        minv.paste(&self.minv);
        for i in k..kj {
            minv[(i, i)] = 1.0 / self.ratio;
        }
        self.minv = minv;
        let mut g = Mat::zeros(kj, kj);
        g.paste(&self.g);
        self.g = g;
        let mut e = Mat::zeros(kj, self.d);
        e.paste(&self.e);
        self.e = e;
        self.chol.grow(j, self.ratio);
        self.logdet = self.chol.logdet();
    }

    /// Drop every feature column not listed in `keep` (ascending original
    /// indices — the order [`crate::model::state::FeatureState::compact`]
    /// returns). Dropped columns must be empty in the cached Z, so the
    /// compacted ZᵀZ/E/G are exactly the retained submatrices; M is then
    /// refactorised from the (exact) compacted ZᵀZ — O(K³ + K²D), no N
    /// factor, and a free drift reset for the M machinery. Returns false
    /// if the refactorisation fails (caller refreshes).
    #[must_use]
    pub fn retain_features(&mut self, keep: &[usize]) -> bool {
        let kk = keep.len();
        debug_assert!(keep.windows(2).all(|w| w[0] < w[1]));
        let ztz = Mat::from_fn(kk, kk, |i, j| self.ztz[(keep[i], keep[j])]);
        let mut m = ztz.clone();
        m.add_diag(self.ratio);
        let Some(ch) = Cholesky::new(&m) else {
            return false;
        };
        let e = Mat::from_fn(kk, self.d, |i, j| self.e[(keep[i], j)]);
        let g = Mat::from_fn(kk, kk, |i, j| self.g[(keep[i], keep[j])]);
        self.e = e;
        self.g = g;
        self.ztz = ztz;
        self.minv = ch.inverse();
        self.logdet = ch.logdet();
        self.chol = UCholesky::from_cholesky(ch);
        true
    }

    /// The borrowed data matrix changed under an unchanged Z (the tail
    /// sampler's situation: instantiated sweeps rewrote the residuals
    /// between sub-iterations). Recompute the X-side statistics
    /// (E = ZᵀX, G = EEᵀ, ‖X‖²) in O(NKD + K²D) and refactorise the M
    /// machinery from the exact cached ZᵀZ (O(K³) — trivial next to the
    /// E recompute). The refactorisation makes a carried cache exactly
    /// as drift-free as the full per-sweep rebuild it replaces, while
    /// still skipping the O(NK²) gram. Returns false if the
    /// refactorisation fails (caller rebuilds from scratch).
    #[must_use]
    pub fn reset_data(&mut self, x: &Mat, z: &Mat) -> bool {
        debug_assert_eq!(z.cols(), self.k(), "Z changed shape — refresh instead");
        self.reset_data_with(x, z.t_matmul(x))
    }

    /// [`Self::reset_data`] from a [`FeatureState`] — the packed E = ZᵀX
    /// accumulates in the same row order as the dense kernel, so the
    /// refreshed statistics are bit-identical either way.
    #[must_use]
    pub fn reset_data_from_state(&mut self, x: &Mat, z: &FeatureState) -> bool {
        debug_assert_eq!(z.k(), self.k(), "Z changed shape — refresh instead");
        self.reset_data_with(x, z.t_matmul(x))
    }

    fn reset_data_with(&mut self, x: &Mat, e: Mat) -> bool {
        debug_assert_eq!(x.rows(), self.n, "data row count changed");
        debug_assert_eq!(x.cols(), self.d, "data dim changed");
        let mut m = self.ztz.clone();
        m.add_diag(self.ratio);
        let Some(ch) = Cholesky::new(&m) else {
            return false;
        };
        self.e = e;
        self.g = self.e.matmul(&self.e.transpose());
        self.tr_xx = x.frob2();
        self.minv = ch.inverse();
        self.logdet = ch.logdet();
        self.chol = UCholesky::from_cholesky(ch);
        true
    }

    #[inline]
    pub fn ratio(&self) -> f64 {
        self.ratio
    }

    fn rank1_gram(&mut self, v: &[f64], s: f64) {
        let k = self.k();
        for i in 0..k {
            if v[i] == 0.0 {
                continue;
            }
            let vi = s * v[i];
            let row = self.ztz.row_mut(i);
            for (j, &vj) in v.iter().enumerate() {
                row[j] += vi * vj;
            }
        }
    }

    /// E ← E + s·vᵀ x_row, and G updated consistently.
    fn rank1_e(&mut self, v: &[f64], x_row: &[f64], s: f64) {
        let k = self.k();
        // G update needs old E: G′ = G + s(vᵀ(xEᵀ) + (Exᵀ)v) + s²(x·x) vvᵀ
        let mut c = vec![0.0; k];
        for i in 0..k {
            let erow = self.e.row(i);
            c[i] = erow.iter().zip(x_row).map(|(a, b)| a * b).sum();
        }
        let xx: f64 = x_row.iter().map(|t| t * t).sum();
        for i in 0..k {
            let gi = self.g.row_mut(i);
            for j in 0..k {
                gi[j] += s * (v[i] * c[j] + c[i] * v[j]) + s * s * xx * v[i] * v[j];
            }
        }
        for (i, &vi) in v.iter().enumerate() {
            if vi == 0.0 {
                continue;
            }
            let erow = self.e.row_mut(i);
            for (t, &xv) in erow.iter_mut().zip(x_row) {
                *t += s * vi * xv;
            }
        }
    }

    fn maybe_symmetrize(&mut self) {
        self.updates += 1;
        if self.updates % 512 == 0 {
            symmetrize(&mut self.minv);
            symmetrize(&mut self.g);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::state::Kernel;
    use crate::rng::Pcg64;
    use crate::testutil::collapsed_problem as problem;

    #[test]
    fn from_state_matches_dense_constructor_bitwise() {
        let (x, z, lg) = problem(30, 5, 7, 3);
        let mut st = FeatureState::from_mat(&z);
        for kernel in [Kernel::Scalar, Kernel::Packed] {
            st.set_kernel(kernel);
            let dense = CollapsedCache::new(&x, &z, lg.ratio());
            let from_st = CollapsedCache::from_state(&x, &st, lg.ratio());
            assert!(dense.ztz.max_abs_diff(&from_st.ztz) == 0.0, "{kernel:?} ztz");
            assert!(dense.e.max_abs_diff(&from_st.e) == 0.0, "{kernel:?} e");
            assert!(dense.g.max_abs_diff(&from_st.g) == 0.0, "{kernel:?} g");
            assert!(dense.minv.max_abs_diff(&from_st.minv) == 0.0, "{kernel:?} minv");
            assert_eq!(dense.loglik(&lg).to_bits(), from_st.loglik(&lg).to_bits());

            // and the reset_data path: perturb X, both refresh routes agree
            let mut x2 = x.clone();
            for v in x2.as_mut_slice().iter_mut() {
                *v *= 1.25;
            }
            let mut a = dense.clone();
            let mut b = from_st.clone();
            assert!(a.reset_data(&x2, &z));
            assert!(b.reset_data_from_state(&x2, &st));
            assert!(a.e.max_abs_diff(&b.e) == 0.0, "{kernel:?} reset e");
            assert_eq!(a.loglik(&lg).to_bits(), b.loglik(&lg).to_bits());

            let mut c = dense.clone();
            c.refresh_from_state(&x2, &st, lg.ratio());
            assert!(a.e.max_abs_diff(&c.e) == 0.0, "{kernel:?} refresh e");
        }
    }

    #[test]
    fn row_loglik_matches_full() {
        let (x, z, lg) = problem(20, 4, 6, 1);
        let mut rng = Pcg64::new(2);
        let a = Mat::from_fn(4, 6, |_, _| rng.normal());
        let total: f64 = (0..20)
            .map(|i| lg.row_loglik(x.row(i), &z.row(i).to_vec(), &a))
            .sum();
        assert!((total - lg.loglik(&x, &z, &a)).abs() < 1e-8);
    }

    #[test]
    fn cache_loglik_matches_fresh() {
        let (x, z, lg) = problem(30, 5, 7, 3);
        let cache = CollapsedCache::new(&x, &z, lg.ratio());
        assert!((cache.loglik(&lg) - lg.collapsed_loglik(&x, &z)).abs() < 1e-7);
    }

    #[test]
    fn remove_insert_roundtrip() {
        let (x, z, lg) = problem(25, 4, 5, 4);
        let mut cache = CollapsedCache::new(&x, &z, lg.ratio());
        let before = cache.loglik(&lg);
        let zr = z.row(7).to_vec();
        let xr = x.row(7).to_vec();
        assert!(cache.remove_row(&zr, &xr));
        assert!(cache.insert_row(&zr, &xr));
        assert!((cache.loglik(&lg) - before).abs() < 1e-7);
    }

    #[test]
    fn candidate_matches_fresh_rebuild() {
        let (x, z, lg) = problem(25, 4, 5, 5);
        let mut cache = CollapsedCache::new(&x, &z, lg.ratio());
        let row = 11;
        let zr = z.row(row).to_vec();
        let xr = x.row(row).to_vec();
        assert!(cache.remove_row(&zr, &xr));
        // candidate: flip bit 2 of the row
        let mut zc = zr.clone();
        zc[2] = 1.0 - zc[2];
        let got = cache.candidate_loglik(&zc, &xr, &lg);
        let mut z2 = z.clone();
        z2[(row, 2)] = zc[2];
        let want = lg.collapsed_loglik(&x, &z2);
        assert!((got - want).abs() < 1e-6, "got={got} want={want}");
    }

    #[test]
    fn candidate_with_unchanged_row_matches_current() {
        let (x, z, lg) = problem(20, 3, 4, 6);
        let mut cache = CollapsedCache::new(&x, &z, lg.ratio());
        let zr = z.row(0).to_vec();
        let xr = x.row(0).to_vec();
        let before = cache.loglik(&lg);
        assert!(cache.remove_row(&zr, &xr));
        let got = cache.candidate_loglik(&zr, &xr, &lg);
        assert!((got - before).abs() < 1e-7);
    }

    #[test]
    fn long_sweep_stays_consistent() {
        let (x, z, lg) = problem(40, 6, 8, 7);
        let mut zdyn = z.clone();
        let mut cache = CollapsedCache::new(&x, &zdyn, lg.ratio());
        let mut rng = Pcg64::new(8);
        for step in 0..300 {
            let i = step % 40;
            let zr = zdyn.row(i).to_vec();
            let xr = x.row(i).to_vec();
            if !cache.remove_row(&zr, &xr) {
                cache.refresh(&x, &zdyn, lg.ratio());
                continue;
            }
            let mut znew = zr.clone();
            let kflip = (step * 5) % 6;
            if rng.bernoulli(0.5) {
                znew[kflip] = 1.0 - znew[kflip];
            }
            assert!(cache.insert_row(&znew, &xr));
            for (j, &v) in znew.iter().enumerate() {
                zdyn[(i, j)] = v;
            }
        }
        let fresh = lg.collapsed_loglik(&x, &zdyn);
        assert!((cache.loglik(&lg) - fresh).abs() < 1e-5,
                "drift: {} vs {}", cache.loglik(&lg), fresh);
    }

    #[test]
    fn aug_closed_form_matches_general_path_at_k0() {
        // the K*=0 fast path must agree with a fresh dense rebuild
        let mut rng = Pcg64::new(30);
        let n = 15;
        let d = 6;
        let x = Mat::from_fn(n, d, |_, _| rng.normal());
        let lg = LinGauss::new(0.4, 1.3);
        let z_empty = Mat::zeros(n, 0);
        let mut cache = CollapsedCache::new(&x, &z_empty, lg.ratio());
        let row = 3;
        let xr = x.row(row).to_vec();
        assert!(cache.remove_row(&[], &xr));
        for j in 1..=4usize {
            let got = cache.candidate_loglik_aug(&[], &xr, j, &lg);
            let mut z2 = Mat::zeros(n, j);
            for c in 0..j {
                z2[(row, c)] = 1.0;
            }
            let want = lg.collapsed_loglik(&x, &z2);
            assert!((got - want).abs() < 1e-7, "j={j}: {got} vs {want}");
        }
    }

    #[test]
    fn aug_batch_matches_per_j_general_path() {
        for (n, k, d, seed) in [(20, 3, 5, 40), (15, 1, 8, 41), (25, 5, 4, 42)] {
            let (x, z, lg) = problem(n, k, d, seed);
            let mut cache = CollapsedCache::new(&x, &z, lg.ratio());
            let row = 2;
            let mut zr = z.row(row).to_vec();
            let xr = x.row(row).to_vec();
            assert!(cache.remove_row(&zr, &xr));
            zr[0] = 1.0 - zr[0]; // arbitrary candidate row
            let batch = cache.candidate_loglik_aug_batch(&zr, &xr, 4, &lg);
            for (j, &got) in batch.iter().enumerate() {
                let want = cache.candidate_loglik_aug(&zr, &xr, j, &lg);
                assert!(
                    (got - want).abs() < 1e-7 * want.abs().max(1.0),
                    "n={n} k={k} j={j}: batch {got} vs dense {want}"
                );
            }
        }
    }

    #[test]
    fn aug_batch_matches_at_k0() {
        let mut rng = Pcg64::new(43);
        let x = Mat::from_fn(12, 6, |_, _| rng.normal());
        let lg = LinGauss::new(0.4, 1.3);
        let mut cache = CollapsedCache::new(&x, &Mat::zeros(12, 0), lg.ratio());
        let xr = x.row(5).to_vec();
        assert!(cache.remove_row(&[], &xr));
        let batch = cache.candidate_loglik_aug_batch(&[], &xr, 3, &lg);
        for (j, &got) in batch.iter().enumerate() {
            let want = cache.candidate_loglik_aug(&[], &xr, j, &lg);
            assert!((got - want).abs() < 1e-8, "j={j}: {got} vs {want}");
        }
    }

    #[test]
    fn aug_candidate_matches_fresh_rebuild() {
        let (x, z, lg) = problem(20, 3, 5, 20);
        let mut cache = CollapsedCache::new(&x, &z, lg.ratio());
        let row = 4;
        let zr = z.row(row).to_vec();
        let xr = x.row(row).to_vec();
        assert!(cache.remove_row(&zr, &xr));
        for j_new in 0..4usize {
            let got = cache.candidate_loglik_aug(&zr, &xr, j_new, &lg);
            // fresh: Z with j_new extra singleton columns active in `row`
            let mut z2 = Mat::zeros(20, 3 + j_new);
            for i in 0..20 {
                for j in 0..3 {
                    z2[(i, j)] = z[(i, j)];
                }
            }
            for j in 0..j_new {
                z2[(row, 3 + j)] = 1.0;
            }
            let want = lg.collapsed_loglik(&x, &z2);
            assert!((got - want).abs() < 1e-6, "j={j_new}: {got} vs {want}");
        }
    }

    #[test]
    fn predictive_equals_marginal_ratio() {
        // P(x_n | z_n, rest) = P(X | Z) / P(X_-n | Z_-n): the predictive
        // form and the joint-ratio form must agree.
        let (x, z, lg) = problem(15, 3, 4, 21);
        let mut cache = CollapsedCache::new(&x, &z, lg.ratio());
        let row = 9;
        let zr = z.row(row).to_vec();
        let xr = x.row(row).to_vec();
        assert!(cache.remove_row(&zr, &xr));
        // joint with row present at candidate zc, minus joint without row
        let mut zc = zr.clone();
        zc[1] = 1.0 - zc[1];
        let with = cache.candidate_loglik(&zc, &xr, &lg);
        // marginal of X without row n: build from scratch on the submatrix
        let idx: Vec<usize> = (0..15).filter(|&i| i != row).collect();
        let xs = Mat::from_fn(14, 4, |i, j| x[(idx[i], j)]);
        let zs = Mat::from_fn(14, 3, |i, j| z[(idx[i], j)]);
        let without = lg.collapsed_loglik(&xs, &zs);
        let want = with - without;
        let got = cache.predictive_loglik(&zc, &xr, &lg);
        assert!((got - want).abs() < 1e-6, "{got} vs {want}");
    }

    #[test]
    fn loglik_at_ratio_matches_oracle() {
        let (x, z, _) = problem(35, 5, 6, 22);
        let lg0 = LinGauss::new(0.5, 1.1);
        let cache = CollapsedCache::new(&x, &z, lg0.ratio());
        // evaluate at a different ridge than the cache was built with
        let prop = LinGauss::new(0.8, 0.9);
        let eval = cache.loglik_at_ratio(&prop).unwrap();
        let want = prop.collapsed_loglik(&x, &z);
        assert!(
            (eval.loglik - want).abs() < 1e-9 * want.abs().max(1.0),
            "{} vs {}",
            eval.loglik,
            want
        );
    }

    #[test]
    fn adopt_makes_cache_live_at_new_ratio() {
        let (x, z, _) = problem(30, 4, 5, 23);
        let lg0 = LinGauss::new(0.5, 1.1);
        let mut cache = CollapsedCache::new(&x, &z, lg0.ratio());
        let prop = LinGauss::new(0.7, 1.3);
        let eval = cache.loglik_at_ratio(&prop).unwrap();
        cache.adopt(eval);
        assert_eq!(cache.ratio(), prop.ratio());
        // the adopted cache must behave exactly like a fresh one at the
        // proposal's ratio, including under further rank-1 edits
        let fresh = CollapsedCache::new(&x, &z, prop.ratio());
        assert!((cache.loglik(&prop) - fresh.loglik(&prop)).abs() < 1e-8);
        let zr = z.row(3).to_vec();
        let xr = x.row(3).to_vec();
        assert!(cache.remove_row(&zr, &xr));
        let mut zc = zr.clone();
        zc[1] = 1.0 - zc[1];
        let got = cache.candidate_loglik(&zc, &xr, &prop);
        let mut z2 = z.clone();
        z2[(3, 1)] = zc[1];
        let want = prop.collapsed_loglik(&x, &z2);
        assert!((got - want).abs() < 1e-6, "{got} vs {want}");
    }

    #[test]
    fn append_empty_then_insert_matches_fresh() {
        // grow-by-singletons without touching X or Z: remove a row,
        // append j empty columns, insert the row with the new bits set —
        // must equal a from-scratch cache on the grown Z.
        let (x, z, lg) = problem(25, 3, 5, 24);
        let row = 6;
        for j_new in 1..=3usize {
            let mut cache = CollapsedCache::new(&x, &z, lg.ratio());
            let zr = z.row(row).to_vec();
            let xr = x.row(row).to_vec();
            assert!(cache.remove_row(&zr, &xr));
            cache.append_empty_features(j_new);
            let mut z_ext = zr.clone();
            z_ext.extend(std::iter::repeat(1.0).take(j_new));
            assert!(cache.insert_row(&z_ext, &xr));
            let mut z2 = Mat::zeros(25, 3 + j_new);
            z2.paste(&z);
            for t in 0..j_new {
                z2[(row, 3 + t)] = 1.0;
            }
            let want = lg.collapsed_loglik(&x, &z2);
            let got = cache.loglik(&lg);
            assert!((got - want).abs() < 1e-6, "j={j_new}: {got} vs {want}");
        }
    }

    #[test]
    fn retain_features_drops_empty_columns_exactly() {
        // build Z with two columns we then empty out through the cache,
        // compact, and compare against a fresh cache on the submatrix
        let (x, z, lg) = problem(20, 5, 4, 25);
        let mut zdyn = z.clone();
        let mut cache = CollapsedCache::new(&x, &zdyn, lg.ratio());
        for dead in [1usize, 3] {
            for i in 0..20 {
                if zdyn[(i, dead)] != 0.0 {
                    let zr: Vec<f64> = (0..5).map(|j| zdyn[(i, j)]).collect();
                    let xr = x.row(i).to_vec();
                    assert!(cache.remove_row(&zr, &xr));
                    zdyn[(i, dead)] = 0.0;
                    let zr2: Vec<f64> = (0..5).map(|j| zdyn[(i, j)]).collect();
                    assert!(cache.insert_row(&zr2, &xr));
                }
            }
        }
        let keep = [0usize, 2, 4];
        assert!(cache.retain_features(&keep));
        let zsub = Mat::from_fn(20, 3, |i, j| zdyn[(i, keep[j])]);
        let want = lg.collapsed_loglik(&x, &zsub);
        let got = cache.loglik(&lg);
        assert!((got - want).abs() < 1e-6, "{got} vs {want}");
        // and the compacted cache keeps working under rank-1 edits
        let zr = zsub.row(2).to_vec();
        let xr = x.row(2).to_vec();
        assert!(cache.remove_row(&zr, &xr));
        assert!(cache.insert_row(&zr, &xr));
        assert!((cache.loglik(&lg) - want).abs() < 1e-6);
    }

    #[test]
    fn reset_data_tracks_new_x_same_z() {
        let (x, z, lg) = problem(30, 4, 6, 26);
        let mut cache = CollapsedCache::new(&x, &z, lg.ratio());
        // the "residuals" change between tail sweeps, Z does not
        let mut rng = Pcg64::new(27);
        let x2 = Mat::from_fn(30, 6, |_, _| rng.normal());
        assert!(cache.reset_data(&x2, &z));
        let want = lg.collapsed_loglik(&x2, &z);
        assert!((cache.loglik(&lg) - want).abs() < 1e-7);
    }

    #[test]
    fn apost_mean_solves_normal_equations() {
        let (x, z, lg) = problem(40, 5, 6, 9);
        let ztz = z.gram();
        let ztx = z.t_matmul(&x);
        let mean = lg.apost_mean(&ztz, &ztx);
        // M mean = ZtX
        let mut m = ztz.clone();
        m.add_diag(lg.ratio());
        assert!(m.matmul(&mean).max_abs_diff(&ztx) < 1e-9);
    }

    #[test]
    fn apost_sample_mean_converges() {
        let (x, z, lg) = problem(60, 3, 2, 10);
        let ztz = z.gram();
        let ztx = z.t_matmul(&x);
        let want = lg.apost_mean(&ztz, &ztx);
        let mut rng = Pcg64::new(11);
        let mut acc = Mat::zeros(3, 2);
        let reps = 3000;
        for _ in 0..reps {
            acc.add_assign(&lg.apost_sample(&ztz, &ztx, &mut rng));
        }
        acc.scale(1.0 / reps as f64);
        assert!(acc.max_abs_diff(&want) < 0.05);
    }

    #[test]
    fn collapsed_prefers_true_structure() {
        // collapsed marginal should rank the generating Z above a shuffled Z
        let (x, z, lg) = problem(50, 4, 10, 12);
        let mut rng = Pcg64::new(13);
        let zbad = Mat::from_fn(50, 4, |_, _| if rng.bernoulli(0.4) { 1.0 } else { 0.0 });
        assert!(lg.collapsed_loglik(&x, &z) > lg.collapsed_loglik(&x, &zbad));
    }
}
