//! The linear-Gaussian IBP latent feature model (paper §2).
//!
//! * [`state::FeatureState`] — the dynamic binary matrix Z with maintained
//!   column counts.
//! * [`lingauss`] — uncollapsed and collapsed likelihoods, the incremental
//!   [`lingauss::CollapsedCache`], and the A-posterior.
//! * [`ibp`] — the IBP prior and the conjugate hyper-parameter
//!   conditionals (α, π, σ_X, σ_A).

pub mod ibp;
pub mod lingauss;
pub mod missing;
pub mod state;

pub use lingauss::{CollapsedCache, LinGauss, RatioEval};
pub use state::FeatureState;

/// Full global model state shared between samplers and the coordinator:
/// everything the master broadcasts after a global step.
#[derive(Clone, Debug)]
pub struct GlobalParams {
    /// Loadings for the instantiated features (K⁺ × D).
    pub a: crate::linalg::Mat,
    /// Feature weights π_k (len K⁺).
    pub pi: Vec<f64>,
    pub lg: LinGauss,
    pub alpha: f64,
}

impl GlobalParams {
    pub fn k(&self) -> usize {
        self.pi.len()
    }

    /// logit(π_k) vector in the f32 layout the AOT kernels consume;
    /// entries past K⁺ (padding) get −1e30 ⇒ never activated.
    pub fn prior_logit_padded(&self, k_pad: usize) -> Vec<f32> {
        let mut out = vec![-1e30f32; k_pad];
        for (k, &p) in self.pi.iter().enumerate() {
            let p = p.clamp(1e-12, 1.0 - 1e-12);
            out[k] = (p.ln() - (-p).ln_1p()) as f32;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    #[test]
    fn prior_logit_padding() {
        let gp = GlobalParams {
            a: Mat::zeros(2, 3),
            pi: vec![0.5, 0.9],
            lg: LinGauss::new(0.5, 1.0),
            alpha: 1.0,
        };
        let v = gp.prior_logit_padded(4);
        assert_eq!(v.len(), 4);
        assert!((v[0] - 0.0).abs() < 1e-6);
        assert!((v[1] - (0.9f64 / 0.1).ln() as f32).abs() < 1e-4);
        assert!(v[2] < -1e29 && v[3] < -1e29);
    }
}
