//! Indian Buffet Process prior mathematics and the conjugate hyper-
//! parameter conditionals sampled by the master each global iteration
//! (paper §3: "Sample posterior values for parameters A, σ_X², σ_A², π_k
//! and hyperparameter α").

use crate::model::state::FeatureState;
use crate::rng::distributions::{ln_factorial, ln_gamma};
use crate::rng::Pcg64;

/// H_N = Σ_{i=1}^{N} 1/i.
pub fn harmonic(n: usize) -> f64 {
    (1..=n).map(|i| 1.0 / i as f64).sum()
}

/// Log IBP prior of a feature matrix in left-ordered-form equivalence
/// class (G&G 2005 Eq. 14):
///
/// ```text
/// P([Z]) = α^{K⁺} / (Π_h K_h!) · exp(−α H_N)
///          · Π_k (N − m_k)! (m_k − 1)! / N!
/// ```
pub fn log_prior(state: &FeatureState, alpha: f64) -> f64 {
    let n = state.n();
    let k = state.k();
    let mut lp = k as f64 * alpha.ln() - alpha * harmonic(n);
    for &kh in &state.column_histogram() {
        lp -= ln_factorial(kh as u64);
    }
    for &mk in state.m() {
        assert!(mk > 0, "log_prior expects compacted Z (no empty columns)");
        lp += ln_factorial((n - mk) as u64) + ln_factorial(mk as u64 - 1)
            - ln_factorial(n as u64);
    }
    lp
}

/// α | K⁺ ~ Gamma(a₀ + K⁺, rate b₀ + H_N), with the paper-standard
/// Gamma(1, 1) hyperprior.
pub fn sample_alpha(k_plus: usize, n: usize, rng: &mut Pcg64) -> f64 {
    sample_alpha_prior(k_plus, n, 1.0, 1.0, rng)
}

pub fn sample_alpha_prior(
    k_plus: usize,
    n: usize,
    a0: f64,
    b0: f64,
    rng: &mut Pcg64,
) -> f64 {
    let shape = a0 + k_plus as f64;
    let rate = b0 + harmonic(n);
    rng.gamma(shape, 1.0 / rate)
}

/// π_k | Z ~ Beta(m_k, 1 + N − m_k) for every instantiated feature
/// (the K → ∞ limit of Beta(α/K + m_k, 1 + N − m_k)).
pub fn sample_pi(m: &[usize], n: usize, rng: &mut Pcg64) -> Vec<f64> {
    m.iter()
        .map(|&mk| {
            debug_assert!(mk > 0 && mk <= n);
            rng.beta(mk as f64, 1.0 + (n - mk) as f64)
        })
        .collect()
}

/// σ_X² | X, Z, A ~ InvGamma(a₀ + ND/2, b₀ + RSS/2).
pub fn sample_sigma_x(
    rss: f64,
    n: usize,
    d: usize,
    a0: f64,
    b0: f64,
    rng: &mut Pcg64,
) -> f64 {
    let shape = a0 + (n * d) as f64 / 2.0;
    let scale = b0 + rss / 2.0;
    rng.inv_gamma(shape, scale).sqrt()
}

/// σ_A² | A ~ InvGamma(a₀ + KD/2, b₀ + ‖A‖²/2).
pub fn sample_sigma_a(
    a_frob2: f64,
    k: usize,
    d: usize,
    a0: f64,
    b0: f64,
    rng: &mut Pcg64,
) -> f64 {
    let shape = a0 + (k * d) as f64 / 2.0;
    let scale = b0 + a_frob2 / 2.0;
    rng.inv_gamma(shape, scale).sqrt()
}

/// log Poisson(k; λ) pmf.
pub fn log_poisson_pmf(k: usize, lambda: f64) -> f64 {
    if lambda <= 0.0 {
        return if k == 0 { 0.0 } else { f64::NEG_INFINITY };
    }
    k as f64 * lambda.ln() - lambda - ln_factorial(k as u64)
}

/// log Gamma pdf (shape-rate) — used by diagnostics.
pub fn log_gamma_pdf(x: f64, shape: f64, rate: f64) -> f64 {
    if x <= 0.0 {
        return f64::NEG_INFINITY;
    }
    shape * rate.ln() - ln_gamma(shape) + (shape - 1.0) * x.ln() - rate * x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::sample_ibp;
    use crate::linalg::Mat;

    #[test]
    fn harmonic_values() {
        assert!((harmonic(1) - 1.0).abs() < 1e-12);
        assert!((harmonic(4) - (1.0 + 0.5 + 1.0 / 3.0 + 0.25)).abs() < 1e-12);
    }

    #[test]
    fn log_prior_single_feature_single_row() {
        // N=1, one feature: P = α e^{-α} (Poisson(1;α) for the first
        // customer taking exactly one dish).
        let z = Mat::from_vec(1, 1, vec![1.0]);
        let st = FeatureState::from_mat(&z);
        let alpha = 1.7f64;
        let want = alpha.ln() - alpha; // (N-m)!(m-1)!/N! = 0!0!/1! = 1
        assert!((log_prior(&st, alpha) - want).abs() < 1e-10);
    }

    #[test]
    fn log_prior_matches_restaurant_frequencies() {
        // Empirical check: among IBP samples with N=2, compare relative
        // frequency of two specific configurations against the prior ratio.
        let mut rng = Pcg64::new(1);
        let alpha = 1.0;
        let mut count_a = 0usize; // Z = [[1],[1]] (one shared dish)
        let mut count_b = 0usize; // Z = [[1],[0]] (first-only dish)
        let reps = 60_000;
        for _ in 0..reps {
            let (rows, m) = sample_ibp(2, alpha, &mut rng);
            if m.len() == 1 && rows[0] == vec![1] {
                if rows[1] == vec![1] {
                    count_a += 1;
                } else {
                    count_b += 1;
                }
            }
        }
        let za = FeatureState::from_mat(&Mat::from_vec(2, 1, vec![1.0, 1.0]));
        let zb = FeatureState::from_mat(&Mat::from_vec(2, 1, vec![1.0, 0.0]));
        let want_ratio = (log_prior(&za, alpha) - log_prior(&zb, alpha)).exp();
        let got_ratio = count_a as f64 / count_b as f64;
        assert!(
            (got_ratio - want_ratio).abs() < 0.15 * want_ratio,
            "got {got_ratio}, want {want_ratio}"
        );
    }

    #[test]
    fn alpha_posterior_moments() {
        let mut rng = Pcg64::new(2);
        let (k_plus, n) = (6, 100);
        let shape = 1.0 + k_plus as f64;
        let rate = 1.0 + harmonic(n);
        let want_mean = shape / rate;
        let mean: f64 = (0..50_000)
            .map(|_| sample_alpha(k_plus, n, &mut rng))
            .sum::<f64>()
            / 50_000.0;
        assert!((mean - want_mean).abs() < 0.02, "mean={mean} want={want_mean}");
    }

    #[test]
    fn pi_posterior_mean() {
        let mut rng = Pcg64::new(3);
        let n = 50;
        let m = vec![10usize, 40];
        let mut acc = [0.0f64; 2];
        let reps = 30_000;
        for _ in 0..reps {
            let pi = sample_pi(&m, n, &mut rng);
            acc[0] += pi[0];
            acc[1] += pi[1];
        }
        // E Beta(m, 1+N-m) = m / (m + 1 + N - m) = m / (N+1)
        assert!((acc[0] / reps as f64 - 10.0 / 51.0).abs() < 0.005);
        assert!((acc[1] / reps as f64 - 40.0 / 51.0).abs() < 0.005);
    }

    #[test]
    fn sigma_posteriors_concentrate_on_truth() {
        let mut rng = Pcg64::new(4);
        // huge "data" ⇒ posterior ≈ sqrt(rss / (n d))
        let (n, d) = (5000, 20);
        let true_sx = 0.4;
        let rss = true_sx * true_sx * (n * d) as f64;
        let mut acc = 0.0;
        for _ in 0..2000 {
            acc += sample_sigma_x(rss, n, d, 1.0, 1.0, &mut rng);
        }
        assert!((acc / 2000.0 - true_sx).abs() < 0.01);
    }

    #[test]
    fn poisson_pmf_normalises() {
        let lambda = 2.3;
        let total: f64 = (0..60).map(|k| log_poisson_pmf(k, lambda).exp()).sum();
        assert!((total - 1.0).abs() < 1e-10);
    }
}
