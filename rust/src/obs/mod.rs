//! Zero-dependency runtime observability: phase spans, sampler-health
//! counters, and a per-run report that **cannot perturb the chain**.
//!
//! Everything in this module is always compiled and runtime-toggled
//! (`RunConfig::obs` / `--obs off|counters|full`). The non-perturbation
//! contract — pinned by `rust/tests/obs_equivalence.rs` — is structural:
//!
//! * **no RNG** — nothing here ever touches a [`crate::rng::Pcg64`]; the
//!   per-stream draw tallies read a passive counter the engine maintains
//!   unconditionally;
//! * **no ordering effects** — aggregation is a process-global table of
//!   atomics (`Ordering::Relaxed`); instrumented sites only *add* to it,
//!   they never branch sampler control flow on it, and no message,
//!   checkpoint byte, or merge order depends on the level;
//! * **no allocation on the hot path** — histograms are fixed arrays of
//!   power-of-two buckets; the only locked structure (the K⁺ trajectory)
//!   is touched once per global iteration on the master thread.
//!
//! Levels: `Off` (every probe is a load + branch), `Counters` (atomic
//! counters + K⁺ trajectory), `Full` (adds span timers / histograms).
//!
//! The registry is process-global on purpose: probes live in layers with
//! no configuration path (the thread pool, the collapsed cache fallbacks),
//! and a run owns the process. Concurrent chains in one process (e.g.
//! parallel tests) share the table — tallies may interleave, chains never
//! can, because nothing reads the table back into sampler state.

use std::fmt::Write as _;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicU8, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::config::json::Json;

// ---------------------------------------------------------------------------
// level
// ---------------------------------------------------------------------------

/// Runtime observability level (`--obs`, config key `obs`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ObsLevel {
    /// Probes compile to a relaxed load + untaken branch.
    #[default]
    Off,
    /// Sampler-health counters and the K⁺ trajectory.
    Counters,
    /// Counters plus phase span timers (histograms).
    Full,
}

impl ObsLevel {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "off" => Ok(ObsLevel::Off),
            "counters" => Ok(ObsLevel::Counters),
            "full" => Ok(ObsLevel::Full),
            other => bail!("unknown obs level '{other}' (off|counters|full)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ObsLevel::Off => "off",
            ObsLevel::Counters => "counters",
            ObsLevel::Full => "full",
        }
    }
}

// ---------------------------------------------------------------------------
// span & counter taxonomies
// ---------------------------------------------------------------------------

/// Phase spans (histogram slots). The table in docs/ARCHITECTURE.md
/// §Observability maps each name to its instrumentation site.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Span {
    /// Worker: one uncollapsed `par_sweep_rows` call over the shard.
    WorkerSweep,
    /// Worker p′: one collapsed tail sub-iteration (`TailProposer::sweep`).
    WorkerTail,
    /// Worker: per-iteration suff-stat assembly (combine + gram + ZᵀX).
    WorkerSuffstats,
    /// Master: blocking wait for one worker's `Summary` in the gather.
    MasterGatherWait,
    /// Master: merge of the P summaries into the extended column space.
    MasterMerge,
    /// Master: promote/demote/compact bookkeeping of the global step.
    MasterPromote,
    /// Master: the A-posterior re-solve + π/σ/α draws.
    MasterApost,
    /// Master: encoding + sending one iteration's P broadcasts.
    MasterBroadcast,
    /// Master: measured broadcast→all-summaries round-trip of one
    /// iteration's gather. Wall clock, observability only — the VClock's
    /// simulated comm model stays the vtime source, so chain bytes never
    /// depend on this measurement.
    MasterGatherRtt,
    /// Pool: caller-side dispatch of one fork-join (send all chunks).
    PoolDispatch,
    /// Pool: a job's wait between enqueue and first instruction.
    PoolQueueWait,
    /// Pool: a lane's busy time executing one chunk.
    PoolLaneBusy,
    /// Serve: one `PredictEngine` query end-to-end (impute / reconstruct /
    /// heldout-loglik).
    ServeQuery,
    /// Serve: samples per `accumulate_samples` wave (unit: count, not
    /// seconds).
    ServeWaveSize,
    /// Serial collapsed sampler: one full row sweep (`CollapsedGibbs`).
    CollapsedRowSweep,
}

/// What a span's histogram values mean.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Unit {
    /// Recorded in nanoseconds, reported in seconds.
    Seconds,
    /// Raw magnitudes (e.g. wave sizes).
    Count,
}

pub const N_SPANS: usize = 15;

impl Span {
    pub const ALL: [Span; N_SPANS] = [
        Span::WorkerSweep,
        Span::WorkerTail,
        Span::WorkerSuffstats,
        Span::MasterGatherWait,
        Span::MasterMerge,
        Span::MasterPromote,
        Span::MasterApost,
        Span::MasterBroadcast,
        Span::MasterGatherRtt,
        Span::PoolDispatch,
        Span::PoolQueueWait,
        Span::PoolLaneBusy,
        Span::ServeQuery,
        Span::ServeWaveSize,
        Span::CollapsedRowSweep,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Span::WorkerSweep => "worker.sweep",
            Span::WorkerTail => "worker.tail",
            Span::WorkerSuffstats => "worker.suffstats",
            Span::MasterGatherWait => "master.gather_wait",
            Span::MasterMerge => "master.merge",
            Span::MasterPromote => "master.promote_compact",
            Span::MasterApost => "master.apost_solve",
            Span::MasterBroadcast => "master.broadcast",
            Span::MasterGatherRtt => "master.gather_rtt",
            Span::PoolDispatch => "pool.dispatch",
            Span::PoolQueueWait => "pool.queue_wait",
            Span::PoolLaneBusy => "pool.lane_busy",
            Span::ServeQuery => "serve.query",
            Span::ServeWaveSize => "serve.wave_size",
            Span::CollapsedRowSweep => "collapsed.row_sweep",
        }
    }

    pub fn unit(self) -> Unit {
        match self {
            Span::ServeWaveSize => Unit::Count,
            _ => Unit::Seconds,
        }
    }

    fn index(self) -> usize {
        Span::ALL.iter().position(|s| *s == self).unwrap()
    }
}

/// Sampler-health counters — events that previously vanished silently.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Counter {
    /// σ-MH proposals (collapsed sampler; 2 per `mh_sigmas` call).
    SigmaMhProposed,
    /// σ-MH acceptances.
    SigmaMhAccepted,
    /// Tail K_new Metropolis–Hastings proposals with j′ > 0.
    TailMhProposed,
    /// Tail K_new MH acceptances.
    TailMhAccepted,
    /// Successful `CollapsedCache` rank-1 row removes/inserts.
    CacheRank1Ops,
    /// Rank-1 update lost positive-definiteness (remove/insert/retain
    /// returned false) → full refresh fallback. PR 4's silent slow path.
    CacheSingularFallback,
    /// Sherman–Morrison denominator went NaN → rebuild-and-retry. PR 4's
    /// silent guard.
    CacheNanRetry,
    /// Tail features promoted into the instantiated set.
    FeaturesPromoted,
    /// Instantiated features demoted back to the collapsed tail.
    FeaturesDemoted,
    /// Dead (m_k = 0) features dropped at compaction.
    FeaturesCompacted,
    /// Engine draws on the master stream.
    RngDrawsMaster,
    /// Engine draws on worker streams (summed over P).
    RngDrawsWorker,
    /// Engine draws on per-block sweep substreams (summed over blocks).
    RngDrawsBlock,
    /// Engine draws on serve per-sample query streams.
    RngDrawsServe,
    /// `PredictEngine` queries answered.
    ServeQueries,
    /// Transport bytes the master sent to workers (frame payloads; all
    /// transports, so `channel` runs report the same number a socket run
    /// moves over the wire).
    NetBytesSent,
    /// Transport bytes the master received from workers.
    NetBytesReceived,
}

pub const N_COUNTERS: usize = 17;

impl Counter {
    pub const ALL: [Counter; N_COUNTERS] = [
        Counter::SigmaMhProposed,
        Counter::SigmaMhAccepted,
        Counter::TailMhProposed,
        Counter::TailMhAccepted,
        Counter::CacheRank1Ops,
        Counter::CacheSingularFallback,
        Counter::CacheNanRetry,
        Counter::FeaturesPromoted,
        Counter::FeaturesDemoted,
        Counter::FeaturesCompacted,
        Counter::RngDrawsMaster,
        Counter::RngDrawsWorker,
        Counter::RngDrawsBlock,
        Counter::RngDrawsServe,
        Counter::ServeQueries,
        Counter::NetBytesSent,
        Counter::NetBytesReceived,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Counter::SigmaMhProposed => "sigma_mh.proposed",
            Counter::SigmaMhAccepted => "sigma_mh.accepted",
            Counter::TailMhProposed => "tail_mh.proposed",
            Counter::TailMhAccepted => "tail_mh.accepted",
            Counter::CacheRank1Ops => "cache.rank1_ops",
            Counter::CacheSingularFallback => "cache.singular_fallbacks",
            Counter::CacheNanRetry => "cache.nan_retries",
            Counter::FeaturesPromoted => "features.promoted",
            Counter::FeaturesDemoted => "features.demoted",
            Counter::FeaturesCompacted => "features.compacted",
            Counter::RngDrawsMaster => "rng_draws.master",
            Counter::RngDrawsWorker => "rng_draws.worker",
            Counter::RngDrawsBlock => "rng_draws.block",
            Counter::RngDrawsServe => "rng_draws.serve",
            Counter::ServeQueries => "serve.queries",
            Counter::NetBytesSent => "net.bytes_sent",
            Counter::NetBytesReceived => "net.bytes_received",
        }
    }

    fn index(self) -> usize {
        Counter::ALL.iter().position(|c| *c == self).unwrap()
    }
}

/// Once-per-run warning classes (satellite: surface silent degradation).
/// Warnings fire at **every** obs level — numerical trouble should be
/// visible without opting in — but at most once per class per run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Warn {
    CacheSingular,
    CacheNan,
    /// A replica chain's kept trace points stopped changing entirely
    /// (`metrics::online::STALL_WINDOW` bit-identical points in a row).
    ChainStalled,
    /// A replica chain produced a non-finite trace scalar.
    ChainDiverged,
}

pub const N_WARNS: usize = 4;

impl Warn {
    fn index(self) -> usize {
        match self {
            Warn::CacheSingular => 0,
            Warn::CacheNan => 1,
            Warn::ChainStalled => 2,
            Warn::ChainDiverged => 3,
        }
    }
}

// ---------------------------------------------------------------------------
// histogram
// ---------------------------------------------------------------------------

/// Power-of-two log-spaced buckets: bucket `i` covers `[2^i, 2^{i+1})`
/// (nanoseconds for [`Unit::Seconds`] spans), `0` lands in bucket 0.
pub const N_BUCKETS: usize = 64;

/// Bucket index for a recorded value: `floor(log2(v))`, with 0 → 0.
#[inline]
pub fn bucket_index(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        (63 - v.leading_zeros()) as usize
    }
}

struct Hist {
    buckets: [AtomicU64; N_BUCKETS],
    count: AtomicU64,
    total: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
}

// const-item trick: a `const` can be repeated into an array even though
// `AtomicU64` is not `Copy` (each repetition re-evaluates the const).
#[allow(clippy::declare_interior_mutable_const)]
const ATOMIC_ZERO: AtomicU64 = AtomicU64::new(0);

impl Hist {
    const fn new() -> Self {
        Self {
            buckets: [ATOMIC_ZERO; N_BUCKETS],
            count: AtomicU64::new(0),
            total: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
        }
    }

    fn record(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.total.fetch_add(v, Ordering::Relaxed);
        self.min.fetch_min(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Ordering::Relaxed);
        }
        self.count.store(0, Ordering::Relaxed);
        self.total.store(0, Ordering::Relaxed);
        self.min.store(u64::MAX, Ordering::Relaxed);
        self.max.store(0, Ordering::Relaxed);
    }

    fn snapshot(&self) -> HistSnap {
        let mut buckets = [0u64; N_BUCKETS];
        for (i, b) in self.buckets.iter().enumerate() {
            buckets[i] = b.load(Ordering::Relaxed);
        }
        HistSnap {
            count: self.count.load(Ordering::Relaxed),
            total: self.total.load(Ordering::Relaxed),
            min: self.min.load(Ordering::Relaxed),
            max: self.max.load(Ordering::Relaxed),
            buckets,
        }
    }
}

/// A plain-data histogram snapshot (what `RunReport` carries).
#[derive(Clone, Debug, PartialEq)]
pub struct HistSnap {
    pub count: u64,
    pub total: u64,
    pub min: u64,
    pub max: u64,
    pub buckets: [u64; N_BUCKETS],
}

impl HistSnap {
    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.total as f64 / self.count as f64
        }
    }

    /// Quantile estimate: walk the buckets to the one where the
    /// cumulative count crosses `q·count` and return its geometric
    /// midpoint `2^i · √2` (exact min/max clamp the ends).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let target = (q * self.count as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum >= target {
                let mid = (1u64 << i) as f64 * std::f64::consts::SQRT_2;
                return mid.clamp(self.min as f64, self.max as f64);
            }
        }
        self.max as f64
    }
}

// ---------------------------------------------------------------------------
// the global registry
// ---------------------------------------------------------------------------

struct Registry {
    level: AtomicU8,
    counters: [AtomicU64; N_COUNTERS],
    hists: [Hist; N_SPANS],
    warned: [AtomicBool; N_WARNS],
    /// (iter, K⁺) trajectory; master-thread only, once per global step.
    k_series: Mutex<Series>,
    /// Convergence-diagnostics snapshot (`metrics::online::DiagSummary`
    /// as JSON), set by the multi-chain runner at trace cadence so
    /// checkpoint-cadence report flushes carry the latest numbers.
    /// `None` outside `--chains` runs — the report key is optional.
    diag: Mutex<Option<Json>>,
}

/// Deterministic bounded series: keep every `stride`-th offered point,
/// doubling the stride when the buffer fills (same discipline as
/// `serve::SampleReservoir` — no RNG).
struct Series {
    points: Vec<(u64, u64)>,
    stride: u64,
    offered: u64,
}

const SERIES_CAP: usize = 2048;

impl Series {
    const fn new() -> Self {
        Self { points: Vec::new(), stride: 1, offered: 0 }
    }

    fn push(&mut self, iter: u64, k: u64) {
        if self.offered % self.stride == 0 {
            if self.points.len() == SERIES_CAP {
                // kept points sit at multiples of the old stride in offer
                // order; keeping the even-indexed half leaves exactly the
                // multiples of the doubled stride
                let mut i = 0usize;
                self.points.retain(|_| {
                    let keep = i % 2 == 0;
                    i += 1;
                    keep
                });
                self.stride *= 2;
            }
            if self.offered % self.stride == 0 {
                self.points.push((iter, k));
            }
        }
        self.offered += 1;
    }
}

#[allow(clippy::declare_interior_mutable_const)]
const ATOMIC_FALSE: AtomicBool = AtomicBool::new(false);
#[allow(clippy::declare_interior_mutable_const)]
const HIST_NEW: Hist = Hist::new();

static REG: Registry = Registry {
    level: AtomicU8::new(0),
    counters: [ATOMIC_ZERO; N_COUNTERS],
    hists: [HIST_NEW; N_SPANS],
    warned: [ATOMIC_FALSE; N_WARNS],
    k_series: Mutex::new(Series::new()),
    diag: Mutex::new(None),
};

/// Set the process-wide level (runner does this from `RunConfig::obs`).
pub fn set_level(level: ObsLevel) {
    REG.level.store(level as u8, Ordering::Relaxed);
}

pub fn level() -> ObsLevel {
    match REG.level.load(Ordering::Relaxed) {
        0 => ObsLevel::Off,
        1 => ObsLevel::Counters,
        _ => ObsLevel::Full,
    }
}

/// Are counters live? (`Counters` or `Full`.)
#[inline]
pub fn counting() -> bool {
    REG.level.load(Ordering::Relaxed) >= 1
}

/// Are span timers live? (`Full` only.)
#[inline]
pub fn timing() -> bool {
    REG.level.load(Ordering::Relaxed) >= 2
}

/// Zero every counter, histogram, warning latch, and the K⁺ trajectory
/// (the level is left alone). Called at run start so each run segment
/// reports its own numbers.
pub fn reset() {
    for c in &REG.counters {
        c.store(0, Ordering::Relaxed);
    }
    for h in &REG.hists {
        h.reset();
    }
    for w in &REG.warned {
        w.store(false, Ordering::Relaxed);
    }
    let mut s = REG.k_series.lock().unwrap();
    *s = Series::new();
    *REG.diag.lock().unwrap() = None;
}

/// Publish (or clear) the convergence-diagnostics section of the obs
/// report. The multi-chain runner calls this with the latest
/// `DiagSummary` JSON after each kept trace point; every subsequent
/// report capture/flush includes it under the optional `diag` key.
pub fn set_diag(diag: Option<Json>) {
    *REG.diag.lock().unwrap() = diag;
}

#[inline]
pub fn inc(c: Counter) {
    add(c, 1);
}

#[inline]
pub fn add(c: Counter, n: u64) {
    if counting() {
        REG.counters[c.index()].fetch_add(n, Ordering::Relaxed);
    }
}

/// Record a raw histogram value (wave sizes etc.); `Full` only.
#[inline]
pub fn record_value(s: Span, v: u64) {
    if timing() {
        REG.hists[s.index()].record(v);
    }
}

/// Record an already-measured duration into a span's histogram.
#[inline]
pub fn record_ns(s: Span, ns: u64) {
    if timing() {
        REG.hists[s.index()].record(ns);
    }
}

/// Record the K⁺ trajectory point for a global iteration (master thread,
/// once per step; `Counters` and up).
pub fn record_k(iter: u64, k: u64) {
    if counting() {
        REG.k_series.lock().unwrap().push(iter, k);
    }
}

/// Emit `msg` on stderr at most once per run per class, and always bump
/// the matching counter logic at the call site. Fires at every obs level.
pub fn warn_once(w: Warn, msg: &str) {
    if !REG.warned[w.index()].swap(true, Ordering::Relaxed) {
        eprintln!("pibp: warning: {msg} (further occurrences this run are counted, not printed; see --obs)");
    }
}

/// Crate-internal test gate: lib unit tests that flip the process-global
/// obs level (directly, or through `runner::run`, which sets it from the
/// config) serialise on this so concurrently running tests cannot stomp
/// each other's level mid-assertion. Production code never takes it.
#[cfg(test)]
pub(crate) fn test_level_gate() -> std::sync::MutexGuard<'static, ()> {
    static GATE: Mutex<()> = Mutex::new(());
    GATE.lock().unwrap_or_else(|e| e.into_inner())
}

/// RAII span timer: measures from construction to drop when the level is
/// `Full`, otherwise a no-op (one relaxed load). Dropping records into
/// the span's histogram — never anything else, so instrumented scopes
/// are observationally identical to uninstrumented ones.
pub struct SpanGuard {
    live: Option<(Span, Instant)>,
}

#[inline]
pub fn span(s: Span) -> SpanGuard {
    SpanGuard { live: if timing() { Some((s, Instant::now())) } else { None } }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some((s, t0)) = self.live.take() {
            let ns = t0.elapsed().as_nanos().min(u64::MAX as u128) as u64;
            REG.hists[s.index()].record(ns);
        }
    }
}

// ---------------------------------------------------------------------------
// report
// ---------------------------------------------------------------------------

const REPORT_VERSION: u64 = 1;

/// A plain-data capture of the registry: what `run_obs.json` serialises
/// and `pibp report` / the end-of-run table render.
#[derive(Clone, Debug)]
pub struct RunReport {
    pub level: ObsLevel,
    /// (span, snapshot) for every span, empty ones included.
    pub spans: Vec<(Span, HistSnap)>,
    /// (counter, value) for every counter.
    pub counters: Vec<(Counter, u64)>,
    /// Thinned (iter, K⁺) trajectory.
    pub k_trajectory: Vec<(u64, u64)>,
    /// Convergence diagnostics (multi-chain runs only; optional key).
    pub diag: Option<Json>,
}

impl RunReport {
    /// Snapshot the live registry.
    pub fn capture() -> Self {
        let spans = Span::ALL
            .iter()
            .map(|&s| (s, REG.hists[s.index()].snapshot()))
            .collect();
        let counters = Counter::ALL
            .iter()
            .map(|&c| (c, REG.counters[c.index()].load(Ordering::Relaxed)))
            .collect();
        let k_trajectory = REG.k_series.lock().unwrap().points.clone();
        let diag = REG.diag.lock().unwrap().clone();
        Self { level: level(), spans, counters, k_trajectory, diag }
    }

    /// `run_obs.json` schema (see docs/ARCHITECTURE.md §Observability):
    /// summary statistics only — raw buckets stay in-process.
    pub fn to_json(&self) -> Json {
        let spans = Json::Obj(
            self.spans
                .iter()
                .map(|(s, h)| {
                    let scale = match s.unit() {
                        Unit::Seconds => 1e-9,
                        Unit::Count => 1.0,
                    };
                    let stat = |v: f64| if v.is_finite() { v } else { 0.0 };
                    (
                        s.name().to_string(),
                        Json::obj(vec![
                            ("unit", Json::Str(match s.unit() {
                                Unit::Seconds => "seconds".into(),
                                Unit::Count => "count".into(),
                            })),
                            ("count", Json::Num(h.count as f64)),
                            ("total", Json::Num(stat(h.total as f64 * scale))),
                            (
                                "min",
                                Json::Num(if h.is_empty() {
                                    0.0
                                } else {
                                    h.min as f64 * scale
                                }),
                            ),
                            ("max", Json::Num(h.max as f64 * scale)),
                            ("mean", Json::Num(stat(h.mean() * scale))),
                            ("p50", Json::Num(stat(h.quantile(0.50) * scale))),
                            ("p99", Json::Num(stat(h.quantile(0.99) * scale))),
                        ]),
                    )
                })
                .collect(),
        );
        let counters = Json::Obj(
            self.counters
                .iter()
                .map(|(c, v)| (c.name().to_string(), Json::Num(*v as f64)))
                .collect(),
        );
        let k_iters: Vec<f64> = self.k_trajectory.iter().map(|(i, _)| *i as f64).collect();
        let k_vals: Vec<f64> = self.k_trajectory.iter().map(|(_, k)| *k as f64).collect();
        let mut doc = vec![
            ("version", Json::Num(REPORT_VERSION as f64)),
            ("level", Json::Str(self.level.name().into())),
            ("spans", spans),
            ("counters", counters),
            (
                "k_trajectory",
                Json::obj(vec![
                    ("iters", Json::arr_f64(&k_iters)),
                    ("k", Json::arr_f64(&k_vals)),
                ]),
            ),
        ];
        if let Some(d) = &self.diag {
            doc.push(("diag", d.clone()));
        }
        Json::obj(doc)
    }

    /// Capture the registry and write `run_obs.json` (atomic-ish: plain
    /// write — the file is diagnostic, not durable state).
    pub fn write(path: &Path) -> Result<()> {
        let report = RunReport::capture();
        std::fs::write(path, format!("{}\n", report.to_json()))
            .with_context(|| format!("writing obs report {}", path.display()))
    }

    /// Render the human-readable end-of-run table.
    pub fn render(&self) -> String {
        render_json(&self.to_json()).expect("self-produced report renders")
    }
}

fn fmt_quantity(v: f64, unit: &str) -> String {
    if !v.is_finite() {
        return "-".into();
    }
    if unit == "seconds" {
        if v >= 1.0 {
            format!("{v:.3}s")
        } else if v >= 1e-3 {
            format!("{:.3}ms", v * 1e3)
        } else if v >= 1e-6 {
            format!("{:.3}µs", v * 1e6)
        } else {
            format!("{:.0}ns", v * 1e9)
        }
    } else if v.fract() == 0.0 {
        format!("{}", v as u64)
    } else {
        format!("{v:.2}")
    }
}

/// Pretty-print a parsed `run_obs.json` (the `pibp report` command and
/// the end-of-run table share this renderer). Fails on a file that is
/// missing the schema's required keys — which is exactly the validation
/// the CI smoke wants.
pub fn render_json(doc: &Json) -> Result<String> {
    let version = doc
        .get("version")
        .and_then(|v| v.as_usize())
        .context("obs report: missing 'version'")?;
    if version as u64 != REPORT_VERSION {
        bail!("obs report: unsupported version {version}");
    }
    let level = doc
        .get("level")
        .and_then(|v| v.as_str())
        .context("obs report: missing 'level'")?;
    let spans = match doc.get("spans").context("obs report: missing 'spans'")? {
        Json::Obj(m) => m,
        _ => bail!("obs report: 'spans' is not an object"),
    };
    let counters = match doc.get("counters").context("obs report: missing 'counters'")? {
        Json::Obj(m) => m,
        _ => bail!("obs report: 'counters' is not an object"),
    };

    let mut out = String::new();
    let _ = writeln!(out, "obs report (level={level})");
    let _ = writeln!(
        out,
        "  {:<24} {:>10} {:>12} {:>12} {:>12} {:>12} {:>12}",
        "span", "count", "total", "mean", "p50", "p99", "max"
    );
    for (name, h) in spans {
        let count = h.get("count").and_then(|v| v.as_f64()).unwrap_or(0.0);
        if count == 0.0 {
            continue;
        }
        let unit = h.get("unit").and_then(|v| v.as_str()).unwrap_or("seconds");
        let g = |k: &str| h.get(k).and_then(|v| v.as_f64()).unwrap_or(f64::NAN);
        let _ = writeln!(
            out,
            "  {:<24} {:>10} {:>12} {:>12} {:>12} {:>12} {:>12}",
            name,
            count as u64,
            fmt_quantity(g("total"), unit),
            fmt_quantity(g("mean"), unit),
            fmt_quantity(g("p50"), unit),
            fmt_quantity(g("p99"), unit),
            fmt_quantity(g("max"), unit),
        );
    }
    let _ = writeln!(out, "  {:<24} {:>10}", "counter", "value");
    for (name, v) in counters {
        let v = v.as_f64().unwrap_or(0.0);
        if v == 0.0 {
            continue;
        }
        let _ = writeln!(out, "  {:<24} {:>10}", name, v as u64);
    }
    // derived health rates, when the raw numbers are present
    let rate = |num: &str, den: &str| -> Option<f64> {
        let n = counters.get(num)?.as_f64()?;
        let d = counters.get(den)?.as_f64()?;
        if d > 0.0 {
            Some(n / d)
        } else {
            None
        }
    };
    if let Some(r) = rate("sigma_mh.accepted", "sigma_mh.proposed") {
        let _ = writeln!(out, "  {:<24} {:>9.1}%", "sigma_mh accept rate", 100.0 * r);
    }
    if let Some(r) = rate("tail_mh.accepted", "tail_mh.proposed") {
        let _ = writeln!(out, "  {:<24} {:>9.1}%", "tail_mh accept rate", 100.0 * r);
    }
    if let Some(kt) = doc.get("k_trajectory") {
        let ks = kt.get("k").and_then(|v| v.as_arr()).unwrap_or(&[]);
        if let (Some(first), Some(last)) = (ks.first(), ks.last()) {
            let kmax = ks.iter().filter_map(|v| v.as_f64()).fold(0.0f64, f64::max);
            let _ = writeln!(
                out,
                "  {:<24} {} -> {} (max {})",
                "K+ trajectory",
                first.as_f64().unwrap_or(0.0) as u64,
                last.as_f64().unwrap_or(0.0) as u64,
                kmax as u64,
            );
        }
    }
    // optional convergence-diagnostics section (multi-chain runs)
    if let Some(diag) = doc.get("diag") {
        let chains = diag.get("chains").and_then(|v| v.as_usize()).unwrap_or(0);
        let points = diag.get("points").and_then(|v| v.as_usize()).unwrap_or(0);
        let _ = writeln!(
            out,
            "  diag: {chains} chain(s) × {points} kept trace point(s)"
        );
        if let Some(Json::Obj(quantities)) = diag.get("quantities") {
            let _ = writeln!(
                out,
                "  {:<24} {:>10} {:>12}",
                "diag quantity", "split-Rhat", "min ESS"
            );
            for (name, q) in quantities {
                let rhat = q
                    .get("rhat")
                    .and_then(|v| v.as_f64())
                    .map_or("-".to_string(), |r| format!("{r:.4}"));
                let min_ess = q
                    .get("ess")
                    .and_then(|v| v.as_arr())
                    .map(|es| {
                        es.iter()
                            .filter_map(Json::as_f64)
                            .fold(f64::INFINITY, f64::min)
                    })
                    .filter(|m| m.is_finite())
                    .map_or("-".to_string(), |m| format!("{m:.1}"));
                let _ = writeln!(out, "  {name:<24} {rhat:>10} {min_ess:>12}");
            }
        }
        let until = diag.get("until").and_then(|v| v.as_str()).unwrap_or("");
        if !until.is_empty() {
            match diag.get("stopped_at").and_then(|v| v.as_usize()) {
                Some(i) => {
                    let _ = writeln!(
                        out,
                        "  early stop '{until}' fired after {i} iterations"
                    );
                }
                None => {
                    let _ = writeln!(out, "  early stop '{until}' not triggered");
                }
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_edges() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 0);
        assert_eq!(bucket_index(2), 1);
        assert_eq!(bucket_index(3), 1);
        assert_eq!(bucket_index(4), 2);
        for k in 1..63u32 {
            let p = 1u64 << k;
            assert_eq!(bucket_index(p - 1), (k - 1) as usize, "2^{k}-1");
            assert_eq!(bucket_index(p), k as usize, "2^{k}");
            assert_eq!(bucket_index(p + 1), k as usize, "2^{k}+1");
        }
        assert_eq!(bucket_index(u64::MAX), 63);
    }

    #[test]
    fn hist_empty_and_single_point() {
        let h = Hist::new();
        let empty = h.snapshot();
        assert!(empty.is_empty());
        assert!(empty.mean().is_nan());
        assert!(empty.quantile(0.5).is_nan());
        h.record(1000);
        let one = h.snapshot();
        assert_eq!(one.count, 1);
        assert_eq!((one.min, one.max, one.total), (1000, 1000, 1000));
        // single point: every quantile collapses to it (clamped by
        // min/max, so the bucket-midpoint estimate is exact here)
        assert_eq!(one.quantile(0.5), 1000.0);
        assert_eq!(one.quantile(0.99), 1000.0);
    }

    #[test]
    fn hist_quantiles_are_monotone_and_bounded() {
        let h = Hist::new();
        for i in 1..=1000u64 {
            h.record(i * i); // values 1..1e6, log-spread
        }
        let s = h.snapshot();
        let p50 = s.quantile(0.5);
        let p99 = s.quantile(0.99);
        assert!(p50 <= p99, "p50={p50} p99={p99}");
        assert!(p50 >= s.min as f64 && p99 <= s.max as f64);
        // factor-2 buckets: the p50 estimate is within 2x of the true
        // median (500² = 250000)
        assert!(p50 >= 125_000.0 && p50 <= 500_000.0, "p50={p50}");
    }

    #[test]
    fn levels_gate_counters_and_spans() {
        let _g = test_level_gate();
        let prev = level();
        set_level(ObsLevel::Off);
        reset();
        inc(Counter::ServeQueries);
        record_ns(Span::ServeQuery, 100);
        {
            let _s = span(Span::WorkerSweep);
        }
        let r = RunReport::capture();
        assert!(r.counters.iter().all(|(_, v)| *v == 0));
        assert!(r.spans.iter().all(|(_, h)| h.is_empty()));

        set_level(ObsLevel::Counters);
        inc(Counter::ServeQueries);
        record_ns(Span::ServeQuery, 100);
        let r = RunReport::capture();
        // >= : other tests in this binary may legitimately count too while
        // the level is up — the registry is process-global by design
        assert!(counter_of(&r, Counter::ServeQueries) >= 1);
        assert!(r.spans.iter().all(|(_, h)| h.is_empty()), "counters level must not time");

        set_level(ObsLevel::Full);
        record_ns(Span::ServeQuery, 100);
        {
            let _s = span(Span::WorkerSweep);
        }
        let r = RunReport::capture();
        assert!(span_of(&r, Span::ServeQuery).count >= 1);
        assert!(span_of(&r, Span::WorkerSweep).count >= 1);

        reset();
        set_level(prev);
    }

    fn counter_of(r: &RunReport, c: Counter) -> u64 {
        r.counters.iter().find(|(x, _)| *x == c).unwrap().1
    }

    fn span_of(r: &RunReport, s: Span) -> HistSnap {
        r.spans.iter().find(|(x, _)| *x == s).unwrap().1.clone()
    }

    #[test]
    fn report_json_roundtrips_and_renders() {
        let _g = test_level_gate();
        let prev = level();
        set_level(ObsLevel::Full);
        reset();
        add(Counter::SigmaMhProposed, 10);
        add(Counter::SigmaMhAccepted, 3);
        record_ns(Span::MasterMerge, 2_000_000);
        record_value(Span::ServeWaveSize, 4);
        record_k(0, 5);
        record_k(1, 7);
        let r = RunReport::capture();
        let text = r.to_json().to_string();
        let doc = Json::parse(&text).unwrap();
        assert_eq!(doc.get("version").unwrap().as_usize().unwrap(), 1);
        assert_eq!(doc.get("level").unwrap().as_str().unwrap(), "full");
        let rendered = render_json(&doc).unwrap();
        assert!(rendered.contains("master.merge"), "{rendered}");
        assert!(rendered.contains("sigma_mh accept rate"), "{rendered}");
        assert!(rendered.contains("K+ trajectory"), "{rendered}");
        // required-key validation is what the CI smoke relies on
        assert!(render_json(&Json::obj(vec![("version", Json::Num(1.0))])).is_err());
        reset();
        set_level(prev);
    }

    #[test]
    fn diag_section_is_optional_but_renders_when_set() {
        let _g = test_level_gate();
        let prev = level();
        set_level(ObsLevel::Counters);
        reset();
        // without set_diag, the report has no diag key and renders fine
        let r = RunReport::capture();
        assert!(r.diag.is_none());
        assert!(r.to_json().get("diag").is_none());
        assert!(!r.render().contains("diag:"));
        // with set_diag, the key appears and the renderer shows it
        set_diag(Some(Json::obj(vec![
            ("chains", Json::Num(3.0)),
            ("points", Json::Num(12.0)),
            ("until", Json::Str("rhat<1.01".into())),
            ("stopped_at", Json::Null),
            (
                "quantities",
                Json::obj(vec![(
                    "heldout",
                    Json::obj(vec![
                        ("rhat", Json::Num(1.02)),
                        ("ess", Json::arr_f64(&[8.0, 9.5, 7.25])),
                    ]),
                )]),
            ),
        ])));
        let r = RunReport::capture();
        assert!(r.diag.is_some());
        let rendered = r.render();
        assert!(rendered.contains("diag: 3 chain(s)"), "{rendered}");
        assert!(rendered.contains("heldout"), "{rendered}");
        assert!(rendered.contains("1.0200"), "{rendered}");
        assert!(rendered.contains("7.2"), "{rendered}");
        assert!(rendered.contains("not triggered"), "{rendered}");
        // reset clears the slot
        reset();
        assert!(RunReport::capture().diag.is_none());
        set_level(prev);
    }

    #[test]
    fn warn_once_sets_the_latch() {
        // stderr can't be captured portably; pin the latch semantics:
        // after any number of calls the latch is set, so no further call
        // can print again until the next reset().
        warn_once(Warn::CacheNan, "test warning (expected once in test output)");
        warn_once(Warn::CacheNan, "MUST NOT PRINT");
        assert!(REG.warned[Warn::CacheNan.index()].load(Ordering::Relaxed));
    }

    #[test]
    fn series_thins_deterministically() {
        let mut s = Series::new();
        for i in 0..10_000u64 {
            s.push(i, i % 7);
        }
        assert!(s.points.len() <= SERIES_CAP);
        assert!(s.points.len() > SERIES_CAP / 4, "over-thinned: {}", s.points.len());
        // surviving iters are exactly the multiples of the final stride
        for (it, _) in &s.points {
            assert_eq!(it % s.stride, 0);
        }
        // deterministic: same input, same output
        let mut s2 = Series::new();
        for i in 0..10_000u64 {
            s2.push(i, i % 7);
        }
        assert_eq!(s.points, s2.points);
    }

    #[test]
    fn obs_level_parses() {
        assert_eq!(ObsLevel::parse("off").unwrap(), ObsLevel::Off);
        assert_eq!(ObsLevel::parse("counters").unwrap(), ObsLevel::Counters);
        assert_eq!(ObsLevel::parse("full").unwrap(), ObsLevel::Full);
        assert!(ObsLevel::parse("verbose").is_err());
        for l in [ObsLevel::Off, ObsLevel::Counters, ObsLevel::Full] {
            assert_eq!(ObsLevel::parse(l.name()).unwrap(), l);
        }
    }
}
