//! `pibp` — the launcher.
//!
//! ```text
//! pibp run    [--config c.json] [--set key=value]...   one experiment
//! pibp fig1   [--iters N] [--n N] [--out dir]          paper Figure 1
//! pibp fig2   [--iters N] [--n N] [--out dir]          paper Figure 2
//! pibp info   [--artifacts dir]                        artifact manifest
//! ```

use std::path::Path;

use anyhow::Result;

use pibp::cli::{flag, repeated, Cli, CommandSpec, Parsed};
use pibp::config::{RunConfig, SamplerKind};
use pibp::data::cambridge;
use pibp::metrics::Trace;
use pibp::runner;
use pibp::runtime::Manifest;
use pibp::viz;

fn spec() -> Cli {
    Cli {
        bin: "pibp",
        about: "Parallel MCMC for the Indian Buffet Process (Zhang, Dubey & Williamson 2017)",
        commands: vec![
            CommandSpec {
                name: "run",
                about: "run one experiment from a config (+ overrides)",
                flags: vec![
                    flag("config", "JSON config file ('' = defaults)", ""),
                    flag("threads", "intra-worker sweep threads T ('' = config value)", ""),
                    repeated("set", "override, e.g. --set processors=5"),
                ],
            },
            CommandSpec {
                name: "fig1",
                about: "reproduce Figure 1: held-out log P(X,Z) vs log time",
                flags: vec![
                    flag("iters", "iterations per sampler", "200"),
                    flag("n", "observations", "1000"),
                    flag("seed", "root seed", "0"),
                    flag("backend", "native|pjrt", "native"),
                    flag("threads", "intra-worker sweep threads T", "1"),
                    flag("out", "output directory", "results/fig1"),
                ],
            },
            CommandSpec {
                name: "fig2",
                about: "reproduce Figure 2: true vs posterior features",
                flags: vec![
                    flag("iters", "iterations per sampler", "150"),
                    flag("n", "observations", "1000"),
                    flag("seed", "root seed", "0"),
                    flag("out", "output directory", "results/fig2"),
                ],
            },
            CommandSpec {
                name: "info",
                about: "show the AOT artifact manifest",
                flags: vec![flag("artifacts", "artifacts directory", "artifacts")],
            },
        ],
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cli = spec();
    let parsed = match cli.parse(&args) {
        Ok(p) => p,
        Err(e) => {
            println!("{e}");
            std::process::exit(if args.iter().any(|a| a.contains("help")) { 0 } else { 2 });
        }
    };
    if let Err(e) = dispatch(&parsed) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(p: &Parsed) -> Result<()> {
    match p.command.as_str() {
        "run" => cmd_run(p),
        "fig1" => cmd_fig1(p),
        "fig2" => cmd_fig2(p),
        "info" => cmd_info(p),
        _ => unreachable!(),
    }
}

fn cmd_run(p: &Parsed) -> Result<()> {
    let mut cfg = match p.get("config") {
        Some("") | None => RunConfig::default(),
        Some(path) => RunConfig::from_file(Path::new(path))?,
    };
    // --threads beats the config file; an explicit --set still beats both
    match p.get("threads") {
        Some("") | None => {}
        Some(t) => cfg.apply("threads_per_worker", t)?,
    }
    for kv in p.get_list("set") {
        let (k, v) = kv
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("--set wants key=value, got '{kv}'"))?;
        cfg.apply(k, v)?;
    }
    println!(
        "pibp run: {} sampler={} P={} T={} iters={} backend={:?} seed={}",
        cfg.dataset, cfg.sampler.name(), cfg.processors,
        cfg.threads_per_worker, cfg.iters, cfg.backend, cfg.seed
    );
    let every = (cfg.iters / 20).max(1);
    let out = runner::run(&cfg, |i| {
        if i % every == 0 {
            print!(".");
            use std::io::Write;
            std::io::stdout().flush().ok();
        }
    })?;
    println!();
    report(&out.trace);
    let dir = Path::new(&cfg.out_dir);
    let csv = dir.join(format!("{}.csv", out.trace.label));
    out.trace.save_csv(&csv)?;
    println!("trace → {}", csv.display());
    if out.final_k > 0 {
        println!("\nposterior features (K={}):\n{}", out.final_k,
                 viz::render_features_ascii(&out.features));
    }
    Ok(())
}

fn fig_cfg(p: &Parsed) -> Result<RunConfig> {
    let mut cfg = RunConfig::default();
    cfg.iters = p.get_usize("iters")?;
    cfg.n = p.get_usize("n")?;
    cfg.seed = p.get("seed").unwrap_or("0").parse()?;
    if let Some(b) = p.get("backend") {
        cfg.apply("backend", b)?;
    }
    // fig2 has no --threads flag; fig1 defaults it to 1
    if let Some(t) = p.get("threads") {
        cfg.apply("threads_per_worker", t)?;
    }
    Ok(cfg)
}

fn cmd_fig1(p: &Parsed) -> Result<()> {
    let base = fig_cfg(p)?;
    let out_dir = p.get("out").unwrap_or("results/fig1").to_string();
    println!("Figure 1: held-out log P(X,Z) over log (virtual) time");
    println!("  dataset cambridge {}×36, {} iterations, L=5\n", base.n, base.iters);
    let mut traces: Vec<Trace> = Vec::new();
    // collapsed baseline
    {
        let mut cfg = base.clone();
        cfg.sampler = SamplerKind::Collapsed;
        println!("running collapsed…");
        traces.push(runner::run(&cfg, |_| {})?.trace);
    }
    for p_count in [1usize, 3, 5] {
        let mut cfg = base.clone();
        cfg.sampler = SamplerKind::Hybrid;
        cfg.processors = p_count;
        println!("running hybrid P={p_count}…");
        traces.push(runner::run(&cfg, |_| {})?.trace);
    }
    let dir = Path::new(&out_dir);
    for t in &traces {
        t.save_csv(&dir.join(format!("{}.csv", t.label)))?;
        report(t);
    }
    let refs: Vec<&Trace> = traces.iter().collect();
    println!("\n{}", viz::plot_traces(&refs, 76, 18, true));
    println!("traces → {out_dir}/*.csv  (plot: heldout vs log10(vtime_s))");
    Ok(())
}

fn cmd_fig2(p: &Parsed) -> Result<()> {
    let base = fig_cfg(p)?;
    let out_dir = p.get("out").unwrap_or("results/fig2").to_string();
    let dir = Path::new(&out_dir);
    // true features (top row of the paper's Figure 2)
    let truth = cambridge::true_features(base.k_true);
    viz::save_feature_grid(&dir.join("true_features.pgm"), &truth, 8)?;
    println!("true features:\n{}", viz::render_features_ascii(&truth));
    // collapsed posterior (middle row)
    let mut cfg = base.clone();
    cfg.sampler = SamplerKind::Collapsed;
    println!("running collapsed…");
    let out = runner::run(&cfg, |_| {})?;
    viz::save_feature_grid(&dir.join("collapsed_features.pgm"), &out.features, 8)?;
    println!("collapsed posterior (K={}):\n{}", out.final_k,
             viz::render_features_ascii(&out.features));
    // hybrid P=5 posterior (bottom row)
    let mut cfg = base.clone();
    cfg.sampler = SamplerKind::Hybrid;
    cfg.processors = 5;
    println!("running hybrid P=5…");
    let out = runner::run(&cfg, |_| {})?;
    viz::save_feature_grid(&dir.join("hybrid_p5_features.pgm"), &out.features, 8)?;
    println!("hybrid P=5 posterior (K={}):\n{}", out.final_k,
             viz::render_features_ascii(&out.features));
    println!("images → {out_dir}/*.pgm");
    Ok(())
}

fn cmd_info(p: &Parsed) -> Result<()> {
    let dir = p.get("artifacts").unwrap_or("artifacts");
    let m = Manifest::load(Path::new(dir))?;
    println!("artifacts in {dir}: {} entries", m.entries.len());
    println!("row buckets {:?}, feature buckets {:?}, dims {:?}", m.rows, m.feats, m.dims);
    for e in &m.entries {
        println!(
            "  {:<18} b={:<6} k={:<4} d={:<4} {}",
            e.name,
            e.b.map_or("-".into(), |b| b.to_string()),
            e.k, e.d, e.file
        );
    }
    Ok(())
}

fn report(t: &Trace) {
    let last = t.last().expect("trace non-empty");
    println!(
        "  {:<14} plateau={:.1}  final: heldout={:.1} K={} σx={:.3} α={:.2}  t={:.2}s(virtual)",
        t.label,
        t.plateau(0.25),
        last.heldout,
        last.k,
        last.sigma_x,
        last.alpha,
        last.vtime_s
    );
}
