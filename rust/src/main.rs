//! `pibp` — the launcher.
//!
//! ```text
//! pibp run      [--config c.json] [--set key=value]...   one experiment
//! pibp run      [--chains C] [--until rule]...           C replica chains + convergence diag
//! pibp resume   [--checkpoint f] [--set iters=N]...      continue a checkpointed run
//! pibp predict  [--checkpoint f] [--missing frac]...     query saved posterior samples
//! pibp diagnose [--trace f]... [--rhat-max x]            offline convergence verdict
//! pibp worker   [--connect addr]                         join a socket-transport run
//! pibp fig1     [--iters N] [--n N] [--out dir]          paper Figure 1
//! pibp fig2     [--iters N] [--n N] [--out dir]          paper Figure 2
//! pibp info     [--artifacts dir]                        artifact manifest
//! ```

use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Result};

use pibp::cli::{flag, repeated, switch, Cli, CommandSpec, Parsed};
use pibp::config::json::Json;
use pibp::config::{ObsLevel, RunConfig, SamplerKind};
use pibp::data::cambridge;
use pibp::linalg::Mat;
use pibp::metrics::Trace;
use pibp::model::missing::{missing_mse, Mask};
use pibp::obs;
use pibp::rng::{tags, Pcg64};
use pibp::runner;
use pibp::runtime::Manifest;
use pibp::serve::PredictEngine;
use pibp::snapshot::Checkpoint;
use pibp::viz;

fn spec() -> Cli {
    Cli {
        bin: "pibp",
        about: "Parallel MCMC for the Indian Buffet Process (Zhang, Dubey & Williamson 2017)",
        commands: vec![
            CommandSpec {
                name: "run",
                about: "run one experiment from a config (+ overrides)",
                flags: vec![
                    flag("config", "JSON config file ('' = defaults)", ""),
                    flag("threads", "intra-worker sweep threads T ('' = config value)", ""),
                    flag("obs", "observability level: off|counters|full ('' = config value)", ""),
                    flag("obs-out", "obs report path ('' = <out_dir>/run_obs.json)", ""),
                    flag("chains", "replica chains C for convergence diagnostics ('' = config value)", ""),
                    flag("until", "early-stop rule over the kept trace, e.g. rhat<1.01,ess>200", ""),
                    flag("trace-out", "export traces to this path (.csv|.json; chain c gets a .c{c} suffix)", ""),
                    repeated("set", "override, e.g. --set processors=5"),
                ],
            },
            CommandSpec {
                name: "resume",
                about: "continue a checkpointed run, bit-identical to an uninterrupted one",
                flags: vec![
                    flag("checkpoint", "checkpoint file written by a run with checkpoint_every",
                         "results/checkpoint.pibp"),
                    flag("threads", "intra-worker sweep threads T ('' = checkpointed value)", ""),
                    flag("obs", "observability level: off|counters|full ('' = checkpointed value)", ""),
                    flag("obs-out", "obs report path ('' = <out_dir>/run_obs.json)", ""),
                    repeated("set", "override, e.g. --set iters=2000 (chain-relevant keys must match)"),
                ],
            },
            CommandSpec {
                name: "predict",
                about: "batched posterior queries (imputation, reconstruction, held-out loglik) from a checkpoint",
                flags: vec![
                    flag("checkpoint", "checkpoint holding posterior samples (run with keep_samples=N)",
                         "results/checkpoint.pibp"),
                    flag("queries", "query rows as CSV ('' = the run's held-out split)", ""),
                    flag("rows", "cap on query rows (0 = all)", "0"),
                    flag("missing", "fraction of entries hidden for the imputation query", "0.25"),
                    flag("sweeps", "Gibbs sweeps per posterior sample for latent inference", "3"),
                    flag("seed", "query RNG seed (per-sample streams derive from it)", "0"),
                    flag("threads", "posterior-sample fan-out threads (persistent pool; never changes results)", "1"),
                    flag("obs", "observability level: off|counters|full", "off"),
                    flag("obs-out", "obs report path ('' = print only)", ""),
                ],
            },
            CommandSpec {
                name: "report",
                about: "pretty-print a run_obs.json observability report",
                flags: vec![
                    flag("file", "obs report written by a run with --obs", "run_obs.json"),
                ],
            },
            CommandSpec {
                name: "diagnose",
                about: "offline convergence verdict from exported chain traces (see run --trace-out)",
                flags: vec![
                    repeated("trace", "a chain's trace file (.csv or .json); pass one per chain, ≥2"),
                    flag("rhat-max", "split-R̂ pass threshold", "1.1"),
                    flag("ess-min", "per-chain ESS pass threshold (continuous quantities)", "50"),
                    flag("warmup-frac", "leading fraction of each trace discarded before scoring", "0.5"),
                    flag("threshold", "held-out level for time-to-threshold ('' = skip)", ""),
                    switch("strict", "exit 3 when the overall verdict is FAIL"),
                ],
            },
            CommandSpec {
                name: "worker",
                about: "connect to a master running with transport=uds|tcp and serve one shard",
                flags: vec![
                    flag("connect", "master address: a UDS socket path or host:port (tcp)", ""),
                ],
            },
            CommandSpec {
                name: "fig1",
                about: "reproduce Figure 1: held-out log P(X,Z) vs log time",
                flags: vec![
                    flag("iters", "iterations per sampler", "200"),
                    flag("n", "observations", "1000"),
                    flag("seed", "root seed", "0"),
                    flag("backend", "native|pjrt", "native"),
                    flag("threads", "intra-worker sweep threads T", "1"),
                    flag("out", "output directory", "results/fig1"),
                ],
            },
            CommandSpec {
                name: "fig2",
                about: "reproduce Figure 2: true vs posterior features",
                flags: vec![
                    flag("iters", "iterations per sampler", "150"),
                    flag("n", "observations", "1000"),
                    flag("seed", "root seed", "0"),
                    flag("out", "output directory", "results/fig2"),
                ],
            },
            CommandSpec {
                name: "info",
                about: "show the AOT artifact manifest",
                flags: vec![flag("artifacts", "artifacts directory", "artifacts")],
            },
        ],
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cli = spec();
    let parsed = match cli.parse(&args) {
        Ok(p) => p,
        Err(e) => {
            println!("{e}");
            std::process::exit(if args.iter().any(|a| a.contains("help")) { 0 } else { 2 });
        }
    };
    if let Err(e) = dispatch(&parsed) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(p: &Parsed) -> Result<()> {
    match p.command.as_str() {
        "run" => cmd_run(p),
        "resume" => cmd_resume(p),
        "predict" => cmd_predict(p),
        "report" => cmd_report(p),
        "diagnose" => cmd_diagnose(p),
        "worker" => cmd_worker(p),
        "fig1" => cmd_fig1(p),
        "fig2" => cmd_fig2(p),
        "info" => cmd_info(p),
        _ => unreachable!(),
    }
}

fn cmd_run(p: &Parsed) -> Result<()> {
    let mut cfg = match p.get("config") {
        Some("") | None => RunConfig::default(),
        Some(path) => RunConfig::from_file(Path::new(path))?,
    };
    // --threads/--obs beat the config file; an explicit --set beats all
    match p.get("threads") {
        Some("") | None => {}
        Some(t) => cfg.apply("threads_per_worker", t)?,
    }
    match p.get("obs") {
        Some("") | None => {}
        Some(v) => cfg.apply("obs", v)?,
    }
    match p.get("obs-out") {
        Some("") | None => {}
        Some(v) => cfg.apply("obs_out", v)?,
    }
    match p.get("chains") {
        Some("") | None => {}
        Some(v) => cfg.apply("chains", v)?,
    }
    match p.get("until") {
        Some("") | None => {}
        Some(v) => cfg.apply("until", v)?,
    }
    match p.get("trace-out") {
        Some("") | None => {}
        Some(v) => cfg.apply("trace_out", v)?,
    }
    for kv in p.get_list("set") {
        let (k, v) = kv
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("--set wants key=value, got '{kv}'"))?;
        cfg.apply(k, v)?;
    }
    let every = (cfg.iters / 20).max(1);
    let dot = |i: usize| {
        if i % every == 0 {
            print!(".");
            use std::io::Write;
            std::io::stdout().flush().ok();
        }
    };
    if cfg.chains > 1 || !cfg.until.is_empty() {
        println!(
            "pibp run: {} sampler={} P={} T={} iters={} backend={:?} seed={} chains={}{}",
            cfg.dataset, cfg.sampler.name(), cfg.processors,
            cfg.threads_per_worker, cfg.iters, cfg.backend, cfg.seed, cfg.chains,
            if cfg.until.is_empty() { String::new() } else { format!(" until='{}'", cfg.until) }
        );
        let out = runner::run_multi(&cfg, dot)?;
        println!();
        return finish_run_multi(&cfg, &out);
    }
    println!(
        "pibp run: {} sampler={} P={} T={} iters={} backend={:?} seed={}",
        cfg.dataset, cfg.sampler.name(), cfg.processors,
        cfg.threads_per_worker, cfg.iters, cfg.backend, cfg.seed
    );
    let out = runner::run(&cfg, dot)?;
    println!();
    finish_run(&cfg, &out)
}

fn cmd_resume(p: &Parsed) -> Result<()> {
    let ckpt = p.get("checkpoint").unwrap_or("results/checkpoint.pibp").to_string();
    let mut overrides: Vec<(String, String)> = Vec::new();
    match p.get("threads") {
        Some("") | None => {}
        Some(t) => overrides.push(("threads_per_worker".into(), t.into())),
    }
    match p.get("obs") {
        Some("") | None => {}
        Some(v) => overrides.push(("obs".into(), v.into())),
    }
    match p.get("obs-out") {
        Some("") | None => {}
        Some(v) => overrides.push(("obs_out".into(), v.into())),
    }
    for kv in p.get_list("set") {
        let (k, v) = kv
            .split_once('=')
            .ok_or_else(|| anyhow::anyhow!("--set wants key=value, got '{kv}'"))?;
        overrides.push((k.into(), v.into()));
    }
    let (cfg, out) = runner::resume(Path::new(&ckpt), &overrides, |i| {
        if i % 10 == 0 {
            print!(".");
            use std::io::Write;
            std::io::stdout().flush().ok();
        }
    })?;
    println!();
    println!(
        "pibp resume: {} → iteration {} (P={} T={} seed={})",
        ckpt, cfg.iters, cfg.processors, cfg.threads_per_worker, cfg.seed
    );
    finish_run(&cfg, &out)
}

fn cmd_predict(p: &Parsed) -> Result<()> {
    let obs_level = ObsLevel::parse(p.get("obs").unwrap_or("off"))?;
    obs::set_level(obs_level);
    obs::reset();
    let ckpt_path = p.get("checkpoint").unwrap_or("results/checkpoint.pibp").to_string();
    let ckpt = Checkpoint::load(Path::new(&ckpt_path))?;
    let cfg = RunConfig::from_canonical(&ckpt.config_text)?;
    let samples = ckpt.reservoir.samples();
    if samples.is_empty() {
        bail!(
            "checkpoint {ckpt_path} holds no posterior samples — run the chain \
             with --set keep_samples=N (and checkpoint_every=M) first"
        );
    }
    // query rows: an explicit CSV, or the run's own held-out split
    let queries: Mat = match p.get("queries") {
        Some("") | None => {
            let ds = runner::build_dataset(&cfg)?;
            if cfg.heldout_frac > 0.0 {
                ds.split_heldout(cfg.heldout_frac).1.x
            } else {
                ds.x
            }
        }
        Some(path) => pibp::data::loader::read_csv(Path::new(path))?,
    };
    let cap = p.get_usize("rows")?;
    let queries = if cap > 0 && cap < queries.rows() {
        queries.crop(cap, queries.cols())
    } else {
        queries
    };
    let missing = p.get_f64("missing")?;
    if !(0.0..1.0).contains(&missing) {
        bail!("--missing must be in [0, 1)");
    }
    let sweeps = p.get_usize("sweeps")?;
    let seed: u64 = p.get("seed").unwrap_or("0").parse()?;
    let threads = p.get_usize("threads")?.max(1);
    let (q, d) = (queries.rows(), queries.cols());
    if d != samples[0].a.cols() {
        bail!(
            "query rows have {d} dims but the posterior was fitted on {} dims",
            samples[0].a.cols()
        );
    }
    println!(
        "pibp predict: {} posterior samples (iters {}..{}, thinning stride {}), \
         {q} query rows × {d} dims, {sweeps} sweeps/sample, seed {seed}",
        samples.len(),
        samples.first().map_or(0, |s| s.iter),
        samples.last().map_or(0, |s| s.iter),
        ckpt.reservoir.stride(),
    );
    // honour the run's configured Z kernel (bit-invariant; --set
    // kernel=packed on the original run carries through the checkpoint)
    let engine = PredictEngine::new(samples, sweeps, threads).with_kernel(cfg.kernel);

    // ---- imputation: hide a fraction of entries, fill, score vs truth ----
    let mask = Mask::random(q, d, missing, &mut Pcg64::new(seed).split(tags::PREDICT_MASK));
    let hidden = q * d - mask.observed_count();
    let t0 = Instant::now();
    let recon = engine.impute(&queries, &mask, seed);
    let dt_imp = t0.elapsed().as_secs_f64();
    let mse = missing_mse(&queries, &recon, &mask);
    println!(
        "  imputation   : {hidden} hidden entries ({:.0}%)  MSE={mse:.5}  \
         [{:.1} rows/s]",
        100.0 * missing,
        q as f64 / dt_imp.max(1e-9),
    );

    // ---- held-out predictive log-likelihood over the full rows ----
    let t0 = Instant::now();
    let hp = engine.heldout_loglik(&queries, seed);
    let dt_ll = t0.elapsed().as_secs_f64();
    println!(
        "  heldout      : log-mean-exp predictive  total={:.2}  per-row mean={:.3}  \
         [{:.1} rows/s]",
        hp.total,
        hp.total / q as f64,
        q as f64 / dt_ll.max(1e-9),
    );

    // ---- posterior-mean reconstruction (denoising) ----
    let t0 = Instant::now();
    let denoised = engine.reconstruct(&queries, seed);
    let dt_rec = t0.elapsed().as_secs_f64();
    let rec_rmse = (denoised.sub(&queries).frob2() / (q * d) as f64).sqrt();
    println!(
        "  reconstruct  : RMSE vs observed={rec_rmse:.5}  [{:.1} rows/s]",
        q as f64 / dt_rec.max(1e-9),
    );
    println!(
        "  throughput   : {:.1} queries/s over {} samples (1 query = 1 row × 1 query type)",
        (3 * q) as f64 / (dt_imp + dt_ll + dt_rec).max(1e-9),
        samples.len(),
    );
    if obs_level != ObsLevel::Off {
        eprint!("{}", obs::RunReport::capture().render());
        match p.get("obs-out") {
            Some("") | None => {}
            Some(path) => {
                obs::RunReport::write(Path::new(path))?;
                println!("obs report → {path}");
            }
        }
    }
    Ok(())
}

fn cmd_report(p: &Parsed) -> Result<()> {
    let path = p.get("file").unwrap_or("run_obs.json");
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
    let doc = Json::parse(&text)?;
    print!("{}", obs::render_json(&doc)?);
    Ok(())
}

/// Shared tail of `run`/`resume`: report, persist the trace, show features.
fn finish_run(cfg: &RunConfig, out: &runner::RunOutcome) -> Result<()> {
    report(&out.trace);
    let dir = Path::new(&cfg.out_dir);
    let csv = dir.join(format!("{}.csv", out.trace.label));
    out.trace.save_csv(&csv)?;
    println!("trace → {}", csv.display());
    if !cfg.trace_out.is_empty() {
        let path = Path::new(&cfg.trace_out);
        out.trace.save_auto(path)?;
        println!("trace export → {}", path.display());
    }
    if cfg.checkpoint_every > 0 {
        println!("checkpoint → {}", runner::checkpoint_file(cfg).display());
    }
    if cfg.keep_samples > 0 {
        println!(
            "posterior samples kept: {} (stride {})",
            out.reservoir.len(),
            out.reservoir.stride()
        );
    }
    if out.final_k > 0 {
        println!("\nposterior features (K={}):\n{}", out.final_k,
                 viz::render_features_ascii(&out.features));
    }
    if cfg.obs != ObsLevel::Off {
        eprint!("{}", obs::RunReport::capture().render());
        println!("obs report → {}", runner::obs_report_file(cfg).display());
    }
    Ok(())
}

/// Tail of a diagnosed multi-chain run: per-chain traces (+ optional
/// `--trace-out` exports `pibp diagnose` can reload), the convergence
/// summary table, and the obs report pointer.
fn finish_run_multi(cfg: &RunConfig, out: &runner::MultiOutcome) -> Result<()> {
    let dir = Path::new(&cfg.out_dir);
    for (c, chain) in out.chains.iter().enumerate() {
        report(&chain.trace);
        let csv = dir.join(format!("{}-c{c}.csv", chain.trace.label));
        chain.trace.save_csv(&csv)?;
        println!("chain {c} trace → {}", csv.display());
        if !cfg.trace_out.is_empty() {
            let base = Path::new(&cfg.trace_out);
            let path = if out.chains.len() > 1 {
                runner::chain_file(base, c)
            } else {
                base.to_path_buf()
            };
            chain.trace.save_auto(&path)?;
            println!("chain {c} trace export → {}", path.display());
        }
    }
    print!("{}", out.diag.render());
    if cfg.checkpoint_every > 0 {
        println!(
            "checkpoints → {} (chain-suffixed .c{{c}} when chains > 1)",
            runner::checkpoint_file(cfg).display()
        );
    }
    if cfg.obs != ObsLevel::Off {
        eprint!("{}", obs::RunReport::capture().render());
        println!("obs report → {}", runner::obs_report_file(cfg).display());
    }
    Ok(())
}

/// Offline convergence verdict over exported chain traces: batch
/// split-R̂ + per-chain ESS per watched quantity (post-warmup), plateau
/// levels, optional time-to-threshold — mirroring the gating the live
/// `--until` rule applies, with explicit pass thresholds.
fn cmd_diagnose(p: &Parsed) -> Result<()> {
    let files = p.get_list("trace");
    if files.len() < 2 {
        bail!(
            "pibp diagnose needs at least two --trace files (one per chain; \
             export them with pibp run --chains C --trace-out t.json)"
        );
    }
    let rhat_max = p.get_f64("rhat-max")?;
    let ess_min = p.get_f64("ess-min")?;
    let warmup = p.get_f64("warmup-frac")?;
    if !(0.0..1.0).contains(&warmup) {
        bail!("--warmup-frac must be in [0, 1)");
    }
    let traces: Vec<Trace> = files
        .iter()
        .map(|f| Trace::load(Path::new(f)))
        .collect::<Result<_>>()?;
    let min_pts = traces.iter().map(|t| t.points.len()).min().unwrap_or(0);
    let kept: Vec<&[pibp::metrics::TracePoint]> = traces
        .iter()
        .map(|t| {
            let start = (t.points.len() as f64 * warmup) as usize;
            &t.points[start..]
        })
        .collect();
    println!(
        "pibp diagnose: {} chains, {} points in the shortest trace, warmup {:.0}% discarded",
        traces.len(),
        min_pts,
        100.0 * warmup
    );
    for (c, t) in traces.iter().enumerate() {
        let last = t.last().map_or(f64::NAN, |p| p.heldout);
        print!(
            "  chain {c}: {} ({} pts) plateau={:.1} final heldout={:.1}",
            t.label,
            t.points.len(),
            t.plateau(0.25),
            last
        );
        match p.get("threshold") {
            Some("") | None => println!(),
            Some(th) => {
                let th: f64 = th
                    .parse()
                    .map_err(|_| anyhow::anyhow!("--threshold wants a number, got '{th}'"))?;
                match t.time_to(th) {
                    Some(s) => println!("  reached {th} at vtime {s:.2}s"),
                    None => println!("  never reached {th}"),
                }
            }
        }
    }
    // the same four scalars the live diagnostics watch; k is integer-
    // valued and often constant, so like the live `ess>` gate it is
    // reported but not scored on ESS
    let quantities: [(&str, fn(&pibp::metrics::TracePoint) -> f64, bool); 4] = [
        ("heldout", |p| p.heldout, true),
        ("alpha", |p| p.alpha, true),
        ("sigma_x", |p| p.sigma_x, true),
        ("k", |p| p.k as f64, false),
    ];
    println!("\n  {:<10} {:>10} {:>10}  verdict", "quantity", "split-Rhat", "min ESS");
    let mut all_pass = true;
    for (name, get, ess_gated) in quantities {
        let series: Vec<Vec<f64>> = kept
            .iter()
            .map(|pts| pts.iter().map(|p| get(p)).collect())
            .collect();
        let r = pibp::metrics::split_rhat(&series);
        // constant post-warmup series carry no ESS information (their
        // batch ESS pins near 1 by construction) — skip them like the
        // online gate does
        let min_ess = series
            .iter()
            .filter(|s| !s.is_empty() && s.iter().any(|v| *v != s[0]))
            .map(|s| pibp::metrics::ess(s))
            .fold(f64::INFINITY, f64::min);
        let rhat_ok = r.is_finite() && r < rhat_max;
        let ess_ok = !ess_gated || min_ess.is_infinite() || min_ess > ess_min;
        let pass = rhat_ok && ess_ok;
        all_pass &= pass;
        let ess_str = if min_ess.is_infinite() {
            "const".to_string()
        } else {
            format!("{min_ess:.1}")
        };
        println!(
            "  {:<10} {:>10} {:>10}  {}",
            name,
            if r.is_nan() { "-".to_string() } else { format!("{r:.4}") },
            ess_str,
            if pass { "PASS" } else { "FAIL" }
        );
    }
    println!(
        "\noverall: {} (rhat-max {rhat_max}, ess-min {ess_min})",
        if all_pass { "PASS" } else { "FAIL" }
    );
    if !all_pass && p.get_bool("strict") {
        std::process::exit(3);
    }
    Ok(())
}

/// `pibp worker --connect <addr>` — the process side of the socket
/// transports. Dials the master, completes the versioned handshake,
/// receives its full worker config + X shard in the SETUP frame, then
/// runs the standard worker loop until Shutdown (or the master goes
/// away, which surfaces as a contextual error). All sampling state
/// comes from the master, so any `pibp` binary of the same protocol
/// version can serve any run.
fn cmd_worker(p: &Parsed) -> Result<()> {
    let addr = match p.get("connect") {
        Some(a) if !a.is_empty() => a,
        _ => bail!(
            "pibp worker needs --connect <addr> — the master's listen address \
             (a UDS socket path, or host:port for tcp)"
        ),
    };
    pibp::coordinator::run_remote_worker(addr)
}

fn fig_cfg(p: &Parsed) -> Result<RunConfig> {
    let mut cfg = RunConfig::default();
    cfg.iters = p.get_usize("iters")?;
    cfg.n = p.get_usize("n")?;
    cfg.seed = p.get("seed").unwrap_or("0").parse()?;
    if let Some(b) = p.get("backend") {
        cfg.apply("backend", b)?;
    }
    // fig2 has no --threads flag; fig1 defaults it to 1
    if let Some(t) = p.get("threads") {
        cfg.apply("threads_per_worker", t)?;
    }
    Ok(cfg)
}

fn cmd_fig1(p: &Parsed) -> Result<()> {
    let base = fig_cfg(p)?;
    let out_dir = p.get("out").unwrap_or("results/fig1").to_string();
    println!("Figure 1: held-out log P(X,Z) over log (virtual) time");
    println!("  dataset cambridge {}×36, {} iterations, L=5\n", base.n, base.iters);
    let mut traces: Vec<Trace> = Vec::new();
    // collapsed baseline
    {
        let mut cfg = base.clone();
        cfg.sampler = SamplerKind::Collapsed;
        println!("running collapsed…");
        traces.push(runner::run(&cfg, |_| {})?.trace);
    }
    for p_count in [1usize, 3, 5] {
        let mut cfg = base.clone();
        cfg.sampler = SamplerKind::Hybrid;
        cfg.processors = p_count;
        println!("running hybrid P={p_count}…");
        traces.push(runner::run(&cfg, |_| {})?.trace);
    }
    let dir = Path::new(&out_dir);
    for t in &traces {
        t.save_csv(&dir.join(format!("{}.csv", t.label)))?;
        report(t);
    }
    let refs: Vec<&Trace> = traces.iter().collect();
    println!("\n{}", viz::plot_traces(&refs, 76, 18, true));
    println!("traces → {out_dir}/*.csv  (plot: heldout vs log10(vtime_s))");
    Ok(())
}

fn cmd_fig2(p: &Parsed) -> Result<()> {
    let base = fig_cfg(p)?;
    let out_dir = p.get("out").unwrap_or("results/fig2").to_string();
    let dir = Path::new(&out_dir);
    // true features (top row of the paper's Figure 2)
    let truth = cambridge::true_features(base.k_true);
    viz::save_feature_grid(&dir.join("true_features.pgm"), &truth, 8)?;
    println!("true features:\n{}", viz::render_features_ascii(&truth));
    // collapsed posterior (middle row)
    let mut cfg = base.clone();
    cfg.sampler = SamplerKind::Collapsed;
    println!("running collapsed…");
    let out = runner::run(&cfg, |_| {})?;
    viz::save_feature_grid(&dir.join("collapsed_features.pgm"), &out.features, 8)?;
    println!("collapsed posterior (K={}):\n{}", out.final_k,
             viz::render_features_ascii(&out.features));
    // hybrid P=5 posterior (bottom row)
    let mut cfg = base.clone();
    cfg.sampler = SamplerKind::Hybrid;
    cfg.processors = 5;
    println!("running hybrid P=5…");
    let out = runner::run(&cfg, |_| {})?;
    viz::save_feature_grid(&dir.join("hybrid_p5_features.pgm"), &out.features, 8)?;
    println!("hybrid P=5 posterior (K={}):\n{}", out.final_k,
             viz::render_features_ascii(&out.features));
    println!("images → {out_dir}/*.pgm");
    Ok(())
}

fn cmd_info(p: &Parsed) -> Result<()> {
    let dir = p.get("artifacts").unwrap_or("artifacts");
    let m = Manifest::load(Path::new(dir))?;
    println!("artifacts in {dir}: {} entries", m.entries.len());
    println!("row buckets {:?}, feature buckets {:?}, dims {:?}", m.rows, m.feats, m.dims);
    for e in &m.entries {
        println!(
            "  {:<18} b={:<6} k={:<4} d={:<4} {}",
            e.name,
            e.b.map_or("-".into(), |b| b.to_string()),
            e.k, e.d, e.file
        );
    }
    Ok(())
}

fn report(t: &Trace) {
    let last = t.last().expect("trace non-empty");
    println!(
        "  {:<14} plateau={:.1}  final: heldout={:.1} K={} σx={:.3} α={:.2}  t={:.2}s(virtual)",
        t.label,
        t.plateau(0.25),
        last.heldout,
        last.k,
        last.sigma_x,
        last.alpha,
        last.vtime_s
    );
}
