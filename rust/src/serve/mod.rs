//! Posterior serving: a fitted model as a durable, queryable artifact.
//!
//! Two pieces:
//!
//! * [`SampleReservoir`] — a bounded, deterministically thinned store of
//!   posterior samples (Z, A, π, σ, α) accumulated during a run
//!   (`keep_samples` in `RunConfig`) and persisted inside checkpoints
//!   (`crate::snapshot`). Thinning is the classic keep-every-k-and-double
//!   scheme: record every `stride`-th iteration; when the reservoir is
//!   full, drop every other kept sample and double the stride. The kept
//!   set is a pure function of (capacity, offered iterations) — no RNG —
//!   so it survives checkpoint/resume bit-exactly.
//! * [`PredictEngine`] — batched prediction queries averaged over the
//!   stored samples: posterior-mean **reconstruction** of query rows,
//!   **missing-entry imputation** (reusing `model::missing`), and
//!   held-out per-row predictive **log-likelihood** (log-mean-exp across
//!   samples). Posterior samples are embarrassingly parallel, so the
//!   engine fans the **samples** out across a persistent
//!   [`crate::parallel::ThreadPool`]: sample `s` infers its latents on
//!   its own derived stream (`Pcg64::new(seed).split(tags::serve_sample(s))`) into a
//!   private per-sample buffer, and the buffers are merged in sample
//!   order — so every query result is byte-identical for every thread
//!   count and every task completion ("arrival") order.
//!
//! This mirrors how Dubey et al. (distributed collapsed BNP) and Zhang et
//! al. (accelerated non-conjugate sampling) use fitted BNP models: not as
//! one-shot experiments but as posterior artifacts answering held-out
//! prediction and imputation queries.

// Compiler-enforced twin of detlint rule R4 (no-panic-coordinator): deny
// `unwrap()` outside test builds. Proven-infallible sites carry a scoped
// `#[allow]` plus a detlint waiver with the proof. CI runs clippy with
// this lint promoted to blocking.
#![cfg_attr(not(test), deny(clippy::unwrap_used))]

use crate::linalg::Mat;
use crate::model::missing::{masked_sweep, reconstruct_into, Mask};
use crate::model::state::{FeatureState, Kernel};
use crate::model::LinGauss;
use crate::obs;
use crate::parallel::{par_sweep_rows, ExecConfig, ParallelCtx};
use crate::rng::{tags, Pcg64};
use crate::samplers::uncollapsed::residuals;

/// RNG tag base for per-sample query streams — an alias of the central
/// registry entry (`rng::tags::SERVE_BASE`; see the repo-wide tag table
/// in docs/ARCHITECTURE.md): sample s draws from
/// `Pcg64::new(query_seed).split(tags::serve_sample(s))`.
pub const QUERY_TAG_BASE: u64 = tags::SERVE_BASE;

/// One thinned posterior draw: the global feature assignment at that
/// iteration plus every global parameter needed to answer queries.
#[derive(Clone, Debug, PartialEq)]
pub struct PosteriorSample {
    /// Global iteration (1-based) this sample was taken at.
    pub iter: u64,
    /// Gathered global Z (N × K⁺), matching the column space of `a`/`pi`.
    pub z: FeatureState,
    /// Feature loadings (K⁺ × D).
    pub a: Mat,
    pub pi: Vec<f64>,
    pub sigma_x: f64,
    pub sigma_a: f64,
    pub alpha: f64,
}

impl PosteriorSample {
    pub fn k(&self) -> usize {
        self.pi.len()
    }

    fn prior_logit(&self) -> Vec<f64> {
        self.pi
            .iter()
            .map(|&p| {
                let p = p.clamp(1e-12, 1.0 - 1e-12);
                (p / (1.0 - p)).ln()
            })
            .collect()
    }
}

/// Bounded store of thinned posterior samples (see module docs for the
/// thinning scheme). `capacity == 0` disables recording entirely.
#[derive(Clone, Debug, PartialEq)]
pub struct SampleReservoir {
    cap: usize,
    stride: u64,
    samples: Vec<PosteriorSample>,
}

impl SampleReservoir {
    pub fn new(capacity: usize) -> Self {
        Self { cap: capacity, stride: 1, samples: Vec::new() }
    }

    /// Rebuild from checkpointed parts (`crate::snapshot`).
    pub fn from_parts(cap: usize, stride: u64, samples: Vec<PosteriorSample>) -> Self {
        Self { cap, stride: stride.max(1), samples }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Current thinning stride: samples are recorded at iterations that
    /// are multiples of this.
    pub fn stride(&self) -> u64 {
        self.stride
    }

    pub fn samples(&self) -> &[PosteriorSample] {
        &self.samples
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// Should iteration `iter` (1-based, counting completed global
    /// iterations) be recorded? Callers gate the (expensive) global-Z
    /// gather on this before building a [`PosteriorSample`].
    pub fn wants(&self, iter: u64) -> bool {
        self.cap > 0 && iter % self.stride == 0
    }

    /// Change the capacity in place (e.g. a `--set keep_samples=N`
    /// override on resume). Growing keeps everything; shrinking thins
    /// with the same stride-doubling rule until the kept set fits; 0
    /// stops future recording but keeps what was already collected (so
    /// later checkpoints don't lose data).
    ///
    /// The stride doubling is capped: if it can no longer thin the kept
    /// set (pathological iteration values — e.g. duplicate `iter: 0`
    /// samples, which every stride divides — or a stride about to
    /// overflow `u64`), the oldest samples are dropped directly instead
    /// of doubling forever.
    pub fn set_capacity(&mut self, cap: usize) {
        self.cap = cap;
        if cap > 0 {
            while self.samples.len() > cap {
                let Some(next) = self.stride.checked_mul(2) else {
                    // stride exhausted (63 doublings): thinning by
                    // divisibility cannot shrink this set — keep the
                    // newest `cap` samples and stop
                    let excess = self.samples.len() - cap;
                    self.samples.drain(..excess);
                    break;
                };
                self.stride = next;
                self.samples.retain(|t| t.iter % next == 0);
            }
        }
    }

    /// Record a sample taken at a `wants`-approved iteration. When the
    /// reservoir is full, every other kept sample is dropped and the
    /// stride doubles — capacity is never exceeded and the kept set stays
    /// evenly spaced over the whole chain. The doubling is capped exactly
    /// as in [`Self::set_capacity`].
    pub fn record(&mut self, s: PosteriorSample) {
        if !self.wants(s.iter) {
            return;
        }
        while self.samples.len() >= self.cap {
            let Some(next) = self.stride.checked_mul(2) else {
                // cannot thin by stride any further — make room by
                // dropping the oldest kept sample(s)
                let excess = self.samples.len() + 1 - self.cap;
                self.samples.drain(..excess);
                break;
            };
            self.stride = next;
            self.samples.retain(|t| t.iter % next == 0);
            if s.iter % next != 0 {
                return;
            }
        }
        self.samples.push(s);
    }
}

/// Per-row held-out predictive log-likelihood query result.
#[derive(Clone, Debug)]
pub struct HeldoutPredict {
    /// log (1/S Σ_s P(x_i, z_i | θ_s)) per query row (log-mean-exp over
    /// samples of the per-sample joint row score).
    pub per_row: Vec<f64>,
    /// Sum over rows.
    pub total: f64,
}

/// Numerically stable log-mean-exp.
pub fn log_mean_exp(vals: &[f64]) -> f64 {
    let m = vals.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if !m.is_finite() {
        return m;
    }
    let s: f64 = vals.iter().map(|v| (v - m).exp()).sum();
    m + (s / vals.len() as f64).ln()
}

/// Batched prediction over a set of posterior samples.
///
/// Queries fan the *samples* out across `threads` lanes of a persistent
/// pool (samples are embarrassingly parallel); each sample's latent
/// inference runs serially inside its task on the sample's own derived
/// stream, and per-sample buffers are merged in sample order. Results are
/// therefore byte-identical for every `threads` value, scheduling mode,
/// and task completion order.
pub struct PredictEngine<'a> {
    samples: &'a [PosteriorSample],
    /// Gibbs sweeps used to infer each query row's latent z per sample.
    sweeps: usize,
    /// Per-sample fan-out context (persistent pool when `threads > 1`).
    ctx: ParallelCtx,
    /// Within-sample sweep executor: inline — sample-level parallelism
    /// already saturates the lanes, and nesting pools would oversubscribe.
    /// Bit-wise this is indistinguishable from any other choice (the
    /// executor contract makes sweeps T-invariant).
    sweep_exec: ExecConfig,
}

impl<'a> PredictEngine<'a> {
    /// `threads` parallelises queries *across posterior samples* through
    /// a persistent pool — results are identical for every value
    /// (`threads ≤ 1`, including 0, runs inline).
    pub fn new(samples: &'a [PosteriorSample], sweeps: usize, threads: usize) -> Self {
        Self::with_ctx(samples, sweeps, ParallelCtx::pooled(threads))
    }

    /// Like [`Self::new`], but scheduling onto a caller-supplied context.
    pub fn with_ctx(samples: &'a [PosteriorSample], sweeps: usize, ctx: ParallelCtx) -> Self {
        Self { samples, sweeps, ctx, sweep_exec: ExecConfig::default() }
    }

    /// Select the Z storage kernel for per-sample latent inference.
    /// Bit-invariant: answers are byte-identical for either value (the
    /// packed sweep kernel mirrors the scalar one exactly).
    pub fn with_kernel(mut self, kernel: Kernel) -> Self {
        self.sweep_exec.kernel = kernel;
        self
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    fn sample_rng(seed: u64, s: usize) -> Pcg64 {
        Pcg64::new(seed).split(tags::serve_sample(s))
    }

    /// Run `f(s, sample)` for every posterior sample — possibly in
    /// parallel, each task on its own lane — and return the results
    /// **indexed by sample**, so downstream merges in sample order are
    /// independent of which task finished first.
    fn for_each_sample<R, F>(&self, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize, &PosteriorSample) -> R + Sync,
    {
        let mut slots: Vec<(usize, Option<R>)> =
            (0..self.samples.len()).map(|s| (s, None)).collect();
        self.ctx.run(&mut slots, |slot| {
            slot.1 = Some(f(slot.0, &self.samples[slot.0]));
        });
        slots
            .into_iter()
            // detlint:allow(no-panic-coordinator): ctx.run applies f to every slice element exactly once (executor contract), so every slot is Some
            .map(|(_, r)| r.expect("ctx.run visits every sample slot"))
            .collect()
    }

    /// Matrix-valued fan-out with bounded memory: `f(s, sample, out)`
    /// fills a zeroed per-sample n×d buffer, and buffers are summed into
    /// the accumulator **in strict sample order** — but samples are
    /// processed in contiguous waves of at most `ctx.threads()` tasks, so
    /// peak memory is O(T · n · d), not O(S · n · d), while the addition
    /// order (and therefore every output byte) is identical to a serial
    /// sample-by-sample loop.
    fn accumulate_samples<F>(&self, n: usize, d: usize, f: F) -> Mat
    where
        F: Fn(usize, &PosteriorSample, &mut Mat) + Sync,
    {
        let mut acc = Mat::zeros(n, d);
        let wave = self.ctx.threads().max(1);
        // the T wave buffers are allocated once and reused (re-zeroed)
        // across waves — O(T) allocations for the whole query, like the
        // pre-fan-out single reused scratch matrix
        let mut slots: Vec<(usize, Mat)> = Vec::with_capacity(wave);
        for start in (0..self.samples.len()).step_by(wave) {
            let end = (start + wave).min(self.samples.len());
            slots.truncate(end - start);
            while slots.len() < end - start {
                slots.push((0, Mat::zeros(n, d)));
            }
            for (i, slot) in slots.iter_mut().enumerate() {
                slot.0 = start + i;
                slot.1.as_mut_slice().fill(0.0);
            }
            obs::record_value(obs::Span::ServeWaveSize, (end - start) as u64);
            self.ctx.run(&mut slots, |slot| {
                f(slot.0, &self.samples[slot.0], &mut slot.1);
            });
            for (_, part) in &slots {
                acc.add_assign(part);
            }
        }
        acc
    }

    /// Infer latent assignments for the query rows under one sample.
    /// `mask: None` means fully observed rows, swept through the
    /// deterministic block executor; `Some(mask)` sweeps only over the
    /// observed entries (`masked_sweep`, for imputation). Both paths share
    /// every other piece of the inference setup so they cannot drift
    /// apart. Called from per-sample fan-out tasks, so it takes no `&mut`
    /// engine state.
    fn infer_z(
        &self,
        ps: &PosteriorSample,
        x: &Mat,
        mask: Option<&Mask>,
        rng: &mut Pcg64,
    ) -> FeatureState {
        let n = x.rows();
        let k = ps.k();
        let mut z = FeatureState::empty_with(n, self.sweep_exec.kernel);
        z.add_features(k);
        if k > 0 {
            let logit = ps.prior_logit();
            let inv2s2 = 1.0 / (2.0 * ps.sigma_x * ps.sigma_x);
            match mask {
                Some(m) => {
                    for _ in 0..self.sweeps {
                        masked_sweep(x, m, &mut z, &ps.a, &logit, inv2s2, rng);
                    }
                }
                None => {
                    let mut resid = residuals(x, &z, &ps.a, 0..n);
                    for _ in 0..self.sweeps {
                        par_sweep_rows(
                            &mut z, &mut resid, &ps.a, &logit, inv2s2, 0..n, k,
                            &self.sweep_exec, rng,
                        );
                    }
                }
            }
        }
        z
    }

    /// Posterior-mean denoising reconstruction of fully observed query
    /// rows: mean over samples of Z_q A. Samples fan out in parallel
    /// waves, each into its own buffer; buffers merge in sample order
    /// ([`Self::accumulate_samples`] — O(T) live buffers).
    pub fn reconstruct(&self, x: &Mat, seed: u64) -> Mat {
        assert!(!self.samples.is_empty(), "predict: no posterior samples");
        let _q = obs::span(obs::Span::ServeQuery);
        obs::inc(obs::Counter::ServeQueries);
        let (n, d) = (x.rows(), x.cols());
        let mut acc = self.accumulate_samples(n, d, |s, ps, part| {
            let mut rng = Self::sample_rng(seed, s);
            let z = self.infer_z(ps, x, None, &mut rng);
            for i in 0..n {
                let row = part.row_mut(i);
                for k in 0..ps.k() {
                    if z.get(i, k) == 1 {
                        for (t, &v) in row.iter_mut().zip(ps.a.row(k)) {
                            *t += v;
                        }
                    }
                }
            }
            obs::add(obs::Counter::RngDrawsServe, rng.draw_count());
        });
        acc.scale(1.0 / self.samples.len() as f64);
        acc
    }

    /// Batched missing-entry imputation: for each sample (in parallel
    /// waves), infer the query rows' z from the *observed* entries only
    /// (`masked_sweep`) and reconstruct into that sample's private buffer
    /// ([`reconstruct_into`]); the buffers are averaged in sample order
    /// ([`Self::accumulate_samples`] — O(T) live buffers). Observed
    /// entries pass through unchanged; missing entries get the
    /// posterior-mean fill.
    pub fn impute(&self, x: &Mat, mask: &Mask, seed: u64) -> Mat {
        assert!(!self.samples.is_empty(), "predict: no posterior samples");
        let _q = obs::span(obs::Span::ServeQuery);
        obs::inc(obs::Counter::ServeQueries);
        let (n, d) = (x.rows(), x.cols());
        let mut acc = self.accumulate_samples(n, d, |s, ps, recon| {
            let mut rng = Self::sample_rng(seed, s);
            let z = self.infer_z(ps, x, Some(mask), &mut rng);
            reconstruct_into(recon, x, mask, &z, &ps.a);
            obs::add(obs::Counter::RngDrawsServe, rng.draw_count());
        });
        acc.scale(1.0 / self.samples.len() as f64);
        acc
    }

    /// Held-out predictive joint log-likelihood per query row:
    /// `log (1/S) Σ_s P(x_i | z_i^s, A^s, σ^s) P(z_i^s | π^s)` with z_i^s
    /// inferred per sample from the full row — samples in parallel, the
    /// per-row log-mean-exp combining them in sample order.
    pub fn heldout_loglik(&self, x: &Mat, seed: u64) -> HeldoutPredict {
        assert!(!self.samples.is_empty(), "predict: no posterior samples");
        let _q = obs::span(obs::Span::ServeQuery);
        obs::inc(obs::Counter::ServeQueries);
        let n = x.rows();
        let per_sample: Vec<Vec<f64>> = self.for_each_sample(|s, ps| {
            let mut rng = Self::sample_rng(seed, s);
            let z = self.infer_z(ps, x, None, &mut rng);
            let lg = LinGauss::new(ps.sigma_x, ps.sigma_a);
            let mut rows = Vec::with_capacity(n);
            for i in 0..n {
                let zr = z.row_f64(i);
                let mut ll = lg.row_loglik(x.row(i), &zr, &ps.a);
                for (k, &p) in ps.pi.iter().enumerate() {
                    let p = p.clamp(1e-12, 1.0 - 1e-12);
                    ll += if z.get(i, k) == 1 { p.ln() } else { (1.0 - p).ln() };
                }
                rows.push(ll);
            }
            obs::add(obs::Counter::RngDrawsServe, rng.draw_count());
            rows
        });
        let mut per_row = Vec::with_capacity(n);
        let mut vals = vec![0.0f64; per_sample.len()];
        for i in 0..n {
            for (s, rows) in per_sample.iter().enumerate() {
                vals[s] = rows[i];
            }
            per_row.push(log_mean_exp(&vals));
        }
        let total = per_row.iter().sum();
        HeldoutPredict { per_row, total }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::missing::missing_mse;

    fn mk_sample(iter: u64) -> PosteriorSample {
        PosteriorSample {
            iter,
            z: FeatureState::empty(1),
            a: Mat::zeros(0, 1),
            pi: vec![],
            sigma_x: 0.5,
            sigma_a: 1.0,
            alpha: 1.0,
        }
    }

    /// Planted model + S jittered posterior samples around it.
    fn planted(n: usize, k: usize, d: usize, s_count: usize, seed: u64)
               -> (Mat, Vec<PosteriorSample>) {
        let mut rng = Pcg64::new(seed);
        let mut z = FeatureState::empty(n);
        z.add_features(k);
        for i in 0..n {
            for j in 0..k {
                if rng.bernoulli(0.5) {
                    z.set(i, j, 1);
                }
            }
        }
        let a = Mat::from_fn(k, d, |_, _| 2.0 * rng.normal());
        let mut x = z.to_mat().matmul(&a);
        for v in x.as_mut_slice().iter_mut() {
            *v += 0.1 * rng.normal();
        }
        let samples = (0..s_count)
            .map(|s| {
                let mut a_s = a.clone();
                for v in a_s.as_mut_slice().iter_mut() {
                    *v += 0.02 * rng.normal();
                }
                PosteriorSample {
                    iter: s as u64 + 1,
                    z: z.clone(),
                    a: a_s,
                    pi: vec![0.5; k],
                    sigma_x: 0.15,
                    sigma_a: 1.0,
                    alpha: 1.0,
                }
            })
            .collect();
        (x, samples)
    }

    #[test]
    fn reservoir_thins_deterministically_and_never_exceeds_capacity() {
        let mut r = SampleReservoir::new(4);
        for iter in 1..=20u64 {
            if r.wants(iter) {
                r.record(mk_sample(iter));
            }
            assert!(r.len() <= 4, "capacity exceeded at iter {iter}");
        }
        // cap 4, iters 1..=20: stride doubles 1→2→4→8; survivors are the
        // multiples of 8 seen so far
        assert_eq!(r.stride(), 8);
        let kept: Vec<u64> = r.samples().iter().map(|s| s.iter).collect();
        assert_eq!(kept, vec![8, 16]);
    }

    #[test]
    fn set_capacity_shrinks_grows_and_disables() {
        let mut r = SampleReservoir::new(8);
        for iter in 1..=8u64 {
            if r.wants(iter) {
                r.record(mk_sample(iter));
            }
        }
        assert_eq!(r.len(), 8);
        // shrink: thins with the same doubling rule
        r.set_capacity(3);
        assert!(r.len() <= 3, "len {} after shrink", r.len());
        let kept: Vec<u64> = r.samples().iter().map(|s| s.iter).collect();
        assert_eq!(kept, vec![4, 8]); // stride doubled 1→2→4
        assert_eq!(r.stride(), 4);
        // grow: keeps everything, future recording resumes
        r.set_capacity(4);
        if r.wants(12) {
            r.record(mk_sample(12));
        }
        assert_eq!(r.len(), 3);
        // disable: keeps the collected samples but records no more
        r.set_capacity(0);
        assert!(!r.wants(16));
        assert_eq!(r.len(), 3);
    }

    #[test]
    fn set_capacity_shrink_to_one_and_zero() {
        // dense reservoir: iters 1..=8 at stride 1
        let mut r = SampleReservoir::new(8);
        for iter in 1..=8u64 {
            r.record(mk_sample(iter));
        }
        assert_eq!(r.len(), 8);
        // shrink to 1: stride doubles 1→2→4→8, survivor is iter 8
        r.set_capacity(1);
        assert_eq!(r.len(), 1);
        assert_eq!(r.samples()[0].iter, 8);
        assert_eq!(r.stride(), 8);
        // shrink to 0: keeps the collected sample, stops recording
        r.set_capacity(0);
        assert_eq!(r.len(), 1);
        assert!(!r.wants(16));
        r.record(mk_sample(16));
        assert_eq!(r.len(), 1);
    }

    #[test]
    fn pathological_iters_cannot_overflow_stride() {
        // iter 0 divides every stride, so the doubling loop alone could
        // never thin this set — the cap on doubling must kick in instead
        // of overflowing u64 (shrink path)
        let mut r = SampleReservoir::from_parts(
            4,
            1,
            vec![mk_sample(0), mk_sample(0), mk_sample(0)],
        );
        r.set_capacity(1);
        assert_eq!(r.len(), 1, "shrink-to-1 did not terminate at capacity");
        // record path: a full reservoir of iter-0 samples plus another
        // iter-0 offer must also terminate, at ≤ capacity
        let mut r = SampleReservoir::from_parts(2, 1, vec![mk_sample(0), mk_sample(0)]);
        r.record(mk_sample(0));
        assert!(r.len() <= 2, "record overflowed capacity: {}", r.len());
    }

    #[test]
    fn reservoir_zero_capacity_records_nothing() {
        let mut r = SampleReservoir::new(0);
        for iter in 1..=10u64 {
            assert!(!r.wants(iter));
            r.record(mk_sample(iter));
        }
        assert!(r.is_empty());
    }

    #[test]
    fn reservoir_small_capacity_keeps_latest_spacing() {
        let mut r = SampleReservoir::new(1);
        for iter in 1..=8u64 {
            if r.wants(iter) {
                r.record(mk_sample(iter));
            }
        }
        assert_eq!(r.len(), 1);
        // stride grows past the horizon; the survivor is a power of two
        let it = r.samples()[0].iter;
        assert!(it == 4 || it == 8, "kept iter {it}");
    }

    #[test]
    fn impute_is_deterministic_and_thread_invariant() {
        let (x, samples) = planted(40, 3, 12, 4, 1);
        let mut mrng = Pcg64::new(2);
        let mask = Mask::random(40, 12, 0.3, &mut mrng);
        let e1 = PredictEngine::new(&samples, 3, 1);
        let e2 = PredictEngine::new(&samples, 3, 4);
        let r1 = e1.impute(&x, &mask, 7);
        let r2 = e2.impute(&x, &mask, 7);
        assert!(r1.max_abs_diff(&r2) == 0.0, "imputation depends on T");
        // loglik goes through the parallel executor — also T-invariant
        let l1 = e1.heldout_loglik(&x, 7);
        let l2 = e2.heldout_loglik(&x, 7);
        assert_eq!(l1.total.to_bits(), l2.total.to_bits());
        for (a, b) in l1.per_row.iter().zip(&l2.per_row) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn queries_are_kernel_invariant() {
        // packed per-sample latent inference must answer every query
        // byte-identically to the scalar kernel, at any thread count
        let (x, samples) = planted(40, 3, 12, 4, 1);
        let mut mrng = Pcg64::new(2);
        let mask = Mask::random(40, 12, 0.3, &mut mrng);
        let scalar = PredictEngine::new(&samples, 3, 2);
        let packed = PredictEngine::new(&samples, 3, 4).with_kernel(Kernel::Packed);
        assert!(scalar.reconstruct(&x, 7).max_abs_diff(&packed.reconstruct(&x, 7)) == 0.0);
        assert!(scalar.impute(&x, &mask, 7).max_abs_diff(&packed.impute(&x, &mask, 7)) == 0.0);
        let ls = scalar.heldout_loglik(&x, 7);
        let lp = packed.heldout_loglik(&x, 7);
        assert_eq!(ls.total.to_bits(), lp.total.to_bits());
        for (a, b) in ls.per_row.iter().zip(&lp.per_row) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn impute_beats_column_mean_fill() {
        let (x, samples) = planted(60, 3, 24, 5, 3);
        let mut mrng = Pcg64::new(4);
        let mask = Mask::random(60, 24, 0.35, &mut mrng);
        let engine = PredictEngine::new(&samples, 4, 1);
        let recon = engine.impute(&x, &mask, 9);
        let model_mse = missing_mse(&x, &recon, &mask);
        // baseline: per-column observed mean
        let mut fill = x.clone();
        for j in 0..24 {
            let (mut s, mut c) = (0.0f64, 0.0f64);
            for i in 0..60 {
                if mask.observed(i, j) {
                    s += x[(i, j)];
                    c += 1.0;
                }
            }
            let mu = s / c.max(1.0);
            for i in 0..60 {
                if !mask.observed(i, j) {
                    fill[(i, j)] = mu;
                }
            }
        }
        let base_mse = missing_mse(&x, &fill, &mask);
        assert!(
            model_mse < 0.5 * base_mse,
            "posterior imputation {model_mse:.4} vs mean fill {base_mse:.4}"
        );
    }

    #[test]
    fn impute_passes_observed_entries_through() {
        let (x, samples) = planted(15, 2, 8, 3, 5);
        let mut mrng = Pcg64::new(6);
        let mask = Mask::random(15, 8, 0.4, &mut mrng);
        let engine = PredictEngine::new(&samples, 2, 1);
        let recon = engine.impute(&x, &mask, 11);
        for i in 0..15 {
            for j in 0..8 {
                if mask.observed(i, j) {
                    assert_eq!(recon[(i, j)].to_bits(), x[(i, j)].to_bits());
                }
            }
        }
    }

    #[test]
    fn reconstruct_denoises_toward_truth() {
        let (x, samples) = planted(50, 3, 16, 4, 8);
        let engine = PredictEngine::new(&samples, 4, 2);
        let recon = engine.reconstruct(&x, 13);
        // reconstruction should be close to the observed matrix (which is
        // truth + small noise) — much closer than a zero prediction
        let err = recon.sub(&x).frob2() / x.frob2();
        assert!(err < 0.25, "relative reconstruction error {err}");
    }

    #[test]
    fn heldout_loglik_prefers_matching_rows() {
        let (x, samples) = planted(30, 3, 16, 3, 10);
        let engine = PredictEngine::new(&samples, 4, 1);
        let good = engine.heldout_loglik(&x, 17);
        // scrambled rows should score much worse
        let mut rng = Pcg64::new(11);
        let mut xb = x.clone();
        for v in xb.as_mut_slice().iter_mut() {
            *v = 3.0 * rng.normal();
        }
        let bad = engine.heldout_loglik(&xb, 17);
        assert!(good.total > bad.total + 50.0,
                "good {} vs scrambled {}", good.total, bad.total);
        assert_eq!(good.per_row.len(), 30);
        assert!(good.per_row.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn log_mean_exp_basics() {
        let v = log_mean_exp(&[0.0, 0.0, 0.0]);
        assert!(v.abs() < 1e-12);
        // dominated by the max term
        let v = log_mean_exp(&[-1000.0, 0.0]);
        assert!((v - (0.5f64).ln()).abs() < 1e-9);
        assert_eq!(log_mean_exp(&[f64::NEG_INFINITY]), f64::NEG_INFINITY);
    }

    #[test]
    fn k_zero_samples_are_handled() {
        let x = Mat::from_fn(8, 4, |i, j| (i + j) as f64 * 0.1);
        let samples = vec![mk_sample_with_n(1), mk_sample_with_n(2)];
        let engine = PredictEngine::new(&samples, 2, 1);
        let mask = Mask::full(8, 4);
        let recon = engine.impute(&x, &mask, 3);
        assert!(recon.max_abs_diff(&x) == 0.0); // fully observed ⇒ passthrough
        let ll = engine.heldout_loglik(&x, 3);
        assert!(ll.total.is_finite());
    }

    fn mk_sample_with_n(iter: u64) -> PosteriorSample {
        PosteriorSample {
            iter,
            z: FeatureState::empty(8),
            a: Mat::zeros(0, 4),
            pi: vec![],
            sigma_x: 0.5,
            sigma_a: 1.0,
            alpha: 1.0,
        }
    }
}
