//! Collapsed sampling of the *uninstantiated tail* — the p′ step of the
//! paper's hybrid algorithm (§3).
//!
//! Conditioned on the instantiated features' loadings A⁺, the tail model
//! sees the residuals R = X_{p′} − Z⁺ A⁺ as data: tail loadings A* are
//! marginalised, so resampling tail bits and proposing K_new ~ Poisson(α/N)
//! new features is exactly collapsed linear-Gaussian IBP inference on R,
//! with the conditional prior (m_k − z_nk)/N using the *global* N.
//!
//! Tail features exist only on p′ until the master promotes them into the
//! instantiated set at the next global step, so all bookkeeping here is
//! shard-local.

use crate::linalg::Mat;
use crate::model::state::FeatureState;
use crate::model::{ibp, CollapsedCache, LinGauss};
use crate::obs;
use crate::rng::Pcg64;

/// How K_new is drawn (paper §3 pseudocode: "Propose K_new features from
/// P(K_new) ∝ P(X|Z_new), using a Metropolis-…").
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Proposal {
    /// Evaluate j = 0..=kmax exactly and normalise (G&G-style truncated
    /// Gibbs; our default — lower variance per sweep).
    #[default]
    TruncatedExact,
    /// Metropolis–Hastings with the prior Poisson(α/N) as the proposal,
    /// accepted with the marginal-likelihood ratio — the paper's stated
    /// variant. Prior-as-proposal makes the Hastings ratio exactly
    /// P(X|Z′)/P(X|Z).
    MetropolisHastings,
}

/// Collapsed sampler for the uninstantiated tail on one shard's residuals
/// (the p′ step of the hybrid algorithm).
///
/// The proposer owns only the tail *assignments*; the residual matrix is
/// **borrowed per sweep** (`sweep(&resid, …)`). The instantiated-feature
/// sweeps rewrite the residual between sub-iterations, so at the start of
/// every sweep the cache's X-side statistics (E = Z*ᵀR, G, ‖R‖²) are
/// recomputed from the borrowed matrix — but Z*ᵀZ* depends only on the
/// tail assignments the proposer owns, so it **persists across sweeps**
/// and M⁻¹/L/log|M| are refactorised from it exactly
/// ([`CollapsedCache::reset_data`], O(K³) and a free drift reset),
/// dropping the per-sweep O(BK²) gram rebuild the old code paid on top
/// of the unavoidable O(BKD) for E.
///
/// # Examples
///
/// Residuals with a strong repeated pattern make the tail sampler
/// instantiate a feature for it:
///
/// ```
/// use pibp::linalg::Mat;
/// use pibp::model::state::FeatureState;
/// use pibp::model::LinGauss;
/// use pibp::rng::Pcg64;
/// use pibp::samplers::tail::TailProposer;
///
/// let mut rng = Pcg64::new(7);
/// // every 3rd row carries a large rank-1 pattern, the rest is tiny noise
/// let resid = Mat::from_fn(30, 8, |i, j| {
///     let signal = if i % 3 == 0 { 3.0 } else { 0.0 };
///     signal + 0.05 * (((i * 8 + j) % 7) as f64 - 3.0)
/// });
/// let mut tp = TailProposer::new(FeatureState::empty(30), LinGauss::new(0.3, 1.0));
/// for _ in 0..5 {
///     // alpha = 1, global N = 30, propose up to 4 features, budget 8
///     tp.sweep(&resid, 1.0, 30, 4, 8, &mut rng);
/// }
/// assert!(tp.k_star() >= 1, "structured residuals must instantiate a tail feature");
/// let tail = tp.take_tail();        // hand the bits to the master…
/// assert_eq!(tp.k_star(), 0);       // …which resets the proposer
/// assert!(tail.check_invariants());
/// ```
pub struct TailProposer {
    /// Shard rows B (shape contract for every borrowed residual).
    rows: usize,
    /// Shard-local tail assignments (B × K*). Private on purpose: the
    /// carried `cache`'s Z-side statistics are only valid because every
    /// mutation goes through tracked operations in [`Self::sweep`] /
    /// [`Self::take_tail`] — direct writes would silently stale them.
    z_tail: FeatureState,
    lg: LinGauss,
    pub proposal: Proposal,
    /// Collapsed machinery carried across sweeps; the Z-side statistics
    /// stay valid because every change to `z_tail` goes through tracked
    /// cache operations. `None` until the first sweep / after `take_tail`.
    cache: Option<CollapsedCache>,
}

impl TailProposer {
    /// Build from carried-over tail assignments (pass
    /// `FeatureState::empty(b)` on first use). Cheap: no cache is built
    /// until a residual is seen in [`Self::sweep`].
    pub fn new(z_tail: FeatureState, lg: LinGauss) -> Self {
        Self {
            rows: z_tail.n(),
            z_tail,
            lg,
            proposal: Proposal::default(),
            cache: None,
        }
    }

    pub fn with_proposal(mut self, proposal: Proposal) -> Self {
        self.proposal = proposal;
        self
    }

    #[inline]
    pub fn k_star(&self) -> usize {
        self.z_tail.k()
    }

    /// One collapsed sweep over all shard rows of `resid` (the current
    /// X_p′ − Z⁺ A⁺, B × D): resample existing tail bits, then the
    /// truncated-exact K_new step per row.
    /// `n_global` is the full data-set N (the prior's denominator);
    /// `k_budget` caps how many new features may still be created.
    pub fn sweep(
        &mut self,
        resid: &Mat,
        alpha: f64,
        n_global: usize,
        kmax_new: usize,
        k_budget: usize,
        rng: &mut Pcg64,
    ) {
        assert_eq!(resid.rows(), self.rows, "residual shape changed");
        let b = self.rows;
        // the instantiated sweeps rewrote the residual since the last
        // call: recompute the X-side statistics (E, G, ‖R‖²) and let
        // reset_data refactorise M from the exact cached Z*ᵀZ* — the
        // carried cache is as drift-free as a full rebuild, minus the
        // O(BK²) gram
        let mut carried = None;
        if let Some(mut c) = self.cache.take() {
            if c.k() == self.z_tail.k()
                && c.ratio() == self.lg.ratio()
                && c.reset_data_from_state(resid, &self.z_tail)
            {
                carried = Some(c);
            }
        }
        let mut cache = carried.unwrap_or_else(|| {
            CollapsedCache::from_state(resid, &self.z_tail, self.lg.ratio())
        });
        // §Perf L3-2: the Poisson(α/N) pmf is row-invariant — precompute
        // it once per sweep instead of paying ln_gamma per (row, j).
        let lambda = alpha / n_global as f64;
        let logpmf: Vec<f64> = (0..=kmax_new)
            .map(|j| ibp::log_poisson_pmf(j, lambda))
            .collect();
        for row in 0..b {
            self.update_row(
                &mut cache, resid, row, &logpmf, n_global, kmax_new, k_budget,
                rng,
            );
        }
        // tail columns that died stay dead — drop them now so the
        // promotion payload is minimal. The cache compacts its own
        // statistics (dead columns contribute exact zeros) and is kept
        // for the next sub-iteration's sweep.
        let before = self.z_tail.k();
        let keep = self.z_tail.compact();
        if self.z_tail.k() != before && !cache.retain_features(&keep) {
            obs::inc(obs::Counter::CacheSingularFallback);
            obs::warn_once(
                obs::Warn::CacheSingular,
                "tail cache rank-1 update went singular; falling back to a full refresh",
            );
            cache.refresh_from_state(resid, &self.z_tail, self.lg.ratio());
        }
        self.cache = Some(cache);
    }

    #[allow(clippy::too_many_arguments)]
    fn update_row(
        &mut self,
        cache: &mut CollapsedCache,
        resid: &Mat,
        row: usize,
        logpmf: &[f64],
        n_global: usize,
        kmax_new: usize,
        k_budget: usize,
        rng: &mut Pcg64,
    ) {
        let k = self.z_tail.k();
        let x_row: Vec<f64> = resid.row(row).to_vec();
        let mut z_cur = self.z_tail.row_f64(row);
        if k > 0 {
            let m_minus: Vec<usize> = (0..k)
                .map(|j| self.z_tail.m()[j] - self.z_tail.get(row, j) as usize)
                .collect();
            if cache.remove_row(&z_cur, &x_row) {
                obs::inc(obs::Counter::CacheRank1Ops);
            } else {
                obs::inc(obs::Counter::CacheSingularFallback);
                obs::warn_once(
                    obs::Warn::CacheSingular,
                    "tail cache rank-1 update went singular; falling back to a full refresh",
                );
                self.rebuild_cache_excluding(cache, resid, row, &x_row);
            }
            for j in 0..k {
                if m_minus[j] == 0 {
                    z_cur[j] = 0.0;
                    continue;
                }
                let prior_logit = (m_minus[j] as f64).ln()
                    - ((n_global - m_minus[j]) as f64).ln();
                let mut z1 = z_cur.clone();
                z1[j] = 1.0;
                let mut z0 = z_cur;
                z0[j] = 0.0;
                let mut dll = cache.candidate_loglik(&z1, &x_row, &self.lg)
                    - cache.candidate_loglik(&z0, &x_row, &self.lg);
                if !dll.is_finite() {
                    // drift poisoned the SM denominator: rebuild from
                    // exact statistics (row excluded) and retry once
                    obs::inc(obs::Counter::CacheNanRetry);
                    obs::warn_once(
                        obs::Warn::CacheNan,
                        "tail cache produced a non-finite weight; refreshed and retried",
                    );
                    self.rebuild_cache_excluding(cache, resid, row, &x_row);
                    dll = cache.candidate_loglik(&z1, &x_row, &self.lg)
                        - cache.candidate_loglik(&z0, &x_row, &self.lg);
                    debug_assert!(dll.is_finite(), "fresh cache gave NaN weight");
                }
                let logit = prior_logit + dll;
                let u = rng.uniform();
                z_cur = if (u / (1.0 - u)).ln() < logit { z1 } else { z0 };
            }
        }
        // K_new ~ P(j) ∝ Poisson(j; α/N) · P(R | Z* ∪ j singletons)
        // (batched Schur-complement evaluation — §Perf L3-3)
        let kmax = kmax_new.min(k_budget.saturating_sub(self.z_tail.k()));
        let mut logw =
            cache.candidate_loglik_aug_batch(&z_cur, &x_row, kmax, &self.lg);
        if logw.iter().any(|w| w.is_nan()) {
            // poisoned denominator: rebuild (row excluded) and retry once
            obs::inc(obs::Counter::CacheNanRetry);
            obs::warn_once(
                obs::Warn::CacheNan,
                "tail cache produced a non-finite weight; refreshed and retried",
            );
            self.rebuild_cache_excluding(cache, resid, row, &x_row);
            logw = cache.candidate_loglik_aug_batch(&z_cur, &x_row, kmax, &self.lg);
        }
        let k_new = match self.proposal {
            Proposal::TruncatedExact => {
                let weighted: Vec<f64> = logw
                    .iter()
                    .enumerate()
                    .map(|(j, ll)| ll + logpmf[j])
                    .collect();
                rng.categorical_log(&weighted)
            }
            Proposal::MetropolisHastings if logpmf.len() >= 2 => {
                // propose j′ ~ Poisson(α/N) (prior), accept with the
                // likelihood ratio; current state is j = 0 new features
                // for this row this visit.
                let lambda = (logpmf[1] - logpmf[0]).exp(); // ln λ − ln 1!
                let j_prop = (rng.poisson(lambda) as usize).min(kmax);
                if j_prop == 0 {
                    0
                } else {
                    obs::inc(obs::Counter::TailMhProposed);
                    if (logw[j_prop] - logw[0]) > rng.uniform().ln() {
                        obs::inc(obs::Counter::TailMhAccepted);
                        j_prop
                    } else {
                        0
                    }
                }
            }
            Proposal::MetropolisHastings => 0,
        };
        for (j, &v) in z_cur.iter().enumerate() {
            self.z_tail.set(row, j, v as u8);
        }
        if k_new > 0 {
            let first = self.z_tail.add_features(k_new);
            for j in 0..k_new {
                self.z_tail.set(row, first + j, 1);
            }
            // new columns are empty in the cached Z* (this row is
            // excluded): block-extend the statistics — no O(B·…) rebuild
            cache.append_empty_features(k_new);
        }
        if self.z_tail.k() > 0 {
            let z_row = self.z_tail.row_f64(row);
            if cache.insert_row(&z_row, &x_row) {
                obs::inc(obs::Counter::CacheRank1Ops);
            } else {
                obs::inc(obs::Counter::CacheSingularFallback);
                obs::warn_once(
                    obs::Warn::CacheSingular,
                    "tail cache rank-1 update went singular; falling back to a full refresh",
                );
                cache.refresh_from_state(resid, &self.z_tail, self.lg.ratio());
            }
        }
    }

    /// Rebuild `cache` from exact statistics with `row` excluded — the
    /// sweep's recovery path when a rank-1 update or candidate weight
    /// degenerates. Correct ONLY while `row`'s resampled bits have not
    /// yet been committed to `z_tail` (commits happen at the end of
    /// [`Self::update_row`]), so `row_f64(row)` matches what the cache
    /// held; every call site sits before that commit.
    fn rebuild_cache_excluding(
        &self,
        cache: &mut CollapsedCache,
        resid: &Mat,
        row: usize,
        x_row: &[f64],
    ) {
        cache.refresh_from_state(resid, &self.z_tail, self.lg.ratio());
        if self.z_tail.k() > 0 {
            let z_orig = self.z_tail.row_f64(row);
            let ok = cache.remove_row(&z_orig, x_row);
            debug_assert!(ok, "remove after refresh must succeed");
        }
    }

    /// Hand the tail assignments to the master for promotion and reset.
    pub fn take_tail(&mut self) -> FeatureState {
        self.cache = None; // the machinery belonged to the departing Z*
        std::mem::replace(&mut self.z_tail, FeatureState::empty(self.rows))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn discovers_planted_residual_feature() {
        // residuals contain one strong rank-1 binary pattern: the tail
        // sampler must instantiate ≈1 feature for it.
        let mut rng = Pcg64::new(1);
        let b = 60;
        let d = 16;
        let pattern: Vec<f64> = (0..d).map(|j| if j % 2 == 0 { 2.5 } else { -2.0 }).collect();
        let member: Vec<bool> = (0..b).map(|i| i % 3 == 0).collect();
        let mut resid = Mat::from_fn(b, d, |_, _| 0.2 * rng.normal());
        for i in 0..b {
            if member[i] {
                for j in 0..d {
                    resid[(i, j)] += pattern[j];
                }
            }
        }
        let lg = LinGauss::new(0.25, 1.5);
        let mut tp = TailProposer::new(FeatureState::empty(b), lg);
        for _ in 0..8 {
            tp.sweep(&resid, 2.0, 1000, 4, 16, &mut rng);
        }
        assert!(
            (1..=2).contains(&tp.k_star()),
            "expected ≈1 tail feature, got {}",
            tp.k_star()
        );
        // membership should match the planted pattern closely
        let z = tp.take_tail();
        let best_col = (0..z.k())
            .max_by_key(|&k| z.m()[k])
            .unwrap();
        let agree = (0..b)
            .filter(|&i| (z.get(i, best_col) == 1) == member[i])
            .count();
        assert!(agree as f64 / b as f64 > 0.9, "agreement {}", agree as f64 / b as f64);
    }

    #[test]
    fn pure_noise_stays_nearly_empty() {
        let mut rng = Pcg64::new(2);
        let resid = Mat::from_fn(50, 12, |_, _| 0.3 * rng.normal());
        let lg = LinGauss::new(0.3, 1.0);
        let mut tp = TailProposer::new(FeatureState::empty(50), lg);
        for _ in 0..5 {
            tp.sweep(&resid, 1.0, 1000, 4, 16, &mut rng);
        }
        assert!(tp.k_star() <= 1, "noise grew {} features", tp.k_star());
    }

    #[test]
    fn respects_k_budget() {
        let mut rng = Pcg64::new(3);
        // very structured residuals that would like many features
        let resid = Mat::from_fn(40, 10, |i, j| ((i * j) % 7) as f64 - 3.0);
        let lg = LinGauss::new(0.2, 1.5);
        let mut tp = TailProposer::new(FeatureState::empty(40), lg);
        for _ in 0..5 {
            tp.sweep(&resid, 3.0, 500, 4, 3, &mut rng);
        }
        assert!(tp.k_star() <= 3, "budget violated: {}", tp.k_star());
    }

    #[test]
    fn mh_proposal_also_discovers_planted_feature() {
        let mut rng = Pcg64::new(9);
        let b = 60;
        let d = 12;
        let mut resid = Mat::from_fn(b, d, |_, _| 0.2 * rng.normal());
        for i in 0..b {
            if i % 3 == 0 {
                for j in 0..d {
                    resid[(i, j)] += if j % 2 == 0 { 2.5 } else { -2.0 };
                }
            }
        }
        let lg = LinGauss::new(0.25, 1.5);
        let mut tp = TailProposer::new(FeatureState::empty(b), lg)
            .with_proposal(Proposal::MetropolisHastings);
        // MH fires at prior rate α/N per row-visit — use the local N so
        // the expected number of accepted proposals is comfortably > 1
        for _ in 0..20 {
            tp.sweep(&resid, 2.0, b, 4, 16, &mut rng);
        }
        assert!(
            tp.k_star() >= 1 && tp.k_star() <= 3,
            "MH variant found {} features",
            tp.k_star()
        );
    }

    #[test]
    fn mh_on_noise_stays_empty() {
        let mut rng = Pcg64::new(10);
        let resid = Mat::from_fn(40, 10, |_, _| 0.3 * rng.normal());
        let lg = LinGauss::new(0.3, 1.0);
        let mut tp = TailProposer::new(FeatureState::empty(40), lg)
            .with_proposal(Proposal::MetropolisHastings);
        for _ in 0..10 {
            tp.sweep(&resid, 1.0, 1000, 4, 16, &mut rng);
        }
        assert!(tp.k_star() <= 1, "MH grew {} on noise", tp.k_star());
    }

    #[test]
    fn take_tail_resets() {
        let mut rng = Pcg64::new(4);
        let resid = Mat::from_fn(30, 8, |i, _| if i % 2 == 0 { 3.0 } else { -3.0 });
        let lg = LinGauss::new(0.3, 1.5);
        let mut tp = TailProposer::new(FeatureState::empty(30), lg);
        tp.sweep(&resid, 2.0, 100, 4, 8, &mut rng);
        let t = tp.take_tail();
        assert!(t.check_invariants());
        assert_eq!(tp.k_star(), 0);
    }
}
