//! The accelerated Gibbs sampler of Doshi-Velez & Ghahramani (2009) —
//! the paper's reference [2]: "exhibits the mixing quality of a collapsed
//! sampler with the speed of an uncollapsed sampler".
//!
//! The accelerated conditional is the *predictive* form of the collapsed
//! one, `P(x_n | z_n, X₋n, Z₋n) = N(x_n; z_n M₋n⁻¹ E₋n, σ_X²(1 + z_n M₋n⁻¹
//! z_nᵀ) I)`, maintained with rank-1 information-filter updates instead of
//! the joint-marginal ratio — identical stationary distribution (pinned by
//! a test in `collapsed.rs`), cheaper per-bit constant (no `G = E Eᵀ`).
//!
//! Implementation-wise this is [`CollapsedGibbs`] in
//! [`Mode::Predictive`]; this module exists to give the algorithm its own
//! name, constructor and bench identity.

use crate::linalg::Mat;
use crate::model::LinGauss;
use crate::rng::Pcg64;
use crate::samplers::collapsed::{CollapsedGibbs, Mode};
use crate::samplers::SamplerOptions;

pub type AcceleratedGibbs = CollapsedGibbs;

/// Construct the accelerated sampler.
pub fn new(
    x: Mat,
    lg: LinGauss,
    alpha: f64,
    opts: SamplerOptions,
    rng: &mut Pcg64,
) -> AcceleratedGibbs {
    CollapsedGibbs::new(x, lg, alpha, Mode::Predictive, opts, rng)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accelerated_runs_and_mixes() {
        let mut rng = Pcg64::new(1);
        // binary-glyph loadings, Cambridge-style SNR (see collapsed.rs on
        // why extreme-SNR planted problems freeze single-bit Gibbs)
        let z = Mat::from_fn(60, 3, |_, _| if rng.bernoulli(0.5) { 1.0 } else { 0.0 });
        let a = Mat::from_fn(3, 12, |_, _| if rng.bernoulli(0.4) { 1.0 } else { 0.0 });
        let mut x = z.matmul(&a);
        for v in x.as_mut_slice().iter_mut() {
            *v += 0.5 * rng.normal();
        }
        let mut s = new(
            x, LinGauss::new(0.5, 1.0), 1.0,
            SamplerOptions::default(),
            &mut rng,
        );
        let mut last = 0usize;
        for _ in 0..40 {
            last = s.step(&mut rng).k;
        }
        assert!((1..=8).contains(&last), "K={last}");
    }
}
