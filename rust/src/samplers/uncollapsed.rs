//! Uncollapsed Gibbs sampling.
//!
//! Two things live here:
//!
//! 1. [`sweep_rows`] — one uncollapsed Gibbs sweep over a block of rows
//!    given (A, logit π), maintaining the residual matrix R = X − Z A
//!    incrementally. This is the f64 native mirror of the L1 Pallas
//!    `zsweep` kernel: the hybrid workers use either this or the AOT
//!    executable, and integration tests pin the two against each other.
//!
//! 2. [`UncollapsedGibbs`] — the finite-K baseline sampler of paper Eq. 2
//!    (π_k ~ Beta(α/K, 1); no new features ever created). The paper's §2
//!    argument — poor mixing as dimensionality grows because a "good" new
//!    feature must be proposed blindly — is reproduced by the benches.

use crate::linalg::Mat;
use crate::model::state::FeatureState;
use crate::model::{ibp, GlobalParams, LinGauss};
use crate::rng::Pcg64;
use crate::samplers::{IterStats, SamplerOptions};

/// Block-local sweep kernel: one Gibbs sweep over a contiguous row block,
/// columns `0..k_limit`, given loadings `a` and per-feature prior logits.
///
/// `zbits` and `resid` are the raw row-major slices for exactly the
/// block's rows (strides `stride` = K and `d` respectively; see
/// [`FeatureState::rows_bits_mut`]); `resid` must hold X − Z A for those
/// rows on entry and is kept consistent. Column-count changes are
/// accumulated into `m_delta` (length ≥ `k_limit`) for the caller to fold
/// back via [`FeatureState::apply_m_delta`]. Returns the number of flips.
///
/// This is the unit the [`crate::parallel`] executor schedules: it touches
/// nothing outside its slices, so disjoint blocks run concurrently with
/// one RNG substream each and merge by summing `m_delta`s.
#[allow(clippy::too_many_arguments)]
pub fn sweep_block(
    zbits: &mut [u8],
    stride: usize,
    resid: &mut [f64],
    d: usize,
    a: &Mat,
    prior_logit: &[f64],
    inv2s2: f64,
    k_limit: usize,
    rng: &mut Pcg64,
    m_delta: &mut [i64],
) -> usize {
    if k_limit == 0 || d == 0 {
        return 0;
    }
    debug_assert!(k_limit <= stride && k_limit <= a.rows());
    debug_assert!(k_limit <= m_delta.len());
    let b = resid.len() / d;
    debug_assert_eq!(resid.len(), b * d);
    debug_assert_eq!(zbits.len(), b * stride);
    let mut flips = 0;
    for n in 0..b {
        let zrow = &mut zbits[n * stride..n * stride + stride];
        let rrow = &mut resid[n * d..(n + 1) * d];
        for k in 0..k_limit {
            let z_old = zrow[k];
            let arow = a.row(k);
            // r0 = residual with bit k forced to 0
            // dll = loglik(1) − loglik(0) = (2·r0·a_k − a_k·a_k)·inv2s2
            let mut r0_dot_a = 0.0;
            let mut a_dot_a = 0.0;
            if z_old == 1 {
                for j in 0..d {
                    let aj = arow[j];
                    r0_dot_a += (rrow[j] + aj) * aj;
                    a_dot_a += aj * aj;
                }
            } else {
                for j in 0..d {
                    let aj = arow[j];
                    r0_dot_a += rrow[j] * aj;
                    a_dot_a += aj * aj;
                }
            }
            let logit = prior_logit[k] + (2.0 * r0_dot_a - a_dot_a) * inv2s2;
            // sigmoid via logistic sampling: z=1 iff u < σ(logit)
            // ⇔ logit(u) < logit ⇔ ln(u/(1−u)) < logit
            let u = rng.uniform();
            let z_new = if (u / (1.0 - u)).ln() < logit { 1u8 } else { 0u8 };
            if z_new != z_old {
                flips += 1;
                // r ← r0 − z_new·a_k, starting from r = r0 − z_old·a_k
                let sign = z_old as f64 - z_new as f64; // +a if 1→0, −a if 0→1
                for j in 0..d {
                    rrow[j] += sign * arow[j];
                }
                zrow[k] = z_new;
                m_delta[k] += if z_new == 1 { 1 } else { -1 };
            }
        }
    }
    flips
}

/// Packed twin of [`sweep_block`]: the same Gibbs sweep over a row block
/// whose Z bits live in `u64` words (row stride `words_per_row` =
/// ⌈K/64⌉; see [`FeatureState::rows_words_mut`]).
///
/// **Bit-identical to the scalar kernel by construction**: the f64 inner
/// products over D, the uniform draw per (row, column), the flip test and
/// the residual update are copied verbatim — only how z_old is read and
/// z_new written differs, and those are exact bit operations. The
/// differential suite `rust/tests/packed_equivalence.rs` pins Z bits,
/// residual bytes and flip counts against [`sweep_block`] across a seed
/// grid.
#[allow(clippy::too_many_arguments)]
pub fn sweep_block_packed(
    zwords: &mut [u64],
    words_per_row: usize,
    resid: &mut [f64],
    d: usize,
    a: &Mat,
    prior_logit: &[f64],
    inv2s2: f64,
    k_limit: usize,
    rng: &mut Pcg64,
    m_delta: &mut [i64],
) -> usize {
    if k_limit == 0 || d == 0 {
        return 0;
    }
    debug_assert!(k_limit <= words_per_row * 64 && k_limit <= a.rows());
    debug_assert!(k_limit <= m_delta.len());
    let b = resid.len() / d;
    debug_assert_eq!(resid.len(), b * d);
    debug_assert_eq!(zwords.len(), b * words_per_row);
    let mut flips = 0;
    for n in 0..b {
        let zrow = &mut zwords[n * words_per_row..(n + 1) * words_per_row];
        let rrow = &mut resid[n * d..(n + 1) * d];
        for k in 0..k_limit {
            let (wi, bit) = (k / 64, 1u64 << (k % 64));
            let z_old = u8::from(zrow[wi] & bit != 0);
            let arow = a.row(k);
            let mut r0_dot_a = 0.0;
            let mut a_dot_a = 0.0;
            if z_old == 1 {
                for j in 0..d {
                    let aj = arow[j];
                    r0_dot_a += (rrow[j] + aj) * aj;
                    a_dot_a += aj * aj;
                }
            } else {
                for j in 0..d {
                    let aj = arow[j];
                    r0_dot_a += rrow[j] * aj;
                    a_dot_a += aj * aj;
                }
            }
            let logit = prior_logit[k] + (2.0 * r0_dot_a - a_dot_a) * inv2s2;
            let u = rng.uniform();
            let z_new = if (u / (1.0 - u)).ln() < logit { 1u8 } else { 0u8 };
            if z_new != z_old {
                flips += 1;
                let sign = z_old as f64 - z_new as f64;
                for j in 0..d {
                    rrow[j] += sign * arow[j];
                }
                zrow[wi] ^= bit;
                m_delta[k] += if z_new == 1 { 1 } else { -1 };
            }
        }
    }
    flips
}

/// One *serial* Gibbs sweep of `z[rows]` over columns `0..k_limit`: the
/// whole range as a single block on the caller's RNG stream (one uniform
/// per (row, column), row-major order). `resid` must hold X − Z A on
/// entry for the swept rows and is kept consistent. Returns the number of
/// flips.
///
/// The hybrid workers, the serial oracle and the held-out evaluator use
/// [`crate::parallel::par_sweep_rows`] instead, which runs
/// [`sweep_block`]s on per-block RNG substreams so the result is
/// identical for every thread count; this single-stream form remains the
/// finite-K baseline's sweep and the kernel's reference semantics.
#[allow(clippy::too_many_arguments)]
pub fn sweep_rows(
    x: &Mat,
    z: &mut FeatureState,
    resid: &mut Mat,
    a: &Mat,
    prior_logit: &[f64],
    inv2s2: f64,
    rows: std::ops::Range<usize>,
    k_limit: usize,
    rng: &mut Pcg64,
) -> usize {
    debug_assert_eq!(resid.rows(), x.rows());
    debug_assert!(k_limit <= z.k() && k_limit <= a.rows());
    let d = x.cols();
    let mut m_delta = vec![0i64; k_limit];
    let rslice = &mut resid.as_mut_slice()[rows.start * d..rows.end * d];
    let flips = if z.is_packed() {
        let wpr = z.words_per_row();
        sweep_block_packed(
            z.rows_words_mut(rows.clone()),
            wpr,
            rslice,
            d,
            a,
            prior_logit,
            inv2s2,
            k_limit,
            rng,
            &mut m_delta,
        )
    } else {
        let stride = z.k();
        sweep_block(
            z.rows_bits_mut(rows.clone()),
            stride,
            rslice,
            d,
            a,
            prior_logit,
            inv2s2,
            k_limit,
            rng,
            &mut m_delta,
        )
    };
    z.apply_m_delta(&m_delta);
    flips
}

/// Compute the residual matrix X − Z A for a row range (initialisation).
pub fn residuals(x: &Mat, z: &FeatureState, a: &Mat, rows: std::ops::Range<usize>) -> Mat {
    let d = x.cols();
    let mut r = Mat::zeros(x.rows(), d);
    for n in rows {
        let rrow = r.row_mut(n);
        rrow.copy_from_slice(x.row(n));
        for k in 0..z.k().min(a.rows()) {
            if z.get(n, k) == 1 {
                let arow = a.row(k);
                for j in 0..d {
                    rrow[j] -= arow[j];
                }
            }
        }
    }
    r
}

/// The finite-K uncollapsed Gibbs baseline (paper Eq. 2).
pub struct UncollapsedGibbs {
    pub x: Mat,
    pub z: FeatureState,
    pub params: GlobalParams,
    pub k_fixed: usize,
    resid: Mat,
    opts: SamplerOptions,
    iter: usize,
}

impl UncollapsedGibbs {
    pub fn new(
        x: Mat,
        k_fixed: usize,
        lg: LinGauss,
        alpha: f64,
        opts: SamplerOptions,
        rng: &mut Pcg64,
    ) -> Self {
        let n = x.rows();
        let d = x.cols();
        let mut z = FeatureState::empty(n);
        z.add_features(k_fixed);
        // initialise sparse-random Z and prior draws of π, A
        for i in 0..n {
            for k in 0..k_fixed {
                if rng.bernoulli(0.1) {
                    z.set(i, k, 1);
                }
            }
        }
        let pi: Vec<f64> = (0..k_fixed)
            .map(|_| rng.beta(alpha / k_fixed as f64, 1.0))
            .collect();
        let a = Mat::from_fn(k_fixed, d, |_, _| lg.sigma_a * rng.normal());
        let resid = residuals(&x, &z, &a, 0..n);
        Self {
            x,
            z,
            params: GlobalParams { a, pi, lg, alpha },
            k_fixed,
            resid,
            opts,
            iter: 0,
        }
    }

    /// One full iteration: Z sweep, then (π, A, σ, α?) updates.
    pub fn step(&mut self, rng: &mut Pcg64) -> IterStats {
        let n = self.x.rows();
        let d = self.x.cols();
        let inv2s2 = 1.0 / (2.0 * self.params.lg.sigma_x * self.params.lg.sigma_x);
        let prior_logit: Vec<f64> = self
            .params
            .pi
            .iter()
            .map(|&p| {
                let p = p.clamp(1e-12, 1.0 - 1e-12);
                (p / (1.0 - p)).ln()
            })
            .collect();
        sweep_rows(
            &self.x, &mut self.z, &mut self.resid, &self.params.a,
            &prior_logit, inv2s2, 0..n, self.k_fixed, rng,
        );
        // π_k ~ Beta(α/K + m_k, 1 + N − m_k)  (finite-K construction)
        let ak = self.params.alpha / self.k_fixed as f64;
        self.params.pi = self
            .z
            .m()
            .iter()
            .map(|&mk| rng.beta(ak + mk as f64, 1.0 + (n - mk) as f64))
            .collect();
        // A | X, Z (kernel-dispatched suffstats: popcount gram when Z is
        // packed, the dense path otherwise — bit-identical either way)
        let ztz = self.z.gram();
        let ztx = self.z.t_matmul(&self.x);
        self.params.a = self.params.lg.apost_sample(&ztz, &ztx, rng);
        self.resid = residuals(&self.x, &self.z, &self.params.a, 0..n);
        if self.opts.sample_sigmas {
            let rss = self.resid.frob2();
            self.params.lg.sigma_x = ibp::sample_sigma_x(
                rss, n, d, self.opts.sigma_a0, self.opts.sigma_b0, rng,
            );
            self.params.lg.sigma_a = ibp::sample_sigma_a(
                self.params.a.frob2(), self.k_fixed, d,
                self.opts.sigma_a0, self.opts.sigma_b0, rng,
            );
        }
        self.iter += 1;
        let active = self.z.m().iter().filter(|&&m| m > 0).count();
        IterStats {
            iter: self.iter,
            k: active,
            alpha: self.params.alpha,
            sigma_x: self.params.lg.sigma_x,
            sigma_a: self.params.lg.sigma_a,
            train_joint: self.train_joint(),
        }
    }

    /// log P(X | Z, A) + log P(Z | π).
    pub fn train_joint(&self) -> f64 {
        let n = self.x.rows() as f64;
        let zm = self.z.to_mat();
        let ll = self.params.lg.loglik(&self.x, &zm, &self.params.a);
        let mut prior = 0.0;
        for (k, &p) in self.params.pi.iter().enumerate() {
            let p = p.clamp(1e-12, 1.0 - 1e-12);
            let mk = self.z.m()[k] as f64;
            prior += mk * p.ln() + (n - mk) * (1.0 - p).ln();
        }
        ll + prior
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::planted;

    #[test]
    fn residuals_match_definition() {
        let (x, z, a) = planted(20, 4, 6, 1);
        let r = residuals(&x, &z, &a, 0..20);
        let want = x.sub(&z.to_mat().matmul(&a));
        assert!(r.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn sweep_keeps_residuals_consistent() {
        let (x, mut z, a) = planted(30, 5, 8, 2);
        let mut rng = Pcg64::new(3);
        let mut resid = residuals(&x, &z, &a, 0..30);
        let logit = vec![0.0; 5];
        sweep_rows(&x, &mut z, &mut resid, &a, &logit, 2.0, 0..30, 5, &mut rng);
        let want = residuals(&x, &z, &a, 0..30);
        assert!(resid.max_abs_diff(&want) < 1e-10);
        assert!(z.check_invariants());
    }

    #[test]
    fn sweep_recovers_planted_bits() {
        // strong signal + true A ⇒ a single sweep lands near the truth
        let (x, z_true, a) = planted(100, 4, 36, 4);
        let mut z = FeatureState::empty(100);
        z.add_features(4);
        let mut rng = Pcg64::new(5);
        let mut resid = residuals(&x, &z, &a, 0..100);
        let logit = vec![0.0; 4];
        let inv2s2 = 1.0 / (2.0 * 0.01);
        sweep_rows(&x, &mut z, &mut resid, &a, &logit, inv2s2, 0..100, 4, &mut rng);
        let agree: usize = (0..100)
            .map(|i| (0..4).filter(|&k| z.get(i, k) == z_true.get(i, k)).count())
            .sum();
        assert!(agree as f64 / 400.0 > 0.95, "agreement {}", agree as f64 / 400.0);
    }

    #[test]
    fn sweep_respects_row_range_and_k_limit() {
        let (x, mut z, a) = planted(30, 5, 8, 6);
        let snapshot = z.clone();
        let mut resid = residuals(&x, &z, &a, 0..30);
        let logit = vec![0.0; 5];
        let mut rng = Pcg64::new(7);
        sweep_rows(&x, &mut z, &mut resid, &a, &logit, 2.0, 10..20, 3, &mut rng);
        for i in (0..10).chain(20..30) {
            assert_eq!(z.row_bits(i), snapshot.row_bits(i), "row {i} touched");
        }
        for i in 10..20 {
            for k in 3..5 {
                assert_eq!(z.get(i, k), snapshot.get(i, k), "k>{k} touched");
            }
        }
    }

    #[test]
    fn extreme_logit_pins_bits() {
        let (x, mut z, a) = planted(10, 3, 4, 8);
        let mut resid = residuals(&x, &z, &a, 0..10);
        let mut rng = Pcg64::new(9);
        sweep_rows(&x, &mut z, &mut resid, &a, &[1e9; 3], 0.0, 0..10, 3, &mut rng);
        assert!(z.m().iter().all(|&m| m == 10));
        sweep_rows(&x, &mut z, &mut resid, &a, &[-1e9; 3], 0.0, 0..10, 3, &mut rng);
        assert!(z.m().iter().all(|&m| m == 0));
    }

    #[test]
    fn packed_sweep_block_matches_scalar_bitwise() {
        use crate::model::state::Kernel;
        let d = 6usize;
        for k in [5usize, 64, 70] {
            let (x, z0, a) = planted(25, k, d, 14 + k as u64);
            let logit: Vec<f64> = (0..k).map(|j| 0.1 * j as f64 - 0.2).collect();

            let mut zs = z0.clone();
            let mut rs = residuals(&x, &zs, &a, 0..25);
            let mut rng_s = Pcg64::new(7);
            let mut md_s = vec![0i64; k];
            let flips_s = sweep_block(
                zs.rows_bits_mut(0..25), k, rs.as_mut_slice(), d, &a, &logit,
                1.3, k, &mut rng_s, &mut md_s,
            );
            zs.apply_m_delta(&md_s);

            let mut zp = z0.clone();
            zp.set_kernel(Kernel::Packed);
            let wpr = zp.words_per_row();
            let mut rp = residuals(&x, &zp, &a, 0..25);
            let mut rng_p = Pcg64::new(7);
            let mut md_p = vec![0i64; k];
            let flips_p = sweep_block_packed(
                zp.rows_words_mut(0..25), wpr, rp.as_mut_slice(), d, &a, &logit,
                1.3, k, &mut rng_p, &mut md_p,
            );
            zp.apply_m_delta(&md_p);

            assert_eq!(flips_s, flips_p, "K={k}: flip counts diverged");
            assert_eq!(md_s, md_p, "K={k}: m_delta diverged");
            assert_eq!(zs, zp, "K={k}: Z bits diverged");
            assert!(rs.max_abs_diff(&rp) == 0.0, "K={k}: residuals diverged");
            assert_eq!(
                rng_s.next_u64(),
                rng_p.next_u64(),
                "K={k}: RNG consumption diverged"
            );
            assert!(flips_s > 0, "K={k}: sweep never flipped a bit");
            assert!(zp.check_invariants());
        }
    }

    #[test]
    fn baseline_sampler_converges_on_easy_problem() {
        let (x, _, _) = planted(60, 3, 12, 10);
        let mut rng = Pcg64::new(11);
        let mut s = UncollapsedGibbs::new(
            x, 3, LinGauss::new(0.5, 1.5), 1.0,
            SamplerOptions::default(), &mut rng,
        );
        let first = s.step(&mut rng).train_joint;
        let mut last = first;
        for _ in 0..60 {
            last = s.step(&mut rng).train_joint;
        }
        assert!(last > first, "no improvement: {first} → {last}");
        // the finite uncollapsed baseline mixes slowly (the paper's §2
        // motivation) — only require the noise estimate to be heading down
        // from its 1.0-ish start, not to reach the true 0.1.
        assert!(s.params.lg.sigma_x < 1.0, "sigma_x={}", s.params.lg.sigma_x);
    }

    #[test]
    fn deterministic_given_seed() {
        let (x, _, _) = planted(30, 3, 6, 12);
        let run = |seed| {
            let mut rng = Pcg64::new(seed);
            let mut s = UncollapsedGibbs::new(
                x.clone(), 3, LinGauss::new(0.5, 1.0), 1.0,
                SamplerOptions::default(), &mut rng,
            );
            (0..10).map(|_| s.step(&mut rng).train_joint).collect::<Vec<_>>()
        };
        assert_eq!(run(42), run(42));
        assert_ne!(run(42), run(43));
    }
}
