//! Held-out evaluation — the metric plotted in the paper's Figure 1:
//! joint `log P(X_test, Z_test)` on a held-out set, monitored over time.
//!
//! Protocol (DESIGN.md §Held-out metric): freeze the sampler's current
//! global state `(A, π, σ_X)`, run `g_sweeps` uncollapsed Gibbs sweeps on
//! the held-out rows' Z (warm-started between evaluations, never fed back
//! into the chain), and report
//! `log P(X_test | Z_test, A, σ_X) + log P(Z_test | π)`.
//! The same evaluator serves every sampler, so Figure-1 curves are
//! directly comparable.

use crate::linalg::Mat;
use crate::model::state::{FeatureState, Kernel};
use crate::model::GlobalParams;
use crate::parallel::{par_sweep_rows, ExecConfig, ParallelCtx};
use crate::rng::Pcg64;
use crate::samplers::uncollapsed::residuals;

pub struct HeldoutEval {
    pub x_test: Mat,
    z_test: FeatureState,
    g_sweeps: usize,
    /// Executor config for the test-set sweeps. Like every
    /// [`crate::parallel`] sweep, the evaluation is bit-identical for any
    /// thread count.
    exec: ExecConfig,
}

impl HeldoutEval {
    pub fn new(x_test: Mat, g_sweeps: usize) -> Self {
        let n = x_test.rows();
        Self {
            x_test,
            z_test: FeatureState::empty(n),
            g_sweeps,
            exec: ExecConfig::default(),
        }
    }

    /// Run the held-out sweeps on a persistent pool of `threads` lanes
    /// (same results, less wall-clock; the pool is spawned once here and
    /// reused by every `evaluate` call — `threads ≤ 1` runs inline).
    /// Mutates only the context, preserving a previously chosen kernel.
    pub fn with_threads(self, threads: usize) -> Self {
        self.with_ctx(ParallelCtx::pooled(threads))
    }

    /// Like [`Self::with_threads`], but scheduling onto a caller-supplied
    /// context (e.g. a pool shared with other sweep sites).
    pub fn with_ctx(mut self, ctx: ParallelCtx) -> Self {
        self.exec.ctx = ctx;
        self
    }

    /// Select the Z storage kernel for the held-out sweeps. Bit-invariant
    /// — the evaluation trace is identical for either value.
    pub fn with_kernel(mut self, kernel: Kernel) -> Self {
        self.exec.kernel = kernel;
        self.z_test.set_kernel(kernel);
        self
    }

    /// The warm-started held-out Z — checkpointed so a resumed run's
    /// evaluation schedule continues bit-identically (`crate::snapshot`).
    pub fn z_state(&self) -> &FeatureState {
        &self.z_test
    }

    /// Restore the warm-started held-out Z from a checkpoint.
    pub fn restore_z_state(&mut self, z: FeatureState) -> anyhow::Result<()> {
        if z.n() != self.x_test.rows() {
            anyhow::bail!(
                "evaluator snapshot has {} rows, held-out set has {}",
                z.n(),
                self.x_test.rows()
            );
        }
        self.z_test = z;
        // snapshots decode repr-agnostically; adopt the configured kernel
        self.z_test.set_kernel(self.exec.kernel);
        Ok(())
    }

    /// Evaluate the joint held-out log-likelihood under `params`.
    pub fn evaluate(&mut self, params: &GlobalParams, rng: &mut Pcg64) -> f64 {
        let n = self.x_test.rows();
        let k = params.k();
        if k == 0 {
            // no features: Z empty, P(Z|π) = 1
            return params.lg.loglik(
                &self.x_test,
                &Mat::zeros(n, 0),
                &Mat::zeros(0, self.x_test.cols()),
            );
        }
        // resize the warm-started Z to the current K (new features start
        // off; removed features are dropped by rebuilding when K shrank)
        if self.z_test.k() < k {
            self.z_test.add_features(k - self.z_test.k());
        } else if self.z_test.k() > k {
            self.z_test = FeatureState::empty_with(n, self.exec.kernel);
            self.z_test.add_features(k);
        }
        let prior_logit: Vec<f64> = params
            .pi
            .iter()
            .map(|&p| {
                let p = p.clamp(1e-12, 1.0 - 1e-12);
                (p / (1.0 - p)).ln()
            })
            .collect();
        let inv2s2 = 1.0 / (2.0 * params.lg.sigma_x * params.lg.sigma_x);
        let mut resid = residuals(&self.x_test, &self.z_test, &params.a, 0..n);
        for _ in 0..self.g_sweeps {
            par_sweep_rows(
                &mut self.z_test, &mut resid, &params.a, &prior_logit,
                inv2s2, 0..n, k, &self.exec, rng,
            );
        }
        self.joint(params)
    }

    /// log P(X_test | Z_test, A) + log P(Z_test | π) at the current Z_test.
    fn joint(&self, params: &GlobalParams) -> f64 {
        let n = self.x_test.rows() as f64;
        let zm = self.z_test.to_mat();
        let ll = params.lg.loglik(&self.x_test, &zm, &params.a);
        let mut prior = 0.0;
        for (kk, &p) in params.pi.iter().enumerate() {
            let p = p.clamp(1e-12, 1.0 - 1e-12);
            let mk = self.z_test.m()[kk] as f64;
            prior += mk * p.ln() + (n - mk) * (1.0 - p).ln();
        }
        ll + prior
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::LinGauss;

    fn planted_params(k: usize, d: usize, seed: u64) -> (GlobalParams, Mat, Mat) {
        let mut rng = Pcg64::new(seed);
        let a = Mat::from_fn(k, d, |_, _| 2.0 * rng.normal());
        let z = Mat::from_fn(50, k, |_, _| if rng.bernoulli(0.5) { 1.0 } else { 0.0 });
        let mut x = z.matmul(&a);
        for v in x.as_mut_slice().iter_mut() {
            *v += 0.2 * rng.normal();
        }
        let params = GlobalParams {
            a,
            pi: vec![0.5; k],
            lg: LinGauss::new(0.2, 1.0),
            alpha: 1.0,
        };
        (params, x, z)
    }

    #[test]
    fn true_params_beat_wrong_params() {
        let (params, x, _) = planted_params(3, 12, 1);
        let mut rng = Pcg64::new(2);
        let mut ev = HeldoutEval::new(x.clone(), 3);
        let good = ev.evaluate(&params, &mut rng);
        // wrong loadings
        let mut bad = params.clone();
        let mut rng2 = Pcg64::new(3);
        bad.a = Mat::from_fn(3, 12, |_, _| 2.0 * rng2.normal());
        let mut ev2 = HeldoutEval::new(x, 3);
        let badv = ev2.evaluate(&bad, &mut rng);
        assert!(good > badv + 50.0, "good={good} bad={badv}");
    }

    #[test]
    fn warm_start_improves_or_holds() {
        let (params, x, _) = planted_params(4, 16, 4);
        let mut rng = Pcg64::new(5);
        let mut ev = HeldoutEval::new(x, 2);
        let first = ev.evaluate(&params, &mut rng);
        let second = ev.evaluate(&params, &mut rng);
        assert!(second >= first - 25.0, "warm start regressed: {first} → {second}");
    }

    #[test]
    fn handles_feature_count_changes() {
        let (params3, x, _) = planted_params(3, 8, 6);
        let (params5, _, _) = planted_params(5, 8, 7);
        let (params2, _, _) = planted_params(2, 8, 8);
        let mut rng = Pcg64::new(9);
        let mut ev = HeldoutEval::new(x, 2);
        let a = ev.evaluate(&params3, &mut rng);
        let b = ev.evaluate(&params5, &mut rng);
        let c = ev.evaluate(&params2, &mut rng);
        assert!(a.is_finite() && b.is_finite() && c.is_finite());
    }

    #[test]
    fn packed_kernel_evaluation_is_bit_identical() {
        // held-out traces are part of the chain contract: the packed
        // kernel must reproduce the scalar trace bit-for-bit, warm starts
        // and K changes included — in any builder order
        let (params4, x, _) = planted_params(4, 16, 4);
        let (params2, _, _) = planted_params(2, 16, 5);
        let run = |ev: HeldoutEval| {
            let mut ev = ev;
            let mut rng = Pcg64::new(5);
            let mut out = vec![];
            for p in [&params4, &params4, &params2] {
                out.push(ev.evaluate(p, &mut rng).to_bits());
            }
            out
        };
        let scalar = run(HeldoutEval::new(x.clone(), 2).with_threads(2));
        let packed =
            run(HeldoutEval::new(x.clone(), 2).with_kernel(Kernel::Packed).with_threads(2));
        assert_eq!(scalar, packed);
        // kernel applied after the ctx must behave the same
        let packed2 =
            run(HeldoutEval::new(x, 2).with_threads(2).with_kernel(Kernel::Packed));
        assert_eq!(scalar, packed2);
    }

    #[test]
    fn empty_params_ok() {
        let x = Mat::from_fn(10, 4, |i, j| (i + j) as f64 * 0.1);
        let params = GlobalParams {
            a: Mat::zeros(0, 4),
            pi: vec![],
            lg: LinGauss::new(0.5, 1.0),
            alpha: 1.0,
        };
        let mut rng = Pcg64::new(10);
        let mut ev = HeldoutEval::new(x, 3);
        assert!(ev.evaluate(&params, &mut rng).is_finite());
    }
}
