//! The paper's hybrid sampler (§3, Algorithm 1) — serial reference
//! implementation.
//!
//! One iteration:
//! ```text
//! for L sub-iterations:
//!     every processor p: uncollapsed Gibbs sweep of its shard's Z over
//!                        the K⁺ instantiated features, given (π, A)
//!     processor p′ only: collapsed sweep of the uninstantiated tail on
//!                        residuals + Poisson(α/N) new-feature proposals
//! master:
//!     gather sufficient statistics; promote K* tail features into K⁺;
//!     sample A, σ_X, σ_A, π, α; drop dead features; broadcast; pick p′
//! ```
//!
//! This module runs those phases sequentially in one thread — it is the
//! **semantics oracle** the parallel [`crate::coordinator`] is pinned
//! against. To make the pinning *chain-for-chain* rather than merely
//! distributional, the sampler mirrors the coordinator's reproducibility
//! contract exactly:
//!
//! * **RNG streams** — from a root `seed`, the master draws from
//!   `Pcg64::new(seed).split(tags::MASTER)` and simulated worker `p`
//!   draws from `Pcg64::new(seed).split(tags::worker(p))`, the same
//!   derivation used by
//!   `coordinator::master` / `coordinator::worker`; each uncollapsed
//!   sweep follows the [`crate::parallel`] per-row-block discipline (one
//!   parent draw, then `split(tags::block(b))` per block), so the chain is
//!   identical to a coordinator running any `threads_per_worker`;
//! * **draw order** — the master step picks the *next* p′ before sampling
//!   globals (the coordinator needs p′ early for its demotion decision),
//!   and samples A, π, σ_X, σ_A, α in that order;
//! * **arithmetic** — the sufficient statistics (ZᵀZ, ZᵀX, tr XᵀX) are
//!   accumulated shard-by-shard in worker order (FP addition is not
//!   associative, so a global computation would round differently at
//!   P > 1), and the RSS entering the σ_X conditional is assembled from
//!   them (`‖X−ZA‖² = tr XᵀX − 2 tr AᵀZᵀX + tr Aᵀ(ZᵀZ)A`), the same
//!   formula the master uses, so the two implementations agree
//!   bit-for-bit at every P.
//!
//! With demotion disabled (`SamplerOptions { demote_below: 0, .. }` — the
//! serial oracle does not implement the coordinator's demotion
//! optimisation), a coordinator at any P — and any `threads_per_worker` —
//! reproduces this sampler's chain exactly for any number of iterations;
//! see `rust/tests/parallel_equivalence.rs` and
//! `rust/tests/thread_equivalence.rs`. It is also the P = 1
//! configuration measured in Figure 1.

use std::ops::Range;

use crate::linalg::Mat;
use crate::model::state::{FeatureState, Kernel};
use crate::model::{ibp, GlobalParams, LinGauss};
use crate::parallel::{par_sweep_rows, ExecConfig, ParallelCtx};
use crate::rng::{tags, Pcg64};
use crate::samplers::tail::TailProposer;
use crate::samplers::uncollapsed::residuals;
use crate::samplers::{IterStats, SamplerOptions};

#[derive(Clone, Debug)]
pub struct HybridConfig {
    /// Number of (simulated) processors P.
    pub processors: usize,
    /// Sub-iterations L between global steps (paper uses 5).
    pub sub_iters: usize,
    /// Intra-worker sweep threads T. The chain is *identical* for every
    /// value (the executor's per-row-block RNG discipline — see
    /// [`crate::parallel`]); this only changes how the serial oracle's
    /// simulated workers schedule their blocks.
    pub threads_per_worker: usize,
    /// Optional pre-built execution context. `None` (the default) builds
    /// a persistent pool of `threads_per_worker` lanes at construction;
    /// tests pass e.g. [`ParallelCtx::scoped`] to cross-check scheduling
    /// modes — the chain is bit-identical either way.
    pub ctx: Option<ParallelCtx>,
    /// Z storage kernel (scalar bytes or packed u64 words). The chain is
    /// bit-identical for either value — the packed sweep/gram kernels are
    /// exact mirrors (see `rust/tests/packed_equivalence.rs`).
    pub kernel: Kernel,
    pub opts: SamplerOptions,
}

impl Default for HybridConfig {
    fn default() -> Self {
        Self {
            processors: 1,
            sub_iters: 5,
            threads_per_worker: 1,
            ctx: None,
            kernel: Kernel::Scalar,
            opts: SamplerOptions::default(),
        }
    }
}

/// Evenly partition `n` rows into `p` contiguous shards.
///
/// Shards are contiguous, cover `0..n` exactly, and differ in length by at
/// most one (the first `n % p` shards get the extra row).
///
/// # Examples
///
/// ```
/// use pibp::samplers::hybrid::make_shards;
///
/// // n % p != 0: the remainder rows go to the leading shards
/// assert_eq!(make_shards(10, 3), vec![0..4, 4..7, 7..10]);
///
/// // n == p: exactly one row per shard
/// assert!(make_shards(5, 5).iter().all(|s| s.len() == 1));
/// ```
pub fn make_shards(n: usize, p: usize) -> Vec<Range<usize>> {
    assert!(p >= 1 && n >= p, "need at least one row per shard");
    let base = n / p;
    let extra = n % p;
    let mut out = Vec::with_capacity(p);
    let mut start = 0;
    for i in 0..p {
        let len = base + usize::from(i < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

pub struct HybridSampler {
    pub x: Mat,
    /// Instantiated features, all rows (N × K⁺).
    pub z: FeatureState,
    pub params: GlobalParams,
    pub shards: Vec<Range<usize>>,
    pub p_prime: usize,
    cfg: HybridConfig,
    resid: Mat,
    /// Persistent tail assignments on p′ between sub-iterations.
    tail_state: Option<FeatureState>,
    /// Per-shard copies of X (fixed): suff-stat accumulation input.
    x_shards: Vec<Mat>,
    /// Master RNG stream: `Pcg64::new(seed).split(tags::MASTER)`.
    master_rng: Pcg64,
    /// Per-processor streams: `Pcg64::new(seed).split(tags::worker(p))`.
    worker_rngs: Vec<Pcg64>,
    /// ‖X‖², fixed for the run (the σ_X conditional's tr XᵀX term).
    tr_xx: f64,
    /// Persistent executor: the pool (if any) is spawned once here and
    /// reused by every simulated worker's sweep in every iteration.
    exec: ExecConfig,
    iter: usize,
}

impl HybridSampler {
    /// Build the sampler. `seed` fully determines the chain: the master
    /// and per-processor RNG streams are derived from it exactly as the
    /// parallel coordinator derives its own.
    pub fn new(x: Mat, lg: LinGauss, alpha: f64, cfg: HybridConfig, seed: u64) -> Self {
        let n = x.rows();
        let shards = make_shards(n, cfg.processors);
        let mut master_rng = Pcg64::new(seed).split(tags::MASTER);
        let worker_rngs: Vec<Pcg64> = (0..cfg.processors)
            .map(|p| Pcg64::new(seed).split(tags::worker(p)))
            .collect();
        let p_prime = master_rng.below(cfg.processors as u64) as usize;
        // start from the empty feature set: the tail sampler on p′
        // bootstraps the first features, exactly as the algorithm states.
        let z = FeatureState::empty_with(n, cfg.kernel);
        let params = GlobalParams { a: Mat::zeros(0, x.cols()), pi: vec![], lg, alpha };
        let resid = x.clone();
        // Per-shard copies of X, fixed for the run: reused every master
        // step for the shard-ordered suff-stat accumulation below.
        let d = x.cols();
        let x_shards: Vec<Mat> = shards
            .iter()
            .map(|sh| Mat::from_fn(sh.len(), d, |i, j| x[(sh.start + i, j)]))
            .collect();
        // tr XᵀX = Σ_p ‖X_p‖² accumulated in worker order — the same
        // association the coordinator's merge uses, so the σ_X
        // conditional sees bit-identical input at any P (a global frob2
        // groups the additions differently and rounds differently).
        let tr_xx = x_shards.iter().fold(0.0f64, |acc, xp| acc + xp.frob2());
        let exec = ExecConfig::with_ctx(
            cfg.ctx
                .clone()
                .unwrap_or_else(|| ParallelCtx::pooled(cfg.threads_per_worker)),
        )
        .with_kernel(cfg.kernel);
        Self {
            x,
            z,
            params,
            shards,
            p_prime,
            cfg,
            resid,
            tail_state: None,
            x_shards,
            master_rng,
            worker_rngs,
            tr_xx,
            exec,
            iter: 0,
        }
    }

    /// One global iteration (L sub-iterations + master step).
    pub fn step(&mut self) -> IterStats {
        let k_plus = self.z.k();
        let inv2s2 =
            1.0 / (2.0 * self.params.lg.sigma_x * self.params.lg.sigma_x);
        let prior_logit: Vec<f64> = self
            .params
            .pi
            .iter()
            .map(|&p| {
                let p = p.clamp(1e-12, 1.0 - 1e-12);
                (p / (1.0 - p)).ln()
            })
            .collect();

        let shard_pp = self.shards[self.p_prime].clone();
        let b = shard_pp.len();
        let carried = self
            .tail_state
            .take()
            .unwrap_or_else(|| FeatureState::empty_with(b, self.cfg.kernel));
        let mut tp = TailProposer::new(carried, self.params.lg);
        // reusable view of p′'s residual rows (refreshed per sub-iteration)
        let mut local_resid = Mat::zeros(b, self.x.cols());
        for _l in 0..self.cfg.sub_iters {
            // --- every processor: uncollapsed sweep over K⁺ (each on its
            //     own RNG stream, like the real worker threads; blocks of
            //     each shard run on per-block substreams) ---
            for p in 0..self.cfg.processors {
                let shard = self.shards[p].clone();
                if k_plus > 0 {
                    par_sweep_rows(
                        &mut self.z, &mut self.resid, &self.params.a,
                        &prior_logit, inv2s2, shard, k_plus, &self.exec,
                        &mut self.worker_rngs[p],
                    );
                }
            }
            // --- p′: collapsed tail on its shard's residuals ---
            for i in 0..b {
                local_resid
                    .row_mut(i)
                    .copy_from_slice(self.resid.row(shard_pp.start + i));
            }
            let p_prime = self.p_prime;
            tp.sweep(
                &local_resid,
                self.params.alpha,
                self.x.rows(),
                self.cfg.opts.kmax_new,
                self.cfg.opts.k_cap.saturating_sub(k_plus),
                &mut self.worker_rngs[p_prime],
            );
        }
        self.tail_state = Some(tp.take_tail());

        self.master_step();
        self.iter += 1;
        IterStats {
            iter: self.iter,
            k: self.z.k(),
            alpha: self.params.alpha,
            sigma_x: self.params.lg.sigma_x,
            sigma_a: self.params.lg.sigma_a,
            train_joint: self.train_joint(),
        }
    }

    /// Master: promote tail → K⁺, drop dead features, resample globals,
    /// rotate p′ — mirroring `coordinator::master::Coordinator::global_step`
    /// draw-for-draw on the master RNG stream.
    fn master_step(&mut self) {
        let n = self.x.rows();
        let d = self.x.cols();
        // --- promote K* tail features ---
        if let Some(tail) = self.tail_state.take() {
            let k_star = tail.k();
            if k_star > 0 {
                let first = self.z.add_features(k_star);
                let shard = self.shards[self.p_prime].clone();
                for (local, global_row) in shard.enumerate() {
                    for j in 0..k_star {
                        if tail.get(local, j) == 1 {
                            self.z.set(global_row, first + j, 1);
                        }
                    }
                }
            }
        }
        // --- drop features that died during the sweeps ---
        self.z.compact();
        let k = self.z.k();
        // --- rotate p′ FIRST: the coordinator draws the next p′ before
        //     sampling globals (its demotion decision needs it) ---
        let p_next = self.master_rng.below(self.cfg.processors as u64) as usize;
        // --- sample globals given the (promoted, compacted) Z ---
        if k > 0 {
            // ZᵀZ / ZᵀX merged shard-by-shard in worker order, replicating
            // the coordinator master's accumulation so every FP rounding
            // matches at any P (ZᵀZ is integer-valued and order-exact;
            // ZᵀX and tr XᵀX are not associativity-proof).
            let mut ztz = Mat::zeros(k, k);
            let mut ztx = Mat::zeros(k, d);
            for (sh, xp) in self.shards.iter().zip(&self.x_shards) {
                ztz.add_assign(&self.z.gram_range(sh.clone()));
                ztx.add_assign(&self.z.t_matmul_range(sh.clone(), xp));
            }
            self.params.a =
                self.params.lg.apost_sample(&ztz, &ztx, &mut self.master_rng);
            self.params.pi = ibp::sample_pi(self.z.m(), n, &mut self.master_rng);
            if self.cfg.opts.sample_sigmas {
                // RSS from the sufficient statistics and the fresh A —
                // identical arithmetic to the coordinator's master:
                // ‖X−ZA‖² = tr(XᵀX) − 2·tr(AᵀZᵀX) + tr(Aᵀ ZᵀZ A)
                let a = &self.params.a;
                let za = ztz.matmul(a);
                let rss =
                    (self.tr_xx - 2.0 * a.dot(&ztx) + a.dot(&za)).max(1e-12);
                self.params.lg.sigma_x = ibp::sample_sigma_x(
                    rss, n, d, self.cfg.opts.sigma_a0, self.cfg.opts.sigma_b0,
                    &mut self.master_rng,
                );
                self.params.lg.sigma_a = ibp::sample_sigma_a(
                    self.params.a.frob2(), k, d,
                    self.cfg.opts.sigma_a0, self.cfg.opts.sigma_b0,
                    &mut self.master_rng,
                );
            }
        } else {
            self.params.a = Mat::zeros(0, d);
            self.params.pi.clear();
            if self.cfg.opts.sample_sigmas {
                self.params.lg.sigma_x = ibp::sample_sigma_x(
                    self.tr_xx, n, d,
                    self.cfg.opts.sigma_a0, self.cfg.opts.sigma_b0,
                    &mut self.master_rng,
                );
            }
        }
        if self.cfg.opts.sample_alpha {
            self.params.alpha = ibp::sample_alpha(k, n, &mut self.master_rng);
        }
        self.resid = residuals(&self.x, &self.z, &self.params.a, 0..n);
        self.p_prime = p_next;
    }

    /// Joint train log P(X, Z | A, π): the uncollapsed representation's
    /// joint (what the instantiated state defines).
    pub fn train_joint(&self) -> f64 {
        let n = self.x.rows() as f64;
        if self.z.k() == 0 {
            return self.params.lg.loglik(
                &self.x, &Mat::zeros(self.x.rows(), 0), &Mat::zeros(0, self.x.cols()),
            );
        }
        let zm = self.z.to_mat();
        let ll = self.params.lg.loglik(&self.x, &zm, &self.params.a);
        let mut prior = 0.0;
        for (kk, &p) in self.params.pi.iter().enumerate() {
            let p = p.clamp(1e-12, 1.0 - 1e-12);
            let mk = self.z.m()[kk] as f64;
            prior += mk * p.ln() + (n - mk) * (1.0 - p).ln();
        }
        ll + prior
    }

    pub fn k(&self) -> usize {
        self.z.k()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::cambridge::{generate, CambridgeConfig};

    #[test]
    fn shards_partition_exactly() {
        for (n, p) in [(10, 3), (100, 7), (5, 5), (1000, 1)] {
            let sh = make_shards(n, p);
            assert_eq!(sh.len(), p);
            assert_eq!(sh[0].start, 0);
            assert_eq!(sh.last().unwrap().end, n);
            for w in sh.windows(2) {
                assert_eq!(w[0].end, w[1].start);
            }
            let max = sh.iter().map(|r| r.len()).max().unwrap();
            let min = sh.iter().map(|r| r.len()).min().unwrap();
            assert!(max - min <= 1, "unbalanced: {sh:?}");
        }
    }

    #[test]
    fn shards_edge_case_one_row_per_processor() {
        // n == p: every shard is a singleton, in order.
        for n in [1usize, 2, 7, 64] {
            let sh = make_shards(n, n);
            assert_eq!(sh.len(), n);
            for (i, s) in sh.iter().enumerate() {
                assert_eq!(*s, i..i + 1, "shard {i} of n=p={n}");
            }
        }
    }

    #[test]
    fn shards_edge_case_remainder_rows() {
        // n % p != 0: exactly (n % p) shards get one extra row, and they
        // are the leading ones.
        for (n, p) in [(10usize, 3usize), (11, 4), (13, 5), (999, 8)] {
            let sh = make_shards(n, p);
            let base = n / p;
            let extra = n % p;
            assert_ne!(extra, 0, "pick n,p with a remainder");
            for (i, s) in sh.iter().enumerate() {
                let want = base + usize::from(i < extra);
                assert_eq!(s.len(), want, "shard {i} of ({n},{p})");
            }
            assert_eq!(sh.iter().map(|s| s.len()).sum::<usize>(), n);
        }
    }

    #[test]
    #[should_panic(expected = "at least one row per shard")]
    fn shards_reject_more_processors_than_rows() {
        make_shards(3, 4);
    }

    #[test]
    fn bootstraps_features_from_empty() {
        let (ds, _) = generate(&CambridgeConfig { n: 60, seed: 1, ..Default::default() });
        let mut s = HybridSampler::new(
            ds.x, LinGauss::new(0.5, 1.0), 1.0,
            HybridConfig {
                processors: 1,
                sub_iters: 5,
                opts: SamplerOptions { sample_sigmas: false, ..Default::default() },
                ..Default::default()
            },
            2,
        );
        assert_eq!(s.k(), 0);
        for _ in 0..15 {
            s.step();
        }
        assert!(s.k() >= 2, "no features instantiated: K={}", s.k());
    }

    #[test]
    fn recovers_cambridge_truth_serial() {
        let (ds, _) = generate(&CambridgeConfig { n: 150, seed: 3, ..Default::default() });
        let mut s = HybridSampler::new(
            ds.x, LinGauss::new(0.5, 1.0), 1.0,
            HybridConfig::default(), 4,
        );
        let mut ks = vec![];
        for _ in 0..40 {
            ks.push(s.step().k);
        }
        let tail = &ks[25..];
        let mean_k = tail.iter().sum::<usize>() as f64 / tail.len() as f64;
        // the hybrid's uncollapsed feature-death is a slow random walk, so
        // over short runs it carries some near-zero-loading extras on top
        // of the 4 true glyphs (visible in the paper's own Fig. 2 bottom
        // row). Require the truth to be found without runaway growth.
        assert!((2.0..=14.0).contains(&mean_k), "K trace {ks:?}");
        assert!(s.z.check_invariants());
    }

    #[test]
    fn multi_processor_matches_single_distributionally() {
        let (ds, _) = generate(&CambridgeConfig { n: 120, seed: 5, ..Default::default() });
        let run = |p: usize, seed: u64| {
            let mut s = HybridSampler::new(
                ds.x.clone(), LinGauss::new(0.5, 1.0), 1.0,
                HybridConfig {
                    processors: p,
                    sub_iters: 5,
                    opts: SamplerOptions { sample_sigmas: false, ..Default::default() },
                    ..Default::default()
                },
                seed,
            );
            let mut acc = 0.0;
            for i in 0..45 {
                let st = s.step();
                if i >= 25 {
                    acc += st.k as f64;
                }
            }
            acc / 20.0
        };
        let k1 = run(1, 6);
        let k3 = run(3, 7);
        assert!(
            (k1 - k3).abs() <= 3.0,
            "P=1 K≈{k1} vs P=3 K≈{k3}: parallelism changed the posterior"
        );
    }

    #[test]
    fn sigma_estimation_tracks_truth() {
        let (ds, _) = generate(&CambridgeConfig { n: 200, sigma_x: 0.5, seed: 8, ..Default::default() });
        let mut s = HybridSampler::new(
            ds.x, LinGauss::new(1.5, 1.0), 1.0,
            HybridConfig::default(), 9,
        );
        let mut sx = vec![];
        for i in 0..50 {
            let st = s.step();
            if i >= 30 {
                sx.push(st.sigma_x);
            }
        }
        let mean = sx.iter().sum::<f64>() / sx.len() as f64;
        assert!((mean - 0.5).abs() < 0.15, "sigma_x≈{mean}, truth 0.5");
    }

    #[test]
    fn packed_kernel_reproduces_scalar_chain_exactly() {
        // full hybrid chain (sweeps, tail proposals, promotion,
        // compaction, global draws) must be bit-identical under the
        // packed Z kernel, including at P > 1 / T > 1
        let (ds, _) = generate(&CambridgeConfig { n: 60, seed: 10, ..Default::default() });
        let run = |kernel: Kernel| {
            let mut s = HybridSampler::new(
                ds.x.clone(), LinGauss::new(0.5, 1.0), 1.0,
                HybridConfig {
                    processors: 2,
                    threads_per_worker: 2,
                    kernel,
                    ..Default::default()
                },
                11,
            );
            let trace: Vec<_> = (0..10)
                .map(|_| {
                    let st = s.step();
                    (st.k, st.alpha.to_bits(), st.sigma_x.to_bits(),
                     st.sigma_a.to_bits(), st.train_joint.to_bits())
                })
                .collect();
            (trace, s.z.clone(), s.params.a.clone())
        };
        let scalar = run(Kernel::Scalar);
        let packed = run(Kernel::Packed);
        assert_eq!(scalar.0, packed.0, "iteration trace diverged");
        assert_eq!(scalar.1, packed.1, "final Z diverged");
        assert!(scalar.2.max_abs_diff(&packed.2) == 0.0, "final A diverged");
        assert!(packed.1.is_packed() && packed.1.check_invariants());
        assert!(scalar.0.last().unwrap().0 > 0, "chain never grew features");
    }

    #[test]
    fn deterministic_given_seed() {
        let (ds, _) = generate(&CambridgeConfig { n: 50, seed: 10, ..Default::default() });
        let run = |seed: u64| {
            let mut s = HybridSampler::new(
                ds.x.clone(), LinGauss::new(0.5, 1.0), 1.0,
                HybridConfig { processors: 2, ..Default::default() },
                seed,
            );
            (0..8).map(|_| s.step().train_joint).collect::<Vec<_>>()
        };
        assert_eq!(run(11), run(11));
        assert_ne!(run(11), run(12));
    }
}
