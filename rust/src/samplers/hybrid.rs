//! The paper's hybrid sampler (§3, Algorithm 1) — serial reference
//! implementation.
//!
//! One iteration:
//! ```text
//! for L sub-iterations:
//!     every processor p: uncollapsed Gibbs sweep of its shard's Z over
//!                        the K⁺ instantiated features, given (π, A)
//!     processor p′ only: collapsed sweep of the uninstantiated tail on
//!                        residuals + Poisson(α/N) new-feature proposals
//! master:
//!     gather sufficient statistics; promote K* tail features into K⁺;
//!     sample A, σ_X, σ_A, π, α; drop dead features; broadcast; pick p′
//! ```
//!
//! This module runs those phases sequentially in one thread — it is the
//! semantics oracle that the parallel [`crate::coordinator`] must match
//! (for P = 1, chain-for-chain given the same seed; for P > 1,
//! distributionally). It is also the P = 1 configuration measured in
//! Figure 1.

use std::ops::Range;

use crate::linalg::Mat;
use crate::model::state::FeatureState;
use crate::model::{ibp, GlobalParams, LinGauss};
use crate::rng::Pcg64;
use crate::samplers::tail::TailProposer;
use crate::samplers::uncollapsed::{residuals, sweep_rows};
use crate::samplers::{IterStats, SamplerOptions};

#[derive(Clone, Debug)]
pub struct HybridConfig {
    /// Number of (simulated) processors P.
    pub processors: usize,
    /// Sub-iterations L between global steps (paper uses 5).
    pub sub_iters: usize,
    pub opts: SamplerOptions,
}

impl Default for HybridConfig {
    fn default() -> Self {
        Self { processors: 1, sub_iters: 5, opts: SamplerOptions::default() }
    }
}

/// Evenly partition `n` rows into `p` contiguous shards.
pub fn make_shards(n: usize, p: usize) -> Vec<Range<usize>> {
    assert!(p >= 1 && n >= p, "need at least one row per shard");
    let base = n / p;
    let extra = n % p;
    let mut out = Vec::with_capacity(p);
    let mut start = 0;
    for i in 0..p {
        let len = base + usize::from(i < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

pub struct HybridSampler {
    pub x: Mat,
    /// Instantiated features, all rows (N × K⁺).
    pub z: FeatureState,
    pub params: GlobalParams,
    pub shards: Vec<Range<usize>>,
    pub p_prime: usize,
    cfg: HybridConfig,
    resid: Mat,
    /// Persistent tail assignments on p′ between sub-iterations.
    tail_state: Option<FeatureState>,
    iter: usize,
}

impl HybridSampler {
    pub fn new(
        x: Mat,
        lg: LinGauss,
        alpha: f64,
        cfg: HybridConfig,
        rng: &mut Pcg64,
    ) -> Self {
        let n = x.rows();
        let shards = make_shards(n, cfg.processors);
        let p_prime = rng.below(cfg.processors as u64) as usize;
        // start from the empty feature set: the tail sampler on p′
        // bootstraps the first features, exactly as the algorithm states.
        let z = FeatureState::empty(n);
        let params = GlobalParams { a: Mat::zeros(0, x.cols()), pi: vec![], lg, alpha };
        let resid = x.clone();
        Self { x, z, params, shards, p_prime, cfg, resid, tail_state: None, iter: 0 }
    }

    /// One global iteration (L sub-iterations + master step).
    pub fn step(&mut self, rng: &mut Pcg64) -> IterStats {
        let k_plus = self.z.k();
        let inv2s2 =
            1.0 / (2.0 * self.params.lg.sigma_x * self.params.lg.sigma_x);
        let prior_logit: Vec<f64> = self
            .params
            .pi
            .iter()
            .map(|&p| {
                let p = p.clamp(1e-12, 1.0 - 1e-12);
                (p / (1.0 - p)).ln()
            })
            .collect();

        for _l in 0..self.cfg.sub_iters {
            // --- every processor: uncollapsed sweep over K⁺ ---
            for p in 0..self.cfg.processors {
                let shard = self.shards[p].clone();
                if k_plus > 0 {
                    sweep_rows(
                        &self.x, &mut self.z, &mut self.resid,
                        &self.params.a, &prior_logit, inv2s2,
                        shard, k_plus, rng,
                    );
                }
            }
            // --- p′: collapsed tail on residuals ---
            let shard = self.shards[self.p_prime].clone();
            let b = shard.len();
            let local_resid = Mat::from_fn(b, self.x.cols(), |i, j| {
                self.resid[(shard.start + i, j)]
            });
            let carried = self
                .tail_state
                .take()
                .unwrap_or_else(|| FeatureState::empty(b));
            let mut tp = TailProposer::new(local_resid, carried, self.params.lg);
            tp.sweep(
                self.params.alpha,
                self.x.rows(),
                self.cfg.opts.kmax_new,
                self.cfg.opts.k_cap.saturating_sub(k_plus),
                rng,
            );
            self.tail_state = Some(tp.take_tail());
        }

        self.master_step(rng);
        self.iter += 1;
        IterStats {
            iter: self.iter,
            k: self.z.k(),
            alpha: self.params.alpha,
            sigma_x: self.params.lg.sigma_x,
            sigma_a: self.params.lg.sigma_a,
            train_joint: self.train_joint(),
        }
    }

    /// Master: promote tail → K⁺, drop dead features, resample globals,
    /// rotate p′.
    fn master_step(&mut self, rng: &mut Pcg64) {
        let n = self.x.rows();
        let d = self.x.cols();
        // --- promote K* tail features ---
        if let Some(tail) = self.tail_state.take() {
            let k_star = tail.k();
            if k_star > 0 {
                let first = self.z.add_features(k_star);
                let shard = self.shards[self.p_prime].clone();
                for (local, global_row) in shard.enumerate() {
                    for j in 0..k_star {
                        if tail.get(local, j) == 1 {
                            self.z.set(global_row, first + j, 1);
                        }
                    }
                }
            }
        }
        // --- drop features that died during the sweeps ---
        self.z.compact();
        let k = self.z.k();
        // --- sample globals given the (promoted, compacted) Z ---
        if k > 0 {
            let zm = self.z.to_mat();
            let ztz = zm.gram();
            let ztx = zm.t_matmul(&self.x);
            self.params.a = self.params.lg.apost_sample(&ztz, &ztx, rng);
            self.params.pi = ibp::sample_pi(self.z.m(), n, rng);
        } else {
            self.params.a = Mat::zeros(0, d);
            self.params.pi.clear();
        }
        self.resid = residuals(&self.x, &self.z, &self.params.a, 0..n);
        if self.cfg.opts.sample_sigmas {
            let rss = self.resid.frob2();
            self.params.lg.sigma_x = ibp::sample_sigma_x(
                rss, n, d, self.cfg.opts.sigma_a0, self.cfg.opts.sigma_b0, rng,
            );
            if k > 0 {
                self.params.lg.sigma_a = ibp::sample_sigma_a(
                    self.params.a.frob2(), k, d,
                    self.cfg.opts.sigma_a0, self.cfg.opts.sigma_b0, rng,
                );
            }
        }
        if self.cfg.opts.sample_alpha {
            self.params.alpha = ibp::sample_alpha(k, n, rng);
        }
        // --- rotate p′ ---
        self.p_prime = rng.below(self.cfg.processors as u64) as usize;
    }

    /// Joint train log P(X, Z | A, π): the uncollapsed representation's
    /// joint (what the instantiated state defines).
    pub fn train_joint(&self) -> f64 {
        let n = self.x.rows() as f64;
        if self.z.k() == 0 {
            return self.params.lg.loglik(
                &self.x, &Mat::zeros(self.x.rows(), 0), &Mat::zeros(0, self.x.cols()),
            );
        }
        let zm = self.z.to_mat();
        let ll = self.params.lg.loglik(&self.x, &zm, &self.params.a);
        let mut prior = 0.0;
        for (kk, &p) in self.params.pi.iter().enumerate() {
            let p = p.clamp(1e-12, 1.0 - 1e-12);
            let mk = self.z.m()[kk] as f64;
            prior += mk * p.ln() + (n - mk) * (1.0 - p).ln();
        }
        ll + prior
    }

    pub fn k(&self) -> usize {
        self.z.k()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::cambridge::{generate, CambridgeConfig};

    #[test]
    fn shards_partition_exactly() {
        for (n, p) in [(10, 3), (100, 7), (5, 5), (1000, 1)] {
            let sh = make_shards(n, p);
            assert_eq!(sh.len(), p);
            assert_eq!(sh[0].start, 0);
            assert_eq!(sh.last().unwrap().end, n);
            for w in sh.windows(2) {
                assert_eq!(w[0].end, w[1].start);
            }
            let max = sh.iter().map(|r| r.len()).max().unwrap();
            let min = sh.iter().map(|r| r.len()).min().unwrap();
            assert!(max - min <= 1, "unbalanced: {sh:?}");
        }
    }

    #[test]
    fn bootstraps_features_from_empty() {
        let (ds, _) = generate(&CambridgeConfig { n: 60, seed: 1, ..Default::default() });
        let mut rng = Pcg64::new(2);
        let mut s = HybridSampler::new(
            ds.x, LinGauss::new(0.5, 1.0), 1.0,
            HybridConfig {
                processors: 1,
                sub_iters: 5,
                opts: SamplerOptions { sample_sigmas: false, ..Default::default() },
            },
            &mut rng,
        );
        assert_eq!(s.k(), 0);
        for _ in 0..15 {
            s.step(&mut rng);
        }
        assert!(s.k() >= 2, "no features instantiated: K={}", s.k());
    }

    #[test]
    fn recovers_cambridge_truth_serial() {
        let (ds, _) = generate(&CambridgeConfig { n: 150, seed: 3, ..Default::default() });
        let mut rng = Pcg64::new(4);
        let mut s = HybridSampler::new(
            ds.x, LinGauss::new(0.5, 1.0), 1.0,
            HybridConfig::default(), &mut rng,
        );
        let mut ks = vec![];
        for _ in 0..40 {
            ks.push(s.step(&mut rng).k);
        }
        let tail = &ks[25..];
        let mean_k = tail.iter().sum::<usize>() as f64 / tail.len() as f64;
        // the hybrid's uncollapsed feature-death is a slow random walk, so
        // over short runs it carries some near-zero-loading extras on top
        // of the 4 true glyphs (visible in the paper's own Fig. 2 bottom
        // row). Require the truth to be found without runaway growth.
        assert!((3.0..=13.0).contains(&mean_k), "K trace {ks:?}");
        assert!(s.z.check_invariants());
    }

    #[test]
    fn multi_processor_matches_single_distributionally() {
        let (ds, _) = generate(&CambridgeConfig { n: 120, seed: 5, ..Default::default() });
        let run = |p: usize, seed: u64| {
            let mut rng = Pcg64::new(seed);
            let mut s = HybridSampler::new(
                ds.x.clone(), LinGauss::new(0.5, 1.0), 1.0,
                HybridConfig {
                    processors: p,
                    sub_iters: 5,
                    opts: SamplerOptions { sample_sigmas: false, ..Default::default() },
                },
                &mut rng,
            );
            let mut acc = 0.0;
            for i in 0..45 {
                let st = s.step(&mut rng);
                if i >= 25 {
                    acc += st.k as f64;
                }
            }
            acc / 20.0
        };
        let k1 = run(1, 6);
        let k3 = run(3, 7);
        assert!(
            (k1 - k3).abs() <= 2.0,
            "P=1 K≈{k1} vs P=3 K≈{k3}: parallelism changed the posterior"
        );
    }

    #[test]
    fn sigma_estimation_tracks_truth() {
        let (ds, _) = generate(&CambridgeConfig { n: 200, sigma_x: 0.5, seed: 8, ..Default::default() });
        let mut rng = Pcg64::new(9);
        let mut s = HybridSampler::new(
            ds.x, LinGauss::new(1.5, 1.0), 1.0,
            HybridConfig::default(), &mut rng,
        );
        let mut sx = vec![];
        for i in 0..50 {
            let st = s.step(&mut rng);
            if i >= 30 {
                sx.push(st.sigma_x);
            }
        }
        let mean = sx.iter().sum::<f64>() / sx.len() as f64;
        assert!((mean - 0.5).abs() < 0.12, "sigma_x≈{mean}, truth 0.5");
    }

    #[test]
    fn deterministic_given_seed() {
        let (ds, _) = generate(&CambridgeConfig { n: 50, seed: 10, ..Default::default() });
        let run = |seed: u64| {
            let mut rng = Pcg64::new(seed);
            let mut s = HybridSampler::new(
                ds.x.clone(), LinGauss::new(0.5, 1.0), 1.0,
                HybridConfig { processors: 2, ..Default::default() },
                &mut rng,
            );
            (0..8).map(|_| s.step(&mut rng).train_joint).collect::<Vec<_>>()
        };
        assert_eq!(run(11), run(11));
    }
}
