//! MCMC samplers for the linear-Gaussian IBP model.
//!
//! | module | algorithm | role |
//! |---|---|---|
//! | [`collapsed`] | G&G (2005) collapsed Gibbs, A marginalised | Fig. 1/2 baseline |
//! | [`uncollapsed`] | finite-K uncollapsed Gibbs (paper Eq. 2) | motivation baseline + the shared sweep routine |
//! | [`accelerated`] | Doshi-Velez & Ghahramani (2009) predictive form | cited comparison [2] |
//! | [`hybrid`] | the paper's §3 hybrid, serial reference | exactness oracle for the parallel coordinator |
//! | [`tail`] | collapsed sampling of the uninstantiated tail on residuals | shared by hybrid + coordinator p′ |
//! | [`eval`] | held-out joint log P(X,Z) evaluator (Fig. 1 metric) | all samplers |

pub mod accelerated;
pub mod collapsed;
pub mod eval;
pub mod hybrid;
pub mod tail;
pub mod uncollapsed;

/// Knobs shared by every sampler.
#[derive(Clone, Debug)]
pub struct SamplerOptions {
    /// Truncation level for the new-feature proposal (evaluate
    /// k_new ∈ 0..=kmax_new exactly and normalise).
    pub kmax_new: usize,
    /// Resample α each iteration (Gamma(1,1) hyperprior).
    pub sample_alpha: bool,
    /// Resample σ_X, σ_A each iteration.
    pub sample_sigmas: bool,
    /// InvGamma(a0, b0) prior for both σ² conditionals.
    pub sigma_a0: f64,
    pub sigma_b0: f64,
    /// Hard cap on instantiated features (memory guard; far above
    /// anything the posterior visits in the experiments).
    pub k_cap: usize,
    /// Coordinator only: features with global count ≤ this whose entire
    /// support lies inside the next p′ shard are DEMOTED back into that
    /// worker's collapsed tail, where death moves are exact and cheap
    /// (fights the uncollapsed slow-death of junk singletons; see
    /// DESIGN.md §Demotion). 0 disables.
    pub demote_below: usize,
    /// Refresh the collapsed cache from scratch every this-many row
    /// updates (numerical drift control).
    pub refresh_every: usize,
}

impl Default for SamplerOptions {
    fn default() -> Self {
        Self {
            kmax_new: 4,
            sample_alpha: true,
            sample_sigmas: true,
            sigma_a0: 1.0,
            sigma_b0: 1.0,
            k_cap: 64,
            demote_below: 3,
            refresh_every: 2048,
        }
    }
}

/// What every sampler exposes after each iteration (for traces/benches).
#[derive(Clone, Debug)]
pub struct IterStats {
    pub iter: usize,
    /// Instantiated feature count K⁺.
    pub k: usize,
    pub alpha: f64,
    pub sigma_x: f64,
    pub sigma_a: f64,
    /// Joint train log P(X, Z) under the sampler's own representation.
    pub train_joint: f64,
}
