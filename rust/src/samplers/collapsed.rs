//! Collapsed Gibbs sampler for the IBP linear-Gaussian model
//! (Griffiths & Ghahramani 2005) — the baseline the paper compares
//! against in Figures 1 and 2.
//!
//! Loadings A are integrated out; each bit is resampled from
//!
//!   P(Z_nk = 1 | Z₋nk, X) ∝ m₋n,k / N · P(X | Z)
//!
//! followed by a truncated-exact draw of K_new ~ P(k) ∝
//! Poisson(k; α/N)·P(X | Z ∪ k singletons). The [`CollapsedCache`]
//! (Sherman–Morrison) makes each bit O(K² + KD).
//!
//! Two likelihood modes share this implementation:
//! * [`Mode::Exact`] — joint-marginal ratio (classic G&G);
//! * [`Mode::Predictive`] — Doshi-Velez & Ghahramani (2009) accelerated
//!   form, P(x_n | z_n, X₋n): the same conditional (tested equal) with a
//!   cheaper constant, no G matrix needed.

use crate::linalg::Mat;
use crate::model::state::FeatureState;
use crate::model::{ibp, CollapsedCache, LinGauss};
use crate::obs;
use crate::rng::Pcg64;
use crate::samplers::{IterStats, SamplerOptions};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    Exact,
    Predictive,
}

pub struct CollapsedGibbs {
    pub x: Mat,
    pub z: FeatureState,
    pub lg: LinGauss,
    pub alpha: f64,
    pub mode: Mode,
    cache: CollapsedCache,
    opts: SamplerOptions,
    iter: usize,
    rows_since_refresh: usize,
    /// Metropolis step scale for the σ random walks (collapsed σ updates
    /// are non-conjugate because A is integrated out).
    sigma_step: f64,
    sigma_accepts: usize,
    sigma_proposals: usize,
}

impl CollapsedGibbs {
    pub fn new(
        x: Mat,
        lg: LinGauss,
        alpha: f64,
        mode: Mode,
        opts: SamplerOptions,
        rng: &mut Pcg64,
    ) -> Self {
        let n = x.rows();
        // start with one feature per ~Poisson(alpha) to avoid the empty-Z
        // degenerate cache
        let k0 = (rng.poisson(alpha) as usize).clamp(1, opts.k_cap);
        let mut z = FeatureState::empty(n);
        z.add_features(k0);
        for i in 0..n {
            for k in 0..k0 {
                if rng.bernoulli(0.2) {
                    z.set(i, k, 1);
                }
            }
        }
        // ensure no empty columns (prior math requires m_k > 0)
        for k in 0..k0 {
            if z.m()[k] == 0 {
                let i = rng.below(n as u64) as usize;
                z.set(i, k, 1);
            }
        }
        let cache = CollapsedCache::new(&x, &z.to_mat(), lg.ratio());
        Self {
            x,
            z,
            lg,
            alpha,
            mode,
            cache,
            opts,
            iter: 0,
            rows_since_refresh: 0,
            sigma_step: 0.1,
            sigma_accepts: 0,
            sigma_proposals: 0,
        }
    }

    /// One full Gibbs iteration over all rows.
    pub fn step(&mut self, rng: &mut Pcg64) -> IterStats {
        let n = self.x.rows();
        {
            let _sweep = obs::span(obs::Span::CollapsedRowSweep);
            for row in 0..n {
                self.update_row(row, rng);
            }
        }
        self.cleanup_empty();
        if self.opts.sample_alpha {
            self.alpha = ibp::sample_alpha(self.z.k(), n, rng);
        }
        if self.opts.sample_sigmas {
            self.mh_sigmas(rng);
        }
        self.iter += 1;
        IterStats {
            iter: self.iter,
            k: self.z.k(),
            alpha: self.alpha,
            sigma_x: self.lg.sigma_x,
            sigma_a: self.lg.sigma_a,
            train_joint: self.train_joint(),
        }
    }

    /// Resample one observation's row: existing bits, then new features.
    fn update_row(&mut self, row: usize, rng: &mut Pcg64) {
        let n = self.x.rows();
        let k = self.z.k();
        if k == 0 {
            self.propose_new_features(row, &[], rng);
            return;
        }
        let z_orig = self.z.row_f64(row);
        let x_row: Vec<f64> = self.x.row(row).to_vec();
        // m excluding this row
        let m_minus: Vec<usize> = (0..k)
            .map(|j| self.z.m()[j] - self.z.get(row, j) as usize)
            .collect();
        if self.cache.remove_row(&z_orig, &x_row) {
            obs::inc(obs::Counter::CacheRank1Ops);
        } else {
            obs::inc(obs::Counter::CacheSingularFallback);
            obs::warn_once(
                obs::Warn::CacheSingular,
                "collapsed cache rank-1 update went singular; falling back to a full refresh",
            );
            self.rebuild_cache_excluding(row, &x_row);
        }
        let mut z_cur = z_orig.clone();
        for j in 0..k {
            if m_minus[j] == 0 {
                // feature supported only by this row: its conditional prior
                // mass is m₋/N → 0; the bit dies here and the column is
                // cleaned up (singleton birth happens in the new-feature
                // step, keeping the chain reversible in LOF class).
                z_cur[j] = 0.0;
                continue;
            }
            let prior_logit =
                (m_minus[j] as f64).ln() - ((n - m_minus[j]) as f64).ln();
            let mut z1 = z_cur.clone();
            z1[j] = 1.0;
            let mut z0 = z_cur;
            z0[j] = 0.0;
            let mut dll = self.pair_dll(&z1, &z0, &x_row);
            if !dll.is_finite() {
                // drift poisoned a Sherman–Morrison denominator: rebuild
                // from exact statistics (row excluded) and retry once
                obs::inc(obs::Counter::CacheNanRetry);
                obs::warn_once(
                    obs::Warn::CacheNan,
                    "collapsed cache produced a non-finite weight; refreshed and retried",
                );
                self.rebuild_cache_excluding(row, &x_row);
                dll = self.pair_dll(&z1, &z0, &x_row);
                debug_assert!(dll.is_finite(), "fresh cache gave NaN weight");
            }
            z_cur = z1; // reuse allocation; bit set below
            let logit = prior_logit + dll;
            let u = rng.uniform();
            let bit = if (u / (1.0 - u)).ln() < logit { 1.0 } else { 0.0 };
            z_cur[j] = bit;
        }
        self.propose_new_features(row, &z_cur, rng);
        self.rows_since_refresh += 1;
        if self.rows_since_refresh >= self.opts.refresh_every {
            self.cache.refresh(&self.x, &self.z.to_mat(), self.lg.ratio());
            self.rows_since_refresh = 0;
        }
    }

    /// Rebuild the cache from exact statistics with `row` excluded — the
    /// sweep's recovery path when a rank-1 update or candidate weight
    /// degenerates. Correct ONLY while `row`'s resampled bits have not
    /// yet been committed to `self.z` (commits happen at the end of
    /// [`Self::propose_new_features`]), so `row_f64(row)` matches what
    /// the cache held; every call site sits before that commit.
    fn rebuild_cache_excluding(&mut self, row: usize, x_row: &[f64]) {
        self.cache.refresh(&self.x, &self.z.to_mat(), self.lg.ratio());
        if self.z.k() > 0 {
            let z_orig = self.z.row_f64(row);
            let ok = self.cache.remove_row(&z_orig, x_row);
            debug_assert!(ok, "remove after refresh must succeed");
        }
        self.rows_since_refresh = 0;
    }

    /// Mode-dispatched Δloglik of setting bit j (z1) vs clearing it (z0).
    /// NaN when the cache's SM denominator has drifted non-positive — the
    /// caller refreshes and retries.
    fn pair_dll(&self, z1: &[f64], z0: &[f64], x_row: &[f64]) -> f64 {
        match self.mode {
            Mode::Exact => {
                self.cache.candidate_loglik(z1, x_row, &self.lg)
                    - self.cache.candidate_loglik(z0, x_row, &self.lg)
            }
            Mode::Predictive => {
                self.cache.predictive_loglik(z1, x_row, &self.lg)
                    - self.cache.predictive_loglik(z0, x_row, &self.lg)
            }
        }
    }

    /// Truncated-exact K_new step for `row`, then re-insert the row into
    /// the cache (with the grown Z if k_new > 0). Growth extends the
    /// cached statistics in place ([`CollapsedCache::append_empty_features`])
    /// — no O(N·…) rebuild.
    fn propose_new_features(&mut self, row: usize, z_cur: &[f64], rng: &mut Pcg64) {
        let n = self.x.rows();
        let x_row: Vec<f64> = self.x.row(row).to_vec();
        let lambda = self.alpha / n as f64;
        let kmax = self
            .opts
            .kmax_new
            .min(self.opts.k_cap.saturating_sub(self.z.k()));
        // batched Schur-complement evaluation of all j at once (§Perf L3-3)
        let mut logw = self
            .cache
            .candidate_loglik_aug_batch(z_cur, &x_row, kmax, &self.lg);
        if logw.iter().any(|w| w.is_nan()) {
            // poisoned denominator: rebuild (row excluded) and retry once
            obs::inc(obs::Counter::CacheNanRetry);
            obs::warn_once(
                obs::Warn::CacheNan,
                "collapsed cache produced a non-finite weight; refreshed and retried",
            );
            self.rebuild_cache_excluding(row, &x_row);
            logw = self
                .cache
                .candidate_loglik_aug_batch(z_cur, &x_row, kmax, &self.lg);
        }
        for (j, lw) in logw.iter_mut().enumerate() {
            *lw += ibp::log_poisson_pmf(j, lambda);
        }
        let k_new = rng.categorical_log(&logw);
        // commit: write the resampled existing bits
        for (j, &v) in z_cur.iter().enumerate() {
            self.z.set(row, j, v as u8);
        }
        if k_new > 0 {
            let first = self.z.add_features(k_new);
            for j in 0..k_new {
                self.z.set(row, first + j, 1);
            }
            // the new columns are empty in the cached Z (this row is
            // excluded): extend the statistics block-diagonally, then a
            // plain rank-1 insert of the grown row — O(K² + KD)
            self.cache.append_empty_features(k_new);
        }
        if self.z.k() > 0 {
            let z_row = self.z.row_f64(row);
            if self.cache.insert_row(&z_row, &x_row) {
                obs::inc(obs::Counter::CacheRank1Ops);
            } else {
                // singular rank-1 insert: rebuild from scratch (row included)
                obs::inc(obs::Counter::CacheSingularFallback);
                obs::warn_once(
                    obs::Warn::CacheSingular,
                    "collapsed cache rank-1 update went singular; falling back to a full refresh",
                );
                self.cache.refresh(&self.x, &self.z.to_mat(), self.lg.ratio());
                self.rows_since_refresh = 0;
            }
        }
    }

    /// Drop empty columns. The cache compacts its own statistics
    /// ([`CollapsedCache::retain_features`]) — the retained submatrices
    /// are exact because dead columns contribute zeros — so no O(N·…)
    /// rebuild happens here either.
    fn cleanup_empty(&mut self) {
        let before = self.z.k();
        let keep = self.z.compact();
        if self.z.k() != before && !self.cache.retain_features(&keep) {
            obs::inc(obs::Counter::CacheSingularFallback);
            obs::warn_once(
                obs::Warn::CacheSingular,
                "collapsed cache rank-1 update went singular; falling back to a full refresh",
            );
            self.cache.refresh(&self.x, &self.z.to_mat(), self.lg.ratio());
            self.rows_since_refresh = 0;
        }
    }

    /// Random-walk MH on (log σ_X, log σ_A) against the collapsed
    /// marginal (A integrated out ⇒ no conjugate update exists).
    ///
    /// Proposals are evaluated through the ratio-reparameterised cache
    /// path ([`CollapsedCache::loglik_at_ratio`]): M′ = ZᵀZ + r′·I is
    /// factorised from the cached sufficient statistics in O(K³), so a
    /// proposal never touches X or Z — rejection is free, and acceptance
    /// adopts the just-computed factor instead of rebuilding at O(NK²).
    fn mh_sigmas(&mut self, rng: &mut Pcg64) {
        for which in 0..2 {
            let cur = self.cache.loglik(&self.lg) + self.log_sigma_prior(&self.lg);
            let mut prop = self.lg;
            let step = self.sigma_step * rng.normal();
            if which == 0 {
                prop.sigma_x = (prop.sigma_x.ln() + step).exp();
            } else {
                prop.sigma_a = (prop.sigma_a.ln() + step).exp();
            }
            self.sigma_proposals += 1;
            obs::inc(obs::Counter::SigmaMhProposed);
            // the proposal changed the ridge ratio (and possibly σ_X's
            // normalisation): evaluate from the cached ZᵀZ/G — no N work.
            // log-scale proposal is symmetric in log-space; include the
            // Jacobian via the implicit prior on log σ (flat) — we put the
            // InvGamma prior on σ² and add its Jacobian below.
            let u = rng.uniform(); // drawn unconditionally: fixed draw count
            if let Some(eval) = self.cache.loglik_at_ratio(&prop) {
                let prop_ll = eval.loglik + self.log_sigma_prior(&prop);
                if (prop_ll - cur) > u.ln() {
                    self.lg = prop;
                    self.cache.adopt(eval);
                    self.sigma_accepts += 1;
                    obs::inc(obs::Counter::SigmaMhAccepted);
                }
            }
            // else: M′ failed to factorise (degenerate proposal) — reject
        }
        // adapt towards ~40% acceptance during early iterations
        if self.iter < 100 && self.sigma_proposals >= 20 {
            let rate = self.sigma_accepts as f64 / self.sigma_proposals as f64;
            if rate < 0.2 {
                self.sigma_step *= 0.7;
            } else if rate > 0.6 {
                self.sigma_step *= 1.4;
            }
            self.sigma_accepts = 0;
            self.sigma_proposals = 0;
        }
    }

    /// InvGamma(a0,b0) priors on σ_X², σ_A², with the log-σ
    /// reparameterisation Jacobian (dσ²/dlogσ = 2σ²).
    fn log_sigma_prior(&self, lg: &LinGauss) -> f64 {
        let ig = |s2: f64| {
            let (a0, b0) = (self.opts.sigma_a0, self.opts.sigma_b0);
            -(a0 + 1.0) * s2.ln() - b0 / s2 + (2.0 * s2).ln()
        };
        ig(lg.sigma_x * lg.sigma_x) + ig(lg.sigma_a * lg.sigma_a)
    }

    /// Joint train log P(X, Z) (collapsed likelihood + IBP prior).
    pub fn train_joint(&self) -> f64 {
        let ll = self.cache.loglik(&self.lg);
        let prior = if self.z.k() > 0 {
            ibp::log_prior(&self.z, self.alpha)
        } else {
            -self.alpha * ibp::harmonic(self.z.n())
        };
        ll + prior
    }

    pub fn cache(&self) -> &CollapsedCache {
        &self.cache
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::cambridge::{generate, CambridgeConfig};

    /// [`crate::testutil::planted`] with a configurable noise level; the
    /// collapsed API is Mat-based, so Z is densified.
    fn planted(n: usize, k: usize, d: usize, sigma: f64, seed: u64) -> (Mat, Mat) {
        let (x, z, _) = crate::testutil::planted_with(n, k, d, seed, 0.5, 2.0, sigma);
        (x, z.to_mat())
    }

    /// Binary-glyph planted data, Cambridge-style SNR. (With extreme SNR
    /// — tiny σ_X, large continuous loadings — single-bit Gibbs freezes in
    /// a local mode: the well-known collapsed-IBP pathology. Realistic SNR
    /// mixes; that regime is what all experiments use.)
    fn planted_binary(n: usize, k: usize, d: usize, seed: u64) -> (Mat, Mat) {
        let mut rng = Pcg64::new(seed);
        let z = Mat::from_fn(n, k, |_, _| if rng.bernoulli(0.5) { 1.0 } else { 0.0 });
        let a = Mat::from_fn(k, d, |_, _| if rng.bernoulli(0.4) { 1.0 } else { 0.0 });
        let mut x = z.matmul(&a);
        for v in x.as_mut_slice().iter_mut() {
            *v += 0.5 * rng.normal();
        }
        (x, z)
    }

    #[test]
    fn recovers_feature_count_small() {
        let (x, _) = planted_binary(80, 3, 16, 1);
        let mut rng = Pcg64::new(2);
        let mut s = CollapsedGibbs::new(
            x,
            LinGauss::new(0.5, 1.0),
            1.0,
            Mode::Exact,
            SamplerOptions::default(),
            &mut rng,
        );
        let mut ks = vec![];
        for _ in 0..60 {
            ks.push(s.step(&mut rng).k);
        }
        let tail_mean =
            ks[30..].iter().sum::<usize>() as f64 / ks[30..].len() as f64;
        assert!(
            (2.0..=8.0).contains(&tail_mean),
            "posterior K≈{tail_mean}, want ≈3 (trace {ks:?})"
        );
        assert!(s.z.check_invariants());
    }

    #[test]
    fn predictive_mode_matches_exact_distributionally() {
        // both modes target the same posterior: compare long-run mean K
        let (x, _) = planted(60, 2, 12, 0.3, 3);
        let run = |mode, seed| {
            let mut rng = Pcg64::new(seed);
            let mut s = CollapsedGibbs::new(
                x.clone(), LinGauss::new(0.3, 1.5), 1.0, mode,
                SamplerOptions { sample_sigmas: false, ..Default::default() },
                &mut rng,
            );
            let mut acc = 0.0;
            for i in 0..60 {
                let st = s.step(&mut rng);
                if i >= 20 {
                    acc += st.k as f64;
                }
            }
            acc / 40.0
        };
        let ek = run(Mode::Exact, 4);
        let pk = run(Mode::Predictive, 5);
        assert!((ek - pk).abs() < 1.0, "exact {ek} vs predictive {pk}");
    }

    #[test]
    fn train_joint_increases_from_random_init() {
        let (x, _) = planted(50, 3, 10, 0.2, 6);
        let mut rng = Pcg64::new(7);
        let mut s = CollapsedGibbs::new(
            x, LinGauss::new(0.2, 1.5), 1.0, Mode::Exact,
            SamplerOptions { sample_sigmas: false, ..Default::default() },
            &mut rng,
        );
        let first = s.train_joint();
        for _ in 0..25 {
            s.step(&mut rng);
        }
        assert!(s.train_joint() > first + 10.0);
    }

    #[test]
    fn no_empty_columns_after_step() {
        let (x, _) = planted(40, 2, 8, 0.3, 8);
        let mut rng = Pcg64::new(9);
        let mut s = CollapsedGibbs::new(
            x, LinGauss::new(0.3, 1.0), 2.0, Mode::Exact,
            SamplerOptions::default(), &mut rng,
        );
        for _ in 0..10 {
            s.step(&mut rng);
            assert!(s.z.m().iter().all(|&m| m > 0), "empty col survived");
            assert!(s.z.check_invariants());
        }
    }

    #[test]
    fn sigma_mh_tracks_truth() {
        let (x, _) = planted(100, 3, 20, 0.4, 10);
        let mut rng = Pcg64::new(11);
        let mut s = CollapsedGibbs::new(
            x, LinGauss::new(1.0, 1.0), 1.0, Mode::Exact,
            SamplerOptions::default(), &mut rng,
        );
        let mut sx_tail = vec![];
        for i in 0..80 {
            let st = s.step(&mut rng);
            if i >= 40 {
                sx_tail.push(st.sigma_x);
            }
        }
        let mean = sx_tail.iter().sum::<f64>() / sx_tail.len() as f64;
        assert!((mean - 0.4).abs() < 0.15, "sigma_x posterior mean {mean}");
    }

    #[test]
    fn works_on_cambridge_subset() {
        let (ds, _) = generate(&CambridgeConfig { n: 100, seed: 12, ..Default::default() });
        let mut rng = Pcg64::new(13);
        let mut s = CollapsedGibbs::new(
            ds.x, LinGauss::new(0.5, 1.0), 1.0, Mode::Exact,
            SamplerOptions { sample_sigmas: false, ..Default::default() },
            &mut rng,
        );
        let mut k_last = 0;
        for _ in 0..30 {
            k_last = s.step(&mut rng).k;
        }
        assert!((3..=7).contains(&k_last), "K={k_last}, truth 4");
    }
}
