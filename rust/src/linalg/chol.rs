//! Cholesky factorisation and PSD solves.
//!
//! The collapsed IBP likelihood needs `log|M|` and `M⁻¹ ZᵀX` for
//! `M = ZᵀZ + (σ_X²/σ_A²) I` (always symmetric positive definite); the
//! A-posterior needs `L⁻ᵀ E` draws. Everything here is textbook
//! Cholesky–crout with forward/backward substitution.

use super::matrix::Mat;

/// Lower-triangular Cholesky factor L with L Lᵀ = A.
#[derive(Clone, Debug)]
pub struct Cholesky {
    l: Mat,
}

impl Cholesky {
    /// Factorise a symmetric positive-definite matrix. Returns `None` if a
    /// non-positive pivot shows up (matrix not PD to working precision).
    pub fn new(a: &Mat) -> Option<Self> {
        let n = a.rows();
        assert_eq!(n, a.cols(), "cholesky needs square input");
        let mut l = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut s = a[(i, j)];
                for k in 0..j {
                    s -= l[(i, k)] * l[(j, k)];
                }
                if i == j {
                    if s <= 0.0 || !s.is_finite() {
                        return None;
                    }
                    l[(i, j)] = s.sqrt();
                } else {
                    l[(i, j)] = s / l[(j, j)];
                }
            }
        }
        Some(Self { l })
    }

    pub fn factor(&self) -> &Mat {
        &self.l
    }

    /// Consume the factorisation, yielding the lower factor (the seed of
    /// an updatable [`crate::linalg::UCholesky`]).
    pub fn into_factor(self) -> Mat {
        self.l
    }

    /// log |A| = 2 Σ log L_ii.
    pub fn logdet(&self) -> f64 {
        (0..self.l.rows()).map(|i| self.l[(i, i)].ln()).sum::<f64>() * 2.0
    }

    /// Solve A x = b.
    pub fn solve_vec(&self, b: &[f64]) -> Vec<f64> {
        let mut y = self.forward(b);
        self.backward_in_place(&mut y);
        y
    }

    /// Solve A X = B column-wise.
    pub fn solve_mat(&self, b: &Mat) -> Mat {
        let n = self.l.rows();
        assert_eq!(b.rows(), n);
        let mut out = Mat::zeros(n, b.cols());
        // work column by column to reuse the vector solver
        for j in 0..b.cols() {
            let col: Vec<f64> = (0..n).map(|i| b[(i, j)]).collect();
            let x = self.solve_vec(&col);
            for i in 0..n {
                out[(i, j)] = x[i];
            }
        }
        out
    }

    /// A⁻¹ (via n solves) — only used on K×K matrices.
    pub fn inverse(&self) -> Mat {
        let n = self.l.rows();
        self.solve_mat(&Mat::eye(n))
    }

    /// Forward substitution: solve L y = b.
    pub fn forward(&self, b: &[f64]) -> Vec<f64> {
        let n = self.l.rows();
        assert_eq!(b.len(), n);
        let mut y = vec![0.0; n];
        for i in 0..n {
            let mut s = b[i];
            for k in 0..i {
                s -= self.l[(i, k)] * y[k];
            }
            y[i] = s / self.l[(i, i)];
        }
        y
    }

    /// Backward substitution in place: solve Lᵀ x = y.
    pub fn backward_in_place(&self, y: &mut [f64]) {
        let n = self.l.rows();
        for i in (0..n).rev() {
            let mut s = y[i];
            for k in i + 1..n {
                s -= self.l[(k, i)] * y[k];
            }
            y[i] = s / self.l[(i, i)];
        }
    }

    /// Solve Lᵀ X = B (used for matrix-normal draws A = mean + σ L⁻ᵀ E).
    pub fn lt_solve_mat(&self, b: &Mat) -> Mat {
        let n = self.l.rows();
        assert_eq!(b.rows(), n);
        let mut out = b.clone();
        for j in 0..b.cols() {
            for i in (0..n).rev() {
                let mut s = out[(i, j)];
                for k in i + 1..n {
                    s -= self.l[(k, i)] * out[(k, j)];
                }
                out[(i, j)] = s / self.l[(i, i)];
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn random_spd(n: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::new(seed);
        let b = Mat::from_fn(n + 3, n, |_, _| rng.normal());
        let mut a = b.gram();
        a.add_diag(0.5);
        a
    }

    #[test]
    fn reconstructs_matrix() {
        let a = random_spd(6, 1);
        let ch = Cholesky::new(&a).unwrap();
        let l = ch.factor();
        let recon = l.matmul(&l.transpose());
        assert!(recon.max_abs_diff(&a) < 1e-10);
    }

    #[test]
    fn logdet_matches_2x2_formula() {
        let a = Mat::from_vec(2, 2, vec![4.0, 1.0, 1.0, 3.0]);
        let ch = Cholesky::new(&a).unwrap();
        assert!((ch.logdet() - 11f64.ln()).abs() < 1e-12);
    }

    #[test]
    fn solve_recovers_known_solution() {
        let a = random_spd(5, 2);
        let x_true = vec![1.0, -2.0, 0.5, 3.0, -1.0];
        let b = a.matvec(&x_true);
        let x = Cholesky::new(&a).unwrap().solve_vec(&b);
        for (got, want) in x.iter().zip(&x_true) {
            assert!((got - want).abs() < 1e-9);
        }
    }

    #[test]
    fn inverse_times_matrix_is_identity() {
        let a = random_spd(7, 3);
        let inv = Cholesky::new(&a).unwrap().inverse();
        assert!(a.matmul(&inv).max_abs_diff(&Mat::eye(7)) < 1e-9);
    }

    #[test]
    fn solve_mat_matches_columnwise() {
        let a = random_spd(4, 4);
        let b = Mat::from_fn(4, 3, |i, j| (i + j) as f64 - 1.5);
        let ch = Cholesky::new(&a).unwrap();
        let x = ch.solve_mat(&b);
        assert!(a.matmul(&x).max_abs_diff(&b) < 1e-9);
    }

    #[test]
    fn lt_solve_matches_definition() {
        let a = random_spd(5, 5);
        let ch = Cholesky::new(&a).unwrap();
        let e = Mat::from_fn(5, 2, |i, j| (i as f64 - j as f64) * 0.3);
        let x = ch.lt_solve_mat(&e);
        let lt = ch.factor().transpose();
        assert!(lt.matmul(&x).max_abs_diff(&e) < 1e-10);
    }

    #[test]
    fn non_pd_returns_none() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // indefinite
        assert!(Cholesky::new(&a).is_none());
    }
}
