//! Dense row-major f64 matrix — the in-tree replacement for `nalgebra`.
//!
//! Deliberately small: exactly the operations the IBP samplers need, each
//! written for clarity first and the hot ones (matmul, syrk) with cache-
//! friendly loop orders. K here is the number of instantiated features
//! (≤ ~64 in every experiment), so K×K work is trivially cheap; the N×D
//! paths matter and are kept allocation-free where possible.

use std::fmt;
use std::ops::{Index, IndexMut};

/// Dense row-major f64 matrix.
///
/// # Examples
///
/// ```
/// use pibp::linalg::Mat;
///
/// let a = Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0,
///                                  4.0, 5.0, 6.0]);
/// assert_eq!(a[(1, 2)], 6.0);
/// assert_eq!(a.row(0), &[1.0, 2.0, 3.0]);
///
/// // matmul against the identity is the identity map
/// let same = a.matmul(&Mat::eye(3));
/// assert!(same.max_abs_diff(&a) == 0.0);
///
/// // Gram matrix AᵀA equals the explicit product
/// assert!(a.gram().max_abs_diff(&a.transpose().matmul(&a)) < 1e-12);
/// ```
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Self { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Self::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// self * other, ikj loop order (streams `other` rows).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul inner dim");
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let a = self[(i, k)];
                if a == 0.0 {
                    continue; // Z is sparse 0/1 — skip whole rows of other
                }
                let orow = other.row(k);
                let out_row = out.row_mut(i);
                for (o, &b) in out_row.iter_mut().zip(orow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// selfᵀ * other without materialising the transpose.
    pub fn t_matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.rows, other.rows, "t_matmul outer dim");
        let mut out = Mat::zeros(self.cols, other.cols);
        for r in 0..self.rows {
            let srow = self.row(r);
            let orow = other.row(r);
            for (k, &a) in srow.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let out_row = out.row_mut(k);
                for (o, &b) in out_row.iter_mut().zip(orow) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// selfᵀ * self (Gram matrix), exploiting symmetry.
    pub fn gram(&self) -> Mat {
        let k = self.cols;
        let mut out = Mat::zeros(k, k);
        for r in 0..self.rows {
            let row = self.row(r);
            for i in 0..k {
                let a = row[i];
                if a == 0.0 {
                    continue;
                }
                for j in i..k {
                    out[(i, j)] += a * row[j];
                }
            }
        }
        for i in 0..k {
            for j in 0..i {
                out[(i, j)] = out[(j, i)];
            }
        }
        out
    }

    /// Matrix–vector product.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len());
        (0..self.rows)
            .map(|i| self.row(i).iter().zip(v).map(|(a, b)| a * b).sum())
            .collect()
    }

    pub fn add_assign(&mut self, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Mat::from_vec(self.rows, self.cols, data)
    }

    pub fn scale(&mut self, s: f64) {
        for a in self.data.iter_mut() {
            *a *= s;
        }
    }

    /// Add s to the diagonal.
    pub fn add_diag(&mut self, s: f64) {
        let n = self.rows.min(self.cols);
        for i in 0..n {
            self[(i, i)] += s;
        }
    }

    /// Sum of squares of all entries.
    pub fn frob2(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum()
    }

    /// tr(selfᵀ * other) = elementwise dot.
    pub fn dot(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data.iter().zip(&other.data).map(|(a, b)| a * b).sum()
    }

    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Copy `src` into the top-left corner (used by bucket padding).
    pub fn paste(&mut self, src: &Mat) {
        assert!(src.rows <= self.rows && src.cols <= self.cols);
        for i in 0..src.rows {
            let dst = &mut self.row_mut(i)[..src.cols];
            dst.copy_from_slice(src.row(i));
        }
    }

    /// Extract the top-left (r × c) block.
    pub fn crop(&self, r: usize, c: usize) -> Mat {
        assert!(r <= self.rows && c <= self.cols);
        Mat::from_fn(r, c, |i, j| self[(i, j)])
    }

    /// Convert to the f32 row-major buffer format the PJRT runtime uses.
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }

    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Self {
        assert_eq!(data.len(), rows * cols);
        Self { rows, cols, data: data.iter().map(|&x| x as f64).collect() }
    }
}

impl Index<(usize, usize)> for Mat {
    type Output = f64;

    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(8) {
            write!(f, "  ")?;
            for j in 0..self.cols.min(10) {
                write!(f, "{:9.4} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if self.cols > 10 { "…" } else { "" })?;
        }
        if self.rows > 8 {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_hand_case() {
        let a = Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = Mat::from_vec(3, 2, vec![7.0, 8.0, 9.0, 10.0, 11.0, 12.0]);
        let c = a.matmul(&b);
        assert_eq!(c.as_slice(), &[58.0, 64.0, 139.0, 154.0]);
    }

    #[test]
    fn t_matmul_matches_explicit_transpose() {
        let a = Mat::from_fn(5, 3, |i, j| (i * 3 + j) as f64 * 0.5 - 2.0);
        let b = Mat::from_fn(5, 4, |i, j| (i + j) as f64);
        let got = a.t_matmul(&b);
        let want = a.transpose().matmul(&b);
        assert!(got.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn gram_matches_t_matmul_self() {
        let a = Mat::from_fn(7, 4, |i, j| ((i * 7 + j * 3) % 5) as f64 - 2.0);
        assert!(a.gram().max_abs_diff(&a.t_matmul(&a)) < 1e-12);
    }

    #[test]
    fn eye_is_identity_for_matmul() {
        let a = Mat::from_fn(4, 4, |i, j| (i * 4 + j) as f64);
        assert!(a.matmul(&Mat::eye(4)).max_abs_diff(&a) < 1e-15);
        assert!(Mat::eye(4).matmul(&a).max_abs_diff(&a) < 1e-15);
    }

    #[test]
    fn paste_crop_roundtrip() {
        let src = Mat::from_fn(3, 2, |i, j| (i + 10 * j) as f64);
        let mut pad = Mat::zeros(5, 4);
        pad.paste(&src);
        assert!(pad.crop(3, 2).max_abs_diff(&src) < 1e-15);
        assert_eq!(pad[(4, 3)], 0.0);
    }

    #[test]
    fn f32_roundtrip() {
        let a = Mat::from_fn(3, 3, |i, j| (i as f64 - j as f64) * 0.25);
        let b = Mat::from_f32(3, 3, &a.to_f32());
        assert!(a.max_abs_diff(&b) < 1e-6);
    }

    #[test]
    fn matvec_and_dot() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(a.matvec(&[1.0, 1.0]), vec![3.0, 7.0]);
        assert_eq!(a.dot(&a), 1.0 + 4.0 + 9.0 + 16.0);
        assert_eq!(a.frob2(), 30.0);
    }

    #[test]
    #[should_panic(expected = "matmul inner dim")]
    fn shape_mismatch_panics() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(2, 3);
        let _ = a.matmul(&b);
    }
}
