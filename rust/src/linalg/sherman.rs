//! Sherman–Morrison rank-1 inverse updates and the matrix-determinant
//! lemma — the engine of the efficient collapsed Gibbs sweep.
//!
//! The collapsed sampler maintains `Minv = (ZᵀZ + c I)⁻¹` across bit flips.
//! Removing observation row `z_n` from the Gram matrix is a rank-1
//! *downdate* `M − z_n z_nᵀ`; re-inserting the (possibly modified) row is a
//! rank-1 *update*. Both are O(K²) instead of the O(K³) refactorisation,
//! turning the G&G collapsed sweep from O(N K³ + …) into O(N K²(K + D)).

use super::matrix::Mat;

/// In-place update `Minv ← (M + s·v vᵀ)⁻¹` given `Minv = M⁻¹`.
///
/// Sherman–Morrison: (M + s v vᵀ)⁻¹ = M⁻¹ − s (M⁻¹ v)(vᵀ M⁻¹) / (1 + s vᵀM⁻¹v).
/// Returns the factor `1 + s vᵀ M⁻¹ v` (needed for the determinant lemma);
/// `None` if the update is singular (factor ≈ 0).
pub fn sm_update(minv: &mut Mat, v: &[f64], s: f64) -> Option<f64> {
    let k = minv.rows();
    debug_assert_eq!(k, minv.cols());
    debug_assert_eq!(k, v.len());
    // w = Minv v  (Minv symmetric)
    let w = minv.matvec(v);
    let vtw: f64 = v.iter().zip(&w).map(|(a, b)| a * b).sum();
    let denom = 1.0 + s * vtw;
    if denom.abs() < 1e-12 || !denom.is_finite() {
        return None;
    }
    let c = s / denom;
    for i in 0..k {
        let wi = w[i];
        if wi == 0.0 {
            continue;
        }
        let row = minv.row_mut(i);
        for (j, wj) in w.iter().enumerate() {
            row[j] -= c * wi * wj;
        }
    }
    Some(denom)
}

/// Determinant lemma: log|M + s v vᵀ| − log|M| = ln(1 + s vᵀ M⁻¹ v).
/// Evaluates the delta *without* mutating `minv`.
pub fn det_lemma_delta(minv: &Mat, v: &[f64], s: f64) -> f64 {
    let w = minv.matvec(v);
    let vtw: f64 = v.iter().zip(&w).map(|(a, b)| a * b).sum();
    (1.0 + s * vtw).ln()
}

/// Symmetrise in place (drift control after many SM updates).
pub fn symmetrize(m: &mut Mat) {
    let n = m.rows();
    for i in 0..n {
        for j in 0..i {
            let avg = 0.5 * (m[(i, j)] + m[(j, i)]);
            m[(i, j)] = avg;
            m[(j, i)] = avg;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Cholesky;
    use crate::rng::Pcg64;

    fn random_spd(n: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::new(seed);
        let b = Mat::from_fn(n + 2, n, |_, _| rng.normal());
        let mut a = b.gram();
        a.add_diag(1.0);
        a
    }

    #[test]
    fn update_matches_fresh_inverse() {
        let mut rng = Pcg64::new(1);
        let a = random_spd(6, 2);
        let mut minv = Cholesky::new(&a).unwrap().inverse();
        let v: Vec<f64> = (0..6).map(|_| rng.normal()).collect();

        sm_update(&mut minv, &v, 1.0).unwrap();

        let mut a2 = a.clone();
        for i in 0..6 {
            for j in 0..6 {
                a2[(i, j)] += v[i] * v[j];
            }
        }
        let fresh = Cholesky::new(&a2).unwrap().inverse();
        assert!(minv.max_abs_diff(&fresh) < 1e-9);
    }

    #[test]
    fn downdate_then_update_roundtrips() {
        let a = random_spd(5, 3);
        let minv0 = Cholesky::new(&a).unwrap().inverse();
        let mut minv = minv0.clone();
        let v = vec![1.0, 0.0, 1.0, 1.0, 0.0]; // binary like a Z row
        sm_update(&mut minv, &v, -1.0).unwrap();
        sm_update(&mut minv, &v, 1.0).unwrap();
        assert!(minv.max_abs_diff(&minv0) < 1e-9);
    }

    #[test]
    fn det_lemma_matches_cholesky() {
        let a = random_spd(6, 4);
        let ch = Cholesky::new(&a).unwrap();
        let minv = ch.inverse();
        let v = vec![0.5, -1.0, 2.0, 0.0, 1.0, -0.5];
        let delta = det_lemma_delta(&minv, &v, 1.0);
        let mut a2 = a.clone();
        for i in 0..6 {
            for j in 0..6 {
                a2[(i, j)] += v[i] * v[j];
            }
        }
        let want = Cholesky::new(&a2).unwrap().logdet() - ch.logdet();
        assert!((delta - want).abs() < 1e-9);
    }

    #[test]
    fn factor_returned_is_consistent_with_delta() {
        let a = random_spd(4, 5);
        let mut minv = Cholesky::new(&a).unwrap().inverse();
        let v = vec![1.0, 1.0, 0.0, 1.0];
        let delta = det_lemma_delta(&minv, &v, -1.0);
        let factor = sm_update(&mut minv, &v, -1.0).unwrap();
        assert!((factor.ln() - delta).abs() < 1e-12);
    }

    #[test]
    fn long_chain_of_updates_stays_accurate() {
        // Simulates a full collapsed sweep: repeated remove/modify/insert.
        let mut rng = Pcg64::new(6);
        let k = 8;
        let n = 50;
        let mut rows: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..k).map(|_| if rng.bernoulli(0.4) { 1.0 } else { 0.0 }).collect())
            .collect();
        let gram = |rows: &Vec<Vec<f64>>| {
            let mut g = Mat::eye(k);
            g.scale(0.25);
            for r in rows {
                for i in 0..k {
                    for j in 0..k {
                        g[(i, j)] += r[i] * r[j];
                    }
                }
            }
            g
        };
        let mut minv = Cholesky::new(&gram(&rows)).unwrap().inverse();
        for step in 0..500 {
            let i = (step * 7) % n;
            sm_update(&mut minv, &rows[i].clone(), -1.0).unwrap();
            let flip = (step * 3) % k;
            rows[i][flip] = 1.0 - rows[i][flip];
            sm_update(&mut minv, &rows[i].clone(), 1.0).unwrap();
            if step % 100 == 99 {
                symmetrize(&mut minv);
            }
        }
        let fresh = Cholesky::new(&gram(&rows)).unwrap().inverse();
        assert!(minv.max_abs_diff(&fresh) < 1e-6, "drift too large");
    }

    #[test]
    fn singular_update_returns_none() {
        // Removing the only row that supports a direction makes M singular.
        let mut m = Mat::eye(2);
        m[(0, 0)] = 1.0;
        let mut minv = Cholesky::new(&m).unwrap().inverse();
        // 1 - vᵀM⁻¹v = 0 when v = e_0 and M = I ⇒ denom 0
        assert!(sm_update(&mut minv, &[1.0, 0.0], -1.0).is_none());
    }
}
