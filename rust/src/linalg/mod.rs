//! Dense linear algebra substrate (in-tree `nalgebra` replacement).
//!
//! * [`Mat`] — row-major f64 matrix with the ops the samplers need.
//! * [`Cholesky`] — SPD factorisation, solves, log-determinant.
//! * [`UCholesky`] — rank-1 up/down-datable lower factor; exact `log|M|`
//!   for the collapsed cache without summed determinant-lemma drift.
//! * [`sm_update`] / [`det_lemma_delta`] — Sherman–Morrison rank-1 updates
//!   that make the collapsed Gibbs sweep O(K²) per bit flip.

mod chol;
mod matrix;
mod sherman;
mod ucholesky;

pub use chol::Cholesky;
pub use matrix::Mat;
pub use sherman::{det_lemma_delta, sm_update, symmetrize};
pub use ucholesky::UCholesky;
