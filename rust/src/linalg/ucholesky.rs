//! Rank-1 up/down-datable Cholesky factor.
//!
//! The collapsed cache keeps `M = ZᵀZ + r·I` in two forms: the
//! Sherman–Morrison inverse (O(K²) candidate evaluations) and this lower
//! factor L with `L Lᵀ = M`. The factor gives an **exact** `log|M|`
//! (2 Σ ln L_ii) after any number of row removals/insertions — unlike a
//! running sum of matrix-determinant-lemma deltas, whose error compounds
//! over a long sweep — and it is what [`crate::model::CollapsedCache`]
//! swaps wholesale when a σ-MH acceptance changes the ridge.
//!
//! `update` is the classic Givens-rotation scheme (LINPACK `dchud`);
//! `downdate` uses hyperbolic rotations (`dchdd`) and reports failure when
//! the downdated matrix stops being positive definite, the same signal the
//! Sherman–Morrison denominator gives. Both are O(K²).

use super::chol::Cholesky;
use super::matrix::Mat;

/// Lower-triangular factor L with `L Lᵀ = M`, maintained under rank-1
/// updates (`M ± v vᵀ`) without refactorisation.
#[derive(Clone, Debug)]
pub struct UCholesky {
    l: Mat,
}

impl UCholesky {
    /// Factorise a symmetric positive-definite matrix (O(K³) seed; all
    /// subsequent maintenance is O(K²)). `None` if not PD.
    pub fn factorize(m: &Mat) -> Option<Self> {
        Cholesky::new(m).map(Self::from_cholesky)
    }

    /// Adopt an already-computed factorisation.
    pub fn from_cholesky(ch: Cholesky) -> Self {
        Self { l: ch.into_factor() }
    }

    #[inline]
    pub fn dim(&self) -> usize {
        self.l.rows()
    }

    pub fn factor(&self) -> &Mat {
        &self.l
    }

    /// log |M| = 2 Σ ln L_ii — exact for the factor as maintained, no
    /// accumulated delta terms.
    pub fn logdet(&self) -> f64 {
        (0..self.l.rows()).map(|i| self.l[(i, i)].ln()).sum::<f64>() * 2.0
    }

    /// L ← chol(L Lᵀ + v vᵀ) via Givens rotations, O(K²). Always succeeds
    /// for finite inputs (adding v vᵀ keeps M PD); returns `false` only if
    /// a non-finite pivot appears (caller should refactorise).
    pub fn update(&mut self, v: &[f64]) -> bool {
        let n = self.l.rows();
        debug_assert_eq!(v.len(), n);
        let mut x = v.to_vec();
        for k in 0..n {
            if x[k] == 0.0 {
                continue; // identity rotation — binary Z rows are sparse
            }
            let lkk = self.l[(k, k)];
            let r = lkk.hypot(x[k]);
            if !(r > 0.0) || !r.is_finite() {
                return false;
            }
            let c = r / lkk;
            let s = x[k] / lkk;
            self.l[(k, k)] = r;
            for i in k + 1..n {
                let lik = (self.l[(i, k)] + s * x[i]) / c;
                x[i] = c * x[i] - s * lik;
                self.l[(i, k)] = lik;
            }
        }
        true
    }

    /// L ← chol(L Lᵀ − v vᵀ) via hyperbolic rotations, O(K²). Returns
    /// `false` if the downdate leaves M non-PD to working precision; the
    /// factor may then be partially rotated and the caller MUST rebuild
    /// (the collapsed cache falls back to `refresh`, exactly as it does
    /// when the Sherman–Morrison denominator goes non-positive).
    pub fn downdate(&mut self, v: &[f64]) -> bool {
        let n = self.l.rows();
        debug_assert_eq!(v.len(), n);
        let mut x = v.to_vec();
        for k in 0..n {
            if x[k] == 0.0 {
                continue;
            }
            let lkk = self.l[(k, k)];
            let r2 = (lkk - x[k]) * (lkk + x[k]);
            if !(r2 > 0.0) || !r2.is_finite() {
                return false;
            }
            let r = r2.sqrt();
            let c = r / lkk;
            let s = x[k] / lkk;
            self.l[(k, k)] = r;
            for i in k + 1..n {
                let lik = (self.l[(i, k)] - s * x[i]) / c;
                x[i] = c * x[i] - s * lik;
                self.l[(i, k)] = lik;
            }
        }
        true
    }

    /// Append `j` new dimensions decoupled from the existing ones with
    /// diagonal entry `diag` (i.e. M grows block-diagonally by `diag·I_j`).
    /// This is exactly what happens when brand-new singleton features are
    /// added to a cache whose current Z holds them as all-zero columns:
    /// M′ = [[M, 0], [0, r·I_j]], so L′ = [[L, 0], [0, √r·I_j]]. O(K²).
    pub fn grow(&mut self, j: usize, diag: f64) {
        if j == 0 {
            return;
        }
        debug_assert!(diag > 0.0);
        let k = self.l.rows();
        let mut l = Mat::zeros(k + j, k + j);
        l.paste(&self.l);
        let root = diag.sqrt();
        for i in k..k + j {
            l[(i, i)] = root;
        }
        self.l = l;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn random_spd(n: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::new(seed);
        let b = Mat::from_fn(n + 3, n, |_, _| rng.normal());
        let mut a = b.gram();
        a.add_diag(0.75);
        a
    }

    fn rank1(m: &Mat, v: &[f64], s: f64) -> Mat {
        let n = m.rows();
        Mat::from_fn(n, n, |i, j| m[(i, j)] + s * v[i] * v[j])
    }

    #[test]
    fn update_matches_refactorisation() {
        let mut rng = Pcg64::new(1);
        let a = random_spd(7, 2);
        let mut uc = UCholesky::factorize(&a).unwrap();
        let v: Vec<f64> = (0..7).map(|_| rng.normal()).collect();
        assert!(uc.update(&v));
        let fresh = Cholesky::new(&rank1(&a, &v, 1.0)).unwrap();
        assert!(uc.factor().max_abs_diff(fresh.factor()) < 1e-10);
        assert!((uc.logdet() - fresh.logdet()).abs() < 1e-10);
    }

    #[test]
    fn downdate_matches_refactorisation() {
        let a = random_spd(6, 3);
        let mut uc = UCholesky::factorize(&a).unwrap();
        // a row actually "inside" M so the downdate stays PD
        let v = vec![0.5, 0.0, 0.5, 0.5, 0.0, 0.5];
        assert!(uc.downdate(&v));
        let fresh = Cholesky::new(&rank1(&a, &v, -1.0)).unwrap();
        assert!(uc.factor().max_abs_diff(fresh.factor()) < 1e-10);
        assert!((uc.logdet() - fresh.logdet()).abs() < 1e-10);
    }

    #[test]
    fn downdate_update_roundtrips() {
        let a = random_spd(5, 4);
        let v = vec![1.0, 0.0, 1.0, 1.0, 0.0]; // binary like a Z row
        let uc0 = UCholesky::factorize(&a).unwrap();
        let mut uc = uc0.clone();
        assert!(uc.downdate(&v));
        assert!(uc.update(&v));
        assert!(uc.factor().max_abs_diff(uc0.factor()) < 1e-9);
    }

    #[test]
    fn singular_downdate_reports_failure() {
        // M = I, remove e_0 e_0ᵀ entirely ⇒ zero pivot ⇒ not PD
        let mut uc = UCholesky::factorize(&Mat::eye(2)).unwrap();
        assert!(!uc.downdate(&[1.0, 0.0]));
    }

    #[test]
    fn grow_appends_decoupled_block() {
        let a = random_spd(4, 5);
        let mut uc = UCholesky::factorize(&a).unwrap();
        let before = uc.logdet();
        uc.grow(3, 2.5);
        assert_eq!(uc.dim(), 7);
        assert!((uc.logdet() - (before + 3.0 * 2.5f64.ln())).abs() < 1e-12);
        // the grown factor reproduces the block-diagonal matrix
        let big = Mat::from_fn(7, 7, |i, j| {
            if i < 4 && j < 4 {
                a[(i, j)]
            } else if i == j {
                2.5
            } else {
                0.0
            }
        });
        let recon = uc.factor().matmul(&uc.factor().transpose());
        assert!(recon.max_abs_diff(&big) < 1e-10);
    }

    #[test]
    fn long_update_chain_keeps_exact_logdet() {
        // the whole point: after many up/downdates the factor's logdet
        // still matches a fresh factorisation to near machine precision
        let mut rng = Pcg64::new(6);
        let k = 12;
        let n = 60;
        let mut rows: Vec<Vec<f64>> = (0..n)
            .map(|_| (0..k).map(|_| if rng.bernoulli(0.4) { 1.0 } else { 0.0 }).collect())
            .collect();
        let gram = |rows: &Vec<Vec<f64>>| {
            let mut g = Mat::zeros(k, k);
            g.add_diag(0.3);
            for r in rows {
                for i in 0..k {
                    for j in 0..k {
                        g[(i, j)] += r[i] * r[j];
                    }
                }
            }
            g
        };
        let mut uc = UCholesky::factorize(&gram(&rows)).unwrap();
        for step in 0..2000 {
            let i = (step * 7) % n;
            assert!(uc.downdate(&rows[i].clone()));
            let flip = (step * 3) % k;
            rows[i][flip] = 1.0 - rows[i][flip];
            assert!(uc.update(&rows[i].clone()));
        }
        let fresh = Cholesky::new(&gram(&rows)).unwrap();
        assert!(
            (uc.logdet() - fresh.logdet()).abs() < 1e-9,
            "factor logdet drifted: {} vs {}",
            uc.logdet(),
            fresh.logdet()
        );
    }
}
