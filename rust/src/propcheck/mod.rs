//! Mini property-testing framework (in-tree `proptest` replacement):
//! seeded generators, configurable case counts, failure replay via the
//! printed seed, and shrinking-lite (retry the failing case with smaller
//! size parameters).
//!
//! Usage:
//! ```ignore
//! propcheck::run("counts stay consistent", 200, |g| {
//!     let n = g.usize_in(1, 50);
//!     ...build a case from g, return Err(msg) to fail...
//!     Ok(())
//! });
//! ```

use crate::rng::Pcg64;

/// Case generator handed to properties: a seeded RNG plus a size budget
/// that shrinks on replay.
pub struct Gen {
    pub rng: Pcg64,
    /// 1.0 for normal cases; <1.0 during shrink replays.
    pub size: f64,
    pub seed: u64,
}

impl Gen {
    /// Integer in [lo, hi] (inclusive), scaled down when shrinking.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let span = hi - lo;
        let scaled = ((span as f64 * self.size) as usize).max(if span > 0 { 1 } else { 0 });
        lo + self.rng.below(scaled as u64 + 1) as usize
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.rng.uniform()
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.bernoulli(p)
    }

    /// Pick one of the options. Panics (in every build profile) on an
    /// empty slice — `Pcg64::below(0)` only `debug_assert`s, which would
    /// otherwise let a release-mode property index out of bounds with a
    /// far less useful message.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty(), "propcheck: choose from empty slice");
        &xs[self.rng.below(xs.len() as u64) as usize]
    }
}

/// Run `cases` random cases of `prop`. Panics (with the seed and the
/// property's message) on the first failure, after attempting 4 smaller
/// replays of the same seed to report the smallest reproduction found.
pub fn run<F>(name: &str, cases: u64, prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    let base = std::env::var("PIBP_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x9e3779b9u64);
    run_with_base(name, cases, base, prop)
}

/// [`run`] with an explicit base seed — what `PIBP_PROP_SEED` resolves
/// to. Call this directly to replay a printed failure without touching
/// the (process-global) environment.
pub fn run_with_base<F>(name: &str, cases: u64, base: u64, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base.wrapping_add(case);
        let mut g = Gen { rng: Pcg64::new(seed), size: 1.0, seed };
        if let Err(msg) = prop(&mut g) {
            // shrink-lite: replay the same seed with smaller size budgets
            let mut smallest = (1.0, msg);
            for &size in &[0.5, 0.25, 0.1, 0.05] {
                let mut g2 = Gen { rng: Pcg64::new(seed), size, seed };
                if let Err(m2) = prop(&mut g2) {
                    smallest = (size, m2);
                } else {
                    break;
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {seed}, \
                 smallest failing size {}): {}\n\
                 replay with PIBP_PROP_SEED={seed}",
                smallest.0, smallest.1
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        run("trivial", 50, |g| {
            count += 1;
            let n = g.usize_in(1, 10);
            if n >= 1 && n <= 10 { Ok(()) } else { Err(format!("n={n}")) }
        });
        assert_eq!(count, 50 );
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_seed() {
        run("always fails", 10, |_| Err("nope".into()));
    }

    #[test]
    fn generators_respect_bounds() {
        run("bounds", 100, |g| {
            let a = g.usize_in(3, 7);
            if !(3..=7).contains(&a) {
                return Err(format!("usize_in out of range: {a}"));
            }
            let x = g.f64_in(-1.0, 1.0);
            if !(-1.0..=1.0).contains(&x) {
                return Err(format!("f64_in out of range: {x}"));
            }
            let c = *g.choose(&[1, 2, 3]);
            if !(1..=3).contains(&c) {
                return Err("choose out of range".into());
            }
            Ok(())
        });
    }

    #[test]
    fn deterministic_given_env_seed() {
        // same base seed ⇒ same first case values
        let mut g1 = Gen { rng: Pcg64::new(42), size: 1.0, seed: 42 };
        let mut g2 = Gen { rng: Pcg64::new(42), size: 1.0, seed: 42 };
        assert_eq!(g1.usize_in(0, 1000), g2.usize_in(0, 1000));
    }

    /// Run `f`, catch its panic, return the panic payload as a string.
    fn panic_message<F: FnOnce()>(f: F) -> String {
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f))
            .expect_err("expected the property run to panic");
        err.downcast_ref::<String>()
            .cloned()
            .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
            .expect("panic payload was not a string")
    }

    #[test]
    fn printed_seed_replays_the_failure() {
        // a sparse failure: only a few of 200 cases trip it
        let prop = |g: &mut Gen| {
            let n = g.usize_in(0, 1000);
            if n >= 900 { Err(format!("n={n}")) } else { Ok(()) }
        };
        let msg = panic_message(|| run_with_base("sparse", 200, 7, prop));
        assert!(msg.contains("replay with PIBP_PROP_SEED="), "msg={msg}");
        let seed: u64 = msg
            .split("PIBP_PROP_SEED=")
            .nth(1)
            .and_then(|s| s.split_whitespace().next())
            .and_then(|s| s.parse().ok())
            .expect("no seed in panic message");
        // replaying with that seed as base must fail on case 0
        let replay = panic_message(|| run_with_base("sparse", 1, seed, prop));
        assert!(replay.contains("case 0"), "replay did not fail immediately: {replay}");
        assert!(replay.contains(&format!("seed {seed}")), "replay={replay}");
    }

    #[test]
    fn shrink_replays_reduce_usize_in_sizes() {
        // an always-failing property records what each replay generated:
        // the shrink ladder must walk size 1.0 → 0.5 → 0.25 → 0.1 → 0.05,
        // and usize_in must respect each scaled span
        let mut seen: Vec<(f64, usize)> = Vec::new();
        let msg = panic_message(|| {
            run_with_base("always", 1, 3, |g| {
                let n = g.usize_in(0, 1000);
                seen.push((g.size, n));
                Err(format!("n={n}"))
            })
        });
        let sizes: Vec<f64> = seen.iter().map(|&(s, _)| s).collect();
        assert_eq!(sizes, vec![1.0, 0.5, 0.25, 0.1, 0.05]);
        for &(size, n) in &seen {
            let cap = ((1000.0 * size) as usize).max(1);
            assert!(n <= cap, "size {size} produced n={n} > cap {cap}");
        }
        assert!(msg.contains("smallest failing size 0.05"), "msg={msg}");
    }

    #[test]
    fn zero_span_bounds_hold_at_every_size() {
        for &size in &[1.0, 0.5, 0.05] {
            let mut g = Gen { rng: Pcg64::new(9), size, seed: 9 };
            assert_eq!(g.usize_in(5, 5), 5);
            assert_eq!(g.usize_in(0, 0), 0);
            assert_eq!(g.f64_in(2.0, 2.0), 2.0);
            // span 1 at the smallest size must still reach both endpoints
            // eventually, and never exceed them
            let v = g.usize_in(4, 5);
            assert!((4..=5).contains(&v));
        }
    }

    #[test]
    #[should_panic(expected = "choose from empty slice")]
    fn choose_from_empty_slice_panics() {
        let mut g = Gen { rng: Pcg64::new(1), size: 1.0, seed: 1 };
        let _ = g.choose::<u8>(&[]);
    }
}
