//! Non-uniform samplers on top of [`Pcg64`].
//!
//! Algorithms (all classic, all implemented from the papers since no
//! `rand_distr` is available offline):
//! * normal — Marsaglia polar method with spare caching;
//! * gamma  — Marsaglia & Tsang (2000) squeeze, with the Johnk-style
//!   `alpha < 1` boost `G(a) = G(a+1) * U^{1/a}` done in log-space;
//! * beta   — ratio of gammas;
//! * Poisson — Knuth product-of-uniforms for small mean, PTRS
//!   (Hörmann 1993) transformed rejection for large mean;
//! * inverse-gamma — 1/gamma, used for the sigma^2 conditionals;
//! * categorical — linear scan over normalised weights, plus a log-space
//!   Gumbel-max variant used by the collapsed new-feature step.

use super::Pcg64;

impl Pcg64 {
    /// Standard normal via the polar (Marsaglia) method.
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u = 2.0 * self.uniform() - 1.0;
            let v = 2.0 * self.uniform() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.spare_normal = Some(v * f);
                return u * f;
            }
        }
    }

    /// Normal with mean/stddev.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Gamma(shape, scale) — Marsaglia & Tsang.
    pub fn gamma(&mut self, shape: f64, scale: f64) -> f64 {
        assert!(shape > 0.0 && scale > 0.0, "gamma(shape>0, scale>0)");
        if shape < 1.0 {
            // boost: G(a) = G(a+1) * U^{1/a}; do the power in log-space to
            // avoid underflow at tiny shape.
            let g = self.gamma(shape + 1.0, 1.0);
            let u = self.uniform();
            return scale * g * (u.ln() / shape).exp();
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.uniform();
            let x2 = x * x;
            if u < 1.0 - 0.0331 * x2 * x2 {
                return scale * d * v3;
            }
            if u.ln() < 0.5 * x2 + d * (1.0 - v3 + v3.ln()) {
                return scale * d * v3;
            }
        }
    }

    /// Beta(a, b) via the gamma ratio.
    pub fn beta(&mut self, a: f64, b: f64) -> f64 {
        let x = self.gamma(a, 1.0);
        let y = self.gamma(b, 1.0);
        let v = x / (x + y);
        // guard against total underflow at extreme parameters
        v.clamp(f64::MIN_POSITIVE, 1.0 - f64::EPSILON)
    }

    /// Inverse-gamma(shape, scale): X = scale / Gamma(shape, 1).
    pub fn inv_gamma(&mut self, shape: f64, scale: f64) -> f64 {
        scale / self.gamma(shape, 1.0)
    }

    /// Poisson(lambda).
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        assert!(lambda >= 0.0);
        if lambda == 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            // Knuth: product of uniforms.
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.uniform();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        }
        // PTRS transformed rejection (Hörmann 1993).
        let b = 0.931 + 2.53 * lambda.sqrt();
        let a = -0.059 + 0.024_83 * b;
        let inv_alpha = 1.123_9 + 1.132_8 / (b - 3.4);
        let v_r = 0.9277 - 3.6224 / (b - 2.0);
        loop {
            let u = self.uniform() - 0.5;
            let v = self.uniform();
            let us = 0.5 - u.abs();
            let k = ((2.0 * a / us + b) * u + lambda + 0.434_98).floor();
            if us >= 0.07 && v <= v_r {
                return k as u64;
            }
            if k < 0.0 || (us < 0.013 && v > us) {
                continue;
            }
            let log_accept = k * lambda.ln() - lambda - ln_factorial(k as u64);
            if (v * inv_alpha / (a / (us * us) + b)).ln() <= log_accept {
                return k as u64;
            }
        }
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Sample an index from unnormalised log-weights (Gumbel-max — exact and
    /// overflow-safe; used by the collapsed k_new step).
    pub fn categorical_log(&mut self, logw: &[f64]) -> usize {
        debug_assert!(!logw.is_empty());
        // −∞ means "impossible" and is skipped below; NaN means an
        // upstream numerical failure the caller should have caught
        // (the collapsed sweeps refresh-and-retry before drawing)
        debug_assert!(
            logw.iter().all(|w| !w.is_nan()),
            "categorical_log: NaN log-weight in {logw:?}"
        );
        let mut best = 0;
        let mut best_v = f64::NEG_INFINITY;
        for (i, &lw) in logw.iter().enumerate() {
            if lw == f64::NEG_INFINITY {
                continue;
            }
            let g = -(-self.uniform().ln()).ln(); // Gumbel(0,1)
            let v = lw + g;
            if v > best_v {
                best_v = v;
                best = i;
            }
        }
        best
    }
}

/// Discrete distribution with precomputed normalised weights; linear-scan
/// sampling (the support here is always tiny: k_new truncation, p' choice).
#[derive(Clone, Debug)]
pub struct Categorical {
    cdf: Vec<f64>,
}

impl Categorical {
    /// Build from unnormalised non-negative weights.
    pub fn new(weights: &[f64]) -> Self {
        assert!(!weights.is_empty());
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "categorical needs positive mass");
        let mut cdf = Vec::with_capacity(weights.len());
        let mut acc = 0.0;
        for &w in weights {
            assert!(w >= 0.0);
            acc += w / total;
            cdf.push(acc);
        }
        *cdf.last_mut().unwrap() = 1.0;
        Self { cdf }
    }

    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        let u = rng.uniform();
        self.cdf.iter().position(|&c| u <= c).unwrap_or(self.cdf.len() - 1)
    }
}

/// ln(k!) via Stirling/lgamma — needed by PTRS and the IBP prior.
pub fn ln_factorial(k: u64) -> f64 {
    ln_gamma(k as f64 + 1.0)
}

/// Lanczos log-gamma (g = 7, n = 9 coefficients; |rel err| < 1e-13).
pub fn ln_gamma(x: f64) -> f64 {
    assert!(x > 0.0, "ln_gamma domain");
    const G: f64 = 7.0;
    const C: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1_259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_571_6e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // reflection
        let pi = std::f64::consts::PI;
        return (pi / (pi * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = C[0];
    let t = x + G + 0.5;
    for (i, &c) in C.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * std::f64::consts::PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

#[cfg(test)]
mod tests {
    use super::super::Pcg64;
    use super::*;

    fn moments(xs: &[f64]) -> (f64, f64) {
        let n = xs.len() as f64;
        let m = xs.iter().sum::<f64>() / n;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / n;
        (m, v)
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(1);
        let xs: Vec<f64> = (0..200_000).map(|_| rng.normal()).collect();
        let (m, v) = moments(&xs);
        assert!(m.abs() < 0.01, "mean={m}");
        assert!((v - 1.0).abs() < 0.02, "var={v}");
        // tail sanity: ~2.3% beyond 2 sigma each side
        let frac = xs.iter().filter(|x| x.abs() > 2.0).count() as f64 / xs.len() as f64;
        assert!((frac - 0.0455).abs() < 0.005, "frac={frac}");
    }

    #[test]
    fn gamma_moments_large_and_small_shape() {
        let mut rng = Pcg64::new(2);
        for &(shape, scale) in &[(4.5, 2.0), (0.3, 1.5), (1.0, 1.0), (50.0, 0.1)] {
            let xs: Vec<f64> = (0..100_000).map(|_| rng.gamma(shape, scale)).collect();
            let (m, v) = moments(&xs);
            let want_m = shape * scale;
            let want_v = shape * scale * scale;
            assert!((m - want_m).abs() < 0.05 * want_m.max(0.2), "shape={shape} m={m} want {want_m}");
            assert!((v - want_v).abs() < 0.15 * want_v.max(0.2), "shape={shape} v={v} want {want_v}");
            assert!(xs.iter().all(|x| *x > 0.0));
        }
    }

    #[test]
    fn beta_moments() {
        let mut rng = Pcg64::new(3);
        for &(a, b) in &[(2.0, 3.0), (0.5, 0.5), (10.0, 1.0)] {
            let xs: Vec<f64> = (0..100_000).map(|_| rng.beta(a, b)).collect();
            let (m, _) = moments(&xs);
            let want = a / (a + b);
            assert!((m - want).abs() < 0.01, "a={a} b={b} m={m}");
            assert!(xs.iter().all(|x| *x > 0.0 && *x < 1.0));
        }
    }

    #[test]
    fn poisson_moments_small_and_large() {
        let mut rng = Pcg64::new(4);
        for &lam in &[0.01, 0.7, 5.0, 29.9, 60.0, 400.0] {
            let n = 60_000;
            let xs: Vec<f64> = (0..n).map(|_| rng.poisson(lam) as f64).collect();
            let (m, v) = moments(&xs);
            let tol = 4.0 * (lam / n as f64).sqrt() + 0.02;
            assert!((m - lam).abs() < tol.max(0.02 * lam), "lam={lam} m={m}");
            assert!((v - lam).abs() < 0.1 * lam.max(1.0), "lam={lam} v={v}");
        }
    }

    #[test]
    fn poisson_zero() {
        let mut rng = Pcg64::new(5);
        assert_eq!(rng.poisson(0.0), 0);
    }

    #[test]
    fn inv_gamma_mean() {
        let mut rng = Pcg64::new(6);
        // mean = scale / (shape - 1) for shape > 1
        let xs: Vec<f64> = (0..100_000).map(|_| rng.inv_gamma(5.0, 8.0)).collect();
        let (m, _) = moments(&xs);
        assert!((m - 2.0).abs() < 0.05, "m={m}");
    }

    #[test]
    fn categorical_frequencies() {
        let mut rng = Pcg64::new(7);
        let dist = Categorical::new(&[1.0, 2.0, 7.0]);
        let mut counts = [0usize; 3];
        for _ in 0..100_000 {
            counts[dist.sample(&mut rng)] += 1;
        }
        assert!((counts[0] as f64 / 1e5 - 0.1).abs() < 0.01);
        assert!((counts[1] as f64 / 1e5 - 0.2).abs() < 0.01);
        assert!((counts[2] as f64 / 1e5 - 0.7).abs() < 0.01);
    }

    #[test]
    fn categorical_log_matches_linear() {
        let mut rng = Pcg64::new(8);
        let w = [0.2f64, 0.5, 0.3];
        let logw: Vec<f64> = w.iter().map(|x| x.ln()).collect();
        let mut counts = [0usize; 3];
        for _ in 0..100_000 {
            counts[rng.categorical_log(&logw)] += 1;
        }
        for i in 0..3 {
            assert!((counts[i] as f64 / 1e5 - w[i]).abs() < 0.012, "{counts:?}");
        }
    }

    #[test]
    fn categorical_log_ignores_neg_inf() {
        let mut rng = Pcg64::new(9);
        let logw = [f64::NEG_INFINITY, 0.0, f64::NEG_INFINITY];
        for _ in 0..100 {
            assert_eq!(rng.categorical_log(&logw), 1);
        }
    }

    #[test]
    fn ln_gamma_known_values() {
        assert!((ln_gamma(1.0)).abs() < 1e-10);
        assert!((ln_gamma(2.0)).abs() < 1e-10);
        assert!((ln_gamma(5.0) - 24f64.ln()).abs() < 1e-9);
        assert!((ln_gamma(0.5) - 0.5 * std::f64::consts::PI.ln()).abs() < 1e-9);
        assert!((ln_factorial(10) - 3_628_800f64.ln()).abs() < 1e-8);
    }

    #[test]
    fn bernoulli_frequency() {
        let mut rng = Pcg64::new(10);
        let hits = (0..100_000).filter(|_| rng.bernoulli(0.3)).count();
        assert!((hits as f64 / 1e5 - 0.3).abs() < 0.01);
    }
}
