//! Central registry of RNG stream tags.
//!
//! Every [`Pcg64::split`](super::Pcg64::split) call site in the tree must
//! take its tag from this module — `tools/detlint` rule **R1
//! (rng-tag-literal)** rejects raw numeric tags at build-review time. The
//! registry exists so that stream families claimed by different subsystems
//! provably cannot collide: the ranges below are pairwise disjoint *within
//! each parent namespace*, checked both at compile time (const asserts)
//! and by the unit tests at the bottom of this file.
//!
//! ## Namespaces
//!
//! A tag only has to be unique among tags split from the *same parent
//! stream* — `root.split(a)` and `worker_stream.split(a)` are independent
//! even for equal `a`, because [`Pcg64::split`] folds the parent state
//! into the derivation. Two namespaces are in use:
//!
//! * **Root** — streams split directly from `Pcg64::new(seed)` (or, for
//!   data synthesis / serving, from the relevant root seed). All scalar
//!   tags and the `WORKER`/`CHAIN`/`SERVE` families live here.
//! * **Worker** — streams split from a worker's own stream. Only the
//!   per-block substreams of `parallel::par_sweep_rows` live here, so the
//!   `BLOCK` family is unbounded upward.
//!
//! ## Flat map (root namespace)
//!
//! | constant            | value            | width | purpose                               |
//! |---------------------|------------------|-------|---------------------------------------|
//! | `MASTER`            | 1                | 1     | master chain stream (hybrid sampler)  |
//! | `SERIAL_COLLAPSED`  | 2                | 1     | serial collapsed runner stream        |
//! | `SERIAL_UNCOLLAPSED`| 3                | 1     | serial uncollapsed runner stream      |
//! | `WORKER_BASE`       | 1000             | 1000  | worker `p` stream = `worker(p)`       |
//! | `PREDICT_MASK`      | 4242             | 1     | held-out mask sampling (`predict`)    |
//! | `EVAL`              | 7777             | 1     | held-out evaluator stream             |
//! | `CHAIN_BASE`        | 8000             | 1000  | replica chain `c` seed = `chain(c)`   |
//! | `SERVE_BASE`        | 9000             | 14831 | per-sample query stream (`serve`)     |
//! | `SYNTH_DATA`        | 0x5D17 (23831)   | 1     | synthetic data generation             |
//! | `CAMBRIDGE_DATA`    | 0xCA4B (51787)   | 1     | cambridge-figure data generation      |
//!
//! The numeric values are frozen: they reproduce the pre-registry literals
//! bit-for-bit, so the migration to named tags is invisible to every
//! differential grid and pinned seed test.

/// Master chain stream: `Pcg64::new(seed).split(MASTER)`.
pub const MASTER: u64 = 1;
/// Serial collapsed-runner stream.
pub const SERIAL_COLLAPSED: u64 = 2;
/// Serial uncollapsed-runner stream.
pub const SERIAL_UNCOLLAPSED: u64 = 3;

/// Worker stream family: worker `p` splits `WORKER_BASE + p` off the root.
pub const WORKER_BASE: u64 = 1000;
/// Claimed width of the worker family (worker ids 0..WORKER_SPAN).
pub const WORKER_SPAN: u64 = 1000;

/// Per-block substream family for deterministic row sweeps. Parent is the
/// **worker/owner stream**, not the root, so the family is unbounded
/// upward (block counts scale with N); the base stays clear of small
/// scalar tags for readability in traces.
pub const BLOCK_BASE: u64 = 2000;

/// Held-out mask stream for `pibp predict --missing` (root = predict seed).
pub const PREDICT_MASK: u64 = 4242;
/// Held-out evaluator stream.
pub const EVAL: u64 = 7777;

/// Replica-chain family: chain `c > 0` derives its seed from
/// `root.split(CHAIN_BASE + c)`; chain 0 keeps the root seed itself.
pub const CHAIN_BASE: u64 = 8000;
/// Claimed width of the chain family.
pub const CHAIN_SPAN: u64 = 1000;

/// Serving family: posterior sample `s` answers queries from
/// `Pcg64::new(query_seed).split(SERVE_BASE + s)`.
pub const SERVE_BASE: u64 = 9000;
/// Claimed width of the serve family — everything up to the next root tag
/// (`SYNTH_DATA`), so reservoirs of any realistic size fit.
pub const SERVE_SPAN: u64 = SYNTH_DATA - SERVE_BASE;

/// Synthetic linear-Gaussian data generation stream.
pub const SYNTH_DATA: u64 = 0x5D17;
/// Cambridge-figure data generation stream.
pub const CAMBRIDGE_DATA: u64 = 0xCA4B;

/// Stream tag for worker `p`.
#[inline]
pub fn worker(p: usize) -> u64 {
    debug_assert!((p as u64) < WORKER_SPAN, "worker id {p} outside claimed tag range");
    WORKER_BASE + p as u64
}

/// Stream tag for row-sweep block `b` (worker-stream namespace).
#[inline]
pub fn block(b: usize) -> u64 {
    BLOCK_BASE + b as u64
}

/// Seed-derivation tag for replica chain `c` (`c >= 1`; chain 0 is the root).
#[inline]
pub fn chain(c: usize) -> u64 {
    debug_assert!((c as u64) < CHAIN_SPAN, "chain id {c} outside claimed tag range");
    CHAIN_BASE + c as u64
}

/// Stream tag for posterior sample `s` in the serving engine.
#[inline]
pub fn serve_sample(s: usize) -> u64 {
    debug_assert!((s as u64) < SERVE_SPAN, "sample slot {s} outside claimed tag range");
    SERVE_BASE + s as u64
}

/// Which parent stream a tag family is split from (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Parent {
    /// Split directly from a root `Pcg64::new(seed)` stream.
    Root,
    /// Split from a worker/owner stream inside `parallel`.
    Worker,
}

/// One registered tag family: a half-open range `[base, base + span)`.
#[derive(Clone, Copy, Debug)]
pub struct Family {
    pub name: &'static str,
    pub parent: Parent,
    pub base: u64,
    pub span: u64,
}

/// Every tag family in the tree. New stream families MUST be added here;
/// the non-overlap tests below then prove they cannot collide with any
/// existing family in the same namespace.
pub const FAMILIES: &[Family] = &[
    Family { name: "MASTER", parent: Parent::Root, base: MASTER, span: 1 },
    Family { name: "SERIAL_COLLAPSED", parent: Parent::Root, base: SERIAL_COLLAPSED, span: 1 },
    Family { name: "SERIAL_UNCOLLAPSED", parent: Parent::Root, base: SERIAL_UNCOLLAPSED, span: 1 },
    Family { name: "WORKER", parent: Parent::Root, base: WORKER_BASE, span: WORKER_SPAN },
    Family { name: "BLOCK", parent: Parent::Worker, base: BLOCK_BASE, span: u64::MAX - BLOCK_BASE },
    Family { name: "PREDICT_MASK", parent: Parent::Root, base: PREDICT_MASK, span: 1 },
    Family { name: "EVAL", parent: Parent::Root, base: EVAL, span: 1 },
    Family { name: "CHAIN", parent: Parent::Root, base: CHAIN_BASE, span: CHAIN_SPAN },
    Family { name: "SERVE", parent: Parent::Root, base: SERVE_BASE, span: SERVE_SPAN },
    Family { name: "SYNTH_DATA", parent: Parent::Root, base: SYNTH_DATA, span: 1 },
    Family { name: "CAMBRIDGE_DATA", parent: Parent::Root, base: CAMBRIDGE_DATA, span: 1 },
];

// Compile-time non-overlap proof for the root namespace: each family's
// end must not reach the next family's base (families listed in ascending
// base order). Editing a base or span into a collision is a build error.
const _: () = {
    assert!(MASTER + 1 <= SERIAL_COLLAPSED);
    assert!(SERIAL_COLLAPSED + 1 <= SERIAL_UNCOLLAPSED);
    assert!(SERIAL_UNCOLLAPSED + 1 <= WORKER_BASE);
    assert!(WORKER_BASE + WORKER_SPAN <= PREDICT_MASK);
    assert!(PREDICT_MASK + 1 <= EVAL);
    assert!(EVAL + 1 <= CHAIN_BASE);
    assert!(CHAIN_BASE + CHAIN_SPAN <= SERVE_BASE);
    assert!(SERVE_BASE + SERVE_SPAN <= SYNTH_DATA);
    assert!(SYNTH_DATA + 1 <= CAMBRIDGE_DATA);
};

#[cfg(test)]
mod tests {
    use super::*;

    /// General pairwise-disjointness check, per namespace. The const
    /// asserts above already pin the root chain; this test additionally
    /// covers any future family added out of ascending order, and the
    /// worker namespace.
    #[test]
    fn families_are_pairwise_disjoint_per_namespace() {
        for (i, a) in FAMILIES.iter().enumerate() {
            for b in FAMILIES.iter().skip(i + 1) {
                if a.parent != b.parent {
                    continue;
                }
                let disjoint =
                    a.base.saturating_add(a.span) <= b.base || b.base.saturating_add(b.span) <= a.base;
                assert!(
                    disjoint,
                    "tag families {} [{}, +{}) and {} [{}, +{}) overlap",
                    a.name, a.base, a.span, b.name, b.base, b.span
                );
            }
        }
    }

    /// The registry reproduces the historical literal tags bit-for-bit:
    /// this is what makes the call-site migration invisible to the
    /// differential grids and every pinned-seed test.
    #[test]
    fn values_match_the_pre_registry_literals() {
        assert_eq!(MASTER, 1);
        assert_eq!(SERIAL_COLLAPSED, 2);
        assert_eq!(SERIAL_UNCOLLAPSED, 3);
        assert_eq!(worker(0), 1000);
        assert_eq!(worker(7), 1007);
        assert_eq!(block(0), 2000);
        assert_eq!(block(31), 2031);
        assert_eq!(PREDICT_MASK, 4242);
        assert_eq!(EVAL, 7777);
        assert_eq!(chain(1), 8001);
        assert_eq!(chain(2), 8002);
        assert_eq!(serve_sample(0), 9000);
        assert_eq!(serve_sample(5), 9005);
        assert_eq!(SYNTH_DATA, 0x5D17);
        assert_eq!(CAMBRIDGE_DATA, 0xCA4B);
    }

    #[test]
    fn every_constant_appears_in_the_families_table() {
        let find = |n: &str| {
            FAMILIES
                .iter()
                .find(|f| f.name == n)
                .unwrap_or_else(|| panic!("family {n} missing from FAMILIES"))
        };
        assert_eq!(find("WORKER").base, WORKER_BASE);
        assert_eq!(find("BLOCK").base, BLOCK_BASE);
        assert_eq!(find("CHAIN").base, CHAIN_BASE);
        assert_eq!(find("SERVE").base, SERVE_BASE);
        assert_eq!(find("BLOCK").parent, Parent::Worker);
        assert_eq!(find("SERVE").parent, Parent::Root);
    }
}
