//! Deterministic, splittable random-number generation.
//!
//! The offline image ships no `rand` crate, so this module implements the
//! full stack from scratch:
//!
//! * [`Pcg64`] — PCG XSL-RR 128/64 (O'Neill 2014), the main engine. 128-bit
//!   LCG state with xor-shift-rotate output; passes BigCrush, tiny state,
//!   trivially seedable.
//! * [`SplitMix64`] — used only to expand user seeds into full PCG state.
//! * `distributions` — uniform / normal / gamma / beta / Poisson /
//!   Bernoulli / categorical samplers built on the engine.
//!
//! Reproducibility contract: every sampler / worker derives its own stream
//! via [`Pcg64::split`] (distinct odd increment ⇒ independent sequence), so
//! a run is a pure function of the root seed regardless of thread
//! interleaving. The same streams feed the AOT kernels (uniforms are drawn
//! here and shipped into the HLO executables as inputs).

pub mod distributions;
pub mod tags;

pub use distributions::Categorical;

const PCG_MUL: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

/// SplitMix64 — seed expander (Steele, Lea & Flood 2014).
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }
}

/// PCG XSL-RR 128/64: the repo-wide PRNG engine.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128, // must be odd
    /// Cached second normal from the polar method.
    spare_normal: Option<f64>,
    /// Engine advances since construction — a local diagnostic tally read
    /// by the observability layer (`crate::obs`). Deliberately NOT part of
    /// [`PcgState`]: the stream a checkpoint restores is identified by
    /// (state, inc, spare), and the tally restarts per run segment.
    draws: u64,
}

/// A complete, inert snapshot of a [`Pcg64`] stream — everything
/// `next_u64` *and* `normal` depend on, including the polar method's
/// cached spare normal (forgetting it would desynchronise any stream
/// whose last draw was the first half of a normal pair). This is the unit
/// the checkpoint format (`crate::snapshot`) serialises; a restored
/// stream continues bit-for-bit where the exported one stopped.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PcgState {
    pub state: u128,
    /// LCG increment; must be odd (the deserialiser rejects even values).
    pub inc: u128,
    pub spare_normal: Option<f64>,
}

impl Pcg64 {
    /// Seed from a single u64 (expanded through SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let state = ((sm.next_u64() as u128) << 64) | sm.next_u64() as u128;
        let inc = (((sm.next_u64() as u128) << 64) | sm.next_u64() as u128) | 1;
        let mut rng = Self { state, inc, spare_normal: None, draws: 0 };
        rng.next_u64(); // burn in: mix the seed into the state
        rng.draws = 0;
        rng
    }

    /// Derive an independent stream (distinct increment ⇒ disjoint
    /// sequence). `tag` makes the derivation deterministic and collision-
    /// free per call site; production tags come from the central
    /// [`tags`](crate::rng::tags) registry (e.g. worker `p` uses
    /// `root.split(tags::worker(p))`), which is what keeps the families
    /// provably non-overlapping.
    pub fn split(&self, tag: u64) -> Self {
        let mut sm = SplitMix64::new(
            (self.state as u64) ^ (self.state >> 64) as u64 ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15),
        );
        let state = ((sm.next_u64() as u128) << 64) | sm.next_u64() as u128;
        let inc = (((sm.next_u64() as u128) << 64) | sm.next_u64() as u128) | 1;
        let mut rng = Self { state, inc, spare_normal: None, draws: 0 };
        rng.next_u64();
        rng.draws = 0;
        rng
    }

    /// Export the full engine state (see [`PcgState`]).
    pub fn export_state(&self) -> PcgState {
        PcgState {
            state: self.state,
            inc: self.inc,
            spare_normal: self.spare_normal,
        }
    }

    /// Rebuild an engine from an exported snapshot; the stream continues
    /// exactly where [`Self::export_state`] left it. `inc` is forced odd
    /// (the PCG invariant); callers deserialising untrusted bytes should
    /// reject even increments before getting here.
    pub fn from_state(st: PcgState) -> Self {
        debug_assert!(st.inc & 1 == 1, "PCG increment must be odd");
        Self { state: st.state, inc: st.inc | 1, spare_normal: st.spare_normal, draws: 0 }
    }

    /// Engine advances since this stream was constructed / restored — a
    /// pure diagnostic (one add per draw, no branch). The observability
    /// layer differences this at aggregation points to tally per-stream
    /// draw counts; nothing in the sampler ever reads it.
    #[inline]
    pub fn draw_count(&self) -> u64 {
        self.draws
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.draws = self.draws.wrapping_add(1);
        self.state = self.state.wrapping_mul(PCG_MUL).wrapping_add(self.inc);
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Uniform in the open interval (0, 1) — never exactly 0 or 1, so it is
    /// always safe inside log() / logit().
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits, then nudge off zero.
        let u = (self.next_u64() >> 11) as f64 * (1.0 / 9_007_199_254_740_992.0);
        if u <= 0.0 {
            f64::MIN_POSITIVE
        } else {
            u
        }
    }

    /// Uniform f32 in (0,1) — what the AOT kernels consume.
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        let u = (self.next_u64() >> 40) as f32 * (1.0 / 16_777_216.0);
        u.max(f32::MIN_POSITIVE)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift with rejection for exact uniformity.
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Fill a buffer with uniforms in (0,1) as f32 (kernel input format).
    pub fn fill_uniform_f32(&mut self, buf: &mut [f32]) {
        for v in buf.iter_mut() {
            *v = self.uniform_f32();
        }
    }

    /// Fill a buffer with standard normals as f32 (kernel input format).
    pub fn fill_normal_f32(&mut self, buf: &mut [f32]) {
        for v in buf.iter_mut() {
            *v = self.normal() as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn split_streams_are_independent_and_deterministic() {
        let root = Pcg64::new(7);
        let mut s1 = root.split(0);
        let mut s2 = root.split(1);
        let mut s1b = root.split(0);
        for _ in 0..50 {
            assert_eq!(s1.next_u64(), s1b.next_u64());
        }
        let mut s2_vals = vec![];
        for _ in 0..50 {
            s2_vals.push(s2.next_u64());
        }
        let mut s1c = root.split(0);
        let matches = s2_vals.iter().filter(|v| **v == s1c.next_u64()).count();
        assert!(matches <= 1);
    }

    #[test]
    fn export_import_resumes_the_stream_bit_for_bit() {
        let mut a = Pcg64::new(42).split(5);
        for _ in 0..37 {
            a.next_u64();
        }
        // leave a spare normal cached: 3 polar draws consume an odd number
        // of pairs, so the snapshot must carry the half-used pair
        for _ in 0..3 {
            a.normal();
        }
        let snap = a.export_state();
        assert!(snap.spare_normal.is_some(), "test setup: spare must be live");
        let mut b = Pcg64::from_state(snap);
        for _ in 0..64 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // the cached spare itself must replay too
        let mut c = Pcg64::from_state(a.export_state());
        assert_eq!(a.normal().to_bits(), c.normal().to_bits());
        assert_eq!(a.normal().to_bits(), c.normal().to_bits());
    }

    #[test]
    fn draw_count_tallies_engine_advances_only() {
        let mut a = Pcg64::new(42);
        assert_eq!(a.draw_count(), 0, "construction burn-in must not count");
        for _ in 0..10 {
            a.next_u64();
        }
        assert_eq!(a.draw_count(), 10);
        // the tally is diagnostic state: it never affects the stream
        let mut b = Pcg64::new(42);
        assert_eq!(a.next_u64(), {
            for _ in 0..10 {
                b.next_u64();
            }
            b.next_u64()
        });
        // restore resets the tally without touching the stream
        let c = Pcg64::from_state(a.export_state());
        assert_eq!(c.draw_count(), 0);
        let s = a.split(3);
        assert_eq!(s.draw_count(), 0, "split streams start a fresh tally");
    }

    #[test]
    fn uniform_in_open_interval() {
        let mut rng = Pcg64::new(3);
        for _ in 0..10_000 {
            let u = rng.uniform();
            assert!(u > 0.0 && u < 1.0);
            let uf = rng.uniform_f32();
            assert!(uf > 0.0 && uf < 1.0);
        }
    }

    #[test]
    fn uniform_mean_and_var() {
        let mut rng = Pcg64::new(11);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let u = rng.uniform();
            s += u;
            s2 += u * u;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 0.005, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.005, "var={var}");
    }

    #[test]
    fn below_is_unbiased() {
        let mut rng = Pcg64::new(5);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[rng.below(7) as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "counts={counts:?}");
        }
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut rng = Pcg64::new(9);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
