//! # pibp — Parallel MCMC for the Indian Buffet Process
//!
//! A rust + JAX/Pallas reproduction of *"Parallel Markov Chain Monte Carlo
//! for the Indian Buffet Process"* (Zhang, Dubey & Williamson, 2017).
//!
//! The paper's hybrid sampler splits the IBP feature matrix into the
//! finitely many instantiated features (sampled **uncollapsed**, in
//! parallel across observation shards, given the weights `π` and loadings
//! `A`) and the infinite uninstantiated tail (sampled **collapsed** on one
//! rotating processor `p′` which proposes new features). A master process
//! merges sufficient statistics, samples global parameters and broadcasts.
//!
//! Architecture (see DESIGN.md):
//! * [`coordinator`] — the parallel runtime (master/worker threads +
//!   metered channels standing in for MPI).
//! * [`parallel`] — deterministic fork-join substrate: row sweeps run as
//!   fixed-size blocks with one RNG substream per block, scheduled onto a
//!   **persistent thread pool** (spawned once per owner, reused every
//!   sweep) through a cloneable [`parallel::ParallelCtx`] handle —
//!   bit-identical for every thread count and scheduling mode.
//! * [`samplers`] — collapsed / uncollapsed / accelerated baselines and the
//!   serial hybrid reference.
//! * [`runtime`] — PJRT execution of the AOT-lowered JAX/Pallas kernels
//!   (`artifacts/*.hlo.txt`); python never runs at inference time.
//! * [`snapshot`] — versioned binary checkpoints of the *entire* sampler
//!   state (all RNG streams, master + worker chain state, evaluator,
//!   sample reservoir): a run interrupted at iteration t and resumed is
//!   bit-identical to one that never stopped.
//! * [`serve`] — the posterior as a durable, queryable artifact: a
//!   thinned sample reservoir plus a batched prediction engine
//!   (reconstruction / imputation / held-out log-likelihood), fanned out
//!   per posterior sample across the pool with sample-ordered merges —
//!   byte-identical answers at every thread count.
//! * [`obs`] — zero-dependency runtime observability: phase-span
//!   histograms, sampler-health counters and the per-run `run_obs.json`
//!   report, runtime-toggled and provably non-perturbing (no RNG, no
//!   ordering effects — `rust/tests/obs_equivalence.rs`).
//! * [`metrics::online`] — streaming convergence diagnostics (Welford
//!   moments, bounded-lag online ESS, cross-chain split-R̂) behind
//!   `pibp run --chains` / `--until` and the offline `pibp diagnose`
//!   verdict; replica chains stay bit-identical to standalone runs
//!   (`rust/tests/diag_equivalence.rs`).
//! * substrates: [`rng`], [`linalg`], [`data`], [`model`], [`metrics`],
//!   [`viz`], [`cli`], [`config`], [`propcheck`], [`bench`].

pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod linalg;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod parallel;
pub mod propcheck;
pub mod rng;
pub mod runtime;
pub mod runner;
pub mod samplers;
pub mod serve;
pub mod snapshot;
pub mod testutil;
pub mod viz;
