//! Durable sampler state: a versioned, dependency-free binary checkpoint
//! format for the hybrid coordinator.
//!
//! A [`Checkpoint`] captures *everything* a run's future depends on —
//! master RNG + globals + pending structural instruction, every worker's
//! RNG stream / Z bits / pending tail, the held-out evaluator's warm
//! state and its RNG, the convergence trace, and the posterior-sample
//! reservoir (`crate::serve`) — so a chain interrupted at iteration t and
//! resumed is **bit-identical** to one that never stopped, for every
//! (P, T) combination. The per-block sweep substreams from
//! `crate::parallel` need no snapshot of their own: they are derived
//! fresh from the worker stream at each sweep call, so capturing the
//! worker stream state captures them (see docs/ARCHITECTURE.md
//! §Durable state & serving for the layout table).
//!
//! ## File format
//!
//! Little-endian throughout, built on the same `Writer`/`Reader`
//! primitives as the coordinator wire format (`coordinator::messages`):
//!
//! ```text
//! magic "PIBPSNAP" (8) | version u32 | config fingerprint u64
//! | config text (canonical key=value lines)
//! | coordinator snapshot (iter, master, P workers)
//! | eval RNG | eval Z_test bits | trace | sample reservoir | wall_s f64
//! | FNV-1a 64 checksum over every preceding byte
//! ```
//!
//! Unlike the in-process wire format, files outlive binaries, so this
//! format *is* versioned: a magic mismatch, version mismatch, checksum
//! mismatch (corruption / truncation) each fail with a distinct, clear
//! error. Writes are atomic (temp file + rename), so a crash mid-write
//! never destroys the previous good checkpoint.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::coordinator::messages::{Reader, Writer};
use crate::linalg::Mat;
use crate::metrics::{Trace, TracePoint};
use crate::model::state::FeatureState;
use crate::rng::PcgState;
use crate::serve::{PosteriorSample, SampleReservoir};

/// File magic: identifies a pibp checkpoint regardless of version.
pub const MAGIC: [u8; 8] = *b"PIBPSNAP";
/// Current format version. Bump on any layout change; `load` rejects
/// other versions with a clear message rather than misreading bytes.
pub const VERSION: u32 = 1;

/// FNV-1a 64-bit hash — used both as the file checksum and as the
/// `RunConfig` chain fingerprint. Tiny, dependency-free, and stable
/// across platforms (pure integer arithmetic).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

// ---------------------------------------------------------------------
// field codecs
// ---------------------------------------------------------------------

fn write_rng(w: &mut Writer, st: &PcgState) {
    w.u128(st.state);
    w.u128(st.inc);
    match st.spare_normal {
        Some(v) => {
            w.u32(1);
            w.f64(v);
        }
        None => w.u32(0),
    }
}

fn read_rng(r: &mut Reader) -> Result<PcgState> {
    let state = r.u128()?;
    let inc = r.u128()?;
    if inc & 1 == 0 {
        bail!("rng snapshot: PCG increment must be odd (corrupt stream state)");
    }
    let spare_normal = if r.u32()? == 1 { Some(r.f64()?) } else { None };
    Ok(PcgState { state, inc, spare_normal })
}

fn write_opt_bits(w: &mut Writer, st: &Option<FeatureState>) {
    match st {
        Some(t) => {
            w.u32(1);
            w.bits(t);
        }
        None => w.u32(0),
    }
}

fn read_opt_bits(r: &mut Reader) -> Result<Option<FeatureState>> {
    Ok(if r.u32()? == 1 { Some(r.bits()?) } else { None })
}

fn write_u32s(w: &mut Writer, xs: &[u32]) {
    w.u32(xs.len() as u32);
    for &x in xs {
        w.u32(x);
    }
}

fn read_u32s(r: &mut Reader) -> Result<Vec<u32>> {
    let n = r.u32()? as usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(r.u32()?);
    }
    Ok(out)
}

fn write_f64s(w: &mut Writer, xs: &[f64]) {
    w.u32(xs.len() as u32);
    for &x in xs {
        w.f64(x);
    }
}

fn read_f64s(r: &mut Reader) -> Result<Vec<f64>> {
    let n = r.u32()? as usize;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(r.f64()?);
    }
    Ok(out)
}

// ---------------------------------------------------------------------
// snapshot types
// ---------------------------------------------------------------------

/// One worker's complete chain state: its RNG stream (which also derives
/// every per-block sweep substream), shard-local Z bits, and the tail
/// bits pending promotion (p′ only). Captured via `ToWorker::GetState`,
/// installed via `ToWorker::SetState`.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkerSnapshot {
    pub id: u32,
    pub rng: PcgState,
    pub z: FeatureState,
    pub last_tail: Option<FeatureState>,
}

impl WorkerSnapshot {
    pub fn encode_into(&self, w: &mut Writer) {
        w.u32(self.id);
        write_rng(w, &self.rng);
        w.bits(&self.z);
        write_opt_bits(w, &self.last_tail);
    }

    pub fn decode_from(r: &mut Reader) -> Result<Self> {
        Ok(Self {
            id: r.u32()?,
            rng: read_rng(r)?,
            z: r.bits()?,
            last_tail: read_opt_bits(r)?,
        })
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        self.encode_into(&mut w);
        w.buf
    }

    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut r = Reader::new(buf);
        let ws = Self::decode_from(&mut r)?;
        if !r.done() {
            bail!("trailing bytes in WorkerSnapshot");
        }
        Ok(ws)
    }
}

/// The master's chain state: RNG, global parameters, the structural
/// instruction pending for the next broadcast, and the virtual clock.
#[derive(Clone, Debug, PartialEq)]
pub struct MasterSnapshot {
    pub rng: PcgState,
    pub a: Mat,
    pub pi: Vec<f64>,
    pub sigma_x: f64,
    pub sigma_a: f64,
    pub alpha: f64,
    pub next_keep: Vec<u32>,
    pub next_k_star: u32,
    pub next_tail_owner: u32,
    pub next_demote: Vec<u32>,
    pub pending_tail_bits: Option<FeatureState>,
    pub p_prime: u32,
    pub m_global: Vec<u64>,
    pub clock_elapsed_s: f64,
    pub clock_iterations: u64,
    pub clock_comm_bytes: u64,
}

impl MasterSnapshot {
    fn encode_into(&self, w: &mut Writer) {
        write_rng(w, &self.rng);
        w.mat(&self.a);
        write_f64s(w, &self.pi);
        w.f64(self.sigma_x);
        w.f64(self.sigma_a);
        w.f64(self.alpha);
        write_u32s(w, &self.next_keep);
        w.u32(self.next_k_star);
        w.u32(self.next_tail_owner);
        write_u32s(w, &self.next_demote);
        write_opt_bits(w, &self.pending_tail_bits);
        w.u32(self.p_prime);
        w.u32(self.m_global.len() as u32);
        for &m in &self.m_global {
            w.u64(m);
        }
        w.f64(self.clock_elapsed_s);
        w.u64(self.clock_iterations);
        w.u64(self.clock_comm_bytes);
    }

    fn decode_from(r: &mut Reader) -> Result<Self> {
        let rng = read_rng(r)?;
        let a = r.mat()?;
        let pi = read_f64s(r)?;
        let sigma_x = r.f64()?;
        let sigma_a = r.f64()?;
        let alpha = r.f64()?;
        let next_keep = read_u32s(r)?;
        let next_k_star = r.u32()?;
        let next_tail_owner = r.u32()?;
        let next_demote = read_u32s(r)?;
        let pending_tail_bits = read_opt_bits(r)?;
        let p_prime = r.u32()?;
        let nm = r.u32()? as usize;
        let mut m_global = Vec::with_capacity(nm);
        for _ in 0..nm {
            m_global.push(r.u64()?);
        }
        Ok(Self {
            rng,
            a,
            pi,
            sigma_x,
            sigma_a,
            alpha,
            next_keep,
            next_k_star,
            next_tail_owner,
            next_demote,
            pending_tail_bits,
            p_prime,
            m_global,
            clock_elapsed_s: r.f64()?,
            clock_iterations: r.u64()?,
            clock_comm_bytes: r.u64()?,
        })
    }
}

/// Full coordinator state at an iteration boundary: the master plus all P
/// workers. `iter` counts completed global iterations.
#[derive(Clone, Debug, PartialEq)]
pub struct CoordinatorSnapshot {
    pub iter: u64,
    pub master: MasterSnapshot,
    pub workers: Vec<WorkerSnapshot>,
}

impl CoordinatorSnapshot {
    fn encode_into(&self, w: &mut Writer) {
        w.u64(self.iter);
        self.master.encode_into(w);
        w.u32(self.workers.len() as u32);
        for ws in &self.workers {
            ws.encode_into(w);
        }
    }

    fn decode_from(r: &mut Reader) -> Result<Self> {
        let iter = r.u64()?;
        let master = MasterSnapshot::decode_from(r)?;
        let np = r.u32()? as usize;
        let mut workers = Vec::with_capacity(np);
        for _ in 0..np {
            workers.push(WorkerSnapshot::decode_from(r)?);
        }
        Ok(Self { iter, master, workers })
    }
}

fn write_trace(w: &mut Writer, t: &Trace) {
    w.str(&t.label);
    let (stride, seen) = t.thinning();
    w.u64(stride as u64);
    w.u64(seen as u64);
    w.u32(t.points.len() as u32);
    for p in &t.points {
        w.u64(p.iter as u64);
        w.f64(p.vtime_s);
        w.f64(p.wall_s);
        w.f64(p.heldout);
        w.u64(p.k as u64);
        w.f64(p.sigma_x);
        w.f64(p.alpha);
    }
}

fn read_trace(r: &mut Reader) -> Result<Trace> {
    let label = r.str()?;
    let stride = r.u64()? as usize;
    let seen = r.u64()? as usize;
    let npoints = r.u32()? as usize;
    let mut t = Trace::new(label);
    let mut points = Vec::with_capacity(npoints);
    for _ in 0..npoints {
        points.push(TracePoint {
            iter: r.u64()? as usize,
            vtime_s: r.f64()?,
            wall_s: r.f64()?,
            heldout: r.f64()?,
            k: r.u64()? as usize,
            sigma_x: r.f64()?,
            alpha: r.f64()?,
        });
    }
    t.points = points;
    t.restore_thinning(stride, seen);
    Ok(t)
}

fn write_sample(w: &mut Writer, s: &PosteriorSample) {
    w.u64(s.iter);
    w.bits(&s.z);
    w.mat(&s.a);
    write_f64s(w, &s.pi);
    w.f64(s.sigma_x);
    w.f64(s.sigma_a);
    w.f64(s.alpha);
}

fn read_sample(r: &mut Reader) -> Result<PosteriorSample> {
    Ok(PosteriorSample {
        iter: r.u64()?,
        z: r.bits()?,
        a: r.mat()?,
        pi: read_f64s(r)?,
        sigma_x: r.f64()?,
        sigma_a: r.f64()?,
        alpha: r.f64()?,
    })
}

fn write_reservoir(w: &mut Writer, res: &SampleReservoir) {
    w.u64(res.capacity() as u64);
    w.u64(res.stride());
    w.u32(res.samples().len() as u32);
    for s in res.samples() {
        write_sample(w, s);
    }
}

fn read_reservoir(r: &mut Reader) -> Result<SampleReservoir> {
    let cap = r.u64()? as usize;
    let stride = r.u64()?.max(1);
    let n = r.u32()? as usize;
    let mut samples = Vec::with_capacity(n);
    for _ in 0..n {
        samples.push(read_sample(r)?);
    }
    Ok(SampleReservoir::from_parts(cap, stride, samples))
}

// ---------------------------------------------------------------------
// the checkpoint file
// ---------------------------------------------------------------------

/// Everything `pibp resume` / `pibp predict` need, in one atomic file.
#[derive(Clone, Debug)]
pub struct Checkpoint {
    /// Chain fingerprint of the `RunConfig` that produced this state
    /// (`RunConfig::fingerprint`); resume refuses to continue under a
    /// configuration whose fingerprint differs.
    pub fingerprint: u64,
    /// Canonical `key=value` lines of the full `RunConfig`
    /// (`RunConfig::canonical`) — resume reconstructs the config from
    /// this, so no external config file is needed.
    pub config_text: String,
    pub coord: CoordinatorSnapshot,
    /// Held-out evaluator stream (`root.split(7777)`).
    pub eval_rng: PcgState,
    /// The evaluator's warm-started held-out Z.
    pub z_test: FeatureState,
    pub trace: Trace,
    /// Thinned posterior samples accumulated so far (`crate::serve`).
    pub reservoir: SampleReservoir,
    /// Accumulated wall-clock seconds across all segments of the run.
    pub wall_s: f64,
}

/// Borrowing view of checkpoint state for the *writer* path: the live
/// run serialises its trace / reservoir / evaluator state in place,
/// without deep-cloning them into an owned [`Checkpoint`] first (which
/// would transiently double the serialised-state footprint on every
/// checkpoint write). [`Checkpoint`] remains the owned decode target.
pub struct CheckpointRef<'a> {
    pub fingerprint: u64,
    pub config_text: &'a str,
    pub coord: &'a CoordinatorSnapshot,
    pub eval_rng: &'a PcgState,
    pub z_test: &'a FeatureState,
    pub trace: &'a Trace,
    pub reservoir: &'a SampleReservoir,
    pub wall_s: f64,
}

impl CheckpointRef<'_> {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.buf.extend_from_slice(&MAGIC);
        w.u32(VERSION);
        w.u64(self.fingerprint);
        w.str(self.config_text);
        self.coord.encode_into(&mut w);
        write_rng(&mut w, self.eval_rng);
        w.bits(self.z_test);
        write_trace(&mut w, self.trace);
        write_reservoir(&mut w, self.reservoir);
        w.f64(self.wall_s);
        let sum = fnv1a(&w.buf);
        w.u64(sum);
        w.buf
    }

    /// Atomic write: encode, write to a `.pibp.tmp` sibling, rename over
    /// `path` — a crash mid-write never clobbers the previous good
    /// checkpoint.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)
                    .with_context(|| format!("creating {}", dir.display()))?;
            }
        }
        let bytes = self.encode();
        let tmp = path.with_extension("pibp.tmp");
        std::fs::write(&tmp, &bytes)
            .with_context(|| format!("writing {}", tmp.display()))?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming {} → {}", tmp.display(), path.display()))?;
        Ok(())
    }
}

impl Checkpoint {
    fn as_ref(&self) -> CheckpointRef<'_> {
        CheckpointRef {
            fingerprint: self.fingerprint,
            config_text: &self.config_text,
            coord: &self.coord,
            eval_rng: &self.eval_rng,
            z_test: &self.z_test,
            trace: &self.trace,
            reservoir: &self.reservoir,
            wall_s: self.wall_s,
        }
    }

    pub fn encode(&self) -> Vec<u8> {
        self.as_ref().encode()
    }

    pub fn decode(buf: &[u8]) -> Result<Self> {
        if buf.len() < MAGIC.len() + 4 + 8 {
            bail!("checkpoint is truncated: {} bytes is too short for a header", buf.len());
        }
        if buf[..MAGIC.len()] != MAGIC {
            bail!("not a pibp checkpoint (bad magic; expected \"PIBPSNAP\")");
        }
        let version =
            u32::from_le_bytes(buf[MAGIC.len()..MAGIC.len() + 4].try_into().unwrap());
        if version != VERSION {
            bail!(
                "checkpoint format version {version} is not supported by this \
                 build (reads version {VERSION}); re-create the checkpoint or \
                 use a matching pibp binary"
            );
        }
        let body_end = buf.len() - 8;
        let stored = u64::from_le_bytes(buf[body_end..].try_into().unwrap());
        let computed = fnv1a(&buf[..body_end]);
        if stored != computed {
            bail!(
                "checkpoint is corrupt: checksum mismatch (stored \
                 {stored:#018x}, computed {computed:#018x}) — the file was \
                 truncated or altered after writing"
            );
        }
        let mut r = Reader::new(&buf[MAGIC.len() + 4..body_end]);
        let fingerprint = r.u64()?;
        let config_text = r.str()?;
        let coord = CoordinatorSnapshot::decode_from(&mut r)?;
        let eval_rng = read_rng(&mut r)?;
        let z_test = r.bits()?;
        let trace = read_trace(&mut r)?;
        let reservoir = read_reservoir(&mut r)?;
        let wall_s = r.f64()?;
        if !r.done() {
            bail!("trailing bytes in checkpoint body");
        }
        Ok(Self {
            fingerprint,
            config_text,
            coord,
            eval_rng,
            z_test,
            trace,
            reservoir,
            wall_s,
        })
    }

    /// Atomic write (see [`CheckpointRef::save`]).
    pub fn save(&self, path: &Path) -> Result<()> {
        self.as_ref().save(path)
    }

    pub fn load(path: &Path) -> Result<Self> {
        let buf = std::fs::read(path)
            .with_context(|| format!("reading checkpoint {}", path.display()))?;
        Self::decode(&buf)
            .with_context(|| format!("decoding checkpoint {}", path.display()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn bits(n: usize, k: usize, seed: u64) -> FeatureState {
        let mut rng = Pcg64::new(seed);
        let mut st = FeatureState::empty(n);
        st.add_features(k);
        for i in 0..n {
            for j in 0..k {
                if rng.bernoulli(0.35) {
                    st.set(i, j, 1);
                }
            }
        }
        st
    }

    fn sample(iter: u64, seed: u64) -> PosteriorSample {
        let mut rng = Pcg64::new(seed);
        PosteriorSample {
            iter,
            z: bits(11, 3, seed),
            a: Mat::from_fn(3, 5, |_, _| rng.normal()),
            pi: vec![0.2, 0.5, 0.9],
            sigma_x: 0.4,
            sigma_a: 1.1,
            alpha: 2.5,
        }
    }

    fn checkpoint() -> Checkpoint {
        let mut rng = Pcg64::new(3).split(9);
        rng.normal(); // leave a spare normal cached in some streams
        let mut trace = Trace::new("hybrid-p2");
        trace.push(TracePoint {
            iter: 1,
            vtime_s: 0.25,
            wall_s: 0.5,
            heldout: -120.5,
            k: 3,
            sigma_x: 0.45,
            alpha: 1.5,
        });
        let workers = (0..2)
            .map(|p| WorkerSnapshot {
                id: p as u32,
                rng: Pcg64::new(3).split(1000 + p).export_state(),
                z: bits(7, 4, 20 + p),
                last_tail: if p == 1 { Some(bits(7, 2, 30)) } else { None },
            })
            .collect();
        Checkpoint {
            fingerprint: 0xdead_beef_cafe_f00d,
            config_text: "dataset=cambridge\nn=14\nseed=3\n".into(),
            coord: CoordinatorSnapshot {
                iter: 6,
                master: MasterSnapshot {
                    rng: rng.export_state(),
                    a: Mat::from_fn(4, 5, |i, j| i as f64 * 0.5 - j as f64),
                    pi: vec![0.1, 0.4, 0.6, 0.95],
                    sigma_x: 0.5,
                    sigma_a: 1.0,
                    alpha: 1.25,
                    next_keep: vec![0, 2, 3],
                    next_k_star: 1,
                    next_tail_owner: 1,
                    next_demote: vec![1],
                    pending_tail_bits: Some(bits(7, 1, 40)),
                    p_prime: 0,
                    m_global: vec![5, 3, 2, 1],
                    clock_elapsed_s: 1.75,
                    clock_iterations: 6,
                    clock_comm_bytes: 12345,
                },
                workers,
            },
            eval_rng: Pcg64::new(3).split(7777).export_state(),
            z_test: bits(5, 4, 50),
            trace,
            reservoir: SampleReservoir::from_parts(
                4,
                2,
                vec![sample(2, 60), sample(4, 61)],
            ),
            wall_s: 3.125,
        }
    }

    #[test]
    fn roundtrip_preserves_every_field() {
        let ck = checkpoint();
        let back = Checkpoint::decode(&ck.encode()).unwrap();
        assert_eq!(back.fingerprint, ck.fingerprint);
        assert_eq!(back.config_text, ck.config_text);
        assert_eq!(back.coord, ck.coord);
        assert_eq!(back.eval_rng, ck.eval_rng);
        assert_eq!(back.z_test, ck.z_test);
        assert_eq!(back.trace.label, ck.trace.label);
        assert_eq!(back.trace.points, ck.trace.points);
        assert_eq!(back.trace.thinning(), ck.trace.thinning());
        assert_eq!(back.reservoir, ck.reservoir);
        assert_eq!(back.wall_s.to_bits(), ck.wall_s.to_bits());
    }

    #[test]
    fn save_load_roundtrip_on_disk() {
        let dir = std::env::temp_dir().join("pibp_snapshot_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("state.pibp");
        let ck = checkpoint();
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.coord, ck.coord);
        assert_eq!(back.reservoir, ck.reservoir);
        // and the restored RNG stream really continues the original
        let mut orig = Pcg64::from_state(ck.coord.master.rng);
        let mut rest = Pcg64::from_state(back.coord.master.rng);
        for _ in 0..32 {
            assert_eq!(orig.next_u64(), rest.next_u64());
        }
    }

    #[test]
    fn bad_magic_version_checksum_and_truncation_rejected() {
        let ck = checkpoint();
        let enc = ck.encode();

        // magic
        let mut bad = enc.clone();
        bad[0] = b'X';
        let e = Checkpoint::decode(&bad).unwrap_err().to_string();
        assert!(e.contains("not a pibp checkpoint"), "{e}");

        // version
        let mut bad = enc.clone();
        bad[8] = 99;
        let e = Checkpoint::decode(&bad).unwrap_err().to_string();
        assert!(e.contains("version 99"), "{e}");

        // flipped payload byte ⇒ checksum
        let mut bad = enc.clone();
        let mid = enc.len() / 2;
        bad[mid] ^= 0x40;
        let e = Checkpoint::decode(&bad).unwrap_err().to_string();
        assert!(e.contains("corrupt"), "{e}");

        // truncation at several depths
        for cut in [0usize, 7, 13, enc.len() / 2, enc.len() - 1] {
            let e = Checkpoint::decode(&enc[..cut]).unwrap_err().to_string();
            assert!(
                e.contains("truncated") || e.contains("corrupt") || e.contains("magic"),
                "cut={cut}: {e}"
            );
        }

        // trailing garbage also breaks the checksum
        let mut bad = enc.clone();
        bad.push(0);
        assert!(Checkpoint::decode(&bad).is_err());
    }

    #[test]
    fn fnv1a_known_vectors() {
        // standard FNV-1a 64 test vectors
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a(b"foobar"), 0x8594_4171_f739_67e8);
    }
}
