//! The "Cambridge" synthetic image data set (Griffiths & Ghahramani).
//!
//! The paper evaluates on "the 1000 × 36 dimension canonical 'Cambridge'
//! synthetic data set seen in [6]" (G&G 2011): each observation is a 6×6
//! image built as a random superposition of a small set of fixed binary
//! 6×6 feature glyphs plus isotropic Gaussian noise,
//!
//! ```text
//! x_n = Σ_k z_nk · glyph_k + ε,   z_nk ~ Bernoulli(q),  ε ~ N(0, σ²I).
//! ```
//!
//! The canonical set has four glyphs (G&G 2005 Fig. 7 style shapes); we
//! also ship four extras so experiments can scale K. The paper's exact
//! data file is not public — DESIGN.md §Substitutions records that this
//! generator is the standard public reconstruction.

use super::Dataset;
use crate::linalg::Mat;
use crate::rng::{tags, Pcg64};

pub const GLYPH_SIDE: usize = 6;
pub const DIM: usize = GLYPH_SIDE * GLYPH_SIDE;

/// The four canonical 6×6 glyphs (row-major, 0/1), drawn to match the
/// G&G latent-image style: box outline, plus, diagonal, corner hook.
const GLYPHS: [[u8; DIM]; 8] = [
    // 0: box outline in the top-left 4x4
    [
        1, 1, 1, 1, 0, 0,
        1, 0, 0, 1, 0, 0,
        1, 0, 0, 1, 0, 0,
        1, 1, 1, 1, 0, 0,
        0, 0, 0, 0, 0, 0,
        0, 0, 0, 0, 0, 0,
    ],
    // 1: plus sign, centred
    [
        0, 0, 1, 0, 0, 0,
        0, 0, 1, 0, 0, 0,
        1, 1, 1, 1, 1, 0,
        0, 0, 1, 0, 0, 0,
        0, 0, 1, 0, 0, 0,
        0, 0, 0, 0, 0, 0,
    ],
    // 2: main diagonal
    [
        1, 0, 0, 0, 0, 0,
        0, 1, 0, 0, 0, 0,
        0, 0, 1, 0, 0, 0,
        0, 0, 0, 1, 0, 0,
        0, 0, 0, 0, 1, 0,
        0, 0, 0, 0, 0, 1,
    ],
    // 3: bottom-right corner hook
    [
        0, 0, 0, 0, 0, 0,
        0, 0, 0, 0, 0, 0,
        0, 0, 0, 0, 0, 0,
        0, 0, 0, 0, 0, 1,
        0, 0, 0, 0, 0, 1,
        0, 0, 0, 1, 1, 1,
    ],
    // 4: vertical bar (extra)
    [
        0, 1, 0, 0, 0, 0,
        0, 1, 0, 0, 0, 0,
        0, 1, 0, 0, 0, 0,
        0, 1, 0, 0, 0, 0,
        0, 1, 0, 0, 0, 0,
        0, 1, 0, 0, 0, 0,
    ],
    // 5: bottom edge (extra)
    [
        0, 0, 0, 0, 0, 0,
        0, 0, 0, 0, 0, 0,
        0, 0, 0, 0, 0, 0,
        0, 0, 0, 0, 0, 0,
        0, 0, 0, 0, 0, 0,
        1, 1, 1, 1, 1, 1,
    ],
    // 6: anti-diagonal (extra)
    [
        0, 0, 0, 0, 0, 1,
        0, 0, 0, 0, 1, 0,
        0, 0, 0, 1, 0, 0,
        0, 0, 1, 0, 0, 0,
        0, 1, 0, 0, 0, 0,
        1, 0, 0, 0, 0, 0,
    ],
    // 7: 2x2 block top-right (extra)
    [
        0, 0, 0, 0, 1, 1,
        0, 0, 0, 0, 1, 1,
        0, 0, 0, 0, 0, 0,
        0, 0, 0, 0, 0, 0,
        0, 0, 0, 0, 0, 0,
        0, 0, 0, 0, 0, 0,
    ],
];

/// Configuration for the generator.
#[derive(Clone, Debug)]
pub struct CambridgeConfig {
    /// Number of observations (paper: 1000).
    pub n: usize,
    /// Number of latent glyphs used (canonical: 4; max 8).
    pub k_true: usize,
    /// Per-feature activation probability.
    pub activation: f64,
    /// Observation noise stddev (paper-era convention: 0.5).
    pub sigma_x: f64,
    pub seed: u64,
}

impl Default for CambridgeConfig {
    fn default() -> Self {
        Self { n: 1000, k_true: 4, activation: 0.5, sigma_x: 0.5, seed: 0 }
    }
}

/// The true glyph matrix (k_true × 36).
pub fn true_features(k_true: usize) -> Mat {
    assert!(k_true >= 1 && k_true <= GLYPHS.len(), "1..=8 glyphs available");
    Mat::from_fn(k_true, DIM, |k, d| GLYPHS[k][d] as f64)
}

/// Generate the data set; returns (dataset, true Z (n × k_true)).
pub fn generate(cfg: &CambridgeConfig) -> (Dataset, Mat) {
    let mut rng = Pcg64::new(cfg.seed).split(tags::CAMBRIDGE_DATA);
    let a = true_features(cfg.k_true);
    let mut z = Mat::zeros(cfg.n, cfg.k_true);
    for i in 0..cfg.n {
        // guarantee at least the possibility of empty rows, like the
        // Bernoulli superposition model — no resampling.
        for k in 0..cfg.k_true {
            if rng.bernoulli(cfg.activation) {
                z[(i, k)] = 1.0;
            }
        }
    }
    let mut x = z.matmul(&a);
    for v in x.as_mut_slice().iter_mut() {
        *v += cfg.sigma_x * rng.normal();
    }
    (
        Dataset { x, name: format!("cambridge-n{}-k{}", cfg.n, cfg.k_true) },
        z,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonical_shape() {
        let (ds, z) = generate(&CambridgeConfig::default());
        assert_eq!(ds.x.rows(), 1000);
        assert_eq!(ds.x.cols(), 36);
        assert_eq!(z.rows(), 1000);
        assert_eq!(z.cols(), 4);
    }

    #[test]
    fn glyphs_are_distinct_and_binary() {
        let a = true_features(8);
        for k in 0..8 {
            assert!(a.row(k).iter().all(|&v| v == 0.0 || v == 1.0));
            assert!(a.row(k).iter().sum::<f64>() >= 3.0, "glyph {k} too sparse");
            for j in 0..k {
                let diff: f64 = a
                    .row(k)
                    .iter()
                    .zip(a.row(j))
                    .map(|(x, y)| (x - y).abs())
                    .sum();
                assert!(diff >= 2.0, "glyphs {j} and {k} too similar");
            }
        }
    }

    #[test]
    fn activation_rate_matches() {
        let (_, z) = generate(&CambridgeConfig { n: 5000, seed: 3, ..Default::default() });
        let rate = z.as_slice().iter().sum::<f64>() / (5000.0 * 4.0);
        assert!((rate - 0.5).abs() < 0.02, "rate={rate}");
    }

    #[test]
    fn noise_level_matches() {
        let cfg = CambridgeConfig { n: 2000, sigma_x: 0.5, seed: 7, ..Default::default() };
        let (ds, z) = generate(&cfg);
        let a = true_features(cfg.k_true);
        let resid = ds.x.sub(&z.matmul(&a));
        let var = resid.frob2() / (resid.rows() * resid.cols()) as f64;
        assert!((var.sqrt() - 0.5).abs() < 0.02, "sd={}", var.sqrt());
    }

    #[test]
    fn deterministic_given_seed() {
        let (a1, _) = generate(&CambridgeConfig::default());
        let (a2, _) = generate(&CambridgeConfig::default());
        assert!(a1.x.max_abs_diff(&a2.x) == 0.0);
    }
}
