//! Matrix CSV I/O — lets experiment outputs round-trip to disk and makes
//! the examples runnable on user-provided data.

use std::fs;
use std::io::Write as _;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::linalg::Mat;

/// Write a matrix as plain CSV (no header).
pub fn write_csv(path: &Path, m: &Mat) -> Result<()> {
    let mut out = String::with_capacity(m.rows() * m.cols() * 8);
    for i in 0..m.rows() {
        let row = m.row(i);
        for (j, v) in row.iter().enumerate() {
            if j > 0 {
                out.push(',');
            }
            out.push_str(&format!("{v}"));
        }
        out.push('\n');
    }
    let mut f = fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    f.write_all(out.as_bytes())?;
    Ok(())
}

/// Read a numeric CSV (no header) into a matrix.
pub fn read_csv(path: &Path) -> Result<Mat> {
    let text = fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let mut rows: Vec<Vec<f64>> = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let vals: Result<Vec<f64>, _> =
            line.split(',').map(|s| s.trim().parse::<f64>()).collect();
        let vals = vals.with_context(|| format!("line {}", lineno + 1))?;
        if let Some(first) = rows.first() {
            if vals.len() != first.len() {
                bail!("ragged CSV at line {}: {} vs {} columns",
                      lineno + 1, vals.len(), first.len());
            }
        }
        rows.push(vals);
    }
    if rows.is_empty() {
        bail!("empty CSV {}", path.display());
    }
    let (r, c) = (rows.len(), rows[0].len());
    Ok(Mat::from_vec(r, c, rows.into_iter().flatten().collect()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("pibp_loader_test");
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.csv");
        let m = Mat::from_fn(5, 3, |i, j| i as f64 * 0.5 - j as f64 * 1.25);
        write_csv(&path, &m).unwrap();
        let back = read_csv(&path).unwrap();
        assert!(m.max_abs_diff(&back) < 1e-12);
    }

    #[test]
    fn ragged_rejected() {
        let dir = std::env::temp_dir().join("pibp_loader_test");
        fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ragged.csv");
        fs::write(&path, "1,2,3\n4,5\n").unwrap();
        assert!(read_csv(&path).is_err());
    }

    #[test]
    fn missing_file_is_error_with_context() {
        let err = read_csv(Path::new("/nonexistent/x.csv")).unwrap_err();
        assert!(format!("{err:#}").contains("/nonexistent/x.csv"));
    }
}
