//! General IBP-generated synthetic data (for scaling and ablation runs).
//!
//! Samples Z from the Indian Buffet Process restaurant construction
//! (paper §2), loadings A ~ N(0, σ_A² I) and X = Z A + ε — i.e. data drawn
//! exactly from the model the samplers target, so posterior checks
//! (recovered K⁺, noise level) have known ground truth.

use super::Dataset;
use crate::linalg::Mat;
use crate::rng::{tags, Pcg64};

#[derive(Clone, Debug)]
pub struct SynthConfig {
    pub n: usize,
    pub dim: usize,
    pub alpha: f64,
    pub sigma_a: f64,
    pub sigma_x: f64,
    pub seed: u64,
}

impl Default for SynthConfig {
    fn default() -> Self {
        Self { n: 200, dim: 16, alpha: 2.0, sigma_a: 1.0, sigma_x: 0.3, seed: 0 }
    }
}

/// Sample a binary matrix from the IBP restaurant process.
/// Returns (Z, dish counts m).
pub fn sample_ibp(n: usize, alpha: f64, rng: &mut Pcg64) -> (Vec<Vec<u8>>, Vec<usize>) {
    let mut dishes: Vec<usize> = Vec::new(); // m_k
    let mut rows: Vec<Vec<u8>> = Vec::with_capacity(n);
    for cust in 0..n {
        let mut row = vec![0u8; dishes.len()];
        // previously sampled dishes with prob m_k / (cust+1)
        for (k, m) in dishes.iter_mut().enumerate() {
            if rng.bernoulli(*m as f64 / (cust as f64 + 1.0)) {
                row[k] = 1;
                *m += 1;
            }
        }
        // new dishes ~ Poisson(alpha / (cust+1))
        let new = rng.poisson(alpha / (cust as f64 + 1.0)) as usize;
        for _ in 0..new {
            row.push(1);
            dishes.push(1);
        }
        // back-fill older rows
        rows.push(row);
    }
    let k = dishes.len();
    for row in rows.iter_mut() {
        row.resize(k, 0);
    }
    (rows, dishes)
}

/// Generate (dataset, Z_true, A_true).
pub fn generate(cfg: &SynthConfig) -> (Dataset, Mat, Mat) {
    let mut rng = Pcg64::new(cfg.seed).split(tags::SYNTH_DATA);
    let (zrows, _) = sample_ibp(cfg.n, cfg.alpha, &mut rng);
    let k = zrows.first().map_or(0, |r| r.len()).max(1);
    let z = Mat::from_fn(cfg.n, k, |i, j| {
        zrows[i].get(j).copied().unwrap_or(0) as f64
    });
    let a = Mat::from_fn(k, cfg.dim, |_, _| cfg.sigma_a * rng.normal());
    let mut x = z.matmul(&a);
    for v in x.as_mut_slice().iter_mut() {
        *v += cfg.sigma_x * rng.normal();
    }
    (
        Dataset { x, name: format!("synth-n{}-d{}-a{}", cfg.n, cfg.dim, cfg.alpha) },
        z,
        a,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ibp_expected_total_dishes() {
        // E[K] = alpha * H_N
        let n = 500;
        let alpha = 3.0;
        let h: f64 = (1..=n).map(|i| 1.0 / i as f64).sum();
        let mut rng = Pcg64::new(42);
        let mut total = 0.0;
        let reps = 200;
        for _ in 0..reps {
            let (_, m) = sample_ibp(n, alpha, &mut rng);
            total += m.len() as f64;
        }
        let mean_k = total / reps as f64;
        assert!((mean_k - alpha * h).abs() < 1.5, "mean_k={mean_k}, want≈{}", alpha * h);
    }

    #[test]
    fn ibp_first_customer_poisson_alpha() {
        let mut rng = Pcg64::new(1);
        let mut total = 0usize;
        let reps = 2000;
        for _ in 0..reps {
            let (rows, _) = sample_ibp(1, 2.5, &mut rng);
            total += rows[0].iter().filter(|&&b| b == 1).count();
        }
        let mean = total as f64 / reps as f64;
        assert!((mean - 2.5).abs() < 0.15, "mean={mean}");
    }

    #[test]
    fn counts_match_matrix() {
        let mut rng = Pcg64::new(2);
        let (rows, m) = sample_ibp(100, 2.0, &mut rng);
        for (k, want) in m.iter().enumerate() {
            let got = rows.iter().filter(|r| r[k] == 1).count();
            assert_eq!(got, *want);
        }
    }

    #[test]
    fn generate_shapes_and_noise() {
        let cfg = SynthConfig { n: 300, dim: 8, seed: 5, ..Default::default() };
        let (ds, z, a) = generate(&cfg);
        assert_eq!(ds.x.rows(), 300);
        assert_eq!(ds.x.cols(), 8);
        assert_eq!(z.rows(), 300);
        assert_eq!(z.cols(), a.rows());
        let resid = ds.x.sub(&z.matmul(&a));
        let sd = (resid.frob2() / (300.0 * 8.0)).sqrt();
        assert!((sd - cfg.sigma_x).abs() < 0.03, "sd={sd}");
    }

    #[test]
    fn lof_ordering_heads_are_older() {
        // restaurant construction: earlier columns must have their first 1
        // no later than later columns (left-ordered-ish by construction).
        let mut rng = Pcg64::new(3);
        let (rows, m) = sample_ibp(50, 1.5, &mut rng);
        assert!(!m.is_empty());
        assert!(rows.iter().all(|r| r.len() == m.len()));
    }
}
