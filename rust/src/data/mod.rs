//! Data sets: the paper's "Cambridge" synthetic images, general
//! IBP-sampled synthetic data, and CSV I/O.

pub mod cambridge;
pub mod loader;
pub mod synth;

use crate::linalg::Mat;

/// An observation matrix with a display name.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub x: Mat,
    pub name: String,
}

impl Dataset {
    pub fn n(&self) -> usize {
        self.x.rows()
    }

    pub fn dim(&self) -> usize {
        self.x.cols()
    }

    /// Split into (train, heldout) by taking every `1/frac`-th row as
    /// held-out (deterministic, stratified across the file).
    pub fn split_heldout(&self, frac: f64) -> (Dataset, Dataset) {
        assert!(frac > 0.0 && frac < 1.0);
        let period = (1.0 / frac).round().max(2.0) as usize;
        let mut train_rows = Vec::new();
        let mut test_rows = Vec::new();
        for i in 0..self.n() {
            if i % period == period - 1 {
                test_rows.push(i);
            } else {
                train_rows.push(i);
            }
        }
        let take = |idx: &[usize]| {
            Mat::from_fn(idx.len(), self.dim(), |i, j| self.x[(idx[i], j)])
        };
        (
            Dataset { x: take(&train_rows), name: format!("{}-train", self.name) },
            Dataset { x: take(&test_rows), name: format!("{}-test", self.name) },
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_sizes() {
        let ds = Dataset { x: Mat::zeros(100, 4), name: "t".into() };
        let (tr, te) = ds.split_heldout(0.1);
        assert_eq!(te.n(), 10);
        assert_eq!(tr.n(), 90);
        assert_eq!(tr.dim(), 4);
    }

    #[test]
    fn split_preserves_rows() {
        let ds = Dataset {
            x: Mat::from_fn(20, 2, |i, j| (i * 2 + j) as f64),
            name: "t".into(),
        };
        let (tr, te) = ds.split_heldout(0.25);
        assert_eq!(tr.n() + te.n(), 20);
        // every original row appears exactly once across the splits
        let mut seen: Vec<f64> = tr.x.col(0).into_iter().chain(te.x.col(0)).collect();
        seen.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let want: Vec<f64> = (0..20).map(|i| (i * 2) as f64).collect();
        assert_eq!(seen, want);
    }
}
