//! Experiment runner: the glue that `main`, the examples and the bench
//! harness share. Builds the dataset from a [`RunConfig`], drives the
//! selected sampler, evaluates the held-out joint log-likelihood on a
//! schedule, and returns the Figure-1 [`Trace`].
//!
//! For the hybrid sampler this is also where durable state is wired in:
//! `checkpoint_every` writes full [`Checkpoint`]s (`crate::snapshot`) on
//! an iteration schedule, `keep_samples` accumulates a thinned posterior
//! [`SampleReservoir`] (`crate::serve`), and [`resume`] continues an
//! interrupted run **bit-identically** to one that never stopped.

use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::config::{CommModel, ObsLevel, RunConfig, SamplerKind};
use crate::coordinator::{Coordinator, CoordinatorConfig, IterRecord, IterTiming, VClock};
use crate::data::cambridge::{self, CambridgeConfig};
use crate::data::{loader, synth, Dataset};
use crate::linalg::Mat;
use crate::metrics::online::{DiagState, DiagSummary, StopRule, STALL_WINDOW};
use crate::metrics::{Trace, TracePoint};
use crate::model::{GlobalParams, LinGauss};
use crate::obs::{self, RunReport};
use crate::rng::{tags, Pcg64};
use crate::samplers::collapsed::{CollapsedGibbs, Mode};
use crate::samplers::eval::HeldoutEval;
use crate::samplers::uncollapsed::UncollapsedGibbs;
use crate::samplers::SamplerOptions;
use crate::serve::{PosteriorSample, SampleReservoir};
use crate::snapshot::{Checkpoint, CheckpointRef};

/// Build the dataset named by the config.
pub fn build_dataset(cfg: &RunConfig) -> Result<Dataset> {
    match cfg.dataset.as_str() {
        "cambridge" => Ok(cambridge::generate(&CambridgeConfig {
            n: cfg.n,
            k_true: cfg.k_true,
            activation: 0.5,
            sigma_x: cfg.data_sigma_x,
            seed: cfg.seed,
        })
        .0),
        "synth" => Ok(synth::generate(&synth::SynthConfig {
            n: cfg.n,
            dim: cfg.dim,
            alpha: cfg.alpha,
            sigma_a: cfg.sigma_a,
            sigma_x: cfg.data_sigma_x,
            seed: cfg.seed,
        })
        .0),
        path if path.ends_with(".csv") => {
            let x = loader::read_csv(Path::new(path))?;
            Ok(Dataset { x, name: path.to_string() })
        }
        other => bail!("unknown dataset '{other}' (cambridge|synth|<file>.csv)"),
    }
}

fn sampler_options(cfg: &RunConfig) -> SamplerOptions {
    SamplerOptions {
        kmax_new: cfg.kmax_new,
        sample_alpha: cfg.sample_hypers,
        sample_sigmas: cfg.sample_hypers,
        k_cap: cfg.k_cap,
        ..Default::default()
    }
}

/// Where this config's checkpoints live ("" ⇒ `<out_dir>/checkpoint.pibp`).
pub fn checkpoint_file(cfg: &RunConfig) -> PathBuf {
    if cfg.checkpoint_path.is_empty() {
        Path::new(&cfg.out_dir).join("checkpoint.pibp")
    } else {
        PathBuf::from(&cfg.checkpoint_path)
    }
}

/// Where this config's obs report goes ("" ⇒ `<out_dir>/run_obs.json`).
pub fn obs_report_file(cfg: &RunConfig) -> PathBuf {
    if cfg.obs_out.is_empty() {
        Path::new(&cfg.out_dir).join("run_obs.json")
    } else {
        PathBuf::from(&cfg.obs_out)
    }
}

/// Flush the live obs registry to this run's report file. Called at the
/// checkpoint cadence (so resumed runs report consistently) and at run
/// end. Non-fatal: the report is a diagnostic artifact, never the run's
/// durable state, so a full disk must not kill the chain.
fn flush_obs(cfg: &RunConfig) {
    if cfg.obs == ObsLevel::Off {
        return;
    }
    let path = obs_report_file(cfg);
    if let Some(dir) = path.parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    if let Err(e) = RunReport::write(&path) {
        eprintln!("pibp: warning: obs report write failed: {e:#}");
    }
}

/// The outcome of a run: the convergence trace plus final state views.
#[derive(Debug)]
pub struct RunOutcome {
    pub trace: Trace,
    pub final_k: usize,
    pub final_params: GlobalParams,
    /// Posterior feature loadings at the end (K × D) — Figure-2 input.
    pub features: Mat,
    /// Total virtual seconds: the coordinator's [`VClock`] for the
    /// hybrid, accumulated sampler busy time (one worker, no messages —
    /// the same clock through [`SerialVtime`]) for the serial baselines.
    pub elapsed_s: f64,
    /// Thinned posterior samples accumulated when `keep_samples > 0`
    /// (empty otherwise; always empty for the serial baselines).
    pub reservoir: SampleReservoir,
}

/// Run the configured sampler for `cfg.iters` iterations.
///
/// Progress callback fires after every iteration with the iteration index.
/// Multi-chain configs (`chains > 1` or a non-empty `until` rule) must go
/// through [`run_multi`] — this entry point drives exactly one chain.
pub fn run(cfg: &RunConfig, progress: impl FnMut(usize)) -> Result<RunOutcome> {
    cfg.validate()?;
    if cfg.chains > 1 || !cfg.until.is_empty() {
        bail!(
            "config requests convergence diagnostics (chains={} until='{}'): \
             call runner::run_multi (the pibp binary routes --chains / --until there)",
            cfg.chains,
            cfg.until
        );
    }
    match cfg.sampler {
        SamplerKind::Hybrid => run_hybrid(cfg, None, progress),
        _ => run_serial(cfg, progress),
    }
}

/// Resume a checkpointed hybrid run. `overrides` are `--set`-style
/// (key, value) pairs applied on top of the checkpoint's stored config —
/// typically `iters` to extend the horizon, or `threads_per_worker`
/// (both outside the chain fingerprint). Any override that changes a
/// chain-relevant setting is rejected: the resumed chain must be the
/// same chain.
pub fn resume(
    ckpt_path: &Path,
    overrides: &[(String, String)],
    progress: impl FnMut(usize),
) -> Result<(RunConfig, RunOutcome)> {
    let ckpt = Checkpoint::load(ckpt_path)?;
    let mut cfg = RunConfig::from_canonical(&ckpt.config_text)
        .context("reconstructing the checkpoint's run configuration")?;
    for (k, v) in overrides {
        cfg.apply(k, v)?;
    }
    cfg.validate()?;
    if cfg.fingerprint() != ckpt.fingerprint {
        bail!(
            "configuration fingerprint mismatch: the resumed settings change \
             the chain (dataset / sampler / backend / P / L / seed / priors / \
             eval schedule must match the checkpointed run; extend with \
             --set iters=N or change threads instead)"
        );
    }
    let done = ckpt.coord.iter as usize;
    if cfg.iters <= done {
        bail!(
            "checkpoint is already at iteration {done} ≥ target iters={}; \
             extend the run with --set iters=N",
            cfg.iters
        );
    }
    let out = run_hybrid(&cfg, Some(ckpt), progress)?;
    Ok((cfg, out))
}

/// Shared prologue of the hybrid and serial paths: dataset build,
/// held-out split, evaluator on the `split(7777)` stream, labelled +
/// thinned trace. One place, so the baselines' evaluation streams can
/// never drift from the hybrid's (the Figure-1 comparison depends on
/// that).
struct RunSetup {
    train: Dataset,
    lg: LinGauss,
    eval_rng: Pcg64,
    evaluator: HeldoutEval,
    trace: Trace,
}

fn setup_run(cfg: &RunConfig) -> Result<RunSetup> {
    let ds = build_dataset(cfg)?;
    let (train, test) = if cfg.heldout_frac > 0.0 {
        ds.split_heldout(cfg.heldout_frac)
    } else {
        (ds.clone(), ds)
    };
    let mut trace = Trace::new(format!("{}-p{}", cfg.sampler.name(), cfg.processors));
    trace.set_thinning(cfg.trace_thin);
    Ok(RunSetup {
        train,
        lg: LinGauss::new(cfg.sigma_x, cfg.sigma_a),
        eval_rng: Pcg64::new(cfg.seed).split(tags::EVAL),
        // the evaluator owns its persistent sweep pool for the whole run
        // (spawned here once, reused by every scheduled evaluation); the
        // coordinator workers each spawn their own at Coordinator::new
        evaluator: HeldoutEval::new(test.x, cfg.eval_sweeps)
            .with_threads(cfg.threads_per_worker)
            .with_kernel(cfg.kernel),
        trace,
    })
}

/// What one [`ChainRun::step`] did, surfaced so a multi-chain driver can
/// feed convergence diagnostics without touching chain state.
struct StepInfo {
    rec: IterRecord,
    /// Was iteration `i` on the evaluation schedule (`i % eval_every == 0`)?
    scheduled_eval: bool,
    /// The trace point the thinned trace actually **kept** this iteration
    /// (`None` when no eval ran or the thinning counter dropped it). Diag
    /// observes exactly these, so online estimators see precisely
    /// `trace.points` — nothing more, nothing less.
    kept: Option<TracePoint>,
}

/// One live hybrid chain: the coordinator plus every piece of per-chain
/// run state (evaluator, eval RNG stream, thinned trace, posterior
/// reservoir, wall/iteration offsets from a resume). [`run_hybrid`]
/// drives exactly one of these; [`run_multi`] drives `C` of them in
/// lockstep. Both paths share every line of the iteration body, so a
/// replica chain inside a diagnosed run is bit-identical to the same
/// seed run standalone — the property `tests/diag_equivalence.rs` pins.
struct ChainRun {
    cfg: RunConfig,
    coord: Coordinator,
    eval_rng: Pcg64,
    evaluator: HeldoutEval,
    trace: Trace,
    reservoir: SampleReservoir,
    start_iter: usize,
    wall_base: f64,
    wall0: Instant,
}

impl ChainRun {
    /// Build a chain from its config, optionally continuing from a
    /// checkpoint. Fresh runs and resumed runs share every line of the
    /// iteration loop, so their schedules (evaluation, sampling,
    /// checkpoint cadence) are identical by construction.
    fn new(cfg: &RunConfig, resume_from: Option<Checkpoint>) -> Result<Self> {
        let RunSetup { train, lg, mut eval_rng, mut evaluator, mut trace } = setup_run(cfg)?;
        let ccfg = CoordinatorConfig {
            processors: cfg.processors,
            sub_iters: cfg.sub_iters,
            threads_per_worker: cfg.threads_per_worker,
            kernel: cfg.kernel,
            seed: cfg.seed,
            lg,
            alpha: cfg.alpha,
            opts: sampler_options(cfg),
            backend: cfg.backend,
            artifacts_dir: PathBuf::from(&cfg.artifacts_dir),
            comm: cfg.comm,
            // validated by RunConfig::validate, but parse() re-checks so
            // hand-built configs fail here with the same message
            transport: crate::coordinator::TransportConfig::parse(
                &cfg.transport,
                &cfg.listen,
            )?,
        };
        let mut coord = Coordinator::new(&train.x, ccfg).context("starting coordinator")?;
        let mut reservoir = SampleReservoir::new(cfg.keep_samples);
        let mut start_iter = 0usize;
        let mut wall_base = 0.0f64;
        if let Some(ck) = resume_from {
            coord.restore(&ck.coord).context("restoring coordinator state")?;
            eval_rng = Pcg64::from_state(ck.eval_rng);
            evaluator.restore_z_state(ck.z_test)?;
            trace = ck.trace;
            trace.set_thinning(cfg.trace_thin);
            reservoir = ck.reservoir;
            // like trace_thin above, a --set keep_samples override on resume
            // takes effect (no-op when unchanged, preserving bit-exactness)
            reservoir.set_capacity(cfg.keep_samples);
            start_iter = ck.coord.iter as usize;
            wall_base = ck.wall_s;
        }
        Ok(Self {
            cfg: cfg.clone(),
            coord,
            eval_rng,
            evaluator,
            trace,
            reservoir,
            start_iter,
            wall_base,
            wall0: Instant::now(),
        })
    }

    fn wall_s(&self) -> f64 {
        self.wall_base + self.wall0.elapsed().as_secs_f64()
    }

    /// Evaluate held-out likelihood and push a trace point for `rec`,
    /// reporting whether the thinned trace kept it.
    fn eval_and_trace(&mut self, rec: &IterRecord) -> Option<TracePoint> {
        let h = self.evaluator.evaluate(self.coord.params(), &mut self.eval_rng);
        let p = TracePoint {
            iter: rec.iter,
            vtime_s: rec.vtime_total_s,
            wall_s: self.wall_s(),
            heldout: h,
            k: rec.k,
            sigma_x: rec.sigma_x,
            alpha: rec.alpha,
        };
        if self.trace.push(p) { Some(p) } else { None }
    }

    fn write_checkpoint(&mut self) -> Result<()> {
        let path = checkpoint_file(&self.cfg);
        let wall_s = self.wall_s();
        save_checkpoint(
            &self.cfg,
            &mut self.coord,
            &self.eval_rng,
            &self.evaluator,
            &self.trace,
            &self.reservoir,
            wall_s,
            &path,
        )
        .with_context(|| format!("writing checkpoint {}", path.display()))?;
        // flush obs at the same cadence: a crash loses at most one
        // checkpoint interval of diagnostics, like everything else
        flush_obs(&self.cfg);
        Ok(())
    }

    /// Advance the chain one iteration. This is the single shared
    /// iteration body for fresh, resumed and replica chains.
    fn step(&mut self, i: usize) -> Result<StepInfo> {
        let rec = self.coord.step()?;
        let scheduled_eval = i % self.cfg.eval_every == 0;
        let mut kept = None;
        if scheduled_eval {
            kept = self.eval_and_trace(&rec);
        }
        if self.reservoir.wants(rec.iter as u64) {
            // gather_z is a pure read of the workers (no RNG), so sample
            // recording never perturbs the chain
            let z = self.coord.gather_z()?;
            let p = self.coord.params();
            self.reservoir.record(PosteriorSample {
                iter: rec.iter as u64,
                z,
                a: p.a.clone(),
                pi: p.pi.clone(),
                sigma_x: p.lg.sigma_x,
                sigma_a: p.lg.sigma_a,
                alpha: p.alpha,
            });
        }
        if self.cfg.checkpoint_every > 0
            && ((i + 1) % self.cfg.checkpoint_every == 0 || i + 1 == self.cfg.iters)
        {
            self.write_checkpoint()?;
        }
        if i + 1 == self.cfg.iters && !scheduled_eval {
            // bonus final evaluation so every returned trace ends fresh.
            // Deliberately AFTER the checkpoint write: this eval depends
            // on the target horizon (`iters`), so letting it touch
            // checkpointed state (the eval RNG stream, the warm Z_test,
            // the trace thinning counter) would make a resumed run
            // diverge from an uninterrupted one on the evaluation stream.
            // Checkpoints therefore always sit at horizon-independent
            // iteration boundaries.
            kept = self.eval_and_trace(&rec);
        }
        Ok(StepInfo { rec, scheduled_eval, kept })
    }

    /// Finish the chain at iteration `i` as if the configured horizon had
    /// been `i + 1`: write the final checkpoint if the cadence in `step`
    /// didn't just produce one, then run the bonus final evaluation if
    /// iteration `i` wasn't a scheduled one — exactly the tail `step`
    /// performs when `i + 1 == iters`. An early-stopped chain is
    /// therefore bit-identical to a standalone run with `iters = i + 1`.
    fn close_at(&mut self, i: usize, info: &StepInfo) -> Result<()> {
        let at_horizon = i + 1 == self.cfg.iters;
        if self.cfg.checkpoint_every > 0
            && (i + 1) % self.cfg.checkpoint_every != 0
            && !at_horizon
        {
            self.write_checkpoint()?;
        }
        if !info.scheduled_eval && !at_horizon {
            self.eval_and_trace(&info.rec);
        }
        Ok(())
    }

    fn into_outcome(self) -> RunOutcome {
        let params = self.coord.params().clone();
        RunOutcome {
            final_k: params.k(),
            features: params.a.clone(),
            elapsed_s: self.coord.clock.elapsed_s(),
            final_params: params,
            trace: self.trace,
            reservoir: self.reservoir,
        }
    }
}

/// The hybrid (coordinator) path, optionally continuing from a
/// checkpoint: one [`ChainRun`] driven from its start iteration to the
/// configured horizon.
fn run_hybrid(
    cfg: &RunConfig,
    resume_from: Option<Checkpoint>,
    mut progress: impl FnMut(usize),
) -> Result<RunOutcome> {
    obs::set_level(cfg.obs);
    obs::reset();
    let mut chain = ChainRun::new(cfg, resume_from)?;
    for i in chain.start_iter..cfg.iters {
        chain.step(i)?;
        progress(i);
    }
    flush_obs(cfg);
    Ok(chain.into_outcome())
}

/// Maximum autocovariance lag the streaming ESS estimators retain during
/// a diagnosed run. Kept trace points arrive at `eval_every × trace_thin`
/// cadence, so 256 lags cover every realistic Geyer scan depth while
/// keeping `observe` O(256) floats per point per quantity.
pub const DIAG_MAX_LAG: usize = 256;

/// Root seed for replica chain `c` of a multi-chain run: chain 0 keeps
/// the root seed (so a one-chain diagnosed run IS the plain run), higher
/// chains derive a decorrelated 64-bit seed from the reserved
/// `split(tags::chain(c))` diagnostics stream (see the RNG tag table in
/// docs/ARCHITECTURE.md).
pub fn chain_seed(root: u64, c: usize) -> u64 {
    if c == 0 {
        root
    } else {
        Pcg64::new(root).split(tags::chain(c)).next_u64()
    }
}

/// Insert a `.c{c}` suffix before the extension: `trace.json` →
/// `trace.c2.json` (extensionless paths get a plain `.c2` appended).
/// Multi-chain runs name every per-chain artifact this way.
pub fn chain_file(base: &Path, c: usize) -> PathBuf {
    match (
        base.file_stem().and_then(|s| s.to_str()),
        base.extension().and_then(|e| e.to_str()),
    ) {
        (Some(stem), Some(ext)) => base.with_file_name(format!("{stem}.c{c}.{ext}")),
        _ => {
            let mut p = base.as_os_str().to_owned();
            p.push(format!(".c{c}"));
            PathBuf::from(p)
        }
    }
}

/// The config replica chain `c` actually runs: same chain keys, the
/// chain-derived seed, and the multi-chain controls cleared so the
/// replica is an ordinary single-chain run (its checkpoints resume as
/// such). With `chains > 1`, checkpoints move to chain-suffixed paths so
/// replicas never clobber each other. Note the synthetic datasets are
/// generated from `seed`, so replicas explore independent draws of the
/// same generative process — the standard multi-chain R̂ setting applies
/// per chain, and cross-chain R̂ additionally reflects data variability
/// (a `.csv` dataset is shared bit-identically across chains).
pub fn replica_config(cfg: &RunConfig, c: usize) -> RunConfig {
    let mut r = cfg.clone();
    r.seed = chain_seed(cfg.seed, c);
    r.chains = 1;
    r.until = String::new();
    r.trace_out = String::new();
    if cfg.checkpoint_every > 0 && cfg.chains > 1 {
        r.checkpoint_path = chain_file(&checkpoint_file(cfg), c)
            .to_string_lossy()
            .into_owned();
    }
    r
}

/// The outcome of a diagnosed multi-chain run: every replica's
/// [`RunOutcome`] (chain `c` at index `c`) plus the final convergence
/// summary (also mirrored into the obs report's `diag` section).
#[derive(Debug)]
pub struct MultiOutcome {
    pub chains: Vec<RunOutcome>,
    pub diag: DiagSummary,
}

/// Drive `cfg.chains` replica hybrid chains in lockstep with streaming
/// convergence diagnostics (per-chain ESS, cross-chain split-R̂ over the
/// kept trace scalars), and optionally stop every chain early when the
/// config's `until` rule holds.
///
/// Non-perturbation contract: diagnostics only **read** the trace points
/// each chain keeps and draw no RNG, so replica chain `c` here is
/// bit-identical to a standalone [`run`] of [`replica_config`]`(cfg, c)`
/// — enforced by `tests/diag_equivalence.rs`. Early stop at iteration
/// `stopped_at` leaves every chain bit-identical to a standalone run
/// with `iters = stopped_at`, because the stop rule is a deterministic
/// function of the kept trace prefix.
pub fn run_multi(cfg: &RunConfig, mut progress: impl FnMut(usize)) -> Result<MultiOutcome> {
    cfg.validate()?;
    if cfg.sampler != SamplerKind::Hybrid {
        bail!("multi-chain diagnostics require the hybrid sampler");
    }
    let rule = StopRule::parse(&cfg.until)?;
    obs::set_level(cfg.obs);
    obs::reset();
    let c_total = cfg.chains.max(1);
    let mut chains = Vec::with_capacity(c_total);
    for c in 0..c_total {
        chains.push(ChainRun::new(&replica_config(cfg, c), None)?);
    }
    let mut diag = DiagState::new(c_total, DIAG_MAX_LAG);
    let mut stopped_at = None;
    for i in 0..cfg.iters {
        let mut infos = Vec::with_capacity(c_total);
        for chain in chains.iter_mut() {
            infos.push(chain.step(i)?);
        }
        let mut any_kept = false;
        for (c, info) in infos.iter().enumerate() {
            if let Some(p) = &info.kept {
                any_kept = true;
                let ev = diag.observe(c, p);
                if ev.diverged {
                    obs::warn_once(
                        obs::Warn::ChainDiverged,
                        &format!(
                            "chain {c} diverged: non-finite trace scalar at iteration {}",
                            info.rec.iter
                        ),
                    );
                }
                if ev.stalled {
                    obs::warn_once(
                        obs::Warn::ChainStalled,
                        &format!(
                            "chain {c} stalled: {STALL_WINDOW} identical kept trace points \
                             up to iteration {}",
                            info.rec.iter
                        ),
                    );
                }
            }
        }
        let mut stop = false;
        if any_kept {
            // publish the rolling summary so a crash / mid-run obs flush
            // reports the latest diagnostics, not just the final ones
            obs::set_diag(Some(diag.summary(&cfg.until, stopped_at).to_json()));
            if let Some(rule) = &rule {
                if diag.satisfied(rule) {
                    stopped_at = Some(i + 1);
                    for (chain, info) in chains.iter_mut().zip(&infos) {
                        chain.close_at(i, info)?;
                    }
                    stop = true;
                }
            }
        }
        progress(i);
        if stop {
            break;
        }
    }
    let summary = diag.summary(&cfg.until, stopped_at);
    obs::set_diag(Some(summary.to_json()));
    flush_obs(cfg);
    Ok(MultiOutcome {
        chains: chains.into_iter().map(ChainRun::into_outcome).collect(),
        diag: summary,
    })
}

/// Capture and atomically write a checkpoint of the live run. Serialises
/// the trace / reservoir / evaluator state by reference ([`CheckpointRef`])
/// — no deep clones of large state on the checkpoint cadence.
#[allow(clippy::too_many_arguments)]
fn save_checkpoint(
    cfg: &RunConfig,
    coord: &mut Coordinator,
    eval_rng: &Pcg64,
    evaluator: &HeldoutEval,
    trace: &Trace,
    reservoir: &SampleReservoir,
    wall_s: f64,
    path: &Path,
) -> Result<()> {
    let coord_snap = coord.snapshot()?;
    let config_text = cfg.canonical();
    let eval_state = eval_rng.export_state();
    CheckpointRef {
        fingerprint: cfg.fingerprint(),
        config_text: &config_text,
        coord: &coord_snap,
        eval_rng: &eval_state,
        z_test: evaluator.z_state(),
        trace,
        reservoir,
        wall_s,
    }
    .save(path)
}

/// Virtual-time meter for the serial baselines: one "worker", zero
/// messages, so an iteration's virtual duration is exactly its sampler
/// busy time — accumulated through the same [`VClock`] accessor
/// ([`VClock::elapsed_s`]) the hybrid path reports. This fixes the old
/// bug where the serial trace recorded `wall0.elapsed()` — wall time
/// including held-out evaluation, trace recording and setup — as
/// `vtime_s`, inflating the serial curves in any vtime-axis comparison
/// against the hybrid (whose clock meters sampler work only).
struct SerialVtime {
    clock: VClock,
    comm: CommModel,
}

impl SerialVtime {
    fn new(comm: CommModel) -> Self {
        Self { clock: VClock::new(), comm }
    }

    /// Run one metered sampler step: only `f`'s execution advances the
    /// virtual clock (comm byte vectors are empty ⇒ zero comm cost).
    fn step<R>(&mut self, f: impl FnOnce() -> R) -> R {
        let t0 = Instant::now();
        let out = f();
        let timing = IterTiming {
            worker_busy_s: vec![t0.elapsed().as_secs_f64()],
            ..Default::default()
        };
        self.clock.advance(&timing, &self.comm);
        out
    }

    fn vtime_s(&self) -> f64 {
        self.clock.elapsed_s()
    }
}

/// The serial baselines (collapsed / accelerated / uncollapsed); the
/// hybrid is dispatched to [`run_hybrid`] before this is reached.
fn run_serial(cfg: &RunConfig, mut progress: impl FnMut(usize)) -> Result<RunOutcome> {
    obs::set_level(cfg.obs);
    obs::reset();
    let RunSetup { train, lg, mut eval_rng, mut evaluator, mut trace } = setup_run(cfg)?;
    let wall0 = Instant::now();
    let mut vt = SerialVtime::new(cfg.comm);

    if cfg.sampler == SamplerKind::Uncollapsed {
        let mut rng = Pcg64::new(cfg.seed).split(tags::SERIAL_UNCOLLAPSED);
        let k_fixed = cfg.k_cap.min(16);
        let mut s = UncollapsedGibbs::new(
            train.x.clone(), k_fixed, lg, cfg.alpha, sampler_options(cfg), &mut rng,
        );
        for i in 0..cfg.iters {
            let rec = vt.step(|| s.step(&mut rng));
            if i % cfg.eval_every == 0 || i + 1 == cfg.iters {
                let h = evaluator.evaluate(&s.params, &mut eval_rng);
                trace.push(TracePoint {
                    iter: rec.iter,
                    vtime_s: vt.vtime_s(),
                    wall_s: wall0.elapsed().as_secs_f64(),
                    heldout: h,
                    k: rec.k,
                    sigma_x: rec.sigma_x,
                    alpha: rec.alpha,
                });
            }
            progress(i);
        }
        flush_obs(cfg);
        return Ok(RunOutcome {
            final_k: s.params.k(),
            features: s.params.a.clone(),
            elapsed_s: vt.vtime_s(),
            final_params: s.params.clone(),
            trace,
            reservoir: SampleReservoir::new(0),
        });
    }

    let mode = if cfg.sampler == SamplerKind::Collapsed {
        Mode::Exact
    } else {
        Mode::Predictive
    };
    let mut rng = Pcg64::new(cfg.seed).split(tags::SERIAL_COLLAPSED);
    let mut s = CollapsedGibbs::new(
        train.x.clone(), lg, cfg.alpha, mode, sampler_options(cfg), &mut rng,
    );
    for i in 0..cfg.iters {
        let rec = vt.step(|| s.step(&mut rng));
        if i % cfg.eval_every == 0 || i + 1 == cfg.iters {
            // draw (A, π) from their conditionals so the held-out
            // metric is the same joint as the hybrid's
            let params = collapsed_params(&s, &mut rng);
            let h = evaluator.evaluate(&params, &mut eval_rng);
            trace.push(TracePoint {
                iter: rec.iter,
                vtime_s: vt.vtime_s(),
                wall_s: wall0.elapsed().as_secs_f64(),
                heldout: h,
                k: rec.k,
                sigma_x: rec.sigma_x,
                alpha: rec.alpha,
            });
        }
        progress(i);
    }
    let params = collapsed_params(&s, &mut rng);
    flush_obs(cfg);
    Ok(RunOutcome {
        final_k: params.k(),
        features: params.a.clone(),
        elapsed_s: vt.vtime_s(),
        final_params: params,
        trace,
        reservoir: SampleReservoir::new(0),
    })
}

/// Draw (A, π) from their conditionals given a collapsed sampler's state,
/// making its held-out metric comparable with the hybrid's.
pub fn collapsed_params(s: &CollapsedGibbs, rng: &mut Pcg64) -> GlobalParams {
    let zm = s.z.to_mat();
    let n = s.x.rows();
    let k = s.z.k();
    if k == 0 {
        return GlobalParams {
            a: Mat::zeros(0, s.x.cols()),
            pi: vec![],
            lg: s.lg,
            alpha: s.alpha,
        };
    }
    let ztz = zm.gram();
    let ztx = zm.t_matmul(&s.x);
    GlobalParams {
        a: s.lg.apost_sample(&ztz, &ztx, rng),
        pi: crate::model::ibp::sample_pi(s.z.m(), n, rng),
        lg: s.lg,
        alpha: s.alpha,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(sampler: SamplerKind) -> RunConfig {
        RunConfig {
            n: 60,
            iters: 8,
            eval_every: 2,
            sampler,
            ..Default::default()
        }
    }

    // Tests that call `run` take the obs test gate: run() sets the
    // process-global obs level from the config, so concurrent lib tests
    // flipping it must serialise (crate::obs::test_level_gate).

    #[test]
    fn runs_every_sampler_kind() {
        let _g = crate::obs::test_level_gate();
        for kind in [
            SamplerKind::Hybrid,
            SamplerKind::Collapsed,
            SamplerKind::Accelerated,
            SamplerKind::Uncollapsed,
        ] {
            let out = run(&tiny(kind), |_| {}).unwrap();
            assert!(!out.trace.points.is_empty(), "{kind:?}");
            assert!(out.trace.last().unwrap().heldout.is_finite(), "{kind:?}");
            assert!(out.reservoir.is_empty(), "{kind:?}: no keep_samples set");
        }
    }

    #[test]
    fn dataset_selection() {
        let mut cfg = tiny(SamplerKind::Hybrid);
        assert_eq!(build_dataset(&cfg).unwrap().dim(), 36);
        cfg.dataset = "synth".into();
        cfg.dim = 12;
        assert_eq!(build_dataset(&cfg).unwrap().dim(), 12);
        cfg.dataset = "nope".into();
        assert!(build_dataset(&cfg).is_err());
    }

    #[test]
    fn hybrid_multi_processor_runs() {
        let _g = crate::obs::test_level_gate();
        let mut cfg = tiny(SamplerKind::Hybrid);
        cfg.processors = 3;
        let out = run(&cfg, |_| {}).unwrap();
        assert!(out.elapsed_s > 0.0);
    }

    #[test]
    fn keep_samples_fills_the_reservoir() {
        let _g = crate::obs::test_level_gate();
        let mut cfg = tiny(SamplerKind::Hybrid);
        cfg.keep_samples = 4;
        let out = run(&cfg, |_| {}).unwrap();
        assert!(!out.reservoir.is_empty());
        assert!(out.reservoir.len() <= 4);
        let last = out.reservoir.samples().last().unwrap();
        // samples live in the same column space as the broadcast globals
        assert_eq!(last.a.rows(), last.pi.len());
        assert_eq!(last.z.k(), last.pi.len());
        // train split of n=60 at heldout 0.1 keeps 54 rows
        assert_eq!(last.z.n(), 54);
    }

    #[test]
    fn serial_vtime_accumulates_busy_not_wall() {
        use std::time::Duration;
        let mut vt = SerialVtime::new(CommModel::default());
        vt.step(|| std::thread::sleep(Duration::from_millis(10)));
        // unmetered wall time between steps must NOT count
        std::thread::sleep(Duration::from_millis(150));
        vt.step(|| std::thread::sleep(Duration::from_millis(10)));
        let v = vt.vtime_s();
        assert!(v >= 0.020, "metered work undercounted: {v}");
        // generous oversleep margin, but far below the 170ms the old
        // wall-clock bug would have reported
        assert!(v < 0.120, "unmetered wall time leaked into vtime: {v}");
    }

    #[test]
    fn serial_trace_vtime_is_busy_time_not_wall() {
        let _g = crate::obs::test_level_gate();
        for kind in [SamplerKind::Collapsed, SamplerKind::Uncollapsed] {
            let out = run(&tiny(kind), |_| {}).unwrap();
            let pts = &out.trace.points;
            assert!(!pts.is_empty());
            for w in pts.windows(2) {
                assert!(w[0].vtime_s <= w[1].vtime_s, "{kind:?}: vtime not monotone");
            }
            for p in pts {
                assert!(p.vtime_s > 0.0, "{kind:?}: a step took zero time?");
                // vtime counts sampler steps only; wall additionally
                // includes every held-out evaluation up to this point
                assert!(
                    p.vtime_s <= p.wall_s,
                    "{kind:?}: vtime {} > wall {}",
                    p.vtime_s,
                    p.wall_s
                );
            }
            assert!(out.elapsed_s >= pts.last().unwrap().vtime_s);
        }
    }

    #[test]
    fn obs_full_writes_a_parsable_report() {
        let _g = crate::obs::test_level_gate();
        let dir = std::env::temp_dir().join("pibp_obs_report_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut cfg = tiny(SamplerKind::Hybrid);
        cfg.processors = 2;
        cfg.obs = ObsLevel::Full;
        cfg.obs_out = dir.join("run_obs.json").to_string_lossy().into_owned();
        run(&cfg, |_| {}).unwrap();
        let text = std::fs::read_to_string(obs_report_file(&cfg)).unwrap();
        let doc = crate::config::Json::parse(&text).unwrap();
        // the renderer enforces the schema's required keys
        let rendered = crate::obs::render_json(&doc).unwrap();
        assert!(rendered.contains("obs report (level=full)"), "{rendered}");
    }

    #[test]
    fn checkpoint_file_resolution() {
        let mut cfg = RunConfig::default();
        assert_eq!(
            checkpoint_file(&cfg),
            Path::new("results").join("checkpoint.pibp")
        );
        cfg.checkpoint_path = "elsewhere/ck.pibp".into();
        assert_eq!(checkpoint_file(&cfg), PathBuf::from("elsewhere/ck.pibp"));
    }

    #[test]
    fn chain_seed_layout() {
        // chain 0 IS the root seed; higher chains are decorrelated and
        // stable (the derivation is part of the checkpoint/repro contract)
        assert_eq!(chain_seed(42, 0), 42);
        let s1 = chain_seed(42, 1);
        let s2 = chain_seed(42, 2);
        assert_ne!(s1, 42);
        assert_ne!(s1, s2);
        assert_eq!(s1, chain_seed(42, 1), "derivation must be deterministic");
        assert_ne!(chain_seed(43, 1), s1, "root seed must matter");
    }

    #[test]
    fn chain_file_suffixes_before_extension() {
        assert_eq!(
            chain_file(Path::new("out/trace.json"), 2),
            PathBuf::from("out/trace.c2.json")
        );
        assert_eq!(
            chain_file(Path::new("checkpoint.pibp"), 0),
            PathBuf::from("checkpoint.c0.pibp")
        );
        assert_eq!(chain_file(Path::new("noext"), 1), PathBuf::from("noext.c1"));
    }

    #[test]
    fn replica_config_clears_multichain_controls() {
        let mut cfg = tiny(SamplerKind::Hybrid);
        cfg.chains = 3;
        cfg.until = "rhat<1.05".into();
        cfg.trace_out = "t.json".into();
        cfg.checkpoint_every = 4;
        let r = replica_config(&cfg, 1);
        assert_eq!(r.chains, 1);
        assert!(r.until.is_empty() && r.trace_out.is_empty());
        assert_eq!(r.seed, chain_seed(cfg.seed, 1));
        assert_eq!(
            PathBuf::from(&r.checkpoint_path),
            Path::new("results").join("checkpoint.c1.pibp")
        );
        // replica configs validate and fingerprint as plain runs
        r.validate().unwrap();
        // without checkpointing, the path is left alone
        cfg.checkpoint_every = 0;
        assert!(replica_config(&cfg, 1).checkpoint_path.is_empty());
    }

    #[test]
    fn run_rejects_multichain_configs() {
        let mut cfg = tiny(SamplerKind::Hybrid);
        cfg.chains = 2;
        let err = run(&cfg, |_| {}).unwrap_err().to_string();
        assert!(err.contains("run_multi"), "{err}");
        cfg.chains = 1;
        cfg.until = "rhat<1.01".into();
        assert!(run(&cfg, |_| {}).is_err());
    }

    #[test]
    fn run_multi_smoke_with_diag_summary() {
        let _g = crate::obs::test_level_gate();
        let mut cfg = tiny(SamplerKind::Hybrid);
        cfg.chains = 2;
        let out = run_multi(&cfg, |_| {}).unwrap();
        assert_eq!(out.chains.len(), 2);
        assert_eq!(out.diag.chains, 2);
        // iters=8, eval_every=2 keeps i ∈ {0,2,4,6} plus the bonus at 7
        assert_eq!(out.diag.points, 5);
        assert!(out.diag.stopped_at.is_none());
        for c in &out.chains {
            assert_eq!(c.trace.points.len(), 5);
            assert!(c.trace.last().unwrap().heldout.is_finite());
        }
        // chains started from different seeds must not be identical
        let (a, b) = (&out.chains[0].trace.points, &out.chains[1].trace.points);
        assert!(
            a.iter().zip(b).any(|(p, q)| p.heldout != q.heldout),
            "replica chains produced identical traces"
        );
    }

    #[test]
    fn run_multi_early_stop_records_trigger() {
        let _g = crate::obs::test_level_gate();
        let mut cfg = tiny(SamplerKind::Hybrid);
        cfg.chains = 2;
        // a rule any pair of healthy chains satisfies as soon as
        // MIN_STOP_POINTS kept points exist (every non-degenerate series
        // has ESS ≥ 1; rhat is omitted since 4-point split-R̂ of the
        // integer K series can legitimately be non-finite)
        cfg.until = "ess>0.5".into();
        let out = run_multi(&cfg, |_| {}).unwrap();
        // 4th kept point lands at i=6 → stop after completing iteration 7
        let stopped = out.diag.stopped_at.expect("rule should have triggered");
        assert_eq!(stopped, 7);
        for c in &out.chains {
            assert_eq!(c.trace.points.len(), 4);
        }
    }
}
