//! Experiment runner: the glue that `main`, the examples and the bench
//! harness share. Builds the dataset from a [`RunConfig`], drives the
//! selected sampler, evaluates the held-out joint log-likelihood on a
//! schedule, and returns the Figure-1 [`Trace`].

use std::path::{Path, PathBuf};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::config::{RunConfig, SamplerKind};
use crate::coordinator::{Coordinator, CoordinatorConfig};
use crate::data::cambridge::{self, CambridgeConfig};
use crate::data::{loader, synth, Dataset};
use crate::linalg::Mat;
use crate::metrics::{Trace, TracePoint};
use crate::model::{GlobalParams, LinGauss};
use crate::rng::Pcg64;
use crate::samplers::collapsed::{CollapsedGibbs, Mode};
use crate::samplers::eval::HeldoutEval;
use crate::samplers::uncollapsed::UncollapsedGibbs;
use crate::samplers::SamplerOptions;

/// Build the dataset named by the config.
pub fn build_dataset(cfg: &RunConfig) -> Result<Dataset> {
    match cfg.dataset.as_str() {
        "cambridge" => Ok(cambridge::generate(&CambridgeConfig {
            n: cfg.n,
            k_true: cfg.k_true,
            activation: 0.5,
            sigma_x: cfg.data_sigma_x,
            seed: cfg.seed,
        })
        .0),
        "synth" => Ok(synth::generate(&synth::SynthConfig {
            n: cfg.n,
            dim: cfg.dim,
            alpha: cfg.alpha,
            sigma_a: cfg.sigma_a,
            sigma_x: cfg.data_sigma_x,
            seed: cfg.seed,
        })
        .0),
        path if path.ends_with(".csv") => {
            let x = loader::read_csv(Path::new(path))?;
            Ok(Dataset { x, name: path.to_string() })
        }
        other => bail!("unknown dataset '{other}' (cambridge|synth|<file>.csv)"),
    }
}

fn sampler_options(cfg: &RunConfig) -> SamplerOptions {
    SamplerOptions {
        kmax_new: cfg.kmax_new,
        sample_alpha: cfg.sample_hypers,
        sample_sigmas: cfg.sample_hypers,
        k_cap: cfg.k_cap,
        ..Default::default()
    }
}

/// The outcome of a run: the convergence trace plus final state views.
pub struct RunOutcome {
    pub trace: Trace,
    pub final_k: usize,
    pub final_params: GlobalParams,
    /// Posterior feature loadings at the end (K × D) — Figure-2 input.
    pub features: Mat,
    /// Total virtual seconds (hybrid) or wall seconds (serial samplers).
    pub elapsed_s: f64,
}

/// Run the configured sampler for `cfg.iters` iterations.
///
/// Progress callback fires after every iteration with (iter, trace-point
/// just recorded if any).
pub fn run(cfg: &RunConfig, mut progress: impl FnMut(usize)) -> Result<RunOutcome> {
    cfg.validate()?;
    let ds = build_dataset(cfg)?;
    let (train, test) = if cfg.heldout_frac > 0.0 {
        ds.split_heldout(cfg.heldout_frac)
    } else {
        (ds.clone(), ds)
    };
    let lg = LinGauss::new(cfg.sigma_x, cfg.sigma_a);
    let mut eval_rng = Pcg64::new(cfg.seed).split(7777);
    let mut evaluator = HeldoutEval::new(test.x.clone(), cfg.eval_sweeps)
        .with_threads(cfg.threads_per_worker);
    let label = format!("{}-p{}", cfg.sampler.name(), cfg.processors);
    let mut trace = Trace::new(label);

    match cfg.sampler {
        SamplerKind::Hybrid => {
            let ccfg = CoordinatorConfig {
                processors: cfg.processors,
                sub_iters: cfg.sub_iters,
                threads_per_worker: cfg.threads_per_worker,
                seed: cfg.seed,
                lg,
                alpha: cfg.alpha,
                opts: sampler_options(cfg),
                backend: cfg.backend,
                artifacts_dir: PathBuf::from(&cfg.artifacts_dir),
                comm: cfg.comm,
            };
            let mut coord =
                Coordinator::new(&train.x, ccfg).context("starting coordinator")?;
            let wall0 = Instant::now();
            for i in 0..cfg.iters {
                let rec = coord.step()?;
                if i % cfg.eval_every == 0 || i + 1 == cfg.iters {
                    let h = evaluator.evaluate(coord.params(), &mut eval_rng);
                    trace.push(TracePoint {
                        iter: rec.iter,
                        vtime_s: rec.vtime_total_s,
                        wall_s: wall0.elapsed().as_secs_f64(),
                        heldout: h,
                        k: rec.k,
                        sigma_x: rec.sigma_x,
                        alpha: rec.alpha,
                    });
                }
                progress(i);
            }
            let params = coord.params().clone();
            Ok(RunOutcome {
                final_k: params.k(),
                features: params.a.clone(),
                elapsed_s: coord.clock.elapsed_s(),
                final_params: params,
                trace,
            })
        }
        SamplerKind::Collapsed | SamplerKind::Accelerated => {
            let mode = if cfg.sampler == SamplerKind::Collapsed {
                Mode::Exact
            } else {
                Mode::Predictive
            };
            let mut rng = Pcg64::new(cfg.seed).split(2);
            let mut s = CollapsedGibbs::new(
                train.x.clone(), lg, cfg.alpha, mode, sampler_options(cfg), &mut rng,
            );
            let wall0 = Instant::now();
            for i in 0..cfg.iters {
                let rec = s.step(&mut rng);
                if i % cfg.eval_every == 0 || i + 1 == cfg.iters {
                    // draw (A, π) from their conditionals so the held-out
                    // metric is the same joint as the hybrid's
                    let params = collapsed_params(&s, &mut rng);
                    let h = evaluator.evaluate(&params, &mut eval_rng);
                    trace.push(TracePoint {
                        iter: rec.iter,
                        vtime_s: wall0.elapsed().as_secs_f64(),
                        wall_s: wall0.elapsed().as_secs_f64(),
                        heldout: h,
                        k: rec.k,
                        sigma_x: rec.sigma_x,
                        alpha: rec.alpha,
                    });
                }
                progress(i);
            }
            let params = collapsed_params(&s, &mut rng);
            Ok(RunOutcome {
                final_k: params.k(),
                features: params.a.clone(),
                elapsed_s: wall0.elapsed().as_secs_f64(),
                final_params: params,
                trace,
            })
        }
        SamplerKind::Uncollapsed => {
            let mut rng = Pcg64::new(cfg.seed).split(3);
            let k_fixed = cfg.k_cap.min(16);
            let mut s = UncollapsedGibbs::new(
                train.x.clone(), k_fixed, lg, cfg.alpha, sampler_options(cfg), &mut rng,
            );
            let wall0 = Instant::now();
            for i in 0..cfg.iters {
                let rec = s.step(&mut rng);
                if i % cfg.eval_every == 0 || i + 1 == cfg.iters {
                    let h = evaluator.evaluate(&s.params, &mut eval_rng);
                    trace.push(TracePoint {
                        iter: rec.iter,
                        vtime_s: wall0.elapsed().as_secs_f64(),
                        wall_s: wall0.elapsed().as_secs_f64(),
                        heldout: h,
                        k: rec.k,
                        sigma_x: rec.sigma_x,
                        alpha: rec.alpha,
                    });
                }
                progress(i);
            }
            Ok(RunOutcome {
                final_k: s.params.k(),
                features: s.params.a.clone(),
                elapsed_s: wall0.elapsed().as_secs_f64(),
                final_params: s.params.clone(),
                trace,
            })
        }
    }
}

/// Draw (A, π) from their conditionals given a collapsed sampler's state,
/// making its held-out metric comparable with the hybrid's.
pub fn collapsed_params(s: &CollapsedGibbs, rng: &mut Pcg64) -> GlobalParams {
    let zm = s.z.to_mat();
    let n = s.x.rows();
    let k = s.z.k();
    if k == 0 {
        return GlobalParams {
            a: Mat::zeros(0, s.x.cols()),
            pi: vec![],
            lg: s.lg,
            alpha: s.alpha,
        };
    }
    let ztz = zm.gram();
    let ztx = zm.t_matmul(&s.x);
    GlobalParams {
        a: s.lg.apost_sample(&ztz, &ztx, rng),
        pi: crate::model::ibp::sample_pi(s.z.m(), n, rng),
        lg: s.lg,
        alpha: s.alpha,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(sampler: SamplerKind) -> RunConfig {
        RunConfig {
            n: 60,
            iters: 8,
            eval_every: 2,
            sampler,
            ..Default::default()
        }
    }

    #[test]
    fn runs_every_sampler_kind() {
        for kind in [
            SamplerKind::Hybrid,
            SamplerKind::Collapsed,
            SamplerKind::Accelerated,
            SamplerKind::Uncollapsed,
        ] {
            let out = run(&tiny(kind), |_| {}).unwrap();
            assert!(!out.trace.points.is_empty(), "{kind:?}");
            assert!(out.trace.last().unwrap().heldout.is_finite(), "{kind:?}");
        }
    }

    #[test]
    fn dataset_selection() {
        let mut cfg = tiny(SamplerKind::Hybrid);
        assert_eq!(build_dataset(&cfg).unwrap().dim(), 36);
        cfg.dataset = "synth".into();
        cfg.dim = 12;
        assert_eq!(build_dataset(&cfg).unwrap().dim(), 12);
        cfg.dataset = "nope".into();
        assert!(build_dataset(&cfg).is_err());
    }

    #[test]
    fn hybrid_multi_processor_runs() {
        let mut cfg = tiny(SamplerKind::Hybrid);
        cfg.processors = 3;
        let out = run(&cfg, |_| {}).unwrap();
        assert!(out.elapsed_s > 0.0);
    }
}
