//! Typed executors: the bridge between the samplers' f64/dynamic-K world
//! and the static-bucket f32 artifacts.
//!
//! Each op pads live data into the smallest fitting (B, K) bucket —
//! masked rows/features are inert by kernel construction — runs the AOT
//! executable, and crops the results back. Shards larger than the biggest
//! row bucket are chunked (valid for every op here except
//! `collapsed_loglik`, whose marginal does not decompose over rows).

use anyhow::{bail, Result};

use super::pjrt::{Engine, F32Mat};
use crate::linalg::Mat;
use crate::model::state::FeatureState;
use crate::rng::Pcg64;

pub struct Ops<'e> {
    pub engine: &'e Engine,
}

impl<'e> Ops<'e> {
    pub fn new(engine: &'e Engine) -> Self {
        Self { engine }
    }

    /// One uncollapsed Gibbs sweep over all rows of a shard (the hybrid
    /// worker hot path). Updates `z` in place; returns the new residual
    /// matrix X − Z A for the shard.
    ///
    /// Uniforms are drawn from `rng` for the *live* (row, k) lattice only,
    /// row-major — the same consumption order as the native sweep.
    pub fn zsweep(
        &self,
        x: &Mat,
        z: &mut FeatureState,
        a: &Mat,
        prior_logit: &[f64],
        inv2s2: f64,
        rng: &mut Pcg64,
    ) -> Result<Mat> {
        let b_total = x.rows();
        let d = x.cols();
        let k = a.rows();
        assert_eq!(z.k(), k, "feature-state K must match A");
        assert_eq!(prior_logit.len(), k);
        let mut resid = Mat::zeros(b_total, d);
        let max_b = self
            .engine
            .manifest
            .max_rows("zsweep", d)
            .unwrap_or(b_total.max(1));
        let mut start = 0;
        while start < b_total {
            let chunk = (b_total - start).min(max_b);
            self.zsweep_chunk(
                x, z, a, prior_logit, inv2s2, start, chunk, &mut resid, rng,
            )?;
            start += chunk;
        }
        Ok(resid)
    }

    #[allow(clippy::too_many_arguments)]
    fn zsweep_chunk(
        &self,
        x: &Mat,
        z: &mut FeatureState,
        a: &Mat,
        prior_logit: &[f64],
        inv2s2: f64,
        row0: usize,
        rows: usize,
        resid: &mut Mat,
        rng: &mut Pcg64,
    ) -> Result<()> {
        let d = x.cols();
        let k = a.rows();
        let entry = self.engine.manifest.pick("zsweep", rows, k.max(1), d)?;
        let (bp, kp) = (entry.b.unwrap(), entry.k);

        let mut xb = F32Mat::zeros(bp, d);
        let mut zb = F32Mat::zeros(bp, kp);
        let mut ab = F32Mat::zeros(kp, d);
        let mut plb = F32Mat::from_vec(1, kp, vec![-1e30; kp]);
        let mut ub = F32Mat::zeros(bp, kp);
        let mut rm = F32Mat::zeros(bp, 1);
        for i in 0..rows {
            let src = x.row(row0 + i);
            for j in 0..d {
                xb.set(i, j, src[j] as f32);
            }
            for kk in 0..k {
                zb.set(i, kk, z.get(row0 + i, kk) as f32);
                ub.set(i, kk, rng.uniform_f32());
            }
            rm.set(i, 0, 1.0);
        }
        ab.paste_f64(a);
        for kk in 0..k {
            plb.set(0, kk, prior_logit[kk] as f32);
        }
        let out = self.engine.run(
            entry,
            &[xb, zb, ab, plb, ub, F32Mat::scalar(inv2s2 as f32), rm],
        )?;
        let z_new = &out[0];
        let r_new = &out[1];
        for i in 0..rows {
            for kk in 0..k {
                z.set(row0 + i, kk, z_new.get(i, kk) as u8);
            }
            let dst = resid.row_mut(row0 + i);
            for j in 0..d {
                dst[j] = r_new.get(i, j) as f64;
            }
        }
        Ok(())
    }

    /// Local sufficient statistics (ZᵀZ, ZᵀX) for a shard, chunked.
    pub fn suffstats(&self, z: &FeatureState, x: &Mat) -> Result<(Mat, Mat)> {
        let b_total = x.rows();
        let d = x.cols();
        let k = z.k();
        if k == 0 {
            return Ok((Mat::zeros(0, 0), Mat::zeros(0, d)));
        }
        let max_b = self
            .engine
            .manifest
            .max_rows("suffstats", d)
            .unwrap_or(b_total.max(1));
        let mut ztz = Mat::zeros(k, k);
        let mut ztx = Mat::zeros(k, d);
        let mut start = 0;
        while start < b_total {
            let rows = (b_total - start).min(max_b);
            let entry = self.engine.manifest.pick("suffstats", rows, k, d)?;
            let (bp, kp) = (entry.b.unwrap(), entry.k);
            let mut zb = F32Mat::zeros(bp, kp);
            let mut xb = F32Mat::zeros(bp, d);
            let mut rm = F32Mat::zeros(bp, 1);
            for i in 0..rows {
                for kk in 0..k {
                    zb.set(i, kk, z.get(start + i, kk) as f32);
                }
                let src = x.row(start + i);
                for j in 0..d {
                    xb.set(i, j, src[j] as f32);
                }
                rm.set(i, 0, 1.0);
            }
            let out = self.engine.run(entry, &[zb, xb, rm])?;
            ztz.add_assign(&out[0].crop_f64(k, k));
            ztx.add_assign(&out[1].crop_f64(k, d));
            start += rows;
        }
        Ok((ztz, ztx))
    }

    /// Master step: draw A | suff-stats from its matrix-normal posterior
    /// on-device. Standard normals come from `rng` (reproducibility).
    pub fn apost(
        &self,
        ztz: &Mat,
        ztx: &Mat,
        sigma_x: f64,
        sigma_a: f64,
        rng: &mut Pcg64,
    ) -> Result<Mat> {
        let k = ztz.rows();
        let d = ztx.cols();
        if k == 0 {
            return Ok(Mat::zeros(0, d));
        }
        let entry = self.engine.manifest.pick("apost", 0, k, d)?;
        let kp = entry.k;
        let mut ztzb = F32Mat::zeros(kp, kp);
        let mut ztxb = F32Mat::zeros(kp, d);
        let mut eps = F32Mat::zeros(kp, d);
        let mut km = F32Mat::zeros(1, kp);
        ztzb.paste_f64(ztz);
        ztxb.paste_f64(ztx);
        // draw normals only for live rows (same count as the native path)
        for i in 0..k {
            for j in 0..d {
                eps.set(i, j, rng.normal() as f32);
            }
            km.set(0, i, 1.0);
        }
        let out = self.engine.run(
            entry,
            &[ztzb, ztxb, eps, F32Mat::scalar(sigma_x as f32),
              F32Mat::scalar(sigma_a as f32), km],
        )?;
        Ok(out[0].crop_f64(k, d))
    }

    /// Held-out joint log P(X, Z | A, π) (Figure-1 metric), chunked.
    pub fn heldout(
        &self,
        x: &Mat,
        z: &FeatureState,
        a: &Mat,
        pi: &[f64],
        sigma_x: f64,
    ) -> Result<f64> {
        let b_total = x.rows();
        let d = x.cols();
        let k = a.rows();
        if k == 0 {
            let lg = crate::model::LinGauss::new(sigma_x, 1.0);
            return Ok(lg.loglik(x, &Mat::zeros(b_total, 0), &Mat::zeros(0, d)));
        }
        let inv2s2 = 1.0 / (2.0 * sigma_x * sigma_x);
        let logdet_term =
            -0.5 * d as f64 * (crate::model::lingauss::LN_2PI + 2.0 * sigma_x.ln());
        let max_b = self
            .engine
            .manifest
            .max_rows("heldout", d)
            .unwrap_or(b_total.max(1));
        let mut total = 0.0;
        let mut start = 0;
        while start < b_total {
            let rows = (b_total - start).min(max_b);
            let entry = self.engine.manifest.pick("heldout", rows, k, d)?;
            let (bp, kp) = (entry.b.unwrap(), entry.k);
            let mut xb = F32Mat::zeros(bp, d);
            let mut zb = F32Mat::zeros(bp, kp);
            let mut ab = F32Mat::zeros(kp, d);
            let mut lp = F32Mat::zeros(1, kp);
            let mut l1p = F32Mat::zeros(1, kp);
            let mut rm = F32Mat::zeros(bp, 1);
            let mut km = F32Mat::zeros(1, kp);
            for i in 0..rows {
                let src = x.row(start + i);
                for j in 0..d {
                    xb.set(i, j, src[j] as f32);
                }
                for kk in 0..k {
                    zb.set(i, kk, z.get(start + i, kk) as f32);
                }
                rm.set(i, 0, 1.0);
            }
            ab.paste_f64(a);
            for kk in 0..k {
                let p = pi[kk].clamp(1e-12, 1.0 - 1e-12);
                lp.set(0, kk, p.ln() as f32);
                l1p.set(0, kk, (1.0 - p).ln() as f32);
                km.set(0, kk, 1.0);
            }
            let out = self.engine.run(
                entry,
                &[xb, zb, ab, lp, l1p, F32Mat::scalar(inv2s2 as f32),
                  F32Mat::scalar(logdet_term as f32), rm, km],
            )?;
            total += out[0].get(0, 0) as f64;
            start += rows;
        }
        Ok(total)
    }

    /// Collapsed marginal log P(X | Z) on-device (validation path; no
    /// chunking — the marginal does not decompose over rows).
    pub fn collapsed_loglik(
        &self,
        x: &Mat,
        z: &FeatureState,
        sigma_x: f64,
        sigma_a: f64,
    ) -> Result<f64> {
        let b = x.rows();
        let d = x.cols();
        let k = z.k();
        let max_b = self.engine.manifest.max_rows("collapsed_loglik", d).unwrap_or(0);
        if b > max_b {
            bail!("collapsed_loglik artifact caps at {max_b} rows, got {b}");
        }
        let entry = self.engine.manifest.pick("collapsed_loglik", b, k.max(1), d)?;
        let (bp, kp) = (entry.b.unwrap(), entry.k);
        let mut xb = F32Mat::zeros(bp, d);
        let mut zb = F32Mat::zeros(bp, kp);
        let mut km = F32Mat::zeros(1, kp);
        let mut rm = F32Mat::zeros(bp, 1);
        for i in 0..b {
            let src = x.row(i);
            for j in 0..d {
                xb.set(i, j, src[j] as f32);
            }
            for kk in 0..k {
                zb.set(i, kk, z.get(i, kk) as f32);
            }
            rm.set(i, 0, 1.0);
        }
        for kk in 0..k {
            km.set(0, kk, 1.0);
        }
        let out = self.engine.run(
            entry,
            &[xb, zb, F32Mat::scalar(sigma_x as f32),
              F32Mat::scalar(sigma_a as f32), km, rm],
        )?;
        Ok(out[0].get(0, 0) as f64)
    }
}
