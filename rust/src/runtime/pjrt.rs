//! PJRT execution engine: loads AOT-lowered HLO text, compiles it once on
//! the CPU PJRT client, memoises the executable, and runs it on f32
//! buffers. Adapted from the smoke-verified /opt/xla-example/load_hlo
//! pattern (HLO *text* interchange — see DESIGN.md).
//!
//! Two builds of [`Engine`] exist:
//!
//! * **default** — a stub with the identical API whose [`Engine::load`]
//!   always returns an error. The out-of-tree `xla` PJRT bindings are not
//!   vendored in this repository, so default builds cannot execute HLO;
//!   every caller (coordinator workers, benches, integration tests)
//!   already treats a failed `Engine::load` as "fall back to the native
//!   f64 path / skip".
//! * **`--features pjrt`** — the real engine, compiled against the `xla`
//!   dependency. Offline checkouts resolve that to the vendored API stub
//!   (`vendor/xla`, every call errors at runtime — CI uses this build to
//!   keep the engine path type-checked); point the dependency at the
//!   real bindings to execute HLO (see rust/Cargo.toml).

/// A rank-2 f32 host buffer — the only tensor type that crosses the
/// rust ⇄ PJRT boundary (manifest contract).
///
/// ```
/// use pibp::runtime::F32Mat;
/// let mut buf = F32Mat::zeros(2, 3);
/// buf.set(1, 2, 4.5);
/// assert_eq!(buf.get(1, 2), 4.5);
/// assert_eq!(buf.data.len(), 6);
/// ```
#[derive(Clone, Debug, PartialEq)]
pub struct F32Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl F32Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Self { rows, cols, data }
    }

    pub fn scalar(v: f32) -> Self {
        Self { rows: 1, cols: 1, data: vec![v] }
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    /// Copy an f64 matrix into the top-left corner.
    pub fn paste_f64(&mut self, src: &crate::linalg::Mat) {
        assert!(src.rows() <= self.rows && src.cols() <= self.cols);
        for i in 0..src.rows() {
            let base = i * self.cols;
            for j in 0..src.cols() {
                self.data[base + j] = src[(i, j)] as f32;
            }
        }
    }

    /// Extract the top-left block into an f64 matrix.
    pub fn crop_f64(&self, rows: usize, cols: usize) -> crate::linalg::Mat {
        assert!(rows <= self.rows && cols <= self.cols);
        crate::linalg::Mat::from_fn(rows, cols, |i, j| self.get(i, j) as f64)
    }
}

#[cfg(feature = "pjrt")]
mod engine_impl {
    //! The real PJRT engine (requires the `xla` bindings dependency).

    use std::cell::RefCell;
    use std::collections::HashMap;
    use std::path::Path;
    use std::rc::Rc;

    use anyhow::{bail, Context, Result};

    use super::super::artifact::{Entry, Manifest};
    use super::F32Mat;

    /// Compiles + memoises executables for one manifest on one PJRT client.
    ///
    /// Not `Send`: PJRT wrapper types hold raw pointers. Each coordinator
    /// worker thread owns its own `Engine` (CPU client construction is cheap
    /// relative to the per-run compile cache it amortises).
    pub struct Engine {
        client: xla::PjRtClient,
        pub manifest: Manifest,
        cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
        /// Executions performed (metrics).
        pub exec_count: RefCell<usize>,
    }

    impl Engine {
        /// Load the manifest and create a CPU PJRT client.
        pub fn load(artifacts_dir: &Path) -> Result<Self> {
            let manifest = Manifest::load(artifacts_dir)?;
            let client = xla::PjRtClient::cpu().map_err(to_anyhow)?;
            Ok(Self {
                client,
                manifest,
                cache: RefCell::new(HashMap::new()),
                exec_count: RefCell::new(0),
            })
        }

        /// Compile (or fetch memoised) the executable for an entry.
        fn executable(&self, entry: &Entry) -> Result<Rc<xla::PjRtLoadedExecutable>> {
            if let Some(exe) = self.cache.borrow().get(&entry.file) {
                return Ok(exe.clone());
            }
            let path = self.manifest.path_of(entry);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path utf-8")?,
            )
            .map_err(to_anyhow)
            .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(to_anyhow)
                .with_context(|| format!("compiling {}", entry.file))?;
            let exe = Rc::new(exe);
            self.cache.borrow_mut().insert(entry.file.clone(), exe.clone());
            Ok(exe)
        }

        /// Execute an entry on host buffers; validates shapes both ways.
        pub fn run(&self, entry: &Entry, inputs: &[F32Mat]) -> Result<Vec<F32Mat>> {
            if inputs.len() != entry.inputs.len() {
                bail!(
                    "{}: {} inputs given, {} expected",
                    entry.name, inputs.len(), entry.inputs.len()
                );
            }
            let mut literals = Vec::with_capacity(inputs.len());
            for (buf, spec) in inputs.iter().zip(&entry.inputs) {
                if (buf.rows, buf.cols) != spec.shape {
                    bail!(
                        "{}: input '{}' is {}x{}, manifest says {}x{}",
                        entry.name, spec.name, buf.rows, buf.cols,
                        spec.shape.0, spec.shape.1
                    );
                }
                let lit = xla::Literal::vec1(&buf.data)
                    .reshape(&[buf.rows as i64, buf.cols as i64])
                    .map_err(to_anyhow)?;
                literals.push(lit);
            }
            let exe = self.executable(entry)?;
            let result = exe.execute::<xla::Literal>(&literals).map_err(to_anyhow)?;
            *self.exec_count.borrow_mut() += 1;
            let tuple = result[0][0].to_literal_sync().map_err(to_anyhow)?;
            let parts = tuple.to_tuple().map_err(to_anyhow)?;
            if parts.len() != entry.outputs.len() {
                bail!(
                    "{}: {} outputs returned, {} expected",
                    entry.name, parts.len(), entry.outputs.len()
                );
            }
            let mut out = Vec::with_capacity(parts.len());
            for (lit, spec) in parts.into_iter().zip(&entry.outputs) {
                let data: Vec<f32> = lit.to_vec().map_err(to_anyhow)?;
                if data.len() != spec.shape.0 * spec.shape.1 {
                    bail!(
                        "{}: output '{}' has {} elems, want {}x{}",
                        entry.name, spec.name, data.len(), spec.shape.0, spec.shape.1
                    );
                }
                out.push(F32Mat::from_vec(spec.shape.0, spec.shape.1, data));
            }
            Ok(out)
        }

        pub fn compiled_count(&self) -> usize {
            self.cache.borrow().len()
        }
    }

    fn to_anyhow(e: xla::Error) -> anyhow::Error {
        anyhow::anyhow!("{e}")
    }
}

#[cfg(not(feature = "pjrt"))]
mod engine_impl {
    //! API-identical stub used by default builds (no `xla` bindings).

    use std::cell::RefCell;
    use std::path::Path;

    use anyhow::{bail, Result};

    use super::super::artifact::{Entry, Manifest};
    use super::F32Mat;

    /// Stub for the PJRT engine. Exists so the `Backend::Pjrt` code paths
    /// type-check in default builds; [`Engine::load`] always errors, and
    /// every caller treats that as "PJRT unavailable" (native fallback in
    /// the runner, skipped tests/benches).
    pub struct Engine {
        pub manifest: Manifest,
        /// Executions performed (always 0 for the stub; kept for API parity).
        pub exec_count: RefCell<usize>,
    }

    impl Engine {
        /// Always errors: default builds ship without the PJRT bindings.
        pub fn load(artifacts_dir: &Path) -> Result<Self> {
            let _ = Manifest::load(artifacts_dir)?;
            bail!(
                "PJRT backend unavailable: pibp was built without the `pjrt` \
                 feature (the XLA PJRT bindings are not vendored in this \
                 tree); use backend=native"
            )
        }

        /// Unreachable in practice ([`Engine::load`] never succeeds).
        pub fn run(&self, _entry: &Entry, _inputs: &[F32Mat]) -> Result<Vec<F32Mat>> {
            bail!("PJRT backend unavailable (built without the `pjrt` feature)")
        }

        pub fn compiled_count(&self) -> usize {
            0
        }
    }
}

pub use engine_impl::Engine;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32mat_paste_crop() {
        let m = crate::linalg::Mat::from_fn(2, 3, |i, j| (i * 3 + j) as f64);
        let mut buf = F32Mat::zeros(4, 5);
        buf.paste_f64(&m);
        assert_eq!(buf.get(1, 2), 5.0);
        assert_eq!(buf.get(3, 4), 0.0);
        let back = buf.crop_f64(2, 3);
        assert!(back.max_abs_diff(&m) < 1e-6);
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_engine_load_errors() {
        // Regardless of whether artifacts exist, the default build must
        // refuse to construct a PJRT engine (and say why).
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let err = Engine::load(&dir).unwrap_err();
        let msg = format!("{err:#}");
        assert!(
            msg.contains("pjrt") || msg.contains("manifest.json"),
            "unhelpful stub error: {msg}"
        );
    }

    // engine execution is covered by rust/tests/integration_runtime.rs
    // (needs artifacts/ built AND the `pjrt` feature).
}
