//! `artifacts/manifest.json` — the contract between `python/compile/aot.py`
//! and the rust runtime.
//!
//! Every AOT-lowered executable is described by an [`Entry`]: graph name,
//! static bucket sizes (B rows, K features, D dims) and the exact rank-2
//! f32 input/output shapes. The runtime pads live data up to the smallest
//! fitting bucket (masked rows/features are inert by construction — see
//! the kernel docstrings).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::config::json::Json;

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: (usize, usize),
}

#[derive(Clone, Debug)]
pub struct Entry {
    pub name: String,
    /// Row bucket (None for row-independent graphs like `apost`).
    pub b: Option<usize>,
    pub k: usize,
    pub d: usize,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub rows: Vec<usize>,
    pub feats: Vec<usize>,
    pub dims: Vec<usize>,
    pub entries: Vec<Entry>,
}

fn specs(v: &Json, what: &str) -> Result<Vec<TensorSpec>> {
    let arr = v.as_arr().with_context(|| format!("{what} must be an array"))?;
    arr.iter()
        .map(|pair| {
            let p = pair.as_arr().context("tensor spec must be [name, shape]")?;
            let name = p[0].as_str().context("tensor name")?.to_string();
            let s = p[1].as_arr().context("tensor shape")?;
            if s.len() != 2 {
                bail!("tensor '{name}' is not rank-2");
            }
            Ok(TensorSpec {
                name,
                shape: (
                    s[0].as_usize().context("dim 0")?,
                    s[1].as_usize().context("dim 1")?,
                ),
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                path.display()
            )
        })?;
        let v = Json::parse(&text)?;
        let version = v.get("version").and_then(Json::as_usize).unwrap_or(0);
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let list = |key: &str| -> Vec<usize> {
            v.get(key)
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_usize).collect())
                .unwrap_or_default()
        };
        let mut entries = Vec::new();
        for e in v.get("entries").and_then(Json::as_arr).context("entries")? {
            entries.push(Entry {
                name: e.get("name").and_then(Json::as_str).context("name")?.into(),
                b: e.get("b").and_then(Json::as_usize),
                k: e.get("k").and_then(Json::as_usize).context("k")?,
                d: e.get("d").and_then(Json::as_usize).context("d")?,
                file: e.get("file").and_then(Json::as_str).context("file")?.into(),
                inputs: specs(e.get("inputs").context("inputs")?, "inputs")?,
                outputs: specs(e.get("outputs").context("outputs")?, "outputs")?,
            });
        }
        if entries.is_empty() {
            bail!("manifest has no entries");
        }
        Ok(Self {
            dir: dir.to_path_buf(),
            rows: list("rows"),
            feats: list("feats"),
            dims: list("dims"),
            entries,
        })
    }

    /// Smallest bucket entry `name` that fits (b_need rows, k_need feats,
    /// exactly d dims). For row-free graphs pass `b_need = 0`.
    pub fn pick(&self, name: &str, b_need: usize, k_need: usize, d: usize) -> Result<&Entry> {
        self.entries
            .iter()
            .filter(|e| {
                e.name == name
                    && e.d == d
                    && e.k >= k_need
                    && e.b.map_or(b_need == 0, |b| b >= b_need)
            })
            .min_by_key(|e| (e.k, e.b.unwrap_or(0)))
            .with_context(|| {
                format!(
                    "no artifact for {name} with b≥{b_need}, k≥{k_need}, d={d} \
                     (available feats {:?}, rows {:?}; re-run aot.py with bigger buckets)",
                    self.feats, self.rows
                )
            })
    }

    /// Largest row bucket available for `name` (used for chunking).
    pub fn max_rows(&self, name: &str, d: usize) -> Option<usize> {
        self.entries
            .iter()
            .filter(|e| e.name == name && e.d == d)
            .filter_map(|e| e.b)
            .max()
    }

    pub fn path_of(&self, e: &Entry) -> PathBuf {
        self.dir.join(&e.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn repo_artifacts() -> Option<Manifest> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Manifest::load(&dir).ok()
    }

    #[test]
    fn loads_repo_manifest() {
        let Some(m) = repo_artifacts() else { return };
        assert!(m.entries.len() >= 20);
        assert!(m.feats.contains(&8));
        for e in &m.entries {
            assert!(m.path_of(e).exists(), "{} missing", e.file);
        }
    }

    #[test]
    fn pick_selects_smallest_fitting_bucket() {
        let Some(m) = repo_artifacts() else { return };
        let e = m.pick("zsweep", 100, 5, 36).unwrap();
        assert_eq!(e.b, Some(256));
        assert_eq!(e.k, 8);
        let e = m.pick("zsweep", 300, 9, 36).unwrap();
        assert_eq!(e.b, Some(1024));
        assert_eq!(e.k, 16);
        let e = m.pick("apost", 0, 20, 36).unwrap();
        assert_eq!(e.k, 32);
        assert!(m.pick("zsweep", 5000, 5, 36).is_err());
        assert!(m.pick("zsweep", 100, 5, 17).is_err());
        assert!(m.pick("nope", 1, 1, 36).is_err());
    }

    #[test]
    fn entry_shapes_consistent() {
        let Some(m) = repo_artifacts() else { return };
        for e in &m.entries {
            if e.name == "zsweep" {
                let b = e.b.unwrap();
                let byname: std::collections::HashMap<_, _> =
                    e.inputs.iter().map(|t| (t.name.as_str(), t.shape)).collect();
                assert_eq!(byname["x"], (b, e.d));
                assert_eq!(byname["z"], (b, e.k));
                assert_eq!(byname["a"], (e.k, e.d));
                assert_eq!(byname["u"], (b, e.k));
                assert_eq!(byname["inv2s2"], (1, 1));
            }
        }
    }

    #[test]
    fn rejects_bad_manifest() {
        let dir = std::env::temp_dir().join("pibp_bad_manifest");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), r#"{"version": 9}"#).unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::write(dir.join("manifest.json"), r#"{"version": 1, "entries": []}"#)
            .unwrap();
        assert!(Manifest::load(&dir).is_err());
    }
}
