//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`, built once by `make artifacts`) and executes
//! them from the rust hot path. Python never runs at inference time.
//!
//! * [`artifact::Manifest`] — the shape/bucket contract with `aot.py`.
//! * [`pjrt::Engine`] — CPU PJRT client + compile cache.
//! * [`exec::Ops`] — typed, padding-aware ops (zsweep / suffstats /
//!   apost / heldout / collapsed_loglik); every op has a native-rust twin
//!   in `samplers`/`model` that integration tests pin it against.

pub mod artifact;
pub mod exec;
pub mod pjrt;

pub use artifact::Manifest;
pub use exec::Ops;
pub use pjrt::{Engine, F32Mat};
