//! Persistent deterministic thread pool + the [`ParallelCtx`] handle.
//!
//! PR 2's executor re-spawned `std::thread::scope` threads on **every**
//! sweep call — measurable overhead once shards exceed ~10⁵ rows (tens of
//! µs of spawn/join per sub-iteration, × L × iterations). This module
//! replaces the respawn with T long-lived workers created once and reused
//! for every fork-join until the owner drops the handle.
//!
//! Determinism is unaffected by construction: the executor's contract
//! (`crate::parallel` module docs) puts all RNG and merge ordering in the
//! *task structure* (fixed blocks, per-block substreams, index-ordered
//! merges), never in the schedule. Which thread runs a task — a pooled
//! worker, a freshly scoped thread, or the caller inline — cannot change a
//! bit of output. That is what lets the pool be adopted with zero change
//! to any chain, checkpoint, or serving result.
//!
//! ## Channel protocol
//!
//! Each pool worker owns one `std::sync::mpsc` channel of erased closures:
//!
//! ```text
//! caller                               worker w (×(T−1), long-lived)
//!   │  split work into ≤ T chunks        │
//!   │  Job = closure + completion latch  │
//!   ├── senders[w].send(Job) ──────────► │  recv() → catch_unwind(job)
//!   │  (chunk 0 runs on the caller)      │  → latch.done()
//!   │  latch.wait() ◄──────────────────── (last done() notifies)
//!   │  re-raise any task panic           │  recv() blocks for next call
//!   ▼                                    ▼
//! return                              channel dropped ⇒ worker exits
//! ```
//!
//! The caller always executes the first chunk itself (T threads of work
//! from T−1 spawned workers + itself) and **blocks on the latch before
//! returning**. That wait is the soundness argument for lending the
//! workers non-`'static` borrows, exactly as in `std::thread::scope`: no
//! job can outlive the stack frame that owns the borrowed data. A panic
//! inside a job is caught (the long-lived worker survives), recorded on
//! the latch, and re-raised on the caller after every sibling finished.
//!
//! [`ParallelCtx`] is the cheap, cloneable handle threaded through
//! `WorkerConfig` / `HybridConfig` / `ExecConfig`: inline (T = 1), pooled
//! (persistent workers), or scoped (PR-2 respawn semantics, kept for
//! pool-vs-respawn benchmarks and as a scheduling cross-check in tests —
//! all three produce identical bits by the contract above).

use std::any::Any;
use std::fmt;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::obs;

/// A unit of pool work: an erased closure. Jobs handed to the pool are
/// lifetime-erased to `'static` (see the `SAFETY` note in
/// [`ThreadPool::run_scoped`]); the latch wait keeps that honest.
type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fork-join task that may borrow from the caller's stack.
pub(crate) type Task<'env> = Box<dyn FnOnce() + Send + 'env>;

/// Countdown latch: the caller waits until every dispatched job has run
/// (or panicked — the first panic's payload is stashed on the latch and
/// re-raised verbatim after the join, never swallowed and never left to
/// kill a long-lived worker).
struct Latch {
    remaining: Mutex<usize>,
    all_done: Condvar,
    /// First panic payload from a pooled job, resumed on the caller so
    /// the original message/file/line survive (as they would under
    /// scoped or inline execution).
    panic: Mutex<Option<Box<dyn Any + Send>>>,
}

impl Latch {
    fn new(count: usize) -> Self {
        Self {
            remaining: Mutex::new(count),
            all_done: Condvar::new(),
            panic: Mutex::new(None),
        }
    }

    fn done(&self) {
        let mut r = self.remaining.lock().unwrap_or_else(|e| e.into_inner());
        *r -= 1;
        if *r == 0 {
            self.all_done.notify_all();
        }
    }

    fn wait(&self) {
        let mut r = self.remaining.lock().unwrap_or_else(|e| e.into_inner());
        while *r > 0 {
            r = self.all_done.wait(r).unwrap_or_else(|e| e.into_inner());
        }
    }
}

/// T − 1 long-lived worker threads plus the caller: a persistent
/// fork-join arena. Dropping the pool disconnects the channels and joins
/// every worker.
pub struct ThreadPool {
    /// One SPSC job channel per worker. Guarded so the pool handle is
    /// `Sync` (`mpsc::Sender` is `Send` but not `Sync`); dispatch holds
    /// the lock only while pushing the ≤ T−1 jobs of one fork-join.
    senders: Mutex<Vec<Sender<Job>>>,
    handles: Vec<JoinHandle<()>>,
    threads: usize,
}

impl ThreadPool {
    /// Spawn a pool of `threads.max(1)` total execution lanes
    /// (`threads − 1` OS threads; the caller is the last lane). If the OS
    /// refuses a spawn, the pool degrades to the lanes it got — results
    /// are identical at any width, so this only costs wall-clock.
    pub fn new(threads: usize) -> Self {
        let want = threads.max(1);
        let mut senders = Vec::with_capacity(want.saturating_sub(1));
        let mut handles = Vec::with_capacity(want.saturating_sub(1));
        for w in 0..want - 1 {
            let (tx, rx) = channel::<Job>();
            match std::thread::Builder::new()
                .name(format!("pibp-pool-{w}"))
                .spawn(move || {
                    // jobs carry their own unwind guard; recv Err = pool drop
                    while let Ok(job) = rx.recv() {
                        job();
                    }
                }) {
                Ok(h) => {
                    senders.push(tx);
                    handles.push(h);
                }
                Err(e) => {
                    eprintln!(
                        "[pibp pool] could not spawn worker {w} ({e}); \
                         continuing with {} lanes",
                        senders.len() + 1
                    );
                    break;
                }
            }
        }
        let threads = senders.len() + 1;
        Self { senders: Mutex::new(senders), handles, threads }
    }

    /// Total execution lanes (spawned workers + the caller).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `tasks` to completion across the pool, returning only after
    /// every task finished. Tasks may borrow from the caller's stack; a
    /// panic in any task is re-raised here once all siblings are done.
    pub(crate) fn run_scoped<'env>(&self, tasks: Vec<Task<'env>>) {
        let n = tasks.len();
        if n == 0 {
            return;
        }
        let mut it = tasks.into_iter();
        // n >= 1 was checked above; let-else keeps this path panic-free
        let Some(first) = it.next() else { return };
        if n == 1 || self.threads <= 1 {
            first();
            for task in it {
                task();
            }
            return;
        }
        let latch = Arc::new(Latch::new(n - 1));
        let dispatch = obs::span(obs::Span::PoolDispatch);
        {
            let senders = self.senders.lock().unwrap_or_else(|e| e.into_inner());
            for (w, task) in it.enumerate() {
                let latch = Arc::clone(&latch);
                // obs probe: queue wait = enqueue → first instruction.
                // Captured only at obs level `full` (None otherwise), and
                // recorded inside the job — pure measurement, no effect on
                // scheduling, task structure or merge order.
                // detlint:allow(wall-clock-in-chain): obs-only queue-wait probe — the timestamp feeds a histogram, never the chain
                let enqueued = if obs::timing() { Some(Instant::now()) } else { None };
                let job: Task<'_> = Box::new(move || {
                    if let Some(t0) = enqueued {
                        obs::record_ns(
                            obs::Span::PoolQueueWait,
                            t0.elapsed().as_nanos().min(u64::MAX as u128) as u64,
                        );
                    }
                    let run = {
                        let _busy = obs::span(obs::Span::PoolLaneBusy);
                        catch_unwind(AssertUnwindSafe(task))
                    };
                    if let Err(payload) = run {
                        let mut slot =
                            latch.panic.lock().unwrap_or_else(|e| e.into_inner());
                        slot.get_or_insert(payload);
                    }
                    latch.done();
                });
                // SAFETY: `job` borrows only data outliving this call
                // (`'env`) plus the Arc'd latch. `latch.wait()` below does
                // not return until the job has run to completion (`done`
                // fires even on panic, via the catch_unwind above), so no
                // borrow escapes this stack frame — the same argument that
                // makes `std::thread::scope` sound, with the latch playing
                // the role of the scope join.
                let job: Job = unsafe { std::mem::transmute::<Task<'_>, Task<'static>>(job) };
                if let Err(back) = senders[w % senders.len()].send(job) {
                    // worker gone (cannot normally happen before drop):
                    // run the job inline — it still counts down the latch
                    (back.0)();
                }
            }
        }
        drop(dispatch);
        // the caller's own chunk is a busy lane too
        let caller = {
            let _busy = obs::span(obs::Span::PoolLaneBusy);
            catch_unwind(AssertUnwindSafe(first))
        };
        latch.wait();
        // caller-chunk panic wins (its payload is already unwinding this
        // stack); otherwise re-raise the first pooled payload verbatim
        if let Err(payload) = caller {
            resume_unwind(payload);
        }
        let pooled_panic =
            latch.panic.lock().unwrap_or_else(|e| e.into_inner()).take();
        if let Some(payload) = pooled_panic {
            resume_unwind(payload);
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        // disconnect every channel → workers' recv() errors → they exit
        self.senders.lock().unwrap_or_else(|e| e.into_inner()).clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl fmt::Debug for ThreadPool {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "ThreadPool({} lanes)", self.threads)
    }
}

/// How fork-join work is scheduled. Purely a wall-clock choice: every
/// mode produces bit-identical results (the executor contract keeps all
/// RNG and merge order in the task structure, not the schedule).
#[derive(Clone)]
enum CtxInner {
    /// Run tasks sequentially on the caller (T = 1).
    Inline,
    /// Persistent workers, created once, reused every call.
    Pool(Arc<ThreadPool>),
    /// PR-2 semantics: fresh `std::thread::scope` threads per call. Kept
    /// for pool-vs-respawn benchmarks and scheduling cross-checks.
    Scoped(usize),
}

/// Cheap, cloneable handle to an execution strategy — the object threaded
/// through `WorkerConfig` / `HybridConfig` / `ExecConfig` so every sweep
/// site (coordinator worker, serial oracle, held-out evaluator, posterior
/// serving) shares one persistent-pool substrate.
///
/// All constructors clamp `threads ≤ 1` (including 0) to inline
/// execution, so a `--threads 0` arriving from any entry point degrades
/// to the serial path instead of panicking or dividing by zero.
#[derive(Clone)]
pub struct ParallelCtx(CtxInner);

impl ParallelCtx {
    /// Sequential execution on the caller's thread.
    pub fn inline() -> Self {
        Self(CtxInner::Inline)
    }

    /// A persistent pool of `threads` lanes (`threads ≤ 1` ⇒ inline; the
    /// pool spawns `threads − 1` OS threads and lives until the last
    /// clone of this handle drops).
    pub fn pooled(threads: usize) -> Self {
        if threads <= 1 {
            Self(CtxInner::Inline)
        } else {
            Self(CtxInner::Pool(Arc::new(ThreadPool::new(threads))))
        }
    }

    /// Fresh scoped threads on every call (the PR-2 respawn behaviour;
    /// `threads ≤ 1` ⇒ inline). Same bits, more spawn/join overhead —
    /// benchmarked against the pool in `benches/sweep_throughput.rs`.
    pub fn scoped(threads: usize) -> Self {
        if threads <= 1 {
            Self(CtxInner::Inline)
        } else {
            Self(CtxInner::Scoped(threads))
        }
    }

    /// Execution lanes this context schedules onto (≥ 1).
    pub fn threads(&self) -> usize {
        match &self.0 {
            CtxInner::Inline => 1,
            CtxInner::Pool(p) => p.threads(),
            CtxInner::Scoped(t) => *t,
        }
    }

    /// True when this context owns a persistent pool.
    pub fn is_pooled(&self) -> bool {
        matches!(self.0, CtxInner::Pool(_))
    }

    /// Run `f` once per item, scheduling contiguous chunks of `items`
    /// across the context's lanes and returning when all are done.
    ///
    /// The chunk layout depends only on `items.len()` and the lane count
    /// of this context — and since `f` must be deterministic per item
    /// (all our tasks are: private RNG, disjoint writes), the overall
    /// effect is a pure function of `items`, independent of scheduling
    /// mode and completion order.
    pub fn run<T, F>(&self, items: &mut [T], f: F)
    where
        T: Send,
        F: Fn(&mut T) + Sync,
    {
        let t = self.threads().min(items.len()).max(1);
        if t <= 1 {
            for item in items.iter_mut() {
                f(item);
            }
            return;
        }
        let per = items.len().div_ceil(t);
        match &self.0 {
            // detlint:allow(no-panic-coordinator): structurally unreachable — Inline reports threads() == 1, so the t <= 1 early return above always fired
            CtxInner::Inline => unreachable!("inline context has one lane"),
            CtxInner::Pool(pool) => {
                let f = &f;
                let tasks: Vec<Task<'_>> = items
                    .chunks_mut(per)
                    .map(|chunk| {
                        Box::new(move || {
                            for item in chunk {
                                f(item);
                            }
                        }) as Task<'_>
                    })
                    .collect();
                pool.run_scoped(tasks);
            }
            CtxInner::Scoped(_) => {
                let f = &f;
                std::thread::scope(|s| {
                    for chunk in items.chunks_mut(per) {
                        s.spawn(move || {
                            for item in chunk {
                                f(item);
                            }
                        });
                    }
                });
            }
        }
    }
}

impl fmt::Debug for ParallelCtx {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match &self.0 {
            CtxInner::Inline => write!(f, "ParallelCtx::Inline"),
            CtxInner::Pool(p) => write!(f, "ParallelCtx::Pool({} lanes)", p.threads()),
            CtxInner::Scoped(t) => write!(f, "ParallelCtx::Scoped({t})"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    /// A deterministic per-item computation with real work in it.
    fn work(seed: &mut (u64, u64)) {
        let mut rng = Pcg64::new(seed.0);
        let mut acc = 0u64;
        for _ in 0..50 {
            acc = acc.wrapping_add(rng.next_u64());
        }
        seed.1 = acc;
    }

    #[test]
    fn all_modes_produce_identical_results() {
        let base: Vec<(u64, u64)> = (0..23).map(|i| (i as u64, 0)).collect();
        let run = |ctx: &ParallelCtx| {
            let mut items = base.clone();
            ctx.run(&mut items, work);
            items
        };
        let want = run(&ParallelCtx::inline());
        assert!(want.iter().all(|&(_, v)| v != 0));
        for ctx in [
            ParallelCtx::pooled(2),
            ParallelCtx::pooled(4),
            ParallelCtx::pooled(7),
            ParallelCtx::scoped(3),
        ] {
            assert_eq!(run(&ctx), want, "{ctx:?} diverged from inline");
        }
    }

    #[test]
    fn pool_persists_across_many_calls() {
        let ctx = ParallelCtx::pooled(4);
        assert!(ctx.is_pooled());
        for round in 0..100 {
            let mut items: Vec<(u64, u64)> = (0..5).map(|i| (round + i, 0)).collect();
            ctx.run(&mut items, work);
            assert!(items.iter().all(|&(_, v)| v != 0), "round {round}");
        }
    }

    #[test]
    fn zero_and_one_threads_clamp_to_inline() {
        assert_eq!(ParallelCtx::pooled(0).threads(), 1);
        assert_eq!(ParallelCtx::pooled(1).threads(), 1);
        assert_eq!(ParallelCtx::scoped(0).threads(), 1);
        assert!(!ParallelCtx::pooled(0).is_pooled());
        assert_eq!(ThreadPool::new(0).threads(), 1);
        // and an inline-clamped context still runs everything
        let mut items = vec![(3u64, 0u64); 4];
        ParallelCtx::pooled(0).run(&mut items, work);
        assert!(items.iter().all(|&(_, v)| v != 0));
    }

    #[test]
    fn more_items_than_lanes_all_complete() {
        let ctx = ParallelCtx::pooled(3);
        let mut hits = vec![0u32; 100];
        ctx.run(&mut hits, |h| *h += 1);
        assert!(hits.iter().all(|&h| h == 1));
    }

    #[test]
    fn empty_and_single_item_are_fine() {
        let ctx = ParallelCtx::pooled(4);
        let mut empty: Vec<u32> = vec![];
        ctx.run(&mut empty, |_| unreachable!());
        let mut one = vec![7u32];
        ctx.run(&mut one, |v| *v *= 3);
        assert_eq!(one, vec![21]);
    }

    #[test]
    fn task_panic_is_reraised_and_pool_survives() {
        let ctx = ParallelCtx::pooled(4);
        let res = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let mut items = vec![0u32, 1, 2, 3, 4, 5, 6, 7];
            ctx.run(&mut items, |v| {
                if *v == 5 {
                    panic!("boom");
                }
            });
        }));
        let payload = match res {
            Err(p) => p,
            Ok(()) => panic!("task panic was swallowed"),
        };
        // the ORIGINAL payload must survive the pool (same observability
        // as scoped/inline execution), not a generic re-panic
        assert_eq!(payload.downcast_ref::<&str>().copied(), Some("boom"));
        // the long-lived workers caught the unwind and are still serving
        let mut items = vec![(1u64, 0u64); 8];
        ctx.run(&mut items, work);
        assert!(items.iter().all(|&(_, v)| v != 0));
    }

    #[test]
    fn caller_chunk_panic_still_joins_siblings() {
        // chunk 0 runs on the caller; its panic must not return before the
        // pooled siblings finish (they borrow `items` from this frame)
        let ctx = ParallelCtx::pooled(4);
        let res = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let mut items = vec![0u32; 8];
            ctx.run(&mut items, |v| {
                if *v == 0 {
                    panic!("caller-side boom");
                }
            });
        }));
        assert!(res.is_err());
    }
}
