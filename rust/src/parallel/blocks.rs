//! Deterministic partition of a row range into fixed-size blocks.
//!
//! The block layout is the unit of the executor's reproducibility
//! contract: boundaries depend only on `(rows, block_rows)` — never on
//! the thread count — and block `b` of a sweep draws exclusively from
//! the RNG substream `parent.split(BLOCK_TAG_BASE + b)`. Running the
//! same plan on 1 thread or 16 therefore produces bit-identical output.

use std::ops::Range;

/// Rows per block for production sweeps. Small enough that every shard
/// in the paper's experiments (N = 1000, P ≤ 8 ⇒ ≥ 125 rows/worker)
/// splits into several blocks, large enough that per-block RNG-derivation
/// and join overheads are noise next to the O(block · K⁺ · D) sweep work.
pub const DEFAULT_BLOCK_ROWS: usize = 32;

/// RNG tag base for per-block substreams — an alias of the central
/// registry entry (`rng::tags::BLOCK_BASE`; the repo-wide layout lives
/// in `rng/tags.rs`): block b of a sweep draws from
/// `worker_rng.split(tags::block(b))`.
pub const BLOCK_TAG_BASE: u64 = crate::rng::tags::BLOCK_BASE;

/// A row range cut into consecutive blocks of `block_rows` rows (the
/// last block may be ragged).
///
/// # Examples
///
/// ```
/// use pibp::parallel::BlockPlan;
///
/// let plan = BlockPlan::new(10..31, 8);
/// let blocks: Vec<_> = plan.iter().collect();
/// assert_eq!(blocks, vec![10..18, 18..26, 26..31]);
/// assert_eq!(plan.len(), 3);
///
/// // an empty range has no blocks
/// assert!(BlockPlan::new(5..5, 8).is_empty());
/// ```
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BlockPlan {
    rows: Range<usize>,
    block_rows: usize,
}

impl BlockPlan {
    pub fn new(rows: Range<usize>, block_rows: usize) -> Self {
        assert!(block_rows >= 1, "block_rows must be ≥ 1");
        assert!(rows.start <= rows.end, "inverted row range");
        Self { rows, block_rows }
    }

    /// Number of blocks.
    pub fn len(&self) -> usize {
        self.rows.len().div_ceil(self.block_rows)
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Global row range of block `b`.
    pub fn block(&self, b: usize) -> Range<usize> {
        debug_assert!(b < self.len());
        let start = self.rows.start + b * self.block_rows;
        let end = (start + self.block_rows).min(self.rows.end);
        start..end
    }

    /// The blocks, in order.
    pub fn iter(&self) -> impl Iterator<Item = Range<usize>> + '_ {
        (0..self.len()).map(|b| self.block(b))
    }

    /// RNG split tag for block `b` (delegates to the central registry).
    pub fn tag(b: usize) -> u64 {
        crate::rng::tags::block(b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blocks_cover_range_exactly() {
        for (range, bs) in [(0..100, 32), (17..101, 16), (0..1, 32), (3..3, 8), (0..32, 32)] {
            let plan = BlockPlan::new(range.clone(), bs);
            let blocks: Vec<_> = plan.iter().collect();
            assert_eq!(blocks.len(), plan.len());
            if range.is_empty() {
                assert!(plan.is_empty());
                assert!(blocks.is_empty());
                continue;
            }
            assert_eq!(blocks[0].start, range.start);
            assert_eq!(blocks.last().unwrap().end, range.end);
            for w in blocks.windows(2) {
                assert_eq!(w[0].end, w[1].start, "gap in {blocks:?}");
            }
            for b in &blocks[..blocks.len() - 1] {
                assert_eq!(b.len(), bs, "non-final block ragged in {blocks:?}");
            }
            assert!(blocks.last().unwrap().len() <= bs);
        }
    }

    #[test]
    fn layout_is_independent_of_anything_but_inputs() {
        let a: Vec<_> = BlockPlan::new(5..77, 16).iter().collect();
        let b: Vec<_> = BlockPlan::new(5..77, 16).iter().collect();
        assert_eq!(a, b);
    }

    #[test]
    fn tags_are_dense_from_base() {
        assert_eq!(BlockPlan::tag(0), BLOCK_TAG_BASE);
        assert_eq!(BlockPlan::tag(7), BLOCK_TAG_BASE + 7);
    }

    #[test]
    #[should_panic(expected = "block_rows")]
    fn rejects_zero_block_rows() {
        BlockPlan::new(0..10, 0);
    }
}
