//! Deterministic intra-worker parallel execution.
//!
//! The paper's argument for the P-worker coordinator — rows of Z are
//! conditionally independent given the instantiated features (π, A) —
//! applies equally *inside* one worker's uncollapsed sweep. This module
//! exploits it with zero approximation:
//!
//! 1. partition the row range into fixed-size blocks
//!    ([`BlockPlan`], [`DEFAULT_BLOCK_ROWS`] rows each — the layout
//!    depends only on the range, never on the thread count);
//! 2. derive one RNG substream per block with the repo's split
//!    discipline (`worker_rng.split(tags::block(b))`, mirroring the
//!    coordinator's `root.split(tags::worker(p))` layout; both families
//!    live in the central `rng::tags` registry);
//! 3. run [`sweep_block`] kernels against disjoint `&mut` row slices of
//!    Z and the residual matrix, scheduled by a [`ParallelCtx`]: inline,
//!    on a **persistent thread pool** ([`ThreadPool`], the production
//!    path — workers are spawned once and reused for every sweep), or on
//!    per-call scoped threads (the pre-pool behaviour, kept for
//!    benchmarks and scheduling cross-checks);
//! 4. merge per-block scratch (flip counts, column-count deltas) in
//!    block order.
//!
//! Because every block's writes and draws are self-contained, the output
//! is **bit-identical for every thread count and scheduling mode,
//! including T = 1** — which is what lets the serial hybrid oracle
//! (always T = 1) pin multi-threaded coordinator runs chain-for-chain
//! (`rust/tests/thread_equivalence.rs`).
//!
//! ## Parent-stream contract
//!
//! Each [`par_sweep_rows`] call consumes **exactly one `u64`** from the
//! parent stream — no more, regardless of block count or thread count —
//! and then derives block substreams from the advanced state. Advancing
//! the parent makes consecutive sweeps (the L sub-iterations) draw
//! distinct substreams for the same block indices; consuming a fixed
//! amount keeps everything after the sweep (e.g. the p′ tail proposal on
//! the same worker stream) aligned across thread counts.

// Compiler-enforced twin of detlint rule R4 (no-panic-coordinator): deny
// `unwrap()` outside test builds. Proven-infallible sites carry a scoped
// `#[allow]` plus a detlint waiver with the proof. CI runs clippy with
// this lint promoted to blocking.
#![cfg_attr(not(test), deny(clippy::unwrap_used))]

mod blocks;
mod pool;

pub use blocks::{BlockPlan, BLOCK_TAG_BASE, DEFAULT_BLOCK_ROWS};
pub use pool::{ParallelCtx, ThreadPool};

use std::ops::Range;

use crate::linalg::Mat;
use crate::model::state::{FeatureState, Kernel};
use crate::obs;
use crate::rng::{tags, Pcg64};
use crate::samplers::uncollapsed::{sweep_block, sweep_block_packed};

/// Executor knobs. `ctx` is a *scheduling* choice only — it never affects
/// results; `block_rows` is part of the RNG draw-order contract (changing
/// it changes the chain, like changing the seed would); `kernel` selects
/// the Z storage/kernel family the owner builds its states with
/// (scalar bytes vs packed `u64` words) — like `ctx`, it never changes a
/// bit of output, only how fast the bits are produced.
#[derive(Clone, Debug)]
pub struct ExecConfig {
    /// How block tasks are scheduled (inline / persistent pool / scoped).
    pub ctx: ParallelCtx,
    /// Rows per block (fixed; the last block of a range may be ragged).
    pub block_rows: usize,
    /// Which Z kernel family states owned by this executor's call sites
    /// use. [`par_sweep_rows`] itself dispatches on the *state's* actual
    /// layout (so a state of either kind always sweeps correctly); this
    /// field is how owners (workers, evaluators, the serve engine) decide
    /// which layout to build or convert their states into.
    pub kernel: Kernel,
}

impl Default for ExecConfig {
    fn default() -> Self {
        Self {
            ctx: ParallelCtx::inline(),
            block_rows: DEFAULT_BLOCK_ROWS,
            kernel: Kernel::Scalar,
        }
    }
}

impl ExecConfig {
    /// Production config: a persistent pool of `threads` lanes (clamped
    /// to ≥ 1; 0 and 1 run inline) over [`DEFAULT_BLOCK_ROWS`]-row blocks.
    pub fn with_threads(threads: usize) -> Self {
        Self { ctx: ParallelCtx::pooled(threads), ..Self::default() }
    }

    /// Wrap an existing context (e.g. a pool handle shared by the owner).
    pub fn with_ctx(ctx: ParallelCtx) -> Self {
        Self { ctx, ..Self::default() }
    }

    /// Select the Z kernel family (builder-style).
    pub fn with_kernel(mut self, kernel: Kernel) -> Self {
        self.kernel = kernel;
        self
    }

    /// Execution lanes the context schedules onto (≥ 1).
    pub fn threads(&self) -> usize {
        self.ctx.threads()
    }
}

/// One block's disjoint Z view, in whichever layout the state uses.
enum ZChunk<'a> {
    Bytes(&'a mut [u8]),
    Words(&'a mut [u64]),
}

/// One block's work packet: disjoint views plus private scratch.
struct BlockTask<'a> {
    z: ZChunk<'a>,
    resid: &'a mut [f64],
    rng: Pcg64,
    m_delta: Vec<i64>,
    flips: usize,
}

impl BlockTask<'_> {
    /// `stride` is the row stride of the Z view: K for bytes,
    /// `words_per_row` for words.
    fn run(&mut self, stride: usize, d: usize, a: &Mat, prior_logit: &[f64],
           inv2s2: f64, k_limit: usize) {
        self.flips = match &mut self.z {
            ZChunk::Bytes(zb) => sweep_block(
                zb, stride, self.resid, d, a, prior_logit, inv2s2,
                k_limit, &mut self.rng, &mut self.m_delta,
            ),
            ZChunk::Words(zw) => sweep_block_packed(
                zw, stride, self.resid, d, a, prior_logit, inv2s2,
                k_limit, &mut self.rng, &mut self.m_delta,
            ),
        };
    }
}

/// One uncollapsed Gibbs sweep of `z[rows]` over columns `0..k_limit`,
/// executed as fixed-size row blocks through `exec.ctx`'s lanes.
/// `resid` must hold X − Z A on entry for the swept rows and is kept
/// consistent. Returns the total number of flips.
///
/// Semantics match [`crate::samplers::uncollapsed::sweep_rows`] except
/// for the RNG discipline: draws come from per-block substreams
/// (`rng.split(tags::block(b))` after advancing `rng` once) instead
/// of the caller's stream directly, so the result is a pure function of
/// the inputs — independent of the context's lane count and mode.
#[allow(clippy::too_many_arguments)]
pub fn par_sweep_rows(
    z: &mut FeatureState,
    resid: &mut Mat,
    a: &Mat,
    prior_logit: &[f64],
    inv2s2: f64,
    rows: Range<usize>,
    k_limit: usize,
    exec: &ExecConfig,
    rng: &mut Pcg64,
) -> usize {
    // Parent-stream contract (module docs): exactly one draw per call,
    // before any early return, so consumption never depends on the data.
    rng.next_u64();
    // row stride of the raw Z view: K bytes or ⌈K/64⌉ words — the block
    // kernels are dispatched on the state's actual layout, so states of
    // either kind sweep identically regardless of `exec.kernel`
    let packed = z.is_packed();
    let stride = if packed { z.words_per_row() } else { z.k() };
    let d = resid.cols();
    debug_assert!(k_limit <= z.k() && k_limit <= a.rows());
    debug_assert!(rows.end <= z.n() && rows.end <= resid.rows());
    let plan = BlockPlan::new(rows.clone(), exec.block_rows.max(1));
    if plan.is_empty() || k_limit == 0 || d == 0 {
        return 0;
    }

    let mut m_total = vec![0i64; k_limit];
    let mut flips = 0usize;
    {
        // carve the swept range into disjoint per-block views; blocks are
        // fixed-size (ragged tail), so chunks_mut reproduces the plan's
        // boundaries exactly
        let block_rows = exec.block_rows.max(1);
        let rchunks = resid.as_mut_slice()[rows.start * d..rows.end * d]
            .chunks_mut(block_rows * d);
        let mut tasks: Vec<BlockTask> = Vec::with_capacity(plan.len());
        if packed {
            let zchunks =
                z.rows_words_mut(rows.clone()).chunks_mut(block_rows * stride);
            for (b, (zw, rb)) in zchunks.zip(rchunks).enumerate() {
                debug_assert_eq!(zw.len() / stride, plan.block(b).len());
                tasks.push(BlockTask {
                    z: ZChunk::Words(zw),
                    resid: rb,
                    rng: rng.split(tags::block(b)),
                    m_delta: vec![0i64; k_limit],
                    flips: 0,
                });
            }
        } else {
            let zchunks =
                z.rows_bits_mut(rows.clone()).chunks_mut(block_rows * stride);
            for (b, (zb, rb)) in zchunks.zip(rchunks).enumerate() {
                debug_assert_eq!(zb.len() / stride, plan.block(b).len());
                tasks.push(BlockTask {
                    z: ZChunk::Bytes(zb),
                    resid: rb,
                    rng: rng.split(tags::block(b)),
                    m_delta: vec![0i64; k_limit],
                    flips: 0,
                });
            }
        }
        debug_assert_eq!(tasks.len(), plan.len());

        // schedule the blocks — inline, persistent pool, or scoped
        // respawn; which lane runs a block is irrelevant to the output
        // (disjoint writes, private RNG), so this never changes a bit
        exec.ctx.run(&mut tasks, |task| {
            task.run(stride, d, a, prior_logit, inv2s2, k_limit);
        });

        // merge per-block scratch in block order
        for task in &tasks {
            flips += task.flips;
            for (acc, &dm) in m_total.iter_mut().zip(&task.m_delta) {
                *acc += dm;
            }
        }
        // obs: tally the block substreams' passive draw counters — one
        // atomic add per sweep, read after the join (pure diagnostics)
        if obs::counting() {
            let draws: u64 = tasks.iter().map(|t| t.rng.draw_count()).sum();
            obs::add(obs::Counter::RngDrawsBlock, draws);
        }
    }
    z.apply_m_delta(&m_total);
    flips
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::samplers::uncollapsed::residuals;
    use crate::testutil::sweep_problem as problem;

    fn run_once_kernel(ctx: ParallelCtx, block_rows: usize, rows: Range<usize>,
                       k_limit: usize, seed: u64, kernel: Kernel)
                       -> (FeatureState, Mat, usize, u64) {
        let (x, mut z, a, logit) = problem(101, 5, 7, seed);
        z.set_kernel(kernel);
        let mut resid = residuals(&x, &z, &a, 0..x.rows());
        let mut rng = Pcg64::new(99).split(1000);
        let exec = ExecConfig { ctx, block_rows, kernel };
        let flips = par_sweep_rows(
            &mut z, &mut resid, &a, &logit, 1.7, rows, k_limit, &exec, &mut rng,
        );
        // the parent stream's post-state is part of the contract
        (z, resid, flips, rng.next_u64())
    }

    fn run_once_ctx(ctx: ParallelCtx, block_rows: usize, rows: Range<usize>,
                    k_limit: usize, seed: u64)
                    -> (FeatureState, Mat, usize, u64) {
        run_once_kernel(ctx, block_rows, rows, k_limit, seed, Kernel::Scalar)
    }

    fn run_once(threads: usize, block_rows: usize, rows: Range<usize>,
                k_limit: usize, seed: u64)
                -> (FeatureState, Mat, usize, u64) {
        run_once_ctx(ParallelCtx::pooled(threads), block_rows, rows, k_limit, seed)
    }

    #[test]
    fn identical_output_for_every_thread_count() {
        // ragged: 101 rows, block 16 ⇒ 7 blocks, last of 5 rows
        let base = run_once(1, 16, 0..101, 5, 3);
        for t in [2usize, 3, 7] {
            let got = run_once(t, 16, 0..101, 5, 3);
            assert_eq!(got.0, base.0, "Z diverged at T={t}");
            assert!(got.1.max_abs_diff(&base.1) == 0.0, "resid diverged at T={t}");
            assert_eq!(got.2, base.2, "flip count diverged at T={t}");
            assert_eq!(got.3, base.3, "parent RNG state diverged at T={t}");
        }
        // and the sweep did something, so the equalities are meaningful
        assert!(base.2 > 0, "sweep never flipped a bit");
        assert!(base.0.check_invariants());
    }

    #[test]
    fn pool_scoped_and_inline_schedulers_agree_bitwise() {
        // same sweep through all three scheduling modes — the persistent
        // pool must be invisible next to the PR-2 respawn executor and
        // the serial path
        let base = run_once_ctx(ParallelCtx::inline(), 16, 0..101, 5, 21);
        for ctx in [
            ParallelCtx::pooled(2),
            ParallelCtx::pooled(4),
            ParallelCtx::scoped(2),
            ParallelCtx::scoped(4),
        ] {
            let tag = format!("{ctx:?}");
            let got = run_once_ctx(ctx, 16, 0..101, 5, 21);
            assert_eq!(got.0, base.0, "Z diverged under {tag}");
            assert!(got.1.max_abs_diff(&base.1) == 0.0, "resid diverged under {tag}");
            assert_eq!(got.2, base.2, "flips diverged under {tag}");
            assert_eq!(got.3, base.3, "parent RNG diverged under {tag}");
        }
        assert!(base.2 > 0, "sweep never flipped a bit");
    }

    #[test]
    fn one_pool_serves_many_sweeps() {
        // the persistent pool is reused across sweep calls (the whole
        // point); repeated sweeps must match a fresh-context replay
        let (x, mut z, a, logit) = problem(67, 4, 9, 8);
        let mut resid = residuals(&x, &z, &a, 0..67);
        let mut rng = Pcg64::new(5).split(1002);
        let exec = ExecConfig::with_threads(4);
        for _ in 0..5 {
            par_sweep_rows(&mut z, &mut resid, &a, &logit, 2.0, 0..67, 4,
                           &exec, &mut rng);
        }
        let (x2, mut z2, a2, logit2) = problem(67, 4, 9, 8);
        let mut resid2 = residuals(&x2, &z2, &a2, 0..67);
        let mut rng2 = Pcg64::new(5).split(1002);
        for _ in 0..5 {
            // fresh single-use context per sweep — same bits
            let exec1 = ExecConfig::with_threads(2);
            par_sweep_rows(&mut z2, &mut resid2, &a2, &logit2, 2.0, 0..67, 4,
                           &exec1, &mut rng2);
        }
        assert_eq!(z, z2);
        assert!(resid.max_abs_diff(&resid2) == 0.0);
    }

    #[test]
    fn sub_ranges_only_touch_their_rows() {
        let full = run_once(3, 8, 20..60, 5, 4);
        let (x, z0, a, _) = problem(101, 5, 7, 4);
        let resid0 = residuals(&x, &z0, &a, 0..x.rows());
        for i in (0..20).chain(60..101) {
            assert_eq!(full.0.row_bits(i), z0.row_bits(i), "row {i} touched");
            assert_eq!(full.1.row(i), resid0.row(i), "resid row {i} touched");
        }
        assert!(full.0.check_invariants());
    }

    #[test]
    fn residuals_stay_consistent_under_threads() {
        let (x, mut z, a, logit) = problem(67, 4, 9, 8);
        let mut resid = residuals(&x, &z, &a, 0..67);
        let mut rng = Pcg64::new(5).split(1002);
        let exec = ExecConfig {
            ctx: ParallelCtx::pooled(4),
            block_rows: 8,
            kernel: Kernel::Scalar,
        };
        for _ in 0..3 {
            par_sweep_rows(&mut z, &mut resid, &a, &logit, 2.0, 0..67, 4,
                           &exec, &mut rng);
        }
        let want = residuals(&x, &z, &a, 0..67);
        assert!(resid.max_abs_diff(&want) < 1e-10);
        assert!(z.check_invariants());
    }

    #[test]
    fn k_limit_restricts_columns() {
        let got = run_once(2, 16, 0..101, 3, 6);
        let (_, z0, _, _) = problem(101, 5, 7, 6);
        for i in 0..101 {
            for k in 3..5 {
                assert_eq!(got.0.get(i, k), z0.get(i, k), "col {k} touched");
            }
        }
    }

    #[test]
    fn empty_range_is_a_noop_but_advances_parent_once() {
        let (_, mut z, a, logit) = problem(20, 3, 4, 7);
        let z0 = z.clone();
        let mut resid = Mat::zeros(20, 4);
        let mut rng = Pcg64::new(11).split(1000);
        let mut twin = rng.clone();
        let flips = par_sweep_rows(&mut z, &mut resid, &a, &logit, 1.0,
                                   5..5, 3, &ExecConfig::default(), &mut rng);
        assert_eq!(flips, 0);
        assert_eq!(z, z0);
        twin.next_u64(); // the contract: exactly one parent draw
        assert_eq!(rng.next_u64(), twin.next_u64());
    }

    #[test]
    fn single_row_range_works() {
        for t in [1usize, 4] {
            let got = run_once(t, 16, 50..51, 5, 9);
            let base = run_once(1, 16, 50..51, 5, 9);
            assert_eq!(got.0, base.0);
            assert!(got.0.check_invariants());
        }
    }

    #[test]
    fn k_plus_zero_is_a_noop_with_fixed_parent_consumption() {
        // K⁺ = 0: no columns to sweep — but the parent stream still moves
        // by exactly one draw, for every T.
        let mut z = FeatureState::empty(30);
        let mut resid = Mat::from_fn(30, 6, |i, j| (i + j) as f64);
        let resid0 = resid.clone();
        let a = Mat::zeros(0, 6);
        let mut states = vec![];
        for t in [1usize, 3] {
            let mut rng = Pcg64::new(13).split(1001);
            let flips = par_sweep_rows(&mut z, &mut resid, &a, &[], 1.0,
                                       0..30, 0,
                                       &ExecConfig::with_threads(t), &mut rng);
            assert_eq!(flips, 0);
            states.push(rng.next_u64());
        }
        assert_eq!(states[0], states[1]);
        assert_eq!(z.k(), 0);
        assert!(resid.max_abs_diff(&resid0) == 0.0);
    }

    #[test]
    fn packed_kernel_matches_scalar_bitwise_across_threads() {
        // the packed word kernel must be invisible: same Z bits, same
        // residual bytes, same flip count, same parent RNG post-state —
        // for ragged blocks, sub-ranges, k_limits, and every thread count
        for (rows, k_limit, seed) in
            [(0..101, 5, 3), (20..60, 5, 4), (0..101, 3, 6), (50..51, 5, 9)]
        {
            let base = run_once_kernel(ParallelCtx::pooled(1), 16,
                                       rows.clone(), k_limit, seed,
                                       Kernel::Scalar);
            for t in [1usize, 2, 4] {
                let got = run_once_kernel(ParallelCtx::pooled(t), 16,
                                          rows.clone(), k_limit, seed,
                                          Kernel::Packed);
                assert!(got.0.is_packed());
                assert_eq!(got.0, base.0, "Z diverged (packed, T={t})");
                assert!(got.1.max_abs_diff(&base.1) == 0.0,
                        "resid diverged (packed, T={t})");
                assert_eq!(got.2, base.2, "flips diverged (packed, T={t})");
                assert_eq!(got.3, base.3, "parent RNG diverged (packed, T={t})");
                assert!(got.0.check_invariants());
            }
            assert!(base.2 > 0, "sweep never flipped a bit");
        }
    }

    #[test]
    fn packed_kernel_handles_multi_word_rows() {
        // K = 70 spans two words per row; tail-word masking must hold
        // through an actual parallel sweep
        let (x, mut z, a, logit) = problem(53, 70, 6, 17);
        z.set_kernel(Kernel::Packed);
        let mut resid = residuals(&x, &z, &a, 0..53);
        let mut rng = Pcg64::new(99).split(1000);
        let exec = ExecConfig::with_threads(4).with_kernel(Kernel::Packed);
        let flips = par_sweep_rows(&mut z, &mut resid, &a, &logit, 1.7,
                                   0..53, 70, &exec, &mut rng);

        let (x2, mut z2, a2, logit2) = problem(53, 70, 6, 17);
        let mut resid2 = residuals(&x2, &z2, &a2, 0..53);
        let mut rng2 = Pcg64::new(99).split(1000);
        let exec2 = ExecConfig::with_threads(1);
        let flips2 = par_sweep_rows(&mut z2, &mut resid2, &a2, &logit2, 1.7,
                                    0..53, 70, &exec2, &mut rng2);
        assert_eq!(z, z2);
        assert!(resid.max_abs_diff(&resid2) == 0.0);
        assert_eq!(flips, flips2);
        assert_eq!(rng.next_u64(), rng2.next_u64());
        assert!(flips > 0);
        assert!(z.check_invariants());
    }

    #[test]
    fn block_size_is_part_of_the_draw_contract() {
        // different block_rows ⇒ a different (equally valid) chain — this
        // is why DEFAULT_BLOCK_ROWS is fixed repo-wide, like the seed
        let a16 = run_once(1, 16, 0..101, 5, 15);
        let a32 = run_once(1, 32, 0..101, 5, 15);
        assert_ne!(a16.0, a32.0);
    }
}
