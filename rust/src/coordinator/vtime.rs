//! Virtual-time accounting.
//!
//! The image has ONE physical core, so P worker threads cannot exhibit
//! wall-clock speedup. What a real P-processor cluster measures per
//! iteration is
//!
//! ```text
//! t_iter = max_p(worker_busy_p) + master_busy + comm(messages)
//! ```
//!
//! — workers run concurrently (max, not sum), the master's global step is
//! serial, and every scatter/gather/broadcast message pays the modelled
//! latency + bytes/bandwidth (`config::CommModel`). Each worker meters its
//! own busy time with a monotonic clock; message sizes are the real
//! encoded byte counts from `messages.rs`. Wall-clock is recorded too —
//! Figure 1 uses virtual time, EXPERIMENTS.md reports both.

use crate::config::CommModel;

#[derive(Clone, Debug, Default)]
pub struct IterTiming {
    /// Per-worker busy seconds this iteration.
    pub worker_busy_s: Vec<f64>,
    /// Master compute seconds (merge + posterior draws + bookkeeping).
    pub master_busy_s: f64,
    /// Bytes sent master→workers this iteration.
    pub bcast_bytes: Vec<usize>,
    /// Bytes sent workers→master this iteration.
    pub gather_bytes: Vec<usize>,
}

impl IterTiming {
    /// The virtual duration of this iteration under `comm`.
    ///
    /// Broadcasts to different workers leave the master serially (shared
    /// NIC) but only the *last* departure gates the slowest path; we charge
    /// the sum of broadcast costs (conservative, master-serialised send)
    /// plus the gather serialised into the master. This matches a
    /// single-master star topology — exactly the bottleneck the paper's
    /// §5 names as future work.
    pub fn virtual_s(&self, comm: &CommModel) -> f64 {
        let worker_max = self
            .worker_busy_s
            .iter()
            .fold(0.0f64, |a, &b| a.max(b));
        let bcast: f64 = self.bcast_bytes.iter().map(|&b| comm.cost(b)).sum();
        let gather: f64 = self.gather_bytes.iter().map(|&b| comm.cost(b)).sum();
        worker_max + self.master_busy_s + bcast + gather
    }

    pub fn total_bytes(&self) -> usize {
        self.bcast_bytes.iter().sum::<usize>() + self.gather_bytes.iter().sum::<usize>()
    }
}

/// Accumulates a run's virtual clock.
#[derive(Clone, Debug, Default)]
pub struct VClock {
    elapsed_s: f64,
    pub iterations: usize,
    pub total_comm_bytes: usize,
}

impl VClock {
    pub fn new() -> Self {
        Self::default()
    }

    /// Rebuild a clock from checkpointed parts (`crate::snapshot`), so a
    /// resumed run's virtual time continues from where the interrupted
    /// run stopped instead of restarting at zero.
    pub fn from_parts(elapsed_s: f64, iterations: usize, total_comm_bytes: usize) -> Self {
        Self { elapsed_s, iterations, total_comm_bytes }
    }

    /// Advance by one iteration; returns the iteration's virtual duration.
    pub fn advance(&mut self, t: &IterTiming, comm: &CommModel) -> f64 {
        let dt = t.virtual_s(comm);
        self.elapsed_s += dt;
        self.iterations += 1;
        self.total_comm_bytes += t.total_bytes();
        dt
    }

    pub fn elapsed_s(&self) -> f64 {
        self.elapsed_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn comm() -> CommModel {
        CommModel { latency_s: 1e-4, bandwidth_bps: 1e9 }
    }

    #[test]
    fn virtual_time_takes_max_over_workers() {
        let t = IterTiming {
            worker_busy_s: vec![0.010, 0.030, 0.020],
            master_busy_s: 0.005,
            bcast_bytes: vec![],
            gather_bytes: vec![],
        };
        assert!((t.virtual_s(&comm()) - 0.035).abs() < 1e-12);
    }

    #[test]
    fn comm_costs_add_latency_and_bandwidth() {
        let t = IterTiming {
            worker_busy_s: vec![0.0],
            master_busy_s: 0.0,
            bcast_bytes: vec![1_000_000, 1_000_000],
            gather_bytes: vec![500_000],
        };
        // 3 messages × 100µs latency + 2.5e6 bytes / 1e9 Bps
        let want = 3.0 * 1e-4 + 2.5e6 / 1e9;
        assert!((t.virtual_s(&comm()) - want).abs() < 1e-9);
    }

    #[test]
    fn clock_accumulates() {
        let mut clock = VClock::new();
        let t = IterTiming {
            worker_busy_s: vec![0.01],
            master_busy_s: 0.002,
            bcast_bytes: vec![100],
            gather_bytes: vec![200],
        };
        let dt = clock.advance(&t, &comm());
        clock.advance(&t, &comm());
        assert_eq!(clock.iterations, 2);
        assert!((clock.elapsed_s() - 2.0 * dt).abs() < 1e-12);
        assert_eq!(clock.total_comm_bytes, 600);
    }

    #[test]
    fn perfect_scaling_halves_worker_time() {
        // sanity of the model: P workers with busy/P each and fixed master
        // cost shows the expected Amdahl shape.
        let serial = IterTiming {
            worker_busy_s: vec![1.0],
            master_busy_s: 0.1,
            bcast_bytes: vec![1000],
            gather_bytes: vec![1000],
            };
        let par4 = IterTiming {
            worker_busy_s: vec![0.25; 4],
            master_busy_s: 0.1,
            bcast_bytes: vec![1000; 4],
            gather_bytes: vec![1000; 4],
        };
        let c = comm();
        let speedup = serial.virtual_s(&c) / par4.virtual_s(&c);
        assert!(speedup > 3.0 && speedup < 4.0, "speedup={speedup}");
    }
}
