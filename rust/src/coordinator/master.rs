//! The master process and the public [`Coordinator`] driving P workers.
//!
//! Master per iteration (paper §3):
//! 1. broadcast the current global parameters (+ the structural keep /
//!    promote instruction from the previous global step);
//! 2. gather per-shard summaries (m_k, ZᵀZ_p, ZᵀX_p, tail bits from p′);
//! 3. merge; promote the K* tail features into K⁺; drop globally-empty
//!    features; sample A, σ_X, σ_A, π, α; pick the next p′.
//!
//! All master↔worker traffic is byte-encoded (`messages.rs`), moved by a
//! pluggable [`Transport`] (in-process channels by default; UDS/TCP for
//! real worker processes — see `transport/`), and charged to the virtual
//! clock (`vtime.rs`).

use std::path::PathBuf;
use std::sync::mpsc::channel;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::config::{Backend, CommModel};
use crate::linalg::Mat;
use crate::model::state::{FeatureState, Kernel};
use crate::model::{ibp, GlobalParams, LinGauss};
use crate::obs;
use crate::parallel::ParallelCtx;
use crate::rng::{tags, Pcg64};
use crate::runtime::{Engine, Ops};
use crate::samplers::hybrid::make_shards;
use crate::samplers::SamplerOptions;
use crate::snapshot::{CoordinatorSnapshot, MasterSnapshot, WorkerSnapshot};

use super::messages::{Broadcast, Summary, ToWorker, ZReport};
use super::transport::{
    ChannelTransport, SocketTransport, Transport, TransportConfig, WorkerSetup,
};
use super::vtime::{IterTiming, VClock};
use super::worker::{run_worker, WorkerConfig};

/// Coordinator configuration (a cut of `config::RunConfig`).
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    pub processors: usize,
    pub sub_iters: usize,
    /// Intra-worker sweep threads T (native backend; see
    /// [`crate::parallel`]). Changes wall-clock only, never the chain.
    pub threads_per_worker: usize,
    pub seed: u64,
    pub lg: LinGauss,
    pub alpha: f64,
    pub opts: SamplerOptions,
    pub backend: Backend,
    pub artifacts_dir: PathBuf,
    pub comm: CommModel,
    /// Worker Z storage kernel (scalar bytes / packed u64 words). Like
    /// `threads_per_worker`, bit-invariant: the chain is identical for
    /// either value (see `rust/tests/packed_equivalence.rs`).
    pub kernel: Kernel,
    /// How master↔worker frames move: in-process channels (default), or
    /// a UDS/TCP socket serving real `pibp worker --connect` processes.
    /// Bit-invariant — the chain bytes must not depend on how bytes move
    /// (see `rust/tests/process_equivalence.rs`).
    pub transport: TransportConfig,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            processors: 1,
            sub_iters: 5,
            threads_per_worker: 1,
            seed: 0,
            lg: LinGauss::new(0.5, 1.0),
            alpha: 1.0,
            opts: SamplerOptions::default(),
            backend: Backend::Native,
            artifacts_dir: PathBuf::from("artifacts"),
            comm: CommModel::default(),
            kernel: Kernel::Scalar,
            transport: TransportConfig::Channel,
        }
    }
}

/// The merged-and-compacted sufficient statistics of the most recent
/// global step, in the column space of [`Coordinator::params`]: the
/// quantities the master samples (A, π, σ) from. Exposed so tests can pin
/// the merge pipeline against a serial recomputation from the gathered Z
/// (see `rust/tests/parallel_equivalence.rs`).
#[derive(Clone, Debug)]
pub struct MergedStats {
    /// Merged ZᵀZ (K⁺ × K⁺). Integer-valued, so exact under any merge order.
    pub ztz: Mat,
    /// Merged ZᵀX (K⁺ × D), accumulated in worker order.
    pub ztx: Mat,
    /// Merged global column counts m_k.
    pub m: Vec<usize>,
    /// Merged tr XᵀX = Σ_p ‖X_p‖², accumulated in worker order.
    pub tr_xx: f64,
}

/// Per-iteration record (trace row).
#[derive(Clone, Debug)]
pub struct IterRecord {
    pub iter: usize,
    pub k: usize,
    pub alpha: f64,
    pub sigma_x: f64,
    pub sigma_a: f64,
    /// Virtual iteration duration / cumulative virtual time (seconds).
    pub vtime_iter_s: f64,
    pub vtime_total_s: f64,
    /// Wall-clock iteration duration (seconds).
    pub wall_iter_s: f64,
    pub comm_bytes: usize,
    pub max_worker_busy_s: f64,
    pub master_busy_s: f64,
}

pub struct Coordinator {
    /// The message plane to the P workers — in-process channels or a
    /// socket. Everything above this field is transport-agnostic.
    transport: Box<dyn Transport>,
    engine: Option<Engine>,
    rng: Pcg64,
    params: GlobalParams,
    /// Structural instruction pending for the next broadcast.
    next_keep: Vec<u32>,
    next_k_star: u32,
    next_tail_owner: u32,
    next_demote: Vec<u32>,
    /// Copy of the promoted tail bits (from the owner's summary), kept so
    /// `gather_z` can materialise the full matrix without a structural
    /// round-trip.
    pending_tail_bits: Option<FeatureState>,
    p_prime: u32,
    /// Global column counts for the *current* K⁺ (post-merge).
    m_global: Vec<usize>,
    /// Merged suff stats of the last global step (test/diagnostic hook).
    last_merged: Option<MergedStats>,
    n: usize,
    d: usize,
    iter: usize,
    cfg: CoordinatorConfig,
    pub clock: VClock,
    shard_sizes: Vec<usize>,
}

impl Coordinator {
    /// Split `x` into P row shards and spawn the workers.
    pub fn new(x: &Mat, cfg: CoordinatorConfig) -> Result<Self> {
        if cfg.processors == 0 || x.rows() < cfg.processors {
            bail!("need 1 ≤ P ≤ N");
        }
        let n = x.rows();
        let d = x.cols();
        let shards = make_shards(n, cfg.processors);
        // Shard extraction is identical for every transport; worker `id`
        // always gets shard `id` and RNG stream `id`, so where the worker
        // runs (thread here, process over a socket) cannot move bits.
        let shard_of = |shard: &std::ops::Range<usize>| {
            Mat::from_fn(shard.len(), d, |i, j| x[(shard.start + i, j)])
        };
        let transport: Box<dyn Transport> = match &cfg.transport {
            TransportConfig::Channel => {
                let (tx_master, from_workers) = channel::<(usize, Vec<u8>)>();
                let mut to_workers = Vec::with_capacity(cfg.processors);
                let mut handles = Vec::with_capacity(cfg.processors);
                for (id, shard) in shards.iter().enumerate() {
                    let (tx, rx) = channel::<Vec<u8>>();
                    let wcfg = WorkerConfig {
                        id,
                        n_global: n,
                        sub_iters: cfg.sub_iters,
                        // each native worker owns a persistent pool for its
                        // shard sweeps, spawned here once and reused for the
                        // whole run (T ≤ 1, including a pathological 0,
                        // degrades to inline). PJRT workers sweep inside the
                        // kernel and never touch the native executor — don't
                        // spawn idle pool threads for them.
                        ctx: match cfg.backend {
                            Backend::Native => {
                                ParallelCtx::pooled(cfg.threads_per_worker)
                            }
                            Backend::Pjrt => ParallelCtx::inline(),
                        },
                        kernel: cfg.kernel,
                        kmax_new: cfg.opts.kmax_new,
                        k_cap: cfg.opts.k_cap,
                        seed: cfg.seed,
                        backend: cfg.backend,
                        artifacts_dir: cfg.artifacts_dir.clone(),
                    };
                    let x_shard = shard_of(shard);
                    let tx_m = tx_master.clone();
                    handles.push(
                        // detlint:allow(stray-thread): the coordinator is the sanctioned spawn site for worker threads — each is channel-driven and joined in shutdown()
                        std::thread::Builder::new()
                            .name(format!("pibp-worker-{id}"))
                            .spawn(move || run_worker(wcfg, x_shard, rx, tx_m))
                            .context("spawning worker")?,
                    );
                    to_workers.push(tx);
                }
                Box::new(ChannelTransport::new(to_workers, from_workers, handles))
            }
            t @ (TransportConfig::Uds { .. } | TransportConfig::Tcp { .. }) => {
                let setups = shards
                    .iter()
                    .enumerate()
                    .map(|(id, shard)| WorkerSetup {
                        id,
                        n_global: n,
                        sub_iters: cfg.sub_iters,
                        threads: cfg.threads_per_worker,
                        kernel: cfg.kernel,
                        kmax_new: cfg.opts.kmax_new,
                        k_cap: cfg.opts.k_cap,
                        seed: cfg.seed,
                        backend: cfg.backend,
                        artifacts_dir: cfg.artifacts_dir.clone(),
                        x_shard: shard_of(shard),
                    })
                    .collect();
                Box::new(
                    SocketTransport::start(t, setups)
                        .context("starting socket transport")?,
                )
            }
        };
        let engine = match cfg.backend {
            Backend::Pjrt => Some(
                Engine::load(&cfg.artifacts_dir)
                    .context("master: loading artifacts")?,
            ),
            Backend::Native => None,
        };
        let mut rng = Pcg64::new(cfg.seed).split(tags::MASTER);
        let p_prime = rng.below(cfg.processors as u64) as u32;
        Ok(Self {
            transport,
            engine,
            rng,
            params: GlobalParams {
                a: Mat::zeros(0, d),
                pi: vec![],
                lg: cfg.lg,
                alpha: cfg.alpha,
            },
            next_keep: vec![],
            next_k_star: 0,
            next_tail_owner: 0,
            next_demote: vec![],
            pending_tail_bits: None,
            p_prime,
            m_global: vec![],
            last_merged: None,
            n,
            d,
            iter: 0,
            cfg,
            clock: VClock::new(),
            shard_sizes: shards.iter().map(|s| s.len()).collect(),
        })
    }

    pub fn params(&self) -> &GlobalParams {
        &self.params
    }

    pub fn k(&self) -> usize {
        self.params.k()
    }

    pub fn m_global(&self) -> &[usize] {
        &self.m_global
    }

    /// Merged sufficient statistics of the most recent [`Self::step`],
    /// compacted to the current K⁺ column space (None before any step).
    pub fn last_merged(&self) -> Option<&MergedStats> {
        self.last_merged.as_ref()
    }

    /// Receive exactly one message from every worker and decode it —
    /// the shared gather protocol of [`Self::step`], [`Self::gather_z`]
    /// and [`Self::snapshot`]. Every failure mode is a contextual `Err`,
    /// never a panic or a hang: a dead channel, a message from an
    /// unknown or duplicate worker id, a zero-length frame (the worker
    /// abort sentinel — a failing worker ships it precisely so this loop
    /// errors instead of blocking forever at P > 1), and a decode error.
    fn recv_from_all<T>(
        &mut self,
        what: &str,
        mut decode: impl FnMut(usize, &[u8]) -> Result<T>,
    ) -> Result<Vec<T>> {
        let mut out: Vec<Option<T>> =
            (0..self.cfg.processors).map(|_| None).collect();
        for _ in 0..self.cfg.processors {
            // the span measures the master's blocking wait for this
            // message — per worker, so stragglers show up in the p99
            let recv = {
                let _wait = obs::span(obs::Span::MasterGatherWait);
                self.transport.recv()
            };
            let (id, buf) =
                recv.with_context(|| format!("worker died during {what}"))?;
            obs::add(obs::Counter::NetBytesReceived, buf.len() as u64);
            if id >= out.len() {
                bail!("{what}: message from unknown worker id {id} (P={})",
                      out.len());
            }
            if buf.is_empty() {
                bail!("{what}: worker {id} aborted with a fatal error \
                       (see its stderr log)");
            }
            if out[id].is_some() {
                bail!("{what}: duplicate message from worker {id}");
            }
            out[id] = Some(decode(id, &buf)?);
        }
        out.into_iter()
            .enumerate()
            .map(|(p, t)| {
                t.with_context(|| format!("{what}: no message from worker {p}"))
            })
            .collect()
    }

    /// Send the same encoded frame to every worker (broadcast pattern of
    /// `step`/`gather_z`/`snapshot`), counting outbound bytes.
    fn send_all(&mut self, what: &str, msg: &[u8]) -> Result<()> {
        for p in 0..self.cfg.processors {
            self.transport
                .send(p, msg)
                .with_context(|| format!("{what}: sending to worker {p}"))?;
            obs::add(obs::Counter::NetBytesSent, msg.len() as u64);
        }
        Ok(())
    }

    /// One global iteration.
    pub fn step(&mut self) -> Result<IterRecord> {
        // detlint:allow(wall-clock-in-chain): wall_iter_s is reported in IterRecord only; the chain never branches on it
        let wall_start = Instant::now();
        let draws0 = self.rng.draw_count();
        let mut timing = IterTiming {
            worker_busy_s: vec![0.0; self.cfg.processors],
            master_busy_s: 0.0,
            bcast_bytes: Vec::with_capacity(self.cfg.processors),
            gather_bytes: Vec::with_capacity(self.cfg.processors),
        };
        // Measured broadcast→all-summaries round-trip of this iteration
        // (wall clock, obs-only). The VClock's simulated comm model stays
        // the vtime source — vtime is derived from frame *sizes* and
        // worker busy time, never from this measurement, which is what
        // keeps the chain and its vtime trace transport-invariant.
        let rtt_span = obs::span(obs::Span::MasterGatherRtt);
        // ---- broadcast ----
        let bcast_span = obs::span(obs::Span::MasterBroadcast);
        let bcast = Broadcast {
            iter: self.iter as u32,
            a: self.params.a.clone(),
            pi: self.params.pi.clone(),
            sigma_x: self.params.lg.sigma_x,
            sigma_a: self.params.lg.sigma_a,
            alpha: self.params.alpha,
            p_prime: self.p_prime,
            keep: std::mem::take(&mut self.next_keep),
            k_star: self.next_k_star,
            tail_owner: self.next_tail_owner,
            demote: std::mem::take(&mut self.next_demote),
        };
        let msg = ToWorker::Run(bcast).encode();
        timing.bcast_bytes.extend((0..self.cfg.processors).map(|_| msg.len()));
        self.send_all("iteration broadcast", &msg)?;
        drop(bcast_span);
        // ---- gather ----
        let summaries: Vec<Summary> =
            self.recv_from_all("iteration gather", |id, buf| {
                timing.gather_bytes.push(buf.len());
                let s = Summary::decode(buf)?;
                timing.worker_busy_s[id] = s.busy_s;
                Ok(s)
            })?;
        drop(rtt_span);

        // ---- master global step ----
        // detlint:allow(wall-clock-in-chain): master_busy_s feeds the virtual comm-model clock and the obs report, not the chain
        let mstart = Instant::now();
        self.global_step(&summaries)?;
        timing.master_busy_s = mstart.elapsed().as_secs_f64();

        self.iter += 1;
        obs::record_k(self.iter as u64, self.params.k() as u64);
        obs::add(
            obs::Counter::RngDrawsMaster,
            self.rng.draw_count().wrapping_sub(draws0),
        );
        let vtime_iter_s = self.clock.advance(&timing, &self.cfg.comm);
        Ok(IterRecord {
            iter: self.iter,
            k: self.params.k(),
            alpha: self.params.alpha,
            sigma_x: self.params.lg.sigma_x,
            sigma_a: self.params.lg.sigma_a,
            vtime_iter_s,
            vtime_total_s: self.clock.elapsed_s(),
            wall_iter_s: wall_start.elapsed().as_secs_f64(),
            comm_bytes: timing.total_bytes(),
            max_worker_busy_s: timing
                .worker_busy_s
                .iter()
                .fold(0.0f64, |a, &b| a.max(b)),
            master_busy_s: timing.master_busy_s,
        })
    }

    /// Merge summaries, promote, compact, resample globals, pick p′.
    fn global_step(&mut self, summaries: &[Summary]) -> Result<()> {
        let k_plus = self.params.k();
        let p_prime = self.p_prime as usize;
        let tail = summaries[p_prime].tail.as_ref();
        let k_star = tail.map_or(0, |t| t.k());
        let k_ext = k_plus + k_star;

        // ---- merge suff stats into the extended column space ----
        let merge_span = obs::span(obs::Span::MasterMerge);
        let mut ztz = Mat::zeros(k_ext, k_ext);
        let mut ztx = Mat::zeros(k_ext, self.d);
        let mut tr_xx = 0.0;
        let mut m_ext = vec![0usize; k_ext];
        for (p, s) in summaries.iter().enumerate() {
            tr_xx += s.tr_xx;
            if s.m_local.len() != k_plus {
                bail!("worker {p} summary has {} counts, want {k_plus}",
                      s.m_local.len());
            }
            for (k, &m) in s.m_local.iter().enumerate() {
                m_ext[k] += m as usize;
            }
            // s.ztz is (k_plus [+ k_star on p′]) square
            let sk = s.ztz.rows();
            let expect = if p == p_prime { k_ext } else { k_plus };
            if sk != expect {
                bail!("worker {p} ztz is {sk}, want {expect}");
            }
            for i in 0..sk {
                for j in 0..sk {
                    ztz[(i, j)] += s.ztz[(i, j)];
                }
                let src = s.ztx.row(i);
                let dst = ztx.row_mut(i);
                for (t, &v) in dst.iter_mut().zip(src) {
                    *t += v;
                }
            }
        }
        if let Some(t) = tail {
            for j in 0..k_star {
                m_ext[k_plus + j] = t.m()[j];
            }
        }
        drop(merge_span);

        // ---- choose the NEXT p′ first: demotion needs to know it ----
        let promote_span = obs::span(obs::Span::MasterPromote);
        let p_next = self.rng.below(self.cfg.processors as u64) as u32;

        // ---- demotion: small features living entirely inside p_next's
        //      shard go back to the collapsed tail (DESIGN.md §Demotion).
        //      Never demote on top of a fresh promotion to the same owner
        //      beyond the k-cap budget; cheap junk (m ≤ demote_below) only.
        let demote: Vec<u32> = if self.cfg.opts.demote_below > 0 {
            (0..k_plus)
                .filter(|&k| {
                    let m = m_ext[k];
                    m > 0
                        && m <= self.cfg.opts.demote_below
                        && summaries[p_next as usize].m_local[k] as usize == m
                })
                .map(|k| k as u32)
                .collect()
        } else {
            vec![]
        };
        let demoted = |k: usize| demote.binary_search(&(k as u32)).is_ok();

        // ---- global compaction decision ----
        let keep_old: Vec<u32> = (0..k_plus)
            .filter(|&k| m_ext[k] > 0 && !demoted(k))
            .map(|k| k as u32)
            .collect();
        let keep_ext: Vec<usize> = keep_old
            .iter()
            .map(|&k| k as usize)
            .chain(k_plus..k_ext)
            .collect();
        let k_new = keep_ext.len();
        let sel = |m: &Mat| -> Mat {
            Mat::from_fn(k_new, m.cols(), |i, j| m[(keep_ext[i], j)])
        };
        let ztx_c = sel(&ztx);
        let ztz_c = Mat::from_fn(k_new, k_new, |i, j| {
            ztz[(keep_ext[i], keep_ext[j])]
        });
        let m_c: Vec<usize> = keep_ext.iter().map(|&k| m_ext[k]).collect();
        self.last_merged = Some(MergedStats {
            ztz: ztz_c.clone(),
            ztx: ztx_c.clone(),
            m: m_c.clone(),
            tr_xx,
        });
        obs::add(obs::Counter::FeaturesPromoted, k_star as u64);
        obs::add(obs::Counter::FeaturesDemoted, demote.len() as u64);
        // dead features dropped at compaction: the instantiated columns
        // that are neither kept nor demoted (their global m_k hit zero)
        obs::add(
            obs::Counter::FeaturesCompacted,
            (k_plus - keep_old.len() - demote.len()) as u64,
        );
        drop(promote_span);

        // ---- sample globals ----
        let apost_span = obs::span(obs::Span::MasterApost);
        if k_new > 0 {
            self.params.a = match &self.engine {
                Some(eng) => Ops::new(eng).apost(
                    &ztz_c, &ztx_c,
                    self.params.lg.sigma_x, self.params.lg.sigma_a,
                    &mut self.rng,
                )?,
                None => self.params.lg.apost_sample(&ztz_c, &ztx_c, &mut self.rng),
            };
            self.params.pi = ibp::sample_pi(&m_c, self.n, &mut self.rng);
        } else {
            self.params.a = Mat::zeros(0, self.d);
            self.params.pi.clear();
        }
        if self.cfg.opts.sample_sigmas {
            // RSS from the merged stats and the freshly sampled A:
            // ‖X−ZA‖² = tr(XᵀX) − 2·tr(AᵀZᵀX) + tr(Aᵀ ZᵀZ A)
            let rss = if k_new > 0 {
                let a = &self.params.a;
                let za = ztz_c.matmul(a);
                (tr_xx - 2.0 * a.dot(&ztx_c) + a.dot(&za)).max(1e-12)
            } else {
                tr_xx
            };
            self.params.lg.sigma_x = ibp::sample_sigma_x(
                rss, self.n, self.d,
                self.cfg.opts.sigma_a0, self.cfg.opts.sigma_b0,
                &mut self.rng,
            );
            if k_new > 0 {
                self.params.lg.sigma_a = ibp::sample_sigma_a(
                    self.params.a.frob2(), k_new, self.d,
                    self.cfg.opts.sigma_a0, self.cfg.opts.sigma_b0,
                    &mut self.rng,
                );
            }
        }
        if self.cfg.opts.sample_alpha {
            self.params.alpha = ibp::sample_alpha(k_new, self.n, &mut self.rng);
        }
        drop(apost_span);
        self.m_global = m_c;

        // ---- structural instruction for the next broadcast ----
        self.next_keep = keep_old;
        self.next_k_star = k_star as u32;
        self.next_tail_owner = self.p_prime;
        self.next_demote = demote;
        self.pending_tail_bits = tail.cloned();
        self.p_prime = p_next;
        Ok(())
    }

    /// Gather the full N × K⁺ feature matrix (matching `params()`'s
    /// column space) from all workers.
    ///
    /// Worker Z states lag one broadcast behind `params()` — the pending
    /// keep/promote instruction is applied at the next Run — so the master
    /// applies that same instruction here, using its stored copy of the
    /// promoted tail bits for the new columns.
    ///
    /// Every inconsistency (a worker that sent no report, a short or
    /// mis-shaped report, promoted tail bits that were never stored) is a
    /// contextual `Err`, never a panic: checkpointing and `pibp predict`
    /// fail cleanly instead of aborting the process.
    pub fn gather_z(&mut self) -> Result<FeatureState> {
        let msg = ToWorker::SendZ.encode();
        self.send_all("Z gather", &msg)?;
        let reports: Vec<Option<ZReport>> = self
            .recv_from_all("Z gather", |_, buf| ZReport::decode(buf))?
            .into_iter()
            .map(Some)
            .collect();
        assemble_global_z(
            self.n,
            &self.shard_sizes,
            &reports,
            &self.next_keep,
            self.next_k_star as usize,
            self.next_tail_owner as usize,
            self.pending_tail_bits.as_ref(),
        )
    }

    /// Capture the complete chain state at the current iteration
    /// boundary: master RNG + globals + pending structural instruction +
    /// virtual clock, and (via a `GetState` round-trip) every worker's
    /// RNG stream, Z bits and pending tail. A pure read — no RNG stream
    /// advances — so taking snapshots never perturbs the chain.
    ///
    /// The `last_merged` diagnostic hook is deliberately not captured: it
    /// is re-populated by the next `step` and feeds no sampling decision.
    pub fn snapshot(&mut self) -> Result<CoordinatorSnapshot> {
        let msg = ToWorker::GetState.encode();
        self.send_all("state snapshot", &msg)?;
        let workers: Vec<WorkerSnapshot> =
            self.recv_from_all("state snapshot", |_, buf| {
                WorkerSnapshot::decode(buf)
            })?;
        Ok(CoordinatorSnapshot {
            iter: self.iter as u64,
            master: MasterSnapshot {
                rng: self.rng.export_state(),
                a: self.params.a.clone(),
                pi: self.params.pi.clone(),
                sigma_x: self.params.lg.sigma_x,
                sigma_a: self.params.lg.sigma_a,
                alpha: self.params.alpha,
                next_keep: self.next_keep.clone(),
                next_k_star: self.next_k_star,
                next_tail_owner: self.next_tail_owner,
                next_demote: self.next_demote.clone(),
                pending_tail_bits: self.pending_tail_bits.clone(),
                p_prime: self.p_prime,
                m_global: self.m_global.iter().map(|&m| m as u64).collect(),
                clock_elapsed_s: self.clock.elapsed_s(),
                clock_iterations: self.clock.iterations as u64,
                clock_comm_bytes: self.clock.total_comm_bytes as u64,
            },
            workers,
        })
    }

    /// Install a previously captured state, overwriting the freshly
    /// constructed chain: after this, `step` continues bit-identically to
    /// the run the snapshot was taken from — for any thread count T,
    /// since per-block sweep substreams derive from the restored worker
    /// streams. The coordinator must have been built over the same data
    /// shape and processor count (validated here against the shards).
    pub fn restore(&mut self, snap: &CoordinatorSnapshot) -> Result<()> {
        if snap.workers.len() != self.cfg.processors {
            bail!(
                "checkpoint has {} workers but this run is configured for P={}",
                snap.workers.len(),
                self.cfg.processors
            );
        }
        for (p, ws) in snap.workers.iter().enumerate() {
            if ws.id as usize != p {
                bail!("checkpoint worker {p} carries id {}", ws.id);
            }
            if ws.z.n() != self.shard_sizes[p] {
                bail!(
                    "checkpoint worker {p} has a {}-row shard, this run's shard \
                     is {} rows (different N or P?)",
                    ws.z.n(),
                    self.shard_sizes[p]
                );
            }
        }
        for (p, ws) in snap.workers.iter().enumerate() {
            let msg = ToWorker::SetState(ws.clone()).encode();
            self.transport
                .send(p, &msg)
                .with_context(|| format!("restore: sending to worker {p}"))?;
            obs::add(obs::Counter::NetBytesSent, msg.len() as u64);
        }
        // collect the one-byte acks through the shared gather protocol,
        // so a worker that died mid-restore (or shipped the abort
        // sentinel) is a contextual error, not a hang or a silent skip
        self.recv_from_all("restore", |_, _| Ok(()))?;
        let m = &snap.master;
        if m.a.rows() != m.pi.len() {
            bail!("checkpoint master state inconsistent: |A|={} rows, |π|={}",
                  m.a.rows(), m.pi.len());
        }
        self.rng = Pcg64::from_state(m.rng);
        self.params = GlobalParams {
            a: m.a.clone(),
            pi: m.pi.clone(),
            lg: LinGauss::new(m.sigma_x, m.sigma_a),
            alpha: m.alpha,
        };
        self.next_keep = m.next_keep.clone();
        self.next_k_star = m.next_k_star;
        self.next_tail_owner = m.next_tail_owner;
        self.next_demote = m.next_demote.clone();
        self.pending_tail_bits = m.pending_tail_bits.clone();
        self.p_prime = m.p_prime;
        self.m_global = m.m_global.iter().map(|&v| v as usize).collect();
        self.last_merged = None;
        self.iter = snap.iter as usize;
        self.clock = VClock::from_parts(
            m.clock_elapsed_s,
            m.clock_iterations as usize,
            m.clock_comm_bytes as usize,
        );
        Ok(())
    }

    pub fn shutdown(&mut self) {
        // best-effort: a worker that already died must not block the rest
        // from being released
        let msg = ToWorker::Shutdown.encode();
        for p in 0..self.cfg.processors {
            let _ = self.transport.send(p, &msg);
        }
        self.transport.shutdown();
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Assemble the global N × (|keep| + k_star) feature matrix from per-shard
/// Z reports plus the master's pending structural instruction — the pure
/// core of [`Coordinator::gather_z`], factored out so its failure modes
/// (missing report, short report, stale keep index, absent tail bits) are
/// unit-testable without live worker threads.
fn assemble_global_z(
    n: usize,
    shard_sizes: &[usize],
    reports: &[Option<ZReport>],
    keep: &[u32],
    k_star: usize,
    tail_owner: usize,
    tail_bits: Option<&FeatureState>,
) -> Result<FeatureState> {
    let base = keep.len();
    let mut global = FeatureState::empty(n);
    global.add_features(base + k_star);
    let mut row0 = 0usize;
    for (p, rep) in reports.iter().enumerate() {
        let z = &rep
            .as_ref()
            .with_context(|| format!("gather_z: worker {p} sent no Z report"))?
            .z;
        if z.n() != shard_sizes[p] {
            bail!(
                "gather_z: worker {p} reported {} rows, its shard has {}",
                z.n(),
                shard_sizes[p]
            );
        }
        for (new_j, &old_j) in keep.iter().enumerate() {
            if old_j as usize >= z.k() {
                bail!(
                    "gather_z: keep instruction references column {old_j} but \
                     worker {p}'s Z has only {} columns",
                    z.k()
                );
            }
            for i in 0..z.n() {
                if z.get(i, old_j as usize) == 1 {
                    global.set(row0 + i, new_j, 1);
                }
            }
        }
        if p == tail_owner && k_star > 0 {
            let tail = tail_bits.with_context(|| {
                format!(
                    "gather_z: {k_star} promoted tail feature(s) pending on \
                     worker {p} but no tail bits were stored at promotion"
                )
            })?;
            if tail.n() != shard_sizes[p] || tail.k() < k_star {
                bail!(
                    "gather_z: stored tail bits are {}×{}, want {}×≥{k_star}",
                    tail.n(),
                    tail.k(),
                    shard_sizes[p]
                );
            }
            for i in 0..tail.n() {
                for j in 0..k_star {
                    if tail.get(i, j) == 1 {
                        global.set(row0 + i, base + j, 1);
                    }
                }
            }
        }
        row0 += shard_sizes[p];
    }
    Ok(global)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bits(n: usize, k: usize, pattern: &[(usize, usize)]) -> FeatureState {
        let mut st = FeatureState::empty(n);
        st.add_features(k);
        for &(i, j) in pattern {
            st.set(i, j, 1);
        }
        st
    }

    fn report(worker: u32, z: FeatureState) -> Option<ZReport> {
        Some(ZReport { worker, z })
    }

    #[test]
    fn assemble_reorders_keeps_and_appends_tail() {
        // two shards of 2 rows; keep = [2, 0] reorders; one promoted tail
        // column owned by worker 1
        let reports = vec![
            report(0, bits(2, 3, &[(0, 0), (1, 2)])),
            report(1, bits(2, 3, &[(0, 2), (1, 1)])),
        ];
        let tail = bits(2, 1, &[(1, 0)]);
        let z = assemble_global_z(4, &[2, 2], &reports, &[2, 0], 1, 1,
                                  Some(&tail))
            .unwrap();
        assert_eq!(z.k(), 3);
        // old col 2 → new col 0: rows 1 (shard 0) and 2 (shard 1)
        assert_eq!(z.get(1, 0), 1);
        assert_eq!(z.get(2, 0), 1);
        // old col 0 → new col 1: row 0
        assert_eq!(z.get(0, 1), 1);
        // tail bit: local row 1 of shard 1 ⇒ global row 3, col 2
        assert_eq!(z.get(3, 2), 1);
        assert_eq!(z.m(), &[2, 1, 1]);
        assert!(z.check_invariants());
    }

    #[test]
    fn assemble_errors_on_missing_report() {
        let reports = vec![report(0, bits(2, 1, &[(0, 0)])), None];
        let err = assemble_global_z(4, &[2, 2], &reports, &[0], 0, 0, None)
            .unwrap_err()
            .to_string();
        assert!(err.contains("worker 1"), "unexpected error: {err}");
        assert!(err.contains("no Z report"), "unexpected error: {err}");
    }

    #[test]
    fn assemble_errors_on_short_report() {
        // worker 1 reports a 1-row Z for a 2-row shard
        let reports = vec![
            report(0, bits(2, 1, &[(0, 0)])),
            report(1, bits(1, 1, &[(0, 0)])),
        ];
        let err = assemble_global_z(4, &[2, 2], &reports, &[0], 0, 0, None)
            .unwrap_err()
            .to_string();
        assert!(err.contains("worker 1 reported 1 rows"), "got: {err}");
    }

    #[test]
    fn assemble_errors_on_stale_keep_index() {
        let reports = vec![report(0, bits(2, 1, &[(0, 0)]))];
        let err = assemble_global_z(2, &[2], &reports, &[3], 0, 0, None)
            .unwrap_err()
            .to_string();
        assert!(err.contains("column 3"), "unexpected error: {err}");
    }

    #[test]
    fn assemble_errors_on_absent_tail_bits() {
        let reports = vec![report(0, bits(2, 1, &[(0, 0)]))];
        let err = assemble_global_z(2, &[2], &reports, &[0], 2, 0, None)
            .unwrap_err()
            .to_string();
        assert!(err.contains("no tail bits were stored"), "got: {err}");
    }

    #[test]
    fn assemble_errors_on_misshapen_tail_bits() {
        let reports = vec![report(0, bits(2, 1, &[(0, 0)]))];
        let tail = bits(1, 1, &[(0, 0)]); // 1 row, shard has 2
        let err = assemble_global_z(2, &[2], &reports, &[0], 1, 0, Some(&tail))
            .unwrap_err()
            .to_string();
        assert!(err.contains("stored tail bits"), "got: {err}");
    }

    #[test]
    fn assemble_with_no_promotion_ignores_tail_state() {
        // k_star = 0: tail bits (even stale ones) are irrelevant
        let reports = vec![report(0, bits(2, 2, &[(0, 1)]))];
        let stale = bits(2, 4, &[(0, 0)]);
        let z = assemble_global_z(2, &[2], &reports, &[1, 0], 0, 0,
                                  Some(&stale))
            .unwrap();
        assert_eq!(z.k(), 2);
        assert_eq!(z.get(0, 0), 1);
        assert_eq!(z.m(), &[1, 0]);
    }
}
