//! Worker thread: owns an observation shard, runs the uncollapsed sweep
//! over the instantiated features every sub-iteration (natively or via the
//! PJRT zsweep artifact), hosts the collapsed tail when elected p′, and
//! ships summary statistics to the master.
//!
//! A worker is a pure function of (its shard, its RNG stream, the
//! broadcast sequence) — no shared state, so chains are reproducible
//! regardless of thread scheduling.

use std::path::PathBuf;
use std::sync::mpsc::{Receiver, Sender};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::config::Backend;
use crate::linalg::Mat;
use crate::model::state::{FeatureState, Kernel};
use crate::model::LinGauss;
use crate::obs;
use crate::parallel::{par_sweep_rows, ExecConfig, ParallelCtx};
use crate::rng::{tags, Pcg64};
use crate::runtime::{Engine, Ops};
use crate::samplers::tail::TailProposer;
use crate::samplers::uncollapsed::residuals;
use crate::snapshot::WorkerSnapshot;

use super::messages::{Broadcast, Summary, ToWorker, ZReport};

/// Static per-worker configuration (fixed at spawn).
#[derive(Clone, Debug)]
pub struct WorkerConfig {
    pub id: usize,
    pub n_global: usize,
    pub sub_iters: usize,
    /// Intra-worker sweep execution context (native backend): a handle to
    /// this worker's persistent thread pool, created once at spawn and
    /// reused by every sweep. Results are bit-identical for every lane
    /// count and scheduling mode — see [`crate::parallel`].
    pub ctx: ParallelCtx,
    /// Z storage kernel (scalar bytes / packed u64 words). Bit-invariant:
    /// the packed sweep and suff-stat kernels mirror the scalar ones
    /// exactly, and the wire/checkpoint encoding is repr-agnostic, so a
    /// worker produces the same chain under either value.
    pub kernel: Kernel,
    pub kmax_new: usize,
    pub k_cap: usize,
    pub seed: u64,
    pub backend: Backend,
    pub artifacts_dir: PathBuf,
}

/// How a worker talks to its master: blocking framed receive +
/// best-effort send. Implemented by the in-process channel pair below
/// (default) and by a socket link in `super::transport::socket` — the
/// worker loop is byte-identical over either, which is half of the
/// transport-invariance argument (the other half is the master assigning
/// ids/shards in its own deterministic order).
pub(crate) trait WorkerEndpoint {
    /// Next inbound frame; `None` once the link is closed (master gone).
    fn recv(&mut self) -> Option<Vec<u8>>;
    /// Best-effort outbound send: a dead master surfaces at the next
    /// `recv`, matching the old channel `.send(..).ok()` semantics.
    fn send(&mut self, frame: Vec<u8>);
}

/// The in-process endpoint over the channel pair `Coordinator::new`
/// wires up. Frames are moved, never copied — the zero-cost default.
pub(crate) struct ChannelEndpoint {
    id: usize,
    rx: Receiver<Vec<u8>>,
    tx: Sender<(usize, Vec<u8>)>,
}

impl WorkerEndpoint for ChannelEndpoint {
    fn recv(&mut self) -> Option<Vec<u8>> {
        self.rx.recv().ok()
    }

    fn send(&mut self, frame: Vec<u8>) {
        self.tx.send((self.id, frame)).ok();
    }
}

/// Thread body for in-process workers. `rx` carries encoded `ToWorker`s;
/// every outbound message is sent as (worker id, encoded bytes).
pub fn run_worker(
    cfg: WorkerConfig,
    x: Mat,
    rx: Receiver<Vec<u8>>,
    tx: Sender<(usize, Vec<u8>)>,
) {
    let mut ep = ChannelEndpoint { id: cfg.id, rx, tx };
    run_worker_on(cfg, x, &mut ep);
}

/// Run the worker loop over any endpoint (thread + channels, or a remote
/// process + socket), with the abort-sentinel discipline on failure.
pub(crate) fn run_worker_on(cfg: WorkerConfig, x: Mat, ep: &mut dyn WorkerEndpoint) {
    if let Err(e) = worker_loop(&cfg, x, ep) {
        // A worker failing is fatal for the run; surface loudly AND tell
        // the master. At P > 1 the other workers keep their links open,
        // so merely dying would leave the master's gather recv blocked
        // forever — the empty frame below is the abort sentinel every
        // master recv loop turns into a contextual error (no valid
        // Summary / ZReport / snapshot encoding is zero-length; over a
        // socket, EOF is translated into the same sentinel by the
        // master's reader).
        eprintln!("[pibp worker {}] fatal: {e:#}", cfg.id);
        ep.send(Vec::new());
    }
}

fn worker_loop(
    cfg: &WorkerConfig,
    x: Mat,
    ep: &mut dyn WorkerEndpoint,
) -> Result<()> {
    let b_rows = x.rows();
    let mut rng = Pcg64::new(cfg.seed).split(tags::worker(cfg.id));
    let mut z = FeatureState::empty_with(b_rows, cfg.kernel);
    // tail bits discovered last iteration, kept until the master's
    // promotion instruction arrives in the next broadcast
    let mut last_tail: Option<FeatureState> = None;
    let engine = match cfg.backend {
        Backend::Pjrt => Some(
            Engine::load(&cfg.artifacts_dir)
                .context("worker: loading artifacts for PJRT backend")?,
        ),
        Backend::Native => None,
    };
    let tr_xx = x.frob2();
    // one executor for the worker's lifetime: the pool behind cfg.ctx is
    // spawned once (at coordinator construction) and serves every sweep
    let exec = ExecConfig::with_ctx(cfg.ctx.clone()).with_kernel(cfg.kernel);

    while let Some(buf) = ep.recv() {
        match ToWorker::decode(&buf)? {
            ToWorker::Shutdown => break,
            ToWorker::SendZ => {
                let msg = ZReport { worker: cfg.id as u32, z: z.clone() };
                ep.send(msg.encode());
            }
            ToWorker::GetState => {
                // checkpoint capture: a pure read — touches no RNG, so a
                // checkpointed run stays bit-identical to an
                // uncheckpointed one
                let snap = WorkerSnapshot {
                    id: cfg.id as u32,
                    rng: rng.export_state(),
                    z: z.clone(),
                    last_tail: last_tail.clone(),
                };
                ep.send(snap.encode());
            }
            ToWorker::SetState(snap) => {
                // resume: the master validated shard shape before sending
                debug_assert_eq!(snap.z.n(), b_rows, "snapshot shard mismatch");
                rng = Pcg64::from_state(snap.rng);
                z = snap.z;
                // snapshots decode repr-agnostically; adopt this worker's
                // configured kernel (bit-invariant), so a scalar-written
                // checkpoint resumes cleanly under the packed kernel and
                // vice versa
                z.set_kernel(cfg.kernel);
                last_tail = snap.last_tail;
                if let Some(t) = last_tail.as_mut() {
                    t.set_kernel(cfg.kernel);
                }
                // one-byte ack keeps the master's recv loop lockstep
                // (deliberately non-empty: a zero-length frame is the
                // worker-abort sentinel)
                ep.send(vec![0xA5]);
            }
            ToWorker::Run(b) => {
                let summary =
                    run_iteration(cfg, &x, &mut z, &mut last_tail, &b, tr_xx,
                                  engine.as_ref(), &exec, &mut rng)?;
                ep.send(summary.encode());
            }
        }
    }
    Ok(())
}

/// Apply the broadcast's structural update, run L sub-iterations, build
/// the summary.
#[allow(clippy::too_many_arguments)]
fn run_iteration(
    cfg: &WorkerConfig,
    x: &Mat,
    z: &mut FeatureState,
    last_tail: &mut Option<FeatureState>,
    b: &Broadcast,
    tr_xx: f64,
    engine: Option<&Engine>,
    exec: &ExecConfig,
    rng: &mut Pcg64,
) -> Result<Summary> {
    let me = cfg.id as u32;
    let draws0 = rng.draw_count();
    // ---- structural update: global compaction + tail promotion +
    //      demotion of shard-local junk back into p′'s tail ----
    let tail_init = apply_structure(z, b, me, last_tail.take())?;

    // detlint:allow(wall-clock-in-chain): busy_s meters worker busy time for the virtual clock and obs report — no sampling decision reads it
    let start = Instant::now();
    let k_plus = z.k();
    debug_assert_eq!(k_plus, b.pi.len());
    let lg = LinGauss::new(b.sigma_x, b.sigma_a);
    let inv2s2 = 1.0 / (2.0 * b.sigma_x * b.sigma_x);
    let prior_logit: Vec<f64> = b
        .pi
        .iter()
        .map(|&p| {
            let p = p.clamp(1e-12, 1.0 - 1e-12);
            (p / (1.0 - p)).ln()
        })
        .collect();

    let i_am_p_prime = b.p_prime == me;
    // construction is cheap (no cache until a sweep) — the proposer just
    // carries the tail bits across the L sub-iterations
    let mut tp = TailProposer::new(tail_init, lg);
    // native path keeps the residual incrementally; PJRT recomputes it
    // inside the kernel (one MXU matmul per sweep)
    let mut resid = if engine.is_none() && k_plus > 0 {
        residuals(x, z, &b.a, 0..x.rows())
    } else {
        x.clone()
    };

    for _l in 0..cfg.sub_iters {
        if k_plus > 0 {
            let _sweep = obs::span(obs::Span::WorkerSweep);
            match engine {
                Some(eng) => {
                    let ops = Ops::new(eng);
                    resid = ops.zsweep(x, z, &b.a, &prior_logit, inv2s2, rng)?;
                }
                None => {
                    par_sweep_rows(
                        z, &mut resid, &b.a, &prior_logit, inv2s2,
                        0..x.rows(), k_plus, exec, rng,
                    );
                }
            }
        }
        if i_am_p_prime {
            let _tail = obs::span(obs::Span::WorkerTail);
            // the tail borrows the residual (== X when K⁺ = 0): nothing
            // is cloned in this hot loop any more
            tp.sweep(
                &resid,
                b.alpha,
                cfg.n_global,
                cfg.kmax_new,
                cfg.k_cap.saturating_sub(k_plus),
                rng,
            );
        }
    }
    let tail_carry = tp.take_tail();

    // ---- summary statistics over [K⁺ | K*_local] ----
    let stats_span = obs::span(obs::Span::WorkerSuffstats);
    let k_star = if i_am_p_prime { tail_carry.k() } else { 0 };
    let combined = combine(z, if i_am_p_prime { Some(&tail_carry) } else { None });
    let (ztz, ztx) = match engine {
        Some(eng) => Ops::new(eng).suffstats(&combined, x)?,
        // popcount gram / sparse ZᵀX under the packed kernel — bit-equal
        // to the dense products the scalar path computes
        None => (combined.gram(), combined.t_matmul(x)),
    };
    let m_local: Vec<u64> = z.m().iter().map(|&m| m as u64).collect();
    drop(stats_span);
    obs::add(
        obs::Counter::RngDrawsWorker,
        rng.draw_count().wrapping_sub(draws0),
    );
    let busy_s = start.elapsed().as_secs_f64();
    let tail = if i_am_p_prime && k_star > 0 {
        *last_tail = Some(tail_carry.clone());
        Some(tail_carry)
    } else {
        *last_tail = None;
        None
    };
    Ok(Summary {
        worker: me,
        iter: b.iter,
        m_local,
        ztz,
        ztx,
        tr_xx,
        tail,
        busy_s,
    })
}

/// Retain `keep` columns, then append `k_star` promoted columns (bits only
/// on the previous p′). Demoted columns are dropped from Z; on this
/// iteration's p′ their bits seed the returned tail state.
///
/// A broadcast that is structurally inconsistent with this worker's state
/// (promotion instruction without stored tail bits, or a tail of the
/// wrong width) is an `Err`, not a panic: the worker loop surfaces it and
/// the master's next `recv` reports the dead worker instead of the whole
/// process aborting.
fn apply_structure(
    z: &mut FeatureState,
    b: &Broadcast,
    me: u32,
    last_tail: Option<FeatureState>,
) -> Result<FeatureState> {
    // column selection in the previous local space; the rebuilt state
    // keeps the worker's storage kernel
    let rows = z.n();
    let kernel = z.kernel();
    let old = std::mem::replace(z, FeatureState::empty_with(rows, kernel));
    let mut next = FeatureState::empty_with(rows, kernel);
    next.add_features(b.keep.len() + b.k_star as usize);
    for (new_j, &old_j) in b.keep.iter().enumerate() {
        if old_j as usize >= old.k() {
            bail!(
                "worker {me}: broadcast keeps column {old_j} but local Z has \
                 only {} columns",
                old.k()
            );
        }
        for i in 0..rows {
            if old.get(i, old_j as usize) == 1 {
                next.set(i, new_j, 1);
            }
        }
    }
    if b.k_star > 0 && b.tail_owner == me {
        let Some(tail) = last_tail else {
            bail!(
                "worker {me}: broadcast promotes k_star={} tail features but \
                 this worker holds no tail bits from the previous iteration",
                b.k_star
            );
        };
        if tail.k() != b.k_star as usize {
            bail!(
                "worker {me}: broadcast promotes k_star={} but the stored \
                 tail has {} features",
                b.k_star,
                tail.k()
            );
        }
        let base = b.keep.len();
        for i in 0..rows {
            for j in 0..tail.k() {
                if tail.get(i, j) == 1 {
                    next.set(i, base + j, 1);
                }
            }
        }
    }
    // demotion: this iteration's p′ harvests the demoted columns' bits
    // into its initial tail; everyone else just dropped them (their local
    // counts are zero — the master only demotes shard-local features).
    let mut tail_init = FeatureState::empty_with(rows, kernel);
    if b.p_prime == me && !b.demote.is_empty() {
        tail_init.add_features(b.demote.len());
        for (tj, &old_j) in b.demote.iter().enumerate() {
            if old_j as usize >= old.k() {
                bail!(
                    "worker {me}: broadcast demotes column {old_j} but local \
                     Z has only {} columns",
                    old.k()
                );
            }
            for i in 0..rows {
                if old.get(i, old_j as usize) == 1 {
                    tail_init.set(i, tj, 1);
                }
            }
        }
        // columns that are empty on this shard (shouldn't happen) are
        // dropped by the tail sweep's compaction
    } else if !b.demote.is_empty() {
        debug_assert!(
            b.demote.iter().all(|&j| {
                (0..rows).all(|i| old.get(i, j as usize) == 0)
            }),
            "demoted feature has bits outside p′"
        );
    }
    *z = next;
    Ok(tail_init)
}

/// `[Z⁺ | Z*]` as one FeatureState (for suff-stats).
fn combine(z: &FeatureState, tail: Option<&FeatureState>) -> FeatureState {
    let mut c = z.clone();
    if let Some(t) = tail {
        let base = c.add_features(t.k());
        for i in 0..c.n() {
            for j in 0..t.k() {
                if t.get(i, j) == 1 {
                    c.set(i, base + j, 1);
                }
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bits(n: usize, pattern: &[(usize, usize)]) -> FeatureState {
        let k = pattern.iter().map(|&(_, j)| j + 1).max().unwrap_or(0);
        let mut st = FeatureState::empty(n);
        st.add_features(k);
        for &(i, j) in pattern {
            st.set(i, j, 1);
        }
        st
    }

    fn bcast(keep: Vec<u32>, k_star: u32, tail_owner: u32) -> Broadcast {
        Broadcast {
            iter: 0,
            a: Mat::zeros(0, 1),
            pi: vec![],
            sigma_x: 0.5,
            sigma_a: 1.0,
            alpha: 1.0,
            p_prime: 0,
            keep,
            k_star,
            tail_owner,
            demote: vec![],
        }
    }

    #[test]
    fn apply_structure_demotes_into_tail_on_p_prime() {
        // p_prime = 0 in bcast(); demote column 1 of a 3-col state
        let mut z = bits(4, &[(0, 0), (1, 1), (2, 2), (3, 1)]);
        let mut b = bcast(vec![0, 2], 0, 9);
        b.demote = vec![1];
        let tail = apply_structure(&mut z, &b, 0, None).unwrap();
        assert_eq!(z.k(), 2);
        assert_eq!(z.get(0, 0), 1);
        assert_eq!(z.get(2, 1), 1);
        assert_eq!(tail.k(), 1);
        assert_eq!(tail.get(1, 0), 1);
        assert_eq!(tail.get(3, 0), 1);
        assert_eq!(tail.m(), &[2]);
    }

    #[test]
    fn apply_structure_demote_dropped_on_others() {
        // worker 5 is not p_prime: demoted column must just vanish
        let mut z = bits(3, &[(0, 0)]);
        let mut b = bcast(vec![0], 0, 9);
        b.demote = vec![1];
        b.p_prime = 2;
        let tail = apply_structure(&mut z, &b, 5, None).unwrap();
        assert_eq!(z.k(), 1);
        assert_eq!(tail.k(), 0);
    }

    #[test]
    fn apply_structure_keeps_and_reorders() {
        let mut z = bits(3, &[(0, 0), (1, 1), (2, 2)]);
        apply_structure(&mut z, &bcast(vec![2, 0], 0, 9), 5, None).unwrap();
        assert_eq!(z.k(), 2);
        assert_eq!(z.get(2, 0), 1); // old col 2 → new col 0
        assert_eq!(z.get(0, 1), 1); // old col 0 → new col 1
        assert_eq!(z.m(), &[1, 1]);
        assert!(z.check_invariants());
    }

    #[test]
    fn apply_structure_promotes_tail_on_owner() {
        let mut z = bits(3, &[(0, 0)]);
        let tail = bits(3, &[(1, 0), (2, 1)]);
        apply_structure(&mut z, &bcast(vec![0], 2, 7), 7, Some(tail)).unwrap();
        assert_eq!(z.k(), 3);
        assert_eq!(z.get(1, 1), 1);
        assert_eq!(z.get(2, 2), 1);
        assert!(z.check_invariants());
    }

    #[test]
    fn apply_structure_zero_columns_on_non_owner() {
        let mut z = bits(3, &[(0, 0)]);
        apply_structure(&mut z, &bcast(vec![0], 2, 7), 3, None).unwrap();
        assert_eq!(z.k(), 3);
        assert_eq!(z.m(), &[1, 0, 0]);
    }

    #[test]
    fn worker_aborts_with_empty_sentinel_on_fatal_error() {
        use std::sync::mpsc::channel;
        let cfg = WorkerConfig {
            id: 3,
            n_global: 4,
            sub_iters: 1,
            ctx: ParallelCtx::inline(),
            kernel: Kernel::Scalar,
            kmax_new: 2,
            k_cap: 8,
            seed: 0,
            backend: Backend::Native,
            artifacts_dir: std::path::PathBuf::from("artifacts"),
        };
        let x = Mat::from_fn(4, 3, |i, j| (i + j) as f64);
        let (to_worker, rx) = channel::<Vec<u8>>();
        let (tx, from_worker) = channel::<(usize, Vec<u8>)>();
        let h = std::thread::spawn(move || run_worker(cfg, x, rx, tx));
        // bytes the wire decoder rejects → worker_loop errors → the
        // worker must ship the zero-length abort sentinel (so a P > 1
        // master errors out of its gather instead of hanging) and exit
        to_worker.send(vec![0xFF, 0xEE, 0xDD]).unwrap();
        let (id, buf) = from_worker.recv().unwrap();
        assert_eq!(id, 3);
        assert!(buf.is_empty(), "abort sentinel must be the empty frame");
        h.join().unwrap();
    }

    #[test]
    fn apply_structure_rejects_inconsistent_broadcasts() {
        // promotion instruction with no stored tail bits → Err, not panic
        let mut z = bits(3, &[(0, 0)]);
        let err = apply_structure(&mut z, &bcast(vec![0], 2, 7), 7, None)
            .unwrap_err()
            .to_string();
        assert!(err.contains("no tail bits"), "unexpected error: {err}");
        // stored tail of the wrong width → Err
        let mut z = bits(3, &[(0, 0)]);
        let tail = bits(3, &[(1, 0)]); // 1 feature, broadcast says 2
        let err = apply_structure(&mut z, &bcast(vec![0], 2, 7), 7, Some(tail))
            .unwrap_err()
            .to_string();
        assert!(err.contains("k_star=2"), "unexpected error: {err}");
        // keep referencing a column the local Z does not have → Err
        let mut z = bits(3, &[(0, 0)]);
        let err = apply_structure(&mut z, &bcast(vec![5], 0, 9), 1, None)
            .unwrap_err()
            .to_string();
        assert!(err.contains("column 5"), "unexpected error: {err}");
    }

    #[test]
    fn combine_appends_tail_block() {
        let z = bits(4, &[(0, 0), (3, 1)]);
        let t = bits(4, &[(2, 0)]);
        let c = combine(&z, Some(&t));
        assert_eq!(c.k(), 3);
        assert_eq!(c.get(2, 2), 1);
        assert_eq!(c.get(0, 0), 1);
        let c2 = combine(&z, None);
        assert_eq!(c2, z);
    }
}
