//! The parallel hybrid MCMC coordinator — the paper's system contribution.
//!
//! A star topology of P workers and one master, communicating via
//! byte-encoded messages over a pluggable transport (standing in for the
//! paper's MPI, with per-message sizes feeding a virtual-time model —
//! see DESIGN.md §Substitutions):
//!
//! * [`worker`] — shard-local uncollapsed sweeps over K⁺ (native or PJRT
//!   zsweep artifact) + the collapsed tail when elected p′;
//! * [`master`] — merge / promote / compact / resample / broadcast;
//! * [`messages`] — the wire format; [`vtime`] — the virtual clock;
//! * [`transport`] — how frames move: in-process channels (default,
//!   worker threads) or UDS/TCP sockets (real `pibp worker --connect`
//!   processes), bit-identical chains either way.
//!
//! The serial semantics oracle lives in `samplers::hybrid`; integration
//! tests pin this parallel implementation against it.

// Compiler-enforced twin of detlint rule R4 (no-panic-coordinator): deny
// `unwrap()` outside test builds. Proven-infallible sites carry a scoped
// `#[allow]` plus a detlint waiver with the proof. CI runs clippy with
// this lint promoted to blocking.
#![cfg_attr(not(test), deny(clippy::unwrap_used))]

pub mod master;
pub mod messages;
pub mod transport;
pub mod vtime;
pub mod worker;

pub use master::{Coordinator, CoordinatorConfig, IterRecord, MergedStats};
pub use transport::{run_remote_worker, Transport, TransportConfig};
pub use vtime::{IterTiming, VClock};
