//! Typed messages + explicit binary wire format.
//!
//! The paper distributes X and Z across processors over MPI and ships
//! "summary statistics" to a master each global iteration (§3, §5). This
//! repo's substitution (DESIGN.md §Substitutions) keeps the exact message
//! discipline but carries it over in-process channels; every message is
//! *actually encoded to bytes and decoded on receipt*, so per-message
//! sizes are real and feed the virtual-time communication model — the
//! overhead the paper's §5 worries about stays measurable.
//!
//! Wire format: little-endian, `u32` tags/lengths, `f64` payloads. The
//! in-process default needs no versioning (both ends are the same
//! binary); the socket transports (`super::transport`) carry these same
//! frames between *processes*, length-prefixed and opened by a versioned
//! hello/handshake, so a mismatched worker binary is a contextual error
//! at connect time rather than a garbage decode here. (The on-disk
//! checkpoint format in `crate::snapshot` reuses these `Writer`/`Reader`
//! primitives but adds magic/version/checksum, because files outlive
//! binaries.)

use anyhow::{bail, Context, Result};

use crate::linalg::Mat;
use crate::model::state::FeatureState;
use crate::snapshot::WorkerSnapshot;

/// Upper bound on any single wire frame (64 MiB — two orders of
/// magnitude above the largest message a big run produces). The socket
/// framing layer (`super::transport::frame`) validates every length
/// prefix against this *before* allocating, and the [`Reader`] validates
/// claimed element counts against the bytes actually present, so a
/// malformed, truncated, or adversarial frame off a socket yields a
/// contextual `Err` — never a huge allocation or a decode panic.
pub const MAX_FRAME: usize = 64 << 20;

/// Master → worker.
#[derive(Clone, Debug, PartialEq)]
pub enum ToWorker {
    /// Run one global iteration (L sub-iterations) with these params.
    Run(Broadcast),
    /// Send back the shard's current Z bits (final gathering / Fig 2).
    SendZ,
    /// Send back the full worker state (RNG stream, Z bits, pending tail)
    /// for a checkpoint — replied to with an encoded [`WorkerSnapshot`].
    GetState,
    /// Install a previously captured worker state (resume); the worker
    /// acknowledges with an empty message so the master can stay lockstep.
    SetState(WorkerSnapshot),
    Shutdown,
}

/// Worker → master, end of each iteration.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub worker: u32,
    pub iter: u32,
    /// Column counts over the shard for the K⁺ instantiated features.
    pub m_local: Vec<u64>,
    /// Shard-local ZᵀZ over [K⁺ | K*_local] columns (tail block only
    /// non-zero on p′).
    pub ztz: Mat,
    /// Shard-local ZᵀX, same column space.
    pub ztx: Mat,
    /// ‖X_p‖² (constant per shard; resent each iter — 8 bytes).
    pub tr_xx: f64,
    /// Tail assignments discovered this iteration (p′ only; rows = shard).
    pub tail: Option<FeatureState>,
    /// Seconds of compute this iteration (virtual-time input).
    pub busy_s: f64,
}

/// Worker → master, response to `SendZ`.
#[derive(Clone, Debug, PartialEq)]
pub struct ZReport {
    pub worker: u32,
    pub z: FeatureState,
}

/// The master's global-step output (paper: "Broadcast new parameters").
#[derive(Clone, Debug, PartialEq)]
pub struct Broadcast {
    pub iter: u32,
    /// Loadings for the K⁺ features *after* promotion+compaction (K⁺ × D).
    pub a: Mat,
    pub pi: Vec<f64>,
    pub sigma_x: f64,
    pub sigma_a: f64,
    pub alpha: f64,
    /// Which worker hosts the collapsed tail this iteration.
    pub p_prime: u32,
    /// Columns of the *previous* K⁺ set each worker must retain, in order
    /// (global compaction decision).
    pub keep: Vec<u32>,
    /// Number of freshly promoted tail features appended after `keep`
    /// (bits live only on `tail_owner` = previous p′).
    pub k_star: u32,
    pub tail_owner: u32,
    /// Columns of the previous K⁺ set DEMOTED into this iteration's p′
    /// tail: their entire global support lies inside p′'s shard and their
    /// count is small, so the master hands them back to the collapsed
    /// block where death moves are cheap (see DESIGN.md §Demotion).
    /// Non-p′ workers drop these columns (all-zero there by construction);
    /// p′ seeds its tail state with their bits.
    pub demote: Vec<u32>,
}

// ---------------------------------------------------------------------
// encoding primitives
// ---------------------------------------------------------------------

pub struct Writer {
    pub buf: Vec<u8>,
}

impl Writer {
    pub fn new() -> Self {
        Self { buf: Vec::with_capacity(256) }
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// 128-bit value as two explicit little-endian u64 halves (lo, hi) —
    /// the PCG state/increment width used by checkpoint RNG snapshots.
    pub fn u128(&mut self, v: u128) {
        self.u64(v as u64);
        self.u64((v >> 64) as u64);
    }

    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Length-prefixed UTF-8 string.
    pub fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
    }

    pub fn mat(&mut self, m: &Mat) {
        self.u32(m.rows() as u32);
        self.u32(m.cols() as u32);
        for &v in m.as_slice() {
            self.f64(v);
        }
    }

    /// Bit-packed binary matrix (8 bits/byte) — Z shards are large but
    /// binary, so this is the wire-efficiency the paper's §5 would want.
    pub fn bits(&mut self, st: &FeatureState) {
        self.u32(st.n() as u32);
        self.u32(st.k() as u32);
        let total = st.n() * st.k();
        let mut byte = 0u8;
        for idx in 0..total {
            let (i, j) = (idx / st.k().max(1), idx % st.k().max(1));
            if st.get(i, j) == 1 {
                byte |= 1 << (idx % 8);
            }
            if idx % 8 == 7 {
                self.buf.push(byte);
                byte = 0;
            }
        }
        if total % 8 != 0 {
            self.buf.push(byte);
        }
    }
}

impl Default for Writer {
    fn default() -> Self {
        Self::new()
    }
}

pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("wire underrun at {} (+{n} of {})", self.pos, self.buf.len());
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Bytes left in the frame — length headers are validated against
    /// this before any allocation sized from them.
    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    #[allow(clippy::unwrap_used)] // infallible: take(4) yields exactly 4 bytes
    pub fn u32(&mut self) -> Result<u32> {
        // detlint:allow(no-panic-coordinator): take(4) returned exactly 4 bytes, so the array conversion cannot fail
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    #[allow(clippy::unwrap_used)] // infallible: take(8) yields exactly 8 bytes
    pub fn u64(&mut self) -> Result<u64> {
        // detlint:allow(no-panic-coordinator): take(8) returned exactly 8 bytes, so the array conversion cannot fail
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn u128(&mut self) -> Result<u128> {
        let lo = self.u64()? as u128;
        let hi = self.u64()? as u128;
        Ok(lo | (hi << 64))
    }

    #[allow(clippy::unwrap_used)] // infallible: take(8) yields exactly 8 bytes
    pub fn f64(&mut self) -> Result<f64> {
        // detlint:allow(no-panic-coordinator): take(8) returned exactly 8 bytes, so the array conversion cannot fail
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn str(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        let bytes = self.take(len)?;
        String::from_utf8(bytes.to_vec()).map_err(|e| anyhow::anyhow!("bad utf-8 string: {e}"))
    }

    pub fn mat(&mut self) -> Result<Mat> {
        let rows = self.u32()? as usize;
        let cols = self.u32()? as usize;
        // validate the claimed element count against the bytes actually
        // present BEFORE allocating: a garbage header off a socket can
        // claim rows×cols near usize::MAX
        let elems = rows
            .checked_mul(cols)
            .filter(|&e| e.checked_mul(8).is_some_and(|b| b <= self.remaining()))
            .with_context(|| {
                format!(
                    "mat header claims {rows}×{cols} f64s but only {} bytes \
                     remain",
                    self.remaining()
                )
            })?;
        let mut data = Vec::with_capacity(elems);
        for _ in 0..elems {
            data.push(self.f64()?);
        }
        Ok(Mat::from_vec(rows, cols, data))
    }

    pub fn bits(&mut self) -> Result<FeatureState> {
        let n = self.u32()? as usize;
        let k = self.u32()? as usize;
        // overflow- and bounds-check the claimed bit count before the
        // n×k state allocation below
        let total = n.checked_mul(k).with_context(|| {
            format!("bits header claims {n}×{k} entries (overflows)")
        })?;
        if total.div_ceil(8) > self.remaining() {
            bail!(
                "bits header claims {n}×{k} entries ({} bytes) but only {} \
                 bytes remain",
                total.div_ceil(8),
                self.remaining()
            );
        }
        let bytes = self.take(total.div_ceil(8))?;
        let mut st = FeatureState::empty(n);
        st.add_features(k);
        for idx in 0..total {
            if bytes[idx / 8] & (1 << (idx % 8)) != 0 {
                st.set(idx / k, idx % k, 1);
            }
        }
        Ok(st)
    }

    pub fn done(&self) -> bool {
        self.pos == self.buf.len()
    }
}

// ---------------------------------------------------------------------
// message codecs
// ---------------------------------------------------------------------

const TAG_RUN: u32 = 1;
const TAG_SENDZ: u32 = 2;
const TAG_SHUTDOWN: u32 = 3;
const TAG_GETSTATE: u32 = 4;
const TAG_SETSTATE: u32 = 5;

impl ToWorker {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        match self {
            ToWorker::Run(b) => {
                w.u32(TAG_RUN);
                w.u32(b.iter);
                w.mat(&b.a);
                w.u32(b.pi.len() as u32);
                for &p in &b.pi {
                    w.f64(p);
                }
                w.f64(b.sigma_x);
                w.f64(b.sigma_a);
                w.f64(b.alpha);
                w.u32(b.p_prime);
                w.u32(b.keep.len() as u32);
                for &k in &b.keep {
                    w.u32(k);
                }
                w.u32(b.k_star);
                w.u32(b.tail_owner);
                w.u32(b.demote.len() as u32);
                for &k in &b.demote {
                    w.u32(k);
                }
            }
            ToWorker::SendZ => w.u32(TAG_SENDZ),
            ToWorker::GetState => w.u32(TAG_GETSTATE),
            ToWorker::SetState(ws) => {
                w.u32(TAG_SETSTATE);
                ws.encode_into(&mut w);
            }
            ToWorker::Shutdown => w.u32(TAG_SHUTDOWN),
        }
        w.buf
    }

    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut r = Reader::new(buf);
        let tag = r.u32()?;
        let msg = match tag {
            TAG_RUN => {
                let iter = r.u32()?;
                let a = r.mat()?;
                let np = r.u32()? as usize;
                let mut pi = Vec::with_capacity(np);
                for _ in 0..np {
                    pi.push(r.f64()?);
                }
                let sigma_x = r.f64()?;
                let sigma_a = r.f64()?;
                let alpha = r.f64()?;
                let p_prime = r.u32()?;
                let nk = r.u32()? as usize;
                let mut keep = Vec::with_capacity(nk);
                for _ in 0..nk {
                    keep.push(r.u32()?);
                }
                let k_star = r.u32()?;
                let tail_owner = r.u32()?;
                let nd = r.u32()? as usize;
                let mut demote = Vec::with_capacity(nd);
                for _ in 0..nd {
                    demote.push(r.u32()?);
                }
                ToWorker::Run(Broadcast {
                    iter, a, pi, sigma_x, sigma_a, alpha,
                    p_prime, keep, k_star, tail_owner, demote,
                })
            }
            TAG_SENDZ => ToWorker::SendZ,
            TAG_GETSTATE => ToWorker::GetState,
            TAG_SETSTATE => ToWorker::SetState(WorkerSnapshot::decode_from(&mut r)?),
            TAG_SHUTDOWN => ToWorker::Shutdown,
            t => bail!("bad ToWorker tag {t}"),
        };
        if !(r.done()) {
            bail!("trailing bytes in ToWorker");
        }
        Ok(msg)
    }
}

impl Summary {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.u32(self.worker);
        w.u32(self.iter);
        w.u32(self.m_local.len() as u32);
        for &m in &self.m_local {
            w.u64(m);
        }
        w.mat(&self.ztz);
        w.mat(&self.ztx);
        w.f64(self.tr_xx);
        match &self.tail {
            Some(t) => {
                w.u32(1);
                w.bits(t);
            }
            None => w.u32(0),
        }
        w.f64(self.busy_s);
        w.buf
    }

    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut r = Reader::new(buf);
        let worker = r.u32()?;
        let iter = r.u32()?;
        let nm = r.u32()? as usize;
        let mut m_local = Vec::with_capacity(nm);
        for _ in 0..nm {
            m_local.push(r.u64()?);
        }
        let ztz = r.mat()?;
        let ztx = r.mat()?;
        let tr_xx = r.f64()?;
        let tail = if r.u32()? == 1 { Some(r.bits()?) } else { None };
        let busy_s = r.f64()?;
        if !r.done() {
            bail!("trailing bytes in Summary");
        }
        Ok(Self { worker, iter, m_local, ztz, ztx, tr_xx, tail, busy_s })
    }
}

impl ZReport {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::new();
        w.u32(self.worker);
        w.bits(&self.z);
        w.buf
    }

    pub fn decode(buf: &[u8]) -> Result<Self> {
        let mut r = Reader::new(buf);
        let worker = r.u32()?;
        let z = r.bits()?;
        if !r.done() {
            bail!("trailing bytes in ZReport");
        }
        Ok(Self { worker, z })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state(n: usize, k: usize, seed: u64) -> FeatureState {
        let mut rng = crate::rng::Pcg64::new(seed);
        let mut st = FeatureState::empty(n);
        st.add_features(k);
        for i in 0..n {
            for j in 0..k {
                if rng.bernoulli(0.3) {
                    st.set(i, j, 1);
                }
            }
        }
        st
    }

    #[test]
    fn broadcast_roundtrip() {
        let msg = ToWorker::Run(Broadcast {
            iter: 7,
            a: Mat::from_fn(3, 4, |i, j| i as f64 - j as f64 * 0.5),
            pi: vec![0.1, 0.5, 0.9],
            sigma_x: 0.5,
            sigma_a: 1.25,
            alpha: 2.0,
            p_prime: 2,
            keep: vec![0, 2, 3],
            k_star: 2,
            tail_owner: 1,
            demote: vec![1, 4],
        });
        let back = ToWorker::decode(&msg.encode()).unwrap();
        assert_eq!(msg, back);
    }

    #[test]
    fn control_roundtrip() {
        for msg in [ToWorker::SendZ, ToWorker::GetState, ToWorker::Shutdown] {
            assert_eq!(ToWorker::decode(&msg.encode()).unwrap(), msg);
        }
    }

    #[test]
    fn set_state_roundtrip() {
        let rng = crate::rng::Pcg64::new(77).split(1003);
        for last_tail in [None, Some(state(9, 2, 5))] {
            let msg = ToWorker::SetState(WorkerSnapshot {
                id: 3,
                rng: rng.export_state(),
                z: state(9, 4, 4),
                last_tail,
            });
            assert_eq!(ToWorker::decode(&msg.encode()).unwrap(), msg);
        }
    }

    #[test]
    fn str_and_u128_primitives_roundtrip() {
        let mut w = Writer::new();
        w.str("pibp — checkpoint");
        w.u128(0x0123_4567_89ab_cdef_fedc_ba98_7654_3210u128);
        w.str("");
        let mut r = Reader::new(&w.buf);
        assert_eq!(r.str().unwrap(), "pibp — checkpoint");
        assert_eq!(r.u128().unwrap(), 0x0123_4567_89ab_cdef_fedc_ba98_7654_3210u128);
        assert_eq!(r.str().unwrap(), "");
        assert!(r.done());
    }

    #[test]
    fn summary_roundtrip_with_and_without_tail() {
        for tail in [None, Some(state(13, 5, 1))] {
            let msg = Summary {
                worker: 3,
                iter: 11,
                m_local: vec![5, 0, 9],
                ztz: Mat::from_fn(3, 3, |i, j| (i * 3 + j) as f64),
                ztx: Mat::from_fn(3, 6, |i, j| (i + j) as f64 * 0.25),
                tr_xx: 123.456,
                tail: tail.clone(),
                busy_s: 0.0125,
            };
            let back = Summary::decode(&msg.encode()).unwrap();
            assert_eq!(msg, back);
        }
    }

    #[test]
    fn zreport_roundtrip() {
        let msg = ZReport { worker: 0, z: state(37, 9, 2) };
        let back = ZReport::decode(&msg.encode()).unwrap();
        assert_eq!(msg, back);
    }

    #[test]
    fn bits_are_packed() {
        let st = state(100, 16, 3);
        let mut w = Writer::new();
        w.bits(&st);
        // 8 header bytes + ceil(1600/8) = 200 payload
        assert_eq!(w.buf.len(), 8 + 200);
    }

    #[test]
    fn truncated_messages_rejected() {
        let msg = Summary {
            worker: 1,
            iter: 2,
            m_local: vec![1],
            ztz: Mat::eye(1),
            ztx: Mat::zeros(1, 2),
            tr_xx: 1.0,
            tail: None,
            busy_s: 0.0,
        };
        let enc = msg.encode();
        for cut in [0, 3, 10, enc.len() - 1] {
            assert!(Summary::decode(&enc[..cut]).is_err(), "cut={cut}");
        }
        let mut extended = enc.clone();
        extended.push(0);
        assert!(Summary::decode(&extended).is_err());
    }

    #[test]
    fn garbage_mat_header_rejected_before_allocation() {
        // rows×cols×8 overflows usize → Err, no allocation attempted
        let mut w = Writer::new();
        w.u32(u32::MAX);
        w.u32(u32::MAX);
        assert!(Reader::new(&w.buf).mat().is_err());
        // claimed size merely exceeding the payload is also rejected
        let mut w = Writer::new();
        w.u32(1000);
        w.u32(1000);
        w.f64(0.5);
        let err = format!("{:#}", Reader::new(&w.buf).mat().unwrap_err());
        assert!(err.contains("bytes remain"), "{err}");
    }

    #[test]
    fn garbage_bits_header_rejected_before_allocation() {
        // n×k overflows usize
        let mut w = Writer::new();
        w.u32(u32::MAX);
        w.u32(u32::MAX);
        let err = format!("{:#}", Reader::new(&w.buf).bits().unwrap_err());
        assert!(err.contains("overflows"), "{err}");
        // header claims 64×64 bits, zero payload bytes follow
        let mut w = Writer::new();
        w.u32(64);
        w.u32(64);
        let err = format!("{:#}", Reader::new(&w.buf).bits().unwrap_err());
        assert!(err.contains("bytes remain"), "{err}");
    }

    #[test]
    fn real_messages_fit_far_under_max_frame() {
        // sanity-pin the bound: a generously sized Summary is still two
        // orders of magnitude below MAX_FRAME
        let msg = Summary {
            worker: 0,
            iter: 0,
            m_local: vec![3; 256],
            ztz: Mat::zeros(256, 256),
            ztx: Mat::zeros(256, 64),
            tr_xx: 1.0,
            tail: Some(state(512, 16, 9)),
            busy_s: 0.1,
        };
        let len = msg.encode().len();
        assert!(len < MAX_FRAME / 64, "{len} vs {MAX_FRAME}");
    }

    #[test]
    fn empty_featurestate_roundtrip() {
        let st = FeatureState::empty(5);
        let mut w = Writer::new();
        w.bits(&st);
        let mut r = Reader::new(&w.buf);
        let back = r.bits().unwrap();
        assert_eq!(back.n(), 5);
        assert_eq!(back.k(), 0);
    }
}
