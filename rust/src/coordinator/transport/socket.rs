//! Socket transports: real multi-process workers over a Unix domain
//! socket or TCP, plus the `pibp worker --connect` peer that runs the
//! worker loop against such a socket.
//!
//! ## Handshake (one per worker connection)
//!
//! ```text
//! worker → master   HELLO  frame: [magic u32][proto_version u32]
//! master → worker   SETUP  frame: [magic][proto][worker id][full worker
//!                   config][X shard][fnv1a over all preceding bytes]
//! ```
//!
//! A peer that is not a pibp worker (bad magic) or a mismatched binary
//! (different protocol version) is a contextual error at connection time,
//! not a garbage decode mid-run; the trailing checksum catches a
//! corrupted setup before a worker starts sampling from it. Worker ids
//! are assigned by the master in accept order — and since every
//! freshly-connected worker process is identical (it has no state until
//! SETUP arrives), the OS's accept order cannot affect the chain: shard
//! `i` and RNG stream `i` always go to whichever peer the master calls
//! worker `i`.
//!
//! ## Failure semantics
//!
//! Accept and connect poll with bounded retries (≈10 s) and then fail
//! with instructions, never hang. After the handshake the master's
//! per-connection reader thread converts EOF or any socket error into
//! the zero-length abort sentinel, so a worker process that dies mid-run
//! surfaces through `recv_from_all`'s existing taxonomy ("worker N
//! aborted…") within one gather, instead of blocking it forever.
//!
//! Deadlines here are *retry budgets* (attempt counts × a fixed poll
//! interval) and socket read timeouts — no wall-clock reads, so the
//! determinism linter's wall-clock census is untouched.

use std::io::{BufReader, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::config::Backend;
use crate::coordinator::messages::{Reader as WireReader, Writer as WireWriter};
use crate::coordinator::worker::{run_worker_on, WorkerConfig, WorkerEndpoint};
use crate::linalg::Mat;
use crate::model::state::Kernel;
use crate::parallel::ParallelCtx;
use crate::snapshot::fnv1a;

use super::frame::{read_frame, write_frame};
use super::{Transport, TransportConfig};

/// First word of every handshake frame ("PIBP").
pub const HELLO_MAGIC: u32 = 0x5049_4250;
/// Bumped whenever the wire format of any frame changes, so a stale
/// worker binary is told "mismatched pibp binaries" instead of
/// mis-decoding broadcasts.
pub const PROTO_VERSION: u32 = 1;

/// Fixed poll interval for bounded accept/connect retry loops.
const POLL: Duration = Duration::from_millis(25);
/// ≈10 s of accept polling before giving up on a missing worker.
const ACCEPT_ATTEMPTS: usize = 400;
/// ≈10 s of connect polling (workers usually start before the master
/// binds, so the first attempts legitimately fail).
const CONNECT_ATTEMPTS: usize = 400;
/// Read timeout while a handshake frame is outstanding.
const HANDSHAKE_TIMEOUT: Duration = Duration::from_secs(5);

// ---------------------------------------------------------------------
// streams + listeners (the only file that touches std::net / unix::net
// besides main.rs — detlint's net-outside-transport rule pins this)
// ---------------------------------------------------------------------

/// A connected duplex byte stream, UDS or TCP.
pub(crate) enum Stream {
    Tcp(TcpStream),
    Uds(UnixStream),
}

impl Stream {
    fn try_clone(&self) -> Result<Self> {
        Ok(match self {
            Self::Tcp(s) => Self::Tcp(s.try_clone().context("cloning tcp stream")?),
            Self::Uds(s) => Self::Uds(s.try_clone().context("cloning unix stream")?),
        })
    }

    /// Shut down both directions of the *underlying socket* (shared with
    /// every clone), so a reader blocked in `read_exact` wakes with EOF.
    fn shutdown_both(&self) {
        match self {
            Self::Tcp(s) => {
                let _ = s.shutdown(Shutdown::Both);
            }
            Self::Uds(s) => {
                let _ = s.shutdown(Shutdown::Both);
            }
        }
    }

    fn set_read_timeout(&self, d: Option<Duration>) -> Result<()> {
        match self {
            Self::Tcp(s) => s.set_read_timeout(d).context("tcp read timeout"),
            Self::Uds(s) => s.set_read_timeout(d).context("uds read timeout"),
        }
    }

    fn set_nonblocking(&self, nb: bool) -> Result<()> {
        match self {
            Self::Tcp(s) => s.set_nonblocking(nb).context("tcp nonblocking"),
            Self::Uds(s) => s.set_nonblocking(nb).context("uds nonblocking"),
        }
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Self::Tcp(s) => s.read(buf),
            Self::Uds(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Self::Tcp(s) => s.write(buf),
            Self::Uds(s) => s.write(buf),
        }
    }
    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Self::Tcp(s) => s.flush(),
            Self::Uds(s) => s.flush(),
        }
    }
}

enum Listener {
    Tcp(TcpListener),
    /// Keeps the bound path so `shutdown` can unlink it.
    Uds(UnixListener, PathBuf),
}

impl Listener {
    fn bind(cfg: &TransportConfig) -> Result<Self> {
        match cfg {
            TransportConfig::Channel => {
                bail!("the channel transport has no listener")
            }
            TransportConfig::Uds { listen } => {
                let path = PathBuf::from(listen);
                // a stale socket file from a crashed previous run would
                // make bind fail; it is dead (nothing accepts on it)
                let _ = std::fs::remove_file(&path);
                let l = UnixListener::bind(&path)
                    .with_context(|| format!("binding unix socket {listen}"))?;
                l.set_nonblocking(true).context("uds listener nonblocking")?;
                Ok(Self::Uds(l, path))
            }
            TransportConfig::Tcp { listen } => {
                let l = TcpListener::bind(listen)
                    .with_context(|| format!("binding tcp listener {listen}"))?;
                l.set_nonblocking(true).context("tcp listener nonblocking")?;
                Ok(Self::Tcp(l))
            }
        }
    }

    /// Accept one connection, polling for up to `ACCEPT_ATTEMPTS × POLL`.
    fn accept(&self, waiting_for: usize, total: usize) -> Result<Stream> {
        for _ in 0..ACCEPT_ATTEMPTS {
            let got = match self {
                Self::Tcp(l) => l.accept().map(|(s, _)| Stream::Tcp(s)),
                Self::Uds(l, _) => l.accept().map(|(s, _)| Stream::Uds(s)),
            };
            match got {
                Ok(s) => {
                    // accepted sockets do not inherit the listener's
                    // nonblocking flag on Linux, but make it explicit —
                    // the reader threads rely on blocking reads
                    s.set_nonblocking(false)?;
                    return Ok(s);
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(POLL);
                }
                Err(e) => return Err(e).context("accepting worker connection"),
            }
        }
        bail!(
            "timed out waiting for worker {}/{total} to connect — start \
             {total} `pibp worker --connect <addr>` processes pointed at \
             this run's listen address",
            waiting_for + 1,
        )
    }
}

// ---------------------------------------------------------------------
// handshake frames
// ---------------------------------------------------------------------

fn hello_frame() -> Vec<u8> {
    let mut w = WireWriter::new();
    w.u32(HELLO_MAGIC);
    w.u32(PROTO_VERSION);
    w.buf
}

fn check_hello(frame: &[u8]) -> Result<()> {
    let mut r = WireReader::new(frame);
    let magic = r.u32().context("hello frame too short")?;
    if magic != HELLO_MAGIC {
        bail!(
            "handshake failed: bad magic {magic:#010x} — is the peer a \
             pibp worker?"
        );
    }
    let proto = r.u32().context("hello frame too short")?;
    if proto != PROTO_VERSION {
        bail!(
            "handshake failed: peer speaks protocol v{proto}, this binary \
             speaks v{PROTO_VERSION} — mismatched pibp binaries"
        );
    }
    Ok(())
}

/// Everything a remote worker process needs to become worker `id` of a
/// run: the full static worker config plus its X shard. Sent once, right
/// after the hello, with a trailing fnv1a checksum so a corrupted setup
/// is rejected before any sampling happens.
pub struct WorkerSetup {
    pub id: usize,
    pub n_global: usize,
    pub sub_iters: usize,
    /// Intra-worker sweep threads T (the remote process builds its own
    /// pool; bit-invariant like every T).
    pub threads: usize,
    pub kernel: Kernel,
    pub kmax_new: usize,
    pub k_cap: usize,
    pub seed: u64,
    pub backend: Backend,
    pub artifacts_dir: PathBuf,
    pub x_shard: Mat,
}

impl WorkerSetup {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = WireWriter::new();
        w.u32(HELLO_MAGIC);
        w.u32(PROTO_VERSION);
        w.u32(self.id as u32);
        w.u64(self.n_global as u64);
        w.u64(self.sub_iters as u64);
        w.u64(self.threads as u64);
        w.str(self.kernel.name());
        w.u64(self.kmax_new as u64);
        w.u64(self.k_cap as u64);
        w.u64(self.seed);
        w.str(self.backend.name());
        w.str(&self.artifacts_dir.to_string_lossy());
        w.mat(&self.x_shard);
        let sum = fnv1a(&w.buf);
        w.u64(sum);
        w.buf
    }

    pub fn decode(frame: &[u8]) -> Result<Self> {
        if frame.len() < 8 {
            bail!("setup frame too short ({} bytes)", frame.len());
        }
        let (body, tail) = frame.split_at(frame.len() - 8);
        let mut r = WireReader::new(tail);
        let want = r.u64().context("setup checksum")?;
        let got = fnv1a(body);
        if want != got {
            bail!(
                "setup frame checksum mismatch (want {want:#018x}, got \
                 {got:#018x}) — corrupted handshake"
            );
        }
        let mut r = WireReader::new(body);
        check_hello(body)?;
        let _magic = r.u32()?;
        let _proto = r.u32()?;
        let id = r.u32()? as usize;
        let n_global = r.u64()? as usize;
        let sub_iters = r.u64()? as usize;
        let threads = r.u64()? as usize;
        let kernel = Kernel::parse(&r.str()?)?;
        let kmax_new = r.u64()? as usize;
        let k_cap = r.u64()? as usize;
        let seed = r.u64()?;
        let backend = Backend::parse(&r.str()?)?;
        let artifacts_dir = PathBuf::from(r.str()?);
        let x_shard = r.mat()?;
        if !r.done() {
            bail!("trailing bytes in setup frame");
        }
        Ok(Self {
            id, n_global, sub_iters, threads, kernel, kmax_new, k_cap,
            seed, backend, artifacts_dir, x_shard,
        })
    }
}

// ---------------------------------------------------------------------
// master side
// ---------------------------------------------------------------------

/// Master side of a socket transport: one framed writer per worker plus
/// one reader thread per connection funnelling `(id, frame)` into a
/// single queue — the same shape the channel transport has natively.
pub struct SocketTransport {
    writers: Vec<Stream>,
    inbound: Receiver<(usize, Vec<u8>)>,
    readers: Vec<JoinHandle<()>>,
    uds_path: Option<PathBuf>,
}

impl SocketTransport {
    /// Bind, accept one connection per setup, run the handshake on each,
    /// and ship the i-th accepted peer its `WorkerSetup` (= its identity).
    pub fn start(cfg: &TransportConfig, setups: Vec<WorkerSetup>) -> Result<Self> {
        let listener = Listener::bind(cfg)?;
        let total = setups.len();
        let (tx, inbound) = channel::<(usize, Vec<u8>)>();
        let mut writers = Vec::with_capacity(total);
        let mut readers = Vec::with_capacity(total);
        for (id, setup) in setups.into_iter().enumerate() {
            let mut stream = listener.accept(id, total)?;
            stream.set_read_timeout(Some(HANDSHAKE_TIMEOUT))?;
            let hello = read_frame(&mut stream)
                .with_context(|| format!("reading hello from worker {id}"))?;
            check_hello(&hello)
                .with_context(|| format!("worker {id} handshake"))?;
            write_frame(&mut stream, &setup.encode())
                .with_context(|| format!("sending setup to worker {id}"))?;
            stream.set_read_timeout(None)?;
            let reader_stream = stream.try_clone()?;
            let tx_r = tx.clone();
            readers.push(
                // detlint:allow(stray-thread): one reader per accepted worker socket — it only forwards frames into the master's inbound queue and exits on EOF, unblocked by shutdown()'s socket shutdown
                std::thread::Builder::new()
                    .name(format!("pibp-sock-reader-{id}"))
                    .spawn(move || reader_loop(id, reader_stream, tx_r))
                    .context("spawning socket reader")?,
            );
            writers.push(stream);
        }
        let uds_path = match cfg {
            TransportConfig::Uds { listen } => Some(PathBuf::from(listen)),
            _ => None,
        };
        Ok(Self { writers, inbound, readers, uds_path })
    }
}

/// Forward every frame from one worker socket into the shared inbound
/// queue. EOF or any socket error becomes the zero-length abort sentinel:
/// a killed worker process fails the master's gather with "worker N
/// aborted" context instead of hanging it.
fn reader_loop(id: usize, stream: Stream, tx: Sender<(usize, Vec<u8>)>) {
    let mut r = BufReader::new(stream);
    loop {
        match read_frame(&mut r) {
            Ok(frame) => {
                let aborted = frame.is_empty();
                if tx.send((id, frame)).is_err() || aborted {
                    // master gone, or the worker shipped its own abort
                    // sentinel (its next event is EOF anyway)
                    return;
                }
            }
            Err(_) => {
                tx.send((id, Vec::new())).ok();
                return;
            }
        }
    }
}

impl Transport for SocketTransport {
    fn send(&mut self, worker: usize, frame: &[u8]) -> Result<()> {
        let stream = self
            .writers
            .get_mut(worker)
            .with_context(|| format!("no worker {worker}"))?;
        write_frame(stream, frame)
            .with_context(|| format!("sending frame to worker {worker} (died?)"))
    }

    fn recv(&mut self) -> Result<(usize, Vec<u8>)> {
        self.inbound.recv().context("all worker sockets closed")
    }

    fn shutdown(&mut self) {
        // closing the shared underlying sockets wakes every reader thread
        // with EOF (any queued Shutdown frame has already been written)
        for w in &self.writers {
            w.shutdown_both();
        }
        self.writers.clear();
        for h in self.readers.drain(..) {
            let _ = h.join();
        }
        if let Some(p) = self.uds_path.take() {
            let _ = std::fs::remove_file(p);
        }
    }
}

// ---------------------------------------------------------------------
// worker side (`pibp worker --connect`)
// ---------------------------------------------------------------------

/// Interpret a `--connect` address: an explicit `uds:`/`tcp:` prefix
/// wins; otherwise anything containing `/` (or no `:`) is a socket path.
fn parse_addr(addr: &str) -> Result<TransportConfig> {
    if let Some(path) = addr.strip_prefix("uds:") {
        return TransportConfig::parse("uds", path);
    }
    if let Some(hostport) = addr.strip_prefix("tcp:") {
        return TransportConfig::parse("tcp", hostport);
    }
    if addr.contains('/') || !addr.contains(':') {
        TransportConfig::parse("uds", addr)
    } else {
        TransportConfig::parse("tcp", addr)
    }
}

/// Connect with bounded retry — workers are typically launched *before*
/// the master binds, so early refusals are expected.
fn connect(cfg: &TransportConfig, addr: &str) -> Result<Stream> {
    let mut last_err = None;
    for _ in 0..CONNECT_ATTEMPTS {
        let got = match cfg {
            TransportConfig::Uds { listen } => {
                UnixStream::connect(listen).map(Stream::Uds)
            }
            TransportConfig::Tcp { listen } => {
                TcpStream::connect(listen).map(Stream::Tcp)
            }
            TransportConfig::Channel => bail!("channel transport has no address"),
        };
        match got {
            Ok(s) => return Ok(s),
            Err(e) => {
                last_err = Some(e);
                std::thread::sleep(POLL);
            }
        }
    }
    Err(last_err
        .map(anyhow::Error::from)
        .unwrap_or_else(|| anyhow::anyhow!("no connect attempt made")))
    .with_context(|| {
        format!("could not connect to a pibp master at {addr} (is it running?)")
    })
}

/// The `pibp worker --connect <addr>` entry point: handshake, receive
/// this process's identity + shard, then run the standard worker loop
/// over the socket until the master sends Shutdown or the link drops.
pub fn run_remote_worker(addr: &str) -> Result<()> {
    let cfg = parse_addr(addr)?;
    let mut stream = connect(&cfg, addr)?;
    write_frame(&mut stream, &hello_frame()).context("sending hello")?;
    stream.set_read_timeout(Some(HANDSHAKE_TIMEOUT))?;
    let setup_frame = read_frame(&mut stream).context(
        "reading setup frame (master gone, or all worker slots taken?)",
    )?;
    let setup = WorkerSetup::decode(&setup_frame)?;
    stream.set_read_timeout(None)?;
    let wcfg = WorkerConfig {
        id: setup.id,
        n_global: setup.n_global,
        sub_iters: setup.sub_iters,
        // same pool policy as in-process workers: a native worker owns a
        // persistent pool, a PJRT worker sweeps inside the kernel
        ctx: match setup.backend {
            Backend::Native => ParallelCtx::pooled(setup.threads),
            Backend::Pjrt => ParallelCtx::inline(),
        },
        kernel: setup.kernel,
        kmax_new: setup.kmax_new,
        k_cap: setup.k_cap,
        seed: setup.seed,
        backend: setup.backend,
        artifacts_dir: setup.artifacts_dir,
    };
    let writer = stream.try_clone()?;
    let mut ep = SocketEndpoint::new(BufReader::new(stream), writer);
    eprintln!(
        "[pibp worker {}] connected to {addr} ({} rows, kernel={})",
        setup.id,
        setup.x_shard.rows(),
        wcfg.kernel.name(),
    );
    run_worker_on(wcfg, setup.x_shard, &mut ep);
    Ok(())
}

/// The worker half of a socket link: framed reads, best-effort writes —
/// the same contract `ChannelEndpoint` gives the in-process worker loop.
/// Lives here rather than in `worker.rs` so every socket syscall stays
/// inside `coordinator/transport/` (detlint's net-outside-transport).
pub(crate) struct SocketEndpoint {
    reader: BufReader<Stream>,
    writer: Stream,
}

impl SocketEndpoint {
    fn new(reader: BufReader<Stream>, writer: Stream) -> Self {
        Self { reader, writer }
    }
}

impl WorkerEndpoint for SocketEndpoint {
    /// `None` on EOF / socket error — the worker loop exits exactly as it
    /// does when an in-process channel closes.
    fn recv(&mut self) -> Option<Vec<u8>> {
        read_frame(&mut self.reader).ok()
    }

    /// Best-effort, like the channel `.send(..).ok()`: if the master is
    /// gone the next `recv` ends the loop.
    fn send(&mut self, frame: Vec<u8>) {
        let _ = write_frame(&mut self.writer, &frame);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup_fixture() -> WorkerSetup {
        WorkerSetup {
            id: 2,
            n_global: 100,
            sub_iters: 5,
            threads: 4,
            kernel: Kernel::Packed,
            kmax_new: 3,
            k_cap: 64,
            seed: 42,
            backend: Backend::Native,
            artifacts_dir: PathBuf::from("artifacts"),
            x_shard: Mat::from_fn(25, 4, |i, j| i as f64 - 0.25 * j as f64),
        }
    }

    #[test]
    fn setup_roundtrips_bit_exactly() {
        let s = setup_fixture();
        let back = WorkerSetup::decode(&s.encode()).unwrap();
        assert_eq!(back.id, s.id);
        assert_eq!(back.n_global, s.n_global);
        assert_eq!(back.sub_iters, s.sub_iters);
        assert_eq!(back.threads, s.threads);
        assert_eq!(back.kernel, s.kernel);
        assert_eq!(back.kmax_new, s.kmax_new);
        assert_eq!(back.k_cap, s.k_cap);
        assert_eq!(back.seed, s.seed);
        assert_eq!(back.backend, s.backend);
        assert_eq!(back.artifacts_dir, s.artifacts_dir);
        assert_eq!(back.x_shard.max_abs_diff(&s.x_shard), 0.0);
    }

    #[test]
    fn corrupted_setup_is_rejected_by_the_checksum() {
        let mut enc = setup_fixture().encode();
        let mid = enc.len() / 2;
        enc[mid] ^= 0x40;
        let err = WorkerSetup::decode(&enc).unwrap_err().to_string();
        assert!(err.contains("checksum"), "{err}");
    }

    #[test]
    fn hello_is_validated_magic_then_version() {
        check_hello(&hello_frame()).unwrap();

        let mut w = WireWriter::new();
        w.u32(0xdead_beef);
        w.u32(PROTO_VERSION);
        let err = check_hello(&w.buf).unwrap_err().to_string();
        assert!(err.contains("bad magic"), "{err}");

        let mut w = WireWriter::new();
        w.u32(HELLO_MAGIC);
        w.u32(PROTO_VERSION + 9);
        let err = check_hello(&w.buf).unwrap_err().to_string();
        assert!(err.contains("mismatched pibp binaries"), "{err}");
    }

    #[test]
    fn addresses_parse_with_and_without_scheme_prefixes() {
        let uds = |s| TransportConfig::Uds { listen: String::from(s) };
        let tcp = |s| TransportConfig::Tcp { listen: String::from(s) };
        assert_eq!(parse_addr("uds:/tmp/w.sock").unwrap(), uds("/tmp/w.sock"));
        assert_eq!(parse_addr("tcp:127.0.0.1:4242").unwrap(), tcp("127.0.0.1:4242"));
        assert_eq!(parse_addr("/tmp/w.sock").unwrap(), uds("/tmp/w.sock"));
        assert_eq!(parse_addr("relative.sock").unwrap(), uds("relative.sock"));
        assert_eq!(parse_addr("localhost:9000").unwrap(), tcp("localhost:9000"));
    }

    #[test]
    fn uds_handshake_and_frames_end_to_end() {
        // One master ↔ one remote endpoint over a real unix socketpair.
        let path = std::env::temp_dir()
            .join(format!("pibp_sock_test_{}.sock", std::process::id()));
        let cfg = TransportConfig::Uds {
            listen: path.to_string_lossy().into_owned(),
        };
        let cfg2 = cfg.clone();
        let worker = std::thread::spawn(move || -> Result<Vec<u8>> {
            let addr = match &cfg2 {
                TransportConfig::Uds { listen } => listen.clone(),
                _ => unreachable!(),
            };
            let mut s = connect(&cfg2, &addr)?;
            write_frame(&mut s, &hello_frame())?;
            let setup = WorkerSetup::decode(&read_frame(&mut s)?)?;
            assert_eq!(setup.id, 0);
            // echo one frame back, then send the abort sentinel
            let got = read_frame(&mut s)?;
            write_frame(&mut s, &got)?;
            write_frame(&mut s, &[])?;
            Ok(got)
        });
        let mut t = SocketTransport::start(&cfg, vec![WorkerSetup {
            id: 0,
            ..setup_fixture()
        }])
        .unwrap();
        t.send(0, b"ping-frame").unwrap();
        assert_eq!(t.recv().unwrap(), (0, b"ping-frame".to_vec()));
        // the worker's explicit abort sentinel arrives as an empty frame
        assert_eq!(t.recv().unwrap(), (0, Vec::new()));
        t.shutdown();
        assert_eq!(worker.join().unwrap().unwrap(), b"ping-frame");
        assert!(!path.exists(), "shutdown must unlink the uds path");
    }

    #[test]
    fn tcp_dead_peer_surfaces_as_the_abort_sentinel() {
        // Bind an ephemeral port, connect a raw peer, handshake, then
        // drop the peer without a sentinel: the reader must synthesise
        // one from EOF instead of leaving recv() blocked forever.
        let probe = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = probe.local_addr().unwrap().to_string();
        drop(probe);
        let cfg = TransportConfig::Tcp { listen: addr.clone() };
        let cfg2 = cfg.clone();
        let peer = std::thread::spawn(move || -> Result<()> {
            let mut s = connect(&cfg2, &addr)?;
            write_frame(&mut s, &hello_frame())?;
            let _setup = read_frame(&mut s)?;
            Ok(()) // stream drops here — simulated worker death
        });
        let mut t =
            SocketTransport::start(&cfg, vec![setup_fixture()]).unwrap();
        peer.join().unwrap().unwrap();
        assert_eq!(t.recv().unwrap(), (0, Vec::new()));
        t.shutdown();
    }

    #[test]
    fn non_worker_peer_fails_the_handshake_contextually() {
        let path = std::env::temp_dir()
            .join(format!("pibp_sock_badpeer_{}.sock", std::process::id()));
        let cfg = TransportConfig::Uds {
            listen: path.to_string_lossy().into_owned(),
        };
        let cfg2 = cfg.clone();
        let peer = std::thread::spawn(move || {
            let addr = match &cfg2 {
                TransportConfig::Uds { listen } => listen.clone(),
                _ => unreachable!(),
            };
            let mut s = connect(&cfg2, &addr).unwrap();
            // a length-prefixed frame that is not a hello
            write_frame(&mut s, &[9, 9, 9, 9, 9, 9, 9, 9]).unwrap();
            // hold the stream open until the master rejects us
            let _ = read_frame(&mut s);
        });
        let err = SocketTransport::start(&cfg, vec![setup_fixture()])
            .unwrap_err()
            .to_string();
        assert!(err.contains("handshake"), "{err}");
        peer.join().unwrap();
        let _ = std::fs::remove_file(&path);
    }
}
