//! The coordinator's message plane, abstracted: how bytes move between
//! the master and its P workers.
//!
//! The paper's hybrid sampler is an MPI algorithm — X and Z live on P
//! processors and only summary statistics travel each global iteration
//! (§3, §5). Everything above this module already speaks byte-encoded
//! frames (`super::messages`), so the *only* thing a transport decides is
//! delivery:
//!
//! | impl                        | medium                         | workers are…          |
//! |-----------------------------|--------------------------------|-----------------------|
//! | [`ChannelTransport`]        | in-process `std::sync::mpsc`   | threads (default)     |
//! | [`SocketTransport`] (`uds`) | Unix domain socket             | separate processes    |
//! | [`SocketTransport`] (`tcp`) | TCP loopback/network           | separate processes    |
//!
//! **The chain bytes must not depend on how bytes move.** Every frame is
//! produced and consumed by the same codecs regardless of transport, the
//! master assigns worker ids (and therefore RNG streams and shards) in
//! its own deterministic order, and virtual time is charged from frame
//! *sizes* via the `CommModel`, never from measured socket timing — so a
//! P-worker run over sockets is bit-identical to the same run in-process
//! (`rust/tests/process_equivalence.rs` pins this).
//!
//! Socket framing is length-prefixed (`frame`), opened by a versioned
//! hello/handshake (`socket`) so a mismatched peer is a contextual error,
//! not a garbage decode. A worker process that dies mid-run surfaces as
//! the zero-length abort sentinel (EOF ⇒ sentinel), which the master's
//! gather loop turns into a contextual error instead of hanging.

use anyhow::{bail, Result};

pub mod channel;
pub mod frame;
pub mod socket;

pub use channel::ChannelTransport;
pub use socket::{run_remote_worker, SocketTransport, WorkerSetup};

/// Which message plane a coordinator run uses. Parsed from the
/// `transport`/`listen` config keys; excluded from the resume
/// fingerprint (like `kernel` and `obs`) because it is bit-invariant.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum TransportConfig {
    /// In-process channels; the coordinator spawns its workers as
    /// threads. Zero-cost default — the pre-transport behaviour.
    Channel,
    /// Unix domain socket at this path; workers are separate
    /// `pibp worker --connect <path>` processes.
    Uds { listen: String },
    /// TCP socket at this `host:port`; workers are separate
    /// `pibp worker --connect <host:port>` processes.
    Tcp { listen: String },
}

impl Default for TransportConfig {
    fn default() -> Self {
        Self::Channel
    }
}

impl TransportConfig {
    /// Build from the `transport` / `listen` config keys.
    pub fn parse(kind: &str, listen: &str) -> Result<Self> {
        Ok(match kind {
            "channel" => Self::Channel,
            "uds" => {
                if listen.is_empty() {
                    bail!("transport=uds requires listen=<socket path>");
                }
                Self::Uds { listen: listen.to_string() }
            }
            "tcp" => {
                if listen.is_empty() {
                    bail!("transport=tcp requires listen=<host:port>");
                }
                Self::Tcp { listen: listen.to_string() }
            }
            other => bail!("unknown transport '{other}' (channel|uds|tcp)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Channel => "channel",
            Self::Uds { .. } => "uds",
            Self::Tcp { .. } => "tcp",
        }
    }
}

/// The master side of the message plane: P framed, ordered, reliable
/// duplex links, one per worker.
///
/// Contract shared by every implementation (what `master.rs` relies on):
/// * `send(p, frame)` delivers `frame` to worker `p` intact and in order,
///   or returns a contextual `Err` — never blocks forever;
/// * `recv()` yields the next `(worker id, frame)` from any worker; a
///   zero-length frame is the worker-abort sentinel. A worker whose link
///   dies (process killed, socket EOF, channel dropped) is surfaced as
///   that same sentinel or a contextual `Err` — never a silent hang;
/// * `shutdown()` is idempotent and best-effort: it releases threads,
///   sockets and any filesystem artifacts (UDS paths) without panicking.
pub trait Transport: Send {
    fn send(&mut self, worker: usize, frame: &[u8]) -> Result<()>;
    fn recv(&mut self) -> Result<(usize, Vec<u8>)>;
    fn shutdown(&mut self);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_the_three_kinds() {
        assert_eq!(TransportConfig::parse("channel", "").unwrap(), TransportConfig::Channel);
        assert_eq!(
            TransportConfig::parse("uds", "/tmp/x.sock").unwrap(),
            TransportConfig::Uds { listen: "/tmp/x.sock".into() }
        );
        assert_eq!(
            TransportConfig::parse("tcp", "127.0.0.1:7777").unwrap(),
            TransportConfig::Tcp { listen: "127.0.0.1:7777".into() }
        );
    }

    #[test]
    fn parse_rejects_missing_listen_and_unknown_kinds() {
        assert!(TransportConfig::parse("uds", "").is_err());
        assert!(TransportConfig::parse("tcp", "").is_err());
        let err = TransportConfig::parse("mpi", "").unwrap_err().to_string();
        assert!(err.contains("channel|uds|tcp"), "{err}");
    }

    #[test]
    fn names_roundtrip() {
        for (kind, listen) in [("channel", ""), ("uds", "/s"), ("tcp", "h:1")] {
            let t = TransportConfig::parse(kind, listen).unwrap();
            assert_eq!(t.name(), kind);
        }
    }
}
