//! The in-process transport: `std::sync::mpsc` channels to worker
//! threads, exactly the message plane the coordinator used before the
//! `Transport` abstraction existed. Zero-cost default — frames are moved,
//! not copied onto a wire.
//!
//! The coordinator itself spawns the worker threads (it is the
//! sanctioned `stray-thread` spawn site) and hands this transport the
//! channel ends plus the join handles; `shutdown()` joins them.

use std::sync::mpsc::{Receiver, Sender};
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use super::Transport;

pub struct ChannelTransport {
    to_workers: Vec<Sender<Vec<u8>>>,
    from_workers: Receiver<(usize, Vec<u8>)>,
    handles: Vec<JoinHandle<()>>,
}

impl ChannelTransport {
    pub fn new(
        to_workers: Vec<Sender<Vec<u8>>>,
        from_workers: Receiver<(usize, Vec<u8>)>,
        handles: Vec<JoinHandle<()>>,
    ) -> Self {
        Self { to_workers, from_workers, handles }
    }
}

impl Transport for ChannelTransport {
    fn send(&mut self, worker: usize, frame: &[u8]) -> Result<()> {
        self.to_workers
            .get(worker)
            .with_context(|| format!("no worker {worker}"))?
            .send(frame.to_vec())
            .context("worker channel closed")
    }

    fn recv(&mut self) -> Result<(usize, Vec<u8>)> {
        // A dead worker drops its sender; once all are gone recv() errs,
        // which the master reports as "worker died during <phase>".
        self.from_workers.recv().context("all worker channels closed")
    }

    fn shutdown(&mut self) {
        // Drop the senders first so any worker still blocked on recv()
        // sees a closed channel and exits its loop, then join.
        self.to_workers.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}
