//! Length-prefixed framing for socket transports.
//!
//! Wire layout of one frame: `u32 LE payload length` followed by that
//! many payload bytes. A zero-length frame is legal — it is the abort
//! sentinel the in-process channels already use. The length prefix is
//! validated against [`messages::MAX_FRAME`] *before* any allocation, so
//! a garbage or adversarial header yields a contextual `Err`, not a
//! multi-gigabyte `Vec` (satellite: frame hardening).

use std::io::{Read, Write};

use anyhow::{bail, Context, Result};

use crate::coordinator::messages::MAX_FRAME;

/// Write one length-prefixed frame and flush it.
pub fn write_frame<W: Write>(w: &mut W, frame: &[u8]) -> Result<()> {
    if frame.len() > MAX_FRAME {
        bail!(
            "refusing to send a {} byte frame (max {} bytes)",
            frame.len(),
            MAX_FRAME
        );
    }
    let len = frame.len() as u32;
    w.write_all(&len.to_le_bytes()).context("writing frame length")?;
    w.write_all(frame).context("writing frame payload")?;
    w.flush().context("flushing frame")?;
    Ok(())
}

/// Read one length-prefixed frame. EOF before the header is an `Err`
/// (callers translate it into the abort sentinel); a length above
/// `MAX_FRAME` is rejected before allocating.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Vec<u8>> {
    let mut hdr = [0u8; 4];
    r.read_exact(&mut hdr).context("reading frame length (peer closed?)")?;
    let len = u32::from_le_bytes(hdr) as usize;
    if len > MAX_FRAME {
        bail!(
            "frame length {len} exceeds the {MAX_FRAME} byte bound — \
             corrupt stream or mismatched peer"
        );
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf)
        .with_context(|| format!("reading {len} byte frame payload (peer closed?)"))?;
    Ok(buf)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn frames_roundtrip_including_the_empty_sentinel() {
        let mut wire = Vec::new();
        write_frame(&mut wire, b"hello").unwrap();
        write_frame(&mut wire, &[]).unwrap();
        write_frame(&mut wire, &[0xA5]).unwrap();
        let mut r = Cursor::new(wire);
        assert_eq!(read_frame(&mut r).unwrap(), b"hello");
        assert_eq!(read_frame(&mut r).unwrap(), Vec::<u8>::new());
        assert_eq!(read_frame(&mut r).unwrap(), vec![0xA5]);
        // Stream exhausted: the next read errors instead of spinning.
        assert!(read_frame(&mut r).is_err());
    }

    #[test]
    fn oversized_length_prefix_is_rejected_before_allocation() {
        // Header claims u32::MAX bytes; nothing follows. Must error on
        // the bound check, not attempt a 4 GiB allocation.
        let wire = u32::MAX.to_le_bytes().to_vec();
        let err = read_frame(&mut Cursor::new(wire)).unwrap_err().to_string();
        assert!(err.contains("exceeds"), "{err}");
    }

    #[test]
    fn truncated_payload_is_a_contextual_error() {
        let mut wire = Vec::new();
        write_frame(&mut wire, &[1, 2, 3, 4, 5, 6, 7, 8]).unwrap();
        wire.truncate(wire.len() - 3);
        let err = format!("{:#}", read_frame(&mut Cursor::new(wire)).unwrap_err());
        assert!(err.contains("frame payload"), "{err}");
    }

    #[test]
    fn oversized_send_is_refused() {
        struct NullSink;
        impl std::io::Write for NullSink {
            fn write(&mut self, b: &[u8]) -> std::io::Result<usize> {
                Ok(b.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        let big = vec![0u8; MAX_FRAME + 1];
        assert!(write_frame(&mut NullSink, &big).is_err());
    }
}
