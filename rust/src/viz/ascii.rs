//! ASCII rendering of feature tiles — Figure 2 in a terminal.

use crate::linalg::Mat;

const RAMP: &[u8] = b" .:-=+*#%@";

/// Render K features (rows of a K × D matrix, D a perfect square) as
/// side-by-side ASCII tiles.
pub fn render_features_ascii(features: &Mat) -> String {
    let k = features.rows();
    let d = features.cols();
    let side = (d as f64).sqrt().round() as usize;
    assert_eq!(side * side, d, "D must be a perfect square");
    if k == 0 {
        return String::from("(no features)\n");
    }
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for &v in features.as_slice() {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    let span = (hi - lo).max(1e-9);
    let mut out = String::new();
    for row in 0..side {
        for kk in 0..k {
            for col in 0..side {
                let v = features[(kk, row * side + col)];
                let idx = (((v - lo) / span) * (RAMP.len() - 1) as f64).round() as usize;
                let c = RAMP[idx.min(RAMP.len() - 1)] as char;
                // double width so tiles look square in a terminal
                out.push(c);
                out.push(c);
            }
            out.push_str("  ");
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_expected_shape() {
        let feats = Mat::from_fn(2, 9, |k, d| if (k + d) % 2 == 0 { 1.0 } else { 0.0 });
        let s = render_features_ascii(&feats);
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 3);
        // 2 tiles × (3 cells × 2 chars) + 2 gutters of 2 spaces
        assert!(lines[0].len() >= 2 * 6 + 2);
        assert!(s.contains('@') && s.contains(' '));
    }

    #[test]
    fn constant_features_do_not_panic() {
        let feats = Mat::zeros(1, 4);
        let s = render_features_ascii(&feats);
        assert!(!s.is_empty());
    }

    #[test]
    fn empty_ok() {
        assert!(render_features_ascii(&Mat::zeros(0, 9)).contains("no features"));
    }
}
