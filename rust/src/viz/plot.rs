//! ASCII line plots — Figure 1 in a terminal. Supports multiple series
//! with per-series glyphs and an optional log-scaled x axis (the paper
//! plots log time).

use crate::metrics::Trace;

const GLYPHS: &[char] = &['o', '+', 'x', '*', '#', '@'];

/// Render traces as an ASCII chart of heldout vs (log10) virtual time.
pub fn plot_traces(traces: &[&Trace], width: usize, height: usize, log_x: bool) -> String {
    let mut pts: Vec<(usize, f64, f64)> = Vec::new(); // (series, x, y)
    for (s, t) in traces.iter().enumerate() {
        for p in &t.points {
            let x = if log_x { p.vtime_s.max(1e-9).log10() } else { p.vtime_s };
            if x.is_finite() && p.heldout.is_finite() {
                pts.push((s, x, p.heldout));
            }
        }
    }
    if pts.is_empty() {
        return String::from("(no data)\n");
    }
    let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(_, x, y) in &pts {
        x0 = x0.min(x);
        x1 = x1.max(x);
        y0 = y0.min(y);
        y1 = y1.max(y);
    }
    if x1 - x0 < 1e-12 {
        x1 = x0 + 1.0;
    }
    if y1 - y0 < 1e-12 {
        y1 = y0 + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    for &(s, x, y) in &pts {
        let cx = (((x - x0) / (x1 - x0)) * (width - 1) as f64).round() as usize;
        let cy = (((y - y0) / (y1 - y0)) * (height - 1) as f64).round() as usize;
        let row = height - 1 - cy;
        grid[row][cx] = GLYPHS[s % GLYPHS.len()];
    }
    let mut out = String::new();
    out.push_str(&format!("{:>12.1} ┐\n", y1));
    for row in grid {
        out.push_str("             │");
        out.extend(row);
        out.push('\n');
    }
    out.push_str(&format!("{:>12.1} └{}\n", y0, "─".repeat(width)));
    out.push_str(&format!(
        "             {}{:<12.3}{}{:>12.3}\n",
        if log_x { "log10(s) " } else { "seconds " },
        x0,
        " ".repeat(width.saturating_sub(30)),
        x1
    ));
    for (s, t) in traces.iter().enumerate() {
        out.push_str(&format!("  {} = {}\n", GLYPHS[s % GLYPHS.len()], t.label));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::TracePoint;

    fn trace(label: &str, n: usize, offset: f64) -> Trace {
        let mut t = Trace::new(label);
        for i in 0..n {
            t.push(TracePoint {
                iter: i,
                vtime_s: 0.1 * (i + 1) as f64,
                wall_s: 0.0,
                heldout: offset + i as f64,
                k: 1,
                sigma_x: 0.5,
                alpha: 1.0,
            });
        }
        t
    }

    #[test]
    fn renders_all_series() {
        let a = trace("alpha", 20, -100.0);
        let b = trace("beta", 20, -90.0);
        let s = plot_traces(&[&a, &b], 60, 12, true);
        assert!(s.contains('o') && s.contains('+'));
        assert!(s.contains("alpha") && s.contains("beta"));
        assert!(s.lines().count() >= 14);
    }

    #[test]
    fn empty_ok() {
        assert!(plot_traces(&[], 40, 10, false).contains("no data"));
    }

    #[test]
    fn constant_series_no_panic() {
        let mut t = Trace::new("const");
        t.push(TracePoint {
            iter: 0, vtime_s: 1.0, wall_s: 0.0, heldout: -5.0,
            k: 1, sigma_x: 0.5, alpha: 1.0,
        });
        let s = plot_traces(&[&t], 40, 8, true);
        assert!(s.contains('o'));
    }
}
