//! Feature visualisation — reproduces the paper's Figure 2 (true features
//! vs posterior features as 6×6 images) as PGM files and ASCII art.

pub mod ascii;
pub mod pgm;
pub mod plot;

pub use ascii::render_features_ascii;
pub use plot::plot_traces;
pub use pgm::{save_feature_grid, write_pgm};
