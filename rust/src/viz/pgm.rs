//! Binary PGM (P5) output — dependency-free grayscale images.
//!
//! `save_feature_grid` lays a set of D-dimensional feature vectors out as
//! side-by-side √D×√D tiles with separators and upscaling — the exact
//! presentation of the paper's Figure 2 rows.

use std::io::Write as _;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::linalg::Mat;

/// Write a grayscale image (row-major, values clamped to [0,255]).
pub fn write_pgm(path: &Path, width: usize, height: usize, pixels: &[u8]) -> Result<()> {
    if pixels.len() != width * height {
        bail!("pixel buffer {} != {width}x{height}", pixels.len());
    }
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir).ok();
    }
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    write!(f, "P5\n{width} {height}\n255\n")?;
    f.write_all(pixels)?;
    Ok(())
}

/// Render each row of `features` (K × D, D a perfect square) as a tile,
/// normalised to the matrix's global [min, max], upscaled by `scale`,
/// separated by 1-pixel white gutters; write as one PGM strip.
pub fn save_feature_grid(path: &Path, features: &Mat, scale: usize) -> Result<()> {
    let k = features.rows();
    let d = features.cols();
    let side = (d as f64).sqrt().round() as usize;
    if side * side != d {
        bail!("D={d} is not a perfect square");
    }
    if k == 0 {
        bail!("no features to render");
    }
    let (mut lo, mut hi) = (f64::INFINITY, f64::NEG_INFINITY);
    for &v in features.as_slice() {
        lo = lo.min(v);
        hi = hi.max(v);
    }
    let span = (hi - lo).max(1e-9);
    let tile = side * scale;
    let width = k * tile + (k - 1);
    let height = tile;
    let mut pixels = vec![255u8; width * height];
    for kk in 0..k {
        let x0 = kk * (tile + 1);
        for py in 0..tile {
            for px in 0..tile {
                let v = features[(kk, (py / scale) * side + px / scale)];
                // dark = high intensity (feature "on"), like the paper
                let g = 255.0 - 255.0 * (v - lo) / span;
                pixels[py * width + x0 + px] = g.clamp(0.0, 255.0) as u8;
            }
        }
    }
    write_pgm(path, width, height, &pixels)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_valid_pgm() {
        let dir = std::env::temp_dir().join("pibp_pgm");
        let p = dir.join("t.pgm");
        write_pgm(&p, 4, 2, &[0, 64, 128, 255, 1, 2, 3, 4]).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        assert!(bytes.starts_with(b"P5\n4 2\n255\n"));
        assert_eq!(bytes.len(), 11 + 8);
    }

    #[test]
    fn grid_layout_dimensions() {
        let feats = Mat::from_fn(3, 36, |k, d| ((k + d) % 2) as f64);
        let dir = std::env::temp_dir().join("pibp_pgm");
        let p = dir.join("grid.pgm");
        save_feature_grid(&p, &feats, 4).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        // width = 3*24 + 2 = 74, height = 24
        let header = format!("P5\n{} {}\n255\n", 74, 24);
        assert!(bytes.starts_with(header.as_bytes()));
        assert_eq!(bytes.len(), header.len() + 74 * 24);
    }

    #[test]
    fn rejects_non_square() {
        let feats = Mat::zeros(2, 10);
        let p = std::env::temp_dir().join("pibp_pgm/bad.pgm");
        assert!(save_feature_grid(&p, &feats, 2).is_err());
    }
}
