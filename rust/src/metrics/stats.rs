//! Scalar summary statistics (mean / variance / quantiles) used by the
//! bench harness's result rows.

#[derive(Clone, Debug)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub median: f64,
    pub p90: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Self {
        assert!(!xs.is_empty(), "summary of empty slice");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n.max(2).saturating_sub(1) as f64;
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Self {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            median: quantile(&sorted, 0.5),
            p90: quantile(&sorted, 0.9),
            max: sorted[n - 1],
        }
    }
}

/// Linear-interpolated quantile of a pre-sorted slice.
pub fn quantile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty() && (0.0..=1.0).contains(&q));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_known_values() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.median - 3.0).abs() < 1e-12);
        assert!((s.std - 2.5f64.sqrt()).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn quantile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((quantile(&xs, 0.25) - 2.5).abs() < 1e-12);
        assert_eq!(quantile(&xs, 0.0), 0.0);
        assert_eq!(quantile(&xs, 1.0), 10.0);
        assert_eq!(quantile(&[7.0], 0.3), 7.0);
    }

    #[test]
    #[should_panic]
    fn empty_panics() {
        Summary::of(&[]);
    }
}
