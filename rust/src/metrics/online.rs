//! Streaming convergence estimators — the statistical half of the
//! observability story (the runtime half is [`crate::obs`]).
//!
//! `pibp run --chains C` feeds every kept [`TracePoint`] of every
//! replica chain into this module at trace cadence:
//!
//! * [`Welford`] — numerically stable running mean/variance;
//! * [`OnlineEss`] — bounded-lag online autocovariance giving an
//!   incremental Geyer ESS, O(lags) per point and O(lags) memory;
//! * [`OnlineRhat`] — incremental cross-chain split-R̂ from per-chain
//!   prefix sums, O(1) per point and O(chains) per query;
//! * [`StopRule`] — the parsed `--until "rhat<1.01,ess>200"` early-stop
//!   predicate;
//! * [`DiagState`] — the per-run aggregator (4 scalar quantities ×
//!   C chains) whose [`DiagSummary`] lands in the `diag` section of
//!   `run_obs.json`.
//!
//! All streamed values are shifted by the first value seen (`y = x −
//! c`) before accumulation, so the sum-of-products rearrangements the
//! online forms rely on do not catastrophically cancel when the scale
//! dwarfs the variance (held-out log-likelihoods sit in the −10³ range
//! while moving by single digits). The estimators are pinned to agree
//! with the batch [`ess`](crate::metrics::ess)/
//! [`split_rhat`](crate::metrics::split_rhat) on identical inputs to
//! ≤ 1e-12 relative error (unit tests here plus
//! `rust/tests/diag_equivalence.rs` on real traces). The only possible
//! divergence is the Geyer truncation decision when an autocorrelation
//! pair is exactly at zero — a measure-zero tie for continuous series.

use crate::config::json::Json;
use crate::metrics::trace::TracePoint;
use anyhow::{bail, Result};

/// Welford's running mean / variance (numerically stable one-pass
/// update; `m2` carries Σ(x − μ)² exactly in the recurrence).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn new() -> Self {
        Welford::default()
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        let delta2 = x - self.mean;
        self.m2 += delta * delta2;
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    /// Population (biased, ÷n) variance — matches the normalisation the
    /// batch ACF uses.
    pub fn var_biased(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Sample (÷(n−1)) variance.
    pub fn var_sample(&self) -> f64 {
        if self.n < 2 {
            f64::NAN
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
}

/// Incremental Geyer ESS over a stream, keeping only the first and last
/// `max_lag` (shifted) values plus one running lagged-product sum per
/// lag. `push` is O(min(max_lag, n)); `ess()` is O(max_lag).
///
/// With `max_lag ≥ n − 2` the estimate replicates the batch
/// [`ess`](crate::metrics::ess) exactly (same truncation, ≤ 1e-12
/// relative arithmetic difference); a smaller bound truncates the
/// Geyer scan at `max_lag`, which only matters for chains whose
/// autocorrelation survives past it (the estimate then errs high).
#[derive(Debug, Clone)]
pub struct OnlineEss {
    max_lag: usize,
    shift: f64,
    n: usize,
    sum: f64,
    sumsq: f64,
    /// first `max_lag` shifted values
    head: Vec<f64>,
    /// last `max_lag` shifted values, `ring[i % max_lag]` holding y_i
    ring: Vec<f64>,
    /// `lagsum[l-1]` = Σ_i y_i · y_{i+l}
    lagsum: Vec<f64>,
}

impl OnlineEss {
    pub fn new(max_lag: usize) -> Self {
        let max_lag = max_lag.max(1);
        OnlineEss {
            max_lag,
            shift: 0.0,
            n: 0,
            sum: 0.0,
            sumsq: 0.0,
            head: Vec::with_capacity(max_lag),
            ring: vec![0.0; max_lag],
            lagsum: vec![0.0; max_lag],
        }
    }

    pub fn push(&mut self, x: f64) {
        let y = if self.n == 0 {
            self.shift = x;
            0.0
        } else {
            x - self.shift
        };
        // update lagged products against the previous min(max_lag, n)
        // values *before* the ring slot for y_n is overwritten
        for l in 1..=self.max_lag.min(self.n) {
            self.lagsum[l - 1] += self.ring[(self.n - l) % self.max_lag] * y;
        }
        self.ring[self.n % self.max_lag] = y;
        if self.head.len() < self.max_lag {
            self.head.push(y);
        }
        self.sum += y;
        self.sumsq += y * y;
        self.n += 1;
    }

    pub fn count(&self) -> usize {
        self.n
    }

    /// True when the stream has no usable variance (fewer than two
    /// points, or all points equal) — callers skip such series when
    /// gating on ESS, since the batch estimator pins them to 1.
    pub fn is_degenerate(&self) -> bool {
        if self.n < 2 {
            return true;
        }
        let mu = self.sum / self.n as f64;
        self.sumsq - self.n as f64 * mu * mu <= 0.0
    }

    pub fn ess(&self) -> f64 {
        let n = self.n;
        if n < 4 {
            return n as f64;
        }
        let nf = n as f64;
        let mu = self.sum / nf;
        // Σ(y−μ)² = Σy² − nμ², i.e. the batch ACF's n·var normaliser
        let nvar = self.sumsq - nf * mu * mu;
        let max_lag = self.max_lag.min(n - 2);
        let mut tau = 1.0;
        let mut lag = 1;
        if nvar <= 0.0 {
            // constant series: rho ≡ 1, every Geyer pair adds 4
            while lag + 1 <= max_lag {
                tau += 4.0;
                lag += 2;
            }
            return (nf / tau).clamp(1.0, nf);
        }
        // prefix sums of the first / last max_lag values, so each
        // autocovariance query below is O(1)
        let mut headp = vec![0.0; max_lag + 1];
        for j in 1..=max_lag {
            headp[j] = headp[j - 1] + self.head[j - 1];
        }
        let mut tailp = vec![0.0; max_lag + 1];
        for j in 1..=max_lag {
            tailp[j] = tailp[j - 1] + self.ring[(n - j) % self.max_lag];
        }
        // Σ_{i<n−l} (y_i − μ)(y_{i+l} − μ)
        //   = lagsum[l−1] − μ·(pre + post) + (n−l)·μ²
        // with pre = Σ_{i<n−l} y_i = sum − tailp[l]
        // and post = Σ_{i≥l}  y_i = sum − headp[l]
        let acov = |l: usize| -> f64 {
            let pre = self.sum - tailp[l];
            let post = self.sum - headp[l];
            self.lagsum[l - 1] - mu * (pre + post) + (n - l) as f64 * mu * mu
        };
        while lag + 1 <= max_lag {
            let pair = (acov(lag) + acov(lag + 1)) / nvar;
            if pair <= 0.0 {
                break;
            }
            tau += 2.0 * pair;
            lag += 2;
        }
        (nf / tau).clamp(1.0, nf)
    }
}

/// Incremental cross-chain split-R̂: per-chain prefix sums of the
/// shifted values and their squares make any split mean/variance an
/// O(1) difference, so `rhat()` costs O(chains) at any point in the
/// stream. The shift is shared across chains (the first value pushed
/// overall), keeping between-chain mean differences exact.
///
/// Matches the batch [`split_rhat`](crate::metrics::split_rhat)
/// semantics: chains truncate to the min length, halves are
/// `[0, half)` and `[len−half, len)`, NaN below 2 chains or 4 points.
#[derive(Debug, Clone)]
pub struct OnlineRhat {
    shift: Option<f64>,
    /// per chain: prefix sums `ps[i] = Σ_{j<i} y_j` (len n+1), same
    /// for squares
    ps: Vec<Vec<f64>>,
    ps2: Vec<Vec<f64>>,
}

impl OnlineRhat {
    pub fn new(chains: usize) -> Self {
        OnlineRhat {
            shift: None,
            ps: vec![vec![0.0]; chains],
            ps2: vec![vec![0.0]; chains],
        }
    }

    pub fn push(&mut self, chain: usize, x: f64) {
        let shift = *self.shift.get_or_insert(x);
        let y = x - shift;
        let last = *self.ps[chain].last().unwrap();
        self.ps[chain].push(last + y);
        let last2 = *self.ps2[chain].last().unwrap();
        self.ps2[chain].push(last2 + y * y);
    }

    /// Points in the shortest chain.
    pub fn min_len(&self) -> usize {
        self.ps.iter().map(|p| p.len() - 1).min().unwrap_or(0)
    }

    pub fn rhat(&self) -> f64 {
        if self.ps.len() < 2 {
            return f64::NAN;
        }
        let len = self.min_len();
        if len < 4 {
            return f64::NAN;
        }
        let half = len / 2;
        let nf = half as f64;
        let mut means = Vec::with_capacity(self.ps.len() * 2);
        let mut vars = Vec::with_capacity(self.ps.len() * 2);
        for c in 0..self.ps.len() {
            for (a, b) in [(0usize, half), (len - half, len)] {
                let s = self.ps[c][b] - self.ps[c][a];
                let s2 = self.ps2[c][b] - self.ps2[c][a];
                let mu = s / nf;
                means.push(mu);
                vars.push((s2 - nf * mu * mu) / (nf - 1.0));
            }
        }
        let m = means.len() as f64;
        let grand = means.iter().sum::<f64>() / m;
        let b = nf / (m - 1.0)
            * means.iter().map(|mu| (mu - grand) * (mu - grand)).sum::<f64>();
        let w = vars.iter().sum::<f64>() / m;
        if w <= 0.0 {
            return if b <= 0.0 { 1.0 } else { f64::INFINITY };
        }
        let var_plus = (nf - 1.0) / nf * w + b / nf;
        (var_plus / w).sqrt()
    }
}

/// Parsed `--until` early-stop rule: comma-separated `rhat<X` / `ess>Y`
/// conditions, all of which must hold simultaneously.
#[derive(Debug, Clone, PartialEq)]
pub struct StopRule {
    pub rhat_lt: Option<f64>,
    pub ess_gt: Option<f64>,
}

impl StopRule {
    /// Parse `"rhat<1.01,ess>200"`. Empty input means no rule (Ok(None)).
    pub fn parse(s: &str) -> Result<Option<StopRule>> {
        let s = s.trim();
        if s.is_empty() {
            return Ok(None);
        }
        let mut rule = StopRule { rhat_lt: None, ess_gt: None };
        for part in s.split(',') {
            let part = part.trim();
            let (slot, value) = if let Some(v) = part.strip_prefix("rhat<") {
                (&mut rule.rhat_lt, v)
            } else if let Some(v) = part.strip_prefix("ess>") {
                (&mut rule.ess_gt, v)
            } else {
                bail!("unrecognised stop condition '{part}' (expected rhat<X or ess>Y)");
            };
            let x: f64 = match value.trim().parse() {
                Ok(x) => x,
                Err(_) => bail!("bad threshold in stop condition '{part}'"),
            };
            if !(x > 0.0) || !x.is_finite() {
                bail!("stop threshold must be a positive finite number, got '{part}'");
            }
            if slot.is_some() {
                bail!("duplicate stop condition '{part}'");
            }
            *slot = Some(x);
        }
        Ok(Some(rule))
    }
}

/// The four `TracePoint` scalars the diagnostics watch, in report order.
pub const DIAG_QUANTITIES: [&str; 4] = ["heldout", "alpha", "sigma_x", "k"];
/// `k` (integer-valued, often constant) is excluded from ESS gating.
const N_ESS_GATED: usize = 3;

fn quantity_values(p: &TracePoint) -> [f64; 4] {
    [p.heldout, p.alpha, p.sigma_x, p.k as f64]
}

/// Kept points a chain must accumulate before the stop rule can fire
/// (split-R̂ and the Geyer scan both need 4).
pub const MIN_STOP_POINTS: usize = 4;
/// Identical consecutive kept points before a chain is called stalled.
pub const STALL_WINDOW: usize = 8;

/// What `DiagState::observe` noticed about the chain at this point —
/// the caller turns these into `obs::warn_once` events (this module
/// stays free of the obs registry so the metrics layer has no
/// side-channel).
#[derive(Debug, Clone, Copy, Default)]
pub struct DiagEvent {
    /// A non-finite scalar appeared (first time for this chain).
    pub diverged: bool,
    /// The last [`STALL_WINDOW`] kept points were bit-identical
    /// (first time for this chain).
    pub stalled: bool,
}

/// Per-run aggregator: one [`OnlineEss`] per (chain, quantity), one
/// [`OnlineRhat`] per quantity, plus stall/divergence trackers.
pub struct DiagState {
    chains: usize,
    ess: Vec<[OnlineEss; 4]>,
    rhat: Vec<OnlineRhat>,
    counts: Vec<usize>,
    recent: Vec<Vec<(u64, usize)>>,
    stalled: Vec<bool>,
    diverged: Vec<bool>,
}

impl DiagState {
    pub fn new(chains: usize, max_lag: usize) -> Self {
        DiagState {
            chains,
            ess: (0..chains)
                .map(|_| std::array::from_fn(|_| OnlineEss::new(max_lag)))
                .collect(),
            rhat: (0..4).map(|_| OnlineRhat::new(chains)).collect(),
            counts: vec![0; chains],
            recent: vec![Vec::new(); chains],
            stalled: vec![false; chains],
            diverged: vec![false; chains],
        }
    }

    /// Feed one kept trace point of `chain`. Returns newly-crossed
    /// stall/divergence flags (each fires at most once per chain).
    pub fn observe(&mut self, chain: usize, p: &TracePoint) -> DiagEvent {
        let vals = quantity_values(p);
        for (q, v) in vals.iter().enumerate() {
            self.ess[chain][q].push(*v);
            self.rhat[q].push(chain, *v);
        }
        self.counts[chain] += 1;
        let mut ev = DiagEvent::default();
        if !(p.heldout.is_finite() && p.alpha.is_finite() && p.sigma_x.is_finite())
            && !self.diverged[chain]
        {
            self.diverged[chain] = true;
            ev.diverged = true;
        }
        let rec = &mut self.recent[chain];
        rec.push((p.heldout.to_bits(), p.k));
        if rec.len() > STALL_WINDOW {
            rec.remove(0);
        }
        if rec.len() == STALL_WINDOW
            && rec.iter().all(|e| *e == rec[0])
            && !self.stalled[chain]
        {
            self.stalled[chain] = true;
            ev.stalled = true;
        }
        ev
    }

    /// Kept points in the shortest chain (all equal under lockstep).
    pub fn points(&self) -> usize {
        self.counts.iter().copied().min().unwrap_or(0)
    }

    /// Deterministic early-stop predicate: every condition of `rule`
    /// must hold over every watched quantity. `rhat<` requires a
    /// *finite* split-R̂ below the bound for all four quantities (NaN —
    /// e.g. a single chain — never satisfies it); `ess>` gates the
    /// continuous quantities only, skipping chains whose series is
    /// constant so far (their batch ESS pins to 1 by construction).
    pub fn satisfied(&self, rule: &StopRule) -> bool {
        if self.points() < MIN_STOP_POINTS {
            return false;
        }
        if let Some(x) = rule.rhat_lt {
            for q in 0..DIAG_QUANTITIES.len() {
                let r = self.rhat[q].rhat();
                if !(r.is_finite() && r < x) {
                    return false;
                }
            }
        }
        if let Some(y) = rule.ess_gt {
            for q in 0..N_ESS_GATED {
                for c in 0..self.chains {
                    let e = &self.ess[c][q];
                    if e.is_degenerate() {
                        continue;
                    }
                    if !(e.ess() > y) {
                        return false;
                    }
                }
            }
        }
        true
    }

    pub fn summary(&self, until: &str, stopped_at: Option<usize>) -> DiagSummary {
        DiagSummary {
            chains: self.chains,
            points: self.points(),
            until: until.to_string(),
            stopped_at,
            rhat: (0..DIAG_QUANTITIES.len()).map(|q| self.rhat[q].rhat()).collect(),
            ess: (0..DIAG_QUANTITIES.len())
                .map(|q| (0..self.chains).map(|c| self.ess[c][q].ess()).collect())
                .collect(),
            stalled: self.stalled.clone(),
            diverged: self.diverged.clone(),
        }
    }
}

/// Snapshot of the diagnostics at some point in the run — what lands
/// in the `diag` section of `run_obs.json` and on stdout.
#[derive(Debug, Clone)]
pub struct DiagSummary {
    pub chains: usize,
    pub points: usize,
    pub until: String,
    /// Completed iterations when the stop rule fired — a standalone
    /// run with `iters` set to this value reproduces the stopped
    /// chains bit-for-bit.
    pub stopped_at: Option<usize>,
    /// Split-R̂ per quantity ([`DIAG_QUANTITIES`] order); NaN when
    /// unavailable.
    pub rhat: Vec<f64>,
    /// ESS per quantity per chain.
    pub ess: Vec<Vec<f64>>,
    pub stalled: Vec<bool>,
    pub diverged: Vec<bool>,
}

fn num_or_null(x: f64) -> Json {
    if x.is_finite() {
        Json::Num(x)
    } else {
        Json::Null
    }
}

impl DiagSummary {
    pub fn to_json(&self) -> Json {
        let quantities = DIAG_QUANTITIES
            .iter()
            .enumerate()
            .map(|(q, name)| {
                (
                    *name,
                    Json::obj(vec![
                        ("rhat", num_or_null(self.rhat[q])),
                        (
                            "ess",
                            Json::Arr(
                                self.ess[q].iter().map(|&e| num_or_null(e)).collect(),
                            ),
                        ),
                    ]),
                )
            })
            .collect();
        Json::obj(vec![
            ("chains", Json::Num(self.chains as f64)),
            ("points", Json::Num(self.points as f64)),
            ("until", Json::Str(self.until.clone())),
            (
                "stopped_at",
                self.stopped_at.map_or(Json::Null, |i| Json::Num(i as f64)),
            ),
            ("quantities", Json::obj(quantities)),
            (
                "stalled",
                Json::Arr(self.stalled.iter().map(|&b| Json::Bool(b)).collect()),
            ),
            (
                "diverged",
                Json::Arr(self.diverged.iter().map(|&b| Json::Bool(b)).collect()),
            ),
        ])
    }

    /// Human-readable verdict block (stdout after a `--chains` run).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "convergence diagnostics: {} chain(s) × {} kept point(s)\n",
            self.chains, self.points
        ));
        out.push_str(&format!(
            "  {:<10} {:>9}   {}\n",
            "quantity", "split-R̂", "ESS per chain"
        ));
        for (q, name) in DIAG_QUANTITIES.iter().enumerate() {
            let r = self.rhat[q];
            let rs = if r.is_finite() { format!("{r:.4}") } else { "n/a".to_string() };
            let es = self.ess[q]
                .iter()
                .map(|e| if e.is_finite() { format!("{e:.1}") } else { "n/a".into() })
                .collect::<Vec<_>>()
                .join(" ");
            out.push_str(&format!("  {name:<10} {rs:>9}   {es}\n"));
        }
        for c in 0..self.chains {
            if self.diverged[c] {
                out.push_str(&format!("  chain {c}: DIVERGED (non-finite scalar)\n"));
            } else if self.stalled[c] {
                out.push_str(&format!(
                    "  chain {c}: STALLED ({STALL_WINDOW} identical kept points)\n"
                ));
            }
        }
        if !self.until.is_empty() {
            match self.stopped_at {
                Some(i) => out.push_str(&format!(
                    "  early stop '{}' fired after {} iterations\n",
                    self.until, i
                )),
                None => out.push_str(&format!(
                    "  early stop '{}' not triggered\n",
                    self.until
                )),
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::{ess, split_rhat};
    use crate::rng::Pcg64;

    fn rel_err(a: f64, b: f64) -> f64 {
        (a - b).abs() / b.abs().max(1.0)
    }

    fn ar1(seed: u64, n: usize, phi: f64, offset: f64) -> Vec<f64> {
        let mut rng = Pcg64::new(seed);
        let mut xs = vec![offset; n];
        for i in 1..n {
            xs[i] = offset + phi * (xs[i - 1] - offset) + rng.normal();
        }
        xs
    }

    #[test]
    fn welford_matches_batch_moments() {
        let mut rng = Pcg64::new(11);
        let xs: Vec<f64> = (0..500).map(|_| 1e6 + rng.normal()).collect();
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let n = xs.len() as f64;
        let mean = xs.iter().sum::<f64>() / n;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
        assert!(rel_err(w.mean(), mean) < 1e-12, "{} vs {mean}", w.mean());
        assert!(
            (w.var_biased() - var).abs() / var.abs().max(1e-12) < 1e-9,
            "{} vs {var}",
            w.var_biased()
        );
        assert_eq!(w.count(), 500);
    }

    #[test]
    fn online_ess_matches_batch_on_full_lag() {
        let mut cases: Vec<Vec<f64>> = Vec::new();
        let mut rng = Pcg64::new(21);
        cases.push((0..200).map(|_| rng.normal()).collect());
        cases.push(ar1(22, 300, 0.9, 0.0));
        // heldout-scale offsets: large mean, small moves
        cases.push(ar1(23, 150, 0.8, -12345.6));
        cases.push((0..120).map(|i| (i % 2) as f64).collect());
        for xs in &cases {
            let mut o = OnlineEss::new(xs.len()); // ≥ n−2: full batch parity
            for &x in xs {
                o.push(x);
            }
            let b = ess(xs);
            assert!(
                rel_err(o.ess(), b) < 1e-12,
                "online {} vs batch {b} (n={})",
                o.ess(),
                xs.len()
            );
        }
    }

    #[test]
    fn online_ess_degenerate_and_short() {
        let mut o = OnlineEss::new(64);
        for _ in 0..50 {
            o.push(7.5);
        }
        assert_eq!(o.ess(), ess(&vec![7.5; 50]));
        assert_eq!(o.ess(), 1.0);
        assert!(o.is_degenerate());
        for len in 0..4usize {
            let mut o = OnlineEss::new(8);
            for i in 0..len {
                o.push(i as f64);
            }
            assert_eq!(o.ess(), len as f64);
        }
    }

    #[test]
    fn bounded_lag_truncates_but_stays_sane() {
        let xs = ar1(31, 800, 0.99, 0.0);
        let mut o = OnlineEss::new(8);
        for &x in &xs {
            o.push(x);
        }
        let e = o.ess();
        assert!(e >= 1.0 && e <= xs.len() as f64, "ess {e}");
        // a series whose Geyer scan stops before the bound is
        // unaffected by it: alternating data truncates at the first pair
        let alt: Vec<f64> = (0..200).map(|i| (i % 2) as f64).collect();
        let mut o = OnlineEss::new(8);
        for &x in &alt {
            o.push(x);
        }
        assert!(rel_err(o.ess(), ess(&alt)) < 1e-12);
    }

    #[test]
    fn online_rhat_matches_batch() {
        let chains: Vec<Vec<f64>> = (0..3)
            .map(|c| ar1(40 + c, 100, 0.7, -900.0 + 3.0 * c as f64))
            .collect();
        let mut o = OnlineRhat::new(3);
        for (c, xs) in chains.iter().enumerate() {
            for &x in xs {
                o.push(c, x);
            }
        }
        let b = split_rhat(&chains);
        assert!(rel_err(o.rhat(), b) < 1e-12, "online {} vs batch {b}", o.rhat());
    }

    #[test]
    fn online_rhat_unequal_lengths_truncate_like_batch() {
        let mut chains: Vec<Vec<f64>> = (0..2)
            .map(|c| ar1(50 + c, 60, 0.5, 10.0 * c as f64))
            .collect();
        chains[0].extend(ar1(99, 40, 0.5, 500.0)); // tail past min len
        let mut o = OnlineRhat::new(2);
        for (c, xs) in chains.iter().enumerate() {
            for &x in xs {
                o.push(c, x);
            }
        }
        assert_eq!(o.min_len(), 60);
        let b = split_rhat(&chains);
        assert!(rel_err(o.rhat(), b) < 1e-12, "online {} vs batch {b}", o.rhat());
    }

    #[test]
    fn online_rhat_degenerate() {
        let mut o = OnlineRhat::new(1);
        for i in 0..10 {
            o.push(0, i as f64);
        }
        assert!(o.rhat().is_nan(), "one chain → NaN");
        let mut o = OnlineRhat::new(2);
        o.push(0, 1.0);
        o.push(1, 2.0);
        assert!(o.rhat().is_nan(), "short chains → NaN");
        let mut o = OnlineRhat::new(2);
        for _ in 0..20 {
            o.push(0, 5.0);
            o.push(1, 5.0);
        }
        assert_eq!(o.rhat(), 1.0, "constant equal chains → exactly 1");
    }

    #[test]
    fn stop_rule_parses() {
        assert_eq!(StopRule::parse("").unwrap(), None);
        assert_eq!(StopRule::parse("   ").unwrap(), None);
        let r = StopRule::parse("rhat<1.01,ess>200").unwrap().unwrap();
        assert_eq!(r.rhat_lt, Some(1.01));
        assert_eq!(r.ess_gt, Some(200.0));
        let r = StopRule::parse(" ess>50 ").unwrap().unwrap();
        assert_eq!(r.rhat_lt, None);
        assert_eq!(r.ess_gt, Some(50.0));
        assert!(StopRule::parse("rhat>1.01").is_err());
        assert!(StopRule::parse("rhat<abc").is_err());
        assert!(StopRule::parse("rhat<-1").is_err());
        assert!(StopRule::parse("rhat<1.1,rhat<1.2").is_err());
        assert!(StopRule::parse("bogus").is_err());
    }

    fn tp(heldout: f64, k: usize, alpha: f64, sigma_x: f64) -> TracePoint {
        TracePoint {
            iter: 0,
            vtime_s: 0.0,
            wall_s: 0.0,
            heldout,
            k,
            sigma_x,
            alpha,
        }
    }

    #[test]
    fn diag_state_stall_and_divergence_fire_once() {
        let mut d = DiagState::new(1, 64);
        let mut stalls = 0;
        for _ in 0..STALL_WINDOW + 3 {
            let ev = d.observe(0, &tp(-100.0, 5, 1.0, 0.5));
            if ev.stalled {
                stalls += 1;
            }
        }
        assert_eq!(stalls, 1, "stall warning must fire exactly once");
        let ev = d.observe(0, &tp(f64::NAN, 5, 1.0, 0.5));
        assert!(ev.diverged);
        let ev = d.observe(0, &tp(f64::NAN, 5, 1.0, 0.5));
        assert!(!ev.diverged, "divergence warning must fire exactly once");
    }

    #[test]
    fn stop_rule_satisfaction() {
        // two identical, constant chains: R̂ = 1 exactly, all ESS
        // streams degenerate → both conditions pass once 4 points exist
        let rule = StopRule::parse("rhat<1.01,ess>200").unwrap().unwrap();
        let mut d = DiagState::new(2, 64);
        for i in 0..4 {
            for c in 0..2 {
                let ev = d.observe(c, &tp(-50.0, 3, 1.0, 0.5));
                let _ = ev;
            }
            if i < 3 {
                assert!(!d.satisfied(&rule), "needs {MIN_STOP_POINTS} points");
            }
        }
        assert!(d.satisfied(&rule));
        // a single chain can never satisfy an rhat condition
        let mut d = DiagState::new(1, 64);
        for _ in 0..10 {
            d.observe(0, &tp(-50.0, 3, 1.0, 0.5));
        }
        assert!(!d.satisfied(&rule));
        // varying chains gate on real ESS: 6 noisy points can't reach 200
        let rule = StopRule::parse("ess>200").unwrap().unwrap();
        let mut d = DiagState::new(2, 64);
        let mut rng = Pcg64::new(77);
        for _ in 0..6 {
            for c in 0..2 {
                d.observe(c, &tp(-50.0 + rng.normal(), 3, 1.0, 0.5));
            }
        }
        assert!(!d.satisfied(&rule), "ESS ≤ n < 200 must block the rule");
    }

    #[test]
    fn summary_json_shape() {
        let mut d = DiagState::new(2, 64);
        let mut rng = Pcg64::new(78);
        for _ in 0..8 {
            for c in 0..2 {
                d.observe(c, &tp(-50.0 + rng.normal(), 3, 1.0 + 0.1 * rng.normal(), 0.5));
            }
        }
        let s = d.summary("rhat<1.01", Some(42));
        let j = s.to_json();
        assert_eq!(j.get("chains").and_then(Json::as_usize), Some(2));
        assert_eq!(j.get("points").and_then(Json::as_usize), Some(8));
        assert_eq!(j.get("stopped_at").and_then(Json::as_usize), Some(42));
        assert_eq!(j.get("until").and_then(Json::as_str), Some("rhat<1.01"));
        let q = j.get("quantities").expect("quantities");
        for name in DIAG_QUANTITIES {
            let entry = q.get(name).unwrap_or_else(|| panic!("missing {name}"));
            assert_eq!(
                entry.get("ess").and_then(Json::as_arr).map(<[Json]>::len),
                Some(2)
            );
        }
        // text renders without panicking and mentions each quantity
        let text = s.render();
        for name in DIAG_QUANTITIES {
            assert!(text.contains(name), "render missing {name}: {text}");
        }
        // round-trips through the serialiser (NaN-free by construction)
        let parsed = Json::parse(&j.to_string()).expect("diag json parses");
        assert_eq!(parsed.get("chains").and_then(Json::as_usize), Some(2));
    }
}
