//! Autocorrelation and effective sample size — the mixing diagnostics
//! behind the "collapsed mixes better than uncollapsed" comparisons
//! (paper §2) and our T-S3 ablation tables.

/// Normalised autocorrelation function up to `max_lag` (biased estimator,
/// standard for ESS).
pub fn autocorrelation(xs: &[f64], max_lag: usize) -> Vec<f64> {
    let n = xs.len();
    assert!(n >= 2, "need at least 2 samples");
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var: f64 = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    if var == 0.0 {
        return vec![1.0; max_lag.min(n - 1) + 1];
    }
    (0..=max_lag.min(n - 1))
        .map(|lag| {
            let mut acc = 0.0;
            for i in 0..n - lag {
                acc += (xs[i] - mean) * (xs[i + lag] - mean);
            }
            acc / (n as f64 * var)
        })
        .collect()
}

/// Effective sample size via Geyer's initial positive sequence: sum
/// consecutive autocorrelation pairs until a pair goes non-positive.
///
/// Lags are computed one at a time, on demand — Geyer truncation
/// usually fires within a handful of pairs, so the cost is O(n·τ)
/// rather than the O(n²) of materialising `autocorrelation(xs, n-2)`
/// up front. The per-lag arithmetic is identical to
/// [`autocorrelation`]'s, so the result is bit-for-bit the same as a
/// Geyer scan over the full ACF
/// (`tests::incremental_ess_matches_full_scan_reference`).
pub fn ess(xs: &[f64]) -> f64 {
    let n = xs.len();
    if n < 4 {
        return n as f64;
    }
    let mean = xs.iter().sum::<f64>() / n as f64;
    let var: f64 = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
    // The full scan walked rho[1..n-1] (autocorrelation(xs, n-2) has
    // n-1 entries), i.e. pairs while lag + 1 <= n - 2.
    let max_lag = n - 2;
    let mut tau = 1.0; // integrated autocorrelation time ×2 accumulator
    let mut lag = 1;
    if var == 0.0 {
        // autocorrelation() reports rho ≡ 1 for a zero-variance series,
        // so every pair contributes 2·(1+1) without needing the data.
        while lag + 1 <= max_lag {
            tau += 4.0;
            lag += 2;
        }
        return (n as f64 / tau).clamp(1.0, n as f64);
    }
    let rho = |lag: usize| -> f64 {
        let mut acc = 0.0;
        for i in 0..n - lag {
            acc += (xs[i] - mean) * (xs[i + lag] - mean);
        }
        acc / (n as f64 * var)
    };
    while lag + 1 <= max_lag {
        let pair = rho(lag) + rho(lag + 1);
        if pair <= 0.0 {
            break;
        }
        tau += 2.0 * pair;
        lag += 2;
    }
    (n as f64 / tau).clamp(1.0, n as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn iid_has_full_ess() {
        let mut rng = Pcg64::new(1);
        let xs: Vec<f64> = (0..4000).map(|_| rng.normal()).collect();
        let e = ess(&xs);
        assert!(e > 2500.0, "iid ESS {e} should be near n");
    }

    #[test]
    fn ar1_reduces_ess() {
        // AR(1) with phi = 0.9 → ESS ≈ n (1-phi)/(1+phi) ≈ n/19
        let mut rng = Pcg64::new(2);
        let n = 8000;
        let mut xs = vec![0.0; n];
        for i in 1..n {
            xs[i] = 0.9 * xs[i - 1] + rng.normal();
        }
        let e = ess(&xs);
        let want = n as f64 / 19.0;
        assert!(e > want * 0.4 && e < want * 2.5, "ESS {e}, want ≈{want}");
    }

    #[test]
    fn acf_lag0_is_one() {
        let mut rng = Pcg64::new(3);
        let xs: Vec<f64> = (0..500).map(|_| rng.normal()).collect();
        let rho = autocorrelation(&xs, 10);
        assert!((rho[0] - 1.0).abs() < 1e-12);
        assert!(rho.len() == 11);
    }

    #[test]
    fn constant_series_degenerate() {
        let xs = vec![2.0; 100];
        assert_eq!(ess(&xs), 1.0);
    }

    #[test]
    fn acf_matches_hand_computed_values() {
        // xs = [1,2,3,4]: mean 2.5, biased var 1.25.
        //   rho(1) = (0.75 - 0.25 + 0.75) / (4 · 1.25) = 0.25
        //   rho(2) = (-0.75 - 0.75)       / (4 · 1.25) = -0.3
        let rho = autocorrelation(&[1.0, 2.0, 3.0, 4.0], 2);
        assert!((rho[0] - 1.0).abs() < 1e-12);
        assert!((rho[1] - 0.25).abs() < 1e-12, "rho1={}", rho[1]);
        assert!((rho[2] - (-0.3)).abs() < 1e-12, "rho2={}", rho[2]);
    }

    /// The pre-optimisation algorithm: full ACF up front, then the
    /// Geyer scan. Kept verbatim as the regression reference for the
    /// incremental rewrite.
    fn ess_reference(xs: &[f64]) -> f64 {
        let n = xs.len();
        if n < 4 {
            return n as f64;
        }
        let rho = autocorrelation(xs, n - 2);
        let mut tau = 1.0;
        let mut lag = 1;
        while lag + 1 < rho.len() {
            let pair = rho[lag] + rho[lag + 1];
            if pair <= 0.0 {
                break;
            }
            tau += 2.0 * pair;
            lag += 2;
        }
        (n as f64 / tau).clamp(1.0, n as f64)
    }

    #[test]
    fn incremental_ess_matches_full_scan_reference() {
        let mut rng = Pcg64::new(7);
        let mut cases: Vec<Vec<f64>> = Vec::new();
        // iid, two AR(1) strengths, constant, short, alternating, and an
        // integer-valued K⁺-like series
        cases.push((0..300).map(|_| rng.normal()).collect());
        for phi in [0.9, 0.99] {
            let mut xs = vec![0.0; 500];
            for i in 1..500 {
                xs[i] = phi * xs[i - 1] + rng.normal();
            }
            cases.push(xs);
        }
        cases.push(vec![3.25; 64]);
        cases.push(vec![1.0, 2.0, 3.0, 4.0]);
        cases.push(vec![1.0, 2.0, 3.0]);
        cases.push((0..100).map(|i| (i % 2) as f64).collect());
        cases.push((0..80).map(|i| ((i * 7) % 5) as f64).collect());
        for xs in &cases {
            assert_eq!(
                ess(xs).to_bits(),
                ess_reference(xs).to_bits(),
                "incremental ess diverged from reference on n={}",
                xs.len()
            );
        }
    }

    #[test]
    fn ess_matches_hand_computed_values() {
        // [1,2,3,4]: first Geyer pair rho(1)+rho(2) = 0.25 - 0.3 < 0, so
        // tau = 1 and ESS = n = 4.
        assert!((ess(&[1.0, 2.0, 3.0, 4.0]) - 4.0).abs() < 1e-12);
        // [1,1,2,2,3,3]: mean 2, biased var 2/3,
        //   rho(1) = 2/4 = 0.5, rho(2) = 0, pair = 0.5 > 0 → tau = 2,
        //   rho(3) = -0.25, rho(4) = -0.5, pair < 0 → stop.
        // ESS = 6 / 2 = 3.
        assert!((ess(&[1.0, 1.0, 2.0, 2.0, 3.0, 3.0]) - 3.0).abs() < 1e-12);
    }
}
