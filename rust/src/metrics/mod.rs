//! Run metrics: convergence traces (the Figure-1 series), summary
//! statistics, autocorrelation / effective sample size, and CSV/JSON
//! export for the bench harness.

pub mod ess;
pub mod rhat;
pub mod stats;
pub mod trace;

pub use ess::{autocorrelation, ess};
pub use rhat::split_rhat;
pub use stats::Summary;
pub use trace::{Trace, TracePoint};
