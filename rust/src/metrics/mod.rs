//! Run metrics: convergence traces (the Figure-1 series), summary
//! statistics, autocorrelation / effective sample size, streaming
//! convergence estimators (`pibp run --chains` / `pibp diagnose`),
//! and CSV/JSON export for the bench harness.

pub mod ess;
pub mod online;
pub mod rhat;
pub mod stats;
pub mod trace;

pub use ess::{autocorrelation, ess};
pub use online::{
    DiagEvent, DiagState, DiagSummary, OnlineEss, OnlineRhat, StopRule, Welford,
    DIAG_QUANTITIES,
};
pub use rhat::split_rhat;
pub use stats::Summary;
pub use trace::{Trace, TracePoint};
