//! Gelman–Rubin potential scale reduction factor (R̂) — the multi-chain
//! convergence diagnostic behind `pibp run --chains C` (streamed, via
//! `metrics::online`), the offline `pibp diagnose` verdict, and the
//! diagnostics example. Split-R̂ per BDA3: each chain is halved, so
//! within-chain non-stationarity also inflates the statistic.

/// Split-R̂ over ≥ 2 chains of ≥ 4 samples each.
///
/// Unequal-length chains are truncated to the shortest length `len`
/// before splitting: every chain contributes its halves
/// `[0, len/2)` and `[len − len/2, len)`, so samples beyond `len` are
/// ignored entirely. Returns NaN for degenerate input — fewer than two
/// chains, or any chain (after truncation) shorter than 4, which
/// includes an empty chain.
pub fn split_rhat(chains: &[Vec<f64>]) -> f64 {
    if chains.len() < 2 {
        return f64::NAN;
    }
    let len = match chains.iter().map(Vec::len).min() {
        Some(l) if l >= 4 => l,
        _ => return f64::NAN, // an empty or too-short chain can't be split
    };
    let half = len / 2;
    // split every chain into two halves of length `half`, both taken
    // from the truncated prefix [0, len)
    let mut splits: Vec<&[f64]> = Vec::with_capacity(chains.len() * 2);
    for c in chains {
        splits.push(&c[..half]);
        splits.push(&c[len - half..len]);
    }
    let m = splits.len() as f64;
    let n = half as f64;
    let means: Vec<f64> = splits.iter().map(|s| mean(s)).collect();
    let grand = mean(&means);
    // between-chain variance
    let b = n / (m - 1.0)
        * means.iter().map(|mu| (mu - grand) * (mu - grand)).sum::<f64>();
    // within-chain variance
    let w = splits
        .iter()
        .zip(&means)
        .map(|(s, mu)| {
            s.iter().map(|x| (x - mu) * (x - mu)).sum::<f64>() / (n - 1.0)
        })
        .sum::<f64>()
        / m;
    if w <= 0.0 {
        return if b <= 0.0 { 1.0 } else { f64::INFINITY };
    }
    let var_plus = (n - 1.0) / n * w + b / n;
    (var_plus / w).sqrt()
}

fn mean(xs: &[f64]) -> f64 {
    xs.iter().sum::<f64>() / xs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn identical_distributions_give_rhat_near_one() {
        let mut rng = Pcg64::new(1);
        let chains: Vec<Vec<f64>> = (0..4)
            .map(|_| (0..2000).map(|_| rng.normal()).collect())
            .collect();
        let r = split_rhat(&chains);
        assert!((r - 1.0).abs() < 0.02, "R̂={r}");
    }

    #[test]
    fn shifted_chains_give_large_rhat() {
        let mut rng = Pcg64::new(2);
        let chains: Vec<Vec<f64>> = (0..4)
            .map(|c| (0..500).map(|_| rng.normal() + 3.0 * c as f64).collect())
            .collect();
        let r = split_rhat(&chains);
        assert!(r > 2.0, "R̂={r} should flag disagreement");
    }

    #[test]
    fn trending_chain_flagged_by_split() {
        // both chains trend identically — plain R̂ would miss it, split-R̂
        // must flag it
        let chains: Vec<Vec<f64>> = (0..2)
            .map(|_| (0..1000).map(|i| i as f64 * 0.01).collect())
            .collect();
        let r = split_rhat(&chains);
        assert!(r > 1.5, "R̂={r} should flag trends");
    }

    #[test]
    fn degenerate_inputs() {
        assert!(split_rhat(&[vec![1.0, 2.0, 3.0, 4.0]]).is_nan());
        assert!(split_rhat(&[vec![1.0], vec![2.0]]).is_nan());
        let r = split_rhat(&[vec![5.0; 100], vec![5.0; 100]]);
        assert_eq!(r, 1.0);
    }

    #[test]
    fn empty_chain_gives_nan() {
        assert!(split_rhat(&[]).is_nan());
        assert!(split_rhat(&[vec![], vec![1.0, 2.0, 3.0, 4.0]]).is_nan());
        assert!(split_rhat(&[vec![1.0, 2.0, 3.0, 4.0], vec![]]).is_nan());
    }

    #[test]
    fn unequal_lengths_truncate_to_min() {
        // the longer chain's tail beyond the min length must be ignored:
        // appending wild values to one chain changes nothing
        let base = vec![vec![1.0, 2.0, 1.0, 2.0], vec![3.0, 4.0, 3.0, 4.0]];
        let mut longer = base.clone();
        longer[0].extend_from_slice(&[900.0, -900.0, 1e6]);
        let r_base = split_rhat(&base);
        let r_long = split_rhat(&longer);
        assert_eq!(
            r_long.to_bits(),
            r_base.to_bits(),
            "truncation must drop the long chain's tail: {r_long} vs {r_base}"
        );
        assert!((r_base - (19.0f64 / 6.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn matches_hand_computed_value() {
        // chains [[1,2,1,2], [3,4,3,4]], len 4 → halves of length 2:
        //   splits [1,2],[1,2],[3,4],[3,4]; means 1.5,1.5,3.5,3.5; grand 2.5
        //   B = n/(m−1)·Σ(μ−ḡ)² = 2/3·(1+1+1+1) = 8/3
        //   W = mean of within-vars = 0.5
        //   var⁺ = (n−1)/n·W + B/n = 0.25 + 4/3 = 19/12
        //   R̂ = sqrt(var⁺/W) = sqrt(19/6)
        let r = split_rhat(&[vec![1.0, 2.0, 1.0, 2.0], vec![3.0, 4.0, 3.0, 4.0]]);
        assert!(
            (r - (19.0f64 / 6.0).sqrt()).abs() < 1e-12,
            "R̂={r}, want sqrt(19/6)={}",
            (19.0f64 / 6.0).sqrt()
        );
    }
}
