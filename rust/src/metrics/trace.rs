//! Convergence traces: (time, metric) series — what the paper's Figure 1
//! plots (held-out joint log P(X,Z) over log time).

use std::fmt::Write as _;
use std::path::Path;

use anyhow::{Context, Result};

use crate::config::json::Json;

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TracePoint {
    pub iter: usize,
    /// Virtual-time seconds since the run started (Figure-1 x-axis).
    pub vtime_s: f64,
    /// Wall-clock seconds since the run started.
    pub wall_s: f64,
    /// Held-out joint log P(X, Z) (Figure-1 y-axis).
    pub heldout: f64,
    pub k: usize,
    pub sigma_x: f64,
    pub alpha: f64,
}

#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub label: String,
    pub points: Vec<TracePoint>,
    /// Keep-every-k thinning stride over *offered* points (1 = keep all).
    /// Long checkpointed chains record thousands of evaluations; thinning
    /// bounds trace memory without skewing the kept schedule. 0 is
    /// treated as 1 so `Trace::default()` keeps everything.
    thin_stride: usize,
    /// Points offered to `push` so far (kept or not) — part of the
    /// thinning schedule, persisted across checkpoint/resume.
    seen: usize,
}

impl Trace {
    pub fn new(label: impl Into<String>) -> Self {
        Self { label: label.into(), points: Vec::new(), thin_stride: 1, seen: 0 }
    }

    /// Keep only every `stride`-th offered point from now on (the 1st,
    /// `stride+1`-th, … of the offered sequence). `stride ≤ 1` keeps all.
    pub fn set_thinning(&mut self, stride: usize) {
        self.thin_stride = stride.max(1);
    }

    /// (stride, offered-count) — checkpoint serialisation hook.
    pub fn thinning(&self) -> (usize, usize) {
        (self.thin_stride.max(1), self.seen)
    }

    /// Restore the thinning schedule from a checkpoint.
    pub fn restore_thinning(&mut self, stride: usize, seen: usize) {
        self.thin_stride = stride.max(1);
        self.seen = seen;
    }

    /// Offer a point to the trace; returns whether the thinning
    /// schedule kept it (the streaming diagnostics observe exactly the
    /// kept points, so they follow this return value).
    pub fn push(&mut self, p: TracePoint) -> bool {
        let keep = self.seen % self.thin_stride.max(1) == 0;
        self.seen += 1;
        if keep {
            self.points.push(p);
        }
        keep
    }

    pub fn last(&self) -> Option<&TracePoint> {
        self.points.last()
    }

    /// Mean of the final `frac` fraction of held-out values (plateau).
    pub fn plateau(&self, frac: f64) -> f64 {
        if self.points.is_empty() {
            return f64::NAN;
        }
        let start = ((1.0 - frac) * self.points.len() as f64) as usize;
        let tail = &self.points[start.min(self.points.len() - 1)..];
        tail.iter().map(|p| p.heldout).sum::<f64>() / tail.len() as f64
    }

    /// First virtual time at which the trace reaches `threshold`
    /// (time-to-quality, the Figure-1 comparison statistic).
    pub fn time_to(&self, threshold: f64) -> Option<f64> {
        self.points
            .iter()
            .find(|p| p.heldout >= threshold)
            .map(|p| p.vtime_s)
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::from("iter,vtime_s,wall_s,heldout,k,sigma_x,alpha\n");
        for p in &self.points {
            let _ = writeln!(
                out,
                "{},{:.6},{:.6},{:.4},{},{:.5},{:.4}",
                p.iter, p.vtime_s, p.wall_s, p.heldout, p.k, p.sigma_x, p.alpha
            );
        }
        out
    }

    pub fn save_csv(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir).ok();
        }
        std::fs::write(path, self.to_csv())
            .with_context(|| format!("writing {}", path.display()))
    }

    /// Column-major JSON export. Unlike [`to_csv`](Self::to_csv) (which
    /// rounds for readability), numbers serialise with Rust's
    /// shortest-roundtrip formatting, so `.json` trace files preserve
    /// every f64 bit — `pibp diagnose` prefers them.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("label", Json::Str(self.label.clone())),
            ("iter", Json::Arr(self.points.iter().map(|p| Json::Num(p.iter as f64)).collect())),
            ("vtime_s", Json::arr_f64(&self.points.iter().map(|p| p.vtime_s).collect::<Vec<_>>())),
            ("wall_s", Json::arr_f64(&self.points.iter().map(|p| p.wall_s).collect::<Vec<_>>())),
            ("heldout", Json::arr_f64(&self.points.iter().map(|p| p.heldout).collect::<Vec<_>>())),
            ("k", Json::Arr(self.points.iter().map(|p| Json::Num(p.k as f64)).collect())),
            ("sigma_x", Json::arr_f64(&self.points.iter().map(|p| p.sigma_x).collect::<Vec<_>>())),
            ("alpha", Json::arr_f64(&self.points.iter().map(|p| p.alpha).collect::<Vec<_>>())),
        ])
    }

    /// Write the trace to `path`, format chosen by extension: `.json`
    /// gets the full-precision JSON export, anything else the CSV.
    pub fn save_auto(&self, path: &Path) -> Result<()> {
        if path.extension().and_then(|e| e.to_str()) == Some("json") {
            if let Some(dir) = path.parent() {
                std::fs::create_dir_all(dir).ok();
            }
            std::fs::write(path, format!("{}\n", self.to_json()))
                .with_context(|| format!("writing {}", path.display()))
        } else {
            self.save_csv(path)
        }
    }

    /// Load a trace exported by `--trace-out` (or [`save_csv`](Self::save_csv)/
    /// [`save_auto`](Self::save_auto)), dispatching on the `.json`
    /// extension. The label falls back to the file stem when the file
    /// doesn't carry one.
    pub fn load(path: &Path) -> Result<Trace> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading trace {}", path.display()))?;
        let mut t = if path.extension().and_then(|e| e.to_str()) == Some("json") {
            Trace::from_json_text(&text)
                .with_context(|| format!("parsing trace {}", path.display()))?
        } else {
            Trace::from_csv(&text)
                .with_context(|| format!("parsing trace {}", path.display()))?
        };
        if t.label.is_empty() {
            t.label = path
                .file_stem()
                .and_then(|s| s.to_str())
                .unwrap_or("trace")
                .to_string();
        }
        Ok(t)
    }

    /// Parse the CSV format [`to_csv`](Self::to_csv) writes (fixed
    /// 7-column header). CSV values are rounded at export; use the
    /// JSON format where full precision matters.
    pub fn from_csv(text: &str) -> Result<Trace> {
        let mut lines = text.lines();
        let header = lines.next().unwrap_or("").trim();
        if header != "iter,vtime_s,wall_s,heldout,k,sigma_x,alpha" {
            anyhow::bail!("unrecognised trace CSV header '{header}'");
        }
        let mut t = Trace::new("");
        for (ln, line) in lines.enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let cols: Vec<&str> = line.split(',').collect();
            if cols.len() != 7 {
                anyhow::bail!("trace CSV row {} has {} columns, want 7", ln + 2, cols.len());
            }
            let f = |i: usize| -> Result<f64> {
                cols[i]
                    .trim()
                    .parse::<f64>()
                    .with_context(|| format!("trace CSV row {} col {}", ln + 2, i + 1))
            };
            t.push(TracePoint {
                iter: f(0)? as usize,
                vtime_s: f(1)?,
                wall_s: f(2)?,
                heldout: f(3)?,
                k: f(4)? as usize,
                sigma_x: f(5)?,
                alpha: f(6)?,
            });
        }
        Ok(t)
    }

    /// Parse the JSON format [`to_json`](Self::to_json) writes.
    /// `iter` and `heldout` are required; series absent from older
    /// exports (`wall_s`, `sigma_x`, `alpha`) default to 0.
    pub fn from_json_text(text: &str) -> Result<Trace> {
        let doc = Json::parse(text)?;
        let series = |key: &str| -> Option<Vec<f64>> {
            doc.get(key)?
                .as_arr()?
                .iter()
                .map(Json::as_f64)
                .collect::<Option<Vec<f64>>>()
        };
        let iters = series("iter")
            .ok_or_else(|| anyhow::anyhow!("trace JSON missing 'iter' array"))?;
        let heldout = series("heldout")
            .ok_or_else(|| anyhow::anyhow!("trace JSON missing 'heldout' array"))?;
        if heldout.len() != iters.len() {
            anyhow::bail!("trace JSON series lengths disagree");
        }
        let n = iters.len();
        let opt = |key: &str| -> Result<Vec<f64>> {
            match series(key) {
                Some(v) if v.len() == n => Ok(v),
                Some(_) => anyhow::bail!("trace JSON '{key}' length disagrees"),
                None => Ok(vec![0.0; n]),
            }
        };
        let vtime = opt("vtime_s")?;
        let wall = opt("wall_s")?;
        let k = opt("k")?;
        let sigma_x = opt("sigma_x")?;
        let alpha = opt("alpha")?;
        let mut t = Trace::new(
            doc.get("label").and_then(Json::as_str).unwrap_or("").to_string(),
        );
        for i in 0..n {
            t.push(TracePoint {
                iter: iters[i] as usize,
                vtime_s: vtime[i],
                wall_s: wall[i],
                heldout: heldout[i],
                k: k[i] as usize,
                sigma_x: sigma_x[i],
                alpha: alpha[i],
            });
        }
        Ok(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(n: usize) -> Trace {
        let mut t = Trace::new("test");
        for i in 0..n {
            t.push(TracePoint {
                iter: i,
                vtime_s: i as f64 * 0.5,
                wall_s: i as f64,
                heldout: -100.0 + i as f64,
                k: 4,
                sigma_x: 0.5,
                alpha: 1.0,
            });
        }
        t
    }

    #[test]
    fn plateau_uses_tail() {
        let t = mk(10);
        // last 20% = points 8, 9 → heldout −92, −91
        assert!((t.plateau(0.2) - (-91.5)).abs() < 1e-9);
    }

    #[test]
    fn time_to_threshold() {
        let t = mk(10);
        assert_eq!(t.time_to(-95.0), Some(2.5));
        assert_eq!(t.time_to(0.0), None);
    }

    #[test]
    fn plateau_and_time_to_on_empty_trace() {
        let t = Trace::new("empty");
        assert!(t.plateau(0.25).is_nan(), "empty plateau must be NaN");
        assert!(t.plateau(0.0).is_nan());
        assert_eq!(t.time_to(-100.0), None);
        assert!(t.last().is_none());
    }

    #[test]
    fn plateau_and_time_to_on_single_point_trace() {
        let t = mk(1); // one point: heldout −100 at vtime 0
        assert!((t.plateau(0.25) - (-100.0)).abs() < 1e-12);
        // frac 0 still averages at least the final point, never 0/0
        assert!((t.plateau(0.0) - (-100.0)).abs() < 1e-12);
        assert_eq!(t.time_to(-100.0), Some(0.0));
        assert_eq!(t.time_to(-99.0), None);
    }

    #[test]
    fn csv_roundtrippable_shape() {
        let t = mk(3);
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 4);
        assert!(csv.starts_with("iter,"));
    }

    #[test]
    fn json_contains_series() {
        let t = mk(2);
        let j = t.to_json();
        assert_eq!(j.get("heldout").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn json_export_roundtrips_bit_exactly() {
        let mut t = Trace::new("rt");
        for i in 0..5 {
            t.push(TracePoint {
                iter: i,
                vtime_s: 0.1 + i as f64 / 3.0,
                wall_s: 0.2 + i as f64 / 7.0,
                heldout: -1234.567_890_123 + (i as f64).sin(),
                k: 3 + i,
                sigma_x: 0.123_456_789 * (i + 1) as f64,
                alpha: 1.0 / (i + 1) as f64,
            });
        }
        let text = t.to_json().to_string();
        let back = Trace::from_json_text(&text).expect("parses");
        assert_eq!(back.label, "rt");
        assert_eq!(back.points.len(), t.points.len());
        for (a, b) in t.points.iter().zip(&back.points) {
            assert_eq!(a.iter, b.iter);
            assert_eq!(a.k, b.k);
            for (x, y) in [
                (a.vtime_s, b.vtime_s),
                (a.wall_s, b.wall_s),
                (a.heldout, b.heldout),
                (a.sigma_x, b.sigma_x),
                (a.alpha, b.alpha),
            ] {
                assert_eq!(x.to_bits(), y.to_bits(), "json must be full-precision");
            }
        }
    }

    #[test]
    fn csv_export_roundtrips_to_printed_precision() {
        let t = mk(4);
        let back = Trace::from_csv(&t.to_csv()).expect("parses");
        assert_eq!(back.points.len(), 4);
        for (a, b) in t.points.iter().zip(&back.points) {
            assert_eq!(a.iter, b.iter);
            assert_eq!(a.k, b.k);
            assert!((a.heldout - b.heldout).abs() < 1e-3);
            assert!((a.vtime_s - b.vtime_s).abs() < 1e-5);
        }
        assert!(Trace::from_csv("bogus\n1,2").is_err());
        assert!(Trace::from_csv("iter,vtime_s,wall_s,heldout,k,sigma_x,alpha\n1,2\n").is_err());
    }

    #[test]
    fn push_reports_thinning_decision() {
        let mut t = Trace::new("kept");
        t.set_thinning(2);
        let p = TracePoint {
            iter: 0, vtime_s: 0.0, wall_s: 0.0, heldout: -1.0,
            k: 0, sigma_x: 0.5, alpha: 1.0,
        };
        assert!(t.push(p));
        assert!(!t.push(p));
        assert!(t.push(p));
    }

    #[test]
    fn thinning_keeps_every_kth_offered_point() {
        let mut t = Trace::new("thin");
        t.set_thinning(3);
        for i in 0..10 {
            t.push(TracePoint {
                iter: i,
                vtime_s: 0.0,
                wall_s: 0.0,
                heldout: -1.0,
                k: 0,
                sigma_x: 0.5,
                alpha: 1.0,
            });
        }
        // offered indices 0..10, stride 3 ⇒ kept offered-indices 0,3,6,9
        let kept: Vec<usize> = t.points.iter().map(|p| p.iter).collect();
        assert_eq!(kept, vec![0, 3, 6, 9]);
        assert_eq!(t.thinning(), (3, 10));
    }

    #[test]
    fn thinning_schedule_survives_restore() {
        let mut t = Trace::new("thin");
        t.set_thinning(2);
        for i in 0..3 {
            t.push(TracePoint {
                iter: i, vtime_s: 0.0, wall_s: 0.0, heldout: -1.0,
                k: 0, sigma_x: 0.5, alpha: 1.0,
            });
        }
        // simulate resume: rebuild and continue the offered sequence
        let (stride, seen) = t.thinning();
        let mut resumed = Trace::new("thin");
        resumed.points = t.points.clone();
        resumed.restore_thinning(stride, seen);
        for i in 3..7 {
            resumed.push(TracePoint {
                iter: i, vtime_s: 0.0, wall_s: 0.0, heldout: -1.0,
                k: 0, sigma_x: 0.5, alpha: 1.0,
            });
        }
        let kept: Vec<usize> = resumed.points.iter().map(|p| p.iter).collect();
        assert_eq!(kept, vec![0, 2, 4, 6]);
    }

    #[test]
    fn default_and_zero_stride_keep_everything() {
        let mut t = Trace::default();
        for i in 0..4 {
            t.push(TracePoint {
                iter: i, vtime_s: 0.0, wall_s: 0.0, heldout: -1.0,
                k: 0, sigma_x: 0.5, alpha: 1.0,
            });
        }
        assert_eq!(t.points.len(), 4);
    }
}
