//! Hand-rolled CLI parser (in-tree `clap` replacement): subcommands,
//! typed flags with defaults, `--set key=value` repeated overrides, and
//! generated `--help` text.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Declarative flag spec.
#[derive(Clone, Debug)]
pub struct FlagSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// None ⇒ boolean flag; Some(default) ⇒ takes a value.
    pub default: Option<&'static str>,
    /// May be repeated (collects into a list), e.g. --set.
    pub repeated: bool,
}

/// One subcommand.
#[derive(Clone, Debug)]
pub struct CommandSpec {
    pub name: &'static str,
    pub about: &'static str,
    pub flags: Vec<FlagSpec>,
}

/// The whole CLI.
#[derive(Clone, Debug)]
pub struct Cli {
    pub bin: &'static str,
    pub about: &'static str,
    pub commands: Vec<CommandSpec>,
}

/// Parse result.
#[derive(Clone, Debug)]
pub struct Parsed {
    pub command: String,
    values: BTreeMap<String, String>,
    lists: BTreeMap<String, Vec<String>>,
    bools: BTreeMap<String, bool>,
}

impl Parsed {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_usize(&self, name: &str) -> Result<usize> {
        let v = self.values.get(name).map(|s| s.as_str()).unwrap_or("");
        v.parse().map_err(|_| anyhow::anyhow!("--{name} wants an integer, got '{v}'"))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64> {
        let v = self.values.get(name).map(|s| s.as_str()).unwrap_or("");
        v.parse().map_err(|_| anyhow::anyhow!("--{name} wants a number, got '{v}'"))
    }

    pub fn get_bool(&self, name: &str) -> bool {
        self.bools.get(name).copied().unwrap_or(false)
    }

    pub fn get_list(&self, name: &str) -> &[String] {
        self.lists.get(name).map(|v| v.as_slice()).unwrap_or(&[])
    }
}

impl Cli {
    pub fn help(&self) -> String {
        let mut out = format!("{} — {}\n\nUSAGE:\n  {} <command> [flags]\n\nCOMMANDS:\n",
                              self.bin, self.about, self.bin);
        for c in &self.commands {
            out.push_str(&format!("  {:<12} {}\n", c.name, c.about));
        }
        out.push_str("\nRun `");
        out.push_str(self.bin);
        out.push_str(" <command> --help` for that command's flags.\n");
        out
    }

    pub fn command_help(&self, cmd: &CommandSpec) -> String {
        let mut out = format!("{} {} — {}\n\nFLAGS:\n", self.bin, cmd.name, cmd.about);
        for f in &cmd.flags {
            let val = match (&f.default, f.repeated) {
                (Some(d), false) => format!("<value> (default {d})"),
                (Some(_), true) => "<value> (repeatable)".to_string(),
                (None, _) => String::new(),
            };
            out.push_str(&format!("  --{:<22} {} {}\n", f.name, f.help, val));
        }
        out
    }

    /// Parse argv (excluding the binary name). `--help` anywhere returns
    /// Err with the help text — callers print it and exit 0.
    pub fn parse(&self, args: &[String]) -> Result<Parsed> {
        if args.is_empty() || args[0] == "--help" || args[0] == "-h" || args[0] == "help" {
            bail!("{}", self.help());
        }
        let cmd_name = &args[0];
        let Some(cmd) = self.commands.iter().find(|c| c.name == cmd_name) else {
            bail!("unknown command '{cmd_name}'\n\n{}", self.help());
        };
        let mut parsed = Parsed {
            command: cmd.name.to_string(),
            values: BTreeMap::new(),
            lists: BTreeMap::new(),
            bools: BTreeMap::new(),
        };
        for f in &cmd.flags {
            if let (Some(d), false) = (&f.default, f.repeated) {
                parsed.values.insert(f.name.to_string(), d.to_string());
            }
        }
        let mut i = 1;
        while i < args.len() {
            let arg = &args[i];
            if arg == "--help" || arg == "-h" {
                bail!("{}", self.command_help(cmd));
            }
            let Some(name) = arg.strip_prefix("--") else {
                bail!("unexpected positional argument '{arg}'");
            };
            // --name=value form
            let (name, inline) = match name.split_once('=') {
                Some((n, v)) => (n, Some(v.to_string())),
                None => (name, None),
            };
            let Some(spec) = cmd.flags.iter().find(|f| f.name == name) else {
                bail!("unknown flag --{name} for '{}'\n\n{}", cmd.name,
                      self.command_help(cmd));
            };
            match (&spec.default, spec.repeated) {
                (None, _) => {
                    if inline.is_some() {
                        bail!("--{name} takes no value");
                    }
                    parsed.bools.insert(name.to_string(), true);
                }
                (Some(_), repeated) => {
                    let value = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            if i >= args.len() {
                                bail!("--{name} needs a value");
                            }
                            args[i].clone()
                        }
                    };
                    if repeated {
                        parsed.lists.entry(name.to_string()).or_default().push(value);
                    } else {
                        parsed.values.insert(name.to_string(), value);
                    }
                }
            }
            i += 1;
        }
        Ok(parsed)
    }
}

/// Flag helpers.
pub fn flag(name: &'static str, help: &'static str, default: &'static str) -> FlagSpec {
    FlagSpec { name, help, default: Some(default), repeated: false }
}

pub fn switch(name: &'static str, help: &'static str) -> FlagSpec {
    FlagSpec { name, help, default: None, repeated: false }
}

pub fn repeated(name: &'static str, help: &'static str) -> FlagSpec {
    FlagSpec { name, help, default: Some(""), repeated: true }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli {
            bin: "pibp",
            about: "test",
            commands: vec![CommandSpec {
                name: "run",
                about: "run it",
                flags: vec![
                    flag("iters", "iterations", "100"),
                    flag("sampler", "which sampler", "hybrid"),
                    switch("quiet", "no output"),
                    repeated("set", "override"),
                ],
            }],
        }
    }

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let p = cli().parse(&argv("run --iters 50 --set a=1 --set b=2")).unwrap();
        assert_eq!(p.command, "run");
        assert_eq!(p.get_usize("iters").unwrap(), 50);
        assert_eq!(p.get("sampler"), Some("hybrid"));
        assert_eq!(p.get_list("set"), &["a=1", "b=2"]);
        assert!(!p.get_bool("quiet"));
    }

    #[test]
    fn equals_form_and_switch() {
        let p = cli().parse(&argv("run --iters=7 --quiet")).unwrap();
        assert_eq!(p.get_usize("iters").unwrap(), 7);
        assert!(p.get_bool("quiet"));
    }

    #[test]
    fn errors_are_helpful() {
        let c = cli();
        assert!(c.parse(&argv("nope")).unwrap_err().to_string().contains("unknown command"));
        assert!(c.parse(&argv("run --bogus 1")).unwrap_err().to_string().contains("unknown flag"));
        assert!(c.parse(&argv("run --iters")).unwrap_err().to_string().contains("needs a value"));
        assert!(c.parse(&argv("run --quiet=1")).unwrap_err().to_string().contains("takes no value"));
        let help = c.parse(&argv("--help")).unwrap_err().to_string();
        assert!(help.contains("COMMANDS"));
        let chelp = c.parse(&argv("run --help")).unwrap_err().to_string();
        assert!(chelp.contains("--iters"));
    }

    #[test]
    fn bad_types_reported() {
        let p = cli().parse(&argv("run --iters abc")).unwrap();
        assert!(p.get_usize("iters").is_err());
    }
}
