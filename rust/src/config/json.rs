//! Minimal JSON parser/serialiser (in-tree `serde_json` replacement).
//!
//! Full RFC-8259 grammar minus `\u` surrogate-pair pedantry (accepted,
//! decoded best-effort). Used for `artifacts/manifest.json`, experiment
//! configs and metric exports. Recursive-descent, zero dependencies.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }

    // -- accessors -------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|x| *x >= 0.0 && x.fract() == 0.0).map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // -- builders --------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected '{}' at byte {}", b as char, self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => bail!("unexpected byte '{}' at {}", c as char, self.pos),
            None => bail!("unexpected end of input"),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(
                                &self.bytes[self.pos + 1..self.pos + 5],
                            )?;
                            let code = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => bail!("bad escape at byte {}", self.pos),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a run of plain bytes (UTF-8 passes through)
                    let start = self.pos;
                    while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\') {
                        self.pos += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.pos),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            map.insert(key, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.pos),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_f64().unwrap(), 2.0);
        assert!(arr[2].get("b").unwrap().is_null());
    }

    #[test]
    fn roundtrip_display_parse() {
        let src = r#"{"entries":[{"b":256,"file":"z.hlo.txt","k":8,"shapes":[[1,2],[3,4]]}],"version":1}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".into());
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""é""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn as_usize_guards() {
        assert_eq!(Json::Num(5.0).as_usize(), Some(5));
        assert_eq!(Json::Num(5.5).as_usize(), None);
        assert_eq!(Json::Num(-1.0).as_usize(), None);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let text = std::fs::read_to_string(
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json"),
        );
        if let Ok(text) = text {
            let v = Json::parse(&text).unwrap();
            assert!(v.get("entries").unwrap().as_arr().unwrap().len() > 10);
        }
    }
}
